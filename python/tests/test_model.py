"""L2 shard programs vs the monolithic reference model.

The key theorem of 1D TP: summing the per-worker branch partials
(= all-reduce) and adding residuals reproduces the unsharded model
exactly.  Also checks pruning semantics, the migration slice programs,
and the golden-bundle engine simulation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import golden as G
from compile import model as M

CFG = M.ModelCfg("t", hs=32, depth=2, heads=4, e=4, bs=2, img=16)


@pytest.fixture(scope="module")
def setup():
    full = M.init_full_params(CFG, jax.random.PRNGKey(0))
    shards = [[M.shard_block(blk, w, CFG) for blk in full["blocks"]]
              for w in range(CFG.e)]
    patches, labels = G.synth_batch(CFG, 7)
    return full, shards, jnp.asarray(patches), jnp.asarray(labels)


def _full_idx(k):
    return jnp.arange(k, dtype=jnp.int32), jnp.ones((k,), jnp.float32)


class TestConfig:
    def test_seq_matches_paper(self):
        # 32x32 image, patch 4 → 64 patches + cls = the paper's sql=65
        assert M.PRESETS["vit-tiny"].seq == 65

    def test_param_counts(self):
        cfg = M.PRESETS["vit-100m"]
        assert 80e6 < cfg.params_total() < 120e6
        assert abs(cfg.params_per_worker() * cfg.e
                   - cfg.params_total()) / cfg.params_total() < 0.2

    def test_keep_count_buckets(self):
        assert M.keep_count(256, 1.0) == 256
        assert M.keep_count(256, 0.5) == 128
        assert M.keep_count(256, 0.125) == 32
        assert M.keep_count(16, 0.125) == 8  # floor at lane width

    def test_shards_tile_full_params(self):
        cfg = CFG
        full = M.init_full_params(cfg, jax.random.PRNGKey(1))
        blk = full["blocks"][0]
        ws = [M.shard_block(blk, w, cfg) for w in range(cfg.e)]
        w1_cat = jnp.concatenate([s["w1"] for s in ws], axis=1)
        np.testing.assert_allclose(
            w1_cat, blk["w1"].reshape(cfg.hs, cfg.e * cfg.ffl))
        w2_cat = jnp.concatenate([s["w2"] for s in ws], axis=0)
        np.testing.assert_allclose(
            w2_cat, blk["w2"].reshape(cfg.e * cfg.ffl, cfg.hs))


class TestTPEquivalence:
    def test_attn_partials_sum_to_full(self, setup):
        full, shards, patches, labels = setup
        x = M.embed_fwd(patches, full["w_patch"], full["pos"], full["cls"], CFG)
        idx, mask = _full_idx(CFG.hs)
        part = sum(
            M.attn_fwd(x, s["ln1_g"], s["ln1_b"], s["wqkv"], s["wo"],
                       idx, mask, CFG)
            for s in (shards[w][0] for w in range(CFG.e)))
        # monolithic attention of block 0
        blk = full["blocks"][0]
        b, s_, hs = x.shape
        xln = M.layernorm(x, blk["ln1_g"], blk["ln1_b"])
        qkv = (xln.reshape(b * s_, hs) @ blk["wqkv"].reshape(hs, 3 * hs)
               ).reshape(b, s_, 3, CFG.heads, CFG.hd)
        q, k, v = (qkv[:, :, i].transpose(0, 2, 1, 3) for i in range(3))
        att = jax.nn.softmax(
            jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(CFG.hd), axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3)
        want = (o.reshape(b * s_, hs) @ blk["wo"].reshape(hs, hs)
                ).reshape(b, s_, hs)
        np.testing.assert_allclose(part, want, rtol=1e-4, atol=1e-4)

    def test_mlp_partials_sum_to_full(self, setup):
        full, shards, patches, labels = setup
        x = M.embed_fwd(patches, full["w_patch"], full["pos"], full["cls"], CFG)
        i1, m1 = _full_idx(CFG.hs)
        i2, m2 = _full_idx(CFG.ffl)
        part = sum(
            M.mlp_fwd(x, s["ln2_g"], s["ln2_b"], s["w1"], s["w2"],
                      i1, m1, i2, m2, CFG)
            for s in (shards[w][0] for w in range(CFG.e)))
        blk = full["blocks"][0]
        b, s_, hs = x.shape
        xln = M.layernorm(x, blk["ln2_g"], blk["ln2_b"]).reshape(b * s_, hs)
        h = M.gelu(xln @ blk["w1"].reshape(hs, CFG.e * CFG.ffl))
        want = (h @ blk["w2"].reshape(CFG.e * CFG.ffl, hs)).reshape(b, s_, hs)
        np.testing.assert_allclose(part, want, rtol=1e-4, atol=1e-4)

    def test_engine_sim_matches_reference_model(self, setup):
        full, shards, patches, labels = setup
        loss, _, _, _, _ = G.sim_step(full, shards, patches, labels, CFG)
        want, _ = M.reference_loss(full, patches, labels, CFG)
        np.testing.assert_allclose(loss, float(want), rtol=1e-4)

    def test_sgd_descends(self, setup):
        full, shards, patches, labels = setup
        f, s = full, shards
        losses = []
        for _ in range(3):
            loss, _, f, s, _ = G.sim_step(f, s, patches, labels, CFG)
            losses.append(loss)
        assert losses[-1] < losses[0]


class TestPruning:
    def test_pruned_step_changes_loss_slightly(self, setup):
        full, shards, patches, labels = setup
        base, _, _, _, _ = G.sim_step(full, shards, patches, labels, CFG)
        kq = M.keep_count(CFG.hs, 0.5)
        kf = M.keep_count(CFG.ffl, 0.5)
        qi = jnp.asarray(np.arange(0, 2 * kq, 2) % CFG.hs, jnp.int32)
        fi = jnp.asarray(np.arange(0, 2 * kf, 2) % CFG.ffl, jnp.int32)
        pruned, _, _, _, _ = G.sim_step(
            full, shards, patches, labels, CFG,
            qkv_idx=qi, ffl_idx=fi, straggler=1)
        assert pruned != pytest.approx(base, rel=1e-6)  # pruning has effect
        assert abs(pruned - base) / abs(base) < 0.5     # but bounded

    def test_mlp_co_prune_never_materializes_pruned_cols(self, setup):
        # mlp_fwd with idx2 of size kf produces the same value as zeroing
        # the pruned FC1 cols / FC2 rows in the dense computation.
        full, shards, patches, labels = setup
        x = M.embed_fwd(patches, full["w_patch"], full["pos"], full["cls"], CFG)
        s = shards[0][0]
        kf = CFG.ffl // 2
        fi = jnp.asarray(np.arange(kf) * 2, jnp.int32)
        i1, m1 = _full_idx(CFG.hs)
        got = M.mlp_fwd(x, s["ln2_g"], s["ln2_b"], s["w1"], s["w2"],
                        i1, m1, fi, jnp.ones((kf,), jnp.float32), CFG)
        b, s_, hs = x.shape
        xln = M.layernorm(x, s["ln2_g"], s["ln2_b"]).reshape(b * s_, hs)
        w1z = s["w1"][:, fi]
        w2z = s["w2"][fi, :]
        want = (M.gelu(xln @ w1z) @ w2z).reshape(b, s_, hs)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestMigrationSlices:
    def test_slices_partition_ffn_exactly(self, setup):
        """straggler's kept slice + receivers' migrated slices == full FFN
        branch (the exactness the paper claims for migration)."""
        full, shards, patches, labels = setup
        x = M.embed_fwd(patches, full["w_patch"], full["pos"], full["cls"], CFG)
        s = shards[0][0]
        i1, m1 = _full_idx(CFG.hs)
        i2, m2 = _full_idx(CFG.ffl)
        want = M.mlp_fwd(x, s["ln2_g"], s["ln2_b"], s["w1"], s["w2"],
                         i1, m1, i2, m2, CFG)
        # straggler keeps first half; two receivers take a quarter each
        kf = CFG.ffl // 2
        kept = jnp.arange(kf, dtype=jnp.int32)
        got = M.mlp_fwd(x, s["ln2_g"], s["ln2_b"], s["w1"], s["w2"],
                        i1, m1, kept, jnp.ones((kf,), jnp.float32), CFG)
        kb = CFG.ffl // 4
        mig_fwd = M.build_mlp_mig_fwd(kb)
        for r in range(2):
            sl = jnp.arange(kf + r * kb, kf + (r + 1) * kb, dtype=jnp.int32)
            w1c = s["w1"][:, sl]
            w2c = s["w2"][sl, :]
            got = got + mig_fwd(x, s["ln2_g"], s["ln2_b"], w1c, w2c)[0]
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_padded_slice_is_exact(self, setup):
        full, shards, patches, labels = setup
        x = M.embed_fwd(patches, full["w_patch"], full["pos"], full["cls"], CFG)
        s = shards[0][0]
        kb = CFG.ffl // 2
        sl = jnp.arange(kb // 2, dtype=jnp.int32)  # only half the bucket used
        w1c = jnp.zeros((CFG.hs, kb))
        w1c = w1c.at[:, : kb // 2].set(s["w1"][:, sl])
        w2c = jnp.zeros((kb, CFG.hs))
        w2c = w2c.at[: kb // 2, :].set(s["w2"][sl, :])
        mig_fwd = M.build_mlp_mig_fwd(kb)
        got = mig_fwd(x, s["ln2_g"], s["ln2_b"], w1c, w2c)[0]
        want = mig_fwd(
            x, s["ln2_g"], s["ln2_b"], s["w1"][:, sl], s["w2"][sl, :])[0] \
            if False else None
        # direct dense check instead (kb//2-sized slice):
        b, s_, hs = x.shape
        xln = M.layernorm(x, s["ln2_g"], s["ln2_b"]).reshape(b * s_, hs)
        want = (M.gelu(xln @ s["w1"][:, sl]) @ s["w2"][sl, :]).reshape(b, s_, hs)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_mig_bwd_grads_match_dense_slice(self, setup):
        full, shards, patches, labels = setup
        x = M.embed_fwd(patches, full["w_patch"], full["pos"], full["cls"], CFG)
        s = shards[0][0]
        kb = CFG.ffl // 4
        sl = jnp.arange(kb, dtype=jnp.int32)
        w1c, w2c = s["w1"][:, sl], s["w2"][sl, :]
        dy = jnp.ones_like(x) * 0.01
        mig_bwd = M.build_mlp_mig_bwd(kb)
        dx, dg, db, dw1c, dw2c = mig_bwd(x, s["ln2_g"], s["ln2_b"],
                                         w1c, w2c, dy)

        def dense(x_, g_, b_, w1_, w2_):
            bshp, s_, hs = x_.shape
            xln = M.layernorm(x_, g_, b_).reshape(bshp * s_, hs)
            return jnp.sum(
                (M.gelu(xln @ w1_) @ w2_).reshape(bshp, s_, hs) * dy)

        grads = jax.grad(dense, argnums=(0, 1, 2, 3, 4))(
            x, s["ln2_g"], s["ln2_b"], w1c, w2c)
        for got, want in zip((dx, dg, db, dw1c, dw2c), grads):
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestHead:
    def test_head_grads_match_autodiff(self, setup):
        full, shards, patches, labels = setup
        x = M.embed_fwd(patches, full["w_patch"], full["pos"], full["cls"], CFG)
        hf = M.build_head_fwdbwd(CFG)
        loss, ncorrect, dx, dg, db, dwh, dbh = hf(
            x, full["lnf_g"], full["lnf_b"], full["w_head"], full["b_head"],
            labels)

        def lf(x_, g_, b_, wh_, bh_):
            return M.head_loss(x_, g_, b_, wh_, bh_, labels, CFG)[0]

        want = jax.grad(lf, argnums=(0, 1, 2, 3, 4))(
            x, full["lnf_g"], full["lnf_b"], full["w_head"], full["b_head"])
        for got, w_ in zip((dx, dg, db, dwh, dbh), want):
            np.testing.assert_allclose(got, w_, rtol=1e-4, atol=1e-4)
        assert 0 <= int(ncorrect) <= CFG.bs

    def test_infer_matches_fwdbwd_loss(self, setup):
        full, shards, patches, labels = setup
        x = M.embed_fwd(patches, full["w_patch"], full["pos"], full["cls"], CFG)
        hf = M.build_head_fwdbwd(CFG)
        hi = M.build_head_infer(CFG)
        args = (x, full["lnf_g"], full["lnf_b"], full["w_head"],
                full["b_head"], labels)
        np.testing.assert_allclose(hf(*args)[0], hi(*args)[0], rtol=1e-6)
