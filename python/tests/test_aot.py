"""AOT path: manifest integrity + HLO text interchange format."""

import json
import os

import pytest

from compile import aot
from compile import model as M

CFG = M.ModelCfg("aot-t", hs=32, depth=1, heads=4, e=4, bs=2, img=16)


class TestInventory:
    def test_all_roles_present(self):
        inv = aot.executable_inventory(CFG)
        roles = {meta["role"] for _, _, _, _, meta in inv}
        assert roles == {
            "embed_fwd", "embed_bwd", "head_fwdbwd", "head_infer",
            "attn_fwd", "attn_bwd", "mlp_fwd", "mlp_bwd",
            "mlp_mig_fwd", "mlp_mig_bwd"}

    def test_bucket_counts(self):
        inv = aot.executable_inventory(CFG)
        names = [n for n, *_ in inv]
        assert sum(n.startswith("attn_fwd") for n in names) == len(M.KEEP_FRACS)
        # diagonal + straggler-side (g00, b) column
        assert sum(n.startswith("mlp_fwd") for n in names) == \
            2 * len(M.KEEP_FRACS) - 1
        mig_kbs = {M.keep_count(CFG.ffl, f) for f in M.MIG_FRACS}
        assert sum(n.startswith("mlp_mig_fwd") for n in names) == len(mig_kbs)

    def test_names_unique(self):
        inv = aot.executable_inventory(CFG)
        names = [n for n, *_ in inv]
        assert len(names) == len(set(names))

    def test_input_specs_have_dims_and_dtype(self):
        for name, _, ins, outs, _ in aot.executable_inventory(CFG):
            for spec in ins + outs:
                assert spec["dtype"] in ("f32", "i32"), name
                assert all(isinstance(d, int) and d > 0 for d in spec["dims"])


class TestLowering:
    @pytest.fixture(scope="class")
    def built(self, tmp_path_factory):
        out = str(tmp_path_factory.mktemp("artifacts"))
        aot.build_model(CFG, out, with_golden=False, verbose=False)
        return os.path.join(out, CFG.name)

    def test_manifest_parses(self, built):
        with open(os.path.join(built, "manifest.json")) as f:
            man = json.load(f)
        assert man["model"]["hs"] == CFG.hs
        assert len(man["executables"]) == len(aot.executable_inventory(CFG))

    def test_hlo_is_text_format(self, built):
        # xla_extension 0.5.1 requires the TEXT parser path (64-bit proto
        # ids are rejected) — every artifact must be parseable HLO text.
        with open(os.path.join(built, "manifest.json")) as f:
            man = json.load(f)
        for ex in man["executables"]:
            with open(os.path.join(built, ex["file"])) as f:
                head = f.read(200)
            assert head.startswith("HloModule"), ex["name"]

    def test_entry_params_match_manifest(self, built):
        with open(os.path.join(built, "manifest.json")) as f:
            man = json.load(f)
        for ex in man["executables"]:
            with open(os.path.join(built, ex["file"])) as f:
                text = f.read()
            lines = text.splitlines()
            start = next(i for i, l in enumerate(lines)
                         if l.startswith("ENTRY"))
            nparams = 0
            for l in lines[start + 1:]:
                if l.startswith("}"):
                    break
                if "parameter(" in l:
                    nparams += 1
            assert nparams == len(ex["inputs"]), ex["name"]


class TestGoldenBundle:
    def test_roundtrip(self, tmp_path):
        from compile import golden as G
        import numpy as np
        import struct
        path = str(tmp_path / "g.bin")
        G.write_bundle(path, {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
                              "b": np.asarray([1, 2], np.int32)})
        with open(path, "rb") as f:
            hlen = struct.unpack("<I", f.read(4))[0]
            header = json.loads(f.read(hlen))
            data = f.read()
        assert [e["name"] for e in header["entries"]] == ["a", "b"]
        a = np.frombuffer(data[:24], "<f4").reshape(2, 3)
        np.testing.assert_allclose(a, np.arange(6).reshape(2, 3))
        b = np.frombuffer(data[24:32], "<i4")
        np.testing.assert_array_equal(b, [1, 2])
