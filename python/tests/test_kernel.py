"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes, pruning fractions, masks, and dtypes; every
property pins ``pruned_matmul`` (and its hand-written custom_vjp, which
encodes the paper's grad_input / grad_weight dataflows) against ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pruned_matmul, pruned_matmul_fwd_only, pick_block, vmem_bytes
from compile.kernels.ref import (
    grad_input_ref, grad_weight_ref, pruned_matmul_ref)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _case(rng, m, k, n, kp, dup_pad):
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    idx = np.sort(rng.choice(k, kp, replace=False)).astype(np.int32)
    mask = np.ones(kp, np.float32)
    if dup_pad and kp >= 2:
        # migration-style padding: duplicate indices neutralized by mask
        npad = kp // 4
        if npad:
            idx[-npad:] = idx[0]
            mask[-npad:] = 0.0
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(idx), jnp.asarray(mask)


dims = st.sampled_from([1, 2, 3, 4, 5, 8, 12, 16, 24, 32, 65, 128])
keeps = st.sampled_from([1, 2, 4, 8, 12, 16, 24, 32])


class TestForward:
    @given(m=dims, k=dims, n=dims, kp=keeps, dup=st.booleans(),
           seed=st.integers(0, 2**16))
    def test_matches_oracle(self, m, k, n, kp, dup, seed):
        kp = min(kp, k)
        x, w, idx, mask = _case(np.random.default_rng(seed), m, k, n, kp, dup)
        got = pruned_matmul_fwd_only(x, w, idx, mask)
        want = pruned_matmul_ref(x, w, idx, mask)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_full_keep_is_plain_matmul(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
        idx = jnp.arange(32, dtype=jnp.int32)
        mask = jnp.ones(32, jnp.float32)
        np.testing.assert_allclose(
            pruned_matmul_fwd_only(x, w, idx, mask), x @ w,
            rtol=1e-5, atol=1e-5)

    def test_zero_mask_zero_output(self):
        rng = np.random.default_rng(1)
        x, w, idx, mask = _case(rng, 8, 16, 8, 8, False)
        out = pruned_matmul_fwd_only(x, w, idx, jnp.zeros_like(mask))
        np.testing.assert_allclose(out, np.zeros_like(out), atol=0)

    def test_workload_scales_with_keep(self):
        # pruning halves the contraction → the oracle and the kernel agree
        # that only kept columns contribute (paper Fig. 2 left).
        rng = np.random.default_rng(2)
        x, w, idx, mask = _case(rng, 8, 64, 8, 32, False)
        got = pruned_matmul_fwd_only(x, w, idx, mask)
        dense = x @ w
        assert not np.allclose(got, dense, atol=1e-3)

    @given(seed=st.integers(0, 2**16))
    def test_jit_matches_eager(self, seed):
        x, w, idx, mask = _case(np.random.default_rng(seed), 8, 16, 8, 8, False)
        got = jax.jit(pruned_matmul_fwd_only)(x, w, idx, mask)
        np.testing.assert_allclose(
            got, pruned_matmul_fwd_only(x, w, idx, mask), rtol=1e-6)


class TestBackward:
    @given(m=dims, k=dims, n=dims, kp=keeps, seed=st.integers(0, 2**16))
    def test_grads_match_autodiff_of_oracle(self, m, k, n, kp, seed):
        kp = min(kp, k)
        x, w, idx, mask = _case(np.random.default_rng(seed), m, k, n, kp, False)

        def loss_kernel(x, w):
            return jnp.sum(pruned_matmul(x, w, idx, mask) ** 2)

        def loss_ref(x, w):
            return jnp.sum(pruned_matmul_ref(x, w, idx, mask) ** 2)

        gx, gw = jax.grad(loss_kernel, argnums=(0, 1))(x, w)
        rx, rw = jax.grad(loss_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-4)

    @given(seed=st.integers(0, 2**16))
    def test_grad_weight_zero_imputed_rows(self, seed):
        # Paper Fig. 2 right: pruned rows of grad_weight are exactly zero.
        rng = np.random.default_rng(seed)
        x, w, idx, mask = _case(rng, 8, 32, 8, 16, False)

        def loss(w):
            return jnp.sum(pruned_matmul(x, w, idx, mask))

        gw = jax.grad(loss)(w)
        pruned_rows = np.setdiff1d(np.arange(32), np.asarray(idx))
        np.testing.assert_allclose(np.asarray(gw)[pruned_rows], 0.0, atol=0)

    @given(seed=st.integers(0, 2**16))
    def test_grad_dataflows_match_explicit_formulas(self, seed):
        rng = np.random.default_rng(seed)
        x, w, idx, mask = _case(rng, 8, 32, 8, 16, True)
        dy = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)

        _, vjp = jax.vjp(lambda x, w: pruned_matmul(x, w, idx, mask), x, w)
        dx, dw = vjp(dy)
        np.testing.assert_allclose(
            dx, grad_input_ref(dy, w, idx, mask, 32), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            dw, grad_weight_ref(x, dy, idx, mask, 32), rtol=1e-4, atol=1e-4)


class TestBlocking:
    @given(n=st.integers(1, 600), tgt=st.sampled_from([8, 64, 128]))
    def test_pick_block_divides(self, n, tgt):
        b = pick_block(n, tgt)
        assert n % b == 0 and 1 <= b <= max(1, min(n, tgt))

    def test_vmem_budget_at_mxu_tiles(self):
        # DESIGN.md §9: (128,128,128) f32 tiles with a 768-wide gather
        # source stay far inside a 16 MiB/core VMEM budget.
        assert vmem_bytes(128, 128, 128, kfull=768) < 16 * 2**20 // 4
