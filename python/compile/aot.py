"""AOT compile path: lower every (role, bucket) shard program to HLO text.

Run once at build time (``make artifacts``); Python never executes at
training time.  Interchange format is **HLO text**, not a serialized
``HloModuleProto`` — jax >= 0.5 emits protos with 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Outputs, per model preset:

    artifacts/<model>/<exec>.hlo.txt      one per executable variant
    artifacts/<model>/manifest.json       model cfg + executable specs
    artifacts/<model>/golden.bin          (vit-tiny) cross-language golden

Executable inventory (DESIGN.md §3):
    embed_fwd, embed_bwd, head_fwdbwd, head_infer
    attn_fwd_<b>, attn_bwd_<b>             b ∈ γ buckets over hs
    mlp_fwd_<b1>_<b2>, mlp_bwd_<b1>_<b2>   diagonal (ZERO) + (g00, b)
                                           column (migration straggler side)
    mlp_mig_fwd_k<kb>, mlp_mig_bwd_k<kb>   receiver-side migration slices
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import golden as G
from . import model as M

F32, I32 = "f32", "i32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sds(dims, dtype=F32):
    return jax.ShapeDtypeStruct(
        tuple(dims), jnp.float32 if dtype == F32 else jnp.int32)


def _spec(name, dims, dtype=F32):
    return dict(name=name, dims=list(dims), dtype=dtype)


def executable_inventory(cfg: M.ModelCfg):
    """Yield (name, builder_fn, input_specs, output_specs, meta)."""
    b, s, s0 = cfg.bs, cfg.seq, cfg.seq0
    hs, pd, hsl, ffl, cl = cfg.hs, cfg.pd, cfg.hsl, cfg.ffl, cfg.classes
    x3 = ("x", (b, s, hs))
    inv = []

    inv.append(("embed_fwd", M.build_embed_fwd(cfg),
                [_spec("patches", (b, s0, pd)), _spec("w_patch", (pd, hs)),
                 _spec("pos", (s, hs)), _spec("cls", (hs,))],
                [_spec("x0", (b, s, hs))], dict(role="embed_fwd")))
    inv.append(("embed_bwd", M.build_embed_bwd(cfg),
                [_spec("patches", (b, s0, pd)), _spec("w_patch", (pd, hs)),
                 _spec("pos", (s, hs)), _spec("cls", (hs,)),
                 _spec("dy", (b, s, hs))],
                [_spec("dw_patch", (pd, hs)), _spec("dpos", (s, hs)),
                 _spec("dcls", (hs,))], dict(role="embed_bwd")))
    inv.append(("head_fwdbwd", M.build_head_fwdbwd(cfg),
                [_spec(*x3), _spec("lnf_g", (hs,)), _spec("lnf_b", (hs,)),
                 _spec("w_head", (hs, cl)), _spec("b_head", (cl,)),
                 _spec("labels", (b,), I32)],
                [_spec("loss", ()), _spec("ncorrect", (), I32),
                 _spec("dx", (b, s, hs)), _spec("dlnf_g", (hs,)),
                 _spec("dlnf_b", (hs,)), _spec("dw_head", (hs, cl)),
                 _spec("db_head", (cl,))], dict(role="head_fwdbwd")))
    inv.append(("head_infer", M.build_head_infer(cfg),
                [_spec(*x3), _spec("lnf_g", (hs,)), _spec("lnf_b", (hs,)),
                 _spec("w_head", (hs, cl)), _spec("b_head", (cl,)),
                 _spec("labels", (b,), I32)],
                [_spec("loss", ()), _spec("ncorrect", (), I32)],
                dict(role="head_infer")))

    for frac in M.KEEP_FRACS:
        kq = M.keep_count(hs, frac)
        bname = M.bucket_name(frac)
        inv.append((f"attn_fwd_{bname}", M.build_attn_fwd(cfg),
                    [_spec(*x3), _spec("ln1_g", (hs,)), _spec("ln1_b", (hs,)),
                     _spec("wqkv", (hs, 3 * hsl)), _spec("wo", (hsl, hs)),
                     _spec("idx", (kq,), I32), _spec("mask", (kq,))],
                    [_spec("y_partial", (b, s, hs))],
                    dict(role="attn_fwd", gamma=1 - frac, keep=kq)))
        inv.append((f"attn_bwd_{bname}", M.build_attn_bwd(cfg),
                    [_spec(*x3), _spec("ln1_g", (hs,)), _spec("ln1_b", (hs,)),
                     _spec("wqkv", (hs, 3 * hsl)), _spec("wo", (hsl, hs)),
                     _spec("idx", (kq,), I32), _spec("mask", (kq,)),
                     _spec("dy", (b, s, hs))],
                    [_spec("dx", (b, s, hs)), _spec("dln1_g", (hs,)),
                     _spec("dln1_b", (hs,)), _spec("dwqkv", (hs, 3 * hsl)),
                     _spec("dwo", (hsl, hs))],
                    dict(role="attn_bwd", gamma=1 - frac, keep=kq)))

    combos = [(f, f) for f in M.KEEP_FRACS]
    combos += [(1.0, f) for f in M.KEEP_FRACS if f != 1.0]
    for f1, f2 in combos:
        k1, k2 = M.keep_count(hs, f1), M.keep_count(ffl, f2)
        b1, b2 = M.bucket_name(f1), M.bucket_name(f2)
        suffix = b1 if f1 == f2 else f"{b1}_{b2}"
        ins = [_spec(*x3), _spec("ln2_g", (hs,)), _spec("ln2_b", (hs,)),
               _spec("w1", (hs, ffl)), _spec("w2", (ffl, hs)),
               _spec("idx1", (k1,), I32), _spec("mask1", (k1,)),
               _spec("idx2", (k2,), I32), _spec("mask2", (k2,))]
        inv.append((f"mlp_fwd_{suffix}", M.build_mlp_fwd(cfg), ins,
                    [_spec("y_partial", (b, s, hs))],
                    dict(role="mlp_fwd", gamma1=1 - f1, gamma2=1 - f2,
                         keep1=k1, keep2=k2)))
        inv.append((f"mlp_bwd_{suffix}", M.build_mlp_bwd(cfg),
                    ins + [_spec("dy", (b, s, hs))],
                    [_spec("dx", (b, s, hs)), _spec("dln2_g", (hs,)),
                     _spec("dln2_b", (hs,)), _spec("dw1", (hs, ffl)),
                     _spec("dw2", (ffl, hs))],
                    dict(role="mlp_bwd", gamma1=1 - f1, gamma2=1 - f2,
                         keep1=k1, keep2=k2)))

    mig_kbs = sorted({M.keep_count(ffl, frac) for frac in M.MIG_FRACS})
    for kb in mig_kbs:
        inv.append((f"mlp_mig_fwd_k{kb}", M.build_mlp_mig_fwd(kb),
                    [_spec(*x3), _spec("ln2_g", (hs,)), _spec("ln2_b", (hs,)),
                     _spec("w1c", (hs, kb)), _spec("w2c", (kb, hs))],
                    [_spec("y_partial", (b, s, hs))],
                    dict(role="mlp_mig_fwd", kb=kb)))
        inv.append((f"mlp_mig_bwd_k{kb}", M.build_mlp_mig_bwd(kb),
                    [_spec(*x3), _spec("ln2_g", (hs,)), _spec("ln2_b", (hs,)),
                     _spec("w1c", (hs, kb)), _spec("w2c", (kb, hs)),
                     _spec("dy", (b, s, hs))],
                    [_spec("dx_partial", (b, s, hs)), _spec("dln2_g", (hs,)),
                     _spec("dln2_b", (hs,)), _spec("dw1c", (hs, kb)),
                     _spec("dw2c", (kb, hs))],
                    dict(role="mlp_mig_bwd", kb=kb)))
    return inv


def build_model(cfg: M.ModelCfg, out_dir: str, with_golden: bool,
                verbose: bool = True):
    mdir = os.path.join(out_dir, cfg.name)
    os.makedirs(mdir, exist_ok=True)
    manifest = dict(
        model=dict(name=cfg.name, hs=cfg.hs, depth=cfg.depth,
                   heads=cfg.heads, e=cfg.e, bs=cfg.bs, img=cfg.img,
                   patch=cfg.patch, chans=cfg.chans, classes=cfg.classes,
                   mlp_ratio=cfg.mlp_ratio, seq=cfg.seq, seq0=cfg.seq0,
                   pd=cfg.pd, hsl=cfg.hsl, hl=cfg.hl, hd=cfg.hd, ffl=cfg.ffl,
                   params_total=cfg.params_total(),
                   params_per_worker=cfg.params_per_worker()),
        buckets=[dict(name=M.bucket_name(f), gamma=1 - f,
                      keep_hs=M.keep_count(cfg.hs, f),
                      keep_ffl=M.keep_count(cfg.ffl, f))
                 for f in M.KEEP_FRACS],
        mig_buckets=sorted({M.keep_count(cfg.ffl, f) for f in M.MIG_FRACS}),
        executables=[],
    )
    for name, fn, ins, outs, meta in executable_inventory(cfg):
        t0 = time.time()
        args = [_sds(i["dims"], i["dtype"]) for i in ins]
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(mdir, fname), "w") as f:
            f.write(text)
        manifest["executables"].append(
            dict(name=name, file=fname, inputs=ins, outputs=outs, **meta))
        if verbose:
            print(f"  [{cfg.name}] {name}: {len(text)} chars "
                  f"({time.time() - t0:.1f}s)")
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if with_golden:
        t0 = time.time()
        G.write_bundle(os.path.join(mdir, "golden.bin"), G.build_golden(cfg))
        if verbose:
            print(f"  [{cfg.name}] golden.bin ({time.time() - t0:.1f}s)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", nargs="+", default=["vit-tiny", "vit-s", "vit-m"],
                    choices=sorted(M.PRESETS) + ["all"])
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    models = sorted(M.PRESETS) if "all" in args.models else args.models
    for name in models:
        cfg = M.PRESETS[name]
        print(f"[aot] building {name}: hs={cfg.hs} depth={cfg.depth} "
              f"e={cfg.e} params={cfg.params_total() / 1e6:.1f}M")
        build_model(cfg, args.out, with_golden=(name == "vit-tiny"))
    print("[aot] done")


if __name__ == "__main__":
    main()
