"""Layer-1 Pallas kernel: the paper's resized GEMM hot-spot.

ZERO-resizing (paper §III-A) shrinks the *contraction* dimension of the
linear-layer GEMMs on straggling tasks: prune ``hs·γ`` columns of the input
and the matching rows of the weight, keep the output shape fixed.  This
kernel expresses exactly that contract:

    pruned_matmul(x[M,K], w[K,N], keep_idx[K'], mask[K']) =
        (x[:, keep_idx] * mask) @ w[keep_idx, :]

``keep_idx`` is a *runtime* int32 tensor, so which columns survive is a
runtime decision (priority selection, lineage, migration assignment all
live in the Rust coordinator); only K' — the pruning *bucket* — is static.
``mask`` is almost always all-ones; the migration path pads ``keep_idx`` to
the bucket size with arbitrary indices and zeroes them out through the
mask, keeping migrated arithmetic exact (see rust/src/migration/).

TPU mapping (DESIGN.md §9): the gather is the HBM→VMEM re-layout of a
K'-length contraction streamed through (bm, bk)×(bk, bn) MXU tiles; output
tiles never change shape with γ, which is the paper's consistency
constraint expressed in tiling terms.  On this CPU-only testbed the kernel
runs under ``interpret=True`` (real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute); correctness is pinned against the
pure-jnp oracle in ``ref.py``.

The backward pass is a hand-written ``custom_vjp`` that mirrors the paper's
two backward dataflows (§II-B):

    grad_input :  dx[:, idx] += (dy @ w[idx, :]^T) * mask      (scatter-add)
    grad_weight:  dw[idx, :] += mask · (x[:, idx]^T @ dy)      (scatter-add)

The scatters leave exact zeros in the pruned positions — the paper's
Zero-imputation default; Average/Same are host-side re-imputations applied
by the Rust lineage module on top of the same artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["pruned_matmul", "pruned_matmul_fwd_only", "pick_block", "vmem_bytes"]


def pick_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target``.

    Shapes are static at trace time so the block search is free; favouring
    big blocks keeps the grid small under interpret mode and maps to
    128-wide MXU tiles when the dims allow it.
    """
    for c in range(min(n, target), 0, -1):
        if n % c == 0:
            return c
    return n


def vmem_bytes(bm: int, bn: int, bk: int, kfull: int, itemsize: int = 4) -> int:
    """VMEM footprint estimate of one grid step (DESIGN.md §9 / §Perf).

    x block is (bm, kfull) because the gather indexes into the full
    contraction (scalar-prefetch DMA on real TPU would stream only the
    gathered bk slice; interpret mode materializes the block).
    """
    return itemsize * (bm * kfull + kfull * bn + bm * bn + bk)


def _mm_kernel(idx_ref, mask_ref, x_ref, w_ref, o_ref, *, nk: int):
    """Grid (M/bm, N/bn, K'/bk); o block is revisited across k and used as
    the f32 accumulator (consistency constraint: o's tiling is γ-free)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]                      # [bk] int32 gather indices
    mask = mask_ref[...]                    # [bk] f32 validity mask
    xb = x_ref[...][:, idx] * mask[None, :]  # [bm, bk] gathered+masked
    wb = w_ref[...][idx, :]                 # [bk, bn] gathered
    o_ref[...] += jnp.dot(xb, wb, preferred_element_type=o_ref.dtype)


def pruned_matmul_fwd_only(x, w, idx, mask):
    """The raw pallas_call — no autodiff wiring. Prefer ``pruned_matmul``."""
    m, kfull = x.shape
    _, n = w.shape
    (kp,) = idx.shape
    bm = pick_block(m, 128)
    bn = pick_block(n, 128)
    bk = pick_block(kp, 128)
    grid = (m // bm, n // bn, kp // bk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
            pl.BlockSpec((bm, kfull), lambda i, j, k: (i, 0)),
            pl.BlockSpec((kfull, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(idx, mask, x, w)


@jax.custom_vjp
def pruned_matmul(x, w, idx, mask):
    """(x[:, idx] * mask) @ w[idx, :] with the paper's pruned backward."""
    return pruned_matmul_fwd_only(x, w, idx, mask)


def _fwd(x, w, idx, mask):
    return pruned_matmul_fwd_only(x, w, idx, mask), (x, w, idx, mask)


def _bwd(res, dy):
    x, w, idx, mask = res
    m, _ = x.shape
    n = dy.shape[1]
    ones_n = jnp.ones((n,), jnp.float32)
    ones_m = jnp.ones((m,), jnp.float32)
    ar_n = jnp.arange(n, dtype=jnp.int32)
    ar_m = jnp.arange(m, dtype=jnp.int32)

    # grad_input dataflow: compact dxc = dy @ w[idx,:]^T, scatter-ADD so
    # mask-padded duplicate indices contribute exactly zero.
    wg = w[idx, :]
    dxc = pruned_matmul_fwd_only(dy, wg.T, ar_n, ones_n) * mask[None, :]
    dx = jnp.zeros_like(x).at[:, idx].add(dxc)

    # grad_weight dataflow: compact dwc = (x[:,idx]*mask)^T @ dy, scatter-ADD
    # into zeros — the Zero-imputed grad_weight of paper Fig. 2 (right).
    xg = x[:, idx] * mask[None, :]
    dwc = pruned_matmul_fwd_only(xg.T, dy, ar_m, ones_m)
    dw = jnp.zeros_like(w).at[idx, :].add(dwc)

    # idx/mask are structural inputs — no cotangent (float0 / zeros).
    return dx, dw, np.zeros(idx.shape, jax.dtypes.float0), jnp.zeros_like(mask)


pruned_matmul.defvjp(_fwd, _bwd)
