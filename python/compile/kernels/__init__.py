# L1: Pallas kernel(s) for the paper's compute hot-spot.
from .pruned_matmul import pruned_matmul, pruned_matmul_fwd_only, pick_block, vmem_bytes  # noqa: F401
from . import ref  # noqa: F401
