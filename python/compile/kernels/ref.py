"""Pure-jnp oracles for the Layer-1 kernel and the resizing dataflows.

These are the correctness ground truth: every pallas path in
``pruned_matmul.py`` and every model branch in ``model.py`` is pinned
against a function here by ``python/tests/``.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "pruned_matmul_ref",
    "grad_input_ref",
    "grad_weight_ref",
    "expand_cols_zero",
    "expand_rows_zero",
]


def pruned_matmul_ref(x, w, idx, mask=None):
    """(x[:, idx] * mask) @ w[idx, :] — paper Fig. 2 (left), forward."""
    xg = x[:, idx]
    if mask is not None:
        xg = xg * mask[None, :]
    return xg @ w[idx, :]


def grad_input_ref(dy, w, idx, mask, kfull):
    """Zero-imputed grad_input: dx[:, idx] += (dy @ w[idx,:]^T) * mask."""
    dxc = (dy @ w[idx, :].T) * mask[None, :]
    return jnp.zeros((dy.shape[0], kfull), dy.dtype).at[:, idx].add(dxc)


def grad_weight_ref(x, dy, idx, mask, kfull):
    """Zero-imputed grad_weight of paper Fig. 2 (right):
    dw[idx, :] += (x[:, idx] * mask)^T @ dy, zeros at pruned rows."""
    dwc = (x[:, idx] * mask[None, :]).T @ dy
    return jnp.zeros((kfull, dy.shape[1]), dy.dtype).at[idx, :].add(dwc)


def expand_cols_zero(compact, idx, kfull):
    """Lineage re-expansion (paper's lookup-table recovery), columns."""
    return jnp.zeros((compact.shape[0], kfull), compact.dtype).at[:, idx].set(compact)


def expand_rows_zero(compact, idx, kfull):
    """Lineage re-expansion, rows."""
    return jnp.zeros((kfull, compact.shape[1]), compact.dtype).at[idx, :].set(compact)
