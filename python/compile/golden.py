"""Golden-bundle generator: cross-language numerics ground truth.

Simulates the Rust engine's exact dataflow in JAX — e worker shards, the
branch executables of ``model.py``, exact-sum collectives, plain SGD — and
writes a binary bundle the Rust integration tests replay step-for-step.
If Rust's PJRT path, shard bookkeeping, residual adds, collectives,
lineage scatter, or optimizer diverge from this simulation, the golden
test fails.

Bundle contents (``tensors.bin`` format, see ``write_bundle``):
  params.<w>.<name>   per-worker shard tensors (worker-major)
  batch.patches / batch.labels
  keep_idx.qkv / keep_idx.ffl    the pruned-step index sets (worker 2)
  golden.loss_step{0..2}         unpruned 3-step SGD loss trajectory
  golden.acc_step0               ncorrect at step 0
  golden.pruned_loss             loss of a step where worker 2 runs γ=0.5
  golden.grad_ck.<name>          checksums (sum, |sum|) of step-0 grads

Binary layout: u32 LE header length, JSON header
``{"entries": [{name, dims, dtype, offset_elems, count}]}``, then raw
little-endian element data. Reader: ``rust/src/util/bin.rs``.
"""

from __future__ import annotations

import json
import struct

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M

SGD_LR = 0.05


# ---------------------------------------------------------------------------
# tensors.bin writer
# ---------------------------------------------------------------------------

def write_bundle(path: str, tensors: dict):
    """tensors: name -> np.ndarray (f32 or i32)."""
    entries = []
    blobs = []
    offset = 0
    for name, arr in tensors.items():
        arr = np.asarray(arr)
        if arr.dtype in (np.float64,):
            arr = arr.astype(np.float32)
        if arr.dtype in (np.int64,):
            arr = arr.astype(np.int32)
        assert arr.dtype in (np.float32, np.int32), (name, arr.dtype)
        dtype = "f32" if arr.dtype == np.float32 else "i32"
        entries.append(dict(name=name, dims=list(arr.shape), dtype=dtype,
                            offset_elems=offset, count=int(arr.size)))
        blobs.append(arr.astype("<f4" if dtype == "f32" else "<i4").tobytes())
        offset += int(arr.size)
    header = json.dumps({"entries": entries}).encode()
    with open(path, "wb") as f:
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)


# ---------------------------------------------------------------------------
# Synthetic dataset (must match rust/src/data/synthetic.rs exactly)
# ---------------------------------------------------------------------------

def synth_batch(cfg: M.ModelCfg, seed: int):
    """Class-template + noise patches.  Deterministic given (cfg, seed);
    the Rust generator reproduces this from the same bundle, so only the
    golden batch itself needs to cross the language boundary."""
    rng = np.random.default_rng(seed)
    templates = rng.normal(size=(cfg.classes, cfg.seq0, cfg.pd)).astype(np.float32)
    labels = rng.integers(0, cfg.classes, size=(cfg.bs,)).astype(np.int32)
    noise = rng.normal(size=(cfg.bs, cfg.seq0, cfg.pd)).astype(np.float32)
    patches = 0.5 * templates[labels] + 0.5 * noise
    return patches, labels


# ---------------------------------------------------------------------------
# Engine simulation (mirrors rust/src/train/trainer.rs step dataflow)
# ---------------------------------------------------------------------------

def _shards(full, cfg):
    return [[M.shard_block(blk, w, cfg) for blk in full["blocks"]]
            for w in range(cfg.e)]


def sim_step(full, shards, patches, labels, cfg, qkv_idx=None, ffl_idx=None,
             straggler=None):
    """One engine step.  Returns (loss, ncorrect, new_full, new_shards).

    ``qkv_idx``/``ffl_idx``: keep-index sets applied on ``straggler``'s
    blocks (ZERO-resizing, Zero imputation — vjp scatter already leaves
    zeros).  Replicated params are updated from worker 0's (identical)
    grads, shard params from their owner's grads.
    """
    e = cfg.e
    full_hs = jnp.arange(cfg.hs, dtype=jnp.int32)
    ones_hs = jnp.ones((cfg.hs,), jnp.float32)
    full_ffl = jnp.arange(cfg.ffl, dtype=jnp.int32)
    ones_ffl = jnp.ones((cfg.ffl,), jnp.float32)

    def idx_for(w, kind):
        if straggler is not None and w == straggler:
            if kind == "qkv" and qkv_idx is not None:
                return qkv_idx, jnp.ones((qkv_idx.shape[0],), jnp.float32)
            if kind == "ffl" and ffl_idx is not None:
                return ffl_idx, jnp.ones((ffl_idx.shape[0],), jnp.float32)
        return (full_hs, ones_hs) if kind == "qkv" else (full_ffl, ones_ffl)

    x = M.embed_fwd(patches, full["w_patch"], full["pos"], full["cls"], cfg)
    attn_in, mlp_in = [], []
    for k in range(cfg.depth):
        attn_in.append(x)
        part = jnp.zeros_like(x)
        for w in range(e):
            s = shards[w][k]
            qi, qm = idx_for(w, "qkv")
            part = part + M.attn_fwd(x, s["ln1_g"], s["ln1_b"], s["wqkv"],
                                     s["wo"], qi, qm, cfg)
        x = x + part  # all-reduce + residual
        mlp_in.append(x)
        part = jnp.zeros_like(x)
        for w in range(e):
            s = shards[w][k]
            qi, qm = idx_for(w, "qkv")
            fi, fm = idx_for(w, "ffl")
            part = part + M.mlp_fwd(x, s["ln2_g"], s["ln2_b"], s["w1"],
                                    s["w2"], qi, qm, fi, fm, cfg)
        x = x + part

    hf = M.build_head_fwdbwd(cfg)
    loss, ncorrect, dx, dlnf_g, dlnf_b, dwh, dbh = hf(
        x, full["lnf_g"], full["lnf_b"], full["w_head"], full["b_head"], labels)

    grads = {w: [dict() for _ in range(cfg.depth)] for w in range(e)}
    rep = dict(lnf_g=dlnf_g, lnf_b=dlnf_b, w_head=dwh, b_head=dbh)
    dy = dx
    for k in reversed(range(cfg.depth)):
        # MLP branch backward
        dpart = jnp.zeros_like(dy)
        for w in range(e):
            s = shards[w][k]
            qi, qm = idx_for(w, "qkv")
            fi, fm = idx_for(w, "ffl")
            bwd = M.build_mlp_bwd(cfg)
            dxw, dg, db, dw1, dw2 = bwd(
                mlp_in[k], s["ln2_g"], s["ln2_b"], s["w1"], s["w2"],
                qi, qm, fi, fm, dy)
            dpart = dpart + dxw
            grads[w][k].update(ln2_g=dg, ln2_b=db, w1=dw1, w2=dw2)
        # ln grads are all-reduced (identical update on all replicas)
        ln2_g_sum = sum(grads[w][k]["ln2_g"] for w in range(e))
        ln2_b_sum = sum(grads[w][k]["ln2_b"] for w in range(e))
        for w in range(e):
            grads[w][k]["ln2_g"] = ln2_g_sum
            grads[w][k]["ln2_b"] = ln2_b_sum
        dy = dy + dpart
        # Attention branch backward
        dpart = jnp.zeros_like(dy)
        for w in range(e):
            s = shards[w][k]
            qi, qm = idx_for(w, "qkv")
            bwd = M.build_attn_bwd(cfg)
            dxw, dg, db, dwq, dwo = bwd(
                attn_in[k], s["ln1_g"], s["ln1_b"], s["wqkv"], s["wo"],
                qi, qm, dy)
            dpart = dpart + dxw
            grads[w][k].update(ln1_g=dg, ln1_b=db, wqkv=dwq, wo=dwo)
        ln1_g_sum = sum(grads[w][k]["ln1_g"] for w in range(e))
        ln1_b_sum = sum(grads[w][k]["ln1_b"] for w in range(e))
        for w in range(e):
            grads[w][k]["ln1_g"] = ln1_g_sum
            grads[w][k]["ln1_b"] = ln1_b_sum
        dy = dy + dpart

    eb = M.build_embed_bwd(cfg)
    dwp, dpos, dcls = eb(patches, full["w_patch"], full["pos"], full["cls"], dy)
    rep.update(w_patch=dwp, pos=dpos, cls=dcls)

    # SGD
    new_full = dict(full)
    for name, g in rep.items():
        new_full[name] = full[name] - SGD_LR * g
    new_shards = []
    for w in range(e):
        ws = []
        for k in range(cfg.depth):
            s, g = shards[w][k], grads[w][k]
            ws.append({n: s[n] - SGD_LR * g[n] for n in s})
        new_shards.append(ws)
    # blocks inside new_full only matter for reference checks; keep stale.
    return float(loss), int(ncorrect), new_full, new_shards, grads


def build_golden(cfg: M.ModelCfg, seed: int = 42):
    key = jax.random.PRNGKey(seed)
    full = M.init_full_params(cfg, key)
    shards = _shards(full, cfg)
    patches, labels = synth_batch(cfg, seed)

    out = {}
    for w in range(cfg.e):
        for k, blk in enumerate(shards[w]):
            for n, v in blk.items():
                out[f"params.{w}.blk{k}.{n}"] = np.asarray(v)
    for n in ("w_patch", "pos", "cls", "lnf_g", "lnf_b", "w_head", "b_head"):
        out[f"params.rep.{n}"] = np.asarray(full[n])
    out["batch.patches"] = patches
    out["batch.labels"] = labels

    # unpruned 3-step trajectory on the same batch
    f, s = full, shards
    losses, accs, g0 = [], [], None
    for step in range(3):
        loss, ncorrect, f, s, grads = sim_step(f, s, patches, labels, cfg)
        losses.append(loss)
        accs.append(ncorrect)
        if step == 0:
            g0 = grads
    out["golden.loss_steps"] = np.asarray(losses, np.float32)
    out["golden.acc_step0"] = np.asarray([accs[0]], np.int32)
    for n in ("wqkv", "wo", "w1", "w2", "ln1_g"):
        g = np.asarray(g0[1][0][n])
        out[f"golden.grad_ck.{n}"] = np.asarray(
            [g.sum(), np.abs(g).sum()], np.float32)

    # pruned step: worker 2 at γ=0.5 with deterministic even-index keeps
    kq = M.keep_count(cfg.hs, 0.5)
    kf = M.keep_count(cfg.ffl, 0.5)
    qkv_idx = jnp.asarray(np.arange(0, 2 * kq, 2) % cfg.hs, jnp.int32)
    ffl_idx = jnp.asarray(np.arange(0, 2 * kf, 2) % cfg.ffl, jnp.int32)
    loss_p, _, _, _, _ = sim_step(full, _shards(full, cfg), patches, labels,
                                  cfg, qkv_idx=qkv_idx, ffl_idx=ffl_idx,
                                  straggler=2 % cfg.e)
    out["keep_idx.qkv"] = np.asarray(qkv_idx)
    out["keep_idx.ffl"] = np.asarray(ffl_idx)
    out["golden.pruned_loss"] = np.asarray([loss_p], np.float32)
    out["golden.sgd_lr"] = np.asarray([SGD_LR], np.float32)
    return out
