"""Layer-2: ViT shard programs under 1D tensor parallelism (Megatron split).

The paper trains ViT-1B/3B on Colossal-AI's 1D tensor parallelism: within
each transformer block the first GEMM of a branch is column-split across
the ``e`` tasks, the second is row-split, so each branch needs exactly one
all-reduce per direction (paper §II-B).  This module defines the
*per-worker branch functions* — everything between two collectives — and
builders that close them over a static pruning bucket.  ``aot.py`` lowers
each builder to an HLO-text artifact; the Rust coordinator owns residual
adds, collectives, optimizer, lineage, and scheduling.

Every TP GEMM goes through the Layer-1 ``pruned_matmul`` kernel, so the
resized contraction (ZERO-resizing) and the migrated column sets
(SEMI-migration) are both runtime ``keep_idx`` choices over the same
artifacts.

Shard layout per worker (column-then-row split):

    wqkv [hs, 3·hsl]   column-split of full [hs, 3·hs]   (hsl = hs/e)
    wo   [hsl, hs]     row-split    of full [hs, hs]
    w1   [hs, ffl]     column-split of full [hs, 4·hs]   (ffl = 4·hs/e)
    w2   [ffl, hs]     row-split    of full [4·hs, hs]
    ln*/embed/head     replicated

Prunable contractions (the paper's "linear projections and
transformations"): QKV in-dim (hs), FC1 in-dim (hs), FC2 in-dim (ffl).
FC1's *output* columns are co-pruned with FC2's input rows so the pruned
intermediate is never materialized — the resizing saves both GEMMs, exactly
the FFN workload model of paper §II-B.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import pruned_matmul

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

#: Static pruning buckets: fraction of the contraction that SURVIVES.
#: γ = 1 - keep_frac ∈ {0, 0.25, 0.5, 0.75, 0.875}; Eq.(1) demands are
#: rounded *up* to the nearest bucket by the Rust coordinator.
KEEP_FRACS = (1.0, 0.75, 0.5, 0.25, 0.125)

#: Migration-slice buckets (fraction of a contraction a receiver computes
#: for a straggler).  Padded to size with the kernel's validity mask.
MIG_FRACS = (0.5, 0.25, 0.125)


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Static model/parallelism configuration an artifact set is built for."""

    name: str
    hs: int         # hidden size
    depth: int      # number of transformer blocks
    heads: int
    e: int          # tensor-parallel degree (paper's number of tasks)
    bs: int         # per-iteration batch size
    img: int = 32
    patch: int = 4
    chans: int = 3
    classes: int = 10
    mlp_ratio: int = 4

    def __post_init__(self):
        assert self.hs % self.heads == 0, "hs must divide into heads"
        assert self.heads % self.e == 0, "heads must split across e workers"
        assert self.img % self.patch == 0

    @property
    def seq0(self) -> int:
        return (self.img // self.patch) ** 2

    @property
    def seq(self) -> int:
        # +1 class token — the paper's sql=65 for 32x32/p4.
        return self.seq0 + 1

    @property
    def pd(self) -> int:
        return self.chans * self.patch * self.patch

    @property
    def hsl(self) -> int:
        return self.hs // self.e

    @property
    def hl(self) -> int:
        return self.heads // self.e

    @property
    def hd(self) -> int:
        return self.hs // self.heads

    @property
    def ffl(self) -> int:
        return self.mlp_ratio * self.hs // self.e

    @property
    def tokens(self) -> int:
        return self.bs * self.seq

    def params_per_worker(self) -> int:
        blk = 4 * self.hs + self.hs * 3 * self.hsl + self.hsl * self.hs \
            + self.hs * self.ffl + self.ffl * self.hs
        emb = self.pd * self.hs + self.seq * self.hs + self.hs
        head = 2 * self.hs + self.hs * self.classes + self.classes
        return self.depth * blk + emb + head

    def params_total(self) -> int:
        """Global parameter count (shards summed once, replicas once)."""
        blk = 4 * self.hs + self.hs * 3 * self.hs + self.hs * self.hs \
            + self.hs * self.mlp_ratio * self.hs + self.mlp_ratio * self.hs * self.hs
        emb = self.pd * self.hs + self.seq * self.hs + self.hs
        head = 2 * self.hs + self.hs * self.classes + self.classes
        return self.depth * blk + emb + head


#: Artifact-set presets.  vit-tiny: unit tests + rust golden check;
#: vit-s / vit-m: the two "paper scale points" for benches (stand-ins for
#: ViT-1B and ViT-3B — see DESIGN.md §2 substitutions); vit-100m: the
#: end-to-end example (~100M parameters).
PRESETS = {
    "vit-tiny": ModelCfg("vit-tiny", hs=128, depth=2, heads=4, e=4, bs=8),
    "vit-s": ModelCfg("vit-s", hs=256, depth=4, heads=8, e=8, bs=16),
    "vit-m": ModelCfg("vit-m", hs=384, depth=6, heads=8, e=8, bs=16),
    "vit-100m": ModelCfg("vit-100m", hs=768, depth=12, heads=12, e=4, bs=8),
}


def keep_count(k: int, frac: float) -> int:
    """Bucket keep-size: multiple of 8 (lane width), at least 8."""
    return max(8, int(round(k * frac / 8)) * 8)


def bucket_name(frac: float) -> str:
    """Bucket suffix by pruning percentage, e.g. 0.75 keep → 'g25'."""
    return f"g{int(round((1.0 - frac) * 100)):02d}"


# ---------------------------------------------------------------------------
# Numerics helpers
# ---------------------------------------------------------------------------

def layernorm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def _full_idx(k: int):
    return jnp.arange(k, dtype=jnp.int32), jnp.ones((k,), jnp.float32)


def _pm(x2d, w):
    """pruned_matmul over the full (unpruned) contraction."""
    idx, mask = _full_idx(x2d.shape[1])
    return pruned_matmul(x2d, w, idx, mask)


# ---------------------------------------------------------------------------
# Branch functions (one per-worker program between collectives)
# ---------------------------------------------------------------------------

def embed_fwd(patches, w_patch, pos, cls, cfg: ModelCfg):
    """Patch embedding + cls token + positional embedding (replicated)."""
    b = patches.shape[0]
    tok = _pm(patches.reshape(b * cfg.seq0, cfg.pd), w_patch)
    tok = tok.reshape(b, cfg.seq0, cfg.hs)
    cls_tok = jnp.broadcast_to(cls[None, None, :], (b, 1, cfg.hs))
    return jnp.concatenate([cls_tok, tok], axis=1) + pos[None, :, :]


def attn_fwd(x, ln_g, ln_b, wqkv, wo, idx, mask, cfg: ModelCfg):
    """Attention branch, this worker's heads; returns the row-split partial
    (Rust all-reduces it).  ``idx`` prunes the QKV contraction (hs)."""
    b, s, hs = x.shape
    xln = layernorm(x, ln_g, ln_b)
    qkv = pruned_matmul(xln.reshape(b * s, hs), wqkv, idx, mask)
    qkv = qkv.reshape(b, s, 3, cfg.hl, cfg.hd)
    q = qkv[:, :, 0].transpose(0, 2, 1, 3)  # [b, hl, s, hd]
    k = qkv[:, :, 1].transpose(0, 2, 1, 3)
    v = qkv[:, :, 2].transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(cfg.hd)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    o = o.transpose(0, 2, 1, 3).reshape(b * s, cfg.hsl)
    y = _pm(o, wo)  # row-split GEMM → partial sum
    return y.reshape(b, s, hs)


def mlp_fwd(x, ln_g, ln_b, w1, w2, idx1, mask1, idx2, mask2, cfg: ModelCfg):
    """FFN branch.  ``idx1`` prunes FC1's contraction (hs); ``idx2``
    co-prunes FC1's output columns and FC2's contraction rows (ffl), so the
    pruned intermediate h is never computed — both GEMMs shrink, matching
    the paper's FFN workload model."""
    b, s, hs = x.shape
    xln = layernorm(x, ln_g, ln_b).reshape(b * s, hs)
    w1g = w1[:, idx2] * mask2[None, :]        # N-side co-prune of FC1
    h = pruned_matmul(xln, w1g, idx1, mask1)  # [b·s, |idx2|]
    h = gelu(h)
    kp2 = idx2.shape[0]
    ar, ones = _full_idx(kp2)
    w2g = w2[idx2, :] * mask2[:, None]        # K-side prune of FC2
    y = pruned_matmul(h, w2g, ar, ones)       # [b·s, hs] partial sum
    return y.reshape(b, s, hs)


def head_loss(x, lnf_g, lnf_b, w_head, b_head, labels, cfg: ModelCfg):
    """Final LN → cls-token pool → classifier → mean softmax-CE.
    Replicated on every worker (inputs are identical post all-reduce)."""
    xln = layernorm(x, lnf_g, lnf_b)
    pooled = xln[:, 0, :]
    logits = _pm(pooled, w_head) + b_head[None, :]
    logp = jax.nn.log_softmax(logits)
    b = labels.shape[0]
    loss = -jnp.mean(logp[jnp.arange(b), labels])
    return loss, logits


# ---------------------------------------------------------------------------
# Executable builders: functions aot.py lowers, one per (role, bucket).
# All return tuples of arrays; input order is what the manifest documents.
# ---------------------------------------------------------------------------

def build_embed_fwd(cfg: ModelCfg):
    def f(patches, w_patch, pos, cls):
        return (embed_fwd(patches, w_patch, pos, cls, cfg),)
    return f


def build_embed_bwd(cfg: ModelCfg):
    def f(patches, w_patch, pos, cls, dy):
        fwd = lambda wp, p, c: embed_fwd(patches, wp, p, c, cfg)
        _, vjp = jax.vjp(fwd, w_patch, pos, cls)
        return vjp(dy)  # (dw_patch, dpos, dcls)
    return f


def build_attn_fwd(cfg: ModelCfg):
    def f(x, ln_g, ln_b, wqkv, wo, idx, mask):
        return (attn_fwd(x, ln_g, ln_b, wqkv, wo, idx, mask, cfg),)
    return f


def build_attn_bwd(cfg: ModelCfg):
    """Rematerializing vjp of the attention branch: recomputes the branch
    internally so only the branch *input* is stored between fwd and bwd —
    the pruned activations are temporary, per the consistency constraint."""
    def f(x, ln_g, ln_b, wqkv, wo, idx, mask, dy):
        fwd = lambda x_, g_, b_, wq_, wo_: attn_fwd(
            x_, g_, b_, wq_, wo_, idx, mask, cfg)
        _, vjp = jax.vjp(fwd, x, ln_g, ln_b, wqkv, wo)
        return vjp(dy)  # (dx, dln_g, dln_b, dwqkv, dwo)
    return f


def build_mlp_fwd(cfg: ModelCfg):
    def f(x, ln_g, ln_b, w1, w2, idx1, mask1, idx2, mask2):
        return (mlp_fwd(x, ln_g, ln_b, w1, w2, idx1, mask1, idx2, mask2, cfg),)
    return f


def build_mlp_bwd(cfg: ModelCfg):
    def f(x, ln_g, ln_b, w1, w2, idx1, mask1, idx2, mask2, dy):
        fwd = lambda x_, g_, b_, w1_, w2_: mlp_fwd(
            x_, g_, b_, w1_, w2_, idx1, mask1, idx2, mask2, cfg)
        _, vjp = jax.vjp(fwd, x, ln_g, ln_b, w1, w2)
        return vjp(dy)  # (dx, dln_g, dln_b, dw1, dw2)
    return f


def build_head_fwdbwd(cfg: ModelCfg):
    """Loss + metrics + all head gradients in one executable (the head is
    replicated and cheap; fusing fwd+bwd avoids a second artifact)."""
    def f(x, lnf_g, lnf_b, w_head, b_head, labels):
        def lf(x_, g_, b_, wh_, bh_):
            return head_loss(x_, g_, b_, wh_, bh_, labels, cfg)[0]
        loss, vjp = jax.vjp(lf, x, lnf_g, lnf_b, w_head, b_head)
        dx, dg, db, dwh, dbh = vjp(jnp.ones(()))
        _, logits = head_loss(x, lnf_g, lnf_b, w_head, b_head, labels, cfg)
        ncorrect = jnp.sum(
            (jnp.argmax(logits, axis=-1) == labels).astype(jnp.int32))
        return loss, ncorrect, dx, dg, db, dwh, dbh
    return f


def build_head_infer(cfg: ModelCfg):
    def f(x, lnf_g, lnf_b, w_head, b_head, labels):
        loss, logits = head_loss(x, lnf_g, lnf_b, w_head, b_head, labels, cfg)
        ncorrect = jnp.sum(
            (jnp.argmax(logits, axis=-1) == labels).astype(jnp.int32))
        return loss, ncorrect
    return f


# --- Migration slice programs (paper §IV-A) ---------------------------------
# Workload migration operates on the FFN branch at ffl-slice granularity
# (the paper's own running example is the FFN layer): a receiver computes a
# self-contained slice of the straggler's FFN —
#
#     y_mig = gelu(LN(x) @ w1c) @ w2c
#
# over *compact broadcast* weights w1c = w1[:, mig] ([hs, kb]) and
# w2c = w2[mig, :] ([kb, hs]).  x and the LN params are replicated under
# column-wise TP, so only the weights move (the paper: "the input matrix
# has already been available everywhere").  The slice output is a [b,s,hs]
# partial whose collection folds into the branch all-reduce — the paper's
# reduce-merging — and the backward slice's dx/dLN partials fold into the
# backward all-reduce the same way.  Compact weight grads are returned to
# the straggler, which lineage-scatters them (exact — no imputation).
#
# Rust zero-pads w1c/w2c up to the kb bucket: zero FC1 columns give
# gelu(0)=0 activations which meet zero FC2 rows, so padding contributes
# exactly nothing.  Attention GEMMs are balanced by resizing only; this
# caps the migratable share of a block at the FFN's ~2/3 of its FLOPs,
# which is why pure MIG cannot fully catch up at large χ (paper Fig. 10).

def build_mlp_mig_fwd(kb: int):
    def f(x, ln_g, ln_b, w1c, w2c):
        b, s, hs = x.shape
        xln = layernorm(x, ln_g, ln_b).reshape(b * s, hs)
        h = gelu(_pm(xln, w1c))
        y = _pm(h, w2c)
        return (y.reshape(b, s, hs),)
    return f


def build_mlp_mig_bwd(kb: int):
    def f(x, ln_g, ln_b, w1c, w2c, dy):
        def fwd(x_, g_, b_, w1_, w2_):
            b, s, hs = x_.shape
            xln = layernorm(x_, g_, b_).reshape(b * s, hs)
            return _pm(gelu(_pm(xln, w1_)), w2_).reshape(b, s, hs)
        _, vjp = jax.vjp(fwd, x, ln_g, ln_b, w1c, w2c)
        return vjp(dy)  # (dx_partial, dln_g, dln_b, dw1c, dw2c)
    return f


# ---------------------------------------------------------------------------
# Reference model (monolithic, unsharded) + shard mapping — tests/golden.
# ---------------------------------------------------------------------------

def init_full_params(cfg: ModelCfg, key):
    """Full (unsharded) parameter pytree with ViT-standard init."""
    ks = jax.random.split(key, 4 * cfg.depth + 3)
    std = 0.02
    blocks = []
    for i in range(cfg.depth):
        k0, k1, k2, k3 = ks[4 * i: 4 * i + 4]
        blocks.append(dict(
            ln1_g=jnp.ones((cfg.hs,)), ln1_b=jnp.zeros((cfg.hs,)),
            wqkv=jax.random.normal(k0, (cfg.hs, 3, cfg.heads, cfg.hd)) * std,
            wo=jax.random.normal(k1, (cfg.heads, cfg.hd, cfg.hs)) * std,
            ln2_g=jnp.ones((cfg.hs,)), ln2_b=jnp.zeros((cfg.hs,)),
            w1=jax.random.normal(k2, (cfg.hs, cfg.e, cfg.ffl)) * std,
            w2=jax.random.normal(k3, (cfg.e, cfg.ffl, cfg.hs)) * std,
        ))
    kp, kh = ks[-2:]
    return dict(
        blocks=blocks,
        w_patch=jax.random.normal(kp, (cfg.pd, cfg.hs)) * std,
        pos=jnp.zeros((cfg.seq, cfg.hs)),
        cls=jnp.zeros((cfg.hs,)),
        lnf_g=jnp.ones((cfg.hs,)), lnf_b=jnp.zeros((cfg.hs,)),
        w_head=jax.random.normal(kh, (cfg.hs, cfg.classes)) * std,
        b_head=jnp.zeros((cfg.classes,)),
    )


def shard_block(blk, w: int, cfg: ModelCfg):
    """Extract worker ``w``'s 1D-TP shard of one block's full params."""
    lo, hi = w * cfg.hl, (w + 1) * cfg.hl
    return dict(
        ln1_g=blk["ln1_g"], ln1_b=blk["ln1_b"],
        wqkv=blk["wqkv"][:, :, lo:hi, :].reshape(cfg.hs, 3 * cfg.hsl),
        wo=blk["wo"][lo:hi].reshape(cfg.hsl, cfg.hs),
        ln2_g=blk["ln2_g"], ln2_b=blk["ln2_b"],
        w1=blk["w1"][:, w, :],
        w2=blk["w2"][w],
    )


def reference_loss(full, patches, labels, cfg: ModelCfg):
    """Monolithic (e=1 semantics) forward — the TP golden reference."""
    x = embed_fwd(patches, full["w_patch"], full["pos"], full["cls"], cfg)
    b, s, hs = x.shape
    for blk in full["blocks"]:
        xln = layernorm(x, blk["ln1_g"], blk["ln1_b"])
        qkv = xln.reshape(b * s, hs) @ blk["wqkv"].reshape(cfg.hs, 3 * cfg.hs)
        qkv = qkv.reshape(b, s, 3, cfg.heads, cfg.hd)
        q = qkv[:, :, 0].transpose(0, 2, 1, 3)
        k = qkv[:, :, 1].transpose(0, 2, 1, 3)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3)
        att = jax.nn.softmax(
            jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(cfg.hd), axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3)
        x = x + (o.reshape(b * s, cfg.hs) @ blk["wo"].reshape(cfg.hs, cfg.hs)
                 ).reshape(b, s, hs)
        xln = layernorm(x, blk["ln2_g"], blk["ln2_b"]).reshape(b * s, hs)
        h = gelu(xln @ blk["w1"].reshape(cfg.hs, cfg.e * cfg.ffl))
        x = x + (h @ blk["w2"].reshape(cfg.e * cfg.ffl, cfg.hs)).reshape(b, s, hs)
    loss, logits = head_loss(
        x, full["lnf_g"], full["lnf_b"], full["w_head"], full["b_head"],
        labels, cfg)
    return loss, logits
