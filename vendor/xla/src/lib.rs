//! Offline stub of the `xla` (PJRT bindings) crate surface that flextp's
//! `pjrt` backend compiles against.
//!
//! The real crate links `xla_extension` (a multi-GB native XLA build) and
//! cannot be vendored here.  This stub keeps the `--features pjrt` code
//! path *compiling* offline — every constructor returns a descriptive
//! error at runtime, so selecting `--backend pjrt` in a stub build fails
//! fast with a clear message instead of failing to build.  To run the real
//! PJRT path, point the `xla` dependency in `rust/Cargo.toml` at a checkout
//! of the real bindings (see DESIGN.md §8).

// Stub handle types carry never-read unit fields on purpose.
#![allow(dead_code)]

use std::fmt;

const STUB_MSG: &str = "xla stub: this build has no real PJRT runtime; \
                        point rust/Cargo.toml's `xla` dependency at the real \
                        bindings to enable --backend pjrt (DESIGN.md §8)";

/// Error type mirroring the real crate's; implements `std::error::Error`
/// so `?` converts it into `anyhow::Error` at call sites.
#[derive(Debug)]
pub struct Error(pub &'static str);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>() -> Result<T> {
    Err(Error(STUB_MSG))
}

/// Element dtypes flextp exchanges with PJRT.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    I32,
}

/// Host-side literal (tensor value).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        stub_err()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub_err()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub_err()
    }
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err()
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub_err()
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }

    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_constructors_fail_fast_with_message() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("x").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[0u8; 16]
        )
        .is_err());
    }
}
