//! Vendored, dependency-free drop-in for the subset of the `anyhow` crate
//! this workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build environment has no network access to crates.io, so the repo
//! vendors this shim as a path dependency named `anyhow` — every
//! `use anyhow::...` in the codebase compiles unchanged.  The shim keeps
//! anyhow's ergonomics (context chaining, `?` conversion from any
//! `std::error::Error + Send + Sync + 'static` — anyhow's own bound),
//! renders the chain as strings for diagnostics, and keeps the original
//! root error alive so [`Error::downcast_ref`] can recover typed errors
//! (e.g. a scenario parser's error enum) through any number of contexts.

use std::fmt;

/// A context-chained error.  Like `anyhow::Error`, this type deliberately
/// does NOT implement `std::error::Error`, which is what lets the blanket
/// `From<E: std::error::Error>` conversion below coexist with the
/// reflexive `From<Error> for Error`.
pub struct Error {
    /// outermost context first, root cause last
    chain: Vec<String>,
    /// the originating typed error, when there was one (`Error::msg`
    /// and the macros build pure-string errors with no root)
    root: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a displayable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], root: None }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// A reference to the originating error if it is (or sources) an
    /// `E` — anyhow's downcast, restricted to shared access.  Contexts
    /// added along the way don't hide the root cause.
    pub fn downcast_ref<E: std::error::Error + 'static>(&self) -> Option<&E> {
        let mut cur: Option<&(dyn std::error::Error + 'static)> =
            self.root.as_ref().map(|b| &**b as &(dyn std::error::Error + 'static));
        while let Some(e) = cur {
            if let Some(t) = e.downcast_ref::<E>() {
                return Some(t);
            }
            cur = e.source();
        }
        None
    }

    /// The outermost message (most recent context).
    pub fn to_message(&self) -> &str {
        &self.chain[0]
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain, root: Some(Box::new(e)) }
    }
}

/// `anyhow::Result` alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`, mirroring anyhow's.
pub trait Context<T> {
    /// Attach a context message to the error (eagerly evaluated).
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message to the error.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading manifest").unwrap_err();
        assert_eq!(e.to_message(), "loading manifest");
        assert_eq!(e.root_cause(), "gone");
        assert!(e.to_string().starts_with("loading manifest: "));
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_message(), "missing 7");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                bail!("three is right out");
            }
            Ok(n)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).unwrap_err().to_string().contains("three"));
        assert!(f(11).unwrap_err().to_string().contains("11"));
        let e = anyhow!("plain {}", 5);
        assert_eq!(e.to_message(), "plain 5");
    }

    #[test]
    fn downcast_ref_survives_context_chaining() {
        #[derive(Debug, PartialEq)]
        struct Typed(u32);
        impl fmt::Display for Typed {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "typed error {}", self.0)
            }
        }
        impl std::error::Error for Typed {}

        let e = Error::from(Typed(7)).context("outer").context("outermost");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());
        // string-built errors have no typed root
        assert!(anyhow!("plain").downcast_ref::<Typed>().is_none());
        // the io root of a ?-converted error is reachable too
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading").unwrap_err();
        assert_eq!(e.downcast_ref::<std::io::Error>().unwrap().to_string(), "gone");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by:"));
    }
}
