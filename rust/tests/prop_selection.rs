//! Property tests (seeded, hand-rolled — proptest is unavailable offline)
//! for the ZERO-resizing selection policies in `resizing/priority.rs` and
//! `resizing::select_keep`: pruned index sets must be sorted, unique, and
//! in-range, keep/prune must partition the dimension, and selections must
//! be *monotone in χ* — a slower straggler (larger Eq. 1 γ, more pruned
//! columns) prunes a superset of what a faster one prunes, so the
//! round-robin priority schedule degrades gracefully as skew grows.

use std::collections::BTreeSet;

use flextp::resizing::priority::Tracker;
use flextp::resizing::{select_keep, Selection};
use flextp::straggler::gamma_eq1;
use flextp::util::rng::Rng;

const CASES: usize = 60;

fn assert_sorted_unique_in_range(v: &[u32], n: usize, what: &str) {
    assert!(v.windows(2).all(|w| w[0] < w[1]), "{what}: not sorted/unique: {v:?}");
    assert!(v.iter().all(|&i| (i as usize) < n), "{what}: out of range: {v:?}");
}

#[test]
fn prop_pri_list_sorted_unique_in_range_and_nested_in_count() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xA1);
        let n = 4 + rng.below(120);
        let mut tr = Tracker::new(n);
        let delta: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
        tr.epoch_update(&delta, &[]);
        let c1 = 1 + rng.below(n - 1);
        let c2 = c1 + rng.below(n - c1 + 1);
        let p1 = tr.pri_list(c1);
        let p2 = tr.pri_list(c2);
        assert_eq!(p1.len(), c1);
        assert_eq!(p2.len(), c2);
        assert_sorted_unique_in_range(&p1, n, "pri_list(c1)");
        assert_sorted_unique_in_range(&p2, n, "pri_list(c2)");
        // nested: pruning more keeps the smaller pruned set inside the
        // larger one (a δ-ranked truncation is prefix-monotone)
        let set2: BTreeSet<u32> = p2.iter().copied().collect();
        assert!(
            p1.iter().all(|i| set2.contains(i)),
            "pri_list({c1}) ⊄ pri_list({c2})"
        );
    }
}

#[test]
fn prop_keep_set_is_exact_complement_of_pri_list() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xB2);
        let n = 4 + rng.below(120);
        let mut tr = Tracker::new(n);
        let delta: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
        tr.epoch_update(&delta, &[]);
        let prune = 1 + rng.below(n - 1);
        let kept = tr.keep_set(n - prune);
        let pruned = tr.pri_list(prune);
        assert_sorted_unique_in_range(&kept, n, "keep_set");
        let mut all: Vec<u32> = kept.iter().chain(pruned.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..n as u32).collect::<Vec<u32>>(), "not a partition");
    }
}

#[test]
fn prop_select_keep_invariants_on_both_paths() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xC3);
        let n = 4 + rng.below(120);
        let keep = 1 + rng.below(n);
        // random path (ZERO-Rd, or Pri before stats exist)
        let tracker = Tracker::new(n);
        let v = select_keep(n, keep, Selection::Random, Some(&tracker), &mut rng);
        assert_eq!(v.len(), keep);
        assert_sorted_unique_in_range(&v, n, "random select_keep");
        // priority path with stats
        let mut tr = Tracker::new(n);
        let delta: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
        tr.epoch_update(&delta, &[]);
        let v = select_keep(n, keep, Selection::Priority, Some(&tr), &mut rng);
        assert_eq!(v.len(), keep);
        assert_sorted_unique_in_range(&v, n, "priority select_keep");
        // keep == n is always the identity
        let v = select_keep(n, n, Selection::Priority, Some(&tr), &mut rng);
        assert_eq!(v, (0..n as u32).collect::<Vec<u32>>());
    }
}

#[test]
fn prop_pruned_sets_monotone_in_chi() {
    // χ enters through Eq. (1): T_i = χ·T_base, γ = (T_i − T_avg)/M_i.
    // Larger χ ⇒ larger γ ⇒ more pruned columns, and under priority
    // selection the pruned set grows monotonically (supersets).
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xD4);
        let n = 8 + rng.below(100);
        let mut tr = Tracker::new(n);
        let delta: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
        tr.epoch_update(&delta, &[]);
        let t_base = 0.5 + rng.uniform() as f64;
        let t_avg = t_base; // homogeneous peers
        let gamma_max = 0.875;
        let mut prev: BTreeSet<u32> = BTreeSet::new();
        let mut prev_gamma = -1.0f64;
        for chi in [1.0f64, 1.5, 2.0, 4.0, 8.0] {
            let t_i = chi * t_base;
            let m_i = 0.9 * t_i; // GEMM-dominated iteration
            let gamma = gamma_eq1(t_i, t_avg, m_i, gamma_max);
            assert!(gamma >= prev_gamma, "γ not monotone in χ");
            prev_gamma = gamma;
            let prune = ((n as f64) * gamma).floor() as usize;
            let pruned: BTreeSet<u32> = tr.pri_list(prune).into_iter().collect();
            assert_eq!(pruned.len(), prune);
            assert!(
                prev.is_subset(&pruned),
                "χ={chi}: pruned set shrank (not monotone)"
            );
            prev = pruned;
        }
        // χ=1 (no straggling) prunes nothing
        assert_eq!(gamma_eq1(t_base, t_avg, 0.9 * t_base, gamma_max), 0.0);
    }
}

// ---------------------------------------------------------------------------
// Degenerate-shape properties of the Eq. (1) kernel dataflows (PR 3):
// empty keep sets and zero dimensions must yield empty/zero outputs, not
// panics — the planners can legitimately produce them at extreme γ.
// ---------------------------------------------------------------------------

#[test]
fn prop_pruned_kernels_handle_empty_and_degenerate_selections() {
    use flextp::runtime::native::ops;
    use flextp::tensor::linalg;

    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xD6);
        let rows = rng.below(6); // 0 included
        let kfull = 1 + rng.below(40);
        let n = rng.below(24); // 0 included
        let x = rng.normal_vec(rows * kfull, 1.0);
        let w = rng.normal_vec(kfull * n, 1.0);
        let dy = rng.normal_vec(rows * n, 1.0);
        // keep set of random size, INCLUDING empty
        let kp = rng.below(kfull + 1);
        let idx: Vec<i32> = (0..kp).map(|_| rng.below(kfull) as i32).collect();
        let mask: Vec<f32> = idx.iter().map(|_| rng.uniform()).collect();

        let y = ops::pruned_matmul(&x, &w, rows, kfull, n, &idx, &mask);
        assert_eq!(y.len(), rows * n, "fwd shape (rows={rows}, n={n}, kp={kp})");
        if kp == 0 {
            assert!(y.iter().all(|&v| v == 0.0), "empty keep ⇒ zero forward");
        }
        let (dx, dw) = ops::pruned_matmul_bwd(&x, &w, &dy, rows, kfull, n, &idx, &mask);
        assert_eq!(dx.len(), rows * kfull);
        assert_eq!(dw.len(), kfull * n);
        if kp == 0 {
            assert!(dx.iter().all(|&v| v == 0.0), "empty keep ⇒ zero dx");
            assert!(dw.iter().all(|&v| v == 0.0), "empty keep ⇒ zero dw");
        }
        // kept positions partition: every non-zero dw row index is kept
        let kept: BTreeSet<usize> = idx.iter().map(|&i| i as usize).collect();
        for r in 0..kfull {
            if !kept.contains(&r) {
                assert!(
                    dw[r * n..(r + 1) * n].iter().all(|&v| v == 0.0),
                    "unkept row {r} received gradient"
                );
            }
        }
        // dense kernels on the same degenerate dims
        assert_eq!(linalg::matmul(&x, &w, rows, kfull, n).len(), rows * n);
        assert_eq!(linalg::matmul_at_b(&x, &dy, rows, kfull, n).len(), kfull * n);
        assert_eq!(linalg::matmul_a_bt(&dy, &w, rows, n, kfull).len(), rows * kfull);
    }
}

#[test]
fn prop_selection_driven_keeps_never_panic_the_kernels() {
    use flextp::runtime::native::ops;

    // Feed actual planner-produced keep sets (which are sorted/unique but
    // can hit the lane-width floor) through the fused kernels.
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0x5E);
        let n_dim = 8 * (1 + rng.below(16));
        let mut tr = Tracker::new(n_dim);
        let delta: Vec<f32> = (0..n_dim).map(|_| rng.uniform()).collect();
        tr.epoch_update(&delta, &[]);
        let prune = rng.below(n_dim);
        let keep = select_keep(n_dim, n_dim - prune, Selection::Priority, Some(&tr), &mut rng);
        let idx: Vec<i32> = keep.iter().map(|&i| i as i32).collect();
        let mask = vec![1.0f32; idx.len()];
        let rows = 3;
        let ncols = 5;
        let x = rng.normal_vec(rows * n_dim, 1.0);
        let w = rng.normal_vec(n_dim * ncols, 1.0);
        let y = ops::pruned_matmul(&x, &w, rows, n_dim, ncols, &idx, &mask);
        assert_eq!(y.len(), rows * ncols);
        assert!(y.iter().all(|v| v.is_finite()));
    }
}
