//! Property tests (seeded, hand-rolled — proptest is unavailable offline)
//! for the ZERO-resizing selection policies in `resizing/priority.rs` and
//! `resizing::select_keep`: pruned index sets must be sorted, unique, and
//! in-range, keep/prune must partition the dimension, and selections must
//! be *monotone in χ* — a slower straggler (larger Eq. 1 γ, more pruned
//! columns) prunes a superset of what a faster one prunes, so the
//! round-robin priority schedule degrades gracefully as skew grows.

use std::collections::BTreeSet;

use flextp::resizing::priority::Tracker;
use flextp::resizing::{select_keep, Selection};
use flextp::straggler::gamma_eq1;
use flextp::util::rng::Rng;

const CASES: usize = 60;

fn assert_sorted_unique_in_range(v: &[u32], n: usize, what: &str) {
    assert!(v.windows(2).all(|w| w[0] < w[1]), "{what}: not sorted/unique: {v:?}");
    assert!(v.iter().all(|&i| (i as usize) < n), "{what}: out of range: {v:?}");
}

#[test]
fn prop_pri_list_sorted_unique_in_range_and_nested_in_count() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xA1);
        let n = 4 + rng.below(120);
        let mut tr = Tracker::new(n);
        let delta: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
        tr.epoch_update(&delta, &[]);
        let c1 = 1 + rng.below(n - 1);
        let c2 = c1 + rng.below(n - c1 + 1);
        let p1 = tr.pri_list(c1);
        let p2 = tr.pri_list(c2);
        assert_eq!(p1.len(), c1);
        assert_eq!(p2.len(), c2);
        assert_sorted_unique_in_range(&p1, n, "pri_list(c1)");
        assert_sorted_unique_in_range(&p2, n, "pri_list(c2)");
        // nested: pruning more keeps the smaller pruned set inside the
        // larger one (a δ-ranked truncation is prefix-monotone)
        let set2: BTreeSet<u32> = p2.iter().copied().collect();
        assert!(
            p1.iter().all(|i| set2.contains(i)),
            "pri_list({c1}) ⊄ pri_list({c2})"
        );
    }
}

#[test]
fn prop_keep_set_is_exact_complement_of_pri_list() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xB2);
        let n = 4 + rng.below(120);
        let mut tr = Tracker::new(n);
        let delta: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
        tr.epoch_update(&delta, &[]);
        let prune = 1 + rng.below(n - 1);
        let kept = tr.keep_set(n - prune);
        let pruned = tr.pri_list(prune);
        assert_sorted_unique_in_range(&kept, n, "keep_set");
        let mut all: Vec<u32> = kept.iter().chain(pruned.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..n as u32).collect::<Vec<u32>>(), "not a partition");
    }
}

#[test]
fn prop_select_keep_invariants_on_both_paths() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xC3);
        let n = 4 + rng.below(120);
        let keep = 1 + rng.below(n);
        // random path (ZERO-Rd, or Pri before stats exist)
        let tracker = Tracker::new(n);
        let v = select_keep(n, keep, Selection::Random, Some(&tracker), &mut rng);
        assert_eq!(v.len(), keep);
        assert_sorted_unique_in_range(&v, n, "random select_keep");
        // priority path with stats
        let mut tr = Tracker::new(n);
        let delta: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
        tr.epoch_update(&delta, &[]);
        let v = select_keep(n, keep, Selection::Priority, Some(&tr), &mut rng);
        assert_eq!(v.len(), keep);
        assert_sorted_unique_in_range(&v, n, "priority select_keep");
        // keep == n is always the identity
        let v = select_keep(n, n, Selection::Priority, Some(&tr), &mut rng);
        assert_eq!(v, (0..n as u32).collect::<Vec<u32>>());
    }
}

#[test]
fn prop_pruned_sets_monotone_in_chi() {
    // χ enters through Eq. (1): T_i = χ·T_base, γ = (T_i − T_avg)/M_i.
    // Larger χ ⇒ larger γ ⇒ more pruned columns, and under priority
    // selection the pruned set grows monotonically (supersets).
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xD4);
        let n = 8 + rng.below(100);
        let mut tr = Tracker::new(n);
        let delta: Vec<f32> = (0..n).map(|_| rng.uniform()).collect();
        tr.epoch_update(&delta, &[]);
        let t_base = 0.5 + rng.uniform() as f64;
        let t_avg = t_base; // homogeneous peers
        let gamma_max = 0.875;
        let mut prev: BTreeSet<u32> = BTreeSet::new();
        let mut prev_gamma = -1.0f64;
        for chi in [1.0f64, 1.5, 2.0, 4.0, 8.0] {
            let t_i = chi * t_base;
            let m_i = 0.9 * t_i; // GEMM-dominated iteration
            let gamma = gamma_eq1(t_i, t_avg, m_i, gamma_max);
            assert!(gamma >= prev_gamma, "γ not monotone in χ");
            prev_gamma = gamma;
            let prune = ((n as f64) * gamma).floor() as usize;
            let pruned: BTreeSet<u32> = tr.pri_list(prune).into_iter().collect();
            assert_eq!(pruned.len(), prune);
            assert!(
                prev.is_subset(&pruned),
                "χ={chi}: pruned set shrank (not monotone)"
            );
            prev = pruned;
        }
        // χ=1 (no straggling) prunes nothing
        assert_eq!(gamma_eq1(t_base, t_avg, 0.9 * t_base, gamma_max), 0.0);
    }
}
