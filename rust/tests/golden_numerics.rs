//! Numeric golden tests for the native backend — no artifacts required.
//!
//! Three independent oracle families pin the executable math:
//!  1. a *hand-written naive reference* (plain loops, no shared kernels)
//!     for the attention branch forward;
//!  2. *central finite differences* through the forward executables for
//!     every backward executable's gradients (cotangent trick:
//!     φ(θ) = Σ fwd(θ) ⊙ R, so bwd(dy=R) must equal ∇θ φ);
//!  3. *cross-path exactness*: migration slice executables must partition
//!     the full FFN exactly (paper §IV-A), and pruned backwards must
//!     zero-impute exactly (paper Fig. 2).
//! Plus end-to-end descent/replication invariants on the native trainer.
//! The JAX golden-bundle comparison lives behind `--features pjrt` since
//! it needs `make artifacts`.

use flextp::config::RunCfg;
use flextp::runtime::{Arg, ModelInfo, Out, Runtime};
use flextp::tensor::Tensor;
use flextp::train::trainer::Trainer;
use flextp::util::rng::Rng;

fn rt() -> Runtime {
    Runtime::native_for("vit-tiny").expect("native vit-tiny")
}

fn tensors(outs: Vec<Out>) -> Vec<Tensor> {
    outs.into_iter()
        .map(|o| match o {
            Out::F32(t) => t,
            Out::I32(v) => Tensor::from_vec(&[v.len()], v.iter().map(|&x| x as f32).collect()),
        })
        .collect()
}

/// φ(args) = Σ fwd-output₀ ⊙ r, accumulated in f64.
fn phi(rt: &Runtime, name: &str, args: &[Arg], r: &Tensor) -> f64 {
    let (outs, _) = rt.call(name, args).expect("fwd call");
    let y = tensors(outs).remove(0);
    assert_eq!(y.len(), r.len(), "cotangent shape mismatch");
    y.data.iter().zip(&r.data).map(|(a, c)| (*a as f64) * (*c as f64)).sum()
}

type ArgBuilder = for<'a> fn(&'a [Tensor], &'a [Vec<i32>], Option<&'a Tensor>) -> Vec<Arg<'a>>;

/// Central-difference check of `grad` (the backward executable's output
/// for `ts[ti]`) against FD through the forward.  Probes the coordinate
/// with the largest analytic gradient plus a few random ones.
#[allow(clippy::too_many_arguments)]
fn check_grad_fd(
    rt: &Runtime,
    fwd: &str,
    build: ArgBuilder,
    ts: &mut [Tensor],
    idxs: &[Vec<i32>],
    r: &Tensor,
    ti: usize,
    grad: &Tensor,
    rng: &mut Rng,
    label: &str,
) {
    assert_eq!(ts[ti].len(), grad.len(), "{label}: grad shape mismatch for arg {ti}");
    let n = ts[ti].len();
    let best = (0..n)
        .max_by(|&a, &b| grad.data[a].abs().partial_cmp(&grad.data[b].abs()).unwrap())
        .unwrap();
    let mut coords = vec![best];
    for _ in 0..3 {
        coords.push(rng.below(n));
    }
    let eps = 1e-2f32;
    for &ci in &coords {
        let orig = ts[ti].data[ci];
        ts[ti].data[ci] = orig + eps;
        let fp = phi(rt, fwd, &build(ts, idxs, None), r);
        ts[ti].data[ci] = orig - eps;
        let fm = phi(rt, fwd, &build(ts, idxs, None), r);
        ts[ti].data[ci] = orig;
        let fd = (fp - fm) / (2.0 * eps as f64);
        let g = grad.data[ci] as f64;
        let tol = 0.08 * g.abs().max(fd.abs()).max(0.05);
        assert!(
            (g - fd).abs() <= tol,
            "{label}: arg {ti} coord {ci}: analytic {g} vs fd {fd}"
        );
    }
}

fn sorted_keep(rng: &mut Rng, n: usize, k: usize) -> Vec<i32> {
    rng.choose_k(n, k).into_iter().map(|i| i as i32).collect()
}

// ---------------------------------------------------------------------------
// arg builders (plain fns so the borrowed Arg lifetimes stay simple)
// ---------------------------------------------------------------------------

fn attn_args<'a>(ts: &'a [Tensor], idxs: &'a [Vec<i32>], dy: Option<&'a Tensor>) -> Vec<Arg<'a>> {
    let mut v = vec![
        Arg::F32(&ts[0]),
        Arg::F32(&ts[1]),
        Arg::F32(&ts[2]),
        Arg::F32(&ts[3]),
        Arg::F32(&ts[4]),
        Arg::I32(&idxs[0]),
        Arg::F32(&ts[5]),
    ];
    if let Some(d) = dy {
        v.push(Arg::F32(d));
    }
    v
}

fn mlp_args<'a>(ts: &'a [Tensor], idxs: &'a [Vec<i32>], dy: Option<&'a Tensor>) -> Vec<Arg<'a>> {
    let mut v = vec![
        Arg::F32(&ts[0]),
        Arg::F32(&ts[1]),
        Arg::F32(&ts[2]),
        Arg::F32(&ts[3]),
        Arg::F32(&ts[4]),
        Arg::I32(&idxs[0]),
        Arg::F32(&ts[5]),
        Arg::I32(&idxs[1]),
        Arg::F32(&ts[6]),
    ];
    if let Some(d) = dy {
        v.push(Arg::F32(d));
    }
    v
}

fn mig_args<'a>(ts: &'a [Tensor], _idxs: &'a [Vec<i32>], dy: Option<&'a Tensor>) -> Vec<Arg<'a>> {
    let mut v = vec![
        Arg::F32(&ts[0]),
        Arg::F32(&ts[1]),
        Arg::F32(&ts[2]),
        Arg::F32(&ts[3]),
        Arg::F32(&ts[4]),
    ];
    if let Some(d) = dy {
        v.push(Arg::F32(d));
    }
    v
}

fn head_args<'a>(ts: &'a [Tensor], idxs: &'a [Vec<i32>], _dy: Option<&'a Tensor>) -> Vec<Arg<'a>> {
    vec![
        Arg::F32(&ts[0]),
        Arg::F32(&ts[1]),
        Arg::F32(&ts[2]),
        Arg::F32(&ts[3]),
        Arg::F32(&ts[4]),
        Arg::I32(&idxs[0]),
    ]
}

fn embed_args<'a>(ts: &'a [Tensor], _idxs: &'a [Vec<i32>], dy: Option<&'a Tensor>) -> Vec<Arg<'a>> {
    let mut v = vec![Arg::F32(&ts[0]), Arg::F32(&ts[1]), Arg::F32(&ts[2]), Arg::F32(&ts[3])];
    if let Some(d) = dy {
        v.push(Arg::F32(d));
    }
    v
}

// ---------------------------------------------------------------------------
// 1. hand-written reference for the attention branch forward
// ---------------------------------------------------------------------------

/// Naive reference: explicit per-token LN, triple-loop GEMMs, per-head
/// softmax attention.  Shares no code with the backend kernels.
fn reference_attn_fwd(
    m: &ModelInfo,
    x: &Tensor,
    g: &Tensor,
    b: &Tensor,
    wqkv: &Tensor,
    wo: &Tensor,
) -> Vec<f32> {
    let (bs, s, hs, hl, hd, hsl) = (m.bs, m.seq, m.hs, m.hl, m.hd, m.hsl);
    let rows = bs * s;
    let mut xln = vec![0.0f32; rows * hs];
    for i in 0..rows {
        let row = &x.data[i * hs..(i + 1) * hs];
        let mu: f32 = row.iter().sum::<f32>() / hs as f32;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / hs as f32;
        let rs = 1.0 / (var + 1e-5).sqrt();
        for j in 0..hs {
            xln[i * hs + j] = (row[j] - mu) * rs * g.data[j] + b.data[j];
        }
    }
    let mut qkv = vec![0.0f32; rows * 3 * hsl];
    for i in 0..rows {
        for j in 0..3 * hsl {
            let mut acc = 0.0f32;
            for l in 0..hs {
                acc += xln[i * hs + l] * wqkv.data[l * 3 * hsl + j];
            }
            qkv[i * 3 * hsl + j] = acc;
        }
    }
    let mut o = vec![0.0f32; rows * hsl];
    let scale = 1.0 / (hd as f32).sqrt();
    for bi in 0..bs {
        for h in 0..hl {
            let at = |t: usize, sec: usize, d: usize| {
                qkv[(bi * s + t) * 3 * hsl + sec * hsl + h * hd + d]
            };
            for tq in 0..s {
                // softmax row over keys
                let mut logits = vec![0.0f32; s];
                for (tk, lv) in logits.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    for d in 0..hd {
                        acc += at(tq, 0, d) * at(tk, 1, d);
                    }
                    *lv = acc * scale;
                }
                let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut den = 0.0f32;
                for lv in &mut logits {
                    *lv = (*lv - mx).exp();
                    den += *lv;
                }
                for d in 0..hd {
                    let mut acc = 0.0f32;
                    for (tk, lv) in logits.iter().enumerate() {
                        acc += lv / den * at(tk, 2, d);
                    }
                    o[(bi * s + tq) * hsl + h * hd + d] = acc;
                }
            }
        }
    }
    let mut y = vec![0.0f32; rows * hs];
    for i in 0..rows {
        for j in 0..hs {
            let mut acc = 0.0f32;
            for l in 0..hsl {
                acc += o[i * hsl + l] * wo.data[l * hs + j];
            }
            y[i * hs + j] = acc;
        }
    }
    y
}

fn close_max(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn attn_fwd_matches_naive_reference() {
    let rt = rt();
    let m = rt.manifest.model.clone();
    let mut rng = Rng::new(101);
    let x = Tensor::normal(&[m.bs, m.seq, m.hs], 0.5, &mut rng);
    let g = Tensor::from_vec(&[m.hs], (0..m.hs).map(|_| 1.0 + 0.1 * rng.normal()).collect());
    let b = Tensor::normal(&[m.hs], 0.1, &mut rng);
    let wqkv = Tensor::normal(&[m.hs, 3 * m.hsl], 0.05, &mut rng);
    let wo = Tensor::normal(&[m.hsl, m.hs], 0.05, &mut rng);
    let idx: Vec<i32> = (0..m.hs as i32).collect();
    let mask = Tensor::full(&[m.hs], 1.0);
    let (outs, _) = rt
        .call(
            "attn_fwd_g00",
            &[Arg::F32(&x), Arg::F32(&g), Arg::F32(&b), Arg::F32(&wqkv),
              Arg::F32(&wo), Arg::I32(&idx), Arg::F32(&mask)],
        )
        .unwrap();
    let y = tensors(outs).remove(0);
    let want = reference_attn_fwd(&m, &x, &g, &b, &wqkv, &wo);
    let d = close_max(&y.data, &want);
    assert!(d < 2e-3, "attn_fwd deviates from naive reference by {d}");
}

// ---------------------------------------------------------------------------
// 2. finite-difference gradient checks for every backward executable
// ---------------------------------------------------------------------------

#[test]
fn attn_bwd_gradients_match_finite_differences() {
    let rt = rt();
    let m = rt.manifest.model.clone();
    let mut rng = Rng::new(7);
    let kq = rt.manifest.bucket_for_gamma(0.5).keep_hs;
    let idxs = vec![sorted_keep(&mut rng, m.hs, kq)];
    let mut ts = vec![
        Tensor::normal(&[m.bs, m.seq, m.hs], 0.5, &mut rng),
        Tensor::from_vec(&[m.hs], (0..m.hs).map(|_| 1.0 + 0.1 * rng.normal()).collect()),
        Tensor::normal(&[m.hs], 0.1, &mut rng),
        Tensor::normal(&[m.hs, 3 * m.hsl], 0.05, &mut rng),
        Tensor::normal(&[m.hsl, m.hs], 0.05, &mut rng),
        Tensor::full(&[kq], 1.0),
    ];
    let r = Tensor::normal(&[m.bs, m.seq, m.hs], 1.0, &mut rng);
    let (outs, _) = rt.call("attn_bwd_g50", &attn_args(&ts, &idxs, Some(&r))).unwrap();
    let grads = tensors(outs); // dx dg db dwqkv dwo
    for (ti, gi) in [(0, 0), (1, 1), (2, 2), (3, 3), (4, 4)] {
        check_grad_fd(
            &rt, "attn_fwd_g50", attn_args, &mut ts, &idxs, &r, ti, &grads[gi], &mut rng,
            "attn_bwd_g50",
        );
    }
}

#[test]
fn mlp_bwd_gradients_match_finite_differences() {
    let rt = rt();
    let m = rt.manifest.model.clone();
    let mut rng = Rng::new(8);
    let b50 = rt.manifest.bucket_for_gamma(0.5).clone();
    let idxs = vec![
        sorted_keep(&mut rng, m.hs, b50.keep_hs),
        sorted_keep(&mut rng, m.ffl, b50.keep_ffl),
    ];
    let mut ts = vec![
        Tensor::normal(&[m.bs, m.seq, m.hs], 0.5, &mut rng),
        Tensor::from_vec(&[m.hs], (0..m.hs).map(|_| 1.0 + 0.1 * rng.normal()).collect()),
        Tensor::normal(&[m.hs], 0.1, &mut rng),
        Tensor::normal(&[m.hs, m.ffl], 0.05, &mut rng),
        Tensor::normal(&[m.ffl, m.hs], 0.05, &mut rng),
        Tensor::full(&[b50.keep_hs], 1.0),
        Tensor::full(&[b50.keep_ffl], 1.0),
    ];
    let r = Tensor::normal(&[m.bs, m.seq, m.hs], 1.0, &mut rng);
    let (outs, _) = rt.call("mlp_bwd_g50", &mlp_args(&ts, &idxs, Some(&r))).unwrap();
    let grads = tensors(outs); // dx dg db dw1 dw2
    for (ti, gi) in [(0, 0), (1, 1), (2, 2), (3, 3), (4, 4)] {
        check_grad_fd(
            &rt, "mlp_fwd_g50", mlp_args, &mut ts, &idxs, &r, ti, &grads[gi], &mut rng,
            "mlp_bwd_g50",
        );
    }
}

#[test]
fn mig_bwd_gradients_match_finite_differences() {
    let rt = rt();
    let m = rt.manifest.model.clone();
    let mut rng = Rng::new(9);
    let kb = rt.manifest.mig_buckets[0];
    let idxs: Vec<Vec<i32>> = Vec::new();
    let mut ts = vec![
        Tensor::normal(&[m.bs, m.seq, m.hs], 0.5, &mut rng),
        Tensor::from_vec(&[m.hs], (0..m.hs).map(|_| 1.0 + 0.1 * rng.normal()).collect()),
        Tensor::normal(&[m.hs], 0.1, &mut rng),
        Tensor::normal(&[m.hs, kb], 0.05, &mut rng),
        Tensor::normal(&[kb, m.hs], 0.05, &mut rng),
    ];
    let r = Tensor::normal(&[m.bs, m.seq, m.hs], 1.0, &mut rng);
    let fwd = rt.manifest.mig_name("fwd", kb);
    let bwd = rt.manifest.mig_name("bwd", kb);
    let (outs, _) = rt.call(&bwd, &mig_args(&ts, &idxs, Some(&r))).unwrap();
    let grads = tensors(outs); // dx dg db dw1c dw2c
    for (ti, gi) in [(0, 0), (1, 1), (2, 2), (3, 3), (4, 4)] {
        check_grad_fd(&rt, &fwd, mig_args, &mut ts, &idxs, &r, ti, &grads[gi], &mut rng, &bwd);
    }
}

#[test]
fn head_fwdbwd_gradients_match_finite_differences() {
    let rt = rt();
    let m = rt.manifest.model.clone();
    let mut rng = Rng::new(10);
    let labels: Vec<i32> = (0..m.bs).map(|_| rng.below(m.classes) as i32).collect();
    let idxs = vec![labels];
    let mut ts = vec![
        Tensor::normal(&[m.bs, m.seq, m.hs], 0.5, &mut rng),
        Tensor::from_vec(&[m.hs], (0..m.hs).map(|_| 1.0 + 0.1 * rng.normal()).collect()),
        Tensor::normal(&[m.hs], 0.1, &mut rng),
        Tensor::normal(&[m.hs, m.classes], 0.05, &mut rng),
        Tensor::normal(&[m.classes], 0.05, &mut rng),
    ];
    // φ = loss itself (head_infer output 0 with cotangent 1)
    let r = Tensor::full(&[1], 1.0);
    let (outs, _) = rt.call("head_fwdbwd", &head_args(&ts, &idxs, None)).unwrap();
    let all = tensors(outs); // loss ncorrect dx dg db dwh dbh
    for (ti, gi) in [(0, 2), (1, 3), (2, 4), (3, 5), (4, 6)] {
        check_grad_fd(
            &rt, "head_infer", head_args, &mut ts, &idxs, &r, ti, &all[gi], &mut rng,
            "head_fwdbwd",
        );
    }
}

#[test]
fn embed_bwd_gradients_match_finite_differences() {
    let rt = rt();
    let m = rt.manifest.model.clone();
    let mut rng = Rng::new(11);
    let idxs: Vec<Vec<i32>> = Vec::new();
    let mut ts = vec![
        Tensor::normal(&[m.bs, m.seq0, m.pd], 0.5, &mut rng),
        Tensor::normal(&[m.pd, m.hs], 0.05, &mut rng),
        Tensor::normal(&[m.seq, m.hs], 0.1, &mut rng),
        Tensor::normal(&[m.hs], 0.1, &mut rng),
    ];
    let r = Tensor::normal(&[m.bs, m.seq, m.hs], 1.0, &mut rng);
    let (outs, _) = rt.call("embed_bwd", &embed_args(&ts, &idxs, Some(&r))).unwrap();
    let grads = tensors(outs); // dw_patch dpos dcls
    for (ti, gi) in [(1, 0), (2, 1), (3, 2)] {
        check_grad_fd(
            &rt, "embed_fwd", embed_args, &mut ts, &idxs, &r, ti, &grads[gi], &mut rng,
            "embed_bwd",
        );
    }
}

// ---------------------------------------------------------------------------
// 3. cross-path exactness
// ---------------------------------------------------------------------------

#[test]
fn migration_slices_partition_the_ffn_exactly() {
    let rt = rt();
    let m = rt.manifest.model.clone();
    let mut rng = Rng::new(21);
    let x = Tensor::normal(&[m.bs, m.seq, m.hs], 0.5, &mut rng);
    let g = Tensor::full(&[m.hs], 1.0);
    let b = Tensor::zeros(&[m.hs]);
    let w1 = Tensor::normal(&[m.hs, m.ffl], 0.05, &mut rng);
    let w2 = Tensor::normal(&[m.ffl, m.hs], 0.05, &mut rng);
    // full FFN through the mlp executable
    let idx1: Vec<i32> = (0..m.hs as i32).collect();
    let idx2: Vec<i32> = (0..m.ffl as i32).collect();
    let m1 = Tensor::full(&[m.hs], 1.0);
    let m2 = Tensor::full(&[m.ffl], 1.0);
    let (outs, _) = rt
        .call(
            "mlp_fwd_g00",
            &[Arg::F32(&x), Arg::F32(&g), Arg::F32(&b), Arg::F32(&w1), Arg::F32(&w2),
              Arg::I32(&idx1), Arg::F32(&m1), Arg::I32(&idx2), Arg::F32(&m2)],
        )
        .unwrap();
    let full = tensors(outs).remove(0);
    // the same FFN as two migration slices over halves of ffl
    let kb = m.ffl / 2;
    assert!(rt.manifest.mig_buckets.contains(&kb), "expected a ffl/2 bucket");
    let name = rt.manifest.mig_name("fwd", kb);
    let mut sum = Tensor::zeros(&full.dims);
    for half in 0..2 {
        let cols: Vec<u32> = (half * kb..(half + 1) * kb).map(|i| i as u32).collect();
        let w1c = w1.gather_cols(&cols);
        let w2c = w2.gather_rows(&cols);
        let (outs, _) = rt
            .call(
                &name,
                &[Arg::F32(&x), Arg::F32(&g), Arg::F32(&b), Arg::F32(&w1c), Arg::F32(&w2c)],
            )
            .unwrap();
        sum.add_assign(&tensors(outs).remove(0));
    }
    let d = close_max(&sum.data, &full.data);
    assert!(d < 2e-3, "slice partition deviates from full FFN by {d}");
}

#[test]
fn straggler_side_prune_equals_receiver_side_slice() {
    // mlp_fwd with idx2 = S (co-pruned FC1/FC2) must equal the mig slice
    // over the same columns — the two sides of a migration must agree.
    let rt = rt();
    let m = rt.manifest.model.clone();
    let mut rng = Rng::new(22);
    let x = Tensor::normal(&[m.bs, m.seq, m.hs], 0.5, &mut rng);
    let g = Tensor::full(&[m.hs], 1.0);
    let b = Tensor::zeros(&[m.hs]);
    let w1 = Tensor::normal(&[m.hs, m.ffl], 0.05, &mut rng);
    let w2 = Tensor::normal(&[m.ffl, m.hs], 0.05, &mut rng);
    let b50 = rt.manifest.bucket_for_gamma(0.5).clone();
    let kb = b50.keep_ffl;
    assert!(rt.manifest.mig_buckets.contains(&kb), "need a mig bucket matching g50");
    let keep = rng.choose_k(m.ffl, kb);
    let idx1: Vec<i32> = (0..m.hs as i32).collect();
    let idx2: Vec<i32> = keep.iter().map(|&i| i as i32).collect();
    let m1 = Tensor::full(&[m.hs], 1.0);
    let m2 = Tensor::full(&[kb], 1.0);
    let name = rt.manifest.mlp_name("fwd", "g00", &b50.name);
    let (outs, _) = rt
        .call(
            &name,
            &[Arg::F32(&x), Arg::F32(&g), Arg::F32(&b), Arg::F32(&w1), Arg::F32(&w2),
              Arg::I32(&idx1), Arg::F32(&m1), Arg::I32(&idx2), Arg::F32(&m2)],
        )
        .unwrap();
    let pruned = tensors(outs).remove(0);
    let w1c = w1.gather_cols(&keep);
    let w2c = w2.gather_rows(&keep);
    let (outs, _) = rt
        .call(
            &rt.manifest.mig_name("fwd", kb),
            &[Arg::F32(&x), Arg::F32(&g), Arg::F32(&b), Arg::F32(&w1c), Arg::F32(&w2c)],
        )
        .unwrap();
    let slice = tensors(outs).remove(0);
    let d = close_max(&pruned.data, &slice.data);
    assert!(d < 2e-3, "straggler-side and receiver-side disagree by {d}");
}

#[test]
fn pruned_backward_zero_imputes_exactly() {
    let rt = rt();
    let m = rt.manifest.model.clone();
    let mut rng = Rng::new(23);
    let b50 = rt.manifest.bucket_for_gamma(0.5).clone();
    let idxs = vec![
        sorted_keep(&mut rng, m.hs, b50.keep_hs),
        sorted_keep(&mut rng, m.ffl, b50.keep_ffl),
    ];
    let ts = vec![
        Tensor::normal(&[m.bs, m.seq, m.hs], 0.5, &mut rng),
        Tensor::full(&[m.hs], 1.0),
        Tensor::zeros(&[m.hs]),
        Tensor::normal(&[m.hs, m.ffl], 0.05, &mut rng),
        Tensor::normal(&[m.ffl, m.hs], 0.05, &mut rng),
        Tensor::full(&[b50.keep_hs], 1.0),
        Tensor::full(&[b50.keep_ffl], 1.0),
    ];
    let dy = Tensor::normal(&[m.bs, m.seq, m.hs], 1.0, &mut rng);
    let (outs, _) = rt.call("mlp_bwd_g50", &mlp_args(&ts, &idxs, Some(&dy))).unwrap();
    let grads = tensors(outs);
    let (dw1, dw2) = (&grads[3], &grads[4]);
    let kept1: std::collections::BTreeSet<i32> = idxs[0].iter().copied().collect();
    let kept2: std::collections::BTreeSet<i32> = idxs[1].iter().copied().collect();
    // dw1 pruned contraction rows (hs) and pruned columns (ffl) are zero
    for r in 0..m.hs {
        for c in 0..m.ffl {
            let v = dw1.data[r * m.ffl + c];
            if !kept1.contains(&(r as i32)) || !kept2.contains(&(c as i32)) {
                assert_eq!(v, 0.0, "dw1[{r},{c}] not zero-imputed");
            }
        }
    }
    // dw2 pruned rows (ffl) are zero, kept rows mostly nonzero
    let mut kept_nonzero = 0usize;
    for r in 0..m.ffl {
        let row = &dw2.data[r * m.hs..(r + 1) * m.hs];
        if kept2.contains(&(r as i32)) {
            kept_nonzero += row.iter().filter(|v| **v != 0.0).count();
        } else {
            assert!(row.iter().all(|&v| v == 0.0), "dw2 row {r} not zero-imputed");
        }
    }
    assert!(kept_nonzero > 0, "kept gradient rows are all zero");
}

#[test]
fn head_infer_agrees_with_head_fwdbwd() {
    let rt = rt();
    let m = rt.manifest.model.clone();
    let mut rng = Rng::new(24);
    let labels: Vec<i32> = (0..m.bs).map(|_| rng.below(m.classes) as i32).collect();
    let idxs = vec![labels];
    let ts = vec![
        Tensor::normal(&[m.bs, m.seq, m.hs], 0.5, &mut rng),
        Tensor::full(&[m.hs], 1.0),
        Tensor::zeros(&[m.hs]),
        Tensor::normal(&[m.hs, m.classes], 0.05, &mut rng),
        Tensor::zeros(&[m.classes]),
    ];
    let (a, _) = rt.call("head_fwdbwd", &head_args(&ts, &idxs, None)).unwrap();
    let (b, _) = rt.call("head_infer", &head_args(&ts, &idxs, None)).unwrap();
    assert!((a[0].scalar_f32().unwrap() - b[0].scalar_f32().unwrap()).abs() < 1e-6);
    assert_eq!(a[1].scalar_i32().unwrap(), b[1].scalar_i32().unwrap());
    let n = b[1].scalar_i32().unwrap();
    assert!((0..=m.bs as i32).contains(&n));
}

// ---------------------------------------------------------------------------
// end-to-end native-trainer invariants
// ---------------------------------------------------------------------------

#[test]
fn three_step_training_descends() {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.train.momentum = 0.0;
    let mut t = Trainer::new(cfg).expect("native trainer");
    let batch = t.data.train_batch(0);
    t.forced_batch = Some(batch);
    let mut losses = Vec::new();
    for _ in 0..3 {
        losses.push(t.train_iter().expect("step"));
    }
    assert!(losses.iter().all(|l| l.is_finite()), "loss diverged: {losses:?}");
    assert!(
        losses[2] < losses[0],
        "SGD failed to descend on a fixed batch: {losses:?}"
    );
}

#[test]
fn replicated_params_stay_identical_across_steps() {
    let mut t = Trainer::new(RunCfg::new("vit-tiny")).expect("native trainer");
    for _ in 0..2 {
        t.train_iter().unwrap();
    }
    let m = t.model().clone();
    for k in 0..m.depth {
        let base = &t.state.shards[0][k];
        for w in 1..m.e {
            let s = &t.state.shards[w][k];
            assert_eq!(base.ln1_g.data, s.ln1_g.data, "ln1_g diverged w={w} k={k}");
            assert_eq!(base.ln2_b.data, s.ln2_b.data, "ln2_b diverged w={w} k={k}");
        }
    }
}

// ---------------------------------------------------------------------------
// JAX golden bundle (needs `make artifacts`; PJRT-build cross-check only)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod jax_golden {
    use super::*;
    use flextp::config::Strategy;
    use flextp::model::{check_bundle_shapes, ModelState};
    use flextp::util::bin::Bundle;
    use std::path::Path;

    fn setup() -> Option<(Trainer, Bundle)> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/vit-tiny");
        if !dir.exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        let bundle = Bundle::load(&dir.join("golden.bin")).expect("golden bundle");
        let mut cfg = RunCfg::new("vit-tiny");
        cfg.balancer.strategy = Strategy::Baseline;
        cfg.train.lr = bundle.get("golden.sgd_lr").unwrap().f32().unwrap()[0];
        cfg.train.momentum = 0.0;
        let mut t = Trainer::new(cfg).expect("trainer");
        check_bundle_shapes(t.model(), &bundle).expect("bundle/manifest contract");
        t.state = ModelState::from_bundle(&t.model().clone(), &bundle).expect("params");
        let patches = bundle.get("batch.patches").unwrap();
        let labels = bundle.get("batch.labels").unwrap();
        t.forced_batch = Some(flextp::data::Batch {
            patches: Tensor::from_vec(&patches.dims, patches.f32().unwrap().to_vec()),
            labels: labels.i32().unwrap().to_vec(),
        });
        Some((t, bundle))
    }

    #[test]
    fn unpruned_three_step_loss_matches_jax() {
        let Some((mut t, bundle)) = setup() else { return };
        let want = bundle.get("golden.loss_steps").unwrap().f32().unwrap().to_vec();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(t.train_iter().expect("step"));
        }
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            let rel = (g - w).abs() / w.abs().max(1e-6);
            assert!(rel < 2e-3, "step {i}: rust={g} jax={w} rel={rel}");
        }
        assert!(got[2] < got[0], "SGD failed to descend: {got:?}");
    }

    #[test]
    fn pruned_step_matches_jax_zero_imputation() {
        use flextp::balancer::WorkerAction;
        use flextp::resizing::LayerPlan;
        let Some((mut t, bundle)) = setup() else { return };
        let m = t.model().clone();
        // forced action: worker 2 prunes at γ=0.5 with the bundle's keep sets
        let kq: Vec<u32> = bundle.get("keep_idx.qkv").unwrap().i32().unwrap()
            .iter().map(|&i| i as u32).collect();
        let kf: Vec<u32> = bundle.get("keep_idx.ffl").unwrap().i32().unwrap()
            .iter().map(|&i| i as u32).collect();
        let mut actions: Vec<WorkerAction> = Vec::new();
        for w in 0..m.e {
            let mut layers = Vec::new();
            for _ in 0..m.depth {
                if w == 2 % m.e {
                    layers.push(LayerPlan {
                        attn_bucket: "g50".into(),
                        mlp_b1: "g50".into(),
                        mlp_b2: "g50".into(),
                        attn_keep: kq.clone(),
                        mlp_keep1: kq.clone(),
                        mlp_keep2: kf.clone(),
                    });
                } else {
                    layers.push(LayerPlan::full(m.hs, m.ffl));
                }
            }
            actions.push(WorkerAction { layers, mig: None });
        }
        t.forced_actions = Some(actions);
        let got = t.train_iter().expect("pruned step");
        let want = bundle.get("golden.pruned_loss").unwrap().f32().unwrap()[0];
        let rel = (got - want).abs() / want.abs().max(1e-6);
        assert!(rel < 2e-3, "pruned loss rust={got} jax={want} rel={rel}");
    }

    #[test]
    fn grad_checksums_match_jax() {
        let Some((mut t, bundle)) = setup() else { return };
        // Run one step and compare worker-1 block-0 parameter deltas against
        // the golden gradient checksums: p1 = p0 - lr*g ⇒ g = (p0 - p1)/lr.
        let before = t.state.shards[1][0].clone();
        t.train_iter().expect("step");
        let after = &t.state.shards[1][0];
        let lr = t.cfg.train.lr;
        for name in ["wqkv", "wo", "w1", "w2", "ln1_g"] {
            let want = bundle.get(&format!("golden.grad_ck.{name}")).unwrap()
                .f32().unwrap().to_vec();
            let (b, a) = (before.get(name), after.get(name));
            let mut sum = 0.0f64;
            let mut abs = 0.0f64;
            for (x0, x1) in b.data.iter().zip(&a.data) {
                let g = ((x0 - x1) / lr) as f64;
                sum += g;
                abs += g.abs();
            }
            let rel_sum = (sum - want[0] as f64).abs() / (want[0].abs() as f64).max(1e-3);
            let rel_abs = (abs - want[1] as f64).abs() / (want[1].abs() as f64).max(1e-3);
            assert!(rel_sum < 5e-2, "{name}: grad sum rust={sum} jax={}", want[0]);
            assert!(rel_abs < 5e-2, "{name}: grad |sum| rust={abs} jax={}", want[1]);
        }
    }

    #[test]
    fn accuracy_counter_matches_jax() {
        let Some((mut t, bundle)) = setup() else { return };
        let want = bundle.get("golden.acc_step0").unwrap().i32().unwrap()[0];
        // re-derive ncorrect from a fresh forward before any update
        let batch = t.forced_batch.clone().unwrap();
        let x = t.forward_full(&batch).expect("fwd");
        let (outs, _) = t
            .rt
            .call(
                "head_infer",
                &[
                    Arg::F32(&x),
                    Arg::F32(&t.state.rep.lnf_g),
                    Arg::F32(&t.state.rep.lnf_b),
                    Arg::F32(&t.state.rep.w_head),
                    Arg::F32(&t.state.rep.b_head),
                    Arg::I32(&batch.labels),
                ],
            )
            .unwrap();
        let got = outs[1].scalar_i32().unwrap();
        assert_eq!(got, want, "ncorrect rust={got} jax={want}");
    }
}
