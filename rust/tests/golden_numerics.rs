//! Cross-language golden test: the Rust engine must reproduce, step for
//! step, the JAX engine simulation in `python/compile/golden.py` — same
//! shard params, same batch, same collectives, same SGD.  This validates
//! the whole stack: PJRT execution, shard bookkeeping, residual dataflow,
//! all-reduce semantics, lineage/imputation, and the optimizer.

use std::path::Path;

use flextp::balancer::WorkerAction;
use flextp::config::{RunCfg, Strategy};
use flextp::model::{check_bundle_shapes, ModelState};
use flextp::resizing::LayerPlan;
use flextp::tensor::Tensor;
use flextp::train::trainer::Trainer;
use flextp::util::bin::Bundle;

fn setup() -> Option<(Trainer, Bundle)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/vit-tiny");
    if !dir.exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let bundle = Bundle::load(&dir.join("golden.bin")).expect("golden bundle");
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.balancer.strategy = Strategy::Baseline;
    let lr = bundle.get("golden.sgd_lr").unwrap().f32().unwrap()[0];
    cfg.train.lr = lr;
    cfg.train.momentum = 0.0;
    let mut t = Trainer::new(cfg).expect("trainer");
    check_bundle_shapes(t.model(), &bundle).expect("bundle/manifest contract");
    // install golden params + batch
    t.state = ModelState::from_bundle(&t.model().clone(), &bundle).expect("params");
    let m = t.model().clone();
    let patches = bundle.get("batch.patches").unwrap();
    let labels = bundle.get("batch.labels").unwrap();
    t.forced_batch = Some(flextp::data::Batch {
        patches: Tensor::from_vec(&patches.dims, patches.f32().unwrap().to_vec()),
        labels: labels.i32().unwrap().to_vec(),
    });
    let _ = m;
    Some((t, bundle))
}

#[test]
fn unpruned_three_step_loss_matches_jax() {
    let Some((mut t, bundle)) = setup() else { return };
    let want = bundle.get("golden.loss_steps").unwrap().f32().unwrap().to_vec();
    let mut got = Vec::new();
    for _ in 0..3 {
        got.push(t.train_iter().expect("step"));
    }
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        let rel = (g - w).abs() / w.abs().max(1e-6);
        assert!(rel < 2e-3, "step {i}: rust={g} jax={w} rel={rel}");
    }
    // and the loss actually decreased over the steps
    assert!(got[2] < got[0], "SGD failed to descend: {got:?}");
}

#[test]
fn pruned_step_matches_jax_zero_imputation() {
    let Some((mut t, bundle)) = setup() else { return };
    let m = t.model().clone();
    // forced action: worker 2 prunes at γ=0.5 with the bundle's keep sets
    let kq: Vec<u32> = bundle.get("keep_idx.qkv").unwrap().i32().unwrap()
        .iter().map(|&i| i as u32).collect();
    let kf: Vec<u32> = bundle.get("keep_idx.ffl").unwrap().i32().unwrap()
        .iter().map(|&i| i as u32).collect();
    let mut actions: Vec<WorkerAction> = Vec::new();
    for w in 0..m.e {
        let mut layers = Vec::new();
        for _ in 0..m.depth {
            if w == 2 % m.e {
                layers.push(LayerPlan {
                    attn_bucket: "g50".into(),
                    mlp_b1: "g50".into(),
                    mlp_b2: "g50".into(),
                    attn_keep: kq.clone(),
                    mlp_keep1: kq.clone(),
                    mlp_keep2: kf.clone(),
                });
            } else {
                layers.push(LayerPlan::full(m.hs, m.ffl));
            }
        }
        actions.push(WorkerAction { layers, mig: None });
    }
    t.forced_actions = Some(actions);
    let got = t.train_iter().expect("pruned step");
    let want = bundle.get("golden.pruned_loss").unwrap().f32().unwrap()[0];
    let rel = (got - want).abs() / want.abs().max(1e-6);
    assert!(rel < 2e-3, "pruned loss rust={got} jax={want} rel={rel}");
}

#[test]
fn grad_checksums_match_jax() {
    let Some((mut t, bundle)) = setup() else { return };
    // Run one step and compare worker-1 block-0 parameter deltas against
    // the golden gradient checksums: p1 = p0 - lr*g ⇒ g = (p0 - p1)/lr.
    let before = t.state.shards[1][0].clone();
    t.train_iter().expect("step");
    let after = &t.state.shards[1][0];
    let lr = t.cfg.train.lr;
    for name in ["wqkv", "wo", "w1", "w2", "ln1_g"] {
        let want = bundle.get(&format!("golden.grad_ck.{name}")).unwrap()
            .f32().unwrap().to_vec();
        let (b, a) = (before.get(name), after.get(name));
        let mut sum = 0.0f64;
        let mut abs = 0.0f64;
        for (x0, x1) in b.data.iter().zip(&a.data) {
            let g = ((x0 - x1) / lr) as f64;
            sum += g;
            abs += g.abs();
        }
        let rel_sum = (sum - want[0] as f64).abs() / (want[0].abs() as f64).max(1e-3);
        let rel_abs = (abs - want[1] as f64).abs() / (want[1].abs() as f64).max(1e-3);
        assert!(rel_sum < 5e-2, "{name}: grad sum rust={sum} jax={}", want[0]);
        assert!(rel_abs < 5e-2, "{name}: grad |sum| rust={abs} jax={}", want[1]);
    }
}

#[test]
fn accuracy_counter_matches_jax() {
    let Some((mut t, bundle)) = setup() else { return };
    let want = bundle.get("golden.acc_step0").unwrap().i32().unwrap()[0];
    // re-derive ncorrect from a fresh forward before any update
    let batch = t.forced_batch.clone().unwrap();
    let x = t.forward_full(&batch).expect("fwd");
    let (outs, _) = t
        .rt
        .call(
            "head_infer",
            &[
                flextp::runtime::Arg::F32(&x),
                flextp::runtime::Arg::F32(&t.state.rep.lnf_g),
                flextp::runtime::Arg::F32(&t.state.rep.lnf_b),
                flextp::runtime::Arg::F32(&t.state.rep.w_head),
                flextp::runtime::Arg::F32(&t.state.rep.b_head),
                flextp::runtime::Arg::I32(&batch.labels),
            ],
        )
        .unwrap();
    let got = outs[1].scalar_i32().unwrap();
    assert_eq!(got, want, "ncorrect rust={got} jax={want}");
}

#[test]
fn replicated_params_stay_identical_across_steps() {
    let Some((mut t, _)) = setup() else { return };
    for _ in 0..2 {
        t.train_iter().unwrap();
    }
    // LN replicas across workers must remain bit-identical (all-reduced
    // grads + deterministic updates)
    let m = t.model().clone();
    for k in 0..m.depth {
        let base = &t.state.shards[0][k];
        for w in 1..m.e {
            let s = &t.state.shards[w][k];
            assert_eq!(base.ln1_g.data, s.ln1_g.data, "ln1_g diverged w={w} k={k}");
            assert_eq!(base.ln2_b.data, s.ln2_b.data, "ln2_b diverged w={w} k={k}");
        }
    }
}
