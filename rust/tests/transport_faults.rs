//! Transport fault-injection suite (ISSUE 7, DESIGN.md §15).
//!
//! The failure contract: every transport fault surfaces as a typed
//! `TransportError` — never a panic, never a hang — and the one
//! recoverable fault, a dead rank process (`PeerDied`), flows through
//! the PR 6 churn path: the coordinator re-shards the survivors onto
//! the nearest divisor-compatible worker count and the finished run is
//! **bitwise identical** to the kill/checkpoint/`--resume --e E'`
//! oracle.  Pinned here with a real `SIGKILL` (via `Child::kill`), a
//! really-stalled rank (`SIGSTOP` and the built-in stall fault), and
//! the zero-survivor floor.

use flextp::collectives::transport::{LocalTcp, Transport, TransportError};
use flextp::config::{ReplanMode, RunCfg, StragglerPlan, Strategy, TimeModel, TransportKind};
use flextp::contention::{ScenarioError, ScenarioSpec};
use flextp::metrics::RunReport;
use flextp::tensor::Tensor;
use flextp::train::trainer::Trainer;

fn rank_exe() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_BIN_EXE_flextp"))
}

/// vit-tiny (hs=128, heads=4, e=4) with a bursty tenant trace and the
/// deterministic modeled clock — same dynamic pipeline the parity suite
/// runs, so fault recovery is exercised under a non-trivial plan.
fn fault_cfg(transport: TransportKind) -> RunCfg {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.train.threads = 1;
    cfg.train.epochs = 2;
    cfg.train.iters_per_epoch = 6;
    cfg.train.eval_iters = 2;
    cfg.train.momentum = 0.9;
    cfg.train.time_model = TimeModel::Modeled;
    cfg.train.transport = transport;
    cfg.train.rank_exe = Some(rank_exe());
    cfg.balancer.strategy = Strategy::Semi;
    cfg.balancer.replan = ReplanMode::Online;
    cfg.balancer.forced_lambda = Some(1);
    cfg.stragglers = StragglerPlan::Scenario(
        ScenarioSpec::parse("burst:r1@x5:iters2-9,markov:r3@x2:p0.4-0.3,seed:9")
            .expect("scenario"),
    );
    cfg
}

type Observables = (RunReport, u64, u64, usize);

fn observe(r: RunReport, t: &Trainer) -> Observables {
    (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops, t.model().e)
}

fn assert_bitwise(a: &Observables, b: &Observables, what: &str) {
    assert!(
        a.0.loss_curve.iter().all(|l| l.is_finite()),
        "{what}: diverged: {:?}",
        a.0.loss_curve
    );
    assert_eq!(a.0.loss_curve, b.0.loss_curve, "{what}: losses must be bitwise identical");
    assert!(a.0.sim_equal(&b.0), "{what}: per-epoch sim metrics must be bitwise identical");
    assert_eq!(a.1, b.1, "{what}: CommStats::total_bytes must match");
    assert_eq!(a.2, b.2, "{what}: all-reduce op counts must match");
    assert_eq!(a.3, b.3, "{what}: final worker counts must match");
}

/// The headline: SIGKILL rank 2 after iteration 3, mid-run.  The next
/// collective observes the typed `PeerDied`, the coordinator re-shards
/// 4→2 (3 survivors, but 3 divides neither hs=128 nor heads=4), retries
/// the iteration, and finishes — and the whole run reproduces the PR 5
/// kill/checkpoint/`--resume --e 2` oracle bit for bit.
#[test]
fn sigkilled_rank_recovers_through_churn_path_and_matches_oracle() {
    let mut t = Trainer::new(fault_cfg(TransportKind::Tcp)).expect("trainer");
    t.run_to(Some(3)).expect("warmup to the kill point");
    assert_eq!(t.model().e, 4);
    assert!(t.debug_kill_rank(2), "the rank process must exist to be killed");
    let r = t.run().expect("the run must survive the kill");
    let live = observe(r, &t);
    assert_eq!(live.3, 2, "4 ranks with one dead must re-shard to E'=2");
    assert_eq!(live.0.loss_curve.len(), 12, "every scheduled iteration ran");

    // the oracle: same schedule, killed at the same cut, resumed at E'=2
    let cfg = fault_cfg(TransportKind::InProc);
    let dir = std::env::temp_dir()
        .join(format!("flextp_faults_oracle_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let p3 = dir.join(flextp::checkpoint::ckpt_filename(3));
    {
        let mut t = Trainer::new(cfg.clone()).expect("oracle trainer");
        t.run_to(Some(3)).expect("oracle to the cut");
        t.save_checkpoint(&p3).expect("save @3");
        // drop = the kill
    }
    let mut shrunk = cfg;
    shrunk.e_override = Some(2);
    let mut t = Trainer::resume_from(shrunk, &p3).expect("oracle resume onto e=2");
    let r = t.run().expect("oracle run");
    let oracle = observe(r, &t);
    let _ = std::fs::remove_dir_all(&dir);

    assert_bitwise(&live, &oracle, "real kill vs kill/checkpoint/resume oracle");
}

/// Two kills in one run: 4→2 after iteration 2, then the e=2 group
/// loses another rank after iteration 4 and re-forms at E'=2 on the
/// remaining availability.  Repeated recovery must still finish the
/// schedule with finite losses.
#[test]
fn repeated_kills_keep_recovering() {
    let mut t = Trainer::new(fault_cfg(TransportKind::Tcp)).expect("trainer");
    t.run_to(Some(2)).expect("warmup");
    assert!(t.debug_kill_rank(3));
    t.run_to(Some(4)).expect("across the first recovery");
    assert_eq!(t.model().e, 2, "first kill re-shards 4→2");
    assert!(t.debug_kill_rank(1), "the respawned e=2 group is live");
    let r = t.run().expect("across the second recovery");
    assert_eq!(t.model().e, 2, "2 survivors still shard at E'=2");
    assert!(r.loss_curve.iter().all(|l| l.is_finite()));
    assert_eq!(r.loss_curve.len(), 12);
}

/// Zero survivors is the same typed error scenario churn produces —
/// `NoViableWorkerCount` — never a panic or a hang.
#[test]
fn losing_every_worker_is_a_typed_error() {
    let mut cfg = fault_cfg(TransportKind::Tcp);
    cfg.e_override = Some(1);
    // the bursty trace targets r1/r3, which don't exist at e=1
    cfg.stragglers =
        StragglerPlan::Scenario(flextp::contention::preset("calm").expect("calm preset"));
    let mut t = Trainer::new(cfg).expect("trainer");
    t.run_to(Some(2)).expect("warmup");
    assert!(t.debug_kill_rank(0));
    let err = t.run().expect_err("no survivors must fail the run");
    let scen = err
        .downcast_ref::<ScenarioError>()
        .unwrap_or_else(|| panic!("expected a typed ScenarioError, got: {err:#}"));
    assert!(
        matches!(scen, ScenarioError::NoViableWorkerCount { avail: 0, .. }),
        "got: {scen}"
    );
}

/// A stalled (but alive) rank is *not* PeerDied: the coordinator's
/// bounded read surfaces a typed `Timeout` instead of hanging.  Uses
/// the built-in stall fault — rank 1 parks forever at its first Work
/// frame, the deterministic stand-in for a SIGSTOP'd process.
#[test]
fn stalled_rank_surfaces_as_typed_timeout() {
    let mut t = LocalTcp::new(300, Some(rank_exe()));
    t.set_stall(1, 0);
    let mut bufs: Vec<Tensor> =
        (0..4).map(|r| Tensor::from_vec(&[8], vec![r as f32; 8])).collect();
    let err = t.all_reduce("stall-test", &mut bufs).expect_err("stalled rank must time out");
    assert!(matches!(err, TransportError::Timeout { .. }), "got: {err}");
}

/// The same stall through the whole trainer, with a real `SIGSTOP`:
/// the run fails fast with a typed `Timeout` in the error chain — a
/// stopped process is alive, so this must *not* take the PeerDied
/// recovery path or re-shard.
#[cfg(unix)]
#[test]
fn sigstopped_rank_times_out_with_typed_error() {
    let mut cfg = fault_cfg(TransportKind::Tcp);
    cfg.train.transport_timeout_ms = 300;
    let mut t = Trainer::new(cfg).expect("trainer");
    t.run_to(Some(2)).expect("warmup");
    let pid = t.debug_rank_pid(1).expect("spawned group");
    let stopped = std::process::Command::new("kill")
        .args(["-STOP", &pid.to_string()])
        .status()
        .map(|s| s.success())
        .unwrap_or(false);
    assert!(stopped, "kill -STOP {pid} failed");
    let err = t.run().expect_err("a stalled rank must surface, not hang");
    let timed_out =
        matches!(err.downcast_ref::<TransportError>(), Some(TransportError::Timeout { .. }));
    assert!(timed_out, "expected a typed Timeout as the root cause, got: {err:?}");
    assert_eq!(t.model().e, 4, "a timeout must not trigger the re-shard path");
    // Trainer drop tears the group down; SIGKILL reaps stopped processes
}

/// Direct kill on a raw transport group: a clean warmup reduce, then a
/// SIGKILL, then the typed `PeerDied` on the next collective — the
/// signal the trainer's recovery path keys on.
#[test]
fn killed_rank_surfaces_as_typed_peer_died() {
    let mut t = LocalTcp::new(2_000, Some(rank_exe()));
    let mut bufs: Vec<Tensor> =
        (0..4).map(|r| Tensor::from_vec(&[8], vec![r as f32; 8])).collect();
    t.all_reduce("warmup", &mut bufs).expect("clean reduce");
    for b in &bufs {
        assert!(b.data.iter().all(|&x| x == 6.0), "0+1+2+3 on every rank, got {:?}", b.data);
    }
    assert!(t.kill_rank(2));
    // give the kernel a beat to reap, so the liveness probe sees it
    std::thread::sleep(std::time::Duration::from_millis(50));
    let err = t.all_reduce("after-kill", &mut bufs).expect_err("dead rank must surface");
    assert_eq!(err, TransportError::PeerDied { rank: 2 }, "signal-killed rank wins the blame");
}
