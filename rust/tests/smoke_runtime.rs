//! Runtime smoke: load real vit-tiny artifacts, execute, check shapes and
//! basic numerics (requires `make artifacts`).

use std::path::Path;

use flextp::runtime::{Arg, Runtime};
use flextp::tensor::Tensor;

fn artifacts() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/vit-tiny");
    if !dir.exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(&dir).expect("load runtime"))
}

#[test]
fn embed_fwd_executes_with_correct_shapes() {
    let Some(rt) = artifacts() else { return };
    let m = &rt.manifest.model;
    let patches = Tensor::full(&[m.bs, m.seq0, m.pd], 0.1);
    let w_patch = Tensor::full(&[m.pd, m.hs], 0.01);
    let pos = Tensor::zeros(&[m.seq, m.hs]);
    let cls = Tensor::full(&[m.hs], 0.5);
    let (outs, secs) = rt
        .call(
            "embed_fwd",
            &[Arg::F32(&patches), Arg::F32(&w_patch), Arg::F32(&pos), Arg::F32(&cls)],
        )
        .expect("call embed_fwd");
    assert!(secs > 0.0);
    let x0 = outs.into_iter().next().unwrap().tensor().unwrap();
    assert_eq!(x0.dims, vec![m.bs, m.seq, m.hs]);
    // cls token row = cls value (pos is zero)
    assert!((x0.data[0] - 0.5).abs() < 1e-6);
    // patch rows = sum of pd * 0.1 * 0.01
    let want = m.pd as f32 * 0.1 * 0.01;
    assert!((x0.data[m.hs] - want).abs() < 1e-5, "{} vs {want}", x0.data[m.hs]);
}

#[test]
fn attn_fwd_full_bucket_runs() {
    let Some(rt) = artifacts() else { return };
    let m = rt.manifest.model.clone();
    let x = Tensor::full(&[m.bs, m.seq, m.hs], 0.1);
    let g = Tensor::full(&[m.hs], 1.0);
    let b = Tensor::zeros(&[m.hs]);
    let wqkv = Tensor::full(&[m.hs, 3 * m.hsl], 0.01);
    let wo = Tensor::full(&[m.hsl, m.hs], 0.01);
    let idx: Vec<i32> = (0..m.hs as i32).collect();
    let mask = Tensor::full(&[m.hs], 1.0);
    let (outs, _) = rt
        .call(
            "attn_fwd_g00",
            &[Arg::F32(&x), Arg::F32(&g), Arg::F32(&b), Arg::F32(&wqkv),
              Arg::F32(&wo), Arg::I32(&idx), Arg::F32(&mask)],
        )
        .expect("attn_fwd_g00");
    let y = outs.into_iter().next().unwrap().tensor().unwrap();
    assert_eq!(y.dims, vec![m.bs, m.seq, m.hs]);
    assert!(y.data.iter().all(|v| v.is_finite()));
}

#[test]
fn timing_profile_accumulates() {
    let Some(rt) = artifacts() else { return };
    let m = &rt.manifest.model;
    let patches = Tensor::zeros(&[m.bs, m.seq0, m.pd]);
    let w_patch = Tensor::zeros(&[m.pd, m.hs]);
    let pos = Tensor::zeros(&[m.seq, m.hs]);
    let cls = Tensor::zeros(&[m.hs]);
    for _ in 0..3 {
        rt.call(
            "embed_fwd",
            &[Arg::F32(&patches), Arg::F32(&w_patch), Arg::F32(&pos), Arg::F32(&cls)],
        )
        .unwrap();
    }
    let prof = rt.timing_profile();
    let e = prof.iter().find(|(n, _, _)| n == "embed_fwd").unwrap();
    assert_eq!(e.1, 3);
    assert!(e.2 > 0.0);
}

#[test]
fn dim_mismatch_rejected() {
    let Some(rt) = artifacts() else { return };
    let bad = Tensor::zeros(&[1, 2, 3]);
    let z = Tensor::zeros(&[1]);
    assert!(rt
        .call("embed_fwd", &[Arg::F32(&bad), Arg::F32(&z), Arg::F32(&z), Arg::F32(&z)])
        .is_err());
}
