//! Runtime smoke: open vit-tiny on the native backend (manifest
//! synthesized — no artifacts, no Python), execute representative
//! executables, and check shapes, validation, and basic numerics.
//! Runs unconditionally; the PJRT-vs-native cross-check at the bottom is
//! gated on `--features pjrt` + compiled artifacts.

use flextp::runtime::{Arg, Runtime};
use flextp::tensor::Tensor;

fn native() -> Runtime {
    Runtime::native_for("vit-tiny").expect("synthesize vit-tiny runtime")
}

#[test]
fn embed_fwd_executes_with_correct_shapes() {
    let rt = native();
    let m = &rt.manifest.model;
    let patches = Tensor::full(&[m.bs, m.seq0, m.pd], 0.1);
    let w_patch = Tensor::full(&[m.pd, m.hs], 0.01);
    let pos = Tensor::zeros(&[m.seq, m.hs]);
    let cls = Tensor::full(&[m.hs], 0.5);
    let (outs, secs) = rt
        .call(
            "embed_fwd",
            &[Arg::F32(&patches), Arg::F32(&w_patch), Arg::F32(&pos), Arg::F32(&cls)],
        )
        .expect("call embed_fwd");
    assert!(secs > 0.0);
    let x0 = outs.into_iter().next().unwrap().tensor().unwrap();
    assert_eq!(x0.dims, vec![m.bs, m.seq, m.hs]);
    // cls token row = cls value (pos is zero)
    assert!((x0.data[0] - 0.5).abs() < 1e-6);
    // patch rows = sum of pd * 0.1 * 0.01
    let want = m.pd as f32 * 0.1 * 0.01;
    assert!((x0.data[m.hs] - want).abs() < 1e-5, "{} vs {want}", x0.data[m.hs]);
}

#[test]
fn attn_fwd_full_bucket_runs() {
    let rt = native();
    let m = rt.manifest.model.clone();
    let x = Tensor::full(&[m.bs, m.seq, m.hs], 0.1);
    let g = Tensor::full(&[m.hs], 1.0);
    let b = Tensor::zeros(&[m.hs]);
    let wqkv = Tensor::full(&[m.hs, 3 * m.hsl], 0.01);
    let wo = Tensor::full(&[m.hsl, m.hs], 0.01);
    let idx: Vec<i32> = (0..m.hs as i32).collect();
    let mask = Tensor::full(&[m.hs], 1.0);
    let (outs, _) = rt
        .call(
            "attn_fwd_g00",
            &[Arg::F32(&x), Arg::F32(&g), Arg::F32(&b), Arg::F32(&wqkv),
              Arg::F32(&wo), Arg::I32(&idx), Arg::F32(&mask)],
        )
        .expect("attn_fwd_g00");
    let y = outs.into_iter().next().unwrap().tensor().unwrap();
    assert_eq!(y.dims, vec![m.bs, m.seq, m.hs]);
    assert!(y.data.iter().all(|v| v.is_finite()));
}

#[test]
fn every_pruning_bucket_executes() {
    let rt = native();
    let m = rt.manifest.model.clone();
    let x = Tensor::full(&[m.bs, m.seq, m.hs], 0.1);
    let g = Tensor::full(&[m.hs], 1.0);
    let b = Tensor::zeros(&[m.hs]);
    let wqkv = Tensor::full(&[m.hs, 3 * m.hsl], 0.01);
    let wo = Tensor::full(&[m.hsl, m.hs], 0.01);
    for bucket in rt.manifest.buckets.clone() {
        let idx: Vec<i32> = (0..bucket.keep_hs as i32).collect();
        let mask = Tensor::full(&[bucket.keep_hs], 1.0);
        let name = rt.manifest.attn_name("fwd", &bucket.name);
        let (outs, _) = rt
            .call(
                &name,
                &[Arg::F32(&x), Arg::F32(&g), Arg::F32(&b), Arg::F32(&wqkv),
                  Arg::F32(&wo), Arg::I32(&idx), Arg::F32(&mask)],
            )
            .unwrap_or_else(|e| panic!("{name}: {e:?}"));
        let y = outs.into_iter().next().unwrap().tensor().unwrap();
        assert!(y.data.iter().all(|v| v.is_finite()), "{name} produced non-finite");
    }
}

#[test]
fn mixed_mlp_bucket_pair_executes() {
    // differentiated ratios (Alg. 1) pick FC1/FC2 buckets independently —
    // the synthesized inventory must cover mixed non-g00 pairs
    let rt = native();
    let m = rt.manifest.model.clone();
    let x = Tensor::full(&[m.bs, m.seq, m.hs], 0.1);
    let g = Tensor::full(&[m.hs], 1.0);
    let b = Tensor::zeros(&[m.hs]);
    let w1 = Tensor::full(&[m.hs, m.ffl], 0.01);
    let w2 = Tensor::full(&[m.ffl, m.hs], 0.01);
    let b1 = rt.manifest.bucket_for_gamma(0.25).clone();
    let b2 = rt.manifest.bucket_for_gamma(0.5).clone();
    assert_ne!(b1.name, b2.name);
    let idx1: Vec<i32> = (0..b1.keep_hs as i32).collect();
    let idx2: Vec<i32> = (0..b2.keep_ffl as i32).collect();
    let m1 = Tensor::full(&[b1.keep_hs], 1.0);
    let m2 = Tensor::full(&[b2.keep_ffl], 1.0);
    let name = rt.manifest.mlp_name("fwd", &b1.name, &b2.name);
    let (outs, _) = rt
        .call(
            &name,
            &[Arg::F32(&x), Arg::F32(&g), Arg::F32(&b), Arg::F32(&w1), Arg::F32(&w2),
              Arg::I32(&idx1), Arg::F32(&m1), Arg::I32(&idx2), Arg::F32(&m2)],
        )
        .unwrap_or_else(|e| panic!("{name}: {e:?}"));
    let y = outs.into_iter().next().unwrap().tensor().unwrap();
    assert!(y.data.iter().all(|v| v.is_finite()));
}

#[test]
fn out_of_range_keep_index_is_an_error_not_a_panic() {
    let rt = native();
    let m = rt.manifest.model.clone();
    let x = Tensor::full(&[m.bs, m.seq, m.hs], 0.1);
    let g = Tensor::full(&[m.hs], 1.0);
    let b = Tensor::zeros(&[m.hs]);
    let wqkv = Tensor::full(&[m.hs, 3 * m.hsl], 0.01);
    let wo = Tensor::full(&[m.hsl, m.hs], 0.01);
    let mut idx: Vec<i32> = (0..m.hs as i32).collect();
    idx[0] = m.hs as i32; // one past the end
    let mask = Tensor::full(&[m.hs], 1.0);
    let err = rt
        .call(
            "attn_fwd_g00",
            &[Arg::F32(&x), Arg::F32(&g), Arg::F32(&b), Arg::F32(&wqkv),
              Arg::F32(&wo), Arg::I32(&idx), Arg::F32(&mask)],
        )
        .unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");
}

#[test]
fn timing_profile_accumulates() {
    let rt = native();
    let m = &rt.manifest.model;
    let patches = Tensor::zeros(&[m.bs, m.seq0, m.pd]);
    let w_patch = Tensor::zeros(&[m.pd, m.hs]);
    let pos = Tensor::zeros(&[m.seq, m.hs]);
    let cls = Tensor::zeros(&[m.hs]);
    for _ in 0..3 {
        rt.call(
            "embed_fwd",
            &[Arg::F32(&patches), Arg::F32(&w_patch), Arg::F32(&pos), Arg::F32(&cls)],
        )
        .unwrap();
    }
    let prof = rt.timing_profile();
    let e = prof.iter().find(|(n, _, _)| n == "embed_fwd").unwrap();
    assert_eq!(e.1, 3);
    assert!(e.2 > 0.0);
}

#[test]
fn dim_mismatch_rejected() {
    let rt = native();
    let bad = Tensor::zeros(&[1, 2, 3]);
    let z = Tensor::zeros(&[1]);
    assert!(rt
        .call("embed_fwd", &[Arg::F32(&bad), Arg::F32(&z), Arg::F32(&z), Arg::F32(&z)])
        .is_err());
}

#[test]
fn open_falls_back_to_preset_synthesis_without_artifacts() {
    // the clean-checkout path the trainer uses
    let rt = Runtime::open(
        std::path::Path::new("artifacts/definitely-absent"),
        "vit-tiny",
        flextp::config::BackendKind::Native,
    )
    .expect("open with synthesized manifest");
    assert_eq!(rt.manifest.model.name, "vit-tiny");
}

#[test]
fn open_prefers_disk_manifest_when_present() {
    // a compiled manifest on disk (possibly with non-preset bucket sizes)
    // must win over synthesis
    let dir = std::env::temp_dir().join(format!("flextp-smoke-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest_json = r#"{
      "model": {"name":"disk-test","hs":32,"depth":1,"heads":4,"e":4,"bs":2,
                "classes":10,"seq":17,"seq0":16,"pd":48,"hsl":8,"hl":1,
                "hd":8,"ffl":32,"params_total":1000,"params_per_worker":300},
      "buckets": [{"name":"g00","gamma":0,"keep_hs":32,"keep_ffl":32}],
      "mig_buckets": [8],
      "executables": []
    }"#;
    std::fs::write(dir.join("manifest.json"), manifest_json).unwrap();
    let rt = Runtime::open(&dir, "vit-tiny", flextp::config::BackendKind::Native)
        .expect("open with disk manifest");
    assert_eq!(rt.manifest.model.name, "disk-test", "disk manifest was ignored");
    assert_eq!(rt.manifest.model.hs, 32);
    std::fs::remove_dir_all(&dir).ok();
}

/// PJRT-vs-native cross-check: only meaningful in a `--features pjrt`
/// build with real bindings and compiled artifacts on disk.
#[cfg(feature = "pjrt")]
mod pjrt_cross_check {
    use super::*;
    use flextp::config::BackendKind;
    use std::path::Path;

    #[test]
    fn pjrt_matches_native_on_embed_fwd() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/vit-tiny");
        if !dir.exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let Ok(pjrt) = Runtime::open(&dir, "vit-tiny", BackendKind::Pjrt) else {
            eprintln!("skipping: pjrt backend unavailable (stub xla build)");
            return;
        };
        let native = Runtime::open(&dir, "vit-tiny", BackendKind::Native).unwrap();
        let m = native.manifest.model.clone();
        let patches = Tensor::full(&[m.bs, m.seq0, m.pd], 0.1);
        let w_patch = Tensor::full(&[m.pd, m.hs], 0.01);
        let pos = Tensor::zeros(&[m.seq, m.hs]);
        let cls = Tensor::full(&[m.hs], 0.5);
        let args = [Arg::F32(&patches), Arg::F32(&w_patch), Arg::F32(&pos), Arg::F32(&cls)];
        let a = native.call("embed_fwd", &args).unwrap().0[0].clone().tensor().unwrap();
        let args = [Arg::F32(&patches), Arg::F32(&w_patch), Arg::F32(&pos), Arg::F32(&cls)];
        let b = pjrt.call("embed_fwd", &args).unwrap().0[0].clone().tensor().unwrap();
        assert!(a.allclose(&b, 1e-4), "backends disagree on embed_fwd");
    }
}
