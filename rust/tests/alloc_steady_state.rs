//! Zero-alloc steady-state property of the native compute path.
//!
//! A counting global allocator wraps `System`; after a warmup call that
//! populates the workspace, repeated backend calls on the same shapes
//! must allocate only their *outputs* (plus trivial bookkeeping) — no
//! full-size gathered-operand copies, no per-call intermediate buffers.
//! This is the allocation-side acceptance check for the fused pruned
//! contraction + workspace arena of PR 3.
//!
//! Single `#[test]` on purpose: the counters are process-global, so a
//! second concurrently-running test would pollute the deltas.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use flextp::runtime::{Arg, Out, Runtime};
use flextp::tensor::{Tensor, Workspace};
use flextp::util::rng::Rng;

struct Counting;

static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);
static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

// SAFETY: defers entirely to `System`; only counters are added.
unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTING: Counting = Counting;

fn bytes() -> u64 {
    ALLOC_BYTES.load(Ordering::Relaxed)
}

/// Run one call and return (allocated bytes, outputs).
fn measured_call(
    rt: &Runtime,
    name: &str,
    args: &[Arg],
    ws: &mut Workspace,
) -> (u64, Vec<Out>) {
    let before = bytes();
    let (outs, _) = rt.call_ws(name, args, ws).expect("backend call");
    (bytes() - before, outs)
}

/// Recycle every f32 output buffer into the workspace, as the trainer
/// does after merging partials; returns the payload byte count.
fn recycle_outputs(outs: Vec<Out>, ws: &mut Workspace) -> u64 {
    let mut total = 0u64;
    for o in outs {
        if let Out::F32(t) = o {
            total += (t.data.len() * 4) as u64;
            ws.give(t.data);
        }
    }
    total
}

#[test]
fn steady_state_backend_calls_allocate_at_most_their_outputs() {
    let rt = Runtime::native_for("vit-tiny").expect("native runtime");
    let m = rt.manifest.model.clone();
    let rows = m.bs * m.seq;
    let mut rng = Rng::new(123);
    let x = Tensor::normal(&[m.bs, m.seq, m.hs], 1.0, &mut rng);
    let ln_g = Tensor::full(&[m.hs], 1.0);
    let ln_b = Tensor::zeros(&[m.hs]);
    let w1 = Tensor::normal(&[m.hs, m.ffl], 0.1, &mut rng);
    let w2 = Tensor::normal(&[m.ffl, m.hs], 0.1, &mut rng);
    let wqkv = Tensor::normal(&[m.hs, 3 * m.hsl], 0.1, &mut rng);
    let wo = Tensor::normal(&[m.hsl, m.hs], 0.1, &mut rng);
    let dy = Tensor::normal(&[m.bs, m.seq, m.hs], 1.0, &mut rng);
    let idx_hs: Vec<i32> = (0..m.hs as i32).collect();
    let ones_hs = Tensor::full(&[m.hs], 1.0);
    let idx_ffl: Vec<i32> = (0..m.ffl as i32).collect();
    let ones_ffl = Tensor::full(&[m.ffl], 1.0);

    let mlp_bwd_args = [
        Arg::F32(&x),
        Arg::F32(&ln_g),
        Arg::F32(&ln_b),
        Arg::F32(&w1),
        Arg::F32(&w2),
        Arg::I32(&idx_hs),
        Arg::F32(&ones_hs),
        Arg::I32(&idx_ffl),
        Arg::F32(&ones_ffl),
        Arg::F32(&dy),
    ];
    let attn_bwd_args = [
        Arg::F32(&x),
        Arg::F32(&ln_g),
        Arg::F32(&ln_b),
        Arg::F32(&wqkv),
        Arg::F32(&wo),
        Arg::I32(&idx_hs),
        Arg::F32(&ones_hs),
        Arg::F32(&dy),
    ];

    let mut ws = Workspace::new();
    // cold call: populates the workspace, allocates plenty
    let (cold_mlp, outs) = measured_call(&rt, "mlp_bwd_g00", &mlp_bwd_args, &mut ws);
    let reference = outs
        .iter()
        .map(|o| match o {
            Out::F32(t) => t.data.clone(),
            Out::I32(v) => v.iter().map(|&i| i as f32).collect(),
        })
        .collect::<Vec<_>>();
    let out_bytes_mlp = recycle_outputs(outs, &mut ws);
    let (_, outs) = measured_call(&rt, "attn_bwd_g00", &attn_bwd_args, &mut ws);
    let out_bytes_attn = recycle_outputs(outs, &mut ws);
    assert!(
        cold_mlp > out_bytes_mlp,
        "cold call must allocate intermediates ({cold_mlp} vs outputs {out_bytes_mlp}) — \
         is the counting allocator active?"
    );
    // a few more warm rounds so the arena's size-class pool stabilizes
    for _ in 0..3 {
        let (_, outs) = measured_call(&rt, "mlp_bwd_g00", &mlp_bwd_args, &mut ws);
        recycle_outputs(outs, &mut ws);
        let (_, outs) = measured_call(&rt, "attn_bwd_g00", &attn_bwd_args, &mut ws);
        recycle_outputs(outs, &mut ws);
    }
    let warm_ws_allocs = ws.alloc_count();

    // steady state: with outputs recycled, per-call allocation must stay
    // far below one full-size intermediate (rows × hs f32 ≈ 266 KB); the
    // only remaining traffic is Vec-of-Out/dims bookkeeping.  Outputs
    // themselves come out of the workspace because we feed them back.
    let slack = 64 * 1024u64;
    let full_intermediate = (rows * m.hs * 4) as u64;
    assert!(slack < full_intermediate, "slack must discriminate");
    for step in 0..5 {
        let (d, outs) = measured_call(&rt, "mlp_bwd_g00", &mlp_bwd_args, &mut ws);
        // determinism: workspace reuse must not change results bitwise
        for (got, want) in outs.iter().zip(&reference) {
            if let Out::F32(t) = got {
                assert_eq!(&t.data, want, "step {step}: workspace reuse changed results");
            }
        }
        let recycled = recycle_outputs(outs, &mut ws);
        assert!(
            d <= slack,
            "step {step}: mlp_bwd_g00 allocated {d} B in steady state \
             (recycled {recycled} B of outputs; full intermediate would be {full_intermediate} B)"
        );
        let (d, outs) = measured_call(&rt, "attn_bwd_g00", &attn_bwd_args, &mut ws);
        let _ = recycle_outputs(outs, &mut ws);
        assert!(
            d <= slack,
            "step {step}: attn_bwd_g00 allocated {d} B in steady state \
             (outputs were {out_bytes_attn} B)"
        );
    }
    // the arena itself must be fully warmed: no take fell through to the
    // allocator during the measured steps
    assert_eq!(
        ws.alloc_count(),
        warm_ws_allocs,
        "workspace allocated new buffers in steady state"
    );
}
