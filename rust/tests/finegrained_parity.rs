//! Fine-grained-degree parity suite (ISSUE 10, DESIGN.md §18).
//!
//! The tentpole contract: per-component TP degrees are a pure geometry
//! choice.  A `semi@online` run whose attn/mlp components execute over
//! the rank prefix `0..2` while embed/head stay replicated over all 4
//! workers must produce **bitwise identical** observables — losses,
//! per-epoch sim metrics (modulo wall time), `CommStats` — at
//! `--threads` 1 and 4 and over both transports (in-process buffer
//! slots vs rank processes on localhost TCP), because the sub-group
//! all-reduce reuses the full group's binomial/stride association
//! order on the member prefix.
//!
//! Also pinned: `--degrees auto` resolving to the same vector (and the
//! same bits) as the explicit `--e-attn 2 --e-mlp 2` run under a
//! heavy-tail χ row, and the degree vector surviving a
//! kill/checkpoint/resume cycle bitwise — including an elastic resume
//! that re-shards the mixed checkpoint back to uniform degrees.

use flextp::config::{
    DegreeOverrides, ReplanMode, RunCfg, StragglerPlan, Strategy, TimeModel, TransportKind,
};
use flextp::contention::ScenarioSpec;
use flextp::metrics::RunReport;
use flextp::runtime::manifest::Degrees;
use flextp::train::trainer::Trainer;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("flextp_fg_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// vit-tiny over 4 workers with the finegrained-preset contention
/// shape: r3 is a heavy straggler for the whole run (excluded from the
/// 0..2 block groups), and r1 — a member of both groups — bursts
/// mid-run so pruning/migration engage *inside* the sub-groups and the
/// parity below covers a non-trivial plan.
fn fg_cfg(threads: usize, transport: TransportKind) -> RunCfg {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.train.threads = threads;
    cfg.train.epochs = 2;
    cfg.train.iters_per_epoch = 6;
    cfg.train.eval_iters = 2;
    cfg.train.momentum = 0.9;
    cfg.train.time_model = TimeModel::Modeled;
    cfg.train.transport = transport;
    cfg.train.rank_exe = Some(env!("CARGO_BIN_EXE_flextp").into());
    cfg.balancer.strategy = Strategy::Semi;
    cfg.balancer.replan = ReplanMode::Online;
    cfg.balancer.forced_lambda = Some(1);
    cfg.degree_overrides =
        DegreeOverrides { attn: Some(2), mlp: Some(2), ..DegreeOverrides::default() };
    cfg.stragglers = StragglerPlan::Scenario(
        ScenarioSpec::parse("burst:r3@x24:iters0-,burst:r1@x3:iters4-9,chimax:32")
            .expect("scenario"),
    );
    cfg
}

type Observables = (RunReport, u64, u64, Degrees);

fn run(cfg: RunCfg) -> Observables {
    let mut t = Trainer::new(cfg).expect("trainer");
    let r = t.run().expect("run");
    (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops, t.model().degrees)
}

fn assert_bitwise(a: &Observables, b: &Observables, what: &str) {
    assert!(
        a.0.loss_curve.iter().all(|l| l.is_finite()),
        "{what}: diverged: {:?}",
        a.0.loss_curve
    );
    assert_eq!(a.0.loss_curve, b.0.loss_curve, "{what}: losses must be bitwise identical");
    assert!(a.0.sim_equal(&b.0), "{what}: per-epoch sim metrics must be bitwise identical");
    assert_eq!(a.1, b.1, "{what}: CommStats::total_bytes must match");
    assert_eq!(a.2, b.2, "{what}: all-reduce op counts must match");
    assert_eq!(a.3, b.3, "{what}: degree vectors must match");
}

#[test]
fn mixed_degrees_bitwise_identical_at_1_and_4_threads_on_both_transports() {
    let mut per_thread = Vec::new();
    for threads in [1usize, 4] {
        let inproc = run(fg_cfg(threads, TransportKind::InProc));
        assert_eq!(
            inproc.3,
            Degrees { embed: 4, attn: 2, mlp: 2, head: 4 },
            "the overrides must have reached the resolved manifest"
        );
        let tcp = run(fg_cfg(threads, TransportKind::Tcp));
        assert_bitwise(&inproc, &tcp, &format!("inproc vs tcp, threads={threads}"));
        per_thread.push(inproc);
    }
    assert_bitwise(&per_thread[0], &per_thread[1], "mixed degrees, threads 1 vs 4");
    // sanity: the member-rank burst engaged the balancer inside the
    // sub-groups, so the parity covered a non-trivial plan
    assert!(
        per_thread[0].0.epochs.iter().map(|e| e.pruned_cols + e.migrated_cols).sum::<u64>() > 0,
        "no balancing engaged — the mixed-degree comparison would be vacuous"
    );
}

/// `--degrees auto` under the heavy-tail row must derive exactly the
/// explicit a2m2 vector (rank 3's χ24 makes every degree including it
/// lose on the prefix max) and therefore reproduce the explicit run's
/// bits.
#[test]
fn auto_degrees_match_the_explicit_vector_bitwise() {
    let explicit = run(fg_cfg(1, TransportKind::InProc));
    let auto = {
        let mut cfg = fg_cfg(1, TransportKind::InProc);
        cfg.degree_overrides = DegreeOverrides::default();
        cfg.degrees_auto = true;
        run(cfg)
    };
    assert_eq!(auto.3, Degrees { embed: 4, attn: 2, mlp: 2, head: 4 });
    assert_bitwise(&explicit, &auto, "explicit a2m2 vs --degrees auto");
}

/// Kill a mixed-degree run mid-epoch, resume from the snapshot with the
/// same config: the degree vector must round-trip through the
/// checkpoint (meta.model.deg) and the resumed run must be bitwise
/// indistinguishable from an uninterrupted one.
#[test]
fn mixed_degree_kill_resume_round_trips_the_degree_vector() {
    let full = run(fg_cfg(1, TransportKind::InProc));
    let dir = tmp_dir("resume");
    let path = dir.join(flextp::checkpoint::ckpt_filename(5));
    let resumed = {
        let cfg = fg_cfg(1, TransportKind::InProc);
        {
            let mut t = Trainer::new(cfg.clone()).expect("trainer");
            t.run_to(Some(5)).expect("run to kill point");
            t.save_checkpoint(&path).expect("save checkpoint");
            // t dropped here — the "kill"
        }
        let mut t = Trainer::resume_from(cfg, &path).expect("resume");
        assert_eq!(
            t.model().degrees,
            Degrees { embed: 4, attn: 2, mlp: 2, head: 4 },
            "resume must restore the saved degree vector"
        );
        let r = t.run().expect("resumed run");
        (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops, t.model().degrees)
    };
    assert_bitwise(&full, &resumed, "mixed degrees, uninterrupted vs kill/resume");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Resuming a mixed-degree checkpoint *without* the overrides re-shards
/// it back to the uniform vector through the elastic path (same worker
/// count, different degrees): the run must come up at uniform degrees
/// and keep training to finite losses.
#[test]
fn elastic_resume_reshards_mixed_checkpoint_to_uniform() {
    let dir = tmp_dir("to_uniform");
    let path = dir.join(flextp::checkpoint::ckpt_filename(5));
    {
        let mut t = Trainer::new(fg_cfg(1, TransportKind::InProc)).expect("trainer");
        t.run_to(Some(5)).expect("run to snapshot point");
        t.save_checkpoint(&path).expect("save checkpoint");
    }
    let mut cfg = fg_cfg(1, TransportKind::InProc);
    cfg.degree_overrides = DegreeOverrides::default();
    let mut t = Trainer::resume_from(cfg, &path).expect("elastic resume to uniform degrees");
    assert_eq!(t.model().degrees, Degrees::uniform(4), "degrees re-shard to uniform");
    let r = t.run().expect("resumed run");
    assert!(r.loss_curve.iter().all(|l| l.is_finite()), "diverged: {:?}", r.loss_curve);
    let _ = std::fs::remove_dir_all(&dir);
}
