//! Snapshot-robustness property suite: corrupt checkpoints are rejected
//! with typed errors — never a panic, never a partial load — and the
//! atomic writer leaves no torn files behind on simulated failures.
//!
//! The format-level unit tests in `checkpoint::format` cover synthetic
//! snapshots; this file drives the same properties through a **real**
//! trainer checkpoint (tens of entries, a large blob) and the real
//! resume path.

use flextp::checkpoint::{ckpt_filename, latest_in_dir, CkptError, Snapshot};
use flextp::config::{RunCfg, TimeModel};
use flextp::train::trainer::Trainer;
use flextp::util::rng::Rng;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("flextp_robust_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn small_cfg() -> RunCfg {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.train.threads = 1;
    cfg.train.epochs = 1;
    cfg.train.iters_per_epoch = 2;
    cfg.train.eval_iters = 1;
    cfg.train.time_model = TimeModel::Modeled;
    cfg
}

/// One real checkpoint's bytes (written by an actual trainer).
fn real_ckpt_bytes(dir: &std::path::Path) -> Vec<u8> {
    let path = dir.join(ckpt_filename(1));
    let mut t = Trainer::new(small_cfg()).expect("trainer");
    t.run_to(Some(1)).expect("one iteration");
    t.save_checkpoint(&path).expect("save");
    std::fs::read(&path).expect("read back")
}

#[test]
fn prop_truncations_of_a_real_checkpoint_never_panic_or_load() {
    let dir = tmp_dir("trunc");
    let bytes = real_ckpt_bytes(&dir);
    assert!(bytes.len() > 1000, "checkpoint suspiciously small");
    // every prefix length across the structural boundaries, plus a
    // seeded random sample through the blob
    let mut cuts: Vec<usize> = (0..64.min(bytes.len())).collect();
    let mut rng = Rng::new(11);
    for _ in 0..200 {
        cuts.push(rng.below(bytes.len()));
    }
    for len in cuts {
        let e = Snapshot::from_bytes(&bytes[..len])
            .err()
            .unwrap_or_else(|| panic!("truncation to {len} bytes loaded successfully"));
        assert!(
            matches!(
                e,
                CkptError::Truncated { .. }
                    | CkptError::ChecksumMismatch { .. }
                    | CkptError::BadMagic
                    | CkptError::Malformed(_)
            ),
            "len={len}: unexpected error {e:?}"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_bit_flips_anywhere_are_rejected_with_typed_errors() {
    let dir = tmp_dir("flip");
    let bytes = real_ckpt_bytes(&dir);
    let mut rng = Rng::new(23);
    for trial in 0..300 {
        let pos = rng.below(bytes.len());
        let bit = 1u8 << rng.below(8);
        let mut c = bytes.clone();
        c[pos] ^= bit;
        match Snapshot::from_bytes(&c) {
            // magic/version bytes have their own typed rejections; every
            // byte after the checksum field is digest-protected
            Err(
                CkptError::BadMagic
                | CkptError::UnsupportedVersion { .. }
                | CkptError::ChecksumMismatch { .. }
                | CkptError::Malformed(_),
            ) => {}
            Err(e) => panic!("trial {trial} pos {pos}: unexpected error {e:?}"),
            Ok(_) => {
                // the only undetectable flips are inside the stored
                // checksum-adjacent fields colliding — FNV makes that a
                // ~2^-64 event; a clean load here means the flip landed
                // in the checksum field AND forged the digest
                panic!("trial {trial} pos {pos} bit {bit:#x}: corrupt checkpoint loaded");
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_wrong_version_and_foreign_files_are_typed_errors() {
    let dir = tmp_dir("version");
    let mut bytes = real_ckpt_bytes(&dir);
    bytes[8] = 0xFE; // far-future format version
    assert!(matches!(
        Snapshot::from_bytes(&bytes),
        Err(CkptError::UnsupportedVersion { found: 0xFE, .. })
    ));
    // arbitrary files are BadMagic/Truncated, never a panic
    assert!(matches!(Snapshot::from_bytes(b""), Err(CkptError::Truncated { .. })));
    assert!(matches!(
        Snapshot::from_bytes(b"{\"not\": \"a checkpoint\"}"),
        Err(CkptError::BadMagic)
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_checkpoint_never_partially_loads_into_a_trainer() {
    let dir = tmp_dir("partial");
    let path = dir.join(ckpt_filename(1));
    let bytes = real_ckpt_bytes(&dir);
    // flip a byte deep in the blob and write it back
    let mut c = bytes.clone();
    let pos = bytes.len() - 100;
    c[pos] ^= 0x01;
    std::fs::write(&path, &c).unwrap();
    let err = Trainer::resume_from(small_cfg(), &path).unwrap_err().to_string();
    assert!(err.contains("checksum") || err.contains("corrupt"), "got: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn atomic_write_survives_simulated_failures() {
    let dir = tmp_dir("atomic");
    let path = dir.join(ckpt_filename(5));
    let mut t = Trainer::new(small_cfg()).expect("trainer");
    t.run_to(Some(1)).expect("one iteration");
    t.save_checkpoint(&path).expect("save");
    let good = std::fs::read(&path).unwrap();

    // simulated crash mid-save: a half-written .tmp next to the real file
    let torn = dir.join(format!("{}.tmp", ckpt_filename(9)));
    std::fs::write(&torn, &good[..good.len() / 2]).unwrap();
    // discovery ignores the orphan and returns the complete snapshot
    let latest = latest_in_dir(&dir).expect("complete snapshot found");
    assert!(latest.ends_with(ckpt_filename(5)), "picked {latest:?}");
    assert!(Snapshot::load(&latest).is_ok());
    // the torn bytes themselves are typed-rejected
    assert!(Snapshot::load(&torn).is_err());

    // overwriting an existing checkpoint stays atomic: the final file is
    // always a complete parse
    t.save_checkpoint(&path).expect("overwrite");
    assert!(Snapshot::load(&path).is_ok());
    // and no .tmp residue remains from successful saves
    let residue: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .flatten()
        .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
        .filter(|e| e.path() != torn)
        .collect();
    assert!(residue.is_empty(), "successful saves left tmp files: {residue:?}");
    let _ = std::fs::remove_dir_all(&dir);
}
