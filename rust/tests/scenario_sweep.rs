//! End-to-end checks for the scenario sweep harness and the online
//! controller's acceptance criterion (ISSUE 4): under a bursty trace, a
//! SEMI run with `--replan online` must beat static per-epoch
//! replanning on simulated RT without giving up final accuracy.
//!
//! Runs use `--time-model modeled`, so every number below is
//! deterministic — the inequalities are exact properties of the closed
//! simulation, not statistical luck.

use flextp::bench::sweep::{run_sweep, CellSpec, SweepSpec};
use flextp::config::{ReplanMode, Strategy, TimeModel, TransportKind};
use flextp::contention::ScenarioSpec;
use flextp::util::json::Json;

/// A small bursty duel: a χ6 tenant arrives mid-epoch (iteration 3 of
/// 8) and stays — the static per-epoch plan stalls on it for the rest
/// of epoch 0, the online controller replans within a couple of
/// iterations.
fn bursty_duel() -> SweepSpec {
    let mut s = SweepSpec::preset("smoke").expect("smoke preset");
    s.name = "bursty-duel".into();
    s.epochs = 2;
    s.iters = 8;
    s.scenarios = vec![(
        "step6".into(),
        ScenarioSpec::parse("step:r1@x6:iters3-").expect("scenario"),
    )];
    s.cells = vec![
        CellSpec::new(Strategy::Semi, ReplanMode::Online),
        CellSpec::new(Strategy::Semi, ReplanMode::Epoch),
    ];
    s
}

#[test]
fn online_controller_beats_static_epoch_replanning_on_bursty_trace() {
    let spec = bursty_duel();
    assert_eq!(spec.time_model, TimeModel::Modeled);
    let report = run_sweep(&spec).expect("sweep");
    assert_eq!(report.cells.len(), 2);
    let on = report
        .cells
        .iter()
        .find(|c| c.replan == "online")
        .expect("online cell");
    let ep = report
        .cells
        .iter()
        .find(|c| c.replan == "epoch")
        .expect("epoch cell");

    // RT: the online controller must strictly win — the epoch-static
    // plan stalls on the χ6 tenant for most of epoch 0 while the drift
    // detector replans within ~2 iterations.
    assert!(
        on.rt < ep.rt,
        "online RT {:.4}s must beat epoch-static RT {:.4}s",
        on.rt,
        ep.rt
    );

    // ACC: no worse than static replanning, up to eval noise on the
    // tiny synthetic run (both adapt to the same steady state; only the
    // first epoch's few iterations differ).
    assert!(
        on.final_acc >= ep.final_acc - 0.05,
        "online ACC {:.3} regressed vs epoch ACC {:.3}",
        on.final_acc,
        ep.final_acc
    );

    // the controller fired mid-epoch (boundary plans alone would be 2)
    assert!(
        on.replans > spec.epochs as u64,
        "expected mid-epoch replans, got {}",
        on.replans
    );
    // the epoch-static baseline planned exactly once per epoch
    assert_eq!(ep.replans, spec.epochs as u64);

    // χ trace accounting made it into the cells
    assert!(on.chi_max >= 6.0 - 1e-9, "chi_max {:.1}", on.chi_max);
    assert!(on.chi_mean > 1.0);

    // and the comparisons table carries the speedup
    let cmp = report.comparisons();
    assert_eq!(cmp.len(), 1);
    assert!(cmp[0].3 > 1.0, "online_speedup {:.3} must exceed 1", cmp[0].3);
}

#[test]
fn sweep_runs_are_deterministic_under_modeled_time() {
    let mut spec = bursty_duel();
    spec.cells.truncate(1); // semi@online is the interesting cell
    let a = run_sweep(&spec).expect("sweep a");
    let b = run_sweep(&spec).expect("sweep b");
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        assert_eq!(ca.rt, cb.rt, "{}@{}", ca.strategy, ca.replan);
        assert_eq!(ca.final_acc, cb.final_acc);
        assert_eq!(ca.comm_bytes, cb.comm_bytes);
        assert_eq!(ca.replans, cb.replans);
    }
}

#[test]
fn preempted_cell_reproduces_uninterrupted_cell_bitwise() {
    // A `preempt:iterG` scenario event makes the sweep harness kill the
    // trainer mid-epoch, checkpoint, and resume — under the modeled
    // clock the cell's whole metric row must be bitwise identical to
    // the never-interrupted run of the same trace (ISSUE 5 acceptance).
    let mut spec = bursty_duel();
    spec.name = "preempt-parity".into();
    let killed = {
        let mut sc = spec.scenarios[0].1.clone();
        sc.preempt = Some(5); // mid epoch 0 (8 iters/epoch)
        sc
    };
    spec.scenarios = vec![
        ("plain".into(), spec.scenarios[0].1.clone()),
        ("killed".into(), killed),
    ];
    spec.cells = vec![CellSpec::new(Strategy::Semi, ReplanMode::Online)];
    let report = run_sweep(&spec).expect("sweep with preemption");
    let plain = report.cells.iter().find(|c| c.scenario == "plain").unwrap();
    let killed = report.cells.iter().find(|c| c.scenario == "killed").unwrap();
    assert_eq!(plain.rt, killed.rt, "RT must survive kill/resume bitwise");
    assert_eq!(plain.final_acc, killed.final_acc);
    assert_eq!(plain.best_acc, killed.best_acc);
    assert_eq!(plain.comm_bytes, killed.comm_bytes);
    assert_eq!(plain.replans, killed.replans);
    assert_eq!(plain.chi_mean, killed.chi_mean);
    assert_eq!(plain.chi_max, killed.chi_max);
}

/// A `@tcp` transport tag composes with the elasticity tags in the same
/// cell grammar — no duplicated matrix code — and a multi-process cell
/// row is bitwise identical to its in-process twin (DESIGN.md §15).
#[test]
fn tcp_sweep_cell_composes_and_matches_inproc_row() {
    let mut spec = bursty_duel();
    spec.name = "transport-duel".into();
    spec.epochs = 1;
    spec.iters = 5;
    spec.rank_exe = Some(env!("CARGO_BIN_EXE_flextp").into());
    spec.cells = vec![
        CellSpec::new(Strategy::Semi, ReplanMode::Online),
        CellSpec::new(Strategy::Semi, ReplanMode::Online).with_transport(TransportKind::Tcp),
    ];
    let report = run_sweep(&spec).expect("sweep across transports");
    assert_eq!(report.cells.len(), 2);
    let inproc = report.cells.iter().find(|c| c.cell == "live").expect("inproc row");
    let tcp = report.cells.iter().find(|c| c.cell == "live+tcp").expect("tcp row");
    assert_eq!(inproc.rt, tcp.rt, "modeled RT must survive the wire bitwise");
    assert_eq!(inproc.final_acc, tcp.final_acc);
    assert_eq!(inproc.best_acc, tcp.best_acc);
    assert_eq!(inproc.comm_bytes, tcp.comm_bytes);
    assert_eq!(inproc.replans, tcp.replans);
    assert_eq!(inproc.chi_mean, tcp.chi_mean);
    assert_eq!(inproc.chi_max, tcp.chi_max);
}

#[test]
fn sweep_report_writes_parseable_bench_scenarios_json() {
    // pipeline check on a minimal 1×1 matrix (calm scenario, quick)
    let mut spec = SweepSpec::preset("smoke").expect("smoke");
    spec.epochs = 1;
    spec.iters = 3;
    spec.eval_iters = 1;
    spec.scenarios.truncate(1); // calm only
    spec.cells = vec![CellSpec::new(Strategy::Semi, ReplanMode::Online)];
    let report = run_sweep(&spec).expect("sweep");

    let dir = std::env::temp_dir().join("flextp_sweep_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_scenarios.json");
    report.save(&path).expect("save");
    let text = std::fs::read_to_string(&path).unwrap();
    let j = Json::parse(&text).expect("valid JSON");
    let cells = j.get("cells").unwrap().arr().unwrap();
    assert_eq!(cells.len(), 1);
    let c = &cells[0];
    assert_eq!(c.get("scenario").unwrap().str().unwrap(), "calm");
    assert_eq!(c.get("strategy").unwrap().str().unwrap(), "SEMI");
    assert_eq!(c.get("replan").unwrap().str().unwrap(), "online");
    assert!(c.get("rt").unwrap().num().unwrap() > 0.0);
    assert!(c.get("replans").unwrap().num().unwrap() >= 1.0);
    // calm trace: χ stays at 1
    assert_eq!(c.get("chi_max").unwrap().num().unwrap(), 1.0);
    // sweeps trace by default: each cell embeds its phase-time totals
    let p = c.get("phases").unwrap();
    assert!(p.get("compute_s").unwrap().num().unwrap() > 0.0);
    assert!(p.get("spans").unwrap().num().unwrap() > 0.0);
    // calm ⇒ no χ excess, so no straggler to attribute
    assert!(matches!(p.get("straggler").unwrap(), Json::Null));
    // render must not panic and must carry the table header
    assert!(report.render().contains("scenario sweep"));
}
