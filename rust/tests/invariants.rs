//! Property-style invariant sweeps (hand-rolled — proptest is unavailable
//! offline): randomized inputs over many seeds for the coordinator's core
//! invariants (DESIGN.md §6), plus integration runs on the native backend
//! exercising every strategy end-to-end (no artifacts required).

use flextp::cluster::{mig_range, renumber, Clocks};
use flextp::collectives::{cost::CostModel, Comm};
use flextp::config::{Imputation, RunCfg, StragglerPlan, Strategy};
use flextp::resizing::lineage::Lineage;
use flextp::semi::{eq2_beta, CostFns};
use flextp::tensor::Tensor;
use flextp::util::rng::Rng;

const CASES: usize = 60;

#[test]
fn prop_lineage_roundtrip() {
    // expand(compact(g)) == g on kept rows; zeros on pruned rows.
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let n = 4 + rng.below(60);
        let c = 1 + rng.below(12);
        let keep = 1 + rng.below(n);
        let kept = rng.choose_k(n, keep);
        let lin = Lineage::new(n, &kept);
        assert_eq!(lin.kept.len() + lin.pruned.len(), n);
        let g = Tensor::normal(&[n, c], 1.0, &mut rng);
        let compact = g.gather_rows(&lin.kept);
        let mut full = Tensor::zeros(&[n, c]);
        full.scatter_rows_assign(&lin.kept, &compact);
        for (j, &i) in lin.kept.iter().enumerate() {
            let i = i as usize;
            assert_eq!(&full.data[i * c..(i + 1) * c], &compact.data[j * c..(j + 1) * c]);
        }
        for &i in &lin.pruned {
            let i = i as usize;
            assert!(full.data[i * c..(i + 1) * c].iter().all(|&v| v == 0.0));
        }
    }
}

#[test]
fn prop_renumbering_bijective_and_ranges_tile() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x11);
        let e = 2 + rng.below(14);
        let rk = rng.below(e);
        let l = rng.below(512);
        let mut seen = vec![false; e];
        let mut covered = vec![false; l];
        for ri in (0..e).filter(|&r| r != rk) {
            let rp = renumber(ri, rk, e);
            assert!((1..e).contains(&rp));
            assert!(!seen[rp]);
            seen[rp] = true;
            let (s, t) = mig_range(ri, rk, e, l);
            for x in s..t {
                assert!(!covered[x], "overlap");
                covered[x] = true;
            }
        }
        assert!(covered.iter().all(|&b| b), "ranges must tile L_mig");
    }
}

#[test]
fn prop_renumber_roundtrip_and_range_lengths() {
    // The virtual renumbering r' = (r_i + e − r_k) mod e must invert as
    // r_i = (r' + r_k) mod e (round-trip), and each normal task's
    // migrated-column range must have one of the two balanced lengths
    // ⌊L/(e−1)⌋ / ⌈L/(e−1)⌉ with the longer ranges on the lowest new
    // ranks — across randomized (r_i, r_k, e, l_mig).
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x77);
        let e = 2 + rng.below(14);
        let rk = rng.below(e);
        let l = rng.below(512);
        let n = e - 1;
        let mut prev_len = usize::MAX;
        for rp in 1..e {
            // round-trip through the inverse mapping
            let ri = (rp + rk) % e;
            assert_ne!(ri, rk);
            assert_eq!(renumber(ri, rk, e), rp);
            let (s, t) = mig_range(ri, rk, e, l);
            assert!(s <= t && t <= l, "range [{s},{t}) escapes L={l}");
            let len = t - s;
            assert!(
                len == l / n || len == l / n + 1,
                "unbalanced range: len={len} L={l} n={n}"
            );
            // remainder columns go to the lowest new ranks first
            assert!(len <= prev_len, "longer range after shorter one");
            prev_len = len;
        }
    }
}

#[test]
fn prop_eq2_beta_bounded_and_monotone_in_l() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x22);
        let c = CostFns {
            omega1_s: rng.uniform() as f64 * 1e-3,
            omega2_per_col: rng.uniform() as f64 * 1e-4,
            phi1_base_s: rng.uniform() as f64 * 1e-3,
            phi1_per_col: rng.uniform() as f64 * 1e-4,
            phi2_per_col: rng.uniform() as f64 * 1e-4,
        };
        let e = 2 + rng.below(7);
        for l in [8.0, 64.0, 256.0] {
            let b = eq2_beta(l, e, &c);
            assert!((0.0..=1.0).contains(&b), "β={b}");
            // balance residual at the returned β is ~0 for interior points
            if b > 1e-6 && b < 1.0 - 1e-6 {
                let mig = l * b;
                let res = l * (1.0 - b);
                let lhs = c.omega1_s + c.omega2(res);
                let rhs = c.phi1(mig) + c.phi2(mig / (e - 1) as f64);
                assert!(
                    (lhs - rhs).abs() <= 1e-6 * lhs.max(rhs).max(1e-12),
                    "balance violated: {lhs} vs {rhs}"
                );
            }
        }
    }
}

#[test]
fn prop_tree_collectives_dominate_flat_for_large_groups() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x33);
        let e = 4 + rng.below(12);
        let bytes = 1024 * (1 + rng.below(4096));
        let cost = CostModel::default();
        let peers: Vec<usize> = (1..e).collect();
        let (mut c1, mut k1) = (Comm::new(cost), Clocks::new(e));
        c1.broadcast(&mut k1, 0, &peers, bytes);
        let (mut c2, mut k2) = (Comm::new(cost), Clocks::new(e));
        c2.scatter(&mut k2, 0, &peers, bytes);
        assert!(
            k1.now(0) <= k2.now(0) + 1e-12,
            "tree broadcast must not lose to flat scatter (e={e})"
        );
    }
}

#[test]
fn prop_allreduce_is_exact_sum() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x44);
        let e = 2 + rng.below(7);
        let n = 1 + rng.below(100);
        let bufs: Vec<Tensor> = (0..e).map(|_| Tensor::normal(&[n], 1.0, &mut rng)).collect();
        let mut want = Tensor::zeros(&[n]);
        for b in &bufs {
            want.add_assign(b);
        }
        let mut got = bufs.clone();
        let mut comm = Comm::new(CostModel::default());
        let mut clocks = Clocks::new(e);
        comm.all_reduce(&mut clocks, "test", &mut got).unwrap();
        for b in &got {
            assert!(b.allclose(&want, 1e-5));
        }
    }
}

#[test]
fn prop_barrier_monotone() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x55);
        let e = 2 + rng.below(7);
        let mut clocks = Clocks::new(e);
        let mut max = 0.0f64;
        for r in 0..e {
            let dt = rng.uniform() as f64;
            clocks.advance(r, dt);
            max = max.max(dt);
        }
        let b = clocks.barrier();
        assert!((b - max).abs() < 1e-12);
        for r in 0..e {
            assert_eq!(clocks.now(r), b);
        }
    }
}

// ---------------------------------------------------------------------
// Integration: every strategy trains end-to-end on the native backend
// (manifest synthesized — no artifacts required).
// ---------------------------------------------------------------------

fn short_cfg(strategy: Strategy) -> RunCfg {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.balancer.strategy = strategy;
    cfg.train.epochs = 2;
    cfg.train.iters_per_epoch = 2;
    cfg.train.eval_iters = 1;
    cfg.stragglers = StragglerPlan::Fixed(vec![3.0]);
    cfg
}

#[test]
fn integration_all_strategies_run_and_stay_finite() {
    for strategy in [
        Strategy::Baseline,
        Strategy::ZeroRd,
        Strategy::ZeroPri,
        Strategy::ZeroPriDiffE,
        Strategy::ZeroPriDiffR,
        Strategy::Mig,
        Strategy::Semi,
    ] {
        let mut t =
            flextp::train::trainer::Trainer::new(short_cfg(strategy)).expect("trainer");
        let r = t.run().unwrap_or_else(|e| panic!("{} failed: {e:?}", strategy.name()));
        assert!(r.rt() > 0.0, "{}: no time charged", strategy.name());
        assert!(
            r.final_eval_loss().is_finite(),
            "{}: loss diverged", strategy.name()
        );
        assert!(!r.loss_curve.is_empty());
    }
}

#[test]
fn integration_balancers_engage_under_skew() {
    // ZERO prunes, MIG migrates, SEMI does at least one of the two.
    let mut t = flextp::train::trainer::Trainer::new(short_cfg(Strategy::ZeroPri)).unwrap();
    let r = t.run().unwrap();
    assert!(
        r.epochs.iter().map(|e| e.pruned_cols).sum::<u64>() > 0,
        "ZERO-Pri never pruned under χ=3"
    );
    let mut t = flextp::train::trainer::Trainer::new(short_cfg(Strategy::Mig)).unwrap();
    let r = t.run().unwrap();
    assert!(
        r.epochs.iter().map(|e| e.migrated_cols).sum::<u64>() > 0,
        "MIG never migrated under χ=3"
    );
    let mut t = flextp::train::trainer::Trainer::new(short_cfg(Strategy::Semi)).unwrap();
    let r = t.run().unwrap();
    let acted: u64 = r
        .epochs
        .iter()
        .map(|e| e.pruned_cols + e.migrated_cols)
        .sum();
    assert!(acted > 0, "SEMI never balanced under χ=3");
}

#[test]
fn integration_imputation_policies_all_train() {
    for imp in [Imputation::Zero, Imputation::Average, Imputation::Same] {
        let mut cfg = short_cfg(Strategy::ZeroPri);
        cfg.balancer.imputation = imp;
        cfg.balancer.gamma_override = Some(0.5);
        let mut t = flextp::train::trainer::Trainer::new(cfg).unwrap();
        let r = t.run().expect("run");
        assert!(r.final_eval_loss().is_finite(), "{imp:?} diverged");
    }
}

#[test]
fn integration_migration_is_numerically_exact() {
    // A pure-MIG run must produce the same loss trajectory as Baseline on
    // the same batch (migration never changes arithmetic, paper §IV-A).
    let fixed_batch = |strategy: Strategy| {
        let mut cfg = short_cfg(strategy);
        cfg.train.epochs = 1;
        cfg.train.iters_per_epoch = 3;
        let mut t = flextp::train::trainer::Trainer::new(cfg).unwrap();
        let b = t.data.train_batch(0);
        t.forced_batch = Some(b);
        let mut losses = Vec::new();
        for _ in 0..3 {
            losses.push(t.train_iter().unwrap());
        }
        losses
    };
    let base = fixed_batch(Strategy::Baseline);
    let mig = fixed_batch(Strategy::Mig);
    for (i, (b, m)) in base.iter().zip(&mig).enumerate() {
        let rel = (b - m).abs() / b.abs().max(1e-6);
        assert!(rel < 1e-4, "step {i}: MIG loss {m} != baseline {b} (rel {rel})");
    }
}
