//! Memory-budget suite (ISSUE 8, DESIGN.md §16).
//!
//! Every simulated rank carries a byte-accounted budget; OOM is a
//! first-class, injectable, *recoverable* fault.  Pinned here:
//!
//! * the ledger never goes negative (saturating arithmetic under a
//!   seeded adversarial op stream);
//! * a same-seed squeeze trace is bitwise identical at `--threads` 1
//!   and 4 — memory charges are modeled, never arena telemetry;
//! * hard-OOM recovery is bitwise equal to the PR 5/6 oracle: kill at
//!   the fault iteration, checkpoint, `--resume --e E'`;
//! * an iteration that cannot fit even with activation checkpointing is
//!   a typed `MemError::Infeasible`, never a panic (and statics that
//!   cannot fit are a typed `MemError::OutOfMemory`);
//! * activation checkpointing is bitwise loss-invariant — it charges
//!   SimClock time, never touches numerics;
//! * the `mem` sweep preset acceptance row: zero panics across
//!   strategies, `semi@online` completes within capacity, and typed
//!   faults surface as explicit `"error"` rows.

use flextp::bench::sweep::{run_sweep, SweepSpec};
use flextp::config::{ReplanMode, RunCfg, StragglerPlan, Strategy, TimeModel};
use flextp::contention::ScenarioSpec;
use flextp::memory::{FootprintModel, MemError, MemLedger};
use flextp::metrics::RunReport;
use flextp::train::trainer::Trainer;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("flextp_mem_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// vit-tiny (hs=128, heads=4, e=4), SEMI + online controller, modeled
/// clock, with `scenario` scripted on top.
fn mem_cfg(threads: usize, scenario: &str) -> RunCfg {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.train.threads = threads;
    cfg.train.epochs = 2;
    cfg.train.iters_per_epoch = 6;
    cfg.train.eval_iters = 2;
    cfg.train.momentum = 0.9;
    cfg.train.time_model = TimeModel::Modeled;
    cfg.balancer.strategy = Strategy::Semi;
    cfg.balancer.replan = ReplanMode::Online;
    cfg.balancer.forced_lambda = Some(1);
    cfg.stragglers =
        StragglerPlan::Scenario(ScenarioSpec::parse(scenario).expect("scenario"));
    cfg
}

type Observables = (RunReport, u64, u64, usize);

fn run_live(cfg: RunCfg) -> Observables {
    let mut t = Trainer::new(cfg).expect("trainer");
    let r = t.run().expect("live run");
    (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops, t.model().e)
}

fn assert_bitwise(a: &Observables, b: &Observables, what: &str) {
    assert!(a.0.loss_curve.iter().all(|l| l.is_finite()), "{what}: diverged");
    assert_eq!(a.0.loss_curve, b.0.loss_curve, "{what}: losses must be bitwise identical");
    assert!(a.0.sim_equal(&b.0), "{what}: per-epoch sim metrics (incl. mem) must match");
    assert_eq!(a.1, b.1, "{what}: CommStats::total_bytes must match");
    assert_eq!(a.2, b.2, "{what}: all-reduce op counts must match");
    assert_eq!(a.3, b.3, "{what}: final worker counts must match");
}

/// Saturating ledger arithmetic under a seeded adversarial op stream:
/// `used` never underflows, `headroom` never exceeds the effective cap,
/// and the high-water-mark is monotone within an iteration window.
#[test]
fn ledger_never_goes_negative_under_random_ops() {
    let mut ledger = MemLedger::new(4, 1 << 20, &[(2, 1 << 18)]);
    let mut lcg: u64 = 0xDEAD_BEEF_CAFE_F00D;
    let mut next = move || {
        // xorshift64* — deterministic, no external crates
        lcg ^= lcg << 13;
        lcg ^= lcg >> 7;
        lcg ^= lcg << 17;
        lcg
    };
    for step in 0..10_000 {
        let r = (next() % 4) as usize;
        let bytes = next() % (1 << 19);
        match next() % 4 {
            0 => ledger.charge(r, bytes),
            // over-release on purpose: must saturate at zero
            1 => ledger.release(r, bytes.saturating_mul(3)),
            2 => ledger.set_squeeze(r, (next() % 100) as f64 / 100.0),
            _ => ledger.begin_iter(),
        }
        for w in 0..4 {
            assert!(
                ledger.headroom(w) <= ledger.effective_cap(w),
                "step {step}: headroom exceeds the effective cap on rank {w}"
            );
            assert!(
                ledger.hwm(w) >= ledger.used(w) || ledger.hwm(w) == 0,
                "step {step}: hwm fell below live usage on rank {w}"
            );
        }
    }
    // full squeeze: capacity zero, headroom zero, no underflow anywhere
    ledger.set_squeeze(0, 1.0);
    assert_eq!(ledger.effective_cap(0), 0);
    assert_eq!(ledger.headroom(0), 0);
    ledger.release(0, u64::MAX);
    assert_eq!(ledger.used(0), 0);
}

/// A same-seed squeeze trace is bitwise identical at 1 and 4 threads:
/// ledger charges replay modeled footprints on the coordinator in rank
/// order, so thread timing can never leak into any memory observable.
#[test]
fn squeeze_trace_is_bitwise_identical_at_1_and_4_threads() {
    let scenario = "memsqueeze:r1@iter4:x0.5,burst:r1@x5:iters2-9,seed:9";
    let a = run_live(mem_cfg(1, scenario));
    let b = run_live(mem_cfg(4, scenario));
    assert_bitwise(&a, &b, "threads 1 vs 4 under memsqueeze");
    assert_eq!(a.0.loss_curve.len(), 12, "every scheduled iteration ran");
    assert!(a.0.mem_hwm_max() > 0, "the ledger recorded a high-water-mark");
    // the squeeze shows up as *tighter* minimum headroom than a calm run
    let calm = run_live(mem_cfg(1, "burst:r1@x5:iters2-9,seed:9"));
    assert!(
        a.0.mem_headroom_min() < calm.0.mem_headroom_min(),
        "squeeze headroom {} must undercut calm headroom {}",
        a.0.mem_headroom_min(),
        calm.0.mem_headroom_min(),
    );
}

/// Tentpole: a hard `oom:` fault evicts the rank through the churn path
/// and the live recovery is bitwise equal to kill/checkpoint/`--resume
/// --e E'` — at 1 and 4 threads.  vit-tiny at e=4 loses one worker →
/// 3 survivors divide neither hs=128 nor heads=4 → E'=2.
#[test]
fn hard_oom_recovery_matches_resume_oracle_at_1_and_4_threads() {
    let scenario = "oom:r1@iter4,burst:r2@x4:iters2-9,seed:9";
    let mut per_thread = Vec::new();
    for threads in [1usize, 4] {
        let cfg = mem_cfg(threads, scenario);
        let live = run_live(cfg.clone());
        assert_eq!(live.3, 2, "the OOM eviction must land on E'=2");

        // the oracle: run to the fault cut, checkpoint, kill, resume at E'
        let dir = tmp_dir(&format!("oom_oracle_t{threads}"));
        let p4 = dir.join(flextp::checkpoint::ckpt_filename(4));
        {
            let mut t = Trainer::new(cfg.clone()).expect("trainer");
            t.run_to(Some(4)).expect("to the fault point");
            assert_eq!(t.model().e, 4, "oom@4 fires before iteration 4, not earlier");
            t.save_checkpoint(&p4).expect("save @4");
            // drop = the kill
        }
        let mut shrunk = cfg;
        shrunk.e_override = Some(2);
        let mut t = Trainer::resume_from(shrunk, &p4).expect("elastic resume onto e=2");
        let r = t.run().expect("oracle run");
        let oracle =
            (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops, t.model().e);
        assert_bitwise(&live, &oracle, &format!("threads={threads} oom vs oracle"));
        let _ = std::fs::remove_dir_all(&dir);
        per_thread.push(live);
    }
    assert_bitwise(&per_thread[0], &per_thread[1], "threads 1 vs 4 under hard OOM");
}

/// Typed failure modes, never panics: statics that cannot fit are
/// `MemError::OutOfMemory` (when churn recovery is off), and an
/// iteration that cannot fit even with recompute is
/// `MemError::Infeasible` (regardless of churn).
#[test]
fn impossible_budgets_yield_typed_errors_not_panics() {
    let m = flextp::runtime::presets::synthesize("vit-tiny").expect("manifest").model;
    let fp = FootprintModel::new(&m);

    // statics don't fit and there is no churn recovery → hard OOM error
    let mut cfg = mem_cfg(1, "seed:9");
    cfg.train.churn = false;
    cfg.train.mem_cap = Some(fp.static_bytes() / 2);
    let err = Trainer::new(cfg).expect("trainer").run().expect_err("statics cannot fit");
    match err.downcast_ref::<MemError>() {
        Some(MemError::OutOfMemory { rank: 0, .. }) => {}
        other => panic!("expected OutOfMemory on rank 0, got: {other:?} ({err:#})"),
    }

    // statics fit, dynamics don't — not even with one live layer → the
    // plan is infeasible; eviction would not help, so churn stays on and
    // the error is still typed
    let mut cfg = mem_cfg(1, "seed:9");
    cfg.train.mem_cap = Some(fp.static_bytes() + fp.iter_bytes(&m, 0, true) / 2);
    let err = Trainer::new(cfg).expect("trainer").run().expect_err("dynamics cannot fit");
    match err.downcast_ref::<MemError>() {
        Some(MemError::Infeasible { .. }) => {}
        other => panic!("expected Infeasible, got: {other:?} ({err:#})"),
    }
}

/// Activation checkpointing trades SimClock time for memory and must
/// leave the numerics untouched: forcing `--mem-recompute` keeps the
/// loss curve bitwise identical while simulated RT grows and the
/// per-epoch recompute counter engages.  The plan is pinned to
/// BASELINE (stat-independent) so the time surcharge — which adaptive
/// strategies are *meant* to see and react to — cannot route the two
/// runs onto different plans.
#[test]
fn recompute_is_bitwise_loss_invariant_and_charges_time() {
    let scenario = "burst:r1@x5:iters2-9,seed:9";
    let pin = |threads| {
        let mut cfg = mem_cfg(threads, scenario);
        cfg.balancer.strategy = Strategy::Baseline;
        cfg.balancer.replan = ReplanMode::Iter;
        cfg
    };
    let plain = run_live(pin(1));
    let mut forced = pin(1);
    forced.train.mem_recompute = true;
    let forced = run_live(forced);
    assert_eq!(
        plain.0.loss_curve, forced.0.loss_curve,
        "recompute must not perturb a single loss bit"
    );
    assert!(forced.0.total_recompute_iters() > 0, "recompute never engaged");
    assert_eq!(plain.0.total_recompute_iters(), 0, "plain run must not recompute");
    for (i, (a, b)) in plain.0.epochs.iter().zip(&forced.0.epochs).enumerate() {
        assert!(
            b.rt_sim_s > a.rt_sim_s,
            "epoch {i}: recompute RT {:.6} must exceed plain RT {:.6}",
            b.rt_sim_s,
            a.rt_sim_s
        );
        assert!(
            b.mem_hwm_bytes < a.mem_hwm_bytes,
            "epoch {i}: recompute hwm {} must undercut plain hwm {}",
            b.mem_hwm_bytes,
            a.mem_hwm_bytes
        );
    }
}

/// The acceptance row: the `mem` sweep preset completes with zero
/// panics across all strategies; `semi@online` finishes the squeeze
/// scenario within capacity; the fixed-E cell turns the hard OOM into
/// an explicit `"error"` row; live cells recover from it.
#[test]
fn mem_sweep_preset_degrades_gracefully_and_reports_error_rows() {
    let spec = SweepSpec::preset("mem").expect("mem preset");
    let report = run_sweep(&spec).expect("the mem sweep must never panic or abort");
    assert_eq!(report.cells.len(), spec.scenarios.len() * spec.cells.len());

    for c in &report.cells {
        if c.scenario == "memsqueeze" {
            // every strategy rides out the squeeze: no faults, headroom
            // never exhausted, and the ledger saw real pressure
            assert!(c.error.is_none(), "{}@{}: unexpected fault {:?}", c.strategy, c.cell, c.error);
            assert!(c.mem_hwm_bytes > 0, "{}@{}: no high-water-mark", c.strategy, c.cell);
        }
    }
    let online = report
        .cells
        .iter()
        .find(|c| {
            c.scenario == "memsqueeze"
                && c.strategy == "SEMI"
                && c.replan == "online"
                && c.cell == "live"
        })
        .expect("semi@online memsqueeze cell");
    assert!(online.error.is_none(), "semi@online must complete within capacity");

    // the hard-OOM scenario: the fixed-E baseline cannot evict, so its
    // cell is an explicit typed error row; every live cell recovers
    let fixed = report
        .cells
        .iter()
        .find(|c| c.scenario == "hard-oom" && c.cell == "fixed")
        .expect("fixed cell");
    assert_eq!(fixed.error.as_deref(), Some("OutOfMemory"));
    for c in report.cells.iter().filter(|c| c.scenario == "hard-oom" && c.cell == "live") {
        assert!(
            c.error.is_none(),
            "{}@{}: live cells must recover from the OOM, got {:?}",
            c.strategy,
            c.replan,
            c.error
        );
        assert!(c.rt > 0.0, "{}@{}: recovered cell must report RT", c.strategy, c.replan);
    }
}
