//! Bitwise resume-determinism suite (DESIGN.md §13).
//!
//! The subsystem's core contract: a run that is killed after iteration k
//! and resumed from its checkpoint is **indistinguishable** from one
//! that was never interrupted — bitwise-identical losses, eval metrics,
//! and `CommStats`, at `--threads` 1 and 4 alike.  That only holds if
//! the snapshot really captures *everything* the math reads: model
//! shards, optimizer moments, data/trace cursors, monitor + controller
//! statistics, the cached balancing plan, the balancer's RNG stream and
//! priority state, SimClocks, comm counters, and the Same-imputation
//! gradient history.  Each test below kills a run at a different kind of
//! boundary to make a missing piece observable.

use flextp::config::{ReplanMode, RunCfg, StragglerPlan, Strategy, TimeModel};
use flextp::contention::ScenarioSpec;
use flextp::metrics::RunReport;
use flextp::train::trainer::Trainer;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("flextp_resume_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The full dynamic pipeline: SEMI + online controller + momentum under
/// a bursty/stochastic contention trace, deterministic modeled clock.
fn dynamic_cfg(threads: usize) -> RunCfg {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.train.threads = threads;
    cfg.train.epochs = 2;
    cfg.train.iters_per_epoch = 6;
    cfg.train.eval_iters = 2;
    cfg.train.momentum = 0.9;
    cfg.train.time_model = TimeModel::Modeled;
    cfg.balancer.strategy = Strategy::Semi;
    cfg.balancer.replan = ReplanMode::Online;
    cfg.balancer.forced_lambda = Some(1);
    cfg.stragglers = StragglerPlan::Scenario(
        ScenarioSpec::parse("burst:r1@x5:iters2-9,markov:r3@x2:p0.4-0.3,seed:9")
            .expect("scenario"),
    );
    cfg
}

/// (report, comm bytes, allreduce ops) of an uninterrupted run.
fn run_uninterrupted(cfg: RunCfg) -> (RunReport, u64, u64) {
    let mut t = Trainer::new(cfg).expect("trainer");
    let r = t.run().expect("run");
    (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops)
}

/// Kill after iteration `k`, checkpoint, drop everything, resume from
/// the snapshot, finish.  Returns the same observables.
fn run_killed_and_resumed(cfg: RunCfg, k: u64, tag: &str) -> (RunReport, u64, u64) {
    let dir = tmp_dir(tag);
    let path = dir.join(flextp::checkpoint::ckpt_filename(k));
    {
        let mut t = Trainer::new(cfg.clone()).expect("trainer");
        t.run_to(Some(k)).expect("run to kill point");
        assert_eq!(t.giter(), k, "stop_after must stop exactly at k");
        t.save_checkpoint(&path).expect("save checkpoint");
        // t dropped here — the "kill"
    }
    let mut t = Trainer::resume_from(cfg, &path).expect("resume");
    assert_eq!(t.giter(), k, "resume must restore the cursor");
    let r = t.run().expect("resumed run");
    let out = (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops);
    let _ = std::fs::remove_dir_all(&dir);
    out
}

fn assert_bitwise(a: &(RunReport, u64, u64), b: &(RunReport, u64, u64), what: &str) {
    assert!(
        a.0.loss_curve.iter().all(|l| l.is_finite()),
        "{what}: diverged: {:?}",
        a.0.loss_curve
    );
    assert_eq!(a.0.loss_curve, b.0.loss_curve, "{what}: losses must be bitwise identical");
    assert!(a.0.sim_equal(&b.0), "{what}: per-epoch sim metrics must be bitwise identical");
    assert_eq!(a.1, b.1, "{what}: CommStats::total_bytes must match");
    assert_eq!(a.2, b.2, "{what}: all-reduce op counts must match");
}

#[test]
fn mid_epoch_resume_is_bitwise_identical_at_1_and_4_threads() {
    // kill at iteration 4 — mid epoch 0, while the online controller's
    // EWMAs, the cached SEMI plan, and the momentum buffers are all hot
    let mut per_thread = Vec::new();
    for threads in [1usize, 4] {
        let full = run_uninterrupted(dynamic_cfg(threads));
        let resumed =
            run_killed_and_resumed(dynamic_cfg(threads), 4, &format!("mid_t{threads}"));
        assert_bitwise(&full, &resumed, &format!("threads={threads}"));
        per_thread.push(full);
    }
    // and the 1-vs-4-thread parity contract survives the kill/resume
    assert_bitwise(&per_thread[0], &per_thread[1], "threads 1 vs 4");
    // sanity: the scenario actually balanced something
    assert!(
        per_thread[0].0.epochs.iter().map(|e| e.pruned_cols + e.migrated_cols).sum::<u64>() > 0,
        "no balancing engaged — the test would not exercise plan serde"
    );
}

#[test]
fn epoch_boundary_resume_is_bitwise_identical() {
    // kill at iteration 6 — exactly the epoch boundary: the snapshot
    // must already contain epoch 0's eval/metrics and the balancer's
    // epoch_end statistics refresh
    let full = run_uninterrupted(dynamic_cfg(1));
    let resumed = run_killed_and_resumed(dynamic_cfg(1), 6, "boundary");
    assert_bitwise(&full, &resumed, "epoch boundary");
    assert_eq!(resumed.0.epochs.len(), 2);
}

#[test]
fn zero_rd_same_imputation_resume_is_bitwise_identical() {
    // ZERO-Rd draws keep-sets from the balancer's RNG stream and the
    // Same policy reads last iteration's gradients — both must survive
    // the checkpoint for the continuation to stay bitwise.
    let cfg = || {
        let mut cfg = RunCfg::new("vit-tiny");
        cfg.train.threads = 1;
        cfg.train.epochs = 2;
        cfg.train.iters_per_epoch = 5;
        cfg.train.eval_iters = 2;
        cfg.train.time_model = TimeModel::Modeled;
        cfg.balancer.strategy = Strategy::ZeroRd;
        cfg.balancer.imputation = flextp::config::Imputation::Same;
        cfg.balancer.replan = ReplanMode::Iter;
        cfg.stragglers = StragglerPlan::Fixed(vec![3.0, 1.0, 1.0, 1.0]);
        cfg
    };
    let full = run_uninterrupted(cfg());
    // kill at 7 — mid epoch 1, after an epoch_end tracker update
    let resumed = run_killed_and_resumed(cfg(), 7, "zerord");
    assert_bitwise(&full, &resumed, "zero-rd + same imputation");
    assert!(
        full.0.epochs.iter().map(|e| e.pruned_cols).sum::<u64>() > 0,
        "straggler never pruned — RNG stream serde untested"
    );
}

#[test]
fn resume_from_directory_picks_newest_snapshot() {
    let cfg = dynamic_cfg(1);
    let dir = tmp_dir("dirpick");
    {
        let mut ckpt_cfg = cfg.clone();
        ckpt_cfg.train.ckpt_dir = Some(dir.clone());
        ckpt_cfg.train.ckpt_every = 2;
        let mut t = Trainer::new(ckpt_cfg).expect("trainer");
        t.run_to(Some(5)).expect("run");
        // periodic snapshots landed at 2 and 4
        assert!(dir.join(flextp::checkpoint::ckpt_filename(2)).exists());
        assert!(dir.join(flextp::checkpoint::ckpt_filename(4)).exists());
    }
    let t = Trainer::resume_from(cfg, &dir).expect("resume from dir");
    assert_eq!(t.giter(), 4, "directory resume must pick the newest snapshot");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_rejects_mismatched_config_and_model() {
    let dir = tmp_dir("mismatch");
    let path = dir.join(flextp::checkpoint::ckpt_filename(2));
    {
        let mut t = Trainer::new(dynamic_cfg(1)).expect("trainer");
        t.run_to(Some(2)).expect("run");
        t.save_checkpoint(&path).expect("save");
    }
    // a different seed changes the math → typed Incompatible error
    let mut other = dynamic_cfg(1);
    other.train.seed = 43;
    let e = Trainer::resume_from(other, &path).unwrap_err().to_string();
    assert!(e.contains("configuration"), "got: {e}");
    // a different model is rejected before any state moves
    let e = Trainer::resume_from(RunCfg::new("vit-s"), &path).unwrap_err().to_string();
    assert!(e.contains("model") || e.contains("incompatible"), "got: {e}");
    // threads may differ (bitwise-invariant), epochs may extend
    let mut more = dynamic_cfg(4);
    more.train.epochs = 3;
    let t = Trainer::resume_from(more, &path).expect("threads/epochs changes are fine");
    assert_eq!(t.giter(), 2);
    let _ = std::fs::remove_dir_all(&dir);
}
