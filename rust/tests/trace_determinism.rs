//! Zero-observer-effect suite for `--trace` (DESIGN.md §17).
//!
//! The tentpole contract: tracing is a pure *read* of the simulation.
//! Pinned here:
//!
//! * trace-on vs trace-off **bitwise** parity of losses, per-epoch sim
//!   metrics, and `CommStats` — at `--threads` 1 and 4, on both the
//!   in-process and the TCP transport;
//! * trace-content identity across `--threads` 1 vs 4 (the without-wall
//!   JSONL export compares byte-for-byte — `wall_us` is the one
//!   non-deterministic field and it is excluded by construction);
//! * a churn + memory-fault scenario whose trace carries the
//!   transition/eviction events in exactly the order they fired;
//! * the acceptance bar: on the `bursty` preset the attribution report
//!   names the injected straggler and explains ≥ 80% of its excess
//!   SimClock time as χ-slowed compute;
//! * an unwritable trace sink is the typed `TraceError::Unwritable`,
//!   never a panic.

use flextp::config::{ReplanMode, RunCfg, StragglerPlan, Strategy, TimeModel, TransportKind};
use flextp::contention::ScenarioSpec;
use flextp::metrics::RunReport;
use flextp::trace::report::Attribution;
use flextp::trace::{export, Kind, TraceError};
use flextp::train::trainer::Trainer;

/// vit-tiny, SEMI@online, modeled clock, bursty tenant — the same
/// non-trivial plan the transport-parity suite exercises.
fn base_cfg(threads: usize, transport: TransportKind, trace: bool) -> RunCfg {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.train.threads = threads;
    cfg.train.epochs = 2;
    cfg.train.iters_per_epoch = 5;
    cfg.train.eval_iters = 2;
    cfg.train.momentum = 0.9;
    cfg.train.time_model = TimeModel::Modeled;
    cfg.train.transport = transport;
    cfg.train.rank_exe = Some(env!("CARGO_BIN_EXE_flextp").into());
    cfg.train.trace = trace;
    cfg.balancer.strategy = Strategy::Semi;
    cfg.balancer.replan = ReplanMode::Online;
    cfg.balancer.forced_lambda = Some(1);
    cfg.stragglers = StragglerPlan::Scenario(
        ScenarioSpec::parse("burst:r1@x5:iters2-7,markov:r3@x2:p0.4-0.3,seed:9")
            .expect("scenario"),
    );
    cfg
}

type Observables = (RunReport, u64, u64, usize);

fn run(cfg: RunCfg) -> (Trainer, Observables) {
    let mut t = Trainer::new(cfg).expect("trainer");
    let r = t.run().expect("run");
    let obs = (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops, t.model().e);
    (t, obs)
}

fn assert_bitwise(a: &Observables, b: &Observables, what: &str) {
    assert!(a.0.loss_curve.iter().all(|l| l.is_finite()), "{what}: diverged");
    assert_eq!(a.0.loss_curve, b.0.loss_curve, "{what}: losses must be bitwise identical");
    assert!(a.0.sim_equal(&b.0), "{what}: per-epoch sim metrics must be bitwise identical");
    assert_eq!(a.1, b.1, "{what}: CommStats::total_bytes must match");
    assert_eq!(a.2, b.2, "{what}: all-reduce op counts must match");
    assert_eq!(a.3, b.3, "{what}: final worker counts must match");
}

/// The without-wall JSONL export of a finished traced run.
fn jsonl_of(t: &Trainer) -> String {
    let tr = t.tracer.as_ref().expect("traced run").lock().expect("tracer lock");
    assert!(tr.spans_on());
    export::to_jsonl(&tr, false)
}

#[test]
fn trace_on_equals_trace_off_bitwise_across_threads_and_transports() {
    for transport in [TransportKind::InProc, TransportKind::Tcp] {
        for threads in [1usize, 4] {
            let (_, off) = run(base_cfg(threads, transport, false));
            let (traced, on) = run(base_cfg(threads, transport, true));
            assert_bitwise(
                &off,
                &on,
                &format!("trace off vs on, threads={threads} transport={transport:?}"),
            );
            // and the traced run actually recorded the simulation
            let tr = traced.tracer.as_ref().unwrap().lock().unwrap();
            assert!(tr.merged().len() > 100, "a traced run must buffer spans");
            assert_eq!(tr.dropped(), 0, "default ring must not drop on a run this size");
        }
    }
}

#[test]
fn trace_content_is_identical_across_thread_counts() {
    let (t1, o1) = run(base_cfg(1, TransportKind::InProc, true));
    let (t4, o4) = run(base_cfg(4, TransportKind::InProc, true));
    assert_bitwise(&o1, &o4, "threads 1 vs 4");
    let (a, b) = (jsonl_of(&t1), jsonl_of(&t4));
    assert!(!a.is_empty());
    assert_eq!(a, b, "without-wall trace exports must be byte-identical across --threads");
    // the wall-free form really excludes the one nondeterministic field
    assert!(!a.contains("wall_us"));
}

#[test]
fn trace_content_is_identical_across_transports() {
    let (ti, oi) = run(base_cfg(1, TransportKind::InProc, true));
    let (tt, ot) = run(base_cfg(1, TransportKind::Tcp, true));
    assert_bitwise(&oi, &ot, "inproc vs tcp, traced");
    assert_eq!(
        jsonl_of(&ti),
        jsonl_of(&tt),
        "without-wall trace exports must be byte-identical across transports"
    );
}

/// Worker fail + capacity squeeze + forced OOM: the trace must carry
/// the control events in exactly the order they fired.  After
/// `fail:r3` the group re-shards 4→2; the later OOM names a rank that
/// no longer exists (rank-descriptive, like `fail:`), evicts it, and
/// lands on the same E'=2 — so no second transition is recorded.
#[test]
fn churn_and_oom_events_appear_in_fired_order() {
    let mut cfg = base_cfg(1, TransportKind::InProc, true);
    cfg.train.epochs = 2;
    cfg.train.iters_per_epoch = 6;
    cfg.stragglers = StragglerPlan::Scenario(
        ScenarioSpec::parse("fail:r3@iter2,memsqueeze:r1@iter3:x0.5,oom:r2@iter4")
            .expect("scenario"),
    );
    let (t, obs) = run(cfg);
    assert_eq!(obs.3, 2, "fail:r3 must have re-sharded 4→2");
    let tr = t.tracer.as_ref().unwrap().lock().unwrap();
    let controls: Vec<String> = tr
        .merged()
        .iter()
        .filter(|s| matches!(s.kind, Kind::Churn | Kind::Mem))
        .map(|s| s.label.clone())
        .collect();
    assert_eq!(
        controls,
        vec!["fail:r3", "transition:4->2", "squeeze:r1", "oom-evict:r2"],
        "control events must appear in fired order"
    );
    // the squeeze span carries the shrunken capacity as its counter
    let squeeze = tr
        .merged()
        .into_iter()
        .find(|s| s.label == "squeeze:r1")
        .expect("squeeze span")
        .clone();
    assert!(squeeze.bytes > 0, "squeeze span must report the effective capacity");
}

/// Acceptance: on the `bursty` preset (χ6 square wave on rank 1),
/// SEMI@online at 4 threads, the report names rank 1 and attributes
/// ≥ 80% of its excess SimClock time to χ-slowed compute, with the
/// peers' all-reduce waits corroborating from the other side.
#[test]
fn bursty_report_attributes_the_injected_straggler() {
    let mut cfg = base_cfg(4, TransportKind::InProc, true);
    cfg.train.epochs = 2;
    cfg.train.iters_per_epoch = 12;
    cfg.stragglers = StragglerPlan::Scenario(
        flextp::contention::preset("bursty").expect("bursty preset"),
    );
    let (t, _) = run(cfg);
    let tr = t.tracer.as_ref().unwrap().lock().unwrap();
    let attr = Attribution::from_spans(tr.merged());
    let worst = attr.worst_epoch().expect("an epoch with a straggler");
    assert_eq!(worst.straggler, Some(1), "the injected straggler is rank 1");
    assert!(
        worst.attributed_pct >= 80.0,
        "only {:.1}% of the straggler's {:.4}s excess attributed (need ≥ 80%)",
        worst.attributed_pct,
        worst.excess_s
    );
    assert!(worst.excess_s > 0.0);
    assert!(worst.peer_wait_s > 0.0, "peers must have absorbed the straggle as waits");
    // the rendered report names the cause in prose
    assert!(attr.render().contains("straggler rank 1"));

    // round-trip: the report over the exported JSONL agrees with the
    // in-memory one (same aggregation path as `flextp trace report`)
    let text = export::to_jsonl(&tr, true);
    let spans = export::parse_jsonl(&text, std::path::Path::new("mem")).expect("parse");
    let reparsed = Attribution::from_spans(spans.iter());
    let w2 = reparsed.worst_epoch().expect("straggler survives the round trip");
    assert_eq!(w2.straggler, Some(1));
    assert_eq!(w2.attributed_pct.to_bits(), worst.attributed_pct.to_bits());
}

/// An unwritable trace sink surfaces as the typed
/// `TraceError::Unwritable` — the training run itself completes and is
/// never panicked or aborted by the export failure.
#[test]
fn unwritable_trace_out_is_a_typed_warning_not_a_panic() {
    let (t, obs) = run(base_cfg(1, TransportKind::InProc, true));
    assert!(obs.0.loss_curve.iter().all(|l| l.is_finite()), "the run itself completed");
    // a regular file in place of the export directory: both the early
    // probe and the end-of-run export map it to TraceError::Unwritable
    let clash = std::env::temp_dir().join(format!("flextp_trace_clash_{}", std::process::id()));
    std::fs::write(&clash, b"a file, not a directory").unwrap();
    let bad_dir = clash.join("trace");
    let err = flextp::trace::validate_out(&bad_dir).expect_err("probe must fail");
    assert!(matches!(err, TraceError::Unwritable { .. }));
    let tr = t.tracer.as_ref().unwrap().lock().unwrap();
    let err = export::write_outputs(&tr, &bad_dir).expect_err("export must fail");
    assert!(matches!(err, TraceError::Unwritable { .. }));
    assert!(err.to_string().contains("Unwritable"));
    let _ = std::fs::remove_file(&clash);

    // a writable sink exports both forms
    let good = std::env::temp_dir().join(format!("flextp_trace_out_{}", std::process::id()));
    let (jsonl, perfetto) = export::write_outputs(&tr, &good).expect("export");
    assert!(jsonl.exists() && perfetto.exists());
    let text = std::fs::read_to_string(&jsonl).unwrap();
    assert!(export::parse_jsonl(&text, &jsonl).expect("reparse").len() > 100);
    let _ = std::fs::remove_dir_all(&good);
}
