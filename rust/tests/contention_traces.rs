//! Property suite for the trace-driven contention engine (DESIGN.md §12).
//!
//! The subsystem's contract: traces are bounded (1 ≤ χ ≤ chi_max),
//! seeded-deterministic (same seed ⇒ bitwise the same trace, different
//! seeds decorrelate the stochastic tenants), prefix-stable (a longer
//! trace extends a shorter one unchanged), and consistent between the
//! one-shot `StragglerPlan::chis_at` reference path and the trainer's
//! precomputed `ContentionTrace`.

use flextp::config::StragglerPlan;
use flextp::contention::{preset, ContentionTrace, ScenarioError, ScenarioSpec};

fn spec(dsl: &str) -> ScenarioSpec {
    ScenarioSpec::parse(dsl).expect("valid DSL")
}

#[test]
fn prop_chi_bounded_for_every_preset_and_seed() {
    for name in ["calm", "burst1", "bursty", "step6", "tenant-churn", "markov-duo"] {
        for seed in 0..8u64 {
            let mut s = preset(name).unwrap();
            s.seed = seed;
            let t = ContentionTrace::generate(&s, 6, 96);
            assert_eq!(t.len(), 96);
            for g in 0..96 {
                for (r, &c) in t.chis(g).iter().enumerate() {
                    assert!(
                        (1.0..=s.chi_max).contains(&c),
                        "{name} seed={seed} g={g} r={r}: χ={c} out of [1, {}]",
                        s.chi_max
                    );
                }
            }
        }
    }
}

#[test]
fn prop_same_seed_identical_trace() {
    let dsl = "burst:r1@x5:iters2-20,markov:r*@x3:p0.3-0.3,pulse:r2@x2:from1:period5:on2";
    for seed in [0u64, 7, 42, 1 << 40] {
        let mut a = spec(dsl);
        a.seed = seed;
        let b = a.clone();
        let ta = ContentionTrace::generate(&a, 5, 64);
        let tb = ContentionTrace::generate(&b, 5, 64);
        for g in 0..64 {
            assert_eq!(ta.chis(g), tb.chis(g), "seed={seed} g={g}");
        }
    }
}

#[test]
fn prop_different_seeds_decorrelate_stochastic_tenants() {
    // p_on = p_off = 0.5 flips often: over 64 iterations two seeds
    // agreeing everywhere would be a (1/2)^~64 coincidence.
    let mut a = spec("markov:r0@x4:p0.5-0.5");
    let mut b = a.clone();
    a.seed = 1;
    b.seed = 2;
    let ta = ContentionTrace::generate(&a, 1, 64);
    let tb = ContentionTrace::generate(&b, 1, 64);
    let differs = (0..64).any(|g| ta.chis(g) != tb.chis(g));
    assert!(differs, "different seeds produced identical Markov traces");
}

#[test]
fn prop_markov_chains_are_independent_per_rank() {
    // r* spawns one chain per rank; with symmetric 0.5 transitions the
    // ranks' on/off patterns must not be mirror copies of each other.
    let s = spec("markov:r*@x4:p0.5-0.5,seed:5");
    let t = ContentionTrace::generate(&s, 4, 64);
    let col = |r: usize| (0..64).map(|g| t.chis(g)[r]).collect::<Vec<_>>();
    assert!(
        (1..4).any(|r| col(0) != col(r)),
        "all per-rank chains identical — seeds not decorrelated"
    );
    // and each chain actually both fires and rests over 64 steps
    for r in 0..4 {
        let c = col(r);
        assert!(c.iter().any(|&v| v > 1.0), "rank {r} tenant never arrived");
        assert!(c.iter().any(|&v| v == 1.0), "rank {r} tenant never departed");
    }
}

#[test]
fn prop_traces_are_prefix_stable() {
    // The trainer generates epochs·iters rows; tests replay shorter
    // prefixes — both must see the same history.
    let s = spec("markov:r*@x3:p0.25-0.25,burst:r1@x4:iters3-9,seed:11");
    let long = ContentionTrace::generate(&s, 3, 80);
    for len in [1usize, 7, 40, 79] {
        let short = ContentionTrace::generate(&s, 3, len);
        for g in 0..len {
            assert_eq!(short.chis(g), long.chis(g), "len={len} g={g}");
        }
    }
}

#[test]
fn plan_chis_at_matches_realized_trace() {
    // The StragglerPlan::chis_at reference path (replay per call) and
    // the trainer's precomputed trace must agree row for row.
    let sc = spec("step:r2@x3:iters4-,markov:r0@x2:p0.3-0.2,seed:13");
    let plan = StragglerPlan::Scenario(sc.clone());
    let trace = ContentionTrace::from_plan(&plan, 4, 3, 8);
    for g in 0..24 {
        assert_eq!(plan.chis_at(4, g / 8, g), trace.chis(g).to_vec(), "g={g}");
    }
}

#[test]
fn degenerate_plans_realize_as_epoch_constant_traces() {
    let fixed = StragglerPlan::Fixed(vec![3.0, 1.0]);
    let t = ContentionTrace::from_plan(&fixed, 4, 2, 5);
    assert_eq!(t.len(), 10);
    for g in 0..10 {
        assert_eq!(t.chis(g), &[3.0, 1.0, 1.0, 1.0]);
    }
    // RoundRobin rotates at epoch boundaries, holds within an epoch
    let rr = StragglerPlan::RoundRobin { chi: 4.0, period_epochs: 1 };
    let t = ContentionTrace::from_plan(&rr, 3, 3, 4);
    for g in 0..12 {
        let mut want = vec![1.0; 3];
        want[g / 4] = 4.0;
        assert_eq!(t.chis(g), &want[..], "g={g}");
    }
    // None stays calm and out-of-range queries clamp to the last row
    let t = ContentionTrace::from_plan(&StragglerPlan::None, 2, 1, 4);
    assert_eq!(t.chis(400), &[1.0, 1.0]);
}

#[test]
fn trace_cursor_persists_across_resume_without_drift() {
    // The checkpoint subsystem persists the contention-trace position as
    // (plan descriptor string, global iteration) — the descriptor is
    // `ScenarioSpec::describe()` and the trace is regenerated on resume.
    // This test guards the cursor serde against off-by-one drift: for
    // every kill point, the resumed trace's rows from the cursor onward
    // must equal the uninterrupted trace's rows — including the row AT
    // the cursor (the first resumed iteration) and the one before it
    // (the last pre-kill iteration must NOT be replayed as shifted).
    let src = "burst:r1@x5:iters3-11,markov:r*@x2:p0.3-0.25,\
               pulse:r2@x3:from1:period5:on2,seed:17";
    let spec = spec(src);
    let (e, epochs, ipe) = (4usize, 3usize, 8usize);
    let plan = StragglerPlan::Scenario(spec.clone());
    let uninterrupted = ContentionTrace::from_plan(&plan, e, epochs, ipe);
    for kill in [1usize, 7, 8, 13, 23] {
        // what resume actually does: re-parse the persisted descriptor,
        // rebuild the trace, continue at the saved global iteration
        let described = ScenarioSpec::parse(&spec.describe()).expect("descriptor re-parses");
        assert_eq!(described, spec, "describe() must round-trip the spec");
        let resumed =
            ContentionTrace::from_plan(&StragglerPlan::Scenario(described), e, epochs, ipe);
        for g in kill.saturating_sub(1)..(epochs * ipe) {
            assert_eq!(
                resumed.chis(g),
                uninterrupted.chis(g),
                "kill={kill} g={g}: resumed trace drifted"
            );
            // and the chis_at reference path agrees with both
            assert_eq!(
                StragglerPlan::Scenario(spec.clone()).chis_at(e, g / ipe, g),
                uninterrupted.chis(g).to_vec(),
                "kill={kill} g={g}: chis_at disagrees"
            );
        }
    }
    // extending the schedule on resume (--epochs raised) keeps the
    // shared prefix bitwise identical (prefix stability)
    let extended = ContentionTrace::from_plan(&plan, e, epochs + 2, ipe);
    for g in 0..(epochs * ipe) {
        assert_eq!(extended.chis(g), uninterrupted.chis(g), "g={g}");
    }
}

/// DSL strictness (ISSUE 6 satellite): malformed clauses fail the parse
/// with a *typed* `ScenarioError` — never silently ignored — and the
/// error survives the anyhow chain for callers that want to match on it.
#[test]
fn malformed_scenarios_raise_typed_errors() {
    // unknown event kind
    let err = ScenarioSpec::parse("meteor:r1@x2:iters0-4").expect_err("unknown kind");
    match err.downcast_ref::<ScenarioError>() {
        Some(ScenarioError::UnknownEventKind(k)) => assert_eq!(k, "meteor"),
        other => panic!("expected UnknownEventKind, got {other:?} ({err:#})"),
    }
    // malformed churn clauses, each with the offending item in the error
    for bad in [
        "join:r*@iter4",  // churn needs a concrete rank
        "fail:r1@iter0",  // resizing before any work ran
        "join:r1@x4",     // missing @iterK
        "leave:r1",       // missing everything after the rank
        "join:rq@iter3",  // unparsable rank
        "fail:r1@iterx",  // unparsable iteration
    ] {
        let err = ScenarioSpec::parse(bad).expect_err(bad);
        assert!(
            matches!(err.downcast_ref::<ScenarioError>(), Some(ScenarioError::Malformed { .. })),
            "'{bad}' must raise ScenarioError::Malformed, got: {err:#}"
        );
    }
    // a static event aimed past the worker set: typed RankOutOfRange
    // from validate_ranks (parse itself cannot know e)
    let s = spec("step:r3@x6:iters4-");
    let err = s.validate_ranks(2).expect_err("rank 3 of 2");
    match err.downcast_ref::<ScenarioError>() {
        Some(ScenarioError::RankOutOfRange { rank: 3, e: 2 }) => {}
        other => panic!("expected RankOutOfRange, got {other:?} ({err:#})"),
    }
    // JSON path is equally strict
    let err = ScenarioSpec::from_json(
        &flextp::util::json::Json::parse(r#"{"events":[{"kind":"meteor","rank":1,"chi":2}]}"#)
            .unwrap(),
    )
    .expect_err("unknown JSON kind");
    assert!(
        matches!(err.downcast_ref::<ScenarioError>(), Some(ScenarioError::UnknownEventKind(_))),
        "got: {err:#}"
    );
}

/// Churn events are orchestration-level: they parse, describe, sort,
/// and round-trip without ever perturbing the realized χ trace, and
/// their presence suspends static rank validation (the rank set is no
/// longer fixed for the whole run).
#[test]
fn churn_events_ride_along_without_touching_the_chi_trace() {
    let with = spec("burst:r3@x5:iters2-9,fail:r3@iter6,join:r3@iter30,seed:9");
    let without = spec("burst:r3@x5:iters2-9,seed:9");
    let (ta, tb) = (
        ContentionTrace::generate(&with, 4, 40),
        ContentionTrace::generate(&without, 4, 40),
    );
    for g in 0..40 {
        assert_eq!(ta.chis(g), tb.chis(g), "g={g}: churn must not perturb χ");
    }
    // describe() round-trips the churn clauses
    let reparsed = ScenarioSpec::parse(&with.describe()).expect("describe re-parses");
    assert_eq!(reparsed, with);
    assert_eq!(with.churn_sorted().len(), 2);
    // a static out-of-range event is tolerated when churn may resize the
    // worker set mid-run (trace realization drops absent ranks)...
    assert!(with.validate_ranks(2).is_ok());
    // ...but stays an error for churn-free specs
    assert!(without.validate_ranks(2).is_err());
}

#[test]
fn trace_stats_summarize_contention() {
    let t = ContentionTrace::generate(&spec("burst:r0@x5:iters0-2"), 2, 4);
    // rows: [5,1],[5,1],[1,1],[1,1] → mean = 16/8, max = 5
    let (mean, max) = t.stats();
    assert!((mean - 2.0).abs() < 1e-12, "mean={mean}");
    assert_eq!(max, 5.0);
}

#[test]
fn scenario_file_roundtrip_dsl_and_json() {
    let dir = std::env::temp_dir().join("flextp_scenario_test");
    std::fs::create_dir_all(&dir).unwrap();
    let want = spec("burst:r2@x4:iters10-40,seed:7");

    let dsl_path = dir.join("scn.dsl");
    std::fs::write(&dsl_path, "burst:r2@x4:iters10-40,seed:7\n").unwrap();
    assert_eq!(ScenarioSpec::from_file(&dsl_path).unwrap(), want);

    let json_path = dir.join("scn.json");
    std::fs::write(
        &json_path,
        r#"{"seed": 7, "events": [{"kind":"burst","rank":2,"chi":4,"from":10,"to":40}]}"#,
    )
    .unwrap();
    assert_eq!(ScenarioSpec::from_file(&json_path).unwrap(), want);

    assert!(ScenarioSpec::from_file(&dir.join("missing.dsl")).is_err());
}
