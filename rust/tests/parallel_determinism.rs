//! Serial/parallel parity suite for the rank-execution engine.
//!
//! The engine's contract (trainer module docs, DESIGN.md §Concurrency): a
//! `--threads 1` and a `--threads N` run of the same seed + config execute
//! identical arithmetic — bitwise-equal losses, eval metrics, and
//! structural `CommStats` — because workers only compute, all merges
//! replay in rank order on the coordinator, the all-reduce tree is fixed,
//! and the panel-parallel GEMMs preserve per-element accumulation order.
//!
//! CI runs the whole test suite under `FLEXTP_THREADS=1` and
//! `FLEXTP_THREADS=4`; this file additionally pins the 1-vs-N comparison
//! *inside one process*, with forced per-worker actions so every
//! exercised path (pruned buckets, migration slices, broadcast/gather
//! accounting) is timing-independent.

use flextp::balancer::WorkerAction;
use flextp::config::{ReplanMode, RunCfg, StragglerPlan, Strategy, TimeModel};
use flextp::contention::ScenarioSpec;
use flextp::migration;
use flextp::resizing::LayerPlan;
use flextp::tensor::linalg;
use flextp::train::trainer::Trainer;
use flextp::util::rng::Rng;

/// Forced per-worker plan: worker 0 migrates half its FFN to the other
/// ranks, worker 1 prunes at γ=0.5 with seeded random keep sets, workers
/// 2..e run full-width — pruning, migration, and baseline paths all in
/// one iteration, with zero timing-dependent decisions.
fn forced_actions(t: &Trainer) -> Vec<WorkerAction> {
    let man = t.rt.manifest.clone();
    let m = man.model.clone();
    let mut rng = Rng::new(77);
    let mut actions: Vec<WorkerAction> =
        (0..m.e).map(|_| WorkerAction::full(&man)).collect();
    // worker 0: migrate — mirror the kept set into its layer plans the
    // way Balancer::apply_mig_to_layers does
    let mig = migration::plan(&man, 0, 0.5, 1.0, None).expect("migration plan");
    for p in &mut actions[0].layers {
        p.mlp_b1 = "g00".into();
        p.mlp_b2 = mig.kept_bucket.clone();
        p.mlp_keep2 = mig.kept.clone();
    }
    actions[0].mig = Some(mig);
    // worker 1: γ=0.5 pruning with fixed keep sets
    let b50 = man.bucket_for_gamma(0.5).clone();
    for p in &mut actions[1].layers {
        *p = LayerPlan {
            attn_bucket: b50.name.clone(),
            mlp_b1: b50.name.clone(),
            mlp_b2: b50.name.clone(),
            attn_keep: rng.choose_k(m.hs, b50.keep_hs),
            mlp_keep1: rng.choose_k(m.hs, b50.keep_hs),
            mlp_keep2: rng.choose_k(m.ffl, b50.keep_ffl),
        };
    }
    actions
}

/// Run 3 forced-action iterations + one eval at a given thread count.
fn run_at(threads: usize) -> (Vec<f32>, (f64, f64), u64, u64) {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.train.threads = threads;
    cfg.train.momentum = 0.0;
    cfg.train.eval_iters = 2;
    let mut t = Trainer::new(cfg).expect("native trainer");
    t.forced_actions = Some(forced_actions(&t));
    let mut losses = Vec::new();
    for _ in 0..3 {
        losses.push(t.train_iter().expect("train step"));
    }
    let eval = t.eval().expect("eval");
    let bytes = t.comm.stats.total_bytes();
    let allreduce_ops = t.comm.stats.allreduce_ops;
    (losses, eval, bytes, allreduce_ops)
}

#[test]
fn losses_eval_and_comm_bytes_bitwise_identical_1_vs_n_threads() {
    let (l1, e1, b1, ops1) = run_at(1);
    let (l4, e4, b4, ops4) = run_at(4);
    assert!(l1.iter().all(|l| l.is_finite()), "diverged: {l1:?}");
    assert_eq!(l1, l4, "losses must be bitwise identical across thread counts");
    assert_eq!(e1, e4, "eval metrics must be bitwise identical");
    assert_eq!(b1, b4, "CommStats::total_bytes must match");
    assert_eq!(ops1, ops4, "collective op counts must match");
    // migration engaged, so bytes include broadcast + weight-grad gathers
    assert!(b1 > 0);
    // and a repeat at the same thread count reproduces exactly
    let (l1b, e1b, b1b, _) = run_at(1);
    assert_eq!(l1, l1b);
    assert_eq!(e1, e1b);
    assert_eq!(b1, b1b);
}

#[test]
fn dynamic_scenario_with_online_replans_bitwise_identical_1_vs_n_threads() {
    // The full dynamic pipeline — bursty contention trace → modeled
    // SimClock charges → monitor T_i/M_i → EWMA drift controller →
    // mid-epoch SEMI replans (Eq. 2/3, migration + pruning) — is a
    // closed deterministic system under --time-model modeled: the trace
    // is realized on the coordinator, every charge is a pure function of
    // shapes, and plans feed only on those charges.  So thread count
    // must change nothing, bit for bit, even though the *plan itself*
    // changes mid-epoch.
    let run = |threads: usize| {
        let mut cfg = RunCfg::new("vit-tiny");
        cfg.train.threads = threads;
        cfg.train.epochs = 2;
        cfg.train.iters_per_epoch = 8;
        cfg.train.eval_iters = 2;
        cfg.train.time_model = TimeModel::Modeled;
        cfg.balancer.strategy = Strategy::Semi;
        cfg.balancer.replan = ReplanMode::Online;
        // two stragglers at times → the Eq.(3) grouping path; λ=1 pins
        // one migrating straggler so migration slices are exercised
        cfg.balancer.forced_lambda = Some(1);
        cfg.stragglers = StragglerPlan::Scenario(
            ScenarioSpec::parse("burst:r1@x5:iters2-9,markov:r3@x2:p0.4-0.3,seed:9")
                .expect("scenario"),
        );
        let mut t = Trainer::new(cfg).expect("trainer");
        let report = t.run().expect("run");
        let per_epoch: Vec<(f64, f64, u64, u64, f64)> = report
            .epochs
            .iter()
            .map(|e| (e.eval_loss, e.acc, e.replans, e.migrated_cols + e.pruned_cols, e.rt_sim_s))
            .collect();
        (
            report.loss_curve.clone(),
            per_epoch,
            t.comm.stats.total_bytes(),
            t.comm.stats.allreduce_ops,
            report.total_replans(),
        )
    };
    let serial = run(1);
    let parallel = run(4);
    assert!(serial.0.iter().all(|l| l.is_finite()), "diverged: {:?}", serial.0);
    assert_eq!(serial.0, parallel.0, "losses must be bitwise identical");
    assert_eq!(serial.1, parallel.1, "epoch metrics must be bitwise identical");
    assert_eq!(serial.2, parallel.2, "CommStats::total_bytes must match");
    assert_eq!(serial.3, parallel.3, "collective op counts must match");
    assert_eq!(serial.4, parallel.4, "replan counts must match");
    // the controller actually fired mid-epoch: more replans than the
    // 2 epoch-boundary plans alone
    assert!(
        serial.4 > 2,
        "expected drift-triggered mid-epoch replans under the bursty trace, got {}",
        serial.4
    );
    // and the trace actually balanced something
    assert!(serial.1.iter().map(|e| e.3).sum::<u64>() > 0, "no balancing engaged");
}

#[test]
fn gamma_override_strategy_losses_identical_1_vs_n_threads() {
    // The ZERO-Rd planner path (balancer rng, pruned executables chosen
    // per iteration) is also timing-independent under --gamma: only the
    // passive T_avg refresh cadence may differ, and it feeds no decision.
    let run = |threads: usize| -> Vec<f32> {
        let mut cfg = RunCfg::new("vit-tiny");
        cfg.train.threads = threads;
        cfg.balancer.strategy = Strategy::ZeroRd;
        cfg.balancer.gamma_override = Some(0.5);
        let mut t = Trainer::new(cfg).expect("trainer");
        (0..3).map(|_| t.train_iter().expect("step")).collect()
    };
    let serial = run(1);
    let parallel = run(4);
    assert!(serial.iter().all(|l| l.is_finite()));
    assert_eq!(serial, parallel);
}

#[test]
fn forward_full_is_thread_count_invariant() {
    let fwd = |threads: usize| {
        let mut cfg = RunCfg::new("vit-tiny");
        cfg.train.threads = threads;
        let mut t = Trainer::new(cfg).expect("trainer");
        let batch = t.data.train_batch(0);
        t.forward_full(&batch).expect("forward").data
    };
    assert_eq!(fwd(1), fwd(3), "full-width forward must not depend on threads");
}

#[test]
fn gemm_panel_parallelism_is_bitwise_deterministic() {
    // The kernel-level half of the parity argument, on shapes large
    // enough to clear the parallel threshold and odd enough to exercise
    // uneven panel splits.
    let mut rng = Rng::new(41);
    let (m, k, n) = (130, 257, 71);
    let a = rng.normal_vec(m * k, 1.0);
    let b = rng.normal_vec(k * n, 1.0);
    let b2 = rng.normal_vec(m * n, 1.0);
    let bt = rng.normal_vec(n * k, 1.0);
    let serial = linalg::with_gemm_threads(1, || {
        (
            linalg::matmul(&a, &b, m, k, n),
            linalg::matmul_at_b(&a, &b2, m, k, n),
            linalg::matmul_a_bt(&a, &bt, m, k, n),
        )
    });
    for t in [2usize, 4, 8] {
        let par = linalg::with_gemm_threads(t, || {
            (
                linalg::matmul(&a, &b, m, k, n),
                linalg::matmul_at_b(&a, &b2, m, k, n),
                linalg::matmul_a_bt(&a, &bt, m, k, n),
            )
        });
        assert_eq!(serial, par, "GEMM results differ at {t} threads");
    }
}
