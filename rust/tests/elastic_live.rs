//! Live elastic re-parallelization suite (ISSUE 6, DESIGN.md §14).
//!
//! The tentpole contract: when a scenario scripts worker churn
//! (`fail:rN@iterK`, `join:rN@iterK`), the trainer re-shards **in
//! process** — gather → shard at the same global iteration, no
//! `.flexckpt` round-trip — and the result is *bitwise identical* to
//! the PR 5 oracle: kill the run at iteration K, checkpoint, and resume
//! with `--e E'`.  Every observable the math produces (losses, per-epoch
//! sim metrics, CommStats) must match at `--threads` 1 and 4 alike.
//!
//! Also pinned here: mid-epoch accumulator correctness across an E
//! change (satellite 3), graceful degradation when a failure leaves no
//! divisor-compatible worker count (satellite 6), and the churn sweep
//! acceptance row — elastic@online beats both fixed-E baselines on
//! modeled RT (acceptance criterion).

use flextp::bench::sweep::{run_sweep, SweepSpec};
use flextp::config::{ReplanMode, RunCfg, StragglerPlan, Strategy, TimeModel};
use flextp::contention::{ScenarioError, ScenarioSpec};
use flextp::metrics::RunReport;
use flextp::train::trainer::Trainer;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("flextp_live_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// vit-tiny (hs=128, heads=4, e=4) under the full dynamic pipeline —
/// SEMI + online controller + momentum, deterministic modeled clock —
/// with scripted churn: r3 fails at iteration 4 (mid epoch 0, 4→2) and
/// a worker rejoins at iteration 8 (mid epoch 1, 2→4).  A burst tenant
/// keeps the balancer busy on a surviving rank throughout, so the plan,
/// monitor, and controller state all carry real information across both
/// transitions.
fn churn_cfg(threads: usize) -> RunCfg {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.train.threads = threads;
    cfg.train.epochs = 2;
    cfg.train.iters_per_epoch = 6;
    cfg.train.eval_iters = 2;
    cfg.train.momentum = 0.9;
    cfg.train.time_model = TimeModel::Modeled;
    cfg.balancer.strategy = Strategy::Semi;
    cfg.balancer.replan = ReplanMode::Online;
    cfg.balancer.forced_lambda = Some(1);
    cfg.stragglers = StragglerPlan::Scenario(
        ScenarioSpec::parse(
            "fail:r3@iter4,join:r3@iter8,burst:r1@x5:iters2-9,markov:r3@x2:p0.4-0.3,seed:9",
        )
        .expect("scenario"),
    );
    cfg
}

type Observables = (RunReport, u64, u64, usize);

/// One uninterrupted run with live in-process transitions.
fn run_live(cfg: RunCfg) -> Observables {
    let mut t = Trainer::new(cfg).expect("trainer");
    let r = t.run().expect("live run");
    (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops, t.model().e)
}

/// The PR 5 oracle for the same schedule: kill at each churn iteration,
/// checkpoint, and resume with `--e E'` — the elastic restore path the
/// live transition must reproduce bit for bit.
fn run_oracle(cfg: RunCfg, tag: &str) -> Observables {
    let dir = tmp_dir(tag);
    let p4 = dir.join(flextp::checkpoint::ckpt_filename(4));
    {
        let mut t = Trainer::new(cfg.clone()).expect("trainer");
        t.run_to(Some(4)).expect("to the failure point");
        assert_eq!(t.giter(), 4);
        assert_eq!(t.model().e, 4, "the fail event must not have fired yet");
        t.save_checkpoint(&p4).expect("save @4");
        // drop = the kill
    }
    let p8 = dir.join(flextp::checkpoint::ckpt_filename(8));
    {
        let mut shrunk = cfg.clone();
        shrunk.e_override = Some(2);
        let mut t = Trainer::resume_from(shrunk, &p4).expect("elastic resume onto e=2");
        assert_eq!(t.model().e, 2);
        t.run_to(Some(8)).expect("to the join point");
        assert_eq!(t.model().e, 2, "fail@4 must be a no-op on the resumed e=2 run");
        t.save_checkpoint(&p8).expect("save @8");
    }
    let mut grown = cfg;
    grown.e_override = Some(4);
    let mut t = Trainer::resume_from(grown, &p8).expect("elastic resume onto e=4");
    assert_eq!(t.model().e, 4);
    let r = t.run().expect("oracle run");
    let out = (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops, t.model().e);
    let _ = std::fs::remove_dir_all(&dir);
    out
}

fn assert_bitwise(a: &Observables, b: &Observables, what: &str) {
    assert!(
        a.0.loss_curve.iter().all(|l| l.is_finite()),
        "{what}: diverged: {:?}",
        a.0.loss_curve
    );
    assert_eq!(a.0.loss_curve, b.0.loss_curve, "{what}: losses must be bitwise identical");
    assert!(a.0.sim_equal(&b.0), "{what}: per-epoch sim metrics must be bitwise identical");
    assert_eq!(a.1, b.1, "{what}: CommStats::total_bytes must match");
    assert_eq!(a.2, b.2, "{what}: all-reduce op counts must match");
    assert_eq!(a.3, b.3, "{what}: final worker counts must match");
}

#[test]
fn live_transition_matches_kill_resume_oracle_at_1_and_4_threads() {
    let mut per_thread = Vec::new();
    for threads in [1usize, 4] {
        let live = run_live(churn_cfg(threads));
        let oracle = run_oracle(churn_cfg(threads), &format!("oracle_t{threads}"));
        assert_bitwise(&live, &oracle, &format!("threads={threads}"));
        per_thread.push(live);
    }
    // the 1-vs-4-thread parity contract survives live re-sharding
    assert_bitwise(&per_thread[0], &per_thread[1], "threads 1 vs 4");
    let live = &per_thread[0];
    assert_eq!(live.3, 4, "join@8 must have re-grown the run to e=4");
    assert_eq!(live.0.loss_curve.len(), 12, "every scheduled iteration ran");
    // sanity: the burst tenant actually engaged the balancer, so the
    // parity above covered a non-trivial plan across the transitions
    assert!(
        live.0.epochs.iter().map(|e| e.pruned_cols + e.migrated_cols).sum::<u64>() > 0,
        "no balancing engaged — the oracle comparison would be vacuous"
    );
}

#[test]
fn transition_fires_at_the_scheduled_iteration() {
    let mut cfg = churn_cfg(1);
    cfg.train.epochs = 1;
    let mut t = Trainer::new(cfg).expect("trainer");
    t.run_to(Some(4)).expect("to just before the failure");
    assert_eq!(t.model().e, 4, "fail:r3@iter4 fires before iteration 4, not earlier");
    t.run_to(Some(5)).expect("across the failure");
    assert_eq!(t.model().e, 2, "the 4→2 re-shard lands exactly at iteration 4");
    let r = t.run().expect("finish epoch 0");
    assert!(r.loss_curve.iter().all(|l| l.is_finite()));
    assert_eq!(r.loss_curve.len(), 6);
}

/// Satellite 3: epoch accumulators (replans, χ stats, CommStats deltas)
/// survive a *mid-epoch* E change and a kill *between* the transitions.
/// The run is killed at iteration 5 — inside epoch 0, after the 4→2
/// re-shard — and resumed at the same width (`--e 2`, the PR 5
/// epoch-in-progress restore path); the join@8 then fires inside the
/// resumed run.  Everything must still match the live run bitwise.
#[test]
fn mid_epoch_kill_between_transitions_is_bitwise() {
    let cfg = churn_cfg(1);
    let live = run_live(cfg.clone());

    let dir = tmp_dir("between");
    let p5 = dir.join(flextp::checkpoint::ckpt_filename(5));
    {
        let mut t = Trainer::new(cfg.clone()).expect("trainer");
        t.run_to(Some(5)).expect("past the 4→2 transition");
        assert_eq!(t.model().e, 2, "the kill point sits between the transitions");
        t.save_checkpoint(&p5).expect("save @5");
    }
    let mut same = cfg;
    same.e_override = Some(2);
    let mut t = Trainer::resume_from(same, &p5).expect("same-width resume");
    assert_eq!(t.model().e, 2);
    let r = t.run().expect("resumed run");
    let resumed = (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops, t.model().e);
    assert_bitwise(&live, &resumed, "kill between transitions");

    // the accumulator guts, spelled out: epoch 0 closed at e=2 with its
    // partials carried across both the transition and the kill, epoch 1
    // spans the 2→4 re-grow
    for (i, (a, b)) in live.0.epochs.iter().zip(&resumed.0.epochs).enumerate() {
        assert_eq!(a.replans, b.replans, "epoch {i} replans");
        assert_eq!(a.chi_mean, b.chi_mean, "epoch {i} chi_mean");
        assert_eq!(a.chi_max, b.chi_max, "epoch {i} chi_max");
        assert_eq!(a.comm_bytes, b.comm_bytes, "epoch {i} comm bytes");
        assert_eq!(a.pruned_cols, b.pruned_cols, "epoch {i} pruned");
        assert_eq!(a.migrated_cols, b.migrated_cols, "epoch {i} migrated");
        assert_eq!(a.rt_sim_s, b.rt_sim_s, "epoch {i} simulated RT");
    }
    assert_eq!(live.0.epochs[0].rank_compute_s.len(), 2, "epoch 0 finalized at e=2");
    assert_eq!(live.0.epochs[1].rank_compute_s.len(), 4, "epoch 1 finalized at e=4");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite 6: a failure that leaves a worker count dividing neither
/// hs nor heads degrades to the nearest valid divisor; losing everything
/// is a typed error, never a panic.
#[test]
fn failures_degrade_to_nearest_divisor_or_typed_error() {
    let base = |scenario: &str| {
        let mut cfg = churn_cfg(1);
        cfg.train.epochs = 1;
        cfg.stragglers =
            StragglerPlan::Scenario(ScenarioSpec::parse(scenario).expect("scenario"));
        cfg
    };

    // one failure: 3 survivors, but 3 divides neither hs=128 nor
    // heads=4 — the run degrades to E'=2, the nearest valid divisor
    let mut t = Trainer::new(base("fail:r0@iter2")).expect("trainer");
    let r = t.run().expect("nearest-divisor run");
    assert_eq!(t.model().e, 2, "3 survivors must degrade to E'=2");
    assert!(r.loss_curve.iter().all(|l| l.is_finite()));

    // three failures: one survivor still shards (E'=1 always divides)
    let mut t =
        Trainer::new(base("fail:r0@iter2,fail:r1@iter2,fail:r2@iter3")).expect("trainer");
    let r = t.run().expect("single-survivor run");
    assert_eq!(t.model().e, 1);
    assert_eq!(r.loss_curve.len(), 6, "the run finishes its schedule");

    // every worker gone: a typed mid-epoch error, not a panic
    let mut t = Trainer::new(base(
        "fail:r0@iter2,fail:r1@iter2,fail:r2@iter2,fail:r3@iter2",
    ))
    .expect("trainer");
    let err = t.run().expect_err("no survivors must fail the run");
    let scen = err
        .downcast_ref::<ScenarioError>()
        .unwrap_or_else(|| panic!("expected a typed ScenarioError, got: {err:#}"));
    assert!(
        matches!(scen, ScenarioError::NoViableWorkerCount { avail: 0, .. }),
        "got: {scen}"
    );
}

/// The acceptance row: under the churn sweep preset, the live elastic
/// cell must beat *both* fixed-E baselines on modeled RT while staying
/// within accuracy tolerance of the best of them.
#[test]
fn churn_sweep_elastic_cell_beats_both_fixed_baselines() {
    let spec = SweepSpec::preset("churn").expect("churn preset");
    let report = run_sweep(&spec).expect("churn sweep");
    assert_eq!(report.cells.len(), 3);
    let live = report.cells.iter().find(|c| c.cell == "live").expect("live cell");
    let fixed: Vec<_> = report.cells.iter().filter(|c| c.cell.starts_with("fixed")).collect();
    assert_eq!(fixed.len(), 2, "two fixed-E baselines (e=4 and e=2)");
    for f in &fixed {
        assert!(
            live.rt < f.rt,
            "elastic RT {:.4}s must beat fixed '{}' RT {:.4}s",
            live.rt,
            f.cell,
            f.rt
        );
        assert!(
            (live.final_acc - f.final_acc).abs() <= 0.15,
            "elastic ACC {:.3} drifted from '{}' ACC {:.3}",
            live.final_acc,
            f.cell,
            f.final_acc
        );
    }
    // and the report's own comparison table agrees
    let cc = report.churn_comparisons();
    assert_eq!(cc.len(), 1);
    assert!(cc[0].3 > 1.0, "elastic_speedup {:.3} must exceed 1", cc[0].3);
}
