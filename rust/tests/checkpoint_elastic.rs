//! Elastic resume suite (DESIGN.md §13): a checkpoint written by an
//! `E`-rank run restores onto a different worker count.
//!
//! Contract pinned here:
//! * **exactness** — re-partitioning is pure slicing: the full
//!   (TP-undone) model after an elastic restore is bitwise identical to
//!   the checkpointed one, for both shrink (4→2) and grow (4→8);
//! * **loss equivalence** — the post-resume loss trajectory matches the
//!   uninterrupted base run (the fresh-plan oracle at the original E)
//!   within f32 reduction-order tolerance: a different worker count
//!   changes partial-sum order, never the math;
//! * **validation** — indivisible worker counts are rejected up front.

use flextp::checkpoint::elastic::gather_full;
use flextp::config::{RunCfg, TimeModel};
use flextp::train::trainer::Trainer;

const EPOCHS: usize = 1;
const IPE: usize = 4;
const KILL: u64 = 2;

/// vit-s (hs=256, heads=8) run at e=4 — both 2 and 8 divide hs & heads.
fn base_cfg(e: usize) -> RunCfg {
    let mut cfg = RunCfg::new("vit-s");
    cfg.e_override = Some(e);
    cfg.train.threads = 1;
    cfg.train.epochs = EPOCHS;
    cfg.train.iters_per_epoch = IPE;
    cfg.train.eval_iters = 1;
    cfg.train.momentum = 0.9;
    cfg.train.time_model = TimeModel::Modeled;
    // calm + baseline: the oracle comparison isolates re-sharding from
    // balancing-policy divergence across worker counts
    cfg
}

fn tmp_ckpt(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("flextp_elastic_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.join(flextp::checkpoint::ckpt_filename(KILL))
}

#[test]
fn elastic_resume_repartitions_exactly_and_tracks_the_oracle() {
    // --- base 4-rank run: train to the kill point, checkpoint, then
    //     (as the oracle) keep going uninterrupted
    let path = tmp_ckpt("main");
    let mut base = Trainer::new(base_cfg(4)).expect("base trainer");
    base.run_to(Some(KILL)).expect("base to kill point");
    base.save_checkpoint(&path).expect("checkpoint");
    let full_at_kill = gather_full(&base.rt.manifest.model, &base.state);
    let oracle = base.run().expect("oracle continues uninterrupted");
    let oracle_tail = &oracle.loss_curve[KILL as usize..];

    for e in [2usize, 8] {
        let mut t = Trainer::resume_from(base_cfg(e), &path)
            .unwrap_or_else(|err| panic!("elastic resume e={e}: {err}"));
        assert_eq!(t.giter(), KILL);
        assert_eq!(t.model().e, e);
        // exactness: undoing the new partition reproduces the
        // checkpointed full model bit for bit
        let full = gather_full(&t.rt.manifest.model, &t.state);
        assert_eq!(full, full_at_kill, "e={e}: re-partition must round-trip exactly");
        // momentum moved with the weights: resharded buffers exist for
        // every shard key and the rep keys
        assert!(
            t.opt.buffer_count() > 0,
            "e={e}: momentum buffers must survive elastic resume"
        );
        // loss equivalence: same math, different f32 reduction order
        let r = t.run().expect("resumed run");
        assert_eq!(r.loss_curve.len(), oracle.loss_curve.len());
        let tail = &r.loss_curve[KILL as usize..];
        for (i, (a, b)) in tail.iter().zip(oracle_tail).enumerate() {
            assert!(a.is_finite(), "e={e}: loss {i} diverged");
            assert!(
                (a - b).abs() <= 5e-3 * b.abs().max(1.0),
                "e={e}: post-resume loss {i} drifted: resumed {a} vs oracle {b}"
            );
        }
        // the pre-kill history is carried over verbatim
        assert_eq!(
            &r.loss_curve[..KILL as usize],
            &oracle.loss_curve[..KILL as usize],
            "e={e}: restored loss history must be the checkpointed one"
        );
        // eval on the resharded model agrees with the oracle closely
        let (el, ol) = (r.epochs[0].eval_loss, oracle.epochs[0].eval_loss);
        assert!(
            (el - ol).abs() <= 5e-3 * ol.abs().max(1.0),
            "e={e}: eval loss drifted: {el} vs {ol}"
        );
    }
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn elastic_resume_rejects_indivisible_worker_counts() {
    let path = tmp_ckpt("reject");
    {
        let mut base = Trainer::new(base_cfg(4)).expect("base trainer");
        base.run_to(Some(KILL)).expect("base");
        base.save_checkpoint(&path).expect("checkpoint");
    }
    // 3 divides neither hs=256 nor heads=8 → rejected while building the
    // target trainer, with an explanatory error
    let err = Trainer::resume_from(base_cfg(3), &path).unwrap_err().to_string();
    assert!(err.contains("3"), "got: {err}");
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}
