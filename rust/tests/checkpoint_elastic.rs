//! Elastic resume suite (DESIGN.md §13): a checkpoint written by an
//! `E`-rank run restores onto a different worker count.
//!
//! Contract pinned here:
//! * **exactness** — re-partitioning is pure slicing: the full
//!   (TP-undone) model after an elastic restore is bitwise identical to
//!   the checkpointed one, for both shrink (4→2) and grow (4→8);
//! * **loss equivalence** — the post-resume loss trajectory matches the
//!   uninterrupted base run (the fresh-plan oracle at the original E)
//!   within f32 reduction-order tolerance: a different worker count
//!   changes partial-sum order, never the math;
//! * **validation** — indivisible worker counts are rejected up front.

use flextp::checkpoint::elastic::{gather_full, reshard_moments, reshard_state, shard_full};
use flextp::config::{RunCfg, TimeModel};
use flextp::model::{BlockShard, ModelState, RepParams};
use flextp::runtime::presets::synthesize_with_e;
use flextp::train::trainer::Trainer;

const EPOCHS: usize = 1;
const IPE: usize = 4;
const KILL: u64 = 2;

/// vit-s (hs=256, heads=8) run at e=4 — both 2 and 8 divide hs & heads.
fn base_cfg(e: usize) -> RunCfg {
    let mut cfg = RunCfg::new("vit-s");
    cfg.e_override = Some(e);
    cfg.train.threads = 1;
    cfg.train.epochs = EPOCHS;
    cfg.train.iters_per_epoch = IPE;
    cfg.train.eval_iters = 1;
    cfg.train.momentum = 0.9;
    cfg.train.time_model = TimeModel::Modeled;
    // calm + baseline: the oracle comparison isolates re-sharding from
    // balancing-policy divergence across worker counts
    cfg
}

fn tmp_ckpt(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("flextp_elastic_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.join(flextp::checkpoint::ckpt_filename(KILL))
}

#[test]
fn elastic_resume_repartitions_exactly_and_tracks_the_oracle() {
    // --- base 4-rank run: train to the kill point, checkpoint, then
    //     (as the oracle) keep going uninterrupted
    let path = tmp_ckpt("main");
    let mut base = Trainer::new(base_cfg(4)).expect("base trainer");
    base.run_to(Some(KILL)).expect("base to kill point");
    base.save_checkpoint(&path).expect("checkpoint");
    let full_at_kill = gather_full(&base.rt.manifest.model, &base.state);
    let oracle = base.run().expect("oracle continues uninterrupted");
    let oracle_tail = &oracle.loss_curve[KILL as usize..];

    for e in [2usize, 8] {
        let mut t = Trainer::resume_from(base_cfg(e), &path)
            .unwrap_or_else(|err| panic!("elastic resume e={e}: {err}"));
        assert_eq!(t.giter(), KILL);
        assert_eq!(t.model().e, e);
        // exactness: undoing the new partition reproduces the
        // checkpointed full model bit for bit
        let full = gather_full(&t.rt.manifest.model, &t.state);
        assert_eq!(full, full_at_kill, "e={e}: re-partition must round-trip exactly");
        // momentum moved with the weights: resharded buffers exist for
        // every shard key and the rep keys
        assert!(
            t.opt.buffer_count() > 0,
            "e={e}: momentum buffers must survive elastic resume"
        );
        // loss equivalence: same math, different f32 reduction order
        let r = t.run().expect("resumed run");
        assert_eq!(r.loss_curve.len(), oracle.loss_curve.len());
        let tail = &r.loss_curve[KILL as usize..];
        for (i, (a, b)) in tail.iter().zip(oracle_tail).enumerate() {
            assert!(a.is_finite(), "e={e}: loss {i} diverged");
            assert!(
                (a - b).abs() <= 5e-3 * b.abs().max(1.0),
                "e={e}: post-resume loss {i} drifted: resumed {a} vs oracle {b}"
            );
        }
        // the pre-kill history is carried over verbatim
        assert_eq!(
            &r.loss_curve[..KILL as usize],
            &oracle.loss_curve[..KILL as usize],
            "e={e}: restored loss history must be the checkpointed one"
        );
        // eval on the resharded model agrees with the oracle closely
        let (el, ol) = (r.epochs[0].eval_loss, oracle.epochs[0].eval_loss);
        assert!(
            (el - ol).abs() <= 5e-3 * ol.abs().max(1.0),
            "e={e}: eval loss drifted: {el} vs {ol}"
        );
    }
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

/// Every worker count that divides both hs and heads of a preset.
fn valid_es(name: &str) -> Vec<usize> {
    let m = synthesize_with_e(name, 1).expect("preset").model;
    (1..=m.heads.max(8)).filter(|&d| m.hs % d == 0 && m.heads % d == 0).collect()
}

/// Property (satellite 1): gather → shard → gather is bitwise identity
/// for **every** divisor chain E→E'→E'' of hs/heads — not just the
/// hand-picked 4→2/4→8 cases — on both preset geometries, covering
/// the sharded block tensors and the replicated params alike.
#[test]
fn gather_shard_roundtrip_is_identity_for_every_divisor_chain() {
    for name in ["vit-tiny", "vit-s"] {
        let es = valid_es(name);
        assert!(es.len() >= 3, "{name}: want a real divisor lattice, got {es:?}");
        for &e1 in &es {
            let m1 = synthesize_with_e(name, e1).expect("m1").model;
            let s1 = ModelState::init(&m1, 0xE1A57 ^ e1 as u64);
            let full1 = gather_full(&m1, &s1);
            for &e2 in &es {
                let m2 = synthesize_with_e(name, e2).expect("m2").model;
                let s2 = shard_full(&m2, &full1);
                let full2 = gather_full(&m2, &s2);
                assert_eq!(full2, full1, "{name}: {e1}→{e2} must round-trip bitwise");
                for &e3 in &es {
                    let m3 = synthesize_with_e(name, e3).expect("m3").model;
                    let full3 = gather_full(&m3, &reshard_state(&m2, &m3, &s2));
                    assert_eq!(full3, full1, "{name}: chain {e1}→{e2}→{e3} must be identity");
                }
            }
        }
    }
}

/// The same identity for optimizer moments: `reshard_moments` moves
/// momentum with the weights through any divisor chain and hands the
/// replicated `rep.*` buffers through untouched; a map without shard
/// moments (momentum = 0) must not invent any.
#[test]
fn moment_resharding_round_trips_through_every_divisor_chain() {
    let name = "vit-s";
    let es = valid_es(name);
    let m1 = synthesize_with_e(name, es[es.len() - 1]).expect("m1").model;
    // seeded, worker-distinct moment tensors with exactly the shard
    // shapes the optimizer would hold
    let proto = ModelState::init(&m1, 0x40417);
    let mut bufs = std::collections::BTreeMap::new();
    for w in 0..m1.e {
        for k in 0..m1.depth {
            for n in BlockShard::names() {
                bufs.insert(format!("{w}.{k}.{n}"), proto.shards[w][k].get(n).clone());
            }
        }
    }
    for n in RepParams::names() {
        bufs.insert(format!("rep.{n}"), proto.rep.get(n).clone());
    }
    let full1 = gather_full(&m1, &proto);
    for &e2 in &es {
        let m2 = synthesize_with_e(name, e2).expect("m2").model;
        let b2 = reshard_moments(&m1, &m2, &bufs);
        assert_eq!(
            b2.len(),
            m2.e * m2.depth * BlockShard::names().len() + RepParams::names().len(),
            "e={e2}: one buffer per shard key plus the rep passthrough"
        );
        for n in RepParams::names() {
            assert_eq!(b2[&format!("rep.{n}")], bufs[&format!("rep.{n}")], "rep.{n} verbatim");
        }
        for &e3 in &es {
            let m3 = synthesize_with_e(name, e3).expect("m3").model;
            let b3 = reshard_moments(&m2, &m3, &b2);
            // undo TP on the twice-resharded moments: still the original
            let mut s3 = ModelState::init(&m3, 1);
            for w in 0..m3.e {
                for k in 0..m3.depth {
                    for n in BlockShard::names() {
                        *s3.shards[w][k].get_mut(n) = b3[&format!("{w}.{k}.{n}")].clone();
                    }
                }
            }
            s3.rep = proto.rep.clone();
            assert_eq!(
                gather_full(&m3, &s3),
                full1,
                "moments chain {}→{e2}→{e3} must be identity",
                m1.e
            );
        }
    }
    // momentum-off: only rep buffers in, only rep buffers out
    let rep_only: std::collections::BTreeMap<_, _> =
        bufs.iter().filter(|(k, _)| k.starts_with("rep.")).map(|(k, v)| (k.clone(), v.clone())).collect();
    let m2 = synthesize_with_e(name, es[0]).expect("m2").model;
    let out = reshard_moments(&m1, &m2, &rep_only);
    assert_eq!(out.len(), RepParams::names().len(), "no shard moments may be invented");
    assert!(out.keys().all(|k| k.starts_with("rep.")));
}

#[test]
fn elastic_resume_rejects_indivisible_worker_counts() {
    let path = tmp_ckpt("reject");
    {
        let mut base = Trainer::new(base_cfg(4)).expect("base trainer");
        base.run_to(Some(KILL)).expect("base");
        base.save_checkpoint(&path).expect("checkpoint");
    }
    // 3 divides neither hs=256 nor heads=8 → rejected while building the
    // target trainer, with an explanatory error
    let err = Trainer::resume_from(base_cfg(3), &path).unwrap_err().to_string();
    assert!(err.contains("3"), "got: {err}");
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}
