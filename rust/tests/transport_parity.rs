//! Cross-transport parity suite (ISSUE 7, DESIGN.md §15).
//!
//! The tentpole contract: the collective transport is a pure data
//! plane.  Whether ranks are buffer slots in the coordinator (`inproc`)
//! or OS processes exchanging framed f32 payloads over localhost TCP
//! (`tcp`), the same train config must produce **bitwise identical**
//! observables — losses, per-epoch sim metrics (modulo wall time),
//! `CommStats::total_bytes` — at `--threads` 1 and 4 alike.  The wire
//! ranks reduce in the same fixed binary-tree association order as the
//! in-process stride loop, so determinism survives the socket.
//!
//! Also pinned: tcp-vs-tcp same-seed identity (the wire itself adds no
//! nondeterminism), and live elastic re-sharding under tcp (the group
//! respawn at a churn transition) matching the in-process run.

use flextp::config::{ReplanMode, RunCfg, StragglerPlan, Strategy, TimeModel, TransportKind};
use flextp::contention::ScenarioSpec;
use flextp::metrics::RunReport;
use flextp::train::trainer::Trainer;

/// vit-tiny (hs=128, heads=4, e=4), SEMI + online controller, momentum,
/// deterministic modeled clock, bursty tenant trace — the full dynamic
/// pipeline, so parity below covers a non-trivial plan.
fn parity_cfg(threads: usize, transport: TransportKind) -> RunCfg {
    let mut cfg = RunCfg::new("vit-tiny");
    cfg.train.threads = threads;
    cfg.train.epochs = 2;
    cfg.train.iters_per_epoch = 5;
    cfg.train.eval_iters = 2;
    cfg.train.momentum = 0.9;
    cfg.train.time_model = TimeModel::Modeled;
    cfg.train.transport = transport;
    // the harness binary is the test runner, not flextp — point rank
    // re-exec at the real binary Cargo built for this test run
    cfg.train.rank_exe = Some(env!("CARGO_BIN_EXE_flextp").into());
    cfg.balancer.strategy = Strategy::Semi;
    cfg.balancer.replan = ReplanMode::Online;
    cfg.balancer.forced_lambda = Some(1);
    cfg.stragglers = StragglerPlan::Scenario(
        ScenarioSpec::parse("burst:r1@x5:iters2-7,markov:r3@x2:p0.4-0.3,seed:9")
            .expect("scenario"),
    );
    cfg
}

type Observables = (RunReport, u64, u64, usize);

fn run(cfg: RunCfg) -> Observables {
    let mut t = Trainer::new(cfg).expect("trainer");
    let r = t.run().expect("run");
    (r, t.comm.stats.total_bytes(), t.comm.stats.allreduce_ops, t.model().e)
}

fn assert_bitwise(a: &Observables, b: &Observables, what: &str) {
    assert!(
        a.0.loss_curve.iter().all(|l| l.is_finite()),
        "{what}: diverged: {:?}",
        a.0.loss_curve
    );
    assert_eq!(a.0.loss_curve, b.0.loss_curve, "{what}: losses must be bitwise identical");
    assert!(a.0.sim_equal(&b.0), "{what}: per-epoch sim metrics must be bitwise identical");
    assert_eq!(a.1, b.1, "{what}: CommStats::total_bytes must match");
    assert_eq!(a.2, b.2, "{what}: all-reduce op counts must match");
    assert_eq!(a.3, b.3, "{what}: final worker counts must match");
}

#[test]
fn tcp_matches_inproc_bitwise_at_1_and_4_threads() {
    let mut per_thread = Vec::new();
    for threads in [1usize, 4] {
        let inproc = run(parity_cfg(threads, TransportKind::InProc));
        let tcp = run(parity_cfg(threads, TransportKind::Tcp));
        assert_bitwise(&inproc, &tcp, &format!("inproc vs tcp, threads={threads}"));
        per_thread.push(tcp);
    }
    // the 1-vs-4-thread parity contract holds over the wire too
    assert_bitwise(&per_thread[0], &per_thread[1], "tcp threads 1 vs 4");
    let tcp = &per_thread[0];
    assert_eq!(tcp.0.loss_curve.len(), 10, "every scheduled iteration ran");
    assert!(tcp.1 > 0, "the wire run must actually have moved bytes");
    // sanity: the burst tenant engaged the balancer, so the parity
    // above covered a non-trivial plan, not an idle matrix
    assert!(
        tcp.0.epochs.iter().map(|e| e.pruned_cols + e.migrated_cols).sum::<u64>() > 0,
        "no balancing engaged — the transport comparison would be vacuous"
    );
}

#[test]
fn tcp_same_seed_runs_are_identical() {
    let a = run(parity_cfg(1, TransportKind::Tcp));
    let b = run(parity_cfg(1, TransportKind::Tcp));
    assert_bitwise(&a, &b, "tcp vs tcp, same seed");
}

/// Scripted worker churn under tcp: the 4→2 re-shard tears the process
/// group down and `transition_to` respawns it at the new width — and
/// the whole run still matches the in-process elastic run bitwise.
#[test]
fn tcp_live_churn_matches_inproc() {
    let with_churn = |transport| {
        let mut cfg = parity_cfg(1, transport);
        cfg.train.epochs = 2;
        cfg.train.iters_per_epoch = 6;
        cfg.stragglers = StragglerPlan::Scenario(
            ScenarioSpec::parse(
                "fail:r3@iter4,join:r3@iter8,burst:r1@x5:iters2-9,markov:r3@x2:p0.4-0.3,seed:9",
            )
            .expect("scenario"),
        );
        cfg
    };
    let inproc = run(with_churn(TransportKind::InProc));
    let tcp = run(with_churn(TransportKind::Tcp));
    assert_bitwise(&inproc, &tcp, "live churn, inproc vs tcp");
    assert_eq!(tcp.3, 4, "join@8 must have re-grown the run to e=4 over the wire");
}
