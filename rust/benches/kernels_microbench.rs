//! GEMM micro-kernel benchmark + perf-regression gate.
//!
//! Times the packed micro-kernels against the frozen pre-PR scalar
//! reference on the vit preset shapes (dense + pruned, fwd + bwd) and
//! writes `BENCH_kernels.json` at the repository root — median GFLOP/s
//! per shape, serial and threaded.
//!
//! ```text
//! cargo bench --bench kernels_microbench                    # measure + write
//! cargo bench --bench kernels_microbench -- \
//!     --baseline BENCH_kernels.json --out BENCH_kernels.ci.json
//!     # ...and exit 1 if dense packed GFLOP/s regressed > 20%
//! ```
//!
//! Flags: `--model <preset>` (default vit-tiny), `--out <path>`,
//! `--baseline <path>`, `--max-regress <frac>` (default 0.20),
//! `--samples <n>` (default 5), `--target-ms <ms>` (default 25).
//! Relative paths resolve against the repository root.

use flextp::bench::kernels;

fn arg_value(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1)).cloned()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = arg_value(&args, "--model").unwrap_or_else(|| "vit-tiny".to_string());
    let out = arg_value(&args, "--out").unwrap_or_else(|| "BENCH_kernels.json".to_string());
    let baseline = arg_value(&args, "--baseline");
    let max_regress: f64 = arg_value(&args, "--max-regress")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.20);
    let samples: usize = arg_value(&args, "--samples")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(5);
    let target_ms: f64 = arg_value(&args, "--target-ms")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(25.0);

    eprintln!("kernels_microbench: model={model} samples={samples} target_ms={target_ms}");
    let doc = kernels::run_model(&model, samples, target_ms)?;

    // human-readable summary
    for s in doc.get("shapes")?.arr()? {
        let name = s.get("name")?.str()?;
        let serial = s.get("serial")?;
        let threaded = s.get("threaded")?;
        eprintln!(
            "  {name:<24} scalar {:>7.2} | packed {:>7.2} (x{:.2}) | thr {:>7.2} (x{:.2}) GF/s",
            serial.get("scalar_gflops")?.num()?,
            serial.get("packed_gflops")?.num()?,
            serial.get("speedup")?.num()?,
            threaded.get("packed_gflops")?.num()?,
            threaded.get("speedup")?.num()?,
        );
    }

    let out_path = kernels::resolve_path(&out);
    std::fs::write(&out_path, doc.to_string())?;
    eprintln!("wrote {}", out_path.display());

    if let Some(base) = baseline {
        let base_path = kernels::resolve_path(&base);
        let base_doc = kernels::load(&base_path)?;
        let violations = kernels::compare(&doc, &base_doc, max_regress)?;
        if violations.is_empty() {
            eprintln!(
                "regression gate: PASS (within {:.0}% of {})",
                max_regress * 100.0,
                base_path.display()
            );
        } else {
            eprintln!("regression gate: FAIL vs {}", base_path.display());
            for v in &violations {
                eprintln!("  {v}");
            }
            std::process::exit(1);
        }
    }
    Ok(())
}
