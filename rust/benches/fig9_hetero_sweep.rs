//! Paper Fig. 9: overall performance in heterogeneous environments —
//! ACC and RT vs straggling skewness χ ∈ {0, 2, 4, 8} for Baseline,
//! ZERO-Pri, ZERO-PriDiffE (empirical γ=½) and ZERO-PriDiffR (Eq. 1 γ).
//!
//! Expected shape: Baseline RT grows ~linearly in χ; the ZERO variants
//! keep RT roughly flat (the straggler catches up); PriDiffE trades some
//! of that efficiency for a smaller ACC loss; PriDiffR is the preferred
//! enhancement (≈Pri RT, comparable or better ACC).
//!
//! Set `FLEXTP_THREADS=N` to run the simulated ranks concurrently (same
//! numbers, lower wall-clock) — e.g. `FLEXTP_THREADS=0` for all cores.

use flextp::bench::{bench_cfg, out_dir, run};
use flextp::config::{StragglerPlan, Strategy};
use flextp::util::table::TextTable;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("FLEXTP_BENCH_MODEL").unwrap_or("vit-tiny".into());
    let chis = [0.0, 2.0, 4.0, 8.0];
    let strategies = [
        Strategy::Baseline,
        Strategy::ZeroPri,
        Strategy::ZeroPriDiffE,
        Strategy::ZeroPriDiffR,
    ];
    let mut table = TextTable::new(
        &format!("Fig. 9 — hetero sweep ({model}): RT / ACC vs χ"),
        &["solution", "χ=0", "χ=2", "χ=4", "χ=8"],
    );
    let mut baseline_rt = Vec::new();
    for s in strategies {
        let mut rt_row = vec![format!("{} RT", s.name())];
        let mut acc_row = vec![format!("{} ACC", s.name())];
        for (i, &chi) in chis.iter().enumerate() {
            let mut cfg = bench_cfg(&model, s);
            cfg.train.epochs = 2;
            cfg.train.iters_per_epoch = 3;
            if chi > 0.0 {
                cfg.stragglers = StragglerPlan::RoundRobin { chi, period_epochs: 1 };
            }
            let r = run(cfg)?;
            eprintln!("  {} χ={chi}: {}", s.name(), r.summary());
            if s == Strategy::Baseline {
                baseline_rt.push(r.rt());
                rt_row.push(format!("{:.3}s", r.rt()));
            } else {
                rt_row.push(format!(
                    "{:.3}s ({:.1}x)",
                    r.rt(),
                    baseline_rt[i] / r.rt().max(1e-12)
                ));
            }
            acc_row.push(format!("{:.1}%", 100.0 * r.best_acc()));
        }
        table.row(&rt_row);
        table.row(&acc_row);
    }
    println!("{}", table.render());
    table.write_csv(&out_dir().join("fig9_hetero_sweep.csv"))?;
    println!(
        "expected shape (paper): Baseline RT ~linear in χ; ZERO variants flat;\n\
         at χ=8 Pri speedup ≈3.5x with small ACC loss; PriDiffE trades speed\n\
         for ACC; PriDiffR ≈ Pri RT with comparable/better ACC."
    );
    Ok(())
}
