//! Paper Fig. 5: homogeneous-cluster overall performance (ViT-1B scale
//! point → vit-s, DESIGN.md §2): ACC and RT for Baseline / ZERO-Rd /
//! ZERO-Pri at γ ∈ {¼, ½, ~9/10} pruned on EVERY worker.
//!
//! Expected shape: RT falls as γ grows (less GEMM work); ACC falls with
//! γ; ZERO-Pri loses less ACC than ZERO-Rd at equal RT.

use flextp::bench::{bench_cfg, out_dir, run};
use flextp::config::Strategy;
use flextp::util::table::TextTable;

fn sweep(model: &str, title: &str, csv: &str) -> anyhow::Result<()> {
    let gammas = [0.25, 0.5, 0.875];
    let mut table = TextTable::new(
        title,
        &["solution", "γ", "best ACC", "eval loss", "RT (s/epoch)"],
    );
    let base = run(bench_cfg(model, Strategy::Baseline))?;
    eprintln!("  {}", base.summary());
    table.row(&[
        "Baseline".into(),
        "0".into(),
        format!("{:.1}%", 100.0 * base.best_acc()),
        format!("{:.3}", base.final_eval_loss()),
        format!("{:.3}", base.rt()),
    ]);
    for strategy in [Strategy::ZeroRd, Strategy::ZeroPri] {
        for &g in &gammas {
            let mut cfg = bench_cfg(model, strategy);
            cfg.balancer.gamma_override = Some(g);
            let r = run(cfg)?;
            eprintln!("  {} γ={g}: {}", strategy.name(), r.summary());
            table.row(&[
                strategy.name().to_string(),
                format!("{g}"),
                format!("{:.1}%", 100.0 * r.best_acc()),
                format!("{:.3}", r.final_eval_loss()),
                format!("{:.3}", r.rt()),
            ]);
        }
    }
    println!("{}", table.render());
    table.write_csv(&out_dir().join(csv))?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let model = std::env::var("FLEXTP_BENCH_MODEL").unwrap_or("vit-tiny".into());
    sweep(
        &model,
        &format!("Fig. 5 — homogeneous ACC+RT vs γ ({model}, ViT-1B scale point; FLEXTP_BENCH_MODEL=vit-s for paper scale)"),
        "fig5_homog.csv",
    )?;
    println!(
        "expected shape (paper): RT decreases with γ; ACC loss grows with γ;\n\
         Pri narrows Rd's accuracy loss at nearly-zero runtime penalty."
    );
    Ok(())
}
