//! Paper Fig. 10: scalability with a single straggler — ACC delta (vs
//! Baseline) and RT against χ for Baseline, MIG, ZERO-PriDiffR, SEMI.
//!
//! Expected shape: Baseline RT grows linearly with χ (waiting cost);
//! MIG mitigates but cannot fully catch up at large χ (its migratable
//! share is capped by the FFN fraction, and migration itself costs
//! communication); ZERO-PriDiffR and SEMI stay near-flat; SEMI's ACC
//! stays near Baseline's (migration is exact) while pure resizing loses
//! more.

use flextp::bench::{acc_delta_pp, bench_cfg, out_dir, run};
use flextp::config::{StragglerPlan, Strategy};
use flextp::util::table::TextTable;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("FLEXTP_BENCH_MODEL").unwrap_or("vit-tiny".into());
    let chis = [0.0, 2.0, 4.0, 8.0];
    let strategies =
        [Strategy::Baseline, Strategy::Mig, Strategy::ZeroPriDiffR, Strategy::Semi];
    let mut table = TextTable::new(
        &format!("Fig. 10 — single straggler ({model}): RT and ΔACC vs χ"),
        &["solution", "metric", "χ=0", "χ=2", "χ=4", "χ=8"],
    );
    let mut baselines = Vec::new();
    for s in strategies {
        let mut rts = vec![s.name().to_string(), "RT (s)".into()];
        let mut dacc = vec![s.name().to_string(), "ΔACC (pp)".into()];
        for (i, &chi) in chis.iter().enumerate() {
            let mut cfg = bench_cfg(&model, s);
            cfg.train.epochs = 2;
            cfg.train.iters_per_epoch = 3;
            if chi > 0.0 {
                // fixed single straggler (rank 0) — the paper's Fig. 10 setup
                cfg.stragglers = StragglerPlan::Fixed(vec![chi]);
            }
            let r = run(cfg)?;
            eprintln!("  {} χ={chi}: {}", s.name(), r.summary());
            rts.push(format!("{:.3}", r.rt()));
            if s == Strategy::Baseline {
                baselines.push(r.clone());
                dacc.push("0.0".into());
            } else {
                dacc.push(format!("{:+.1}", acc_delta_pp(&r, &baselines[i])));
            }
        }
        table.row(&rts);
        table.row(&dacc);
    }
    println!("{}", table.render());
    table.write_csv(&out_dir().join("fig10_single_straggler.csv"))?;
    println!(
        "expected shape (paper): Baseline RT linear in χ; MIG mitigates but\n\
         lags at high χ; PriDiffR+SEMI scale flat; SEMI keeps ACC closest\n\
         to Baseline."
    );
    Ok(())
}
