//! Paper Fig. 3: imputation policy (Same / Average / Zero) vs ACC at
//! uniform γ=0.5 pruning on every worker.  Expected shape: Same ≥ Zero ≥
//! Average on accuracy; Same pays a previous-gradient memory copy.

use flextp::bench::{bench_cfg, out_dir, run};
use flextp::config::{Imputation, Strategy};
use flextp::util::table::TextTable;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("FLEXTP_BENCH_MODEL").unwrap_or("vit-tiny".into());
    let mut table = TextTable::new(
        &format!("Fig. 3 — imputation policy vs ACC (γ=0.5, {model})"),
        &["policy", "best ACC", "final eval loss", "RT (s/epoch)"],
    );
    for (imp, name) in [
        (Imputation::Same, "Same"),
        (Imputation::Average, "Average"),
        (Imputation::Zero, "Zero"),
    ] {
        let mut cfg = bench_cfg(&model, Strategy::ZeroPri);
        cfg.balancer.imputation = imp;
        cfg.balancer.gamma_override = Some(0.5);
        cfg.train.epochs = 4;
        let r = run(cfg)?;
        eprintln!("  {name}: {}", r.summary());
        table.row(&[
            name.to_string(),
            format!("{:.1}%", 100.0 * r.best_acc()),
            format!("{:.3}", r.final_eval_loss()),
            format!("{:.3}", r.rt()),
        ]);
    }
    println!("{}", table.render());
    table.write_csv(&out_dir().join("fig3_imputation.csv"))?;
    println!("expected shape (paper): Same best ACC (at memory cost), Zero beats Average.");
    Ok(())
}
