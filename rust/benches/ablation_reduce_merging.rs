//! Ablation (DESIGN.md design-choice list): the paper's reduce-merging
//! optimization (§IV-A) — folding the migration result collection into
//! the branch all-reduce — measured by running the same broadcast-reduce
//! migration with merging ON vs OFF (OFF pays an explicit tree-reduce of
//! the full [b,s,hs] partials back to the straggler, "transferred two
//! times" as the paper puts it).

use flextp::bench::{forced_migration_rt, out_dir};
use flextp::config::MigPolicy;
use flextp::util::table::TextTable;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("FLEXTP_BENCH_MODEL").unwrap_or("vit-tiny".into());
    let gbps: f64 = std::env::var("FLEXTP_BENCH_NET_GBPS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(0.25);
    let gammas = [0.25, 0.5, 0.875];
    let mut table = TextTable::new(
        &format!("Ablation — reduce-merging ({model}, ν=1, {gbps} Gbps, sim s/epoch)"),
        &["variant / γ", "0.25", "0.50", "0.88"],
    );
    for (merging, label) in [(true, "merged (paper §IV-A)"), (false, "unmerged (2x transfer)")] {
        let mut row = vec![label.to_string()];
        for &g in &gammas {
            let rt = forced_migration_rt(
                &model, 1, g, MigPolicy::BroadcastReduce, merging, Some(gbps))?;
            row.push(format!("{rt:.3}"));
            eprintln!("  {label} γ={g}: {rt:.3}s");
        }
        table.row(&row);
    }
    println!("{}", table.render());
    table.write_csv(&out_dir().join("ablation_reduce_merging.csv"))?;
    println!(
        "expected shape: merging strictly cheaper — the unmerged variant\n\
         re-sends every receiver's full [b,s,hs] partial to the straggler\n\
         before the all-reduce sends it again."
    );
    Ok(())
}
