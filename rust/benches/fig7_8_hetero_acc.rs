//! Paper Figs. 7 & 8: accuracy variation under a fixed-skewness straggler
//! (χ=2, rotating round-robin) as the forced pruning ratio γ varies —
//! ViT-1B (vit-s) and ViT-3B (vit-m) scale points.
//!
//! Expected shape: ACC loss is much smaller than the homogeneous Fig. 5/6
//! sweeps at equal γ, because only ONE worker (the straggler) prunes
//! instead of all of them.

use flextp::bench::{bench_cfg, out_dir, run};
use flextp::config::{StragglerPlan, Strategy};
use flextp::util::table::TextTable;

fn sweep(model: &str, fig: &str, csv: &str) -> anyhow::Result<()> {
    let gammas = [0.25, 0.5, 0.875];
    let mut table = TextTable::new(
        &format!("{fig} — hetero ACC vs γ, χ=2 ({model})"),
        &["solution", "γ", "best ACC", "eval loss", "RT (s/epoch)"],
    );
    let mut cfg = bench_cfg(model, Strategy::Baseline);
    cfg.stragglers = StragglerPlan::RoundRobin { chi: 2.0, period_epochs: 1 };
    let base = run(cfg)?;
    table.row(&[
        "Baseline".into(),
        "0".into(),
        format!("{:.1}%", 100.0 * base.best_acc()),
        format!("{:.3}", base.final_eval_loss()),
        format!("{:.3}", base.rt()),
    ]);
    for &g in &gammas {
        let mut cfg = bench_cfg(model, Strategy::ZeroPri);
        cfg.stragglers = StragglerPlan::RoundRobin { chi: 2.0, period_epochs: 1 };
        cfg.balancer.gamma_override = Some(g);
        let r = run(cfg)?;
        eprintln!("  ZERO-Pri γ={g}: {}", r.summary());
        table.row(&[
            "ZERO-Pri".into(),
            format!("{g}"),
            format!("{:.1}%", 100.0 * r.best_acc()),
            format!("{:.3}", r.final_eval_loss()),
            format!("{:.3}", r.rt()),
        ]);
    }
    println!("{}", table.render());
    table.write_csv(&out_dir().join(csv))?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let m7 = std::env::var("FLEXTP_BENCH_MODEL7").unwrap_or("vit-tiny".into());
    let m8 = std::env::var("FLEXTP_BENCH_MODEL8").unwrap_or("vit-s".into());
    sweep(&m7, "Fig. 7", "fig7_hetero_acc.csv")?;
    sweep(&m8, "Fig. 8", "fig8_hetero_acc.csv")?;
    println!(
        "expected shape (paper): accuracy loss shrinks vs the homogeneous\n\
         sweep — pruning happens on the one straggler only."
    );
    Ok(())
}
