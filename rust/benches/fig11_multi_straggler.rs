//! Paper Fig. 11: multi-straggler scalability — half the workers straggle
//! at χ = {8, 6, 4, 2}; SEMI's migration-group size λ is forced from 0
//! (pure ZERO-PriDiffR) to z (pure MIG), sweeping the hybrid split.
//!
//! Expected shape: an interior sweet spot — λ=0 loses accuracy (all
//! resizing), λ=z loses efficiency (all migration overloads receivers);
//! the cost-model pick (`auto`) should land near the best λ.

use flextp::bench::{bench_cfg, out_dir, run};
use flextp::config::{StragglerPlan, Strategy};
use flextp::util::table::TextTable;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("FLEXTP_BENCH_MODEL").unwrap_or("vit-tiny".into());
    // half the group straggles with descending skewness (paper: 8,6,4,2
    // on 8 GPUs; scaled to the model's e)
    let probe = bench_cfg(&model, Strategy::Semi);
    let e = flextp::runtime::Manifest::load_or_synthesize(&probe.model_dir(), &model)?.model.e;
    let z = e / 2;
    let chis: Vec<f64> = (0..z).map(|i| 8.0 - 2.0 * i as f64).map(|c| c.max(2.0)).collect();

    let mut table = TextTable::new(
        &format!("Fig. 11 — multi-straggler ({model}, χ per straggler {chis:?})"),
        &["λ (MIG group size)", "RT (s/epoch)", "best ACC", "eval loss"],
    );
    for lambda in 0..=z {
        let mut cfg = bench_cfg(&model, Strategy::Semi);
        cfg.train.epochs = 2;
        cfg.train.iters_per_epoch = 3;
        cfg.stragglers = StragglerPlan::Fixed(chis.clone());
        cfg.balancer.forced_lambda = Some(lambda);
        let r = run(cfg)?;
        eprintln!("  λ={lambda}: {}", r.summary());
        table.row(&[
            format!("{lambda}"),
            format!("{:.3}", r.rt()),
            format!("{:.1}%", 100.0 * r.best_acc()),
            format!("{:.3}", r.final_eval_loss()),
        ]);
    }
    // the cost-model's own choice (Eq. 3)
    let mut cfg = bench_cfg(&model, Strategy::Semi);
    cfg.train.epochs = 2;
    cfg.train.iters_per_epoch = 3;
    cfg.stragglers = StragglerPlan::Fixed(chis.clone());
    let r = run(cfg)?;
    table.row(&[
        "auto (Eq. 3)".to_string(),
        format!("{:.3}", r.rt()),
        format!("{:.1}%", 100.0 * r.best_acc()),
        format!("{:.3}", r.final_eval_loss()),
    ]);
    println!("{}", table.render());
    table.write_csv(&out_dir().join("fig11_multi_straggler.csv"))?;
    println!(
        "expected shape (paper): extremes degrade to pure ZERO (λ=0) and pure\n\
         MIG (λ=z); the sweet spot is interior and Eq. 3 lands near it."
    );
    Ok(())
}
