//! Paper Fig. 6: the Fig. 5 homogeneous sweep at the larger scale point
//! (ViT-3B → vit-m).  Same expected shape; larger model, so the same γ
//! saves more absolute time.

use flextp::bench::{bench_cfg, out_dir, run};
use flextp::config::Strategy;
use flextp::util::table::TextTable;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("FLEXTP_BENCH_MODEL").unwrap_or("vit-s".into());
    let gammas = [0.25, 0.5, 0.875];
    let mut table = TextTable::new(
        &format!("Fig. 6 — homogeneous ACC+RT vs γ ({model}, ViT-3B scale point)"),
        &["solution", "γ", "best ACC", "eval loss", "RT (s/epoch)"],
    );
    let mut base = bench_cfg(&model, Strategy::Baseline);
    base.train.epochs = 2;
    let base = run(base)?;
    eprintln!("  {}", base.summary());
    table.row(&[
        "Baseline".into(),
        "0".into(),
        format!("{:.1}%", 100.0 * base.best_acc()),
        format!("{:.3}", base.final_eval_loss()),
        format!("{:.3}", base.rt()),
    ]);
    for strategy in [Strategy::ZeroRd, Strategy::ZeroPri] {
        for &g in &gammas {
            let mut cfg = bench_cfg(&model, strategy);
            cfg.train.epochs = 2;
            cfg.balancer.gamma_override = Some(g);
            let r = run(cfg)?;
            eprintln!("  {} γ={g}: {}", strategy.name(), r.summary());
            table.row(&[
                strategy.name().to_string(),
                format!("{g}"),
                format!("{:.1}%", 100.0 * r.best_acc()),
                format!("{:.3}", r.final_eval_loss()),
                format!("{:.3}", r.rt()),
            ]);
        }
    }
    println!("{}", table.render());
    table.write_csv(&out_dir().join("fig6_homog.csv"))?;
    println!("expected shape: as Fig. 5, at the larger scale point.");
    Ok(())
}
