//! Paper Table I: "Runtime comparison of different migration policies".
//!
//! Homogeneous cluster; ν selected workers migrate a γ-fraction of their
//! FFN contraction to the others, under broadcast-reduce (tree, with
//! reduce-merging — the paper's design) vs scatter-gather (flat, explicit
//! result collection).  Expected shape: broadcast-reduce wins everywhere,
//! RT grows with γ (migration is not free), and the gap narrows as ν
//! grows (fewer receivers → tree advantage shrinks).

use flextp::bench::{forced_migration_rt, out_dir};
use flextp::config::MigPolicy;
use flextp::util::table::TextTable;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("FLEXTP_BENCH_MODEL").unwrap_or("vit-tiny".into());
    // The paper's Table I regime is COMM-dominated (V100s move MBs per
    // migration). The scaled-down models move ~100 KB, so the modeled
    // interconnect is scaled down proportionally (default 0.25 Gbps for
    // the tiny scale point) to preserve the comm/compute ratio; override
    // with FLEXTP_BENCH_NET_GBPS (e.g. 12 for raw PCIe 3.0).
    let gbps: f64 = std::env::var("FLEXTP_BENCH_NET_GBPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);
    let gammas = [0.0, 0.25, 0.5, 0.75, 0.875];
    let mut table = TextTable::new(
        &format!("Table I — migration policy runtime, {model}, {gbps} Gbps (sim s/epoch)"),
        &["policy(ν) / γ", "0.00", "0.25", "0.50", "0.75", "0.88"],
    );
    for nu in [1usize, 4] {
        for (policy, merging, label) in [
            (MigPolicy::BroadcastReduce, true, "broadcast-reduce"),
            (MigPolicy::ScatterGather, false, "scatter-gather"),
        ] {
            let mut row = vec![format!("{label}({nu})")];
            for &g in &gammas {
                let rt = forced_migration_rt(&model, nu, g, policy, merging, Some(gbps))?;
                row.push(format!("{rt:.3}"));
                eprintln!("  {label}({nu}) γ={g}: {rt:.3}s");
            }
            table.row(&row);
        }
    }
    println!("{}", table.render());
    table.write_csv(&out_dir().join("table1_migration.csv"))?;
    println!(
        "expected shape (paper): broadcast-reduce < scatter-gather at every γ>0;\n\
         both grow with γ; the gap narrows as ν rises from 1 to 4."
    );
    Ok(())
}
