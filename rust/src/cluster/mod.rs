//! The simulated worker group: rank topology + virtual clocks.
//!
//! DESIGN.md §6.5: the testbed has one CPU core and the PJRT handles are
//! `!Send`, so the group is a deterministic lock-step engine.  Each rank
//! owns a virtual clock; real executable timings and modeled communication
//! costs are *charged* to clocks, and a straggler with skewness χ is
//! charged `χ·t_compute` (the paper injects sleeps to the same effect —
//! `--emulate-wall` mode in the trainer really sleeps).

/// Per-rank virtual clocks (seconds).
#[derive(Debug, Clone)]
pub struct Clocks {
    pub(crate) t: Vec<f64>,
    /// per-rank cumulative compute time this iteration (the paper's M_i
    /// numerator bookkeeping is done by the trainer; this is T_i support)
    pub(crate) iter_compute: Vec<f64>,
}

impl Clocks {
    pub fn new(e: usize) -> Clocks {
        Clocks { t: vec![0.0; e], iter_compute: vec![0.0; e] }
    }

    pub fn e(&self) -> usize {
        self.t.len()
    }

    pub fn now(&self, rank: usize) -> f64 {
        self.t[rank]
    }

    /// Charge compute time to one rank.
    pub fn advance(&mut self, rank: usize, dt: f64) {
        debug_assert!(dt >= 0.0, "negative time charge");
        self.t[rank] += dt;
        self.iter_compute[rank] += dt;
    }

    /// Charge communication time (not counted as compute).
    pub fn advance_comm(&mut self, rank: usize, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.t[rank] += dt;
    }

    /// Synchronization barrier: everyone waits for the slowest — the
    /// waiting cost the paper's balancing eliminates.  Returns the
    /// barrier time.
    pub fn barrier(&mut self) -> f64 {
        let max = self.t.iter().cloned().fold(0.0, f64::max);
        for t in &mut self.t {
            *t = max;
        }
        max
    }

    /// Barrier over a subset of ranks.  An empty subset is a no-op that
    /// reports the current clock frontier ([`Clocks::max`]) — it used to
    /// return a bogus 0.0, which callers would treat as a barrier time.
    pub fn barrier_of(&mut self, ranks: &[usize]) -> f64 {
        if ranks.is_empty() {
            return self.max();
        }
        let max = ranks.iter().map(|&r| self.t[r]).fold(0.0, f64::max);
        for &r in ranks {
            self.t[r] = max;
        }
        max
    }

    /// Max clock across ranks (current epoch RT readout).
    pub fn max(&self) -> f64 {
        self.t.iter().cloned().fold(0.0, f64::max)
    }

    /// Reset clocks (new measurement window); keeps rank count.
    pub fn reset(&mut self) {
        self.t.fill(0.0);
        self.iter_compute.fill(0.0);
    }

    /// Take and clear per-rank compute accumulated since the last call —
    /// feeds the straggler monitor's T_i / M_i statistics.
    pub fn take_iter_compute(&mut self) -> Vec<f64> {
        let out = self.iter_compute.clone();
        self.iter_compute.fill(0.0);
        out
    }
}

/// Virtual rank renumbering for migration column assignment (paper §IV-B):
/// with straggler at rank `r_k`, a normal task `r_i` gets
/// `r' = (r_i + e - r_k) % e` ∈ [1, e-1].
pub fn renumber(r_i: usize, r_k: usize, e: usize) -> usize {
    (r_i + e - r_k) % e
}

/// The migrated-column range for normal task `r_i` (paper §IV-B):
/// each of the e-1 normal tasks processes m = L_mig/(e-1) columns,
/// task with new rank r' takes [m(r'-1), m·r').  A remainder (when
/// (e-1) ∤ L_mig) is spread one extra column to the lowest new ranks.
pub fn mig_range(r_i: usize, r_k: usize, e: usize, l_mig: usize) -> (usize, usize) {
    debug_assert_ne!(r_i, r_k);
    let rp = renumber(r_i, r_k, e); // 1..=e-1
    let n = e - 1;
    let base = l_mig / n;
    let extra = l_mig % n;
    // new ranks 1..=extra get (base+1), the rest get base
    let idx = rp - 1;
    let start = if idx < extra {
        idx * (base + 1)
    } else {
        extra * (base + 1) + (idx - extra) * base
    };
    let len = if idx < extra { base + 1 } else { base };
    (start, start + len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_waits_for_slowest() {
        let mut c = Clocks::new(3);
        c.advance(0, 1.0);
        c.advance(1, 5.0);
        c.advance(2, 2.0);
        assert_eq!(c.barrier(), 5.0);
        for r in 0..3 {
            assert_eq!(c.now(r), 5.0);
        }
    }

    #[test]
    fn subset_barrier_leaves_others() {
        let mut c = Clocks::new(3);
        c.advance(0, 1.0);
        c.advance(1, 5.0);
        c.barrier_of(&[0, 1]);
        assert_eq!(c.now(0), 5.0);
        assert_eq!(c.now(2), 0.0);
    }

    #[test]
    fn empty_subset_barrier_is_noop_and_reports_frontier() {
        // regression: barrier_of(&[]) returned 0.0 instead of the frontier
        let mut c = Clocks::new(3);
        c.advance(1, 4.0);
        assert_eq!(c.barrier_of(&[]), 4.0);
        assert_eq!(c.now(0), 0.0, "no clock may move on an empty barrier");
        assert_eq!(c.now(1), 4.0);
        assert_eq!(c.now(2), 0.0);
    }

    #[test]
    fn iter_compute_excludes_comm() {
        let mut c = Clocks::new(2);
        c.advance(0, 1.0);
        c.advance_comm(0, 10.0);
        let m = c.take_iter_compute();
        assert_eq!(m[0], 1.0);
        assert_eq!(c.now(0), 11.0);
        assert_eq!(c.take_iter_compute(), vec![0.0, 0.0]);
    }

    #[test]
    fn renumber_is_paper_example() {
        // paper: e=3, straggler rank 1 (1-indexed task-1 → 0-indexed 0);
        // our 0-indexed version: straggler r_k, normal r_i.
        // task-2 (idx 1) with straggler idx 0: r' = 1; m=1 → first column.
        assert_eq!(renumber(1, 0, 3), 1);
        assert_eq!(renumber(2, 0, 3), 2);
        assert_eq!(mig_range(1, 0, 3, 2), (0, 1));
        assert_eq!(mig_range(2, 0, 3, 2), (1, 2));
    }

    #[test]
    fn renumber_is_bijection() {
        for e in 2..9 {
            for rk in 0..e {
                let mut seen = vec![false; e];
                for ri in 0..e {
                    if ri == rk {
                        continue;
                    }
                    let rp = renumber(ri, rk, e);
                    assert!(rp >= 1 && rp < e);
                    assert!(!seen[rp], "collision");
                    seen[rp] = true;
                }
            }
        }
    }

    #[test]
    fn mig_ranges_tile_exactly() {
        for e in 2..9 {
            for rk in 0..e {
                for l in [0usize, 1, 7, 64, 129] {
                    let mut covered = vec![false; l];
                    for ri in (0..e).filter(|&r| r != rk) {
                        let (s, t) = mig_range(ri, rk, e, l);
                        for c in s..t {
                            assert!(!covered[c], "overlap at {c}");
                            covered[c] = true;
                        }
                    }
                    assert!(covered.iter().all(|&b| b), "gap for e={e} l={l}");
                }
            }
        }
    }
}
