//! # flextp — Flexible Workload Control for Heterogeneous Tensor Parallelism
//!
//! A Rust + JAX + Pallas reproduction of *"Accelerating Heterogeneous
//! Tensor Parallelism via Flexible Workload Control"* (Wang et al., 2024):
//! 1D tensor-parallel ViT training with three dynamic workload-balancing
//! solutions —
//!
//! * **ZERO-resizing** ([`resizing`]): temporarily shrink the contraction
//!   dimension of the straggler's GEMMs (Eq. 1), with lineage tracking,
//!   Zero/Average/Same imputation, priority column selection, and
//!   per-layer differentiated ratios;
//! * **lightweight migration** ([`migration`]): move FFN column slices to
//!   normal tasks over tree broadcast/reduce with reduce-merging;
//! * **SEMI-migration** ([`semi`]): the hybrid that splits balancing work
//!   between the two by the cost model (Eq. 2 / Eq. 3).
//!
//! Architecture (see DESIGN.md): Layer 1 is a Pallas `pruned_matmul`
//! kernel, Layer 2 the JAX shard programs, both AOT-compiled to HLO text
//! by `python/compile/aot.py`; this crate is Layer 3 — the coordinator
//! that owns the training loop, collectives, scheduling, and balancing.
//! Executables run through a pluggable [`runtime::Backend`]: the default
//! **native** backend implements every role in pure Rust (no Python, no
//! XLA, no artifacts — `cargo run -- train` works from a clean checkout),
//! while `--features pjrt` loads the AOT artifacts through PJRT.  Python
//! never runs at training time.

// Numeric-kernel idiom: index-heavy loops over row-major buffers are the
// clearest way to express the GEMM/layernorm/attention dataflows here.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod balancer;
pub mod bench;
pub mod checkpoint;
pub mod cluster;
pub mod collectives;
pub mod config;
pub mod contention;
pub mod data;
pub mod memory;
pub mod metrics;
pub mod migration;
pub mod model;
pub mod resizing;
pub mod runtime;
pub mod semi;
pub mod straggler;
pub mod tensor;
pub mod trace;
pub mod train;
pub mod util;
