//! Configuration system: everything a run needs, assembled from presets,
//! key=value config files, and CLI overrides (clap is unavailable offline;
//! `parse_kv_args` provides `--key value` / `--key=value` parsing).

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::contention::control::ControlCfg;
use crate::contention::ScenarioSpec;
use crate::runtime::manifest::Degrees;

/// Per-component TP degree overrides (`--e-embed/--e-attn/--e-mlp/
/// --e-head`, DESIGN.md §18).  Unset components fall back to the
/// effective global `e` (after `--e`), with attention additionally
/// clamped to a whole-head divisor by the manifest synthesis.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DegreeOverrides {
    pub embed: Option<usize>,
    pub attn: Option<usize>,
    pub mlp: Option<usize>,
    pub head: Option<usize>,
}

impl DegreeOverrides {
    pub fn any(&self) -> bool {
        self.embed.is_some() || self.attn.is_some() || self.mlp.is_some() || self.head.is_some()
    }

    /// Concrete degree vector over `e` workers: overridden components as
    /// requested, the rest uniform at `e`.
    pub fn resolve(&self, e: usize) -> Degrees {
        Degrees {
            embed: self.embed.unwrap_or(e),
            attn: self.attn.unwrap_or(e),
            mlp: self.mlp.unwrap_or(e),
            head: self.head.unwrap_or(e),
        }
    }
}

/// Which execution backend runs the manifest executables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust implementations; runs from a clean checkout (default).
    Native,
    /// AOT HLO artifacts through PJRT (`--features pjrt` + `make artifacts`).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        Ok(match s {
            "native" => BackendKind::Native,
            "pjrt" => BackendKind::Pjrt,
            _ => bail!("unknown backend '{s}' (native|pjrt)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Which balancing solution runs — the paper's compared systems (§V-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Colossal-AI 1D TP as-is: no balancing, stragglers stall the group.
    Baseline,
    /// ZERO-resizing, random column selection (paper ZERO-Rd).
    ZeroRd,
    /// ZERO-resizing, priority selection (paper ZERO-Pri).
    ZeroPri,
    /// Pri + differentiated per-layer ratios, empirical uniform γ=1/2
    /// (paper ZERO-PriDiffE).
    ZeroPriDiffE,
    /// Pri + differentiated ratios, Eq.(1) uniform γ (paper ZERO-PriDiffR).
    ZeroPriDiffR,
    /// Pure lightweight migration (paper MIG).
    Mig,
    /// The hybrid SEMI-migration (paper SEMI).
    Semi,
}

impl Strategy {
    pub fn parse(s: &str) -> Result<Strategy> {
        Ok(match s {
            "baseline" => Strategy::Baseline,
            "zero-rd" => Strategy::ZeroRd,
            "zero-pri" => Strategy::ZeroPri,
            "zero-pridiff-e" => Strategy::ZeroPriDiffE,
            "zero-pridiff-r" => Strategy::ZeroPriDiffR,
            "mig" => Strategy::Mig,
            "semi" => Strategy::Semi,
            _ => bail!("unknown strategy '{s}' (baseline|zero-rd|zero-pri|\
                        zero-pridiff-e|zero-pridiff-r|mig|semi)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Baseline => "Baseline",
            Strategy::ZeroRd => "ZERO-Rd",
            Strategy::ZeroPri => "ZERO-Pri",
            Strategy::ZeroPriDiffE => "ZERO-PriDiffE",
            Strategy::ZeroPriDiffR => "ZERO-PriDiffR",
            Strategy::Mig => "MIG",
            Strategy::Semi => "SEMI",
        }
    }
}

/// Imputation policy for missing gradient dimensions (paper Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Imputation {
    /// Uniform zeros — the paper's chosen compromise.
    Zero,
    /// Per-column average of unpruned dimensions.
    Average,
    /// Same values as the previous iteration (accuracy-best, memory-worst).
    Same,
}

impl Imputation {
    pub fn parse(s: &str) -> Result<Imputation> {
        Ok(match s {
            "zero" => Imputation::Zero,
            "average" => Imputation::Average,
            "same" => Imputation::Same,
            _ => bail!("unknown imputation '{s}' (zero|average|same)"),
        })
    }
}

/// Migration communication primitive pair (paper Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigPolicy {
    /// Tree broadcast + (merged) reduce — the paper's choice.
    BroadcastReduce,
    /// Flat scatter + gather — the conventional baseline.
    ScatterGather,
}

impl MigPolicy {
    pub fn parse(s: &str) -> Result<MigPolicy> {
        Ok(match s {
            "broadcast-reduce" => MigPolicy::BroadcastReduce,
            "scatter-gather" => MigPolicy::ScatterGather,
            _ => bail!("unknown migration policy '{s}'"),
        })
    }
}

/// How stragglers are injected (paper §V-A: sleeping operations, skewness χ).
#[derive(Debug, Clone, PartialEq)]
pub enum StragglerPlan {
    /// Homogeneous cluster.
    None,
    /// Fixed per-rank skewness for the whole run; 1.0 = normal speed.
    Fixed(Vec<f64>),
    /// One straggler at skewness χ, rotating round-robin across ranks
    /// every `period_epochs` (the paper's dynamic heterogeneous scenario).
    RoundRobin { chi: f64, period_epochs: usize },
    /// Trace-driven multi-tenant contention at *iteration* granularity
    /// (`--scenario`/`--scenario-file`, DESIGN.md §12).
    Scenario(ScenarioSpec),
}

impl StragglerPlan {
    /// Per-rank χ multipliers at a given iteration.  `iter` is the
    /// **global** iteration index (`epoch · iters_per_epoch + iter`):
    /// `None`/`Fixed` ignore it, `RoundRobin` keys off `epoch` only
    /// (the legacy degenerate traces), and `Scenario` keys off `iter`
    /// only.  Scenario evaluation replays the seeded trace engine from
    /// iteration 0 — O(iter) per call; the trainer realizes the whole
    /// run once as a `contention::ContentionTrace` instead.
    pub fn chis_at(&self, e: usize, epoch: usize, iter: usize) -> Vec<f64> {
        match self {
            StragglerPlan::None => vec![1.0; e],
            StragglerPlan::Fixed(v) => {
                let mut out = vec![1.0; e];
                for (i, c) in v.iter().enumerate().take(e) {
                    out[i] = c.max(1.0);
                }
                out
            }
            StragglerPlan::RoundRobin { chi, period_epochs } => {
                let mut out = vec![1.0; e];
                let idx = (epoch / period_epochs.max(&1)) % e;
                out[idx] = chi.max(1.0);
                out
            }
            StragglerPlan::Scenario(spec) => {
                crate::contention::ContentionTrace::generate(spec, e, iter + 1)
                    .chis(iter)
                    .to_vec()
            }
        }
    }

    /// Per-rank χ at an epoch boundary — delegates to [`Self::chis_at`]
    /// with iteration 0 (kept for the pre-trace callers/tests).
    pub fn chis(&self, e: usize, epoch: usize) -> Vec<f64> {
        self.chis_at(e, epoch, 0)
    }
}

/// When the balancer's plan is recomputed (DESIGN.md §12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplanMode {
    /// Every iteration — the legacy engine; detection statistics are
    /// gathered (and charged) each iteration.
    Iter,
    /// Once at each epoch boundary — the static per-epoch baseline the
    /// online controller is measured against.
    Epoch,
    /// Epoch boundaries **plus** EWMA-drift-triggered mid-epoch replans
    /// (re-running the pretest cost fits and the Eq. 2/3 allocation),
    /// with the replan overhead charged to the SimClock.
    Online,
}

impl ReplanMode {
    pub fn parse(s: &str) -> Result<ReplanMode> {
        Ok(match s {
            "iter" => ReplanMode::Iter,
            "epoch" => ReplanMode::Epoch,
            "online" => ReplanMode::Online,
            _ => bail!("unknown replan mode '{s}' (iter|epoch|online)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReplanMode::Iter => "iter",
            ReplanMode::Epoch => "epoch",
            ReplanMode::Online => "online",
        }
    }
}

/// Where SimClock compute charges come from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeModel {
    /// Real measured backend seconds (default; adaptive runs vary
    /// run-to-run with host noise, like real clusters).
    Measured,
    /// Deterministic FLOP-model seconds (`contention::timemodel`) — the
    /// closed simulation used by `flextp sweep` and the dynamic-scenario
    /// determinism suite.
    Modeled,
}

impl TimeModel {
    pub fn parse(s: &str) -> Result<TimeModel> {
        Ok(match s {
            "measured" => TimeModel::Measured,
            "modeled" => TimeModel::Modeled,
            _ => bail!("unknown time model '{s}' (measured|modeled)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TimeModel::Measured => "measured",
            TimeModel::Modeled => "modeled",
        }
    }
}

/// Which all-reduce data plane carries the collectives (`--transport`).
/// Accounting (clocks, costs, `CommStats`) is transport-independent, so
/// the two modes are bitwise-interchangeable on every simulated metric
/// (DESIGN.md §15, `tests/transport_parity.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Ranks are buffer slots in the coordinator process (the historic
    /// engine; zero syscalls, default).
    InProc,
    /// Ranks are OS processes (`flextp rank …`) over localhost TCP with
    /// framed, checksummed messages — real process kills exercise the
    /// churn/recovery machinery.
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        Ok(match s {
            "inproc" => TransportKind::InProc,
            "tcp" => TransportKind::Tcp,
            _ => bail!("unknown transport '{s}' (inproc|tcp)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Simulated interconnect (α-β model). Defaults approximate PCIe 3.0 x16
/// (the paper's testbed): ~10 µs latency, ~12 GB/s effective.
#[derive(Debug, Clone, Copy)]
pub struct NetCfg {
    pub alpha_s: f64,
    pub bytes_per_s: f64,
}

impl Default for NetCfg {
    fn default() -> Self {
        NetCfg { alpha_s: 10e-6, bytes_per_s: 12e9 }
    }
}

/// Training-loop parameters.
#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub epochs: usize,
    pub iters_per_epoch: usize,
    pub lr: f32,
    pub momentum: f32,
    pub eval_iters: usize,
    pub seed: u64,
    /// dataset size in batches (cycled)
    pub train_batches: usize,
    /// really sleep (χ-1)·t on stragglers (paper-literal emulation)
    /// instead of only charging the SimClock
    pub emulate_wall: bool,
    /// rank-execution worker threads (`--threads`): per-rank executables
    /// and migration slices run concurrently on a scoped pool, and GEMMs
    /// of replicated single-call roles split into row panels.  0 = all
    /// available cores; 1 = the serial engine.  For a fixed balancing
    /// plan (forced actions, `--gamma` override, baseline) thread count
    /// never changes results — losses are bitwise thread-count-invariant;
    /// adaptive strategies re-plan from *measured* timings, which vary
    /// run to run at any thread count (threads add no new
    /// nondeterminism).  The `FLEXTP_THREADS` env var seeds the default
    /// so the fig5–fig11 bench binaries and the test suite pick it up
    /// without per-binary flags.
    pub threads: usize,
    /// where SimClock compute charges come from (`--time-model`)
    pub time_model: TimeModel,
    /// opt-in per-iteration JSON dump (`--timeline`): χ vs T_i vs RT per
    /// iteration lands in the run report for plotting.  Since the trace
    /// layer landed this is a *view* over the span recorder
    /// (`trace::Tracer::end_iter`), not a separate sampling path.
    pub timeline: bool,
    /// record full phase spans (`--trace`): per-rank ring buffers merged
    /// deterministically and exported as Perfetto `trace.json` + JSONL
    /// at run end; charges NOTHING to SimClocks (DESIGN.md §17)
    pub trace: bool,
    /// trace export directory (`--trace-out`; default `<bench_out>/trace`);
    /// an unwritable path yields a typed `TraceError` warning up front,
    /// never a mid-epoch panic
    pub trace_out: Option<PathBuf>,
    /// per-rank span ring capacity (`--trace-ring`); when exceeded the
    /// oldest spans drop and the drop count is reported at export
    pub trace_ring: usize,
    /// checkpoint directory (`--ckpt-dir`); None disables periodic saves
    pub ckpt_dir: Option<PathBuf>,
    /// save a snapshot every N global iterations (`--ckpt-every`);
    /// 0 disables periodic saves even with a directory set
    pub ckpt_every: usize,
    /// resume source (`--resume`): a `.flexckpt` file, or a checkpoint
    /// directory (the newest complete snapshot is picked)
    pub resume: Option<PathBuf>,
    /// stop (simulated preemption) after this global iteration
    /// (`--stop-after`); the epoch in progress is checkpointable and the
    /// run reports only what completed
    pub stop_after: Option<u64>,
    /// act on scenario `join:`/`leave:`/`fail:` worker-churn events by
    /// re-sharding in-process (`--churn false` ignores them: the
    /// fixed-E baseline rides out the scenario at its starting worker
    /// count).  Part of the math fingerprint — a resumed run must keep
    /// the setting of the run that wrote the snapshot.
    pub churn: bool,
    /// all-reduce data plane (`--transport inproc|tcp`).  Excluded from
    /// the checkpoint math fingerprint: transports are bitwise-equal on
    /// simulated metrics, so a tcp run may resume an inproc snapshot.
    pub transport: TransportKind,
    /// coordinator-side per-read deadline in ms (`--transport-timeout-ms`)
    /// before a stalled rank surfaces as a typed `Timeout`
    pub transport_timeout_ms: u64,
    /// binary to re-exec as `flextp rank` (`--rank-exe`); None resolves
    /// `FLEXTP_RANK_EXE`, then the current executable.  Integration
    /// tests must point this at the real CLI binary — the *test* binary
    /// has no `rank` subcommand.
    pub rank_exe: Option<PathBuf>,
    /// per-rank memory capacity in bytes (`--mem-cap`, byte suffixes
    /// `K`/`M`/`G` accepted); None derives 2× the model's full footprint
    /// from the manifest (`memory::default_cap`).  Part of the math
    /// fingerprint: a tighter cap changes balancing decisions.
    pub mem_cap: Option<u64>,
    /// per-rank capacity overrides (`--mem-cap-rN`), sorted by rank;
    /// entries for ranks ≥ E are ignored by the ledger.
    pub mem_caps: Vec<(usize, u64)>,
    /// force activation-checkpointing (recompute-in-backward) on every
    /// rank every iteration (`--mem-recompute`) — the loss-invariance
    /// baseline; normally recompute engages per rank only under memory
    /// pressure.
    pub mem_recompute: bool,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            epochs: 4,
            iters_per_epoch: 8,
            lr: 0.05,
            momentum: 0.0,
            eval_iters: 4,
            seed: 42,
            train_batches: 8,
            emulate_wall: false,
            threads: env_threads(),
            time_model: TimeModel::Measured,
            timeline: false,
            trace: false,
            trace_out: None,
            trace_ring: crate::trace::DEFAULT_RING_CAP,
            ckpt_dir: None,
            ckpt_every: 0,
            resume: None,
            stop_after: None,
            churn: true,
            transport: TransportKind::InProc,
            transport_timeout_ms: crate::collectives::transport::DEFAULT_COORD_TIMEOUT_MS,
            rank_exe: None,
            mem_cap: None,
            mem_caps: Vec::new(),
            mem_recompute: false,
        }
    }
}

/// Parse a byte size: plain bytes, or binary suffixes `K`/`M`/`G`
/// (also `KiB`/`MiB`/`GiB`) — `--mem-cap 512M`, `--mem-cap 1.5G`.
pub fn parse_bytes(s: &str) -> Result<u64> {
    let t = s.trim();
    let (digits, mult) = ["GiB", "MiB", "KiB", "G", "M", "K", "B"]
        .iter()
        .find_map(|suf| {
            t.strip_suffix(suf).map(|d| {
                let m: u64 = match suf.as_bytes()[0] {
                    b'G' => 1 << 30,
                    b'M' => 1 << 20,
                    b'K' => 1 << 10,
                    _ => 1,
                };
                (d, m)
            })
        })
        .unwrap_or((t, 1));
    let v: f64 = digits.trim().parse().map_err(|_| {
        anyhow::anyhow!("bad byte size '{s}' (examples: 1073741824, 512M, 1.5G)")
    })?;
    if !v.is_finite() || v < 0.0 {
        bail!("byte size '{s}' must be a non-negative number");
    }
    Ok((v * mult as f64).round() as u64)
}

/// Default rank-execution thread count: `FLEXTP_THREADS` when set and
/// parseable, else 1 (the serial engine).
pub fn env_threads() -> usize {
    std::env::var("FLEXTP_THREADS")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(1)
}

/// Balancer parameters (paper defaults: θ_iter = 1e-3, α = 0.8).
#[derive(Debug, Clone)]
pub struct BalancerCfg {
    pub strategy: Strategy,
    pub imputation: Imputation,
    pub mig_policy: MigPolicy,
    /// micro-threshold θ_iter for differentiated ratios
    pub theta_iter: f64,
    /// decay factor α in γ_k = max(γ_k, α·γ)
    pub alpha: f64,
    /// force a uniform pruning ratio (homogeneous Fig. 5/6 sweeps);
    /// also the empirical γ of PriDiffE.
    pub gamma_override: Option<f64>,
    /// Fig. 11: force the number of stragglers that run MIG (λ sweep).
    pub forced_lambda: Option<usize>,
    /// merge migration reduce into the branch all-reduce (paper §IV-A).
    pub reduce_merging: bool,
    /// when the plan is recomputed (`--replan iter|epoch|online`).
    pub replan: ReplanMode,
}

impl Default for BalancerCfg {
    fn default() -> Self {
        BalancerCfg {
            strategy: Strategy::Baseline,
            imputation: Imputation::Zero,
            mig_policy: MigPolicy::BroadcastReduce,
            theta_iter: 1e-3,
            alpha: 0.8,
            gamma_override: None,
            forced_lambda: None,
            reduce_merging: true,
            replan: ReplanMode::Iter,
        }
    }
}

/// A full run specification.
#[derive(Debug, Clone)]
pub struct RunCfg {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub backend: BackendKind,
    pub train: TrainCfg,
    pub balancer: BalancerCfg,
    pub stragglers: StragglerPlan,
    pub net: NetCfg,
    /// online-controller drift-detector parameters (`--ctl-*`).
    pub control: ControlCfg,
    /// override the preset's worker count (`--e`, elastic resume target).
    /// Native backend only: the manifest re-synthesizes with the new
    /// shard widths (`runtime::presets::synthesize_with_e`).
    pub e_override: Option<usize>,
    /// per-component TP degree overrides (`--e-attn` etc., DESIGN.md
    /// §18); components left unset default to the effective global `e`.
    pub degree_overrides: DegreeOverrides,
    /// `--degrees auto`: let the balancer pick per-component degrees
    /// from the blended pretest cost fits and the initial χ profile
    /// (`balancer::select_degrees`) instead of uniform `e`.  Explicit
    /// `--e-*` overrides win over the auto choice per component.
    pub degrees_auto: bool,
}

impl RunCfg {
    pub fn new(model: &str) -> RunCfg {
        RunCfg {
            artifacts_dir: PathBuf::from("artifacts"),
            model: model.to_string(),
            backend: BackendKind::Native,
            train: TrainCfg::default(),
            balancer: BalancerCfg::default(),
            stragglers: StragglerPlan::None,
            net: NetCfg::default(),
            control: ControlCfg::default(),
            e_override: None,
            degree_overrides: DegreeOverrides::default(),
            degrees_auto: false,
        }
    }

    pub fn model_dir(&self) -> PathBuf {
        self.artifacts_dir.join(&self.model)
    }
}

// ---------------------------------------------------------------------------
// CLI parsing (no clap offline)
// ---------------------------------------------------------------------------

/// Parse `--key value` / `--key=value` pairs; returns (positional, map).
pub fn parse_kv_args(args: &[String]) -> Result<(Vec<String>, BTreeMap<String, String>)> {
    let mut pos = Vec::new();
    let mut kv = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                kv.insert(k.to_string(), v.to_string());
            } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                kv.insert(stripped.to_string(), args[i + 1].clone());
                i += 1;
            } else {
                kv.insert(stripped.to_string(), "true".to_string());
            }
        } else {
            pos.push(a.clone());
        }
        i += 1;
    }
    Ok((pos, kv))
}

/// Apply CLI overrides onto a RunCfg.
pub fn apply_overrides(cfg: &mut RunCfg, kv: &BTreeMap<String, String>) -> Result<()> {
    for (k, v) in kv {
        match k.as_str() {
            "artifacts" => cfg.artifacts_dir = PathBuf::from(v),
            "model" => cfg.model = v.clone(),
            "backend" => cfg.backend = BackendKind::parse(v)?,
            "epochs" => cfg.train.epochs = v.parse().context("epochs")?,
            "iters" => cfg.train.iters_per_epoch = v.parse().context("iters")?,
            "lr" => cfg.train.lr = v.parse().context("lr")?,
            "momentum" => cfg.train.momentum = v.parse().context("momentum")?,
            "seed" => cfg.train.seed = v.parse().context("seed")?,
            "eval-iters" => cfg.train.eval_iters = v.parse().context("eval-iters")?,
            "strategy" => cfg.balancer.strategy = Strategy::parse(v)?,
            "imputation" => cfg.balancer.imputation = Imputation::parse(v)?,
            "mig-policy" => cfg.balancer.mig_policy = MigPolicy::parse(v)?,
            "gamma" => cfg.balancer.gamma_override = Some(v.parse().context("gamma")?),
            "lambda" => cfg.balancer.forced_lambda = Some(v.parse().context("lambda")?),
            "theta-iter" => cfg.balancer.theta_iter = v.parse().context("theta-iter")?,
            "alpha" => cfg.balancer.alpha = v.parse().context("alpha")?,
            "no-reduce-merging" => cfg.balancer.reduce_merging = false,
            "emulate-wall" => cfg.train.emulate_wall = true,
            "threads" => cfg.train.threads = v.parse().context("threads")?,
            "e" => cfg.e_override = Some(v.parse().context("e")?),
            "e-embed" => cfg.degree_overrides.embed = Some(v.parse().context("e-embed")?),
            "e-attn" => cfg.degree_overrides.attn = Some(v.parse().context("e-attn")?),
            "e-mlp" => cfg.degree_overrides.mlp = Some(v.parse().context("e-mlp")?),
            "e-head" => cfg.degree_overrides.head = Some(v.parse().context("e-head")?),
            "degrees" => match v.as_str() {
                "auto" => cfg.degrees_auto = true,
                _ => bail!(
                    "--degrees only supports 'auto' (use --e-attn/--e-mlp/\
                     --e-embed/--e-head for explicit per-component degrees)"
                ),
            },
            "ckpt-dir" => cfg.train.ckpt_dir = Some(PathBuf::from(v)),
            "ckpt-every" => cfg.train.ckpt_every = v.parse().context("ckpt-every")?,
            "resume" => cfg.train.resume = Some(PathBuf::from(v)),
            "stop-after" => cfg.train.stop_after = Some(v.parse().context("stop-after")?),
            "churn" => cfg.train.churn = v.parse().context("churn (true|false)")?,
            "transport" => cfg.train.transport = TransportKind::parse(v)?,
            "transport-timeout-ms" => {
                cfg.train.transport_timeout_ms = v.parse().context("transport-timeout-ms")?
            }
            "rank-exe" => cfg.train.rank_exe = Some(PathBuf::from(v)),
            "replan" => cfg.balancer.replan = ReplanMode::parse(v)?,
            "time-model" => cfg.train.time_model = TimeModel::parse(v)?,
            "timeline" => cfg.train.timeline = true,
            "trace" => cfg.train.trace = true,
            "trace-out" => cfg.train.trace_out = Some(PathBuf::from(v)),
            "trace-ring" => cfg.train.trace_ring = v.parse().context("trace-ring")?,
            "ctl-hi" => cfg.control.hi = v.parse().context("ctl-hi")?,
            "ctl-lo" => cfg.control.lo = v.parse().context("ctl-lo")?,
            "ctl-cooldown" => cfg.control.cooldown = v.parse().context("ctl-cooldown")?,
            "ctl-alpha-fast" => cfg.control.alpha_fast = v.parse().context("ctl-alpha-fast")?,
            "ctl-alpha-slow" => cfg.control.alpha_slow = v.parse().context("ctl-alpha-slow")?,
            "chi" => {
                let chi: f64 = v.parse().context("chi")?;
                cfg.stragglers = StragglerPlan::RoundRobin { chi, period_epochs: 1 };
            }
            "chis" => {
                let chis: Result<Vec<f64>, _> = v.split(',').map(str::parse).collect();
                cfg.stragglers = StragglerPlan::Fixed(chis.context("chis")?);
            }
            "scenario" => {
                cfg.stragglers = StragglerPlan::Scenario(
                    ScenarioSpec::parse(v).context("scenario")?,
                );
            }
            "scenario-file" => {
                cfg.stragglers = StragglerPlan::Scenario(
                    ScenarioSpec::from_file(std::path::Path::new(v)).context("scenario-file")?,
                );
            }
            "net-alpha-us" => cfg.net.alpha_s = v.parse::<f64>().context("net-alpha-us")? * 1e-6,
            "net-gbps" => cfg.net.bytes_per_s = v.parse::<f64>().context("net-gbps")? * 1e9,
            "mem-cap" => cfg.train.mem_cap = Some(parse_bytes(v).context("mem-cap")?),
            "mem-recompute" => cfg.train.mem_recompute = true,
            k if k.starts_with("mem-cap-r") => {
                let rank: usize = k["mem-cap-r".len()..]
                    .parse()
                    .with_context(|| format!("bad rank in --{k} (use --mem-cap-r3)"))?;
                let cap = parse_bytes(v).with_context(|| k.to_string())?;
                cfg.train.mem_caps.retain(|(r, _)| *r != rank);
                cfg.train.mem_caps.push((rank, cap));
                cfg.train.mem_caps.sort_by_key(|(r, _)| *r);
            }
            _ => bail!("unknown option --{k}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_roundtrip_and_default() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(RunCfg::new("vit-tiny").backend, BackendKind::Native);
        let mut cfg = RunCfg::new("vit-tiny");
        let (_, kv) = parse_kv_args(&["--backend".to_string(), "pjrt".to_string()]).unwrap();
        apply_overrides(&mut cfg, &kv).unwrap();
        assert_eq!(cfg.backend, BackendKind::Pjrt);
    }

    #[test]
    fn transport_roundtrip_and_overrides() {
        assert_eq!(TransportKind::parse("inproc").unwrap(), TransportKind::InProc);
        assert_eq!(TransportKind::parse("tcp").unwrap(), TransportKind::Tcp);
        assert!(TransportKind::parse("rdma").is_err());
        assert_eq!(TransportKind::Tcp.name(), "tcp");
        let mut cfg = RunCfg::new("vit-tiny");
        assert_eq!(cfg.train.transport, TransportKind::InProc);
        let (_, kv) = parse_kv_args(&[
            "--transport".to_string(),
            "tcp".to_string(),
            "--transport-timeout-ms".to_string(),
            "250".to_string(),
            "--rank-exe".to_string(),
            "/tmp/flextp".to_string(),
        ])
        .unwrap();
        apply_overrides(&mut cfg, &kv).unwrap();
        assert_eq!(cfg.train.transport, TransportKind::Tcp);
        assert_eq!(cfg.train.transport_timeout_ms, 250);
        assert_eq!(cfg.train.rank_exe.as_deref(), Some(std::path::Path::new("/tmp/flextp")));
    }

    #[test]
    fn strategy_roundtrip() {
        for s in ["baseline", "zero-rd", "zero-pri", "zero-pridiff-e",
                  "zero-pridiff-r", "mig", "semi"] {
            assert!(Strategy::parse(s).is_ok(), "{s}");
        }
        assert!(Strategy::parse("nope").is_err());
    }

    #[test]
    fn straggler_plans() {
        let p = StragglerPlan::None;
        assert_eq!(p.chis(4, 0), vec![1.0; 4]);

        let p = StragglerPlan::Fixed(vec![2.0, 1.0]);
        assert_eq!(p.chis(4, 9), vec![2.0, 1.0, 1.0, 1.0]);

        let p = StragglerPlan::RoundRobin { chi: 4.0, period_epochs: 2 };
        assert_eq!(p.chis(4, 0), vec![4.0, 1.0, 1.0, 1.0]);
        assert_eq!(p.chis(4, 2), vec![1.0, 4.0, 1.0, 1.0]);
        assert_eq!(p.chis(4, 8), vec![4.0, 1.0, 1.0, 1.0]); // wraps
    }

    #[test]
    fn chis_at_makes_legacy_plans_degenerate_traces() {
        // Fixed/RoundRobin ignore the iteration — every iteration of an
        // epoch matches the old per-epoch chis() exactly.
        let p = StragglerPlan::Fixed(vec![2.0, 1.0]);
        for it in [0, 1, 7, 99] {
            assert_eq!(p.chis_at(4, 3, it), p.chis(4, 3));
        }
        let p = StragglerPlan::RoundRobin { chi: 4.0, period_epochs: 1 };
        for it in [0, 5] {
            assert_eq!(p.chis_at(4, 2, it), p.chis(4, 2));
        }
        // Scenario keys off the global iteration, not the epoch
        let p = StragglerPlan::Scenario(
            crate::contention::ScenarioSpec::parse("burst:r1@x4:iters2-5").unwrap(),
        );
        assert_eq!(p.chis_at(2, 0, 1), vec![1.0, 1.0]);
        assert_eq!(p.chis_at(2, 7, 3), vec![1.0, 4.0], "epoch is ignored");
        assert_eq!(p.chis_at(2, 0, 5), vec![1.0, 1.0]);
    }

    #[test]
    fn scenario_replan_time_model_overrides_apply() {
        let mut cfg = RunCfg::new("vit-tiny");
        let args: Vec<String> = [
            "--scenario", "burst:r1@x4:iters2-5,seed:9",
            "--replan", "online",
            "--time-model", "modeled",
            "--timeline",
            "--trace",
            "--trace-out", "/tmp/flextp_trace_cfg_test",
            "--trace-ring", "1024",
            "--ctl-hi", "0.5",
            "--ctl-cooldown", "4",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let (_, kv) = parse_kv_args(&args).unwrap();
        apply_overrides(&mut cfg, &kv).unwrap();
        assert!(matches!(cfg.stragglers, StragglerPlan::Scenario(_)));
        assert_eq!(cfg.balancer.replan, ReplanMode::Online);
        assert_eq!(cfg.train.time_model, TimeModel::Modeled);
        assert!(cfg.train.timeline);
        assert!(cfg.train.trace);
        assert_eq!(cfg.train.trace_out.as_deref(),
                   Some(std::path::Path::new("/tmp/flextp_trace_cfg_test")));
        assert_eq!(cfg.train.trace_ring, 1024);
        assert_eq!(cfg.control.hi, 0.5);
        assert_eq!(cfg.control.cooldown, 4);
        assert!(ReplanMode::parse("never").is_err());
        assert!(TimeModel::parse("psychic").is_err());
        let (_, kv) = parse_kv_args(&["--scenario=burst:bogus".to_string()]).unwrap();
        assert!(apply_overrides(&mut cfg, &kv).is_err());
    }

    #[test]
    fn kv_parsing() {
        let args: Vec<String> =
            ["train", "--epochs", "3", "--gamma=0.5", "--no-reduce-merging"]
                .iter().map(|s| s.to_string()).collect();
        let (pos, kv) = parse_kv_args(&args).unwrap();
        assert_eq!(pos, vec!["train"]);
        assert_eq!(kv["epochs"], "3");
        assert_eq!(kv["gamma"], "0.5");
        assert_eq!(kv["no-reduce-merging"], "true");
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = RunCfg::new("vit-tiny");
        let args: Vec<String> = ["--strategy", "semi", "--chi", "4", "--lr", "0.01"]
            .iter().map(|s| s.to_string()).collect();
        let (_, kv) = parse_kv_args(&args).unwrap();
        apply_overrides(&mut cfg, &kv).unwrap();
        assert_eq!(cfg.balancer.strategy, Strategy::Semi);
        assert_eq!(cfg.train.lr, 0.01);
        assert!(matches!(cfg.stragglers, StragglerPlan::RoundRobin { .. }));
    }

    #[test]
    fn threads_override_applies() {
        let mut cfg = RunCfg::new("vit-tiny");
        let (_, kv) = parse_kv_args(&["--threads".to_string(), "4".to_string()]).unwrap();
        apply_overrides(&mut cfg, &kv).unwrap();
        assert_eq!(cfg.train.threads, 4);
        let (_, kv) = parse_kv_args(&["--threads=bogus".to_string()]).unwrap();
        assert!(apply_overrides(&mut cfg, &kv).is_err());
    }

    #[test]
    fn checkpoint_and_elastic_overrides_apply() {
        let mut cfg = RunCfg::new("vit-tiny");
        let args: Vec<String> = [
            "--ckpt-dir", "ckpts",
            "--ckpt-every", "5",
            "--resume", "ckpts/ckpt-00000010.flexckpt",
            "--stop-after", "10",
            "--e", "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let (_, kv) = parse_kv_args(&args).unwrap();
        apply_overrides(&mut cfg, &kv).unwrap();
        assert_eq!(cfg.train.ckpt_dir, Some(PathBuf::from("ckpts")));
        assert_eq!(cfg.train.ckpt_every, 5);
        assert_eq!(
            cfg.train.resume,
            Some(PathBuf::from("ckpts/ckpt-00000010.flexckpt"))
        );
        assert_eq!(cfg.train.stop_after, Some(10));
        assert_eq!(cfg.e_override, Some(2));
        let (_, kv) = parse_kv_args(&["--ckpt-every=soon".to_string()]).unwrap();
        assert!(apply_overrides(&mut cfg, &kv).is_err());
        let (_, kv) = parse_kv_args(&["--e=two".to_string()]).unwrap();
        assert!(apply_overrides(&mut cfg, &kv).is_err());
    }

    #[test]
    fn degree_overrides_apply_and_resolve() {
        let mut cfg = RunCfg::new("vit-tiny");
        assert!(!cfg.degree_overrides.any());
        assert_eq!(cfg.degree_overrides.resolve(4), Degrees::uniform(4));
        let args: Vec<String> = ["--e", "4", "--e-attn", "2", "--e-mlp", "2"]
            .iter().map(|s| s.to_string()).collect();
        let (_, kv) = parse_kv_args(&args).unwrap();
        apply_overrides(&mut cfg, &kv).unwrap();
        assert!(cfg.degree_overrides.any());
        assert_eq!(
            cfg.degree_overrides.resolve(4),
            Degrees { embed: 4, attn: 2, mlp: 2, head: 4 }
        );
        assert!(!cfg.degrees_auto);
        let (_, kv) = parse_kv_args(&["--degrees".to_string(), "auto".to_string()]).unwrap();
        apply_overrides(&mut cfg, &kv).unwrap();
        assert!(cfg.degrees_auto);
        let (_, kv) = parse_kv_args(&["--degrees=2,2,4,4".to_string()]).unwrap();
        assert!(apply_overrides(&mut cfg, &kv).is_err());
        let (_, kv) = parse_kv_args(&["--e-attn=two".to_string()]).unwrap();
        assert!(apply_overrides(&mut cfg, &kv).is_err());
    }

    #[test]
    fn byte_sizes_parse_with_binary_suffixes() {
        assert_eq!(parse_bytes("1073741824").unwrap(), 1 << 30);
        assert_eq!(parse_bytes("512M").unwrap(), 512 << 20);
        assert_eq!(parse_bytes("512MiB").unwrap(), 512 << 20);
        assert_eq!(parse_bytes("1.5G").unwrap(), 3 << 29);
        assert_eq!(parse_bytes("64K").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("0").unwrap(), 0);
        for bad in ["", "MiB", "-1", "1.5Q", "lots"] {
            assert!(parse_bytes(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn memory_overrides_apply() {
        let mut cfg = RunCfg::new("vit-tiny");
        assert_eq!(cfg.train.mem_cap, None);
        assert!(cfg.train.mem_caps.is_empty());
        assert!(!cfg.train.mem_recompute);
        let args: Vec<String> = [
            "--mem-cap", "256M",
            "--mem-cap-r2", "128M",
            "--mem-cap-r0", "64M",
            "--mem-recompute",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let (_, kv) = parse_kv_args(&args).unwrap();
        apply_overrides(&mut cfg, &kv).unwrap();
        assert_eq!(cfg.train.mem_cap, Some(256 << 20));
        assert_eq!(cfg.train.mem_caps, vec![(0, 64 << 20), (2, 128 << 20)]);
        assert!(cfg.train.mem_recompute);
        // latest override for the same rank wins
        let (_, kv) = parse_kv_args(&["--mem-cap-r2=32M".to_string()]).unwrap();
        apply_overrides(&mut cfg, &kv).unwrap();
        assert_eq!(cfg.train.mem_caps, vec![(0, 64 << 20), (2, 32 << 20)]);
        let (_, kv) = parse_kv_args(&["--mem-cap-rX=1M".to_string()]).unwrap();
        assert!(apply_overrides(&mut cfg, &kv).is_err());
        let (_, kv) = parse_kv_args(&["--mem-cap=huge".to_string()]).unwrap();
        assert!(apply_overrides(&mut cfg, &kv).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let mut cfg = RunCfg::new("vit-tiny");
        let (_, kv) = parse_kv_args(&["--bogus=1".to_string()]).unwrap();
        assert!(apply_overrides(&mut cfg, &kv).is_err());
    }
}
