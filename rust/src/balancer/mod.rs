//! The balancing strategy façade — Algorithm 1 + Algorithm 2 dispatch.
//!
//! Every iteration the trainer asks the balancer for a [`WorkerAction`]
//! per rank, computed from the straggler monitor's statistics.  This is
//! where the paper's compared systems differ:
//!
//! | strategy        | detection | resize selection  | γ per layer | migration |
//! |-----------------|-----------|-------------------|-------------|-----------|
//! | Baseline        | —         | —                 | —           | —         |
//! | ZERO-Rd         | T_avg     | random            | uniform Eq.1| —         |
//! | ZERO-Pri        | T_avg     | priority          | uniform Eq.1| —         |
//! | ZERO-PriDiffE   | T_avg     | priority          | diff, γ=½   | —         |
//! | ZERO-PriDiffR   | T_avg     | priority          | diff, Eq.1  | —         |
//! | MIG             | T_min     | —                 | —           | all       |
//! | SEMI            | T_min     | priority          | diff, Eq.1  | Eq.2/Eq.3 |
//!
//! Workload shares used to convert "shed s of GEMM time" into per-GEMM
//! ratios: per worker per block the GEMM time splits ≈ QKV 3/12, O-proj
//! 1/12, FFN 8/12 (hs² units).  The FFN (migratable, idx2) absorbs demand
//! first, QKV (resize-only) covers the remainder; O-proj is never resized
//! (its contraction is the already-small hsl).

pub mod degrees;
pub use degrees::{select_degrees, select_degrees_with_costs};

use crate::config::{BalancerCfg, Strategy};
use crate::migration::{self, MigPlan};
use crate::resizing::priority::BlockTrackers;
use crate::resizing::{LayerPlan, ResizePlanner, Selection};
use crate::runtime::manifest::Manifest;
use crate::semi::{self, CostFns, StragglerStat};
use crate::straggler::{gamma_eq1, Monitor};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// FFN share of a block's GEMM workload (2×[hs,ffl] of 12 hs·hsl units).
pub const FFN_SHARE: f64 = 8.0 / 12.0;
/// QKV share.
pub const QKV_SHARE: f64 = 3.0 / 12.0;
/// Largest compiled pruning ratio.
pub const GAMMA_MAX: f64 = 0.875;

/// What one worker does this iteration.
#[derive(Debug, Clone)]
pub struct WorkerAction {
    /// per-block resizing plan (bucket + keep sets)
    pub layers: Vec<LayerPlan>,
    /// outbound migration (this worker is the straggler), if any
    pub mig: Option<MigPlan>,
}

impl WorkerAction {
    pub fn full(manifest: &Manifest) -> WorkerAction {
        let m = &manifest.model;
        WorkerAction {
            layers: (0..m.depth).map(|_| LayerPlan::full(m.hs, m.ffl)).collect(),
            mig: None,
        }
    }
}

/// Strategy dispatcher + the per-worker statistics it maintains across
/// epochs (priority trackers, weight snapshots, epoch pruned sets).
pub struct Balancer {
    pub cfg: BalancerCfg,
    /// `trackers[w][k]`
    pub trackers: Vec<Vec<BlockTrackers>>,
    /// weight snapshots for δ computation: (wqkv, w1, w2) per (w, k)
    pub(crate) snapshots: Vec<Vec<(Tensor, Tensor, Tensor)>>,
    /// indices pruned during the current epoch, per (w, k, kind)
    pub(crate) pruned_epoch: Vec<Vec<[Vec<bool>; 3]>>,
    pub(crate) rng: Rng,
    /// per-rank bytes available for migration intake (DESIGN.md §16):
    /// the trainer refreshes this from the [`crate::memory::MemLedger`]
    /// before each plan.  A migration plan is dropped when any
    /// receiver's migrated share would not fit its headroom — the
    /// worker then sheds by ZERO-resizing, which shrinks the straggler's
    /// footprint instead of growing a receiver's.  `None` disables the
    /// filter (legacy callers, unit tests).
    mem_headroom: Option<Vec<u64>>,
}

impl Balancer {
    pub fn new(cfg: BalancerCfg, manifest: &Manifest, seed: u64) -> Balancer {
        let m = &manifest.model;
        Balancer {
            cfg,
            trackers: (0..m.e)
                .map(|_| {
                    (0..m.depth)
                        .map(|_| BlockTrackers::new(m.hs, m.hs, m.ffl))
                        .collect()
                })
                .collect(),
            snapshots: Vec::new(),
            pruned_epoch: (0..m.e)
                .map(|_| {
                    (0..m.depth)
                        .map(|_| [vec![false; m.hs], vec![false; m.hs], vec![false; m.ffl]])
                        .collect()
                })
                .collect(),
            rng: Rng::new(seed ^ 0xBA1A),
            mem_headroom: None,
        }
    }

    /// Refresh the per-rank migration-intake headroom (bytes) the next
    /// `plan_iter` enforces; `None` disables the memory filter.
    pub fn set_mem_headroom(&mut self, headroom: Option<Vec<u64>>) {
        self.mem_headroom = headroom;
    }

    /// Drop `action.mig` when any receiver's migrated columns exceed its
    /// intake headroom.  Per-receiver cost uses the same
    /// [`crate::memory::mig_bytes_per_col`] constant the ledger charges,
    /// so the filter is exact.  Returns true when a plan was dropped —
    /// callers fall back to ZERO-resizing for the shed demand.
    fn drop_mig_if_over_headroom(&self, manifest: &Manifest, action: &mut WorkerAction) -> bool {
        let Some(headroom) = &self.mem_headroom else { return false };
        let Some(mig) = &action.mig else { return false };
        let per_col = crate::memory::mig_bytes_per_col(&manifest.model);
        let tight = mig.receivers.iter().any(|rw| {
            let need = rw.cols() as u64 * per_col;
            headroom.get(rw.rank).is_some_and(|&h| need > h)
        });
        if tight {
            action.mig = None;
        }
        tight
    }

    fn selection(&self) -> Selection {
        match self.cfg.strategy {
            Strategy::ZeroRd => Selection::Random,
            _ => Selection::Priority,
        }
    }

    fn planner<'a>(&self, manifest: &'a Manifest, iters_per_epoch: usize) -> ResizePlanner<'a> {
        ResizePlanner {
            manifest,
            selection: self.selection(),
            theta_iter: self.cfg.theta_iter,
            alpha: self.cfg.alpha,
            iters_per_epoch,
        }
    }

    /// Produce this iteration's per-worker actions.
    ///
    /// `t_avg`/`t_list`/`t_min` come from the monitor (already charged);
    /// `costs` from the trainer's pretest (SEMI only).
    pub fn plan_iter(
        &mut self,
        manifest: &Manifest,
        monitor: &Monitor,
        t_avg: &[f64],
        t_min: f64,
        iters_per_epoch: usize,
        costs: &CostFns,
    ) -> Vec<WorkerAction> {
        let e = manifest.model.e;
        let mut actions: Vec<WorkerAction> =
            (0..e).map(|_| WorkerAction::full(manifest)).collect();
        match self.cfg.strategy {
            Strategy::Baseline => {}
            Strategy::ZeroRd | Strategy::ZeroPri => {
                for w in 0..e {
                    let gamma = self.uniform_gamma(monitor, t_avg, w);
                    if gamma > 0.0 {
                        let planner = self.planner(manifest, iters_per_epoch);
                        actions[w].layers =
                            planner.plan_uniform(gamma, &self.trackers[w], &mut self.rng);
                    }
                }
            }
            Strategy::ZeroPriDiffE | Strategy::ZeroPriDiffR => {
                for w in 0..e {
                    let is_straggler = monitor.t_iter[w] > t_avg[w] * 1.001
                        || self.cfg.gamma_override.is_some();
                    if !is_straggler {
                        continue;
                    }
                    let gamma = if self.cfg.strategy == Strategy::ZeroPriDiffE {
                        // empirical uniform γ = 1/2 (paper's "E" branch)
                        self.cfg.gamma_override.unwrap_or(0.5)
                    } else {
                        self.uniform_gamma(monitor, t_avg, w)
                    };
                    if gamma > 0.0 {
                        let planner = self.planner(manifest, iters_per_epoch);
                        actions[w].layers =
                            planner.plan_diff(gamma, &self.trackers[w], &mut self.rng);
                    }
                }
            }
            Strategy::Mig => {
                for w in 0..e {
                    let s = self.shed_frac(monitor, t_min, w);
                    if s <= 0.0 {
                        continue;
                    }
                    // all shed goes to the FFN; exact, no resizing
                    let remove = (s / FFN_SHARE).min(GAMMA_MAX);
                    actions[w].mig =
                        migration::plan(manifest, w, remove, 1.0, self.pref(w));
                    // pure MIG has no resizing fallback: a receiver
                    // without headroom simply vetoes the migration and
                    // the straggler rides out the iteration at full size
                    self.drop_mig_if_over_headroom(manifest, &mut actions[w]);
                    self.apply_mig_to_layers(manifest, &mut actions, w);
                }
            }
            Strategy::Semi => {
                self.plan_semi(manifest, monitor, t_min, iters_per_epoch, costs, &mut actions);
            }
        }
        self.note_pruned(&actions, manifest);
        actions
    }

    /// Eq.(1) uniform γ vs T_avg (or the forced homogeneous override).
    fn uniform_gamma(&self, monitor: &Monitor, t_avg: &[f64], w: usize) -> f64 {
        match self.cfg.gamma_override {
            Some(g) => g.min(GAMMA_MAX),
            None => gamma_eq1(monitor.t_iter[w], t_avg[w], monitor.m_iter[w], GAMMA_MAX),
        }
    }

    /// Fraction of GEMM work to shed vs the strict T_min criterion.
    fn shed_frac(&self, monitor: &Monitor, t_min: f64, w: usize) -> f64 {
        gamma_eq1(monitor.t_iter[w], t_min * 1.001, monitor.m_iter[w], GAMMA_MAX)
    }

    /// SEMI (Algorithm 2): Eq.(2) split for a single straggler, Eq.(3)
    /// grouping for many.
    fn plan_semi(
        &mut self,
        manifest: &Manifest,
        monitor: &Monitor,
        t_min: f64,
        iters_per_epoch: usize,
        costs: &CostFns,
        actions: &mut [WorkerAction],
    ) {
        let m = &manifest.model;
        let e = m.e;
        let mut stragglers: Vec<StragglerStat> = (0..e)
            .filter(|&w| monitor.t_iter[w] > t_min * 1.02)
            .map(|w| StragglerStat {
                rank: w,
                t: monitor.t_iter[w],
                l_cols: m.ffl as f64,
            })
            .collect();
        if stragglers.is_empty() {
            return;
        }
        stragglers.sort_by(|a, b| b.t.partial_cmp(&a.t).unwrap());
        let z = stragglers.len();

        if z == 1 {
            // Eq.(2): split the single straggler's excess between the two.
            let w = stragglers[0].rank;
            let s = self.shed_frac(monitor, t_min, w);
            if s <= 0.0 {
                return;
            }
            let ffn_demand = (s / FFN_SHARE).min(GAMMA_MAX);
            let l_gamma = ffn_demand * m.ffl as f64;
            let beta = semi::eq2_beta(l_gamma, e, costs);
            actions[w].mig = migration::plan(manifest, w, ffn_demand, beta, self.pref(w));
            // memory-tight receivers veto the migration → the else
            // branch sheds the same demand by ZERO-resizing, which
            // shrinks the straggler instead of growing a receiver
            self.drop_mig_if_over_headroom(manifest, &mut actions[w]);
            if actions[w].mig.is_some() {
                // mirror the kept set into the straggler's mlp plans —
                // without this the straggler would compute its full FFN
                // *and* receivers the migrated slice (double-counted
                // partials).  Removed-but-unmigrated columns (the 1-β
                // share) are thereby resized (pruned + imputed).
                self.apply_mig_to_layers_one(manifest, &mut actions[w]);
                // residual GEMM demand not covered by the FFN goes to QKV
                let covered = ffn_demand * FFN_SHARE;
                let qkv_gamma = ((s - covered).max(0.0) / QKV_SHARE).min(GAMMA_MAX);
                self.fill_semi_layers(manifest, actions, w, qkv_gamma, iters_per_epoch);
            } else {
                // β ≈ 0 (migration unprofitable here): pure
                // differentiated resizing against the strict T_min
                let planner = self.planner(manifest, iters_per_epoch);
                actions[w].layers = planner.plan_diff(s, &self.trackers[w], &mut self.rng);
            }
        } else {
            // Eq.(3): top-x migrate, the rest resize against T_min.
            let t_all = monitor.t_iter.clone();
            let l_all = vec![m.ffl as f64; e];
            let x = match self.cfg.forced_lambda {
                Some(l) => l.min(z),
                None => semi::eq3_select_x(&stragglers, &t_all, &l_all, t_min, costs),
            };
            for (i, st) in stragglers.iter().enumerate() {
                let w = st.rank;
                let s = self.shed_frac(monitor, t_min, w);
                if s <= 0.0 {
                    continue;
                }
                if i < x {
                    // migration group (exact)
                    let remove = (s / FFN_SHARE).min(GAMMA_MAX);
                    actions[w].mig =
                        migration::plan(manifest, w, remove, 1.0, self.pref(w));
                    if self.drop_mig_if_over_headroom(manifest, &mut actions[w]) {
                        // memory-tight receivers veto: shed the full
                        // demand by differentiated resizing instead
                        let planner = self.planner(manifest, iters_per_epoch);
                        actions[w].layers =
                            planner.plan_diff(s, &self.trackers[w], &mut self.rng);
                        continue;
                    }
                    self.apply_mig_to_layers_one(manifest, &mut actions[w]);
                    // cap overflow: if FFN could not absorb everything,
                    // resize QKV for the rest
                    let covered = remove * FFN_SHARE;
                    let qkv_gamma = ((s - covered).max(0.0) / QKV_SHARE).min(GAMMA_MAX);
                    if qkv_gamma > 0.0 {
                        self.fill_semi_layers(manifest, actions, w, qkv_gamma, iters_per_epoch);
                    }
                } else {
                    // resizing group: PriDiffR against the strict T_min
                    let planner = self.planner(manifest, iters_per_epoch);
                    actions[w].layers =
                        planner.plan_diff(s, &self.trackers[w], &mut self.rng);
                }
            }
        }
    }

    /// Priority preference ranking over ffl for migration splits (uses the
    /// fc2 tracker when it has stats).
    fn pref(&self, w: usize) -> Option<&[u32]> {
        // Lifetime gymnastics: compute lazily per call instead of caching.
        // fc2 tracker ranking is recomputed by the caller when needed.
        let _ = w;
        None
    }

    /// After migration::plan, mirror the kept set into the worker's mlp
    /// LayerPlans ((g00, kept_bucket) executables) for every block.
    fn apply_mig_to_layers(
        &self,
        manifest: &Manifest,
        actions: &mut [WorkerAction],
        w: usize,
    ) {
        self.apply_mig_to_layers_one(manifest, &mut actions[w]);
    }

    fn apply_mig_to_layers_one(&self, manifest: &Manifest, action: &mut WorkerAction) {
        let m = &manifest.model;
        if let Some(mig) = &action.mig {
            for p in &mut action.layers {
                p.mlp_b1 = "g00".into();
                p.mlp_b2 = mig.kept_bucket.clone();
                p.mlp_keep1 = (0..m.hs as u32).collect();
                p.mlp_keep2 = mig.kept.clone();
            }
        }
    }

    /// SEMI: resize the QKV contraction (keep MLP plans from migration).
    fn fill_semi_layers(
        &mut self,
        manifest: &Manifest,
        actions: &mut [WorkerAction],
        w: usize,
        qkv_gamma: f64,
        iters_per_epoch: usize,
    ) {
        if qkv_gamma <= 0.0 {
            return;
        }
        let m = &manifest.model;
        let b = manifest.bucket_for_gamma(qkv_gamma);
        let planner = self.planner(manifest, iters_per_epoch);
        let _ = planner;
        for (k, p) in actions[w].layers.iter_mut().enumerate() {
            p.attn_bucket = b.name.clone();
            p.attn_keep = crate::resizing::select_keep(
                m.hs,
                b.keep_hs,
                self.selection(),
                Some(&self.trackers[w][k].qkv),
                &mut self.rng,
            );
        }
    }

    /// Record which indices each worker pruned (for the incremental
    /// tracker update at epoch end). Migrated indices are NOT pruned —
    /// their gradients arrive exactly.
    fn note_pruned(&mut self, actions: &[WorkerAction], manifest: &Manifest) {
        let m = &manifest.model;
        for (w, a) in actions.iter().enumerate() {
            for (k, p) in a.layers.iter().enumerate() {
                let marks = &mut self.pruned_epoch[w][k];
                mark_complement(&mut marks[0], &p.attn_keep, m.hs);
                mark_complement(&mut marks[1], &p.mlp_keep1, m.hs);
                // kind 2 (ffl): complement of keep2 minus migrated
                let mut removed = vec![true; m.ffl];
                for &i in &p.mlp_keep2 {
                    removed[i as usize] = false;
                }
                if let Some(mig) = &a.mig {
                    for &i in &mig.migrated {
                        removed[i as usize] = false;
                    }
                }
                for (i, &r) in removed.iter().enumerate() {
                    if r {
                        marks[2][i] = true;
                    }
                }
            }
        }
    }

    /// Epoch-end statistics refresh (paper: coarse-grained epoch
    /// granularity): compute fresh per-index δ against the last snapshot,
    /// with the incremental-update exception for pruned indices.
    pub fn epoch_end(&mut self, state: &crate::model::ModelState) {
        let e = state.e();
        let depth = state.depth();
        let first = self.snapshots.is_empty();
        if first {
            self.snapshots = (0..e)
                .map(|w| {
                    (0..depth)
                        .map(|k| {
                            let b = &state.shards[w][k];
                            (b.wqkv.clone(), b.w1.clone(), b.w2.clone())
                        })
                        .collect()
                })
                .collect();
            return; // first epoch: establish baselines only
        }
        for w in 0..e {
            for k in 0..depth {
                let b = &state.shards[w][k];
                let snap = &self.snapshots[w][k];
                let pruned: [Vec<u32>; 3] = [
                    bools_to_idx(&self.pruned_epoch[w][k][0]),
                    bools_to_idx(&self.pruned_epoch[w][k][1]),
                    bools_to_idx(&self.pruned_epoch[w][k][2]),
                ];
                let t = &mut self.trackers[w][k];
                t.qkv.epoch_update(&b.wqkv.row_abs_delta(&snap.0), &pruned[0]);
                t.fc1.epoch_update(&b.w1.row_abs_delta(&snap.1), &pruned[1]);
                t.fc2.epoch_update(&b.w2.row_abs_delta(&snap.2), &pruned[2]);
                self.snapshots[w][k] =
                    (b.wqkv.clone(), b.w1.clone(), b.w2.clone());
                for m in self.pruned_epoch[w][k].iter_mut() {
                    m.fill(false);
                }
            }
        }
    }
}

fn mark_complement(marks: &mut [bool], kept: &[u32], n: usize) {
    if kept.len() == n {
        return;
    }
    let mut in_kept = vec![false; n];
    for &i in kept {
        in_kept[i as usize] = true;
    }
    for i in 0..n {
        if !in_kept[i] {
            marks[i] = true;
        }
    }
}

fn bools_to_idx(b: &[bool]) -> Vec<u32> {
    b.iter()
        .enumerate()
        .filter(|(_, &x)| x)
        .map(|(i, _)| i as u32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BalancerCfg;
    use crate::runtime::manifest::Manifest;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "model": {"name":"t","hs":32,"depth":2,"heads":4,"e":4,"bs":2,
                    "classes":10,"seq":17,"seq0":16,"pd":48,"hsl":8,"hl":1,
                    "hd":8,"ffl":32,"params_total":0,"params_per_worker":0},
          "buckets": [
            {"name":"g00","gamma":0,"keep_hs":32,"keep_ffl":32},
            {"name":"g25","gamma":0.25,"keep_hs":24,"keep_ffl":24},
            {"name":"g50","gamma":0.5,"keep_hs":16,"keep_ffl":16},
            {"name":"g88","gamma":0.875,"keep_hs":8,"keep_ffl":8}
          ],
          "mig_buckets": [8, 16],
          "executables": []
        }"#,
        )
        .unwrap()
    }

    fn costs() -> CostFns {
        CostFns {
            omega1_s: 1e-5,
            omega2_per_col: 1e-6,
            phi1_base_s: 1e-5,
            phi1_per_col: 1e-6,
            phi2_per_col: 1e-6,
        }
    }

    fn monitor_with(t: Vec<f64>, m_frac: f64) -> Monitor {
        let mut mon = Monitor::new(t.len());
        let m: Vec<f64> = t.iter().map(|x| x * m_frac).collect();
        mon.record(t, m);
        mon
    }

    fn plan(
        strategy: Strategy,
        mon: &Monitor,
        t_avg: Vec<f64>,
        t_min: f64,
    ) -> Vec<WorkerAction> {
        let man = manifest();
        let cfg = BalancerCfg { strategy, ..Default::default() };
        let mut b = Balancer::new(cfg, &man, 7);
        b.plan_iter(&man, mon, &t_avg, t_min, 10, &costs())
    }

    #[test]
    fn baseline_never_acts() {
        let mon = monitor_with(vec![4.0, 1.0, 1.0, 1.0], 0.9);
        let acts = plan(Strategy::Baseline, &mon, vec![1.75; 4], 1.0);
        assert!(acts.iter().all(|a| a.mig.is_none()));
        assert!(acts.iter().all(|a| a.layers.iter().all(|l| l.is_full())));
    }

    #[test]
    fn zero_prunes_only_stragglers() {
        let mon = monitor_with(vec![4.0, 1.0, 1.0, 1.0], 0.9);
        let acts = plan(Strategy::ZeroPri, &mon, vec![1.75; 4], 1.0);
        assert!(!acts[0].layers[0].is_full(), "straggler must prune");
        for w in 1..4 {
            assert!(acts[w].layers[0].is_full(), "normal rank {w} pruned");
        }
    }

    #[test]
    fn mig_assigns_receivers_and_full_idx1() {
        let mon = monitor_with(vec![2.0, 1.0, 1.0, 1.0], 0.9);
        let acts = plan(Strategy::Mig, &mon, vec![1.25; 4], 1.0);
        let mig = acts[0].mig.as_ref().expect("straggler migrates");
        assert!(!mig.receivers.is_empty());
        // MIG never prunes: idx1 full, attention full
        assert_eq!(acts[0].layers[0].mlp_keep1.len(), 32);
        assert_eq!(acts[0].layers[0].attn_bucket, "g00");
        // kept set mirrors into the layer plan
        assert_eq!(acts[0].layers[0].mlp_keep2, mig.kept);
    }

    #[test]
    fn semi_single_straggler_splits() {
        let mon = monitor_with(vec![3.0, 1.0, 1.0, 1.0], 0.9);
        let acts = plan(Strategy::Semi, &mon, vec![1.5; 4], 1.0);
        // heavy straggler → some migration expected under mild costs
        assert!(acts[0].mig.is_some());
        for w in 1..4 {
            assert!(acts[w].mig.is_none());
        }
    }

    #[test]
    fn semi_single_straggler_mirrors_kept_set_into_layers() {
        // Regression: the Eq.(2) branch must reflect the migration plan
        // in the straggler's own mlp plans (kept columns only), exactly
        // like MIG/Eq.(3) — otherwise the migrated slice is computed
        // twice and the partial sums are wrong.
        let mon = monitor_with(vec![3.0, 1.0, 1.0, 1.0], 0.9);
        let acts = plan(Strategy::Semi, &mon, vec![1.5; 4], 1.0);
        let mig = acts[0].mig.as_ref().expect("single straggler migrates here");
        for p in &acts[0].layers {
            assert_eq!(p.mlp_b1, "g00");
            assert_eq!(p.mlp_b2, mig.kept_bucket);
            assert_eq!(p.mlp_keep2, mig.kept);
            assert_eq!(p.mlp_keep1.len(), 32, "idx1 stays full under migration");
        }
    }

    #[test]
    fn semi_resizes_ffn_when_migration_unprofitable() {
        // With prohibitive Φ costs Eq.(2) lands at β≈0: no migration,
        // but the straggler must still shed FFN work via resizing.
        let man = manifest();
        let cfg = BalancerCfg { strategy: Strategy::Semi, ..Default::default() };
        let mut b = Balancer::new(cfg, &man, 7);
        let mon = monitor_with(vec![3.0, 1.0, 1.0, 1.0], 0.9);
        let dear = CostFns {
            omega1_s: 1e-6,
            omega2_per_col: 1e-8,
            phi1_base_s: 1e-1,
            phi1_per_col: 1e-1,
            phi2_per_col: 1e-2,
        };
        let acts = b.plan_iter(&man, &mon, &vec![1.5; 4], 1.0, 10, &dear);
        assert!(acts[0].mig.is_none(), "dear comm must suppress migration");
        assert!(
            acts[0].layers.iter().any(|p| p.mlp_keep2.len() < 32),
            "β≈0 must fall back to FFN resizing"
        );
    }

    #[test]
    fn semi_multi_straggler_grouping() {
        let mon = monitor_with(vec![8.0, 6.0, 1.0, 1.0], 0.9);
        let acts = plan(Strategy::Semi, &mon, vec![4.0; 4], 1.0);
        // at least the slowest should act; others resize or migrate
        assert!(acts[0].mig.is_some() || !acts[0].layers[0].is_full());
        assert!(acts[1].mig.is_some() || !acts[1].layers[0].is_full());
        assert!(acts[2].mig.is_none());
    }

    #[test]
    fn forced_lambda_controls_mig_count() {
        let man = manifest();
        let cfg = BalancerCfg {
            strategy: Strategy::Semi,
            forced_lambda: Some(1),
            ..Default::default()
        };
        let mut b = Balancer::new(cfg, &man, 7);
        let mon = monitor_with(vec![8.0, 6.0, 4.0, 1.0], 0.9);
        let acts = b.plan_iter(&man, &mon, &vec![4.75; 4], 1.0, 10, &costs());
        let migs = acts.iter().filter(|a| a.mig.is_some()).count();
        assert_eq!(migs, 1, "λ=1 → exactly one migrating straggler");
        // the other stragglers resize
        assert!(!acts[1].layers[0].is_full());
    }

    #[test]
    fn gamma_override_forces_uniform_pruning_everywhere() {
        let man = manifest();
        let cfg = BalancerCfg {
            strategy: Strategy::ZeroRd,
            gamma_override: Some(0.5),
            ..Default::default()
        };
        let mut b = Balancer::new(cfg, &man, 7);
        let mon = monitor_with(vec![1.0; 4], 0.9);
        let acts = b.plan_iter(&man, &mon, &vec![1.0; 4], 1.0, 10, &costs());
        for a in &acts {
            assert_eq!(a.layers[0].attn_bucket, "g50");
        }
    }

    #[test]
    fn memory_tight_receivers_veto_migration() {
        let man = manifest();
        let mon = monitor_with(vec![3.0, 1.0, 1.0, 1.0], 0.9);
        // ample headroom: SEMI migrates as usual
        let cfg = BalancerCfg { strategy: Strategy::Semi, ..Default::default() };
        let mut b = Balancer::new(cfg.clone(), &man, 7);
        b.set_mem_headroom(Some(vec![u64::MAX; 4]));
        let acts = b.plan_iter(&man, &mon, &vec![1.5; 4], 1.0, 10, &costs());
        assert!(acts[0].mig.is_some(), "ample headroom must not veto");
        // zero headroom on every receiver: the plan is dropped and the
        // straggler sheds the same demand by resizing instead
        let mut b = Balancer::new(cfg.clone(), &man, 7);
        b.set_mem_headroom(Some(vec![0; 4]));
        let acts = b.plan_iter(&man, &mon, &vec![1.5; 4], 1.0, 10, &costs());
        assert!(acts[0].mig.is_none(), "tight receivers must veto migration");
        assert!(
            acts[0].layers.iter().any(|p| !p.is_full()),
            "vetoed migration must fall back to resizing"
        );
        // pure MIG has no fallback: veto leaves the straggler full-size
        let cfg = BalancerCfg { strategy: Strategy::Mig, ..Default::default() };
        let mut b = Balancer::new(cfg, &man, 7);
        b.set_mem_headroom(Some(vec![0; 4]));
        let mon = monitor_with(vec![2.0, 1.0, 1.0, 1.0], 0.9);
        let acts = b.plan_iter(&man, &mon, &vec![1.25; 4], 1.0, 10, &costs());
        assert!(acts[0].mig.is_none());
        assert!(acts[0].layers.iter().all(|p| p.is_full()));
    }

    #[test]
    fn epoch_end_builds_stats() {
        let man = manifest();
        let mut b = Balancer::new(
            BalancerCfg { strategy: Strategy::ZeroPri, ..Default::default() },
            &man,
            7,
        );
        let mut state = crate::model::ModelState::init(&man.model, 3);
        b.epoch_end(&state); // snapshot only
        assert!(!b.trackers[0][0].qkv.has_stats());
        state.shards[0][0].wqkv.data[0] += 1.0;
        b.epoch_end(&state);
        assert!(b.trackers[0][0].qkv.has_stats());
    }
}
