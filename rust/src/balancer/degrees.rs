//! Per-component TP degree selection (`--degrees auto`, DESIGN.md §18).
//!
//! Fine-grained tensor parallelism lets each model component (attention,
//! MLP) run over its own rank-prefix sub-group instead of the full
//! worker group.  On a heterogeneous cluster that is a real lever: a
//! component whose all-reduce would otherwise synchronize with a
//! heavily χ-skewed rank can simply leave that rank out of its group,
//! trading a larger per-member shard for freedom from the straggler —
//! the same RT-vs-work tradeoff as Eq. 2/3, decided per component at
//! geometry-resolution time rather than per iteration.
//!
//! The selector scores every valid divisor `d` for a component as
//!
//! ```text
//! time(d) = compute(full)/d · max(χ[0..d]) / GEMM_FLOPS  +  comm(d)
//! ```
//!
//! members are always the rank prefix `0..d` (the sub-group formation
//! contract), so the straggler term is the prefix maximum of the
//! iteration-0 χ row.  Compute uses the modeled device rate by default;
//! when the caller passes pretest cost fits, the MLP per-column rate is
//! blended 50/50 with the fitted Φ₂ slope — the same EWMA-style blend
//! `refresh_costs` applies mid-run — so the selection tracks measured
//! hardware where fits exist and the closed model where they don't.
//!
//! Embed and head stay at the uniform degree: they execute replicated,
//! so their degree is declared and validated but buys no time.

use crate::collectives::cost::CostModel;
use crate::contention::timemodel::GEMM_FLOPS_PER_S;
use crate::runtime::manifest::{Degrees, ModelInfo};
use crate::semi::CostFns;

/// Fwd+bwd multiple of a forward pass (bwd ≈ 2× fwd, timemodel contract).
const FWD_BWD: f64 = 3.0;

/// Full (degree-1) attention-branch forward FLOPs for one block.
fn attn_flops_full(m: &ModelInfo) -> f64 {
    let rows = (m.bs * m.seq) as f64;
    let qkv = 2.0 * rows * m.hs as f64 * (3 * m.hs) as f64;
    let core = 4.0 * m.bs as f64 * (m.seq * m.seq) as f64 * m.hs as f64;
    let oproj = 2.0 * rows * (m.hs * m.hs) as f64;
    qkv + core + oproj
}

/// Full (degree-1) MLP-branch forward FLOPs for one block (ffl = 4·hs).
fn mlp_flops_full(m: &ModelInfo) -> f64 {
    let rows = (m.bs * m.seq) as f64;
    let ffl = (crate::runtime::presets::MLP_RATIO * m.hs) as f64;
    2.0 * rows * m.hs as f64 * ffl + 2.0 * rows * ffl * m.hs as f64
}

/// Largest χ on the member prefix `0..d` (clamped to the χ row length —
/// a degenerate row means a homogeneous group).
fn prefix_chi_max(chis: &[f64], d: usize) -> f64 {
    chis[..d.min(chis.len())].iter().cloned().fold(1.0, f64::max)
}

/// Modeled per-member iteration time for a component at degree `d`:
/// χ-skewed compute on the slowest member plus the sub-group all-reduce
/// (one forward reduce and the batched backward reduce per block — the
/// activation-sized buffers dominate, so both price as one ring each).
fn component_time(
    secs_full: f64,
    chis: &[f64],
    net: &CostModel,
    d: usize,
    bytes: usize,
) -> f64 {
    secs_full / d as f64 * prefix_chi_max(chis, d) + 2.0 * net.ring_allreduce(d, bytes)
}

/// Select the per-component degree vector for `m` (already synthesized
/// at the uniform worker count `m.e`) under the iteration-0 χ row.
/// Every returned degree is a valid divisor at its component's own
/// granularity and ≤ `m.e`; a homogeneous χ row returns the uniform
/// vector, keeping `--degrees auto` a no-op on calm clusters.
pub fn select_degrees(
    m: &ModelInfo,
    chis: &[f64],
    net: &CostModel,
) -> Degrees {
    select_degrees_with_costs(m, chis, net, None)
}

/// [`select_degrees`] with optional pretest cost fits blended into the
/// MLP compute rate (Φ₂ is a fitted per-column receiver-compute slope —
/// the measured analogue of the modeled MLP column cost).
pub fn select_degrees_with_costs(
    m: &ModelInfo,
    chis: &[f64],
    net: &CostModel,
    costs: Option<&CostFns>,
) -> Degrees {
    let e = m.e;
    let bytes = m.bs * m.seq * m.hs * 4;

    let attn_secs_full = FWD_BWD * attn_flops_full(m) / GEMM_FLOPS_PER_S;
    let attn = best_degree(
        (1..=e).filter(|&d| m.hs % d == 0 && m.heads % d == 0),
        |d| component_time(attn_secs_full, chis, net, d, bytes),
    );

    let mut mlp_secs_full = FWD_BWD * mlp_flops_full(m) / GEMM_FLOPS_PER_S;
    if let Some(c) = costs {
        if c.phi2_per_col > 0.0 {
            // blend the modeled per-column rate with the fitted Φ₂ slope
            // (cols at degree 1 = the full ffl), 50/50 like refresh_costs
            let cols = (crate::runtime::presets::MLP_RATIO * m.hs) as f64;
            let fitted_full = FWD_BWD * cols * c.phi2_per_col;
            mlp_secs_full = 0.5 * mlp_secs_full + 0.5 * fitted_full;
        }
    }
    let mlp = best_degree(
        (1..=e).filter(|&d| (crate::runtime::presets::MLP_RATIO * m.hs) % d == 0),
        |d| component_time(mlp_secs_full, chis, net, d, bytes),
    );

    // embed/head execute replicated — their degree is declarative
    Degrees { embed: e, attn, mlp, head: e }
}

/// Argmin over candidate degrees; ties break toward the *larger* degree
/// (more parallelism at equal modeled time — the uniform default wins on
/// a homogeneous row because the ring term only then separates degrees).
fn best_degree<I, F>(candidates: I, mut time: F) -> usize
where
    I: Iterator<Item = usize>,
    F: FnMut(usize) -> f64,
{
    let mut best = 1;
    let mut best_t = f64::INFINITY;
    for d in candidates {
        let t = time(d);
        if t < best_t || (t == best_t && d > best) {
            best = d;
            best_t = t;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vit_tiny(e: usize) -> ModelInfo {
        ModelInfo {
            name: "vit-tiny".into(),
            hs: 128,
            depth: 2,
            heads: 8,
            e,
            bs: 8,
            classes: 10,
            seq: 65,
            seq0: 64,
            pd: 48,
            hsl: 128 / e,
            hl: 8 / e,
            hd: 16,
            ffl: 512 / e,
            params_total: 0,
            params_per_worker: 0,
            degrees: Degrees::uniform(e),
        }
    }

    #[test]
    fn homogeneous_row_keeps_the_uniform_vector() {
        let m = vit_tiny(4);
        let d = select_degrees(&m, &[1.0; 4], &CostModel::default());
        assert_eq!(d, Degrees::uniform(4));
    }

    #[test]
    fn heavy_tail_rank_shrinks_block_groups_to_exclude_it() {
        // rank 3 at χ=24: any degree including it pays 24× on the prefix
        // max, so both block components settle on d=2 (d=3 is not a
        // divisor), excluding the straggler entirely
        let m = vit_tiny(4);
        let d = select_degrees(&m, &[1.0, 1.0, 1.0, 24.0], &CostModel::default());
        assert_eq!(d.attn, 2);
        assert_eq!(d.mlp, 2);
        assert_eq!(d.embed, 4, "replicated components keep the uniform degree");
        assert_eq!(d.head, 4);
    }

    #[test]
    fn skew_on_rank_zero_cannot_be_excluded_by_any_prefix() {
        // rank 0 is in every prefix, so the χ term is constant and the
        // widest degree (most parallelism) wins
        let m = vit_tiny(4);
        let d = select_degrees(&m, &[24.0, 1.0, 1.0, 1.0], &CostModel::default());
        assert_eq!(d.attn, 4);
        assert_eq!(d.mlp, 4);
    }

    #[test]
    fn attn_respects_head_divisibility() {
        // heads=2 on hs=128 over e=4: attention candidates are {1, 2}
        // (4 ∤ 2); a calm row then picks 2, mlp keeps 4
        let mut m = vit_tiny(4);
        m.heads = 2;
        let d = select_degrees(&m, &[1.0; 4], &CostModel::default());
        assert_eq!(d.attn, 2);
        assert_eq!(d.mlp, 4);
    }

    #[test]
    fn cost_fit_blend_is_identity_when_fit_matches_model() {
        let m = vit_tiny(4);
        let chis = [1.0, 1.0, 1.0, 24.0];
        let net = CostModel::default();
        let a = select_degrees(&m, &chis, &net);
        // a Φ₂ slope equal to the modeled per-column rate blends to the
        // same total — the selection cannot move
        let cols = (crate::runtime::presets::MLP_RATIO * m.hs) as f64;
        let modeled_per_col = mlp_flops_full(&m) / cols / GEMM_FLOPS_PER_S;
        let costs = CostFns {
            omega1_s: 1e-6,
            omega2_per_col: 1e-7,
            phi1_base_s: 1e-6,
            phi1_per_col: 1e-7,
            phi2_per_col: modeled_per_col,
        };
        let b = select_degrees_with_costs(&m, &chis, &net, Some(&costs));
        assert_eq!(a, b);
    }
}
