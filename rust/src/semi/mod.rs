//! SEMI-migration: the hybrid allocator (paper §IV-B/C).
//!
//! * **Pretest** ([`CostFns`]) — point estimates for the cost functions:
//!   Ω₁ (static allocation overhead of the resized submatrix), Ω₂(n)
//!   (dimension-extraction cost, linear in extracted columns), Φ₁(n)
//!   (migration communication, from the α-β model), Φ₂(n) (remote compute
//!   per column, from measured FFN executable timings).
//! * **Eq. (2)** ([`eq2_beta`]) — a single heavy straggler splits its
//!   excess L·γ columns: β to migration, 1-β to resizing, balancing
//!   straggler-side vs receiver-side added cost.  LHS is decreasing and
//!   RHS increasing in β, so a bisection finds the crossing.
//! * **Eq. (3)** ([`eq3_select_x`]) — with z stragglers sorted by runtime
//!   (slowest first), migrate the top x while f(x) > 0; the rest resize
//!   against T_min.

/// Cost-function point fits, assembled by the trainer's pretest.
#[derive(Debug, Clone, Copy)]
pub struct CostFns {
    /// Ω₁: fixed submatrix allocation/setup cost on the straggler (s)
    pub omega1_s: f64,
    /// Ω₂ slope: extraction cost per resized column (s/col)
    pub omega2_per_col: f64,
    /// Φ₁ affine: per-migration-event latency (s) …
    pub phi1_base_s: f64,
    /// … plus per-column transfer cost (s/col): broadcast of 2·hs weights
    /// out + compact grads back, per layer per iteration
    pub phi1_per_col: f64,
    /// Φ₂ slope: receiver compute per migrated column (s/col)
    pub phi2_per_col: f64,
}

impl CostFns {
    pub fn omega2(&self, cols: f64) -> f64 {
        self.omega2_per_col * cols.max(0.0)
    }

    pub fn phi1(&self, cols: f64) -> f64 {
        if cols <= 0.0 {
            0.0
        } else {
            self.phi1_base_s + self.phi1_per_col * cols
        }
    }

    pub fn phi2(&self, cols: f64) -> f64 {
        self.phi2_per_col * cols.max(0.0)
    }

    /// EWMA-blend with a fresh fit, weight `w` on the fresh values — the
    /// online controller's re-entrant pretest: mid-run refits damp
    /// toward the standing fit instead of jerking the Eq. 2/3 balance
    /// around on one noisy measurement.  `w = 1` replaces outright;
    /// blending two equal fits is the identity (so deterministic modeled
    /// refits stay bitwise stable).
    pub fn blend(&self, fresh: &CostFns, w: f64) -> CostFns {
        let w = w.clamp(0.0, 1.0);
        let mix = |old: f64, new: f64| old + w * (new - old);
        CostFns {
            omega1_s: mix(self.omega1_s, fresh.omega1_s),
            omega2_per_col: mix(self.omega2_per_col, fresh.omega2_per_col),
            phi1_base_s: mix(self.phi1_base_s, fresh.phi1_base_s),
            phi1_per_col: mix(self.phi1_per_col, fresh.phi1_per_col),
            phi2_per_col: mix(self.phi2_per_col, fresh.phi2_per_col),
        }
    }
}

/// Eq. (2): solve Ω₁ + Ω₂(Lγ(1-β)) = Φ₁(Lγβ) + Φ₂(Lγβ/(e-1)) for β∈[0,1].
///
/// Returns the balance point, clamped: if migration is cheaper everywhere
/// → 1.0 (all-migrate); if resizing is cheaper everywhere → 0.0.
pub fn eq2_beta(l_gamma_cols: f64, e: usize, c: &CostFns) -> f64 {
    debug_assert!(e >= 2);
    let lhs_minus_rhs = |beta: f64| {
        let mig = l_gamma_cols * beta;
        let res = l_gamma_cols * (1.0 - beta);
        (c.omega1_s + c.omega2(res)) - (c.phi1(mig) + c.phi2(mig / (e - 1) as f64))
    };
    // LHS-RHS is decreasing in β. Check endpoints.
    if lhs_minus_rhs(0.0) <= 0.0 {
        return 0.0; // even at β=0 migration side dominates → resize only
    }
    if lhs_minus_rhs(1.0) >= 0.0 {
        return 1.0; // resizing side dominates everywhere → migrate all
    }
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if lhs_minus_rhs(mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// One straggler's entry for Eq. (3).
#[derive(Debug, Clone, Copy)]
pub struct StragglerStat {
    pub rank: usize,
    /// iteration runtime T_i (s)
    pub t: f64,
    /// current workload in columns L_i (FFN contraction width available)
    pub l_cols: f64,
}

/// Eq. (3): given stragglers sorted by T descending, all-rank runtimes
/// `t_all`/workloads `l_all`, and T_min, return the largest x such that
/// migrating the top-x is cost-effective (f(x) > 0); x may be 0.
pub fn eq3_select_x(
    stragglers: &[StragglerStat],
    t_all: &[f64],
    l_all: &[f64],
    t_min: f64,
    c: &CostFns,
) -> usize {
    debug_assert!(stragglers.windows(2).all(|w| w[0].t >= w[1].t), "sort desc");
    let e = t_all.len();
    let mut x = 0usize;
    for k in 1..=stragglers.len() {
        if k >= e {
            break; // must leave at least one receiver
        }
        // Γ(x): total migrated columns for the top-k stragglers
        let gamma_x: f64 = stragglers[..k]
            .iter()
            .map(|s| s.l_cols * ((s.t - t_min) / s.t).max(0.0))
            .sum();
        // max receiver slowdown among the other (e-k) tasks
        let mig_ranks: Vec<usize> = stragglers[..k].iter().map(|s| s.rank).collect();
        let max_recv = (0..e)
            .filter(|r| !mig_ranks.contains(r))
            .map(|r| gamma_x / (e - k) as f64 * t_all[r] / l_all[r].max(1e-12))
            .fold(0.0, f64::max);
        let t_k = stragglers[k - 1].t;
        let f = (t_k - t_min) - c.phi1(gamma_x) - max_recv;
        if f > 0.0 {
            x = k;
        } else {
            break; // f decreases with x — the paper's brute-force stop
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cheap_comm() -> CostFns {
        CostFns {
            omega1_s: 1e-4,
            omega2_per_col: 1e-5,
            phi1_base_s: 1e-6,
            phi1_per_col: 1e-7,
            phi2_per_col: 1e-7,
        }
    }

    fn dear_comm() -> CostFns {
        CostFns {
            omega1_s: 1e-6,
            omega2_per_col: 1e-8,
            phi1_base_s: 1e-1,
            phi1_per_col: 1e-1,
            phi2_per_col: 1e-2,
        }
    }

    #[test]
    fn blend_interpolates_and_is_identity_on_equal_fits() {
        let a = cheap_comm();
        let b = dear_comm();
        let half = a.blend(&b, 0.5);
        assert!((half.phi1_per_col - 0.5 * (a.phi1_per_col + b.phi1_per_col)).abs() < 1e-12);
        assert!((half.omega1_s - 0.5 * (a.omega1_s + b.omega1_s)).abs() < 1e-12);
        // w=1 replaces, w=0 keeps
        assert_eq!(a.blend(&b, 1.0).phi2_per_col, b.phi2_per_col);
        assert_eq!(a.blend(&b, 0.0).phi2_per_col, a.phi2_per_col);
        // equal fits: bitwise identity regardless of w (modeled refits)
        let same = a.blend(&a, 0.5);
        assert_eq!(same.omega1_s, a.omega1_s);
        assert_eq!(same.phi1_base_s, a.phi1_base_s);
    }

    #[test]
    fn beta_in_unit_interval() {
        for c in [cheap_comm(), dear_comm()] {
            for l in [8.0, 64.0, 512.0] {
                let b = eq2_beta(l, 8, &c);
                assert!((0.0..=1.0).contains(&b), "β={b}");
            }
        }
    }

    #[test]
    fn cheap_comm_prefers_migration() {
        assert_eq!(eq2_beta(128.0, 8, &cheap_comm()), 1.0);
    }

    #[test]
    fn dear_comm_prefers_resizing() {
        // the Φ₁ base cost makes any migration unprofitable → β ≈ 0
        assert!(eq2_beta(128.0, 8, &dear_comm()) < 1e-6);
    }

    #[test]
    fn beta_balances_interior_case() {
        let c = CostFns {
            omega1_s: 0.0,
            omega2_per_col: 1e-4,
            phi1_base_s: 0.0,
            phi1_per_col: 1e-4,
            phi2_per_col: 0.0,
        };
        // symmetric costs → β = 0.5 exactly
        let b = eq2_beta(100.0, 4, &c);
        assert!((b - 0.5).abs() < 1e-6, "β={b}");
    }

    #[test]
    fn beta_monotone_in_comm_cost() {
        let mut prev = 2.0;
        for phi in [1e-7, 1e-5, 1e-4, 1e-3] {
            let c = CostFns {
                omega1_s: 1e-4,
                omega2_per_col: 1e-5,
                phi1_base_s: 0.0,
                phi1_per_col: phi,
                phi2_per_col: 0.0,
            };
            let b = eq2_beta(128.0, 8, &c);
            assert!(b <= prev + 1e-9, "β not monotone: {b} > {prev}");
            prev = b;
        }
    }

    fn strag(rank: usize, t: f64) -> StragglerStat {
        StragglerStat { rank, t, l_cols: 128.0 }
    }

    #[test]
    fn eq3_zero_when_comm_dominates() {
        let s = [strag(0, 2.0)];
        let t_all = [2.0, 1.0, 1.0, 1.0];
        let l_all = [128.0; 4];
        let x = eq3_select_x(&s, &t_all, &l_all, 1.0, &dear_comm());
        assert_eq!(x, 0);
    }

    #[test]
    fn eq3_selects_slowest_first() {
        let s = [strag(0, 8.0), strag(1, 4.0), strag(2, 2.0)];
        let t_all = [8.0, 4.0, 2.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let l_all = [128.0; 8];
        let x = eq3_select_x(&s, &t_all, &l_all, 1.0, &cheap_comm());
        assert!(x >= 1, "slowest straggler should migrate, x={x}");
        // group = top-x by construction; remaining resize
        assert!(x <= 3);
    }

    #[test]
    fn eq3_x_monotone_in_comm_cost() {
        let s = [strag(0, 8.0), strag(1, 6.0), strag(2, 4.0), strag(3, 2.0)];
        let t_all = [8.0, 6.0, 4.0, 2.0, 1.0, 1.0, 1.0, 1.0];
        let l_all = [128.0; 8];
        let x_cheap = eq3_select_x(&s, &t_all, &l_all, 1.0, &cheap_comm());
        let x_dear = eq3_select_x(&s, &t_all, &l_all, 1.0, &dear_comm());
        assert!(x_cheap >= x_dear, "{x_cheap} < {x_dear}");
    }

    #[test]
    fn eq3_never_starves_receivers() {
        // 7 stragglers of 8 ranks: x can be at most 7 (and the guard keeps
        // at least one receiver).
        let s: Vec<StragglerStat> = (0..7).map(|r| strag(r, 8.0 - r as f64)).collect();
        let t_all = [8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0];
        let l_all = [128.0; 8];
        let x = eq3_select_x(&s, &t_all, &l_all, 1.0, &cheap_comm());
        assert!(x < 8);
    }
}
