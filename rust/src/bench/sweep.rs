//! Scenario × strategy sweep harness (`flextp sweep`, DESIGN.md §12).
//!
//! Runs a matrix of contention scenarios against balancing strategies
//! (each optionally pinned to a replan mode, e.g. `semi@online` vs
//! `semi@epoch`) and writes `BENCH_scenarios.json` — RT, ACC, comm
//! bytes, replan counts, and χ trace stats per cell — plus a rendered
//! table and, where both `semi@online` and `semi@epoch` ran, the online
//! controller's speedup over static per-epoch replanning.
//!
//! Sweeps default to `--time-model modeled`: the SimClock becomes a
//! pure function of the scenario, so cells are deterministic, and
//! re-running a sweep reproduces `BENCH_scenarios.json` byte-for-byte.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{
    DegreeOverrides, ReplanMode, RunCfg, StragglerPlan, Strategy, TimeModel, TransportKind,
};
use crate::contention::{self, ScenarioSpec};
use crate::metrics::RunReport;
use crate::train::trainer::Trainer;
use crate::util::json::{obj, Json};
use crate::util::table::TextTable;

/// One matrix column: a balancing strategy, its replan mode, and its
/// stance towards worker churn.  `churn: true` (the default) lets the
/// trainer act on `join:`/`leave:`/`fail:` scenario events by
/// re-sharding in-process; `churn: false` pins the run to its starting
/// worker count (optionally forced via `e_override`) — the fixed-E
/// baselines the elastic cell is measured against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellSpec {
    pub strategy: Strategy,
    pub replan: ReplanMode,
    /// force the starting worker count (`--e`); `None` keeps the preset's
    pub e_override: Option<usize>,
    /// act on scenario churn events (live elastic re-parallelization)
    pub churn: bool,
    /// collective data plane (DESIGN.md §15); composes with the
    /// elasticity stance, so one matrix covers `live@tcp` without a
    /// duplicated cell list
    pub transport: TransportKind,
    /// per-component TP degree overrides (`--e-attn` etc., DESIGN.md
    /// §18); unset components stay at the effective global `e`
    pub degrees: DegreeOverrides,
    /// `--degrees auto`: pick the per-component vector from the χ row
    /// and the blended pretest cost fits at startup
    pub degrees_auto: bool,
}

impl CellSpec {
    pub fn new(strategy: Strategy, replan: ReplanMode) -> CellSpec {
        CellSpec {
            strategy,
            replan,
            e_override: None,
            churn: true,
            transport: TransportKind::InProc,
            degrees: DegreeOverrides::default(),
            degrees_auto: false,
        }
    }

    pub fn fixed(strategy: Strategy, replan: ReplanMode, e: Option<usize>) -> CellSpec {
        CellSpec { e_override: e, churn: false, ..CellSpec::new(strategy, replan) }
    }

    pub fn with_transport(mut self, transport: TransportKind) -> CellSpec {
        self.transport = transport;
        self
    }

    pub fn with_degrees(mut self, degrees: DegreeOverrides) -> CellSpec {
        self.degrees = degrees;
        self
    }

    pub fn auto_degrees(mut self) -> CellSpec {
        self.degrees_auto = true;
        self
    }

    /// Elasticity/transport/degree tag, the `cell` column of
    /// `BENCH_scenarios.json`: `live`, `live-eN`, `fixed`, or `fixed-eN`,
    /// with a `+tcp` suffix for multi-process cells and a `+deg…` suffix
    /// for fine-grained-degree cells (`+dega2m2` spells the overridden
    /// components, `+degauto` marks balancer-selected degrees).
    /// Uniform in-process cells keep the historic bare tags so existing
    /// consumers (churn-parity CI, `churn_comparisons`) are unaffected.
    pub fn tag(&self) -> String {
        let base = if self.churn { "live" } else { "fixed" };
        let mut tag = match self.e_override {
            Some(e) => format!("{base}-e{e}"),
            None => base.to_string(),
        };
        if self.transport == TransportKind::Tcp {
            tag.push_str("+tcp");
        }
        if self.degrees_auto {
            tag.push_str("+degauto");
        } else if self.degrees.any() {
            tag.push_str("+deg");
            for (c, d) in [
                ('e', self.degrees.embed),
                ('a', self.degrees.attn),
                ('m', self.degrees.mlp),
                ('h', self.degrees.head),
            ] {
                if let Some(d) = d {
                    tag.push_str(&format!("{c}{d}"));
                }
            }
        }
        tag
    }
}

/// One sweep's full specification.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub name: String,
    pub model: String,
    pub epochs: usize,
    pub iters: usize,
    pub eval_iters: usize,
    pub seed: u64,
    pub time_model: TimeModel,
    /// rank binary for `@tcp` cells (`--rank-exe`); `None` re-execs the
    /// current executable
    pub rank_exe: Option<std::path::PathBuf>,
    /// trace every cell and embed its phase-time breakdown as a
    /// `phases` object per cell (default true — tracing has zero
    /// observer effect on the simulated metrics, so the sweep numbers
    /// are bitwise identical either way)
    pub trace: bool,
    /// (label, scenario) rows of the matrix
    pub scenarios: Vec<(String, ScenarioSpec)>,
    /// strategy/replan/elasticity/transport columns of the matrix
    pub cells: Vec<CellSpec>,
}

impl SweepSpec {
    fn base(name: &str) -> SweepSpec {
        SweepSpec {
            name: name.to_string(),
            model: "vit-tiny".to_string(),
            epochs: 3,
            iters: 12,
            eval_iters: 4,
            seed: 42,
            time_model: TimeModel::Modeled,
            rank_exe: None,
            trace: true,
            scenarios: Vec::new(),
            cells: Vec::new(),
        }
    }

    /// Built-in sweep presets (`--preset`).
    pub fn preset(name: &str) -> Result<SweepSpec> {
        let mut s = SweepSpec::base(name);
        match name {
            // CI-sized 2 scenarios × 2 strategies: the calm control and
            // the mid-epoch tenant arrival where online replanning wins
            "smoke" => {
                s.epochs = 2;
                s.iters = 10;
                let killed = {
                    // same step6 trace, but the job is killed after
                    // iteration 13 (mid epoch 2) and resumed from its
                    // checkpoint — under the modeled clock this cell
                    // must reproduce the uninterrupted step6 cell
                    let mut sc = contention::preset("step6")?;
                    sc.preempt = Some(13);
                    sc
                };
                s.scenarios = vec![
                    ("calm".into(), contention::preset("calm")?),
                    ("step6".into(), contention::preset("step6")?),
                    ("step6-kill13".into(), killed),
                ];
                s.cells = vec![
                    CellSpec::new(Strategy::Semi, ReplanMode::Online),
                    CellSpec::new(Strategy::Semi, ReplanMode::Epoch),
                ];
            }
            // the paper's dynamic story: bursty traces vs the controller
            "bursty" => {
                s.scenarios = vec![
                    ("step6".into(), contention::preset("step6")?),
                    ("bursty".into(), contention::preset("bursty")?),
                    ("markov-duo".into(), contention::preset("markov-duo")?),
                ];
                s.cells = vec![
                    CellSpec::new(Strategy::Semi, ReplanMode::Online),
                    CellSpec::new(Strategy::Semi, ReplanMode::Epoch),
                    CellSpec::new(Strategy::Mig, ReplanMode::Online),
                    CellSpec::new(Strategy::Baseline, ReplanMode::Iter),
                ];
            }
            // the live-elasticity headline: worker r3 turns straggler
            // (χ24 — past what γ-capped pruning can absorb) and then
            // fails, and later a replacement joins.  The `live` cell
            // re-shards 4→2→4 in-process; the fixed-E baselines either
            // ride out the straggler at E=4 or pay 2× compute at E=2
            // for the whole run — `churn_comparisons()` pins that the
            // elastic cell beats both on modeled RT (tests/elastic_live.rs)
            "churn" => {
                s.scenarios = vec![(
                    "worker-churn".into(),
                    ScenarioSpec::parse(
                        "fail:r3@iter6,join:r3@iter30,burst:r3@x24:iters6-30,chimax:32",
                    )?,
                )];
                s.cells = vec![
                    CellSpec::new(Strategy::Semi, ReplanMode::Online),
                    CellSpec::fixed(Strategy::Semi, ReplanMode::Online, None),
                    CellSpec::fixed(Strategy::Semi, ReplanMode::Online, Some(2)),
                ];
            }
            // memory pressure: a mid-run capacity squeeze on a rank that
            // simultaneously turns straggler (the balancer must steer
            // migration *away* from it), plus a forced hard OOM in a
            // second scenario.  Live cells recover through the churn
            // eviction path; the fixed-E baseline turns the OOM into an
            // explicit `"error"` row instead of a lost cell.
            "mem" => {
                s.scenarios = vec![
                    (
                        "memsqueeze".into(),
                        ScenarioSpec::parse(
                            "memsqueeze:r1@iter6:x0.5,burst:r1@x6:iters6-24,chimax:32",
                        )?,
                    ),
                    ("hard-oom".into(), ScenarioSpec::parse("oom:r2@iter8")?),
                ];
                s.cells = vec![
                    CellSpec::new(Strategy::Semi, ReplanMode::Online),
                    CellSpec::new(Strategy::Semi, ReplanMode::Epoch),
                    CellSpec::new(Strategy::Mig, ReplanMode::Online),
                    CellSpec::new(Strategy::Baseline, ReplanMode::Iter),
                    CellSpec::fixed(Strategy::Semi, ReplanMode::Online, None),
                ];
            }
            // the fine-grained TP headline (DESIGN.md §18): rank 3 is a
            // heavy straggler for the whole run (χ24 — past what the
            // γ-capped pruning of the uniform cell can absorb).  The
            // mixed-degree cell shrinks the attn/mlp groups to the 0..2
            // rank prefix, leaving r3 out of block compute and both
            // block all-reduces entirely; `--degrees auto` must derive
            // the same vector from the iteration-0 χ row.
            // `finegrained_comparisons()` pins mixed beating uniform-E
            // on modeled RT at equal final ACC (CI finegrained-parity).
            "finegrained" => {
                s.scenarios = vec![(
                    "tail-r3".into(),
                    ScenarioSpec::parse("burst:r3@x24:iters0-,chimax:32")?,
                )];
                let uni = CellSpec::fixed(Strategy::Semi, ReplanMode::Online, None);
                s.cells = vec![
                    uni,
                    uni.with_degrees(DegreeOverrides {
                        attn: Some(2),
                        mlp: Some(2),
                        ..DegreeOverrides::default()
                    }),
                    uni.auto_degrees(),
                ];
            }
            _ => bail!("unknown sweep preset '{name}' (smoke|bursty|churn|mem|finegrained)"),
        }
        Ok(s)
    }
}

/// Parse a strategy cell: `"semi@online"` → Semi/Online; a bare
/// strategy name keeps the legacy per-iteration replanning.  Further
/// `@`-segments compose in any order, at most once each:
///
/// * elasticity — `live` (default) acts on churn events, `fixed`
///   ignores them, `fixed-e2` additionally forces the starting worker
///   count;
/// * transport — `inproc` (default) or `tcp` picks the collective data
///   plane, so `semi@online@live@tcp` runs the elastic cell over real
///   rank processes without a second cell grammar;
/// * degrees — `degauto` turns on balancer-selected per-component
///   degrees, `deg` followed by component letters with degrees
///   (`dega2m2` = `--e-attn 2 --e-mlp 2`) pins them explicitly
///   (DESIGN.md §18).
pub fn parse_cell(s: &str) -> Result<CellSpec> {
    let mut parts = s.split('@');
    let st = Strategy::parse(parts.next().unwrap_or(""))?;
    let rp = match parts.next() {
        Some(rp) => ReplanMode::parse(rp)?,
        None => ReplanMode::Iter,
    };
    let mut cell = CellSpec::new(st, rp);
    let (mut saw_elastic, mut saw_transport, mut saw_degrees) = (false, false, false);
    for seg in parts {
        if matches!(seg, "inproc" | "tcp") {
            if saw_transport {
                bail!("duplicate transport tag '{seg}' in cell '{s}'");
            }
            saw_transport = true;
            cell.transport = TransportKind::parse(seg)?;
            continue;
        }
        if let Some(rest) = seg.strip_prefix("deg") {
            if saw_degrees {
                bail!("duplicate degree tag '{seg}' in cell '{s}'");
            }
            saw_degrees = true;
            if rest == "auto" {
                cell.degrees_auto = true;
            } else {
                cell.degrees = parse_degree_overrides(rest)
                    .with_context(|| format!("bad degree tag '{seg}' in cell '{s}'"))?;
            }
            continue;
        }
        if saw_elastic {
            bail!("duplicate elasticity tag '{seg}' in cell '{s}'");
        }
        saw_elastic = true;
        let (base, e) = match seg.split_once("-e") {
            Some((b, n)) => {
                let e: usize = n
                    .parse()
                    .with_context(|| format!("bad worker count in cell elasticity '{seg}'"))?;
                (b, Some(e))
            }
            None => (seg, None),
        };
        match base {
            "live" => cell.churn = true,
            "fixed" => cell.churn = false,
            _ => bail!(
                "unknown cell tag '{seg}' (live|fixed, optionally -eN, a \
                 transport: inproc|tcp, or degrees: degauto|deg<spec>)"
            ),
        }
        cell.e_override = e;
    }
    Ok(cell)
}

/// Parse a compact per-component degree spec: component letters `e`
/// (embed) / `a` (attn) / `m` (mlp) / `h` (head), each followed by its
/// degree — `a2m2` reads as `--e-attn 2 --e-mlp 2`.
fn parse_degree_overrides(s: &str) -> Result<DegreeOverrides> {
    let mut ov = DegreeOverrides::default();
    let mut chars = s.chars().peekable();
    while let Some(c) = chars.next() {
        let mut n = String::new();
        while chars.peek().map_or(false, |d| d.is_ascii_digit()) {
            n.push(chars.next().expect("peeked"));
        }
        let d: usize = n
            .parse()
            .with_context(|| format!("component '{c}' needs a degree (e.g. '{c}2')"))?;
        let slot = match c {
            'e' => &mut ov.embed,
            'a' => &mut ov.attn,
            'm' => &mut ov.mlp,
            'h' => &mut ov.head,
            _ => bail!("unknown degree component '{c}' (e|a|m|h)"),
        };
        if slot.is_some() {
            bail!("duplicate degree component '{c}'");
        }
        *slot = Some(d);
    }
    if !ov.any() {
        bail!("empty degree spec");
    }
    Ok(ov)
}

/// Parse `"label=dsl;label2=dsl"` (bare specs get s0, s1, … labels).
pub fn parse_scenarios(s: &str) -> Result<Vec<(String, ScenarioSpec)>> {
    let mut out = Vec::new();
    for (i, item) in s.split(';').filter(|x| !x.trim().is_empty()).enumerate() {
        let (label, dsl) = match item.split_once('=') {
            Some((l, d)) => (l.trim().to_string(), d),
            None => (format!("s{i}"), item),
        };
        out.push((label, ScenarioSpec::parse(dsl.trim())?));
    }
    Ok(out)
}

/// One finished matrix cell.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub scenario: String,
    pub strategy: String,
    pub replan: String,
    /// elasticity tag (`CellSpec::tag`): live / fixed / fixed-eN
    pub cell: String,
    /// mean per-epoch simulated runtime (the paper's RT)
    pub rt: f64,
    pub final_acc: f64,
    pub best_acc: f64,
    pub comm_bytes: u64,
    pub replans: u64,
    pub chi_mean: f64,
    pub chi_max: f64,
    /// peak modeled per-rank memory high-water-mark across epochs
    pub mem_hwm_bytes: u64,
    /// tightest peak-usage headroom seen across epochs
    pub mem_headroom_min_bytes: u64,
    /// rank-iterations that degraded to activation checkpointing
    pub recompute_iters: u64,
    /// typed fault variant when the cell died mid-run (`"OutOfMemory"`,
    /// `"NoViableWorkerCount"`, …) — an explicit error row in
    /// `BENCH_scenarios.json` instead of a silently lost cell
    pub error: Option<String>,
    /// phase-time breakdown from the cell's trace (`SweepSpec::trace`);
    /// `None` for untraced and error cells — serialized as an explicit
    /// `"phases": null` so the schema is stable
    pub phases: Option<crate::trace::report::PhaseTotals>,
}

impl SweepCell {
    fn from_report(
        scenario: &str,
        cell: &CellSpec,
        r: &RunReport,
        phases: Option<crate::trace::report::PhaseTotals>,
    ) -> Self {
        SweepCell {
            scenario: scenario.to_string(),
            strategy: cell.strategy.name().to_string(),
            replan: cell.replan.name().to_string(),
            cell: cell.tag(),
            rt: r.rt(),
            final_acc: r.final_acc(),
            best_acc: r.best_acc(),
            comm_bytes: r.total_comm_bytes(),
            replans: r.total_replans(),
            chi_mean: r.chi_mean(),
            chi_max: r.chi_max(),
            mem_hwm_bytes: r.mem_hwm_max(),
            mem_headroom_min_bytes: r.mem_headroom_min(),
            recompute_iters: r.total_recompute_iters(),
            error: None,
            phases,
        }
    }

    fn from_error(scenario: &str, cell: &CellSpec, variant: String) -> Self {
        SweepCell {
            scenario: scenario.to_string(),
            strategy: cell.strategy.name().to_string(),
            replan: cell.replan.name().to_string(),
            cell: cell.tag(),
            rt: 0.0,
            final_acc: 0.0,
            best_acc: 0.0,
            comm_bytes: 0,
            replans: 0,
            chi_mean: 0.0,
            chi_max: 0.0,
            mem_hwm_bytes: 0,
            mem_headroom_min_bytes: 0,
            recompute_iters: 0,
            error: Some(variant),
            phases: None,
        }
    }
}

/// Short variant name when `err`'s chain contains one of the
/// simulator's typed faults.  Only these become error rows; untyped
/// errors (I/O, bugs) still abort the whole sweep.
fn error_variant(err: &anyhow::Error) -> Option<String> {
    fn head(dbg: String) -> String {
        dbg.split(['{', '(', ' ']).next().unwrap_or_default().to_string()
    }
    for cause in err.chain() {
        if let Some(e) = cause.downcast_ref::<crate::memory::MemError>() {
            return Some(head(format!("{e:?}")));
        }
        if let Some(e @ contention::ScenarioError::NoViableWorkerCount { .. }) =
            cause.downcast_ref::<contention::ScenarioError>()
        {
            return Some(head(format!("{e:?}")));
        }
        if let Some(e) = cause.downcast_ref::<crate::collectives::transport::TransportError>() {
            return Some(head(format!("{e:?}")));
        }
    }
    None
}

/// Sweep results: cells + the online-vs-epoch comparisons.
#[derive(Debug, Clone, Default)]
pub struct SweepReport {
    pub name: String,
    pub model: String,
    pub epochs: usize,
    pub iters: usize,
    pub cells: Vec<SweepCell>,
}

/// Run the full scenario × strategy matrix.
pub fn run_sweep(spec: &SweepSpec) -> Result<SweepReport> {
    let mut cells = Vec::new();
    for (label, scen) in &spec.scenarios {
        for cell in &spec.cells {
            let mut cfg = RunCfg::new(&spec.model);
            cfg.balancer.strategy = cell.strategy;
            cfg.balancer.replan = cell.replan;
            cfg.e_override = cell.e_override;
            cfg.degree_overrides = cell.degrees;
            cfg.degrees_auto = cell.degrees_auto;
            cfg.train.churn = cell.churn;
            cfg.train.epochs = spec.epochs;
            cfg.train.iters_per_epoch = spec.iters;
            cfg.train.eval_iters = spec.eval_iters;
            cfg.train.seed = spec.seed;
            cfg.train.time_model = spec.time_model;
            cfg.train.transport = cell.transport;
            cfg.train.rank_exe = spec.rank_exe.clone();
            cfg.train.trace = spec.trace;
            cfg.stragglers = StragglerPlan::Scenario(scen.clone());
            match run_cell(cfg, scen.preempt, label, cell) {
                Ok((r, phases)) => cells.push(SweepCell::from_report(label, cell, &r, phases)),
                // a typed mid-run fault (OOM, no viable worker count,
                // transport death) is a *result*, not a harness failure:
                // record it as an explicit error row
                Err(err) => match error_variant(&err) {
                    Some(variant) => cells.push(SweepCell::from_error(label, cell, variant)),
                    None => {
                        return Err(err.context(format!(
                            "cell {label} × {}@{}@{}",
                            cell.strategy.name(),
                            cell.replan.name(),
                            cell.tag()
                        )))
                    }
                },
            }
        }
    }
    Ok(SweepReport {
        name: spec.name.clone(),
        model: spec.model.clone(),
        epochs: spec.epochs,
        iters: spec.iters,
        cells,
    })
}

/// Execute one matrix cell.  A scenario with a `preempt:iterG` event
/// runs the full kill/checkpoint/resume cycle mid-run: train to G, save
/// an atomic snapshot, drop the trainer (the "kill"), rebuild from the
/// snapshot, and finish — under the modeled clock the resulting report
/// is bitwise identical to an uninterrupted cell (the parity that
/// `tests/scenario_sweep.rs` pins).
fn run_cell(
    cfg: RunCfg,
    preempt: Option<usize>,
    label: &str,
    cell: &CellSpec,
) -> Result<(RunReport, Option<crate::trace::report::PhaseTotals>)> {
    let Some(g) = preempt else {
        let mut t = Trainer::new(cfg)?;
        let r = t.run()?;
        let phases = phase_totals_of(&t);
        return Ok((r, phases));
    };
    let mut t = Trainer::new(cfg.clone())?;
    t.run_to(Some(g as u64))?;
    if t.is_complete() {
        // preemption point beyond the schedule: nothing to resume
        let phases = phase_totals_of(&t);
        return Ok((t.report.clone(), phases));
    }
    let dir = std::env::temp_dir().join(format!(
        "flextp_preempt_{}_{}_{}_{}_{}",
        std::process::id(),
        label.replace(|c: char| !c.is_ascii_alphanumeric(), "-"),
        cell.strategy.name(),
        cell.replan.name(),
        cell.tag(),
    ));
    let path = dir.join(crate::checkpoint::ckpt_filename(t.giter()));
    t.save_checkpoint(&path)?;
    drop(t); // the kill: every live trainer structure is gone
    let mut resumed = Trainer::resume_from(cfg, &path)?;
    let r = resumed.run()?;
    let _ = std::fs::remove_dir_all(&dir);
    // trace buffers are not checkpointed (DESIGN.md §17): the phases of
    // a kill/resume cell cover the resumed segment only
    let phases = phase_totals_of(&resumed);
    Ok((r, phases))
}

/// The cell's whole-run phase totals, aggregated from its tracer
/// (`None` when the cell ran untraced).
fn phase_totals_of(t: &Trainer) -> Option<crate::trace::report::PhaseTotals> {
    let tr = t.tracer.as_ref()?;
    let tr = tr.lock().expect("tracer lock");
    if !tr.spans_on() {
        return None;
    }
    Some(crate::trace::report::Attribution::from_spans(tr.merged()).phase_totals())
}

impl SweepReport {
    /// Exact-key lookup: a comparison side must match on the *full*
    /// (scenario, strategy, replan, cell tag) key and be a healthy row.
    /// The pre-tag lookup matched the first non-error cell of a
    /// strategy/replan, so an `"error"` row (or a multi-tag matrix)
    /// silently paired cells of *different* elasticity tags — a bogus
    /// cross-tag speedup instead of an omitted entry.
    fn find(&self, scenario: &str, strategy: &str, replan: &str, tag: &str) -> Option<&SweepCell> {
        self.cells.iter().find(|c| {
            c.scenario == scenario
                && c.strategy == strategy
                && c.replan == replan
                && c.cell == tag
                && c.error.is_none()
        })
    }

    /// Per scenario and cell tag with both `SEMI@online` and
    /// `SEMI@epoch` cells: (scenario, rt_online, rt_epoch, speedup,
    /// acc_delta_pp).  A typed-fault `"error"` row on either side drops
    /// the pair — the entry is omitted, never NaN/inf or a cross-tag
    /// mispairing.
    pub fn comparisons(&self) -> Vec<(String, f64, f64, f64, f64)> {
        let mut out = Vec::new();
        for label in self.scenario_labels() {
            for tag in self.cell_tags() {
                let (Some(on), Some(ep)) = (
                    self.find(&label, "SEMI", "online", &tag),
                    self.find(&label, "SEMI", "epoch", &tag),
                ) else {
                    continue;
                };
                let speedup = if on.rt > 0.0 { ep.rt / on.rt } else { 0.0 };
                out.push((
                    label.clone(),
                    on.rt,
                    ep.rt,
                    speedup,
                    100.0 * (on.final_acc - ep.final_acc),
                ));
            }
        }
        out
    }

    /// Per scenario with a `live` cell and at least one `fixed*` cell of
    /// the *same strategy and replan mode*: (scenario, rt_live,
    /// rt_fixed_best, speedup over the *best* fixed-E baseline,
    /// final-ACC delta vs that baseline in pp).  A speedup > 1 means the
    /// elastic cell beat every fixed-E baseline on modeled RT — the
    /// churn acceptance bar (tests/elastic_live.rs).  Error rows are
    /// skipped on either side: an errored live cell never falls through
    /// to a live cell of another strategy, and errored baselines drop
    /// out of the best-of pool.
    pub fn churn_comparisons(&self) -> Vec<(String, f64, f64, f64, f64)> {
        let mut out = Vec::new();
        for label in self.scenario_labels() {
            for live in self
                .cells
                .iter()
                .filter(|c| c.scenario == label && c.cell == "live" && c.error.is_none())
            {
                let fixed: Vec<&SweepCell> = self
                    .cells
                    .iter()
                    .filter(|c| {
                        c.scenario == label
                            && c.strategy == live.strategy
                            && c.replan == live.replan
                            && c.cell.starts_with("fixed")
                            && c.error.is_none()
                    })
                    .collect();
                let Some(best) = fixed.iter().copied().min_by(|a, b| a.rt.total_cmp(&b.rt))
                else {
                    continue;
                };
                let speedup = if live.rt > 0.0 { best.rt / live.rt } else { 0.0 };
                out.push((
                    label.clone(),
                    live.rt,
                    best.rt,
                    speedup,
                    100.0 * (live.final_acc - best.final_acc),
                ));
            }
        }
        out
    }

    /// Per scenario pairing each degree-tagged cell (`…+degXN…` /
    /// `…+degauto`) against the uniform-degree cell with the same
    /// elasticity/transport tag, strategy, and replan: (scenario, degree
    /// cell tag, rt_mixed, rt_uniform, speedup, acc_delta_pp).  Speedup
    /// > 1 means the mixed-degree vector beat uniform-E on modeled RT —
    /// the fine-grained acceptance bar (DESIGN.md §18, CI
    /// finegrained-parity).  Error rows on either side drop the pair.
    pub fn finegrained_comparisons(&self) -> Vec<(String, String, f64, f64, f64, f64)> {
        let mut out = Vec::new();
        for label in self.scenario_labels() {
            for deg in self
                .cells
                .iter()
                .filter(|c| c.scenario == label && c.cell.contains("+deg") && c.error.is_none())
            {
                let base = &deg.cell[..deg.cell.find("+deg").expect("tag has +deg")];
                let Some(uni) = self.find(&label, &deg.strategy, &deg.replan, base) else {
                    continue;
                };
                let speedup = if deg.rt > 0.0 { uni.rt / deg.rt } else { 0.0 };
                out.push((
                    label.clone(),
                    deg.cell.clone(),
                    deg.rt,
                    uni.rt,
                    speedup,
                    100.0 * (deg.final_acc - uni.final_acc),
                ));
            }
        }
        out
    }

    fn scenario_labels(&self) -> Vec<String> {
        let mut seen: Vec<String> = Vec::new();
        for c in &self.cells {
            if !seen.contains(&c.scenario) {
                seen.push(c.scenario.clone());
            }
        }
        seen
    }

    fn cell_tags(&self) -> Vec<String> {
        let mut seen: Vec<String> = Vec::new();
        for c in &self.cells {
            if !seen.contains(&c.cell) {
                seen.push(c.cell.clone());
            }
        }
        seen
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("name", self.name.as_str().into()),
            ("model", self.model.as_str().into()),
            ("epochs", self.epochs.into()),
            ("iters_per_epoch", self.iters.into()),
            (
                "cells",
                Json::Arr(
                    self.cells
                        .iter()
                        .map(|c| {
                            obj([
                                ("scenario", c.scenario.as_str().into()),
                                ("strategy", c.strategy.as_str().into()),
                                ("replan", c.replan.as_str().into()),
                                ("cell", c.cell.as_str().into()),
                                ("rt", c.rt.into()),
                                ("final_acc", c.final_acc.into()),
                                ("best_acc", c.best_acc.into()),
                                ("comm_bytes", (c.comm_bytes as f64).into()),
                                ("replans", (c.replans as f64).into()),
                                ("chi_mean", c.chi_mean.into()),
                                ("chi_max", c.chi_max.into()),
                                ("mem_hwm_bytes", (c.mem_hwm_bytes as f64).into()),
                                (
                                    "mem_headroom_min_bytes",
                                    (c.mem_headroom_min_bytes as f64).into(),
                                ),
                                ("recompute_iters", (c.recompute_iters as f64).into()),
                                (
                                    "error",
                                    match &c.error {
                                        Some(v) => v.as_str().into(),
                                        None => Json::Null,
                                    },
                                ),
                                (
                                    "phases",
                                    match &c.phases {
                                        Some(p) => p.to_json(),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "comparisons",
                Json::Arr(
                    self.comparisons()
                        .into_iter()
                        .map(|(s, on, ep, sp, dacc)| {
                            obj([
                                ("scenario", s.into()),
                                ("rt_online", on.into()),
                                ("rt_epoch", ep.into()),
                                ("online_speedup", sp.into()),
                                ("acc_delta_pp", dacc.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "churn_comparisons",
                Json::Arr(
                    self.churn_comparisons()
                        .into_iter()
                        .map(|(s, live, fixed, sp, dacc)| {
                            obj([
                                ("scenario", s.into()),
                                ("rt_live", live.into()),
                                ("rt_fixed_best", fixed.into()),
                                ("elastic_speedup", sp.into()),
                                ("acc_delta_pp", dacc.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "finegrained_comparisons",
                Json::Arr(
                    self.finegrained_comparisons()
                        .into_iter()
                        .map(|(s, tag, mixed, uniform, sp, dacc)| {
                            obj([
                                ("scenario", s.into()),
                                ("cell", tag.into()),
                                ("rt_mixed", mixed.into()),
                                ("rt_uniform", uniform.into()),
                                ("mixed_speedup", sp.into()),
                                ("acc_delta_pp", dacc.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Rendered cell table + comparison lines.
    pub fn render(&self) -> String {
        let mut t = TextTable::new(
            &format!("scenario sweep '{}' ({}, RT in sim-seconds)", self.name, self.model),
            &[
                "scenario", "strategy", "replan", "cell", "RT", "ACC", "comm", "replans",
                "chi_mean", "chi_max", "mem_hwm", "rcmp", "wait_s", "straggler", "error",
            ],
        );
        for c in &self.cells {
            // the trace columns: total all-reduce wait and the attributed
            // straggler ("r1@97%"), blank for untraced/error rows
            let (wait, straggler) = match &c.phases {
                Some(p) => (
                    format!("{:.4}", p.wait_s),
                    match p.straggler {
                        Some(r) => format!("r{r}@{:.0}%", p.attributed_pct),
                        None => "-".to_string(),
                    },
                ),
                None => (String::new(), String::new()),
            };
            t.row(&[
                c.scenario.clone(),
                c.strategy.clone(),
                c.replan.clone(),
                c.cell.clone(),
                format!("{:.4}", c.rt),
                format!("{:.1}%", 100.0 * c.final_acc),
                crate::util::fmt_bytes(c.comm_bytes),
                c.replans.to_string(),
                format!("{:.2}", c.chi_mean),
                format!("{:.1}", c.chi_max),
                crate::util::fmt_bytes(c.mem_hwm_bytes),
                c.recompute_iters.to_string(),
                wait,
                straggler,
                c.error.clone().unwrap_or_default(),
            ]);
        }
        let mut out = t.render();
        for (s, on, ep, sp, dacc) in self.comparisons() {
            out.push_str(&format!(
                "\n{s}: online RT {on:.4}s vs epoch {ep:.4}s → {sp:.2}× \
                 (ΔACC {dacc:+.1}pp)"
            ));
        }
        for (s, live, fixed, sp, dacc) in self.churn_comparisons() {
            out.push_str(&format!(
                "\n{s}: elastic RT {live:.4}s vs best fixed-E {fixed:.4}s → {sp:.2}× \
                 (ΔACC {dacc:+.1}pp)"
            ));
        }
        for (s, tag, mixed, uniform, sp, dacc) in self.finegrained_comparisons() {
            out.push_str(&format!(
                "\n{s}: {tag} RT {mixed:.4}s vs uniform {uniform:.4}s → {sp:.2}× \
                 (ΔACC {dacc:+.1}pp)"
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_and_scenario_parsing() {
        assert_eq!(
            parse_cell("semi@online").unwrap(),
            CellSpec::new(Strategy::Semi, ReplanMode::Online)
        );
        assert_eq!(parse_cell("mig").unwrap(), CellSpec::new(Strategy::Mig, ReplanMode::Iter));
        let fx = parse_cell("semi@online@fixed-e2").unwrap();
        assert_eq!(fx, CellSpec::fixed(Strategy::Semi, ReplanMode::Online, Some(2)));
        assert_eq!(fx.tag(), "fixed-e2");
        assert_eq!(parse_cell("semi@online@fixed").unwrap().tag(), "fixed");
        assert_eq!(parse_cell("semi@online@live").unwrap().tag(), "live");
        assert!(parse_cell("semi@online@sideways").is_err());
        assert!(parse_cell("semi@online@fixed-ex").is_err());
        assert!(parse_cell("semi@sometimes").is_err());
        assert!(parse_cell("vibes@online").is_err());
        // transport tags compose with elasticity tags in either order
        let tcp = parse_cell("semi@online@tcp").unwrap();
        assert_eq!(tcp, CellSpec::new(Strategy::Semi, ReplanMode::Online)
            .with_transport(TransportKind::Tcp));
        assert_eq!(tcp.tag(), "live+tcp");
        assert_eq!(
            parse_cell("semi@online@tcp@fixed-e2").unwrap(),
            parse_cell("semi@online@fixed-e2@tcp").unwrap()
        );
        assert_eq!(parse_cell("semi@online@fixed-e2@tcp").unwrap().tag(), "fixed-e2+tcp");
        // inproc is the explicit spelling of the default (bare tag)
        assert_eq!(parse_cell("semi@online@inproc").unwrap().tag(), "live");
        assert!(parse_cell("semi@online@tcp@inproc").is_err(), "duplicate transport");
        assert!(parse_cell("semi@online@live@fixed").is_err(), "duplicate elasticity");
        // degree tags compose with the elasticity/transport segments
        let dg = parse_cell("semi@online@fixed@dega2m2").unwrap();
        assert_eq!(
            dg.degrees,
            DegreeOverrides { attn: Some(2), mlp: Some(2), ..DegreeOverrides::default() }
        );
        assert!(!dg.degrees_auto && !dg.churn);
        assert_eq!(dg.tag(), "fixed+dega2m2");
        let auto = parse_cell("semi@online@degauto").unwrap();
        assert!(auto.degrees_auto && !auto.degrees.any());
        assert_eq!(auto.tag(), "live+degauto");
        assert_eq!(parse_cell("semi@online@tcp@dega4").unwrap().tag(), "live+tcp+dega4");
        assert!(parse_cell("semi@online@dega2@degauto").is_err(), "duplicate degree tag");
        assert!(parse_cell("semi@online@degz2").is_err(), "unknown component");
        assert!(parse_cell("semi@online@dega").is_err(), "component without a degree");
        assert!(parse_cell("semi@online@dega2a4").is_err(), "duplicate component");
        assert!(parse_cell("semi@online@deg").is_err(), "empty degree spec");
        let sc = parse_scenarios("a=burst:r1@x4:iters0-4;step:r2@x3:iters1-").unwrap();
        assert_eq!(sc.len(), 2);
        assert_eq!(sc[0].0, "a");
        assert_eq!(sc[1].0, "s1");
        assert!(parse_scenarios("a=meteor:r1@x2:iters0-4").is_err());
    }

    #[test]
    fn presets_build() {
        for p in ["smoke", "bursty", "churn", "mem", "finegrained"] {
            let s = SweepSpec::preset(p).unwrap();
            assert!(!s.scenarios.is_empty());
            assert!(!s.cells.is_empty());
            assert_eq!(s.time_model, TimeModel::Modeled);
        }
        assert!(SweepSpec::preset("galaxy").is_err());
        let s = SweepSpec::preset("smoke").unwrap();
        assert_eq!(s.scenarios.len(), 3);
        assert_eq!(s.cells.len(), 2);
        // the smoke matrix carries a kill/resume cell; its χ trace is
        // the plain step6 one
        let killed = &s.scenarios[2].1;
        assert_eq!(killed.preempt, Some(13));
        assert_eq!(killed.events, s.scenarios[1].1.events);
        // the churn matrix pits one live cell against two fixed-E
        // baselines over a worker fail/join scenario
        let c = SweepSpec::preset("churn").unwrap();
        assert_eq!(c.scenarios.len(), 1);
        assert_eq!(c.scenarios[0].1.churn.len(), 2);
        let tags: Vec<String> = c.cells.iter().map(|x| x.tag()).collect();
        assert_eq!(tags, ["live", "fixed", "fixed-e2"]);
        // the mem matrix carries one squeeze and one hard-OOM scenario,
        // and pits live cells against a fixed-E (error-row) baseline
        let m = SweepSpec::preset("mem").unwrap();
        assert_eq!(m.scenarios.len(), 2);
        assert_eq!(m.scenarios[0].1.mem.len(), 1);
        assert_eq!(m.scenarios[1].1.mem.len(), 1);
        assert!(m.cells.iter().any(|x| !x.churn));
        // the finegrained matrix pins a uniform fixed-E cell against an
        // explicit a2m2 vector and the balancer-selected one
        let fg = SweepSpec::preset("finegrained").unwrap();
        assert_eq!(fg.scenarios.len(), 1);
        let tags: Vec<String> = fg.cells.iter().map(|x| x.tag()).collect();
        assert_eq!(tags, ["fixed", "fixed+dega2m2", "fixed+degauto"]);
        assert!(fg.cells.iter().all(|x| !x.churn));
    }

    #[test]
    fn report_json_and_comparisons() {
        let mut r = SweepReport {
            name: "t".into(),
            model: "vit-tiny".into(),
            epochs: 2,
            iters: 4,
            cells: vec![],
        };
        let mk = |replan: &str, cell: &str, rt: f64, acc: f64| SweepCell {
            scenario: "step6".into(),
            strategy: "SEMI".into(),
            replan: replan.into(),
            cell: cell.into(),
            rt,
            final_acc: acc,
            best_acc: acc,
            comm_bytes: 10,
            replans: 4,
            chi_mean: 2.0,
            chi_max: 6.0,
            mem_hwm_bytes: 1 << 20,
            mem_headroom_min_bytes: 1 << 19,
            recompute_iters: 0,
            error: None,
            phases: None,
        };
        r.cells.push(mk("online", "live", 1.0, 0.5));
        r.cells.push(mk("epoch", "live", 2.0, 0.5));
        let cmp = r.comparisons();
        assert_eq!(cmp.len(), 1);
        assert!((cmp[0].3 - 2.0).abs() < 1e-12, "speedup = rt_epoch/rt_online");
        let j = r.to_json().to_string();
        assert!(j.contains("\"online_speedup\":2"));
        assert!(Json::parse(&j).is_ok());
        assert!(r.render().contains("2.00×"));
        // churn comparison: live vs the best of the fixed-E baselines
        r.cells.push(mk("online", "fixed", 3.0, 0.4));
        r.cells.push(mk("online", "fixed-e2", 2.5, 0.5));
        let cc = r.churn_comparisons();
        assert_eq!(cc.len(), 1);
        assert!((cc[0].1 - 1.0).abs() < 1e-12, "rt_live");
        assert!((cc[0].2 - 2.5).abs() < 1e-12, "best fixed rt");
        assert!((cc[0].3 - 2.5).abs() < 1e-12, "elastic speedup");
        assert!(r.to_json().to_string().contains("\"elastic_speedup\":2.5"));
        // untraced cells carry an explicit "phases": null; traced ones
        // embed the breakdown and surface in the rendered table
        assert!(r.to_json().to_string().contains("\"phases\":null"));
        r.cells[0].phases = Some(crate::trace::report::PhaseTotals {
            compute_s: 1.0,
            chi_excess_s: 0.5,
            wait_s: 0.4,
            straggler: Some(1),
            attributed_pct: 97.0,
            ..Default::default()
        });
        let j = r.to_json().to_string();
        assert!(j.contains("\"attributed_pct\":97"));
        assert!(j.contains("\"straggler\":1"));
        assert!(r.render().contains("r1@97%"));
    }

    #[test]
    fn typed_faults_become_error_rows_and_stay_out_of_comparisons() {
        use crate::memory::MemError;
        let oom = anyhow::Error::from(MemError::OutOfMemory {
            rank: 1,
            need_bytes: 10,
            cap_bytes: 5,
        })
        .context("hard OOM on rank 1 at iteration 8");
        assert_eq!(error_variant(&oom).as_deref(), Some("OutOfMemory"));
        let inf = anyhow::Error::from(MemError::Infeasible {
            rank: 0,
            need_bytes: 10,
            headroom_bytes: 5,
        });
        assert_eq!(error_variant(&inf).as_deref(), Some("Infeasible"));
        let dead = anyhow::Error::from(contention::ScenarioError::NoViableWorkerCount {
            avail: 0,
            hs: 32,
            heads: 4,
        });
        assert_eq!(error_variant(&dead).as_deref(), Some("NoViableWorkerCount"));
        assert_eq!(error_variant(&anyhow::anyhow!("disk on fire")), None);

        // an error row is visible in the JSON but never in comparisons
        let cell = CellSpec::fixed(Strategy::Semi, ReplanMode::Online, None);
        let mut r = SweepReport {
            name: "t".into(),
            model: "vit-tiny".into(),
            epochs: 2,
            iters: 4,
            cells: vec![SweepCell::from_error("hard-oom", &cell, "OutOfMemory".into())],
        };
        let j = r.to_json().to_string();
        assert!(j.contains("\"error\":\"OutOfMemory\""));
        assert!(r.comparisons().is_empty());
        assert!(r.churn_comparisons().is_empty());
        assert!(r.render().contains("OutOfMemory"));
        // healthy cells emit an explicit null, keeping the schema stable
        r.cells[0].error = None;
        assert!(r.to_json().to_string().contains("\"error\":null"));
    }

    fn cell(replan: &str, tag: &str, rt: f64, acc: f64) -> SweepCell {
        SweepCell {
            scenario: "step6".into(),
            strategy: "SEMI".into(),
            replan: replan.into(),
            cell: tag.into(),
            rt,
            final_acc: acc,
            best_acc: acc,
            comm_bytes: 10,
            replans: 4,
            chi_mean: 2.0,
            chi_max: 6.0,
            mem_hwm_bytes: 1 << 20,
            mem_headroom_min_bytes: 1 << 19,
            recompute_iters: 0,
            error: None,
            phases: None,
        }
    }

    fn report_of(cells: Vec<SweepCell>) -> SweepReport {
        SweepReport { name: "t".into(), model: "vit-tiny".into(), epochs: 2, iters: 4, cells }
    }

    /// The comparison-pairing regression: an `"error"` row on either
    /// side of a pair must *omit* the entry.  Before the tag-matched
    /// lookup, `find` returned the first non-error cell of the
    /// strategy/replan, so an errored `live` online cell silently
    /// paired the healthy `fixed` online cell against the `live` epoch
    /// cell — a cross-tag comparison presented as an elastic speedup.
    #[test]
    fn comparison_pairs_skip_error_rows_on_either_side() {
        let mut dead = cell("online", "live", 4.0, 0.0);
        dead.error = Some("OutOfMemory".into());
        let mut r = report_of(vec![
            dead,
            cell("epoch", "live", 2.0, 0.5),
            cell("online", "fixed", 1.0, 0.5),
            cell("epoch", "fixed", 0.5, 0.5),
        ]);
        assert!(
            r.comparisons().is_empty(),
            "errored online side must drop the pair, not fall through to another tag"
        );
        assert!(
            r.churn_comparisons().is_empty(),
            "an errored live cell is not an elastic result to compare against"
        );
        // heal the online live cell, fail the epoch live cell: the
        // online/epoch pair is still incomplete, but live-vs-fixed now
        // has both healthy sides — and only the replan-matched baseline
        // counts (the cheaper epoch baseline must not leak into the
        // online live cell's best-of pool)
        r.cells[0].error = None;
        r.cells[1].error = Some("Infeasible".into());
        assert!(r.comparisons().is_empty(), "errored epoch side must drop the pair");
        let cc = r.churn_comparisons();
        assert_eq!(cc.len(), 1);
        assert!((cc[0].2 - 1.0).abs() < 1e-12, "baseline = the online fixed cell, not epoch's");
        // an errored baseline drops out of the best-of pool too
        r.cells[2].error = Some("OutOfMemory".into());
        assert!(r.churn_comparisons().is_empty());
    }

    #[test]
    fn finegrained_comparisons_pair_degree_cells_with_their_uniform_base() {
        let mut r = report_of(vec![
            cell("online", "fixed", 3.0, 0.50),
            cell("online", "fixed+dega2m2", 2.0, 0.50),
            cell("online", "fixed+degauto", 2.0, 0.51),
        ]);
        let fc = r.finegrained_comparisons();
        assert_eq!(fc.len(), 2);
        assert_eq!(fc[0].1, "fixed+dega2m2");
        assert!((fc[0].4 - 1.5).abs() < 1e-12, "mixed_speedup = rt_uniform / rt_mixed");
        assert!((fc[1].5 - 1.0).abs() < 1e-9, "ΔACC in pp vs the uniform base");
        let j = r.to_json().to_string();
        assert!(j.contains("\"mixed_speedup\":1.5"));
        assert!(r.render().contains("fixed+degauto"));
        // an errored uniform base drops every pair built on it
        r.cells[0].error = Some("OutOfMemory".into());
        assert!(r.finegrained_comparisons().is_empty());
    }
}
