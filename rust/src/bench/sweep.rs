//! Scenario × strategy sweep harness (`flextp sweep`, DESIGN.md §12).
//!
//! Runs a matrix of contention scenarios against balancing strategies
//! (each optionally pinned to a replan mode, e.g. `semi@online` vs
//! `semi@epoch`) and writes `BENCH_scenarios.json` — RT, ACC, comm
//! bytes, replan counts, and χ trace stats per cell — plus a rendered
//! table and, where both `semi@online` and `semi@epoch` ran, the online
//! controller's speedup over static per-epoch replanning.
//!
//! Sweeps default to `--time-model modeled`: the SimClock becomes a
//! pure function of the scenario, so cells are deterministic, and
//! re-running a sweep reproduces `BENCH_scenarios.json` byte-for-byte.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{ReplanMode, RunCfg, StragglerPlan, Strategy, TimeModel};
use crate::contention::{self, ScenarioSpec};
use crate::metrics::RunReport;
use crate::train::trainer::Trainer;
use crate::util::json::{obj, Json};
use crate::util::table::TextTable;

/// One sweep's full specification.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub name: String,
    pub model: String,
    pub epochs: usize,
    pub iters: usize,
    pub eval_iters: usize,
    pub seed: u64,
    pub time_model: TimeModel,
    /// (label, scenario) rows of the matrix
    pub scenarios: Vec<(String, ScenarioSpec)>,
    /// (strategy, replan mode) columns of the matrix
    pub cells: Vec<(Strategy, ReplanMode)>,
}

impl SweepSpec {
    fn base(name: &str) -> SweepSpec {
        SweepSpec {
            name: name.to_string(),
            model: "vit-tiny".to_string(),
            epochs: 3,
            iters: 12,
            eval_iters: 4,
            seed: 42,
            time_model: TimeModel::Modeled,
            scenarios: Vec::new(),
            cells: Vec::new(),
        }
    }

    /// Built-in sweep presets (`--preset`).
    pub fn preset(name: &str) -> Result<SweepSpec> {
        let mut s = SweepSpec::base(name);
        match name {
            // CI-sized 2 scenarios × 2 strategies: the calm control and
            // the mid-epoch tenant arrival where online replanning wins
            "smoke" => {
                s.epochs = 2;
                s.iters = 10;
                let killed = {
                    // same step6 trace, but the job is killed after
                    // iteration 13 (mid epoch 2) and resumed from its
                    // checkpoint — under the modeled clock this cell
                    // must reproduce the uninterrupted step6 cell
                    let mut sc = contention::preset("step6")?;
                    sc.preempt = Some(13);
                    sc
                };
                s.scenarios = vec![
                    ("calm".into(), contention::preset("calm")?),
                    ("step6".into(), contention::preset("step6")?),
                    ("step6-kill13".into(), killed),
                ];
                s.cells = vec![
                    (Strategy::Semi, ReplanMode::Online),
                    (Strategy::Semi, ReplanMode::Epoch),
                ];
            }
            // the paper's dynamic story: bursty traces vs the controller
            "bursty" => {
                s.scenarios = vec![
                    ("step6".into(), contention::preset("step6")?),
                    ("bursty".into(), contention::preset("bursty")?),
                    ("markov-duo".into(), contention::preset("markov-duo")?),
                ];
                s.cells = vec![
                    (Strategy::Semi, ReplanMode::Online),
                    (Strategy::Semi, ReplanMode::Epoch),
                    (Strategy::Mig, ReplanMode::Online),
                    (Strategy::Baseline, ReplanMode::Iter),
                ];
            }
            // tenants arriving/departing against resize-only and hybrid
            "churn" => {
                s.scenarios = vec![
                    ("tenant-churn".into(), contention::preset("tenant-churn")?),
                    ("burst1".into(), contention::preset("burst1")?),
                ];
                s.cells = vec![
                    (Strategy::Semi, ReplanMode::Online),
                    (Strategy::ZeroPri, ReplanMode::Iter),
                    (Strategy::Baseline, ReplanMode::Iter),
                ];
            }
            _ => bail!("unknown sweep preset '{name}' (smoke|bursty|churn)"),
        }
        Ok(s)
    }
}

/// Parse a strategy cell: `"semi@online"` → (Semi, Online); a bare
/// strategy name keeps the legacy per-iteration replanning.
pub fn parse_cell(s: &str) -> Result<(Strategy, ReplanMode)> {
    match s.split_once('@') {
        Some((st, rp)) => Ok((Strategy::parse(st)?, ReplanMode::parse(rp)?)),
        None => Ok((Strategy::parse(s)?, ReplanMode::Iter)),
    }
}

/// Parse `"label=dsl;label2=dsl"` (bare specs get s0, s1, … labels).
pub fn parse_scenarios(s: &str) -> Result<Vec<(String, ScenarioSpec)>> {
    let mut out = Vec::new();
    for (i, item) in s.split(';').filter(|x| !x.trim().is_empty()).enumerate() {
        let (label, dsl) = match item.split_once('=') {
            Some((l, d)) => (l.trim().to_string(), d),
            None => (format!("s{i}"), item),
        };
        out.push((label, ScenarioSpec::parse(dsl.trim())?));
    }
    Ok(out)
}

/// One finished matrix cell.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub scenario: String,
    pub strategy: String,
    pub replan: String,
    /// mean per-epoch simulated runtime (the paper's RT)
    pub rt: f64,
    pub final_acc: f64,
    pub best_acc: f64,
    pub comm_bytes: u64,
    pub replans: u64,
    pub chi_mean: f64,
    pub chi_max: f64,
}

impl SweepCell {
    fn from_report(scenario: &str, strategy: Strategy, replan: ReplanMode, r: &RunReport) -> Self {
        SweepCell {
            scenario: scenario.to_string(),
            strategy: strategy.name().to_string(),
            replan: replan.name().to_string(),
            rt: r.rt(),
            final_acc: r.final_acc(),
            best_acc: r.best_acc(),
            comm_bytes: r.total_comm_bytes(),
            replans: r.total_replans(),
            chi_mean: r.chi_mean(),
            chi_max: r.chi_max(),
        }
    }
}

/// Sweep results: cells + the online-vs-epoch comparisons.
#[derive(Debug, Clone, Default)]
pub struct SweepReport {
    pub name: String,
    pub model: String,
    pub epochs: usize,
    pub iters: usize,
    pub cells: Vec<SweepCell>,
}

/// Run the full scenario × strategy matrix.
pub fn run_sweep(spec: &SweepSpec) -> Result<SweepReport> {
    let mut cells = Vec::new();
    for (label, scen) in &spec.scenarios {
        for &(strategy, replan) in &spec.cells {
            let mut cfg = RunCfg::new(&spec.model);
            cfg.balancer.strategy = strategy;
            cfg.balancer.replan = replan;
            cfg.train.epochs = spec.epochs;
            cfg.train.iters_per_epoch = spec.iters;
            cfg.train.eval_iters = spec.eval_iters;
            cfg.train.seed = spec.seed;
            cfg.train.time_model = spec.time_model;
            cfg.stragglers = StragglerPlan::Scenario(scen.clone());
            let r = run_cell(cfg, scen.preempt, label, strategy, replan).with_context(|| {
                format!("cell {label} × {}@{}", strategy.name(), replan.name())
            })?;
            cells.push(SweepCell::from_report(label, strategy, replan, &r));
        }
    }
    Ok(SweepReport {
        name: spec.name.clone(),
        model: spec.model.clone(),
        epochs: spec.epochs,
        iters: spec.iters,
        cells,
    })
}

/// Execute one matrix cell.  A scenario with a `preempt:iterG` event
/// runs the full kill/checkpoint/resume cycle mid-run: train to G, save
/// an atomic snapshot, drop the trainer (the "kill"), rebuild from the
/// snapshot, and finish — under the modeled clock the resulting report
/// is bitwise identical to an uninterrupted cell (the parity that
/// `tests/scenario_sweep.rs` pins).
fn run_cell(
    cfg: RunCfg,
    preempt: Option<usize>,
    label: &str,
    strategy: Strategy,
    replan: ReplanMode,
) -> Result<RunReport> {
    let Some(g) = preempt else {
        let mut t = Trainer::new(cfg)?;
        return t.run();
    };
    let mut t = Trainer::new(cfg.clone())?;
    t.run_to(Some(g as u64))?;
    if t.is_complete() {
        // preemption point beyond the schedule: nothing to resume
        return Ok(t.report.clone());
    }
    let dir = std::env::temp_dir().join(format!(
        "flextp_preempt_{}_{}_{}_{}",
        std::process::id(),
        label.replace(|c: char| !c.is_ascii_alphanumeric(), "-"),
        strategy.name(),
        replan.name(),
    ));
    let path = dir.join(crate::checkpoint::ckpt_filename(t.giter()));
    t.save_checkpoint(&path)?;
    drop(t); // the kill: every live trainer structure is gone
    let mut resumed = Trainer::resume_from(cfg, &path)?;
    let r = resumed.run()?;
    let _ = std::fs::remove_dir_all(&dir);
    Ok(r)
}

impl SweepReport {
    fn find(&self, scenario: &str, strategy: &str, replan: &str) -> Option<&SweepCell> {
        self.cells
            .iter()
            .find(|c| c.scenario == scenario && c.strategy == strategy && c.replan == replan)
    }

    /// Per scenario with both `SEMI@online` and `SEMI@epoch` cells:
    /// (scenario, rt_online, rt_epoch, speedup, acc_delta_pp).
    pub fn comparisons(&self) -> Vec<(String, f64, f64, f64, f64)> {
        let mut out = Vec::new();
        for label in self.scenario_labels() {
            let (Some(on), Some(ep)) = (
                self.find(&label, "SEMI", "online"),
                self.find(&label, "SEMI", "epoch"),
            ) else {
                continue;
            };
            let speedup = if on.rt > 0.0 { ep.rt / on.rt } else { 0.0 };
            out.push((
                label,
                on.rt,
                ep.rt,
                speedup,
                100.0 * (on.final_acc - ep.final_acc),
            ));
        }
        out
    }

    fn scenario_labels(&self) -> Vec<String> {
        let mut seen: Vec<String> = Vec::new();
        for c in &self.cells {
            if !seen.contains(&c.scenario) {
                seen.push(c.scenario.clone());
            }
        }
        seen
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("name", self.name.as_str().into()),
            ("model", self.model.as_str().into()),
            ("epochs", self.epochs.into()),
            ("iters_per_epoch", self.iters.into()),
            (
                "cells",
                Json::Arr(
                    self.cells
                        .iter()
                        .map(|c| {
                            obj([
                                ("scenario", c.scenario.as_str().into()),
                                ("strategy", c.strategy.as_str().into()),
                                ("replan", c.replan.as_str().into()),
                                ("rt", c.rt.into()),
                                ("final_acc", c.final_acc.into()),
                                ("best_acc", c.best_acc.into()),
                                ("comm_bytes", (c.comm_bytes as f64).into()),
                                ("replans", (c.replans as f64).into()),
                                ("chi_mean", c.chi_mean.into()),
                                ("chi_max", c.chi_max.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "comparisons",
                Json::Arr(
                    self.comparisons()
                        .into_iter()
                        .map(|(s, on, ep, sp, dacc)| {
                            obj([
                                ("scenario", s.into()),
                                ("rt_online", on.into()),
                                ("rt_epoch", ep.into()),
                                ("online_speedup", sp.into()),
                                ("acc_delta_pp", dacc.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Rendered cell table + comparison lines.
    pub fn render(&self) -> String {
        let mut t = TextTable::new(
            &format!("scenario sweep '{}' ({}, RT in sim-seconds)", self.name, self.model),
            &["scenario", "strategy", "replan", "RT", "ACC", "comm", "replans", "chi_mean", "chi_max"],
        );
        for c in &self.cells {
            t.row(&[
                c.scenario.clone(),
                c.strategy.clone(),
                c.replan.clone(),
                format!("{:.4}", c.rt),
                format!("{:.1}%", 100.0 * c.final_acc),
                crate::util::fmt_bytes(c.comm_bytes),
                c.replans.to_string(),
                format!("{:.2}", c.chi_mean),
                format!("{:.1}", c.chi_max),
            ]);
        }
        let mut out = t.render();
        for (s, on, ep, sp, dacc) in self.comparisons() {
            out.push_str(&format!(
                "\n{s}: online RT {on:.4}s vs epoch {ep:.4}s → {sp:.2}× \
                 (ΔACC {dacc:+.1}pp)"
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_and_scenario_parsing() {
        assert_eq!(parse_cell("semi@online").unwrap(), (Strategy::Semi, ReplanMode::Online));
        assert_eq!(parse_cell("mig").unwrap(), (Strategy::Mig, ReplanMode::Iter));
        assert!(parse_cell("semi@sometimes").is_err());
        assert!(parse_cell("vibes@online").is_err());
        let sc = parse_scenarios("a=burst:r1@x4:iters0-4;step:r2@x3:iters1-").unwrap();
        assert_eq!(sc.len(), 2);
        assert_eq!(sc[0].0, "a");
        assert_eq!(sc[1].0, "s1");
        assert!(parse_scenarios("a=meteor:r1@x2:iters0-4").is_err());
    }

    #[test]
    fn presets_build() {
        for p in ["smoke", "bursty", "churn"] {
            let s = SweepSpec::preset(p).unwrap();
            assert!(!s.scenarios.is_empty());
            assert!(!s.cells.is_empty());
            assert_eq!(s.time_model, TimeModel::Modeled);
        }
        assert!(SweepSpec::preset("galaxy").is_err());
        let s = SweepSpec::preset("smoke").unwrap();
        assert_eq!(s.scenarios.len(), 3);
        assert_eq!(s.cells.len(), 2);
        // the smoke matrix carries a kill/resume cell; its χ trace is
        // the plain step6 one
        let killed = &s.scenarios[2].1;
        assert_eq!(killed.preempt, Some(13));
        assert_eq!(killed.events, s.scenarios[1].1.events);
    }

    #[test]
    fn report_json_and_comparisons() {
        let mut r = SweepReport {
            name: "t".into(),
            model: "vit-tiny".into(),
            epochs: 2,
            iters: 4,
            cells: vec![],
        };
        let mk = |replan: &str, rt: f64, acc: f64| SweepCell {
            scenario: "step6".into(),
            strategy: "SEMI".into(),
            replan: replan.into(),
            rt,
            final_acc: acc,
            best_acc: acc,
            comm_bytes: 10,
            replans: 4,
            chi_mean: 2.0,
            chi_max: 6.0,
        };
        r.cells.push(mk("online", 1.0, 0.5));
        r.cells.push(mk("epoch", 2.0, 0.5));
        let cmp = r.comparisons();
        assert_eq!(cmp.len(), 1);
        assert!((cmp[0].3 - 2.0).abs() < 1e-12, "speedup = rt_epoch/rt_online");
        let j = r.to_json().to_string();
        assert!(j.contains("\"online_speedup\":2"));
        assert!(Json::parse(&j).is_ok());
        assert!(r.render().contains("2.00×"));
    }
}
