//! Kernel microbenchmark harness (`cargo bench --bench kernels_microbench`).
//!
//! Times the dense and pruned GEMM kernels on the exact shapes the vit
//! presets execute per layer (fwd + bwd), against a checked-in copy of
//! the pre-packing **scalar reference kernels**, and emits a
//! machine-readable `BENCH_kernels.json` at the repository root:
//! median GFLOP/s per shape, serial and threaded, scalar vs packed.
//! That file is the perf trajectory future PRs regress against —
//! [`compare`] implements the CI gate (fail when dense packed GFLOP/s
//! drops more than the allowed fraction below the baseline).
//!
//! The scalar kernels here are *frozen copies* of the pre-PR-3
//! `tensor::linalg` inner loops (blocked saxpy with the per-element
//! zero-skip branch, dot-product `a·bᵀ`, rank-1-update `aᵀ·b`) plus the
//! gather → GEMM → scatter pruned dataflow — kept so every future run
//! re-measures the "before" column on the same silicon it measures the
//! "after" column.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::Manifest;
use crate::tensor::linalg;
use crate::tensor::Workspace;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

const BLOCK_K: usize = 64;
const BLOCK_N: usize = 256;

// ---------------------------------------------------------------------------
// Frozen pre-PR scalar reference kernels ("before" column)
// ---------------------------------------------------------------------------

/// Pre-packing `c += a·b`: B-panel blocked, saxpy inner loop, per-element
/// `av == 0.0` skip — the seed kernel this PR replaced.
pub fn scalar_matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    for k0 in (0..k).step_by(BLOCK_K) {
        let k1 = (k0 + BLOCK_K).min(k);
        for n0 in (0..n).step_by(BLOCK_N) {
            let n1 = (n0 + BLOCK_N).min(n);
            for i in 0..m {
                let a_row = &a[i * k..(i + 1) * k];
                let c_row = &mut c[i * n + n0..i * n + n1];
                for (l, &av) in a_row.iter().enumerate().take(k1).skip(k0) {
                    if av == 0.0 {
                        continue;
                    }
                    let b_row = &b[l * n + n0..l * n + n1];
                    for (cv, bv) in c_row.iter_mut().zip(b_row) {
                        *cv += av * bv;
                    }
                }
            }
        }
    }
}

/// Pre-packing `aᵀ·b` (rank-1 updates over full C rows).
pub fn scalar_matmul_at_b(a: &[f32], b: &[f32], m: usize, ka: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; ka * n];
    for i in 0..m {
        let a_row = &a[i * ka..(i + 1) * ka];
        let b_row = &b[i * n..(i + 1) * n];
        for l in 0..ka {
            let av = a_row[l];
            if av == 0.0 {
                continue;
            }
            let c_row = &mut c[l * n..(l + 1) * n];
            for (cv, bv) in c_row.iter_mut().zip(b_row) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// Pre-packing `a·bᵀ` (scalar dot product per output element).
pub fn scalar_matmul_a_bt(a: &[f32], b: &[f32], m: usize, k: usize, nb: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * nb];
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * nb..(i + 1) * nb];
        for (j, cv) in c_row.iter_mut().enumerate() {
            *cv = linalg::dot(a_row, &b[j * k..(j + 1) * k]);
        }
    }
    c
}

/// Pre-fusion pruned forward: materialized gather → scalar GEMM.
pub fn scalar_pruned_matmul(
    x: &[f32],
    w: &[f32],
    rows: usize,
    kfull: usize,
    n: usize,
    idx: &[i32],
    mask: &[f32],
) -> Vec<f32> {
    let kp = idx.len();
    let mut xg = vec![0.0f32; rows * kp];
    for i in 0..rows {
        let row = &x[i * kfull..(i + 1) * kfull];
        let o = &mut xg[i * kp..(i + 1) * kp];
        for (j, (&ix, &mv)) in idx.iter().zip(mask).enumerate() {
            o[j] = row[ix as usize] * mv;
        }
    }
    let mut wg = vec![0.0f32; kp * n];
    for (j, &ix) in idx.iter().enumerate() {
        wg[j * n..(j + 1) * n].copy_from_slice(&w[ix as usize * n..(ix as usize + 1) * n]);
    }
    let mut y = vec![0.0f32; rows * n];
    scalar_matmul_acc(&mut y, &xg, &wg, rows, kp, n);
    y
}

/// Pre-fusion pruned backward: gathers, scalar GEMMs, full-size scatters.
pub fn scalar_pruned_matmul_bwd(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    rows: usize,
    kfull: usize,
    n: usize,
    idx: &[i32],
    mask: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let kp = idx.len();
    let mut wg = vec![0.0f32; kp * n];
    for (j, &ix) in idx.iter().enumerate() {
        wg[j * n..(j + 1) * n].copy_from_slice(&w[ix as usize * n..(ix as usize + 1) * n]);
    }
    let mut dxc = scalar_matmul_a_bt(dy, &wg, rows, n, kp);
    for i in 0..rows {
        for (v, &mv) in dxc[i * kp..(i + 1) * kp].iter_mut().zip(mask) {
            *v *= mv;
        }
    }
    let mut dx = vec![0.0f32; rows * kfull];
    for i in 0..rows {
        for (j, &ix) in idx.iter().enumerate() {
            dx[i * kfull + ix as usize] += dxc[i * kp + j];
        }
    }
    let mut xg = vec![0.0f32; rows * kp];
    for i in 0..rows {
        let row = &x[i * kfull..(i + 1) * kfull];
        for (j, (&ix, &mv)) in idx.iter().zip(mask).enumerate() {
            xg[i * kp + j] = row[ix as usize] * mv;
        }
    }
    let dwc = scalar_matmul_at_b(&xg, dy, rows, kp, n);
    let mut dw = vec![0.0f32; kfull * n];
    for (j, &ix) in idx.iter().enumerate() {
        for (dv, sv) in dw[ix as usize * n..(ix as usize + 1) * n]
            .iter_mut()
            .zip(&dwc[j * n..(j + 1) * n])
        {
            *dv += sv;
        }
    }
    (dx, dw)
}

// ---------------------------------------------------------------------------
// Timing
// ---------------------------------------------------------------------------

/// Median seconds per call over `samples` samples of adaptively-sized
/// batches (each batch ≥ `target_ms`).
fn time_median<F: FnMut()>(mut f: F, samples: usize, target_ms: f64) -> f64 {
    // warmup + batch sizing
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_ms / 1e3 / once).ceil() as usize).max(1);
    let mut per_call: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_call.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    per_call.sort_by(|a, b| a.partial_cmp(b).unwrap());
    per_call[per_call.len() / 2]
}

struct Measured {
    scalar_s: f64,
    packed_serial_s: f64,
    packed_threaded_s: f64,
}

fn shape_json(
    name: &str,
    kind: &str,
    dims: (usize, usize, usize),
    flops: f64,
    t: &Measured,
    threads: usize,
) -> Json {
    let g = |secs: f64| flops / secs.max(1e-12) / 1e9;
    obj([
        ("name", name.into()),
        ("kind", kind.into()),
        ("m", dims.0.into()),
        ("k", dims.1.into()),
        ("n", dims.2.into()),
        (
            "serial",
            obj([
                ("scalar_gflops", g(t.scalar_s).into()),
                ("packed_gflops", g(t.packed_serial_s).into()),
                ("speedup", (t.scalar_s / t.packed_serial_s.max(1e-12)).into()),
            ]),
        ),
        (
            "threaded",
            obj([
                ("threads", threads.into()),
                ("packed_gflops", g(t.packed_threaded_s).into()),
                ("speedup", (t.scalar_s / t.packed_threaded_s.max(1e-12)).into()),
            ]),
        ),
    ])
}

/// Benchmark every hot GEMM shape of `model`'s presets; returns the
/// `BENCH_kernels.json` document.
pub fn run_model(model: &str, samples: usize, target_ms: f64) -> Result<Json> {
    let man = Manifest::for_model(model)?;
    let m = &man.model;
    let rows = m.bs * m.seq;
    let (hs, hsl, ffl) = (m.hs, m.hsl, m.ffl);
    let threads = linalg::available_cores().clamp(2, 8);
    let mut rng = Rng::new(4242);

    let mut shapes: Vec<Json> = Vec::new();
    let mut dense = |name: &str, mm: usize, kk: usize, nn: usize, shapes: &mut Vec<Json>| {
        let a = rng.normal_vec(mm * kk, 1.0);
        let b = rng.normal_vec(kk * nn, 1.0);
        let mut c = vec![0.0f32; mm * nn];
        let measured = Measured {
            scalar_s: time_median(
                || {
                    c.fill(0.0);
                    scalar_matmul_acc(&mut c, &a, &b, mm, kk, nn);
                },
                samples,
                target_ms,
            ),
            packed_serial_s: linalg::with_gemm_threads(1, || {
                time_median(
                    || {
                        c.fill(0.0);
                        linalg::matmul_acc(&mut c, &a, &b, mm, kk, nn);
                    },
                    samples,
                    target_ms,
                )
            }),
            packed_threaded_s: linalg::with_gemm_threads(threads, || {
                time_median(
                    || {
                        c.fill(0.0);
                        linalg::matmul_acc(&mut c, &a, &b, mm, kk, nn);
                    },
                    samples,
                    target_ms,
                )
            }),
        };
        let flops = 2.0 * (mm * kk * nn) as f64;
        shapes.push(shape_json(name, "dense_ab", (mm, kk, nn), flops, &measured, threads));
    };
    // the per-layer forward GEMMs of the preset
    dense("attn_qkv_fwd", rows, hs, 3 * hsl, &mut shapes);
    dense("attn_out_fwd", rows, hsl, hs, &mut shapes);
    dense("mlp_fc1_fwd", rows, hs, ffl, &mut shapes);
    dense("mlp_fc2_fwd", rows, ffl, hs, &mut shapes);

    // weight-gradient shape: dwqkv = xlnᵀ · dqkv
    {
        let a = rng.normal_vec(rows * hs, 1.0);
        let b = rng.normal_vec(rows * 3 * hsl, 1.0);
        let mut c = vec![0.0f32; hs * 3 * hsl];
        let measured = Measured {
            scalar_s: time_median(
                || {
                    let out = scalar_matmul_at_b(&a, &b, rows, hs, 3 * hsl);
                    std::hint::black_box(&out);
                },
                samples,
                target_ms,
            ),
            packed_serial_s: linalg::with_gemm_threads(1, || {
                time_median(
                    || {
                        c.fill(0.0);
                        linalg::matmul_at_b_acc(&mut c, &a, &b, rows, hs, 3 * hsl);
                    },
                    samples,
                    target_ms,
                )
            }),
            packed_threaded_s: linalg::with_gemm_threads(threads, || {
                time_median(
                    || {
                        c.fill(0.0);
                        linalg::matmul_at_b_acc(&mut c, &a, &b, rows, hs, 3 * hsl);
                    },
                    samples,
                    target_ms,
                )
            }),
        };
        let flops = 2.0 * (rows * hs * 3 * hsl) as f64;
        shapes.push(shape_json(
            "attn_dwqkv_bwd",
            "dense_atb",
            (rows, hs, 3 * hsl),
            flops,
            &measured,
            threads,
        ));
    }
    // input-gradient shape: dxln = dqkv · wqkvᵀ
    {
        let a = rng.normal_vec(rows * 3 * hsl, 1.0);
        let b = rng.normal_vec(hs * 3 * hsl, 1.0);
        let mut c = vec![0.0f32; rows * hs];
        let measured = Measured {
            scalar_s: time_median(
                || {
                    let out = scalar_matmul_a_bt(&a, &b, rows, 3 * hsl, hs);
                    std::hint::black_box(&out);
                },
                samples,
                target_ms,
            ),
            packed_serial_s: linalg::with_gemm_threads(1, || {
                time_median(
                    || {
                        c.fill(0.0);
                        linalg::matmul_a_bt_acc(&mut c, &a, &b, rows, 3 * hsl, hs);
                    },
                    samples,
                    target_ms,
                )
            }),
            packed_threaded_s: linalg::with_gemm_threads(threads, || {
                time_median(
                    || {
                        c.fill(0.0);
                        linalg::matmul_a_bt_acc(&mut c, &a, &b, rows, 3 * hsl, hs);
                    },
                    samples,
                    target_ms,
                )
            }),
        };
        let flops = 2.0 * (rows * 3 * hsl * hs) as f64;
        shapes.push(shape_json(
            "attn_dx_bwd",
            "dense_abt",
            (rows, 3 * hsl, hs),
            flops,
            &measured,
            threads,
        ));
    }
    // pruned g50 contraction on the FC1 shape: fused vs gather-then-GEMM
    {
        let keep = crate::runtime::presets::keep_count(hs, 0.5);
        let idx: Vec<i32> = (0..keep as i32).map(|i| i * 2).collect();
        let mask = vec![1.0f32; keep];
        let x = rng.normal_vec(rows * hs, 1.0);
        let w = rng.normal_vec(hs * ffl, 1.0);
        let dy = rng.normal_vec(rows * ffl, 1.0);
        let mut ws = Workspace::new();
        let fwd_flops = 2.0 * (rows * keep * ffl) as f64;
        let measured = Measured {
            scalar_s: time_median(
                || {
                    let out = scalar_pruned_matmul(&x, &w, rows, hs, ffl, &idx, &mask);
                    std::hint::black_box(&out);
                },
                samples,
                target_ms,
            ),
            packed_serial_s: linalg::with_gemm_threads(1, || {
                time_median(
                    || {
                        let y = crate::runtime::native::ops::pruned_matmul_ws(
                            &x, &w, rows, hs, ffl, &idx, &mask, &mut ws,
                        );
                        ws.give(y);
                    },
                    samples,
                    target_ms,
                )
            }),
            packed_threaded_s: linalg::with_gemm_threads(threads, || {
                time_median(
                    || {
                        let y = crate::runtime::native::ops::pruned_matmul_ws(
                            &x, &w, rows, hs, ffl, &idx, &mask, &mut ws,
                        );
                        ws.give(y);
                    },
                    samples,
                    target_ms,
                )
            }),
        };
        shapes.push(shape_json(
            "mlp_fc1_fwd_pruned_g50",
            "pruned_fwd",
            (rows, keep, ffl),
            fwd_flops,
            &measured,
            threads,
        ));

        let bwd_flops = 4.0 * (rows * keep * ffl) as f64;
        let measured = Measured {
            scalar_s: time_median(
                || {
                    let out = scalar_pruned_matmul_bwd(&x, &w, &dy, rows, hs, ffl, &idx, &mask);
                    std::hint::black_box(&out);
                },
                samples,
                target_ms,
            ),
            packed_serial_s: linalg::with_gemm_threads(1, || {
                time_median(
                    || {
                        let (dx, dw) = crate::runtime::native::ops::pruned_matmul_bwd_ws(
                            &x, &w, &dy, rows, hs, ffl, &idx, &mask, &mut ws,
                        );
                        ws.give(dx);
                        ws.give(dw);
                    },
                    samples,
                    target_ms,
                )
            }),
            packed_threaded_s: linalg::with_gemm_threads(threads, || {
                time_median(
                    || {
                        let (dx, dw) = crate::runtime::native::ops::pruned_matmul_bwd_ws(
                            &x, &w, &dy, rows, hs, ffl, &idx, &mask, &mut ws,
                        );
                        ws.give(dx);
                        ws.give(dw);
                    },
                    samples,
                    target_ms,
                )
            }),
        };
        shapes.push(shape_json(
            "mlp_fc1_bwd_pruned_g50",
            "pruned_bwd",
            (rows, keep, ffl),
            bwd_flops,
            &measured,
            threads,
        ));
    }

    Ok(obj([
        ("schema", "flextp-kernel-bench/v1".into()),
        ("model", model.into()),
        ("rows", rows.into()),
        ("threads", threads.into()),
        ("samples", samples.into()),
        // every number above came from a real timed run on this host —
        // distinguishes CI-refreshed baselines from hand-seeded ones
        ("measured", true.into()),
        (
            "note",
            "scalar = frozen pre-PR-3 reference kernels re-measured on this host; \
             packed = current micro-kernels. Regenerate: cargo bench --bench kernels_microbench"
                .into(),
        ),
        ("shapes", shapes.into_iter().collect()),
    ]))
}

/// CI regression gate: every dense shape's packed GFLOP/s (serial and
/// threaded) must stay within `max_regress` (e.g. 0.20) of the baseline.
/// Returns the list of violations (empty = pass).
pub fn compare(fresh: &Json, baseline: &Json, max_regress: f64) -> Result<Vec<String>> {
    let mut violations = Vec::new();
    let fresh_shapes = fresh.get("shapes")?.arr()?;
    for base in baseline.get("shapes")?.arr()? {
        let name = base.get("name")?.str()?;
        let kind = base.get("kind")?.str()?;
        if !kind.starts_with("dense") {
            continue;
        }
        let Some(now) = fresh_shapes
            .iter()
            .find(|s| s.get("name").and_then(|n| n.str()).map(|n| n == name).unwrap_or(false))
        else {
            violations.push(format!("shape '{name}' missing from fresh run"));
            continue;
        };
        for section in ["serial", "threaded"] {
            let b = base.get(section)?.get("packed_gflops")?.num()?;
            let f = now.get(section)?.get("packed_gflops")?.num()?;
            let floor = (1.0 - max_regress) * b;
            if f < floor {
                violations.push(format!(
                    "{name}/{section}: {f:.2} GFLOP/s < floor {floor:.2} (baseline {b:.2})"
                ));
            }
        }
    }
    Ok(violations)
}

/// Repository root (the bench JSON lives there, not in `rust/`).
pub fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."))
}

/// Resolve a possibly-relative bench path against the repository root.
pub fn resolve_path(p: &str) -> PathBuf {
    let path = Path::new(p);
    if path.is_absolute() {
        path.to_path_buf()
    } else {
        repo_root().join(path)
    }
}

/// Load and parse a bench JSON file.
pub fn load(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading bench baseline {}", path.display()))?;
    Json::parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn scalar_references_agree_with_packed_kernels() {
        let mut rng = Rng::new(61);
        let (m, k, n) = (9, 37, 22);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let mut c_s = vec![0.0f32; m * n];
        scalar_matmul_acc(&mut c_s, &a, &b, m, k, n);
        let c_p = linalg::matmul(&a, &b, m, k, n);
        for (s, p) in c_s.iter().zip(&c_p) {
            assert!((s - p).abs() < 1e-3);
        }
        let b2 = rng.normal_vec(m * n, 1.0);
        let s = scalar_matmul_at_b(&a, &b2, m, k, n);
        let p = linalg::matmul_at_b(&a, &b2, m, k, n);
        for (s, p) in s.iter().zip(&p) {
            assert!((s - p).abs() < 1e-3);
        }
        let bt = rng.normal_vec(n * k, 1.0);
        let s = scalar_matmul_a_bt(&a, &bt, m, k, n);
        let p = linalg::matmul_a_bt(&a, &bt, m, k, n);
        assert_eq!(s, p, "a·bᵀ reference must match bitwise (same dot order)");
        // pruned reference vs fused
        let idx = [1i32, 5, 9, 30];
        let mask = [1.0f32, 0.5, 1.0, 1.0];
        let s = scalar_pruned_matmul(&a, &b, m, k, n, &idx, &mask);
        let p = crate::runtime::native::ops::pruned_matmul(&a, &b, m, k, n, &idx, &mask);
        for (s, p) in s.iter().zip(&p) {
            assert!((s - p).abs() < 1e-3);
        }
        let dy = rng.normal_vec(m * n, 1.0);
        let (sdx, sdw) = scalar_pruned_matmul_bwd(&a, &b, &dy, m, k, n, &idx, &mask);
        let (pdx, pdw) =
            crate::runtime::native::ops::pruned_matmul_bwd(&a, &b, &dy, m, k, n, &idx, &mask);
        for (s, p) in sdx.iter().zip(&pdx) {
            assert!((s - p).abs() < 1e-3);
        }
        for (s, p) in sdw.iter().zip(&pdw) {
            assert!((s - p).abs() < 1e-3);
        }
    }

    #[test]
    fn compare_flags_regressions_and_passes_improvements() {
        let mk = |gf: f64| {
            obj([(
                "shapes",
                vec![obj([
                    ("name", "attn_qkv_fwd".into()),
                    ("kind", "dense_ab".into()),
                    ("serial", obj([("packed_gflops", gf.into())])),
                    ("threaded", obj([("packed_gflops", (2.0 * gf).into())])),
                ])]
                .into_iter()
                .collect(),
            )])
        };
        let base = mk(10.0);
        assert!(compare(&mk(9.0), &base, 0.20).unwrap().is_empty());
        assert!(compare(&mk(50.0), &base, 0.20).unwrap().is_empty());
        let v = compare(&mk(7.0), &base, 0.20).unwrap();
        assert_eq!(v.len(), 2, "both serial and threaded regress: {v:?}");
        // pruned kinds are informational, not gated
        let pruned_only = obj([(
            "shapes",
            vec![obj([
                ("name", "p".into()),
                ("kind", "pruned_fwd".into()),
            ])]
            .into_iter()
            .collect(),
        )]);
        assert!(compare(&mk(1.0), &pruned_only, 0.2).unwrap().is_empty());
    }

    #[test]
    fn run_model_produces_schema_with_speedups() {
        // tiny sample budget — this is a smoke test, not a measurement
        let doc = run_model("vit-tiny", 1, 0.5).expect("bench run");
        assert_eq!(doc.get("schema").unwrap().str().unwrap(), "flextp-kernel-bench/v1");
        let shapes = doc.get("shapes").unwrap().arr().unwrap();
        assert!(shapes.len() >= 7, "expected all preset shapes, got {}", shapes.len());
        for s in shapes {
            assert!(s.get("serial").unwrap().get("packed_gflops").unwrap().num().unwrap() > 0.0);
        }
    }
}
