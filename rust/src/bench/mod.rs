//! Bench harness support (criterion is unavailable offline): every paper
//! table/figure bench is a `harness = false` binary that builds `RunCfg`s
//! with [`bench_cfg`], runs them through the trainer, and prints the
//! paper's rows via `util::table::TextTable` (+ CSV under `bench_out/`).
//! The [`kernels`] submodule is the GEMM microbench harness behind
//! `cargo bench --bench kernels_microbench` and the `BENCH_kernels.json`
//! perf baseline at the repository root.
//!
//! All benches honor `FLEXTP_THREADS` (the `--threads` knob): it seeds
//! `TrainCfg::default`, so `FLEXTP_THREADS=4 cargo bench --bench
//! fig9_hetero_sweep` runs every rank concurrently.  Thread count adds no
//! nondeterminism of its own, but adaptive strategies (Pri/Semi/…)
//! re-plan from measured kernel timings, so their losses/ACC vary run to
//! run whether serial or parallel; fixed-plan runs (baseline, `--gamma`)
//! are bitwise identical across thread counts.

pub mod kernels;
pub mod sweep;

use std::path::PathBuf;

use anyhow::Result;

use crate::config::{RunCfg, Strategy};
use crate::metrics::RunReport;
use crate::train::trainer::Trainer;

/// Small-but-meaningful bench defaults (see DESIGN.md §2 scale mapping).
pub fn bench_cfg(model: &str, strategy: Strategy) -> RunCfg {
    let mut cfg = RunCfg::new(model);
    cfg.balancer.strategy = strategy;
    cfg.train.epochs = 3;
    cfg.train.iters_per_epoch = 4;
    cfg.train.eval_iters = 4;
    cfg.train.lr = 0.03;
    cfg
}

/// Run one configuration end-to-end and return the report.
pub fn run(cfg: RunCfg) -> Result<RunReport> {
    let mut t = Trainer::new(cfg)?;
    t.run()
}

/// Where bench CSVs go.
pub fn out_dir() -> PathBuf {
    PathBuf::from("bench_out")
}

/// Table I runner: homogeneous cluster, ν workers forced to migrate a
/// `remove_frac` slice of their FFN under the given primitive policy.
/// Returns mean simulated epoch RT in seconds.
pub fn forced_migration_rt(
    model: &str,
    nu: usize,
    remove_frac: f64,
    policy: crate::config::MigPolicy,
    reduce_merging: bool,
    net_gbps: Option<f64>,
) -> Result<f64> {
    use crate::balancer::WorkerAction;
    use crate::migration;

    let mut cfg = RunCfg::new(model);
    if let Some(g) = net_gbps {
        cfg.net.bytes_per_s = g * 1e9;
    }
    cfg.balancer.mig_policy = policy;
    cfg.balancer.reduce_merging = reduce_merging;
    cfg.train.epochs = 1;
    cfg.train.iters_per_epoch = 3;
    cfg.train.eval_iters = 1;
    let mut t = Trainer::new(cfg)?;
    let man = t.rt.manifest.clone();
    let m = man.model.clone();
    let mut actions: Vec<WorkerAction> =
        (0..m.e).map(|_| WorkerAction::full(&man)).collect();
    for w in 0..nu.min(m.e.saturating_sub(1)) {
        if remove_frac > 0.0 {
            actions[w].mig = migration::plan(&man, w, remove_frac, 1.0, None);
            if let Some(mig) = actions[w].mig.clone() {
                for p in &mut actions[w].layers {
                    p.mlp_b1 = "g00".into();
                    p.mlp_b2 = mig.kept_bucket.clone();
                    p.mlp_keep2 = mig.kept.clone();
                }
            }
        }
    }
    t.forced_actions = Some(actions);
    t.warmup_and_pretest()?;
    t.run_epoch(0)?;
    Ok(t.report.epochs[0].rt_sim_s)
}

/// ACC delta vs a baseline report, in percentage points (the paper's
/// Fig. 10/11 presentation).
pub fn acc_delta_pp(solution: &RunReport, baseline: &RunReport) -> f64 {
    100.0 * (solution.best_acc() - baseline.best_acc())
}

/// Speedup of a solution vs baseline (paper: RT ratios).
pub fn speedup(solution: &RunReport, baseline: &RunReport) -> f64 {
    if solution.rt() <= 0.0 {
        return 0.0;
    }
    baseline.rt() / solution.rt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EpochMetrics;

    fn rep(rt: f64, acc: f64) -> RunReport {
        let mut r = RunReport::new("x");
        r.epochs.push(EpochMetrics { rt_sim_s: rt, acc, ..Default::default() });
        r
    }

    #[test]
    fn speedup_and_delta() {
        let base = rep(10.0, 0.50);
        let sol = rep(2.5, 0.48);
        assert!((speedup(&sol, &base) - 4.0).abs() < 1e-12);
        assert!((acc_delta_pp(&sol, &base) + 2.0).abs() < 1e-9);
    }

    #[test]
    fn bench_cfg_defaults() {
        let c = bench_cfg("vit-s", Strategy::Semi);
        assert_eq!(c.model, "vit-s");
        assert_eq!(c.balancer.strategy, Strategy::Semi);
        assert!(c.train.epochs >= 2);
    }
}
