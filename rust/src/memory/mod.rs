//! Memory-budgeted ranks (DESIGN.md §16).
//!
//! Every simulated rank owns a byte-accounted memory budget.  The
//! [`MemLedger`] charges a **modeled** footprint — weights, optimizer
//! moments, gradients, live activations, and the `Workspace` arena —
//! against a per-rank capacity (`--mem-cap`, `--mem-cap-rN`, or a
//! deterministic default derived from the manifest), tracks a per
//! iteration high-water mark, and classifies shortfalls:
//!
//! * **near-OOM** (projected headroom under [`NEAR_OOM_FRAC`] of
//!   capacity): the trainer triggers a drift-style replan with the
//!   balancer's headroom constraint engaged;
//! * **plan-infeasible** (the iteration's dynamic footprint does not fit
//!   even in activation-checkpointing mode): typed
//!   [`MemError::Infeasible`] — the plan is rejected, never a panic;
//! * **hard OOM** (the *static* footprint — weights + moments + grads —
//!   no longer fits, or a scripted `oom:rN@iterK` event): typed
//!   [`MemError::OutOfMemory`], recovered through the §14 churn path
//!   (evict the rank, re-shard survivors onto the nearest divisor E').
//!
//! Everything here is a pure function of the manifest, the balancing
//! plan, and the scenario events — never of wall time or actual arena
//! contents (which are thread-timing-dependent under `--threads N`) —
//! so ledger observables are bitwise identical at any thread count and
//! across the kill/checkpoint/`--resume --e E'` oracle.

use crate::runtime::manifest::ModelInfo;

/// Bytes per f32 element.
const F32: u64 = 4;

/// Near-OOM threshold: projected headroom below this fraction of the
/// effective capacity arms the memory-pressure replan trigger.
pub const NEAR_OOM_FRAC: f64 = 0.0625;

/// SimClock surcharge for activation-checkpointing mode: the backward
/// pass re-runs the forward compute, so a rank in recompute mode is
/// charged this fraction of its iteration compute time on top.
pub const RECOMPUTE_TIME_FRAC: f64 = 0.5;

/// Typed memory faults.  Never a panic: hard OOM routes through the
/// churn/recovery path, infeasible plans fail the run with this error
/// (which `flextp sweep` records as an explicit `"error"` row).
#[derive(Debug, Clone, PartialEq)]
pub enum MemError {
    /// The rank's static footprint exceeds its (possibly squeezed)
    /// capacity, or a scripted `oom:` event forced the condition.
    OutOfMemory { rank: usize, need_bytes: u64, cap_bytes: u64 },
    /// The balancing plan's dynamic footprint does not fit the rank's
    /// headroom even with activation checkpointing engaged.
    Infeasible { rank: usize, need_bytes: u64, headroom_bytes: u64 },
}

impl std::fmt::Display for MemError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemError::OutOfMemory { rank, need_bytes, cap_bytes } => write!(
                f,
                "rank {rank} out of memory: static footprint {need_bytes} B \
                 exceeds capacity {cap_bytes} B"
            ),
            MemError::Infeasible { rank, need_bytes, headroom_bytes } => write!(
                f,
                "no feasible plan for rank {rank}: iteration footprint {need_bytes} B \
                 exceeds headroom {headroom_bytes} B even with activation checkpointing"
            ),
        }
    }
}

impl std::error::Error for MemError {}

/// Deterministic per-rank footprint model (bytes).  Mirrors what the
/// simulator actually materializes per rank — shard parameters, SGD
/// moments, gradient buffers, live layer activations, and the workspace
/// arena's steady-state working set — as a pure function of the
/// manifest and the current plan.
#[derive(Debug, Clone)]
pub struct FootprintModel {
    /// shard + replicated parameter elements on one rank
    params: u64,
    /// activation elements held live per transformer layer (residual
    /// stream + QKV + attention output + both MLP intermediates)
    act_per_layer: u64,
    depth: u64,
    /// workspace arena steady-state: double-buffered largest per-layer
    /// working set
    workspace: u64,
}

impl FootprintModel {
    pub fn new(m: &ModelInfo) -> FootprintModel {
        let rep = (m.pd * m.hs + m.seq * m.hs + 3 * m.hs + m.hs * m.classes + m.classes) as u64;
        let params = m.params_per_worker as u64 + rep;
        let tokens = (m.bs * m.seq) as u64;
        let act_per_layer = tokens * (m.hs + 3 * m.hsl + m.hsl + m.ffl) as u64;
        FootprintModel {
            params,
            act_per_layer,
            depth: m.depth as u64,
            workspace: 2 * act_per_layer * F32,
        }
    }

    /// Static residents: weights + optimizer moments + gradient buffers.
    /// These exist whether or not an iteration is running; if they do
    /// not fit, the rank is hard-OOM.
    pub fn static_bytes(&self) -> u64 {
        3 * self.params * F32
    }

    /// Dynamic per-iteration bytes on top of the statics: live
    /// activations (all layers, or one layer's working set in
    /// activation-checkpointing mode), the workspace arena, and weight
    /// columns migrated *onto* this rank (`mig_in_cols` FFN columns,
    /// two panels of `hs` each, plus their activation column).
    pub fn iter_bytes(&self, m: &ModelInfo, mig_in_cols: u64, recompute: bool) -> u64 {
        let live_layers = if recompute { 1 } else { self.depth };
        let acts = self.act_per_layer * live_layers * F32;
        acts + self.workspace + mig_in_cols * mig_bytes_per_col(m)
    }

    /// Full no-pressure footprint: statics + a plain (non-recompute,
    /// no-migration) iteration.  The default capacity is derived from
    /// this.
    pub fn full_bytes(&self, m: &ModelInfo) -> u64 {
        self.static_bytes() + self.iter_bytes(m, 0, false)
    }

    /// Modeled steady-state workspace budget — what `shrink_to` trims a
    /// rank's actual arena back to after a re-shard/transition.
    pub fn workspace_budget(&self) -> u64 {
        self.workspace
    }
}

/// Bytes one migrated-in FFN column costs its receiver: two `hs` weight
/// panels plus one activation column per token.  The balancer's
/// receiver-headroom filter and the trainer's ledger share this constant
/// so the filter is exact, not an estimate.
pub fn mig_bytes_per_col(m: &ModelInfo) -> u64 {
    (2 * m.hs + m.bs * m.seq) as u64 * F32
}

/// Deterministic default capacity: twice the full per-rank footprint,
/// rounded up to a whole MiB — calm runs keep comfortable headroom, a
/// `memsqueeze:…:x0.5` lands the rank right at its working set, and the
/// value is a stable function of the manifest alone.
pub fn default_cap(m: &ModelInfo) -> u64 {
    let mib = 1u64 << 20;
    (2 * FootprintModel::new(m).full_bytes(m)).div_ceil(mib) * mib
}

/// The per-rank memory ledger.  All mutation happens on the coordinator
/// in rank order (the PR 2 determinism contract); charges saturate at
/// zero on release so the ledger can never go negative.
#[derive(Debug, Clone)]
pub struct MemLedger {
    /// configured capacity (before squeezes)
    cap: Vec<u64>,
    /// capacity fraction stolen by co-tenants (`memsqueeze` events);
    /// the latest event per rank wins
    squeeze: Vec<f64>,
    /// bytes currently charged
    used: Vec<u64>,
    /// high-water mark since the last `begin_iter`
    hwm: Vec<u64>,
}

impl MemLedger {
    /// Build a ledger for `e` ranks from the configured capacity
    /// (`cap_default`, normally `--mem-cap` or [`default_cap`]) plus
    /// per-rank overrides (`--mem-cap-rN`); overrides naming ranks
    /// beyond `e` are ignored (the group may have shrunk).
    pub fn new(e: usize, cap_default: u64, overrides: &[(usize, u64)]) -> MemLedger {
        let mut cap = vec![cap_default; e];
        for &(r, c) in overrides {
            if r < e {
                cap[r] = c;
            }
        }
        MemLedger { cap, squeeze: vec![0.0; e], used: vec![0; e], hwm: vec![0; e] }
    }

    pub fn e(&self) -> usize {
        self.cap.len()
    }

    /// Effective capacity after tenant squeezes.
    pub fn effective_cap(&self, rank: usize) -> u64 {
        (self.cap[rank] as f64 * (1.0 - self.squeeze[rank])).max(0.0) as u64
    }

    /// Record a `memsqueeze` event: a co-tenant steals `frac` of the
    /// rank's capacity.  The latest event per rank wins; fractions clamp
    /// to [0, 1].
    pub fn set_squeeze(&mut self, rank: usize, frac: f64) {
        self.squeeze[rank] = frac.clamp(0.0, 1.0);
    }

    pub fn squeeze_of(&self, rank: usize) -> f64 {
        self.squeeze[rank]
    }

    /// Charge bytes to a rank.  The charge always lands (the high-water
    /// mark must reflect the attempt); exceeding the effective capacity
    /// is the *caller's* fault to classify (hard OOM vs infeasible).
    pub fn charge(&mut self, rank: usize, bytes: u64) {
        self.used[rank] = self.used[rank].saturating_add(bytes);
        self.hwm[rank] = self.hwm[rank].max(self.used[rank]);
    }

    /// Release bytes; saturates at zero — the ledger never goes negative.
    pub fn release(&mut self, rank: usize, bytes: u64) {
        self.used[rank] = self.used[rank].saturating_sub(bytes);
    }

    pub fn used(&self, rank: usize) -> u64 {
        self.used[rank]
    }

    /// Remaining headroom (0 when at/over capacity — never negative).
    pub fn headroom(&self, rank: usize) -> u64 {
        self.effective_cap(rank).saturating_sub(self.used[rank])
    }

    /// Per-rank headroom vector (feeds the balancer's receiver filter).
    pub fn headrooms(&self) -> Vec<u64> {
        (0..self.e()).map(|r| self.headroom(r)).collect()
    }

    /// Start a fresh iteration window: clear the per-iteration
    /// high-water mark down to what is still charged.
    pub fn begin_iter(&mut self) {
        for r in 0..self.e() {
            self.hwm[r] = self.used[r];
        }
    }

    /// High-water mark since the last `begin_iter`.
    pub fn hwm(&self, rank: usize) -> u64 {
        self.hwm[rank]
    }

    /// Worst (max) high-water mark across ranks this iteration.
    pub fn hwm_max(&self) -> u64 {
        self.hwm.iter().copied().max().unwrap_or(0)
    }

    /// Tightest (min) headroom across ranks right now.
    pub fn headroom_min(&self) -> u64 {
        (0..self.e()).map(|r| self.headroom(r)).min().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn model() -> ModelInfo {
        crate::runtime::presets::synthesize("vit-tiny").unwrap().model
    }

    #[test]
    fn footprint_orders_sanely() {
        let m = model();
        let fp = FootprintModel::new(&m);
        assert!(fp.static_bytes() > 0);
        // recompute strictly shrinks the dynamic footprint (depth > 1)
        assert!(fp.iter_bytes(&m, 0, true) < fp.iter_bytes(&m, 0, false));
        // migrated-in columns strictly grow it
        assert!(fp.iter_bytes(&m, 16, false) > fp.iter_bytes(&m, 0, false));
        // the default capacity fits the full footprint twice over, MiB-aligned
        let cap = default_cap(&m);
        assert!(cap >= 2 * fp.full_bytes(&m));
        assert_eq!(cap % (1 << 20), 0);
    }

    #[test]
    fn ledger_never_goes_negative_and_headroom_is_bounded() {
        let m = model();
        let cap = default_cap(&m);
        let mut l = MemLedger::new(4, cap, &[(1, cap / 2), (99, 7)]);
        assert_eq!(l.effective_cap(1), cap / 2, "per-rank override applies");
        let mut rng = Rng::new(42);
        let mut charged = vec![0u64; 4];
        for _ in 0..10_000 {
            let r = (rng.next_u64() % 4) as usize;
            let b = rng.next_u64() % (cap / 8);
            if rng.next_u64() % 3 == 0 {
                l.charge(r, b);
                charged[r] = charged[r].saturating_add(b);
            } else {
                // releases routinely exceed what was charged — must saturate
                l.release(r, b);
                charged[r] = charged[r].saturating_sub(b);
            }
            assert!(l.used(r) <= charged[r].max(l.used(r)));
            assert!(l.headroom(r) <= l.effective_cap(r));
        }
        for r in 0..4 {
            l.release(r, u64::MAX);
            assert_eq!(l.used(r), 0, "ledger saturates at zero");
            assert_eq!(l.headroom(r), l.effective_cap(r));
        }
    }

    #[test]
    fn squeeze_shrinks_effective_cap_latest_wins() {
        let mut l = MemLedger::new(2, 1000, &[]);
        l.set_squeeze(0, 0.5);
        assert_eq!(l.effective_cap(0), 500);
        l.set_squeeze(0, 0.25);
        assert_eq!(l.effective_cap(0), 750, "the latest squeeze wins");
        l.set_squeeze(0, 7.0);
        assert_eq!(l.effective_cap(0), 0, "fractions clamp to [0,1]");
        assert_eq!(l.effective_cap(1), 1000);
    }

    #[test]
    fn hwm_tracks_the_iteration_peak() {
        let mut l = MemLedger::new(1, 1000, &[]);
        l.charge(0, 300); // statics
        l.begin_iter();
        l.charge(0, 400);
        l.release(0, 400);
        assert_eq!(l.hwm(0), 700);
        assert_eq!(l.used(0), 300);
        l.begin_iter();
        assert_eq!(l.hwm(0), 300, "begin_iter resets the peak to the residents");
        assert_eq!(l.hwm_max(), 300);
    }
}
