//! Run metrics: the paper's two evaluation quantities — RT (averaged
//! per-epoch elapsed time) and ACC (model accuracy after each epoch) —
//! plus the cost-accounting the SEMI allocator and §Perf need.

use std::path::Path;

use crate::util::json::{obj, Json};

/// Per-epoch record.
#[derive(Debug, Clone, Default)]
pub struct EpochMetrics {
    pub epoch: usize,
    /// simulated wall time of the epoch (max over ranks per iteration,
    /// summed) — the paper's RT
    pub rt_sim_s: f64,
    /// real host wall time (for §Perf accounting)
    pub rt_wall_s: f64,
    pub train_loss: f64,
    pub eval_loss: f64,
    /// eval accuracy in [0,1] — the paper's ACC
    pub acc: f64,
    /// total simulated bytes moved by collectives this epoch
    pub comm_bytes: u64,
    /// columns pruned across all stragglers/layers this epoch
    pub pruned_cols: u64,
    /// columns migrated this epoch
    pub migrated_cols: u64,
    /// per-rank compute seconds (sim) — straggler visibility
    pub rank_compute_s: Vec<f64>,
    /// balancing-plan recomputations this epoch: `--replan iter` counts
    /// every iteration, `epoch` exactly one, `online` the boundary plan
    /// plus every drift-triggered mid-epoch replan
    pub replans: u64,
    /// mean χ over this epoch's (iteration × rank) trace cells
    pub chi_mean: f64,
    /// max χ seen this epoch
    pub chi_max: f64,
    /// worst per-iteration memory high-water mark across ranks (bytes,
    /// modeled ledger — DESIGN.md §16)
    pub mem_hwm_bytes: u64,
    /// tightest end-of-iteration headroom across ranks this epoch
    /// (bytes; ≥ 0 by construction — the ledger saturates)
    pub mem_headroom_min_bytes: u64,
    /// rank·iterations spent in activation-checkpointing mode
    pub recompute_iters: u64,
}

impl EpochMetrics {
    /// Bitwise equality over every **simulated** quantity — everything
    /// except `rt_wall_s`, which measures real host time and legitimately
    /// differs between a resumed run (which only re-pays the post-resume
    /// wall time) and an uninterrupted one.  This is the comparison the
    /// checkpoint-resume parity suite and CI job pin.
    pub fn sim_equal(&self, o: &EpochMetrics) -> bool {
        self.epoch == o.epoch
            && self.rt_sim_s == o.rt_sim_s
            && self.train_loss == o.train_loss
            && self.eval_loss == o.eval_loss
            && self.acc == o.acc
            && self.comm_bytes == o.comm_bytes
            && self.pruned_cols == o.pruned_cols
            && self.migrated_cols == o.migrated_cols
            && self.rank_compute_s == o.rank_compute_s
            && self.replans == o.replans
            && self.chi_mean == o.chi_mean
            && self.chi_max == o.chi_max
            && self.mem_hwm_bytes == o.mem_hwm_bytes
            && self.mem_headroom_min_bytes == o.mem_headroom_min_bytes
            && self.recompute_iters == o.recompute_iters
    }
}

/// One `--timeline` sample: contention vs runtime, per iteration — the
/// raw material for plotting χ against RT and replan events.
///
/// Since the trace layer (DESIGN.md §17) these are synthesized by
/// `trace::Tracer::end_iter` from the same per-rank charge stream that
/// feeds `--trace` spans — one event stream, two views.  The fold is
/// bitwise-exact: the tracer accumulates the identical f64 charges in
/// the identical order the SimClocks do.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct IterSample {
    /// global iteration index
    pub giter: u64,
    pub epoch: usize,
    pub iter: usize,
    /// per-rank χ snapshot this iteration ran under
    pub chi: Vec<f64>,
    /// per-rank compute seconds T_i (sim)
    pub t_iter: Vec<f64>,
    /// simulated elapsed time of this iteration (max-rank clock delta)
    pub rt_iter_s: f64,
    /// did the balancer recompute its plan this iteration?
    pub replanned: bool,
}

impl IterSample {
    fn to_json(&self) -> Json {
        obj([
            ("giter", (self.giter as f64).into()),
            ("epoch", self.epoch.into()),
            ("iter", self.iter.into()),
            ("chi", self.chi.iter().copied().collect()),
            ("t_iter", self.t_iter.iter().copied().collect()),
            ("rt_iter_s", self.rt_iter_s.into()),
            ("replanned", self.replanned.into()),
        ])
    }
}

/// Whole-run report.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub label: String,
    pub epochs: Vec<EpochMetrics>,
    /// per-iteration training losses (the e2e loss curve)
    pub loss_curve: Vec<f32>,
    /// opt-in per-iteration contention/runtime samples (`--timeline`)
    pub timeline: Vec<IterSample>,
}

impl RunReport {
    pub fn new(label: &str) -> Self {
        RunReport { label: label.to_string(), ..Default::default() }
    }

    /// Paper RT: mean per-epoch simulated runtime.
    pub fn rt(&self) -> f64 {
        if self.epochs.is_empty() {
            return 0.0;
        }
        self.epochs.iter().map(|e| e.rt_sim_s).sum::<f64>() / self.epochs.len() as f64
    }

    /// Paper ACC: final-epoch accuracy.
    pub fn final_acc(&self) -> f64 {
        self.epochs.last().map(|e| e.acc).unwrap_or(0.0)
    }

    /// Best accuracy over the run (robust ACC for short bench runs).
    pub fn best_acc(&self) -> f64 {
        self.epochs.iter().map(|e| e.acc).fold(0.0, f64::max)
    }

    pub fn final_eval_loss(&self) -> f64 {
        self.epochs.last().map(|e| e.eval_loss).unwrap_or(f64::NAN)
    }

    pub fn total_comm_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.comm_bytes).sum()
    }

    /// Plan recomputations across the run (replan-overhead accounting).
    pub fn total_replans(&self) -> u64 {
        self.epochs.iter().map(|e| e.replans).sum()
    }

    /// Max χ seen across the run's realized trace.
    pub fn chi_max(&self) -> f64 {
        self.epochs.iter().map(|e| e.chi_max).fold(0.0, f64::max)
    }

    /// Mean of the per-epoch χ means (epochs share an iteration count).
    pub fn chi_mean(&self) -> f64 {
        if self.epochs.is_empty() {
            return 1.0;
        }
        self.epochs.iter().map(|e| e.chi_mean).sum::<f64>() / self.epochs.len() as f64
    }

    /// Peak modeled per-rank memory high-water-mark across epochs.
    pub fn mem_hwm_max(&self) -> u64 {
        self.epochs.iter().map(|e| e.mem_hwm_bytes).max().unwrap_or(0)
    }

    /// Tightest peak-usage headroom seen across epochs.
    pub fn mem_headroom_min(&self) -> u64 {
        self.epochs.iter().map(|e| e.mem_headroom_min_bytes).min().unwrap_or(0)
    }

    /// Rank-iterations that degraded to activation checkpointing.
    pub fn total_recompute_iters(&self) -> u64 {
        self.epochs.iter().map(|e| e.recompute_iters).sum()
    }

    /// Whole-run [`EpochMetrics::sim_equal`]: losses, per-epoch simulated
    /// metrics, and timeline samples all bitwise equal (wall time
    /// excluded).  Used by the resume-determinism harness to state "a
    /// resumed run is indistinguishable from an uninterrupted one".
    pub fn sim_equal(&self, o: &RunReport) -> bool {
        self.loss_curve == o.loss_curve
            && self.epochs.len() == o.epochs.len()
            && self.epochs.iter().zip(&o.epochs).all(|(a, b)| a.sim_equal(b))
            && self.timeline == o.timeline
    }

    pub fn to_json(&self) -> Json {
        let mut top = obj([
            ("label", self.label.as_str().into()),
            ("rt", self.rt().into()),
            ("final_acc", self.final_acc().into()),
            ("best_acc", self.best_acc().into()),
            ("loss_curve", self.loss_curve.iter().map(|l| *l as f64).collect()),
            (
                "epochs",
                Json::Arr(
                    self.epochs
                        .iter()
                        .map(|e| {
                            obj([
                                ("epoch", e.epoch.into()),
                                ("rt_sim_s", e.rt_sim_s.into()),
                                ("rt_wall_s", e.rt_wall_s.into()),
                                ("train_loss", e.train_loss.into()),
                                ("eval_loss", e.eval_loss.into()),
                                ("acc", e.acc.into()),
                                ("comm_bytes", (e.comm_bytes as f64).into()),
                                ("pruned_cols", (e.pruned_cols as f64).into()),
                                ("migrated_cols", (e.migrated_cols as f64).into()),
                                ("replans", (e.replans as f64).into()),
                                ("chi_mean", e.chi_mean.into()),
                                ("chi_max", e.chi_max.into()),
                                ("mem_hwm_bytes", (e.mem_hwm_bytes as f64).into()),
                                (
                                    "mem_headroom_min_bytes",
                                    (e.mem_headroom_min_bytes as f64).into(),
                                ),
                                ("recompute_iters", (e.recompute_iters as f64).into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        if !self.timeline.is_empty() {
            if let Json::Obj(m) = &mut top {
                m.insert(
                    "timeline".to_string(),
                    Json::Arr(self.timeline.iter().map(|s| s.to_json()).collect()),
                );
            }
        }
        top
    }

    pub fn save_json(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())
    }

    /// One-line summary for logs/bench output.
    pub fn summary(&self) -> String {
        format!(
            "{}: RT={:.3}s/epoch ACC={:.1}% loss={:.3} comm={}",
            self.label,
            self.rt(),
            100.0 * self.final_acc(),
            self.final_eval_loss(),
            crate::util::fmt_bytes(self.total_comm_bytes()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(rts: &[f64], accs: &[f64]) -> RunReport {
        let mut r = RunReport::new("t");
        for (i, (&rt, &acc)) in rts.iter().zip(accs).enumerate() {
            r.epochs.push(EpochMetrics {
                epoch: i,
                rt_sim_s: rt,
                acc,
                ..Default::default()
            });
        }
        r
    }

    #[test]
    fn rt_is_mean_of_epochs() {
        let r = mk(&[1.0, 3.0], &[0.1, 0.2]);
        assert_eq!(r.rt(), 2.0);
    }

    #[test]
    fn acc_final_and_best() {
        let r = mk(&[1.0, 1.0, 1.0], &[0.3, 0.6, 0.5]);
        assert_eq!(r.final_acc(), 0.5);
        assert_eq!(r.best_acc(), 0.6);
    }

    #[test]
    fn empty_report_is_safe() {
        let r = RunReport::new("e");
        assert_eq!(r.rt(), 0.0);
        assert_eq!(r.final_acc(), 0.0);
    }

    #[test]
    fn json_emits() {
        let r = mk(&[1.0], &[0.5]);
        let j = r.to_json().to_string();
        assert!(j.contains("\"rt\":1"));
        assert!(j.contains("\"replans\":0"));
        assert!(j.contains("\"chi_max\":0"));
        assert!(!j.contains("\"timeline\""), "timeline is opt-in");
        assert!(Json::parse(&j).is_ok());
    }

    #[test]
    fn replan_and_chi_accounting() {
        let mut r = mk(&[1.0, 1.0], &[0.1, 0.2]);
        r.epochs[0].replans = 3;
        r.epochs[0].chi_mean = 1.5;
        r.epochs[0].chi_max = 6.0;
        r.epochs[1].replans = 1;
        r.epochs[1].chi_mean = 2.5;
        r.epochs[1].chi_max = 4.0;
        assert_eq!(r.total_replans(), 4);
        assert_eq!(r.chi_max(), 6.0);
        assert!((r.chi_mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sim_equal_ignores_wall_time_only() {
        let mut a = mk(&[1.0, 2.0], &[0.1, 0.2]);
        a.loss_curve = vec![2.5, 2.25];
        let mut b = a.clone();
        b.epochs[0].rt_wall_s = 99.0; // wall time may differ
        assert!(a.sim_equal(&b));
        b.epochs[1].rt_sim_s += 1e-9; // any sim field may not
        assert!(!a.sim_equal(&b));
        let mut m = a.clone();
        m.epochs[0].mem_hwm_bytes = 1; // ledger observables are simulated
        assert!(!a.sim_equal(&m));
        let mut c = a.clone();
        c.loss_curve[1] = 2.26;
        assert!(!a.sim_equal(&c));
        let mut d = a.clone();
        d.epochs.pop();
        assert!(!a.sim_equal(&d));
    }

    #[test]
    fn timeline_emits_when_present() {
        let mut r = mk(&[1.0], &[0.5]);
        r.timeline.push(IterSample {
            giter: 4,
            epoch: 0,
            iter: 4,
            chi: vec![1.0, 6.0],
            t_iter: vec![0.01, 0.06],
            rt_iter_s: 0.06,
            replanned: true,
        });
        let j = r.to_json().to_string();
        assert!(j.contains("\"timeline\""));
        assert!(j.contains("\"replanned\":true"));
        let parsed = Json::parse(&j).unwrap();
        let tl = parsed.get("timeline").unwrap().arr().unwrap();
        assert_eq!(tl.len(), 1);
        assert_eq!(tl[0].get("chi").unwrap().arr().unwrap().len(), 2);
    }
}
