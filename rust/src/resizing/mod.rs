//! ZERO-resizing (paper §III): dynamic workload balancing by temporarily
//! shrinking the contraction dimension of the straggler's GEMMs.
//!
//! * [`lineage`] — the lookup table recording which dimensions were pruned
//!   so recovered gradients map to the right weight columns, plus the
//!   imputation policies (Zero/Average/Same, paper Fig. 3).
//! * [`priority`] — `w_var_list` / `pri_list`: prune the columns whose
//!   weights moved least, with the *incremental* update that breaks the
//!   zero-imputation false-positive endless loop (paper §III-B).
//! * [`ResizePlanner`] — Algorithm 1: uniform γ from Eq. (1), per-layer
//!   differentiated γ_k via θ = N_iter·θ_iter and γ_k = max(γ_k, α·γ),
//!   rounded UP to the compiled pruning buckets.

pub mod lineage;
pub mod priority;

use crate::runtime::manifest::Manifest;
use crate::util::rng::Rng;
use priority::BlockTrackers;

/// How pruned columns are chosen.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// blind random (paper ZERO-Rd)
    Random,
    /// importance-based (paper ZERO-Pri)
    Priority,
}

/// Per-layer resizing decision for one worker and one iteration.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// bucket names (manifest naming contract)
    pub attn_bucket: String,
    pub mlp_b1: String,
    pub mlp_b2: String,
    /// kept contraction indices (ascending — the paper's lexicographic
    /// concatenation), sized exactly to the bucket's keep count
    pub attn_keep: Vec<u32>,
    pub mlp_keep1: Vec<u32>,
    pub mlp_keep2: Vec<u32>,
}

impl LayerPlan {
    /// The no-op (γ=0) plan.
    pub fn full(hs: usize, ffl: usize) -> LayerPlan {
        let all_hs: Vec<u32> = (0..hs as u32).collect();
        let all_ffl: Vec<u32> = (0..ffl as u32).collect();
        LayerPlan {
            attn_bucket: "g00".into(),
            mlp_b1: "g00".into(),
            mlp_b2: "g00".into(),
            attn_keep: all_hs.clone(),
            mlp_keep1: all_hs,
            mlp_keep2: all_ffl,
        }
    }

    pub fn is_full(&self) -> bool {
        self.attn_bucket == "g00" && self.mlp_b1 == "g00" && self.mlp_b2 == "g00"
    }

    /// Total pruned columns in this plan (metrics).
    pub fn pruned_cols(&self, hs: usize, ffl: usize) -> u64 {
        ((hs - self.attn_keep.len()) + (hs - self.mlp_keep1.len())
            + (ffl - self.mlp_keep2.len())) as u64
    }
}

/// Pick a keep-set of `keep` indices out of `n`.
pub fn select_keep(
    n: usize,
    keep: usize,
    selection: Selection,
    tracker: Option<&priority::Tracker>,
    rng: &mut Rng,
) -> Vec<u32> {
    debug_assert!(keep <= n);
    if keep == n {
        return (0..n as u32).collect();
    }
    match (selection, tracker) {
        (Selection::Priority, Some(t)) if t.has_stats() => t.keep_set(keep),
        // Rd, or Pri before any statistics exist (first epoch)
        _ => rng.choose_k(n, keep),
    }
}

/// Algorithm 1 driver: produce per-layer plans for one straggling worker.
pub struct ResizePlanner<'a> {
    pub manifest: &'a Manifest,
    pub selection: Selection,
    /// θ_iter micro-threshold (paper default 1e-3)
    pub theta_iter: f64,
    /// decay factor α (paper default 0.8)
    pub alpha: f64,
    pub iters_per_epoch: usize,
}

impl<'a> ResizePlanner<'a> {
    /// Uniform-γ plan (ZERO-Rd / ZERO-Pri): one bucket for all layers.
    pub fn plan_uniform(
        &self,
        gamma: f64,
        trackers: &[BlockTrackers],
        rng: &mut Rng,
    ) -> Vec<LayerPlan> {
        let m = &self.manifest.model;
        let b = self.manifest.bucket_for_gamma(gamma);
        (0..m.depth)
            .map(|k| {
                let t = &trackers[k];
                LayerPlan {
                    attn_bucket: b.name.clone(),
                    mlp_b1: b.name.clone(),
                    mlp_b2: b.name.clone(),
                    attn_keep: select_keep(
                        m.hs, b.keep_hs, self.selection, Some(&t.qkv), rng),
                    mlp_keep1: select_keep(
                        m.hs, b.keep_hs, self.selection, Some(&t.fc1), rng),
                    mlp_keep2: select_keep(
                        m.ffl, b.keep_ffl, self.selection, Some(&t.fc2), rng),
                }
            })
            .collect()
    }

    /// Differentiated per-layer plan (ZERO-PriDiff{E,R}, Alg. 1 lines
    /// 3-15): γ_k from the candidate set {δ_i < θ}, floored by α·γ_uniform,
    /// then rounded up to a bucket.
    pub fn plan_diff(
        &self,
        gamma_uniform: f64,
        trackers: &[BlockTrackers],
        rng: &mut Rng,
    ) -> Vec<LayerPlan> {
        let m = &self.manifest.model;
        let theta = (self.iters_per_epoch as f64) * self.theta_iter;
        (0..m.depth)
            .map(|k| {
                let t = &trackers[k];
                // candidate-set ratio per prunable contraction
                let g_qkv = self.layer_gamma(t.qkv.frac_below(theta), gamma_uniform);
                let g_fc1 = self.layer_gamma(t.fc1.frac_below(theta), gamma_uniform);
                let g_fc2 = self.layer_gamma(t.fc2.frac_below(theta), gamma_uniform);
                let bq = self.manifest.bucket_for_gamma(g_qkv);
                let b1 = self.manifest.bucket_for_gamma(g_fc1);
                let b2 = self.manifest.bucket_for_gamma(g_fc2);
                LayerPlan {
                    attn_bucket: bq.name.clone(),
                    mlp_b1: b1.name.clone(),
                    mlp_b2: b2.name.clone(),
                    attn_keep: select_keep(
                        m.hs, bq.keep_hs, self.selection, Some(&t.qkv), rng),
                    mlp_keep1: select_keep(
                        m.hs, b1.keep_hs, self.selection, Some(&t.fc1), rng),
                    mlp_keep2: select_keep(
                        m.ffl, b2.keep_ffl, self.selection, Some(&t.fc2), rng),
                }
            })
            .collect()
    }

    /// γ_k = max(candidate-fraction, α·γ_uniform)  (Alg. 1 line 11).
    fn layer_gamma(&self, candidate_frac: f64, gamma_uniform: f64) -> f64 {
        candidate_frac.max(self.alpha * gamma_uniform).min(0.9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "model": {"name":"t","hs":32,"depth":2,"heads":4,"e":4,"bs":2,
                    "classes":10,"seq":17,"seq0":16,"pd":48,"hsl":8,"hl":1,
                    "hd":8,"ffl":32,"params_total":0,"params_per_worker":0},
          "buckets": [
            {"name":"g00","gamma":0,"keep_hs":32,"keep_ffl":32},
            {"name":"g25","gamma":0.25,"keep_hs":24,"keep_ffl":24},
            {"name":"g50","gamma":0.5,"keep_hs":16,"keep_ffl":16},
            {"name":"g88","gamma":0.875,"keep_hs":8,"keep_ffl":8}
          ],
          "mig_buckets": [8, 16],
          "executables": []
        }"#,
        )
        .unwrap()
    }

    fn planner(m: &Manifest) -> ResizePlanner {
        ResizePlanner {
            manifest: m,
            selection: Selection::Random,
            theta_iter: 1e-3,
            alpha: 0.8,
            iters_per_epoch: 10,
        }
    }

    fn trackers(m: &Manifest) -> Vec<BlockTrackers> {
        (0..m.model.depth)
            .map(|_| BlockTrackers::new(m.model.hs, m.model.hs, m.model.ffl))
            .collect()
    }

    #[test]
    fn full_plan_is_identity() {
        let p = LayerPlan::full(32, 64);
        assert!(p.is_full());
        assert_eq!(p.attn_keep.len(), 32);
        assert_eq!(p.pruned_cols(32, 64), 0);
    }

    #[test]
    fn uniform_plan_rounds_up() {
        let m = manifest();
        let pl = planner(&m);
        let t = trackers(&m);
        let mut rng = Rng::new(1);
        let plans = pl.plan_uniform(0.3, &t, &mut rng); // 0.3 → g50 bucket
        assert_eq!(plans.len(), 2);
        for p in &plans {
            assert_eq!(p.attn_bucket, "g50");
            assert_eq!(p.attn_keep.len(), 16);
            assert_eq!(p.mlp_keep2.len(), 16);
            // keep sets sorted ascending & unique
            assert!(p.attn_keep.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn diff_plan_respects_alpha_floor() {
        let m = manifest();
        let pl = planner(&m);
        let t = trackers(&m); // no stats → candidate_frac = 0
        let mut rng = Rng::new(1);
        // α·γ = 0.8·0.5 = 0.4 → bucket g50 (round up)
        let plans = pl.plan_diff(0.5, &t, &mut rng);
        for p in &plans {
            assert_eq!(p.attn_bucket, "g50");
        }
    }

    #[test]
    fn select_keep_falls_back_to_random_without_stats() {
        let mut rng = Rng::new(2);
        let t = priority::Tracker::new(16);
        let keep = select_keep(16, 8, Selection::Priority, Some(&t), &mut rng);
        assert_eq!(keep.len(), 8);
        assert!(keep.windows(2).all(|w| w[0] < w[1]));
    }
}
