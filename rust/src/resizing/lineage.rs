//! Lineage lookup table + imputation policies (paper §III-A).
//!
//! The executables return *full-shape* gradients whose pruned rows/columns
//! are exactly zero (the kernel's scatter-add backward).  The lineage
//! records which positions those are, so (a) gradients stay correctly
//! aligned with weights — "map the i-th column gradients to the i-th
//! column weight parameters" — and (b) the Average/Same policies can
//! re-impute them host-side.  Zero is a no-op by construction.

use crate::config::Imputation;
use crate::tensor::Tensor;

/// Kept/pruned index sets over one contraction dimension.
#[derive(Debug, Clone)]
pub struct Lineage {
    pub full: usize,
    /// ascending kept indices
    pub kept: Vec<u32>,
    /// ascending pruned indices (complement)
    pub pruned: Vec<u32>,
}

impl Lineage {
    pub fn new(full: usize, kept: &[u32]) -> Lineage {
        debug_assert!(kept.windows(2).all(|w| w[0] < w[1]), "kept must be sorted");
        let mut is_kept = vec![false; full];
        for &i in kept {
            is_kept[i as usize] = true;
        }
        let pruned = (0..full as u32).filter(|&i| !is_kept[i as usize]).collect();
        Lineage { full, kept: kept.to_vec(), pruned }
    }

    pub fn identity(full: usize) -> Lineage {
        Lineage { full, kept: (0..full as u32).collect(), pruned: Vec::new() }
    }

    pub fn is_identity(&self) -> bool {
        self.pruned.is_empty()
    }
}

/// Re-impute the pruned ROWS of a full-shape gradient (wqkv/w1-row,
/// w2-row lineages) according to the policy.  `prev` is last iteration's
/// gradient for this tensor (required by Same).
pub fn impute_rows(grad: &mut Tensor, lin: &Lineage, policy: Imputation, prev: Option<&Tensor>) {
    if lin.is_identity() {
        return;
    }
    match policy {
        Imputation::Zero => {} // executables already left exact zeros
        Imputation::Average => grad.impute_rows_mean(&lin.pruned),
        Imputation::Same => {
            if let Some(p) = prev {
                grad.copy_rows_from(&lin.pruned, p);
            }
        }
    }
}

/// Re-impute the pruned COLUMNS of a full-shape gradient (w1's co-pruned
/// output columns).
pub fn impute_cols(grad: &mut Tensor, lin: &Lineage, policy: Imputation, prev: Option<&Tensor>) {
    if lin.is_identity() {
        return;
    }
    match policy {
        Imputation::Zero => {}
        Imputation::Average => grad.impute_cols_mean(&lin.pruned),
        Imputation::Same => {
            if let Some(p) = prev {
                grad.copy_cols_from(&lin.pruned, p);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complement_is_exact() {
        let l = Lineage::new(8, &[0, 2, 5]);
        assert_eq!(l.pruned, vec![1, 3, 4, 6, 7]);
        assert_eq!(l.kept.len() + l.pruned.len(), 8);
    }

    #[test]
    fn identity_has_no_pruned() {
        let l = Lineage::identity(16);
        assert!(l.is_identity());
        assert_eq!(l.kept.len(), 16);
    }

    #[test]
    fn roundtrip_gather_scatter_via_lineage() {
        // expand(compact(g)) restores kept rows exactly (DESIGN.md §6 inv.)
        let g = Tensor::from_vec(&[4, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let lin = Lineage::new(4, &[1, 3]);
        let compact = g.gather_rows(&lin.kept);
        let mut full = Tensor::zeros(&[4, 2]);
        full.scatter_rows_assign(&lin.kept, &compact);
        assert_eq!(&full.data[2..4], &[3., 4.]);
        assert_eq!(&full.data[6..8], &[7., 8.]);
        assert_eq!(&full.data[0..2], &[0., 0.]); // pruned zeros
    }

    #[test]
    fn zero_policy_keeps_zeros() {
        let lin = Lineage::new(3, &[0, 2]);
        let mut g = Tensor::from_vec(&[3, 2], vec![1., 1., 0., 0., 2., 2.]);
        impute_rows(&mut g, &lin, Imputation::Zero, None);
        assert_eq!(&g.data[2..4], &[0., 0.]);
    }

    #[test]
    fn average_policy_fills_mean() {
        let lin = Lineage::new(3, &[0, 2]);
        let mut g = Tensor::from_vec(&[3, 2], vec![1., 4., 0., 0., 3., 8.]);
        impute_rows(&mut g, &lin, Imputation::Average, None);
        assert_eq!(&g.data[2..4], &[2., 6.]); // column means of kept rows
    }

    #[test]
    fn same_policy_copies_previous() {
        let lin = Lineage::new(3, &[0, 2]);
        let prev = Tensor::from_vec(&[3, 2], vec![9., 9., 7., 7., 9., 9.]);
        let mut g = Tensor::from_vec(&[3, 2], vec![1., 1., 0., 0., 2., 2.]);
        impute_rows(&mut g, &lin, Imputation::Same, Some(&prev));
        assert_eq!(&g.data[2..4], &[7., 7.]);
        // kept rows untouched
        assert_eq!(&g.data[0..2], &[1., 1.]);
    }

    #[test]
    fn col_imputation_variants() {
        let lin = Lineage::new(3, &[0, 2]); // col 1 pruned
        let mut g = Tensor::from_vec(&[2, 3], vec![1., 0., 3., 4., 0., 8.]);
        impute_cols(&mut g, &lin, Imputation::Average, None);
        assert_eq!(g.data[1], 2.0); // (1+3)/2
        assert_eq!(g.data[4], 6.0); // (4+8)/2

        let prev = Tensor::from_vec(&[2, 3], vec![0., 5., 0., 0., 6., 0.]);
        let mut g = Tensor::from_vec(&[2, 3], vec![1., 0., 3., 4., 0., 8.]);
        impute_cols(&mut g, &lin, Imputation::Same, Some(&prev));
        assert_eq!(g.data[1], 5.0);
        assert_eq!(g.data[4], 6.0);
    }
}
