//! Priority pruning statistics (paper §III-B).
//!
//! Per prunable contraction we track `w_var_list`: δ_i = mean |Δ| of the
//! weight values at contraction index i since the last epoch.  Columns
//! with the smallest variation are pruned first.  The update is
//! **incremental**: indices pruned during the last epoch keep their stale
//! δ — a fresh δ would be ≈0 (zero-imputed gradients barely move those
//! weights), they would be re-pruned forever, and pruning would become a
//! permanent structural change.  With stale values they re-enter the pool
//! on their old merit, giving the paper's "round-robin yet prioritized"
//! schedule.

/// Variation tracker for one contraction dimension of one weight matrix.
#[derive(Debug, Clone)]
pub struct Tracker {
    /// δ per contraction index; None until the first epoch completes
    pub(crate) w_var: Option<Vec<f32>>,
    n: usize,
}

impl Tracker {
    pub fn new(n: usize) -> Tracker {
        Tracker { w_var: None, n }
    }

    pub fn has_stats(&self) -> bool {
        self.w_var.is_some()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Epoch-end update.  `delta[i]` = fresh mean |Δ| for index i;
    /// `pruned_last_epoch` keeps stale values (incremental update).
    pub fn epoch_update(&mut self, delta: &[f32], pruned_last_epoch: &[u32]) {
        debug_assert_eq!(delta.len(), self.n);
        match &mut self.w_var {
            None => {
                // first stats: everything fresh (nothing was pruned before
                // statistics existed — trackers gate pruning selection)
                self.w_var = Some(delta.to_vec());
            }
            Some(v) => {
                let mut stale = vec![false; self.n];
                for &i in pruned_last_epoch {
                    stale[i as usize] = true;
                }
                for i in 0..self.n {
                    if !stale[i] {
                        v[i] = delta[i];
                    }
                }
            }
        }
    }

    /// Keep-set: the `keep` indices with the LARGEST variation (their
    /// complement — the smallest-δ columns — is the paper's pri_list),
    /// ascending order.  Ties break toward keeping the lower index.
    pub fn keep_set(&self, keep: usize) -> Vec<u32> {
        let v = self.w_var.as_ref().expect("keep_set requires stats");
        let mut idx: Vec<u32> = (0..self.n as u32).collect();
        // sort by δ descending, index ascending for ties
        idx.sort_by(|&a, &b| {
            let (da, db) = (v[a as usize], v[b as usize]);
            db.partial_cmp(&da).unwrap().then(a.cmp(&b))
        });
        let mut kept: Vec<u32> = idx.into_iter().take(keep).collect();
        kept.sort_unstable();
        kept
    }

    /// The pri_list itself (to-be-pruned indices, smallest δ first).
    /// Ties break toward pruning the HIGHER index — the exact reverse of
    /// [`Tracker::keep_set`]'s ranking, so `pri_list(c)` is always the
    /// set complement of `keep_set(n − c)` even when δ values collide
    /// (tied δ used to land the same index in both sets).
    pub fn pri_list(&self, count: usize) -> Vec<u32> {
        let v = self.w_var.as_ref().expect("pri_list requires stats");
        let mut idx: Vec<u32> = (0..self.n as u32).collect();
        idx.sort_by(|&a, &b| {
            let (da, db) = (v[a as usize], v[b as usize]);
            da.partial_cmp(&db).unwrap().then(b.cmp(&a))
        });
        idx.truncate(count);
        idx.sort_unstable(); // ascending, per Alg. 1 line 14
        idx
    }

    /// Full keep-priority ranking: all indices, highest δ first (the
    /// order SEMI uses to split kept / migrated / pruned three ways).
    pub fn rank_all(&self) -> Vec<u32> {
        let v = self.w_var.as_ref().expect("rank_all requires stats");
        let mut idx: Vec<u32> = (0..self.n as u32).collect();
        idx.sort_by(|&a, &b| {
            let (da, db) = (v[a as usize], v[b as usize]);
            db.partial_cmp(&da).unwrap().then(a.cmp(&b))
        });
        idx
    }

    /// Fraction of indices with δ < θ (the differentiated-ratio candidate
    /// set, Alg. 1 lines 9-10). 0 before stats exist.
    pub fn frac_below(&self, theta: f64) -> f64 {
        match &self.w_var {
            None => 0.0,
            Some(v) => {
                v.iter().filter(|&&d| (d as f64) < theta).count() as f64 / self.n as f64
            }
        }
    }
}

/// The three prunable contractions of one transformer block.
#[derive(Debug, Clone)]
pub struct BlockTrackers {
    /// QKV input dim (hs) — tracked on wqkv rows
    pub qkv: Tracker,
    /// FC1 input dim (hs) — tracked on w1 rows
    pub fc1: Tracker,
    /// FC2 input dim (ffl) — tracked on w2 rows
    pub fc2: Tracker,
}

impl BlockTrackers {
    pub fn new(hs_qkv: usize, hs_fc1: usize, ffl: usize) -> BlockTrackers {
        BlockTrackers {
            qkv: Tracker::new(hs_qkv),
            fc1: Tracker::new(hs_fc1),
            fc2: Tracker::new(ffl),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_stats_until_first_update() {
        let t = Tracker::new(8);
        assert!(!t.has_stats());
        assert_eq!(t.frac_below(1.0), 0.0);
    }

    #[test]
    fn keep_set_prefers_high_variation() {
        let mut t = Tracker::new(4);
        t.epoch_update(&[0.1, 0.9, 0.05, 0.5], &[]);
        assert_eq!(t.keep_set(2), vec![1, 3]); // largest δ
        assert_eq!(t.pri_list(2), vec![0, 2]); // smallest δ, ascending
    }

    #[test]
    fn keep_and_pri_partition() {
        let mut t = Tracker::new(6);
        t.epoch_update(&[0.3, 0.1, 0.6, 0.2, 0.5, 0.4], &[]);
        let kept = t.keep_set(4);
        let pri = t.pri_list(2);
        let mut all: Vec<u32> = kept.iter().chain(pri.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn incremental_update_keeps_stale_for_pruned() {
        let mut t = Tracker::new(4);
        t.epoch_update(&[0.5, 0.6, 0.7, 0.8], &[]);
        // index 0 was pruned last epoch: its fresh δ would be ~0 (zero
        // imputation), but we must keep 0.5 — otherwise it is re-pruned
        // forever (the endless-loop the paper terminates).
        t.epoch_update(&[0.0, 0.3, 0.71, 0.82], &[0]);
        // stale 0.5 beats index 1's fresh 0.3 → 0 survives on old merit
        assert_eq!(t.keep_set(3), vec![0, 2, 3]);
        assert_eq!(t.pri_list(1), vec![1]);
    }

    #[test]
    fn without_incremental_update_pruning_locks_in() {
        // Control experiment: demonstrate WHY incremental update matters.
        let mut naive = vec![0.5f32, 0.6, 0.7, 0.8];
        // epoch 1: prune argmin = 0. Fresh stats: pruned col barely moved.
        naive[0] = 0.0;
        let argmin = (0..4).min_by(|&a, &b| naive[a].partial_cmp(&naive[b]).unwrap());
        assert_eq!(argmin, Some(0)); // 0 would be pruned again — the loop
    }

    #[test]
    fn ties_break_deterministically_and_complementarily() {
        let mut t = Tracker::new(4);
        t.epoch_update(&[0.5, 0.5, 0.5, 0.5], &[]);
        // keep_set ties keep the lower index; pri_list ties prune the
        // higher index — so the two stay an exact partition under ties.
        assert_eq!(t.keep_set(2), vec![0, 1]);
        assert_eq!(t.pri_list(2), vec![2, 3]);
    }

    #[test]
    fn frac_below_counts() {
        let mut t = Tracker::new(4);
        t.epoch_update(&[0.1, 0.2, 0.3, 0.4], &[]);
        assert_eq!(t.frac_below(0.25), 0.5);
        assert_eq!(t.frac_below(1.0), 1.0);
        assert_eq!(t.frac_below(0.05), 0.0);
    }
}
