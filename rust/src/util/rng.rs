//! Seeded xorshift128+ RNG — deterministic init, data generation, and the
//! property-test harness (the `rand` facade is unavailable offline).

/// xorshift128+ — fast, tiny, good enough for init/data/property tests.
#[derive(Debug, Clone)]
pub struct Rng {
    s0: u64,
    s1: u64,
    /// cached second normal from Box-Muller
    spare: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed so nearby seeds decorrelate.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let s0 = next().max(1);
        let s1 = next().max(1);
        Rng { s0, s1, spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let mut u1 = self.uniform();
        if u1 < 1e-12 {
            u1 = 1e-12;
        }
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f32::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Export the full stream state — (s0, s1, cached Box-Muller spare).
    /// Together with the seed-independent transition function this makes
    /// an `Rng` a resumable *stream cursor*: `from_state(a.state())`
    /// continues exactly where `a` stopped (checkpoint/resume).
    pub fn state(&self) -> (u64, u64, Option<f32>) {
        (self.s0, self.s1, self.spare)
    }

    /// Rebuild an RNG from an exported [`Rng::state`].  The all-zero
    /// xorshift fixed point (never produced by `new`) is nudged off zero
    /// so a corrupt state cannot freeze the stream.
    pub fn from_state(s0: u64, s1: u64, spare: Option<f32>) -> Rng {
        if s0 == 0 && s1 == 0 {
            return Rng { s0: 1, s1: 1, spare };
        }
        Rng { s0, s1, spare }
    }

    /// k distinct indices from [0, n), ascending.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<u32> {
        debug_assert!(k <= n);
        let mut idx: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn state_roundtrip_resumes_stream_exactly() {
        let mut a = Rng::new(9);
        for _ in 0..17 {
            a.normal(); // odd count leaves a Box-Muller spare cached
        }
        let (s0, s1, spare) = a.state();
        let mut b = Rng::from_state(s0, s1, spare);
        for _ in 0..100 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // the all-zero fixed point is rejected
        let mut z = Rng::from_state(0, 0, None);
        assert_ne!(z.next_u64(), 0);
    }

    #[test]
    fn choose_k_distinct_sorted() {
        let mut r = Rng::new(3);
        for _ in 0..100 {
            let v = r.choose_k(64, 16);
            assert_eq!(v.len(), 16);
            assert!(v.windows(2).all(|w| w[0] < w[1]));
            assert!(v.iter().all(|&i| i < 64));
        }
    }
}
