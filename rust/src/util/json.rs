//! Minimal JSON parser/emitter (serde is unavailable offline).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`
//! and serializes metric/bench outputs. Supports the full JSON grammar we
//! emit: objects, arrays, strings (with escapes), f64 numbers, bools,
//! null. Not a general-purpose validator — malformed input yields `Err`,
//! never UB or panic.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        let n = self.num()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn dims(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|d| d.usize()).collect()
    }

    // ---- emission ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s);
        s
    }

    fn emit(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => emit_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.emit(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_str(k, out);
                    out.push(':');
                    v.emit(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> FromIterator<T> for Json {
    fn from_iter<I: IntoIterator<Item = T>>(it: I) -> Self {
        Json::Arr(it.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder: `obj([("a", 1.0.into()), ...])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(pairs: I) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected EOF"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c => {
                    // multi-byte UTF-8: copy raw bytes until char boundary
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let bytes = &self.b[start..start + len];
                        s.push_str(std::str::from_utf8(bytes)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-1.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{"model": {"hs": 128, "name": "vit-tiny"},
                       "executables": [{"name": "attn_fwd_g00",
                                        "inputs": [{"dims": [8, 65, 128]}]}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("model").unwrap().get("hs").unwrap().usize().unwrap(), 128);
        let ex = &v.get("executables").unwrap().arr().unwrap()[0];
        assert_eq!(ex.get("name").unwrap().str().unwrap(), "attn_fwd_g00");
        assert_eq!(
            ex.get("inputs").unwrap().arr().unwrap()[0].get("dims").unwrap().dims().unwrap(),
            vec![8, 65, 128]
        );
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.str().unwrap(), "a\nb\t\"c\" A");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo — ✓\"").unwrap();
        assert_eq!(v.str().unwrap(), "héllo — ✓");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn nested_depth() {
        let v = Json::parse("[[[[[1]]]]]").unwrap();
        let mut cur = &v;
        for _ in 0..5 {
            cur = &cur.arr().unwrap()[0];
        }
        assert_eq!(cur.num().unwrap(), 1.0);
    }
}
