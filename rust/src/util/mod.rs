//! Dependency-free substrate utilities.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! tree (no serde / clap / rand / criterion), so the pieces a framework
//! normally pulls from crates.io are implemented here: a JSON
//! parser/emitter for the artifact manifests and metric dumps, a seeded
//! xorshift RNG for deterministic init/data, the `tensors.bin`
//! cross-language bundle format, and plain-text table rendering for the
//! paper-figure benches.

pub mod bin;
pub mod json;
pub mod rng;
pub mod table;

/// Greatest common divisor (elastic worker-count validation).
pub fn gcd(a: usize, b: usize) -> usize {
    let (mut a, mut b) = (a, b);
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Format a byte count human-readably (metrics/logs).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format seconds with adaptive precision (RT columns).
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else {
        format!("{:.2}ms", s * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(123.4), "123");
        assert_eq!(fmt_secs(1.5), "1.50");
        assert_eq!(fmt_secs(0.0123), "12.30ms");
    }
}
