//! `tensors.bin` — the cross-language tensor bundle format.
//!
//! Layout (written by `python/compile/golden.py::write_bundle`):
//!   u32 LE header length, JSON header
//!   `{"entries": [{name, dims, dtype, offset_elems, count}]}`,
//!   then raw little-endian element data (f32 or i32, 4 bytes each).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub dims: Vec<usize>,
    pub data: Payload,
}

impl Entry {
    pub fn f32(&self) -> Result<&[f32]> {
        match &self.data {
            Payload::F32(v) => Ok(v),
            _ => bail!("entry is not f32"),
        }
    }

    pub fn i32(&self) -> Result<&[i32]> {
        match &self.data {
            Payload::I32(v) => Ok(v),
            _ => bail!("entry is not i32"),
        }
    }
}

#[derive(Debug, Default)]
pub struct Bundle {
    pub entries: BTreeMap<String, Entry>,
}

impl Bundle {
    pub fn load(path: &Path) -> Result<Bundle> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)?;
        let mut raw = Vec::new();
        f.read_to_end(&mut raw)?;

        let mut entries = BTreeMap::new();
        for e in header.get("entries")?.arr()? {
            let name = e.get("name")?.str()?.to_string();
            let dims = e.get("dims")?.dims()?;
            let dtype = e.get("dtype")?.str()?;
            let off = e.get("offset_elems")?.usize()? * 4;
            let count = e.get("count")?.usize()?;
            let bytes = raw
                .get(off..off + count * 4)
                .with_context(|| format!("bundle entry '{name}' out of range"))?;
            let data = match dtype {
                "f32" => Payload::F32(
                    bytes.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                "i32" => Payload::I32(
                    bytes.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                d => bail!("unknown dtype '{d}'"),
            };
            entries.insert(name, Entry { dims, data });
        }
        Ok(Bundle { entries })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut specs = Vec::new();
        let mut blob: Vec<u8> = Vec::new();
        let mut offset = 0usize;
        for (name, e) in &self.entries {
            let (dtype, count) = match &e.data {
                Payload::F32(v) => {
                    for x in v {
                        blob.extend_from_slice(&x.to_le_bytes());
                    }
                    ("f32", v.len())
                }
                Payload::I32(v) => {
                    for x in v {
                        blob.extend_from_slice(&x.to_le_bytes());
                    }
                    ("i32", v.len())
                }
            };
            specs.push(crate::util::json::obj([
                ("name", name.as_str().into()),
                ("dims", e.dims.iter().copied().collect()),
                ("dtype", dtype.into()),
                ("offset_elems", offset.into()),
                ("count", count.into()),
            ]));
            offset += count;
        }
        let header = crate::util::json::obj([("entries", Json::Arr(specs))]).to_string();
        let mut f = std::fs::File::create(path)?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        f.write_all(&blob)?;
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .with_context(|| format!("bundle missing entry '{name}'"))
    }

    /// All entries whose name starts with `prefix` (sorted by name).
    pub fn with_prefix(&self, prefix: &str) -> Vec<(&str, &Entry)> {
        self.entries
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.as_str(), v))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("flextp_bin_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let mut b = Bundle::default();
        b.entries.insert(
            "x".into(),
            Entry { dims: vec![2, 3], data: Payload::F32(vec![1.0, 2.0, 3.0, -4.0, 0.5, 6.0]) },
        );
        b.entries.insert(
            "labels".into(),
            Entry { dims: vec![4], data: Payload::I32(vec![0, 3, 2, 9]) },
        );
        b.save(&path).unwrap();
        let r = Bundle::load(&path).unwrap();
        assert_eq!(r.get("x").unwrap().f32().unwrap(), &[1.0, 2.0, 3.0, -4.0, 0.5, 6.0]);
        assert_eq!(r.get("labels").unwrap().i32().unwrap(), &[0, 3, 2, 9]);
        assert_eq!(r.get("x").unwrap().dims, vec![2, 3]);
    }

    #[test]
    fn prefix_query() {
        let mut b = Bundle::default();
        for name in ["params.0.a", "params.0.b", "params.1.a", "batch.x"] {
            b.entries.insert(
                name.into(),
                Entry { dims: vec![1], data: Payload::F32(vec![0.0]) },
            );
        }
        assert_eq!(b.with_prefix("params.0.").len(), 2);
        assert_eq!(b.with_prefix("params.").len(), 3);
        assert_eq!(b.with_prefix("nope").len(), 0);
    }
}
