//! Plain-text table rendering + CSV writers for the paper-figure benches.
//!
//! Every bench prints the same rows/series the paper reports (criterion is
//! unavailable offline; the bench harness in `crate::bench` uses these).

use std::fmt::Write as _;
use std::path::Path;

/// A simple left-aligned text table with a title, for bench stdout.
pub struct TextTable {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(title: &str, header: &[&str]) -> Self {
        TextTable {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "| {:w$} ", c, w = widths[i]);
            }
            let _ = writeln!(out, "|");
        };
        line(&mut out, &self.header);
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Also dump as CSV (for plotting the figure series).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(s, "{}", self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        std::fs::write(path, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new("T", &["policy", "rt"]);
        t.row(&["broadcast-reduce", "373"]);
        t.row(&["sg", "963"]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("broadcast-reduce"));
        // all data lines same length
        let lines: Vec<&str> = r.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    fn csv_escaping() {
        let mut t = TextTable::new("T", &["a", "b"]);
        t.row(&["x,y", "z\"q\""]);
        let dir = std::env::temp_dir().join("flextp_table_test");
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("\"x,y\""));
        assert!(s.contains("\"z\"\"q\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = TextTable::new("T", &["a", "b"]);
        t.row(&["only-one"]);
    }
}
