//! Online adaptive rebalancing controller (DESIGN.md §12).
//!
//! The paper plans from the straggler monitor's T_i/M_i statistics every
//! iteration; a *static* per-epoch plan (`--replan epoch`) goes stale the
//! moment a tenant arrives mid-epoch.  `--replan online` keeps the plan
//! cached but watches the per-rank iteration runtimes through a
//! **fast/slow EWMA drift detector**: when the fast average diverges from
//! the slow baseline by more than the `hi` threshold on any rank, the
//! trainer re-runs the pretest cost fits and the Eq. (2)/(3) allocation
//! mid-epoch (charging the replan overhead to the SimClock).
//!
//! Two guards keep the controller from thrashing:
//!
//! * **hysteresis** — after a trigger the detector disarms until the
//!   divergence falls back below `lo` (the slow baseline is resynced to
//!   the fast average on trigger, so a sustained level shift reads as
//!   "settled", not as a permanent alarm);
//! * **cooldown** — at least `cooldown` iterations pass between triggers,
//!   giving a fresh plan time to show up in the measurements it will be
//!   judged by.
//!
//! The detector is pure arithmetic over coordinator-side signals: under
//! `--time-model modeled` its decisions are bitwise reproducible at any
//! `--threads` count (pinned by `tests/parallel_determinism.rs`).

/// Drift-detector parameters (`--ctl-*` CLI overrides).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControlCfg {
    /// fast EWMA smoothing factor (reacts within ~2 iterations)
    pub alpha_fast: f64,
    /// slow EWMA smoothing factor (the drift baseline)
    pub alpha_slow: f64,
    /// trigger threshold: max-rank relative |fast − slow| / slow
    pub hi: f64,
    /// re-arm threshold (hysteresis band lower edge)
    pub lo: f64,
    /// minimum iterations between triggers
    pub cooldown: usize,
}

impl Default for ControlCfg {
    fn default() -> Self {
        ControlCfg { alpha_fast: 0.5, alpha_slow: 0.1, hi: 0.3, lo: 0.1, cooldown: 2 }
    }
}

/// One observation's verdict.
#[derive(Debug, Clone, Copy)]
pub struct Drift {
    /// max-rank relative fast/slow divergence
    pub score: f64,
    /// replan now?
    pub triggered: bool,
}

/// Fast/slow EWMA drift detector with hysteresis + cooldown.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    pub cfg: ControlCfg,
    pub(crate) fast: Vec<f64>,
    pub(crate) slow: Vec<f64>,
    pub(crate) armed: bool,
    pub(crate) cooldown_left: usize,
    /// total triggers fired (metrics)
    pub triggers: u64,
}

impl DriftDetector {
    pub fn new(cfg: ControlCfg) -> DriftDetector {
        DriftDetector {
            cfg,
            fast: Vec::new(),
            slow: Vec::new(),
            armed: true,
            cooldown_left: 0,
            triggers: 0,
        }
    }

    /// Feed one iteration's per-rank runtimes T_i; returns the drift
    /// score and whether a replan should fire.  The first observation
    /// (or a rank-count change) seeds both EWMAs and never triggers.
    pub fn observe(&mut self, t: &[f64]) -> Drift {
        if self.fast.len() != t.len() {
            self.fast = t.to_vec();
            self.slow = t.to_vec();
            return Drift { score: 0.0, triggered: false };
        }
        let mut score = 0.0f64;
        for r in 0..t.len() {
            self.fast[r] += self.cfg.alpha_fast * (t[r] - self.fast[r]);
            self.slow[r] += self.cfg.alpha_slow * (t[r] - self.slow[r]);
            let d = (self.fast[r] - self.slow[r]).abs() / self.slow[r].abs().max(1e-12);
            score = score.max(d);
        }
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return Drift { score, triggered: false };
        }
        if !self.armed {
            if score < self.cfg.lo {
                self.armed = true;
            }
            return Drift { score, triggered: false };
        }
        if score > self.cfg.hi {
            self.armed = false;
            self.cooldown_left = self.cfg.cooldown;
            self.slow.copy_from_slice(&self.fast);
            self.triggers += 1;
            return Drift { score, triggered: true };
        }
        Drift { score, triggered: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det() -> DriftDetector {
        DriftDetector::new(ControlCfg::default())
    }

    #[test]
    fn steady_signal_never_triggers() {
        let mut d = det();
        for _ in 0..50 {
            assert!(!d.observe(&[1.0, 1.0, 1.0]).triggered);
        }
        assert_eq!(d.triggers, 0);
    }

    #[test]
    fn level_shift_triggers_once_then_settles() {
        let mut d = det();
        for _ in 0..10 {
            d.observe(&[1.0, 1.0]);
        }
        // rank 1 suddenly 6× slower (a tenant arrived)
        let mut fired = 0;
        for _ in 0..20 {
            if d.observe(&[1.0, 6.0]).triggered {
                fired += 1;
            }
        }
        assert!(fired >= 1, "shift must be detected");
        assert!(fired <= 3, "hysteresis+cooldown must stop the thrash, fired {fired}");
        // settled at the new level: no more triggers
        let before = d.triggers;
        for _ in 0..20 {
            d.observe(&[1.0, 6.0]);
        }
        assert_eq!(d.triggers, before);
    }

    #[test]
    fn detection_is_fast() {
        let mut d = det();
        for _ in 0..8 {
            d.observe(&[1.0, 1.0]);
        }
        // the jump is seen within two observations at default α_fast
        let first = d.observe(&[1.0, 5.0]);
        let second = d.observe(&[1.0, 5.0]);
        assert!(first.triggered || second.triggered, "jump not caught in 2 iters");
    }

    #[test]
    fn cooldown_blocks_back_to_back_triggers() {
        let mut d = DriftDetector::new(ControlCfg { cooldown: 3, ..Default::default() });
        for _ in 0..8 {
            d.observe(&[1.0]);
        }
        // oscillating signal: without cooldown this would fire every step
        let mut gaps = Vec::new();
        let mut last: Option<usize> = None;
        for i in 0..30 {
            let v = if i % 2 == 0 { 5.0 } else { 0.2 };
            if d.observe(&[v]).triggered {
                if let Some(l) = last {
                    gaps.push(i - l);
                }
                last = Some(i);
            }
        }
        assert!(gaps.iter().all(|&g| g > 3), "trigger inside cooldown: {gaps:?}");
    }

    #[test]
    fn first_observation_seeds_without_trigger() {
        let mut d = det();
        assert!(!d.observe(&[9.0, 1.0]).triggered, "init must not trigger");
        // rank-count change re-seeds
        assert!(!d.observe(&[9.0, 1.0, 1.0]).triggered);
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut d = det();
            let mut out = Vec::new();
            for i in 0..40 {
                let t = [1.0, if (10..20).contains(&i) { 4.0 } else { 1.0 }];
                let v = d.observe(&t);
                out.push((v.score, v.triggered));
            }
            out
        };
        assert_eq!(run(), run());
    }
}
