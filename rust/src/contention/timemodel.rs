//! Deterministic modeled compute charges (`--time-model modeled`).
//!
//! By default the SimClock is charged with *measured* backend seconds,
//! which makes adaptive strategies (whose plans feed on T_i/M_i) vary
//! run to run.  The modeled clock replaces every compute charge with a
//! pure function of the executable's shapes — FLOPs at a fixed modeled
//! device rate — so a scenario run is a closed deterministic system:
//! trace → charges → monitor → controller → plan → charges.  That is
//! what lets `tests/parallel_determinism.rs` pin *dynamic* scenarios
//! (mid-epoch replans included) bitwise at `--threads 1` vs `N`, and
//! what makes `flextp sweep` cells reproducible and comparable.
//!
//! The constants are calibration, not measurement: only *relative*
//! magnitudes matter (compute vs the α-β network model), chosen so a
//! vit-tiny iteration lands in the paper's compute-dominated regime.
//! Real math still executes — losses are real; only the clock is
//! modeled.

use crate::runtime::manifest::ModelInfo;

/// Modeled device GEMM throughput (FLOP/s).
pub const GEMM_FLOPS_PER_S: f64 = 50e9;
/// Modeled memory-copy bandwidth (Ω₂ extraction fits).
pub const MEM_BYTES_PER_S: f64 = 4e9;
/// Modeled allocation bandwidth (Ω₁ submatrix-setup fits).
pub const ALLOC_BYTES_PER_S: f64 = 2e9;

fn secs(flops: f64) -> f64 {
    flops / GEMM_FLOPS_PER_S
}

/// One rank's attention branch with `keep_hs` kept contraction columns:
/// QKV projection + attention core + output projection. `bwd` ≈ 2× fwd.
pub fn attn_s(m: &ModelInfo, keep_hs: usize, bwd: bool) -> f64 {
    let rows = (m.bs * m.seq) as f64;
    let qkv = 2.0 * rows * keep_hs as f64 * (3 * m.hsl) as f64;
    let core = 4.0 * m.bs as f64 * (m.seq * m.seq) as f64 * m.hsl as f64;
    let oproj = 2.0 * rows * (m.hsl * m.hs) as f64;
    let f = qkv + core + oproj;
    secs(if bwd { 2.0 * f } else { f })
}

/// One rank's MLP branch with `keep1` kept hs-contraction columns and
/// `keep2` kept ffl columns. `bwd` ≈ 2× fwd.
pub fn mlp_s(m: &ModelInfo, keep1: usize, keep2: usize, bwd: bool) -> f64 {
    let rows = (m.bs * m.seq) as f64;
    let fc1 = 2.0 * rows * (keep1 * keep2) as f64;
    let fc2 = 2.0 * rows * (keep2 * m.hs) as f64;
    let f = fc1 + fc2;
    secs(if bwd { 2.0 * f } else { f })
}

/// A migration receiver slice padded to `kb` columns (w1 cols + w2 rows).
pub fn mig_slice_s(m: &ModelInfo, kb: usize, bwd: bool) -> f64 {
    let rows = (m.bs * m.seq) as f64;
    let f = 4.0 * rows * (m.hs * kb) as f64;
    secs(if bwd { 2.0 * f } else { f })
}

/// Replicated patch embedding (per rank). `bwd` ≈ 2× fwd.
pub fn embed_s(m: &ModelInfo, bwd: bool) -> f64 {
    let f = 2.0 * (m.bs * m.seq0 * m.pd * m.hs) as f64;
    secs(if bwd { 2.0 * f } else { f })
}

/// Replicated head fwd+bwd single call (layernorm + classifier + loss).
pub fn head_s(m: &ModelInfo) -> f64 {
    secs(3.0 * 2.0 * (m.bs * m.hs * m.classes) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelInfo {
        ModelInfo {
            name: "t".into(),
            hs: 128,
            depth: 2,
            heads: 4,
            e: 4,
            bs: 8,
            classes: 10,
            seq: 65,
            seq0: 64,
            pd: 48,
            hsl: 32,
            hl: 1,
            hd: 32,
            ffl: 128,
            params_total: 0,
            params_per_worker: 0,
            degrees: crate::runtime::manifest::Degrees::uniform(4),
        }
    }

    #[test]
    fn monotone_in_keep_sizes() {
        let m = model();
        assert!(attn_s(&m, 64, false) < attn_s(&m, 128, false));
        assert!(mlp_s(&m, 128, 64, false) < mlp_s(&m, 128, 128, false));
        assert!(mlp_s(&m, 64, 128, false) < mlp_s(&m, 128, 128, false));
        assert!(mig_slice_s(&m, 16, false) < mig_slice_s(&m, 64, false));
    }

    #[test]
    fn bwd_is_double_fwd() {
        let m = model();
        assert_eq!(attn_s(&m, 128, true), 2.0 * attn_s(&m, 128, false));
        assert_eq!(mlp_s(&m, 128, 128, true), 2.0 * mlp_s(&m, 128, 128, false));
        assert_eq!(mig_slice_s(&m, 32, true), 2.0 * mig_slice_s(&m, 32, false));
        assert_eq!(embed_s(&m, true), 2.0 * embed_s(&m, false));
    }

    #[test]
    fn vit_tiny_iteration_is_millisecond_scale() {
        // sanity: one rank's fwd+bwd across both blocks sits in the
        // compute-dominated regime vs the α-β net defaults (~µs/collective)
        let m = model();
        let per_block = attn_s(&m, m.hs, false)
            + attn_s(&m, m.hs, true)
            + mlp_s(&m, m.hs, m.ffl, false)
            + mlp_s(&m, m.hs, m.ffl, true);
        let iter = per_block * m.depth as f64;
        assert!(iter > 1e-3, "iter={iter}s too cheap");
        assert!(iter < 1.0, "iter={iter}s too dear");
    }

    #[test]
    fn pure_function_of_shapes() {
        let m = model();
        assert_eq!(mlp_s(&m, 96, 112, true), mlp_s(&m, 96, 112, true));
    }
}
