//! Trace-driven multi-tenant contention engine (DESIGN.md §12).
//!
//! The paper's premise is that *dynamic* resource contention — other
//! tenants time-sharing a GPU, arriving and departing mid-job — is what
//! creates stragglers, yet a fixed per-epoch χ vector can only express
//! static skew.  This module produces per-rank skewness at **iteration**
//! granularity from seeded, deterministic scenario specs:
//!
//! * scripted events — [`Event::Burst`] (a tenant active over an
//!   iteration window; `tenant:` is the arrive/depart-flavored alias),
//!   [`Event::Ramp`] (contention climbing linearly to χ across a
//!   window), [`Event::Step`] (a tenant arrives and stays), and
//!   [`Event::Pulse`] (periodic duty-cycle bursts);
//! * stochastic tenants — [`Event::Markov`], a two-state
//!   Markov-modulated on/off process advanced once per iteration from a
//!   per-(event, rank) seeded RNG;
//! * built-in presets ([`preset`]) and a small DSL
//!   (`burst:r2@x4:iters10-40,markov:r*@x3:p0.2-0.4,seed:7`) shared by
//!   `--scenario`, `--scenario-file`, and the `sweep` subcommand;
//! * worker churn — [`ChurnEvent`] (`join:rN@iterK`, `leave:rN@iterK`,
//!   `fail:rN@iterK`): unlike χ events these change the *size* of the
//!   worker group; the trainer re-shards in-process onto the largest
//!   `E'` the live worker count supports (DESIGN.md §14);
//! * memory pressure — [`MemEvent`] (`memsqueeze:rN@iterK:xF`: a
//!   co-tenant steals fraction F of rank N's memory capacity;
//!   `oom:rN@iterK`: forced hard OOM).  Like churn these are
//!   orchestration-level — they drive the per-rank memory ledger
//!   (DESIGN.md §16), never the χ rows.
//!
//! Concurrent tenants compose **multiplicatively** (time-slicing a
//! device between n tenants multiplies service time), clamped to
//! [`ScenarioSpec::chi_max`]; χ never drops below 1.  Traces are
//! realized by [`ContentionTrace::generate`]: same spec + same seed ⇒
//! bitwise the same trace, and a longer trace is always a prefix
//! extension of a shorter one, so replaying any prefix matches the full
//! run.  The trainer realizes the trace once on the **coordinator**
//! (workers never observe or advance trace state), preserving the
//! 1-vs-N thread determinism contract of `tests/parallel_determinism.rs`.

pub mod control;
pub mod timemodel;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::StragglerPlan;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Which rank(s) a tenant lands on. `r*` gives every rank an
/// *independent* tenant (independent Markov chains, shared windows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankSel {
    One(usize),
    All,
}

impl RankSel {
    pub fn hits(&self, r: usize) -> bool {
        match self {
            RankSel::One(x) => *x == r,
            RankSel::All => true,
        }
    }

    fn parse(s: &str) -> Result<RankSel> {
        let s = s.strip_prefix('r').unwrap_or(s);
        if s == "*" {
            return Ok(RankSel::All);
        }
        Ok(RankSel::One(s.parse().with_context(|| format!("bad rank '{s}'"))?))
    }

    fn name(&self) -> String {
        match self {
            RankSel::One(r) => format!("r{r}"),
            RankSel::All => "r*".to_string(),
        }
    }
}

/// One contention source. Iteration windows are **global** iteration
/// indices (`epoch · iters_per_epoch + iter`), half-open `[from, to)`.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Scripted tenant active during `[from, to)` at multiplier `chi`.
    Burst { rank: RankSel, chi: f64, from: usize, to: usize },
    /// χ climbs linearly 1 → `chi` across `[from, to)`, gone after.
    Ramp { rank: RankSel, chi: f64, from: usize, to: usize },
    /// Tenant arrives at `from` and never departs.
    Step { rank: RankSel, chi: f64, from: usize },
    /// Periodic burst: from `from` on, active for the first `on`
    /// iterations of every `period`.
    Pulse { rank: RankSel, chi: f64, from: usize, period: usize, on: usize },
    /// Markov-modulated on/off tenant: each iteration an *off* tenant
    /// turns on with probability `p_on`, an *on* tenant departs with
    /// probability `p_off`. Starts off.
    Markov { rank: RankSel, chi: f64, p_on: f64, p_off: f64 },
}

/// A scripted worker join/leave/failure (DESIGN.md §14).  `rank` is a
/// label for the affected worker (a join may reuse a departed label);
/// only the *count* of live workers feeds the choice of the next
/// sharding degree, so traces stay well-defined across re-realizations.
/// `at` is a global iteration: the event fires **before** iteration
/// `at` runs — exactly the cut a kill-at-`at` checkpoint makes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChurnEvent {
    pub kind: ChurnKind,
    pub rank: usize,
    pub at: usize,
}

/// `Leave` (graceful departure) and `Fail` (crash) are distinguished in
/// the DSL for reporting, but both shrink the live worker count by one;
/// `Join` grows it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnKind {
    Join,
    Leave,
    Fail,
}

impl ChurnKind {
    pub fn name(&self) -> &'static str {
        match self {
            ChurnKind::Join => "join",
            ChurnKind::Leave => "leave",
            ChurnKind::Fail => "fail",
        }
    }
}

/// A scripted memory event (DESIGN.md §16).  Like [`ChurnEvent`], `at`
/// is a global iteration and the event fires **before** iteration `at`
/// runs — the same cut a kill-at-`at` checkpoint makes, which is what
/// keeps hard-OOM eviction bitwise-equal to the resume oracle.
#[derive(Debug, Clone, PartialEq)]
pub struct MemEvent {
    pub kind: MemKind,
    pub rank: usize,
    pub at: usize,
}

/// `Squeeze` shrinks the rank's effective capacity (the latest squeeze
/// per rank wins; `frac: 0` restores it); `Oom` forces a hard
/// out-of-memory fault that evicts the rank through the churn path.
#[derive(Debug, Clone, PartialEq)]
pub enum MemKind {
    Squeeze { frac: f64 },
    Oom,
}

/// Typed scenario errors.  Parsing and validation surface these through
/// `anyhow`, so callers (and tests) can `downcast_ref::<ScenarioError>()`
/// instead of string-matching, while the CLI keeps the readable message.
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioError {
    /// An event kind the DSL does not know (`meteor:...`).
    UnknownEventKind(String),
    /// A clause naming a known kind that cannot be parsed.
    Malformed { item: String, reason: String },
    /// A χ event targets a rank outside a *static* worker group.
    RankOutOfRange { rank: usize, e: usize },
    /// Worker churn left no live workers to re-shard onto.  Raised both
    /// by scripted `fail:` events and by *real* rank-process death under
    /// `--transport tcp` (a `TransportError::PeerDied` flows into the
    /// same recovery path — tests/transport_faults.rs).
    NoViableWorkerCount { avail: usize, hs: usize, heads: usize },
}

impl std::fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScenarioError::UnknownEventKind(k) => write!(
                f,
                "unknown event kind '{k}' \
                 (burst|tenant|ramp|step|pulse|markov|join|leave|fail|memsqueeze|oom)"
            ),
            ScenarioError::Malformed { item, reason } => write!(f, "'{item}': {reason}"),
            ScenarioError::RankOutOfRange { rank, e } => write!(
                f,
                "scenario targets rank {rank} but the model has only {e} \
                 workers (r0..r{})",
                e - 1
            ),
            ScenarioError::NoViableWorkerCount { avail, hs, heads } => write!(
                f,
                "worker churn left {avail} live worker(s) — no E' ≥ 1 can \
                 shard hs={hs}/heads={heads}"
            ),
        }
    }
}

impl std::error::Error for ScenarioError {}

fn chk_chi(chi: f64) -> Result<f64> {
    if !chi.is_finite() || chi < 1.0 {
        bail!("tenant χ must be ≥ 1 (a tenant can only slow a rank down), got {chi}");
    }
    Ok(chi)
}

fn chk_window(from: usize, to: usize) -> Result<()> {
    if from >= to {
        bail!("empty iteration window iters{from}-{to}");
    }
    Ok(())
}

fn chk_prob(p: f64, what: &str) -> Result<f64> {
    if !(0.0..=1.0).contains(&p) {
        bail!("{what} must be a probability in [0,1], got {p}");
    }
    Ok(p)
}

/// A parsed contention scenario: pure data, `Clone + PartialEq`, held by
/// [`StragglerPlan::Scenario`]. The realized per-iteration χ matrix is a
/// [`ContentionTrace`].
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Trace seed (DSL `seed:N`). All stochastic tenants replay
    /// identically for the same seed, independent of `--seed` (which
    /// keeps controlling weights/data).
    pub seed: u64,
    /// Clamp on the composed per-rank multiplier.
    pub chi_max: f64,
    pub events: Vec<Event>,
    /// Simulated preemption (DSL `preempt:iterN`): kill the job after
    /// global iteration N completes and resume it from a checkpoint.
    /// Orchestration-only — the χ trace itself ignores it; the `flextp
    /// sweep` harness executes the kill/checkpoint/resume cycle.
    pub preempt: Option<usize>,
    /// Worker churn schedule (DSL `join:rN@iterK` etc.).  Like
    /// `preempt`, churn is orchestration-level: it never perturbs the χ
    /// rows — the trainer re-realizes the trace whenever the worker
    /// count changes.
    pub churn: Vec<ChurnEvent>,
    /// Memory-pressure schedule (DSL `memsqueeze:rN@iterK:xF`,
    /// `oom:rN@iterK`).  Orchestration-level like `churn`: drives the
    /// per-rank memory ledger, never the χ rows (DESIGN.md §16).
    pub mem: Vec<MemEvent>,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            seed: 42,
            chi_max: 16.0,
            events: Vec::new(),
            preempt: None,
            churn: Vec::new(),
            mem: Vec::new(),
        }
    }
}

impl ScenarioSpec {
    /// Parse the comma-separated scenario DSL. Grammar (DESIGN.md §12):
    ///
    /// ```text
    /// spec   := item (',' item)*
    /// item   := event | "seed:"N | "chimax:"X | "preset:"NAME
    /// event  := "burst:rR@xC:itersA-B"      scripted tenant over [A,B)
    ///         | "tenant:rR@xC:itersA-B"     alias of burst (arrive A, depart B)
    ///         | "ramp:rR@xC:itersA-B"       χ ramps 1→C across [A,B)
    ///         | "step:rR@xC:itersA-"        tenant arrives at A, stays
    ///         | "pulse:rR@xC:fromA:periodP:onD"  duty-cycle bursts
    ///         | "markov:rR@xC:pON-POFF"     stochastic on/off tenant
    ///         | "join:rN@iterK"             worker N joins before iteration K
    ///         | "leave:rN@iterK"            worker N departs before iteration K
    ///         | "fail:rN@iterK"             worker N crashes before iteration K
    ///         | "memsqueeze:rN@iterK:xF"    tenant steals capacity fraction F
    ///         | "oom:rN@iterK"              forced hard OOM on worker N
    /// R      := rank index | "*" (every rank, independent tenants)
    /// ```
    ///
    /// The empty string parses to the calm (no-contention) scenario.
    pub fn parse(src: &str) -> Result<ScenarioSpec> {
        let mut spec = ScenarioSpec::default();
        for raw in src.split(',') {
            let item = raw.trim();
            if item.is_empty() {
                continue;
            }
            if let Some(v) = item.strip_prefix("seed:") {
                spec.seed = v.parse().with_context(|| format!("bad seed '{v}'"))?;
                continue;
            }
            if let Some(v) = item.strip_prefix("chimax:") {
                let c: f64 = v.parse().with_context(|| format!("bad chimax '{v}'"))?;
                spec.chi_max = chk_chi(c)?;
                continue;
            }
            if let Some(name) = item.strip_prefix("preset:") {
                spec.events.extend(preset(name)?.events);
                continue;
            }
            if let Some(v) = item.strip_prefix("preempt:") {
                let v = v.strip_prefix("iter").unwrap_or(v);
                let g: usize = v.parse().with_context(|| format!("bad preempt '{v}'"))?;
                if g == 0 {
                    bail!("preempt:iter0 would kill the job before any work");
                }
                spec.preempt = Some(g);
                continue;
            }
            if let Some(ev) = parse_churn(item)? {
                spec.churn.push(ev);
                continue;
            }
            if let Some(ev) = parse_mem(item)? {
                spec.mem.push(ev);
                continue;
            }
            spec.events.push(parse_event(item)?);
        }
        Ok(spec)
    }

    /// Build from JSON: either a DSL string, or an object
    /// `{"seed": 7, "chi_max": 16, "events": [{"kind": "burst",
    /// "rank": 2, "chi": 4, "from": 10, "to": 40}, ...]}` (rank may be
    /// `"*"`; `to` omitted means open-ended).
    pub fn from_json(j: &Json) -> Result<ScenarioSpec> {
        if let Json::Str(s) = j {
            return ScenarioSpec::parse(s);
        }
        if let Json::Obj(m) = j {
            for k in m.keys() {
                if !matches!(
                    k.as_str(),
                    "seed" | "chi_max" | "events" | "preempt" | "churn" | "mem"
                ) {
                    bail!("unknown scenario field '{k}' (seed|chi_max|events|preempt|churn|mem)");
                }
            }
        }
        let mut spec = ScenarioSpec::default();
        if let Some(s) = j.opt("seed") {
            spec.seed = s.num()? as u64;
        }
        if let Some(c) = j.opt("chi_max") {
            spec.chi_max = chk_chi(c.num()?)?;
        }
        if let Some(p) = j.opt("preempt") {
            let g = p.usize()?;
            if g == 0 {
                bail!("preempt: 0 would kill the job before any work");
            }
            spec.preempt = Some(g);
        }
        for ev in j.get("events")?.arr()? {
            spec.events.push(event_from_json(ev)?);
        }
        if let Some(c) = j.opt("churn") {
            for ev in c.arr()? {
                spec.churn.push(churn_from_json(ev)?);
            }
        }
        if let Some(c) = j.opt("mem") {
            for ev in c.arr()? {
                spec.mem.push(mem_from_json(ev)?);
            }
        }
        Ok(spec)
    }

    /// Load a scenario from disk: JSON when the file starts with `{` or
    /// `"`, the DSL otherwise.
    pub fn from_file(path: &Path) -> Result<ScenarioSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario file {}", path.display()))?;
        let t = text.trim();
        if t.starts_with('{') || t.starts_with('"') {
            ScenarioSpec::from_json(&Json::parse(t)?)
        } else {
            ScenarioSpec::parse(t)
        }
    }

    /// Every rank index a scripted/stochastic event targets must exist in
    /// the worker group, else the event would silently never fire and the
    /// run would measure a scenario that never happened.  Called by the
    /// trainer (and the sweep harness) once the model's `e` is known.
    ///
    /// Under worker churn the live rank set is dynamic: a χ event may
    /// legitimately name a rank that exists only at the larger `E` (it is
    /// inert while the group is smaller), so the static range check is
    /// skipped — trace realization at any `E'` simply never applies
    /// events whose rank is absent.  A scripted `oom:` event evicts a
    /// rank and makes the group dynamic too, so it suspends the check
    /// the same way.
    pub fn validate_ranks(&self, e: usize) -> Result<()> {
        if !self.churn.is_empty() || self.mem.iter().any(|m| m.kind == MemKind::Oom) {
            return Ok(());
        }
        for m in &self.mem {
            if m.rank >= e {
                return Err(anyhow::Error::from(ScenarioError::RankOutOfRange {
                    rank: m.rank,
                    e,
                })
                .context(format!("in scenario '{}'", self.describe())));
            }
        }
        for ev in &self.events {
            let rank = match ev {
                Event::Burst { rank, .. }
                | Event::Ramp { rank, .. }
                | Event::Step { rank, .. }
                | Event::Pulse { rank, .. }
                | Event::Markov { rank, .. } => rank,
            };
            if let RankSel::One(r) = rank {
                if *r >= e {
                    return Err(anyhow::Error::from(ScenarioError::RankOutOfRange {
                        rank: *r,
                        e,
                    })
                    .context(format!("in scenario '{}'", self.describe())));
                }
            }
        }
        Ok(())
    }

    /// The churn schedule in firing order (stable sort on `at`, so
    /// same-iteration events coalesce in spec order).
    pub fn churn_sorted(&self) -> Vec<ChurnEvent> {
        let mut v = self.churn.clone();
        v.sort_by_key(|c| c.at);
        v
    }

    /// The memory-event schedule in firing order (stable on `at`, like
    /// [`Self::churn_sorted`]).
    pub fn mem_sorted(&self) -> Vec<MemEvent> {
        let mut v = self.mem.clone();
        v.sort_by_key(|m| m.at);
        v
    }

    /// Compact one-line rendering (labels, sweep tables).  Includes
    /// `seed:`/`chimax:` when they differ from the defaults, so the
    /// rendered string re-parses to an equivalent spec (stochastic
    /// tenants and clamping reproduce).
    pub fn describe(&self) -> String {
        if self.events.is_empty()
            && self.preempt.is_none()
            && self.churn.is_empty()
            && self.mem.is_empty()
        {
            // a calm trace is seed/chimax-independent, so those stay
            // implicit too
            return "calm".to_string();
        }
        let mut items: Vec<String> = self
            .events
            .iter()
            .map(|e| match e {
                Event::Burst { rank, chi, from, to } => {
                    if *to == usize::MAX {
                        format!("burst:{}@x{chi}:iters{from}-", rank.name())
                    } else {
                        format!("burst:{}@x{chi}:iters{from}-{to}", rank.name())
                    }
                }
                Event::Ramp { rank, chi, from, to } => {
                    format!("ramp:{}@x{chi}:iters{from}-{to}", rank.name())
                }
                Event::Step { rank, chi, from } => {
                    format!("step:{}@x{chi}:iters{from}-", rank.name())
                }
                Event::Pulse { rank, chi, from, period, on } => {
                    format!("pulse:{}@x{chi}:from{from}:period{period}:on{on}", rank.name())
                }
                Event::Markov { rank, chi, p_on, p_off } => {
                    format!("markov:{}@x{chi}:p{p_on}-{p_off}", rank.name())
                }
            })
            .collect();
        for c in &self.churn {
            items.push(format!("{}:r{}@iter{}", c.kind.name(), c.rank, c.at));
        }
        for m in &self.mem {
            items.push(match &m.kind {
                MemKind::Squeeze { frac } => {
                    format!("memsqueeze:r{}@iter{}:x{frac}", m.rank, m.at)
                }
                MemKind::Oom => format!("oom:r{}@iter{}", m.rank, m.at),
            });
        }
        let defaults = ScenarioSpec::default();
        if self.seed != defaults.seed {
            items.push(format!("seed:{}", self.seed));
        }
        if self.chi_max != defaults.chi_max {
            items.push(format!("chimax:{}", self.chi_max));
        }
        if let Some(g) = self.preempt {
            items.push(format!("preempt:iter{g}"));
        }
        items.join(",")
    }
}

/// Parse `"r2@x4"` → (rank selector, χ).
fn parse_target(s: &str) -> Result<(RankSel, f64)> {
    let (r, c) = s
        .split_once('@')
        .with_context(|| format!("expected rR@xC, got '{s}'"))?;
    let rank = RankSel::parse(r)?;
    let c = c.strip_prefix('x').unwrap_or(c);
    let chi = chk_chi(c.parse().with_context(|| format!("bad χ '{c}'"))?)?;
    Ok((rank, chi))
}

/// Parse `"itersA-B"` → (A, Some(B)); `"itersA-"` / `"itersA"` → (A, None).
fn parse_iters(s: &str) -> Result<(usize, Option<usize>)> {
    let s = s
        .strip_prefix("iters")
        .with_context(|| format!("expected itersA-B, got '{s}'"))?;
    let (a, b) = match s.split_once('-') {
        Some((a, "")) => (a, None),
        Some((a, b)) => (a, Some(b)),
        None => (s, None),
    };
    let from = a.parse().with_context(|| format!("bad iteration '{a}'"))?;
    let to = match b {
        Some(b) => Some(b.parse().with_context(|| format!("bad iteration '{b}'"))?),
        None => None,
    };
    Ok((from, to))
}

/// Parse a churn clause `join:rN@iterK` / `leave:rN@iterK` /
/// `fail:rN@iterK`.  Returns `Ok(None)` when `item` is not a churn kind
/// (so the caller falls through to χ-event parsing) and a typed
/// [`ScenarioError::Malformed`] when the kind matches but the body does
/// not.
fn parse_churn(item: &str) -> Result<Option<ChurnEvent>> {
    let Some((kind_s, rest)) = item.split_once(':') else {
        return Ok(None);
    };
    let kind = match kind_s {
        "join" => ChurnKind::Join,
        "leave" => ChurnKind::Leave,
        "fail" => ChurnKind::Fail,
        _ => return Ok(None),
    };
    let mal = |reason: &str| ScenarioError::Malformed {
        item: item.to_string(),
        reason: reason.to_string(),
    };
    let (r, at_s) = rest
        .split_once('@')
        .ok_or_else(|| mal("expected rN@iterK"))?;
    let rank = match RankSel::parse(r).map_err(|_| mal("expected a rank like r3"))? {
        RankSel::One(x) => x,
        RankSel::All => {
            return Err(mal("churn events need a concrete rank; r* is not a worker").into())
        }
    };
    let at_s = at_s
        .strip_prefix("iter")
        .ok_or_else(|| mal("expected @iterK"))?;
    let at: usize = at_s
        .parse()
        .map_err(|_| mal("bad iteration after @iter"))?;
    if at == 0 {
        return Err(mal(
            "churn at iteration 0 would resize before any work — start the run with --e instead",
        )
        .into());
    }
    Ok(Some(ChurnEvent { kind, rank, at }))
}

/// JSON form of a churn clause: `{"kind":"fail","rank":3,"at":12}`.
fn churn_from_json(j: &Json) -> Result<ChurnEvent> {
    if let Json::Obj(m) = j {
        for k in m.keys() {
            if !matches!(k.as_str(), "kind" | "rank" | "at") {
                bail!("churn event does not take a '{k}' field (allowed: kind, rank, at)");
            }
        }
    }
    let kind = match j.get("kind")?.str()? {
        "join" => ChurnKind::Join,
        "leave" => ChurnKind::Leave,
        "fail" => ChurnKind::Fail,
        other => return Err(ScenarioError::UnknownEventKind(other.to_string()).into()),
    };
    let ev = ChurnEvent { kind, rank: j.get("rank")?.usize()?, at: j.get("at")?.usize()? };
    if ev.at == 0 {
        bail!("churn at iteration 0 would resize before any work");
    }
    Ok(ev)
}

/// Parse a memory clause `memsqueeze:rN@iterK:xF` / `oom:rN@iterK`.
/// Returns `Ok(None)` when `item` is not a memory kind (the caller
/// falls through to χ-event parsing) and a typed
/// [`ScenarioError::Malformed`] when the kind matches but the body does
/// not — mirroring [`parse_churn`].
fn parse_mem(item: &str) -> Result<Option<MemEvent>> {
    let Some((kind_s, rest)) = item.split_once(':') else {
        return Ok(None);
    };
    if kind_s != "memsqueeze" && kind_s != "oom" {
        return Ok(None);
    }
    let mal = |reason: &str| ScenarioError::Malformed {
        item: item.to_string(),
        reason: reason.to_string(),
    };
    let mut parts = rest.split(':');
    let target = parts.next().unwrap_or("");
    let (r, at_s) = target
        .split_once('@')
        .ok_or_else(|| mal("expected rN@iterK"))?;
    let rank = match RankSel::parse(r).map_err(|_| mal("expected a rank like r3"))? {
        RankSel::One(x) => x,
        RankSel::All => {
            return Err(mal("memory events need a concrete rank; r* is not a worker").into())
        }
    };
    let at_s = at_s.strip_prefix("iter").ok_or_else(|| mal("expected @iterK"))?;
    let at: usize = at_s.parse().map_err(|_| mal("bad iteration after @iter"))?;
    if at == 0 {
        return Err(mal(
            "memory events at iteration 0 fire before any work — shrink --mem-cap instead",
        )
        .into());
    }
    let kind = match kind_s {
        "memsqueeze" => {
            let f = parts.next().ok_or_else(|| mal("memsqueeze needs a :xF fraction"))?;
            let f = f.strip_prefix('x').ok_or_else(|| mal("expected :xF fraction"))?;
            let frac: f64 = f.parse().map_err(|_| mal("bad squeeze fraction"))?;
            if !(0.0..=1.0).contains(&frac) {
                return Err(mal("squeeze fraction must be in [0,1]").into());
            }
            MemKind::Squeeze { frac }
        }
        _ => MemKind::Oom,
    };
    if let Some(extra) = parts.next() {
        return Err(mal(&format!("trailing field '{extra}'")).into());
    }
    Ok(Some(MemEvent { kind, rank, at }))
}

/// JSON form of a memory clause: `{"kind":"memsqueeze","rank":1,
/// "at":6,"frac":0.5}` / `{"kind":"oom","rank":3,"at":6}`.
fn mem_from_json(j: &Json) -> Result<MemEvent> {
    if let Json::Obj(m) = j {
        for k in m.keys() {
            if !matches!(k.as_str(), "kind" | "rank" | "at" | "frac") {
                bail!("memory event does not take a '{k}' field (allowed: kind, rank, at, frac)");
            }
        }
    }
    let kind = match j.get("kind")?.str()? {
        "memsqueeze" => {
            let frac = j.get("frac")?.num()?;
            if !(0.0..=1.0).contains(&frac) {
                bail!("squeeze fraction must be in [0,1], got {frac}");
            }
            MemKind::Squeeze { frac }
        }
        "oom" => {
            if j.opt("frac").is_some() {
                bail!("oom events do not take a 'frac' field");
            }
            MemKind::Oom
        }
        other => return Err(ScenarioError::UnknownEventKind(other.to_string()).into()),
    };
    let ev = MemEvent { kind, rank: j.get("rank")?.usize()?, at: j.get("at")?.usize()? };
    if ev.at == 0 {
        bail!("memory events at iteration 0 fire before any work");
    }
    Ok(ev)
}

fn parse_event(item: &str) -> Result<Event> {
    let mut parts = item.split(':');
    let kind = parts.next().unwrap_or("");
    let target = parts
        .next()
        .with_context(|| format!("'{item}': missing rR@xC target"))?;
    let (rank, chi) = parse_target(target)?;
    let ev = match kind {
        "burst" | "tenant" => {
            let w = parts.next().with_context(|| format!("'{item}': missing itersA-B"))?;
            let (from, to) = parse_iters(w)?;
            let to = to.unwrap_or(usize::MAX);
            chk_window(from, to)?;
            Event::Burst { rank, chi, from, to }
        }
        "ramp" => {
            let w = parts.next().with_context(|| format!("'{item}': missing itersA-B"))?;
            let (from, to) = parse_iters(w)?;
            let to = to.with_context(|| format!("'{item}': ramp needs a closed itersA-B window"))?;
            chk_window(from, to)?;
            Event::Ramp { rank, chi, from, to }
        }
        "step" => {
            let w = parts.next().with_context(|| format!("'{item}': missing itersA-"))?;
            let (from, _) = parse_iters(w)?;
            Event::Step { rank, chi, from }
        }
        "pulse" => {
            let (mut from, mut period, mut on) = (0usize, None, None);
            for p in parts.by_ref() {
                if let Some(v) = p.strip_prefix("from") {
                    from = v.parse().with_context(|| format!("bad from '{v}'"))?;
                } else if let Some(v) = p.strip_prefix("period") {
                    period = Some(v.parse::<usize>().with_context(|| format!("bad period '{v}'"))?);
                } else if let Some(v) = p.strip_prefix("on") {
                    on = Some(v.parse::<usize>().with_context(|| format!("bad on '{v}'"))?);
                } else {
                    bail!("'{item}': unknown pulse field '{p}'");
                }
            }
            let period = period.with_context(|| format!("'{item}': pulse needs periodP"))?;
            let on = on.with_context(|| format!("'{item}': pulse needs onD"))?;
            if period == 0 || on == 0 || on > period {
                bail!("'{item}': need 0 < on ≤ period");
            }
            Event::Pulse { rank, chi, from, period, on }
        }
        "markov" => {
            let w = parts.next().with_context(|| format!("'{item}': missing pON-POFF"))?;
            let w = w.strip_prefix('p').with_context(|| format!("'{item}': expected pON-POFF"))?;
            let (a, b) = w
                .split_once('-')
                .with_context(|| format!("'{item}': expected pON-POFF"))?;
            let p_on = chk_prob(a.parse().with_context(|| format!("bad p_on '{a}'"))?, "p_on")?;
            let p_off = chk_prob(b.parse().with_context(|| format!("bad p_off '{b}'"))?, "p_off")?;
            Event::Markov { rank, chi, p_on, p_off }
        }
        other => return Err(ScenarioError::UnknownEventKind(other.to_string()).into()),
    };
    if let Some(extra) = parts.next() {
        return Err(ScenarioError::Malformed {
            item: item.to_string(),
            reason: format!("trailing field '{extra}'"),
        }
        .into());
    }
    Ok(ev)
}

/// Reject JSON event fields the kind does not consume — a `"to"` on a
/// `step` (or a typoed `"p_onn"`) would otherwise be dropped silently
/// and the run would simulate a different scenario than the file says.
fn chk_event_keys(j: &Json, kind: &str, allowed: &[&str]) -> Result<()> {
    if let Json::Obj(m) = j {
        for k in m.keys() {
            if k != "kind" && k != "rank" && k != "chi" && !allowed.contains(&k.as_str()) {
                bail!("'{kind}' event does not take a '{k}' field (allowed: {allowed:?})");
            }
        }
    }
    Ok(())
}

fn event_from_json(j: &Json) -> Result<Event> {
    let kind = j.get("kind")?.str()?;
    let rank = {
        let r = j.get("rank")?;
        if let Json::Str(s) = r { RankSel::parse(s)? } else { RankSel::One(r.usize()?) }
    };
    let chi = chk_chi(j.get("chi")?.num()?)?;
    let from = match j.opt("from") {
        Some(v) => v.usize()?,
        None => 0,
    };
    Ok(match kind {
        "burst" | "tenant" => {
            chk_event_keys(j, kind, &["from", "to"])?;
            let to = match j.opt("to") {
                Some(v) => v.usize()?,
                None => usize::MAX,
            };
            chk_window(from, to)?;
            Event::Burst { rank, chi, from, to }
        }
        "ramp" => {
            chk_event_keys(j, kind, &["from", "to"])?;
            let to = j.get("to")?.usize()?;
            chk_window(from, to)?;
            Event::Ramp { rank, chi, from, to }
        }
        "step" => {
            chk_event_keys(j, kind, &["from"])?;
            Event::Step { rank, chi, from }
        }
        "pulse" => {
            chk_event_keys(j, kind, &["from", "period", "on"])?;
            let period = j.get("period")?.usize()?;
            let on = j.get("on")?.usize()?;
            if period == 0 || on == 0 || on > period {
                bail!("pulse needs 0 < on ≤ period");
            }
            Event::Pulse { rank, chi, from, period, on }
        }
        "markov" => {
            chk_event_keys(j, kind, &["p_on", "p_off"])?;
            Event::Markov {
                rank,
                chi,
                p_on: chk_prob(j.get("p_on")?.num()?, "p_on")?,
                p_off: chk_prob(j.get("p_off")?.num()?, "p_off")?,
            }
        }
        other => return Err(ScenarioError::UnknownEventKind(other.to_string()).into()),
    })
}

/// Built-in scenario presets (all expressed in the DSL, so
/// `preset:NAME` composes with further items).
pub fn preset(name: &str) -> Result<ScenarioSpec> {
    let dsl = match name {
        // homogeneous control run
        "calm" => "",
        // one mid-run tenant burst
        "burst1" => "burst:r1@x4:iters8-24",
        // square-wave contention: 6-on / 6-off from iteration 4
        "bursty" => "pulse:r1@x6:from4:period12:on6",
        // a heavy tenant arrives mid-epoch and never leaves
        "step6" => "step:r1@x6:iters4-",
        // arrivals, departures, and a background stochastic tenant
        "tenant-churn" => "step:r2@x3:iters6-,tenant:r0@x2:iters10-30,markov:r3@x2:p0.1-0.3",
        // two independent Markov-modulated tenants
        "markov-duo" => "markov:r1@x4:p0.2-0.5,markov:r2@x3:p0.15-0.4",
        _ => bail!(
            "unknown scenario preset '{name}' \
             (calm|burst1|bursty|step6|tenant-churn|markov-duo)"
        ),
    };
    ScenarioSpec::parse(dsl)
}

/// One Markov tenant chain, realized per (event, rank).
struct Chain {
    rank: usize,
    chi: f64,
    p_on: f64,
    p_off: f64,
    rng: Rng,
    on: bool,
}

/// Decorrelate per-(event, rank) chain seeds (Rng::new splitmixes more).
fn chain_seed(seed: u64, event: usize, rank: usize) -> u64 {
    seed ^ (event as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (rank as u64).wrapping_mul(0xD134_2543_DE82_EF95)
}

/// A realized per-iteration χ matrix: `rows[global_iter][rank]`.
///
/// Generated once, on the coordinator, before training starts; queries
/// past the generated horizon clamp to the last row (a `step` tenant
/// stays, a frozen pulse holds its last state — documented behavior for
/// out-of-range probes, which regular runs never make).
#[derive(Debug, Clone)]
pub struct ContentionTrace {
    e: usize,
    rows: Vec<Vec<f64>>,
}

impl ContentionTrace {
    /// Realize `total_iters` iterations of a scenario for `e` ranks.
    /// Deterministic: same (spec, e, total_iters-prefix) ⇒ same rows.
    pub fn generate(spec: &ScenarioSpec, e: usize, total_iters: usize) -> ContentionTrace {
        let total = total_iters.max(1);
        let mut chains: Vec<Chain> = Vec::new();
        for (i, ev) in spec.events.iter().enumerate() {
            if let Event::Markov { rank, chi, p_on, p_off } = ev {
                for r in 0..e {
                    if rank.hits(r) {
                        chains.push(Chain {
                            rank: r,
                            chi: *chi,
                            p_on: *p_on,
                            p_off: *p_off,
                            rng: Rng::new(chain_seed(spec.seed, i, r)),
                            on: false,
                        });
                    }
                }
            }
        }
        let mut rows = Vec::with_capacity(total);
        for g in 0..total {
            // advance every stochastic chain exactly once per iteration
            // (fixed RNG consumption → prefix-stable traces)
            for c in chains.iter_mut() {
                let u = c.rng.uniform() as f64;
                if c.on {
                    if u < c.p_off {
                        c.on = false;
                    }
                } else if u < c.p_on {
                    c.on = true;
                }
            }
            let mut chi = vec![1.0f64; e];
            for ev in &spec.events {
                match ev {
                    Event::Burst { rank, chi: c, from, to } => {
                        if g >= *from && g < *to {
                            mul(&mut chi, rank, *c);
                        }
                    }
                    Event::Ramp { rank, chi: c, from, to } => {
                        if g >= *from && g < *to {
                            let denom = (to - 1 - from).max(1) as f64;
                            let f = 1.0 + (c - 1.0) * (g - from) as f64 / denom;
                            mul(&mut chi, rank, f);
                        }
                    }
                    Event::Step { rank, chi: c, from } => {
                        if g >= *from {
                            mul(&mut chi, rank, *c);
                        }
                    }
                    Event::Pulse { rank, chi: c, from, period, on } => {
                        if g >= *from && (g - from) % period < *on {
                            mul(&mut chi, rank, *c);
                        }
                    }
                    Event::Markov { .. } => {} // handled via chains below
                }
            }
            for c in &chains {
                if c.on {
                    chi[c.rank] *= c.chi;
                }
            }
            for v in &mut chi {
                *v = v.clamp(1.0, spec.chi_max);
            }
            rows.push(chi);
        }
        ContentionTrace { e, rows }
    }

    /// Realize any [`StragglerPlan`] as a trace: `None`/`Fixed`/
    /// `RoundRobin` become degenerate (epoch-constant) traces, scenarios
    /// run the full engine.
    pub fn from_plan(
        plan: &StragglerPlan,
        e: usize,
        epochs: usize,
        iters_per_epoch: usize,
    ) -> ContentionTrace {
        let ipe = iters_per_epoch.max(1);
        let total = (epochs * ipe).max(1);
        if let StragglerPlan::Scenario(spec) = plan {
            return Self::generate(spec, e, total);
        }
        let rows = (0..total).map(|g| plan.chis_at(e, g / ipe, g)).collect();
        ContentionTrace { e, rows }
    }

    pub fn e(&self) -> usize {
        self.e
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// χ row at global iteration `g`, clamped to the generated horizon.
    pub fn chis(&self, g: usize) -> &[f64] {
        &self.rows[g.min(self.rows.len() - 1)]
    }

    /// (mean, max) χ over all ranks × iterations.
    pub fn stats(&self) -> (f64, f64) {
        let mut sum = 0.0;
        let mut max = 0.0f64;
        let mut n = 0usize;
        for row in &self.rows {
            for &v in row {
                sum += v;
                max = max.max(v);
                n += 1;
            }
        }
        (if n > 0 { sum / n as f64 } else { 1.0 }, max)
    }
}

fn mul(chi: &mut [f64], rank: &RankSel, c: f64) {
    for (r, v) in chi.iter_mut().enumerate() {
        if rank.hits(r) {
            *v *= c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsl_parses_every_kind() {
        let s = ScenarioSpec::parse(
            "burst:r2@x4:iters10-40,tenant:r0@x2:iters5-9,ramp:r1@x3:iters0-8,\
             step:r3@x6:iters4-,pulse:r1@x6:from4:period12:on6,\
             markov:r*@x3:p0.2-0.4,seed:7,chimax:12",
        )
        .unwrap();
        assert_eq!(s.seed, 7);
        assert_eq!(s.chi_max, 12.0);
        assert_eq!(s.events.len(), 6);
        assert_eq!(
            s.events[0],
            Event::Burst { rank: RankSel::One(2), chi: 4.0, from: 10, to: 40 }
        );
        assert!(matches!(s.events[1], Event::Burst { .. }), "tenant aliases burst");
        assert!(matches!(s.events[5], Event::Markov { rank: RankSel::All, .. }));
    }

    #[test]
    fn dsl_rejects_bad_specs() {
        assert!(ScenarioSpec::parse("burst:r2@x0.5:iters0-4").is_err(), "χ<1");
        assert!(ScenarioSpec::parse("burst:r2@x4:iters9-4").is_err(), "empty window");
        assert!(ScenarioSpec::parse("ramp:r2@x4:iters3-").is_err(), "open ramp");
        assert!(ScenarioSpec::parse("markov:r2@x4:p1.5-0.2").is_err(), "bad prob");
        assert!(ScenarioSpec::parse("pulse:r1@x2:from0:period4:on9").is_err(), "on>period");
        assert!(ScenarioSpec::parse("meteor:r1@x2:iters0-4").is_err(), "unknown kind");
        assert!(ScenarioSpec::parse("burst:r1@x2:iters0-4:bogus").is_err(), "trailing");
    }

    #[test]
    fn empty_spec_is_calm() {
        let s = ScenarioSpec::parse("").unwrap();
        assert!(s.events.is_empty());
        let t = ContentionTrace::generate(&s, 4, 16);
        for g in 0..16 {
            assert_eq!(t.chis(g), &[1.0; 4]);
        }
    }

    #[test]
    fn presets_parse_and_compose() {
        for name in ["calm", "burst1", "bursty", "step6", "tenant-churn", "markov-duo"] {
            preset(name).unwrap_or_else(|e| panic!("preset {name}: {e}"));
        }
        assert!(preset("nope").is_err());
        let s = ScenarioSpec::parse("preset:step6,seed:3").unwrap();
        assert_eq!(s.seed, 3);
        assert_eq!(s.events.len(), 1);
    }

    #[test]
    fn scripted_windows_are_half_open() {
        let s = ScenarioSpec::parse("burst:r1@x4:iters2-5").unwrap();
        let t = ContentionTrace::generate(&s, 3, 8);
        for g in 0..8 {
            let want = if (2..5).contains(&g) { 4.0 } else { 1.0 };
            assert_eq!(t.chis(g), &[1.0, want, 1.0], "g={g}");
        }
    }

    #[test]
    fn step_is_permanent_and_pulse_is_periodic() {
        let s = ScenarioSpec::parse("step:r0@x2:iters3-").unwrap();
        let t = ContentionTrace::generate(&s, 2, 10);
        for g in 0..10 {
            assert_eq!(t.chis(g)[0], if g >= 3 { 2.0 } else { 1.0 });
        }
        let s = ScenarioSpec::parse("pulse:r0@x3:from2:period4:on2").unwrap();
        let t = ContentionTrace::generate(&s, 1, 12);
        for g in 2..12 {
            let want = if (g - 2) % 4 < 2 { 3.0 } else { 1.0 };
            assert_eq!(t.chis(g)[0], want, "g={g}");
        }
    }

    #[test]
    fn ramp_climbs_monotonically_to_chi() {
        let s = ScenarioSpec::parse("ramp:r0@x5:iters2-7").unwrap();
        let t = ContentionTrace::generate(&s, 1, 10);
        assert_eq!(t.chis(1)[0], 1.0);
        assert_eq!(t.chis(2)[0], 1.0, "ramp starts at 1");
        for g in 3..7 {
            assert!(t.chis(g)[0] > t.chis(g - 1)[0], "not climbing at {g}");
        }
        assert_eq!(t.chis(6)[0], 5.0, "reaches χ at the window end");
        assert_eq!(t.chis(7)[0], 1.0, "gone after the window");
    }

    #[test]
    fn tenants_compose_multiplicatively_and_clamp() {
        let s = ScenarioSpec::parse("burst:r0@x4:iters0-8,burst:r0@x3:iters2-8").unwrap();
        let t = ContentionTrace::generate(&s, 1, 8);
        assert_eq!(t.chis(1)[0], 4.0);
        assert_eq!(t.chis(3)[0], 12.0);
        let s = ScenarioSpec::parse("chimax:5,burst:r0@x4:iters0-8,burst:r0@x3:iters0-8")
            .unwrap();
        let t = ContentionTrace::generate(&s, 1, 4);
        assert_eq!(t.chis(0)[0], 5.0, "clamped to chimax");
    }

    #[test]
    fn json_object_and_string_forms_agree() {
        let dsl = ScenarioSpec::parse("burst:r2@x4:iters10-40,markov:r*@x3:p0.2-0.4,seed:7")
            .unwrap();
        let j = Json::parse(
            r#"{"seed": 7, "events": [
                 {"kind":"burst","rank":2,"chi":4,"from":10,"to":40},
                 {"kind":"markov","rank":"*","chi":3,"p_on":0.2,"p_off":0.4}]}"#,
        )
        .unwrap();
        assert_eq!(ScenarioSpec::from_json(&j).unwrap(), dsl);
        let j = Json::parse(r#""burst:r2@x4:iters10-40,markov:r*@x3:p0.2-0.4,seed:7""#).unwrap();
        assert_eq!(ScenarioSpec::from_json(&j).unwrap(), dsl);
    }

    #[test]
    fn describe_roundtrips_through_parse() {
        // non-default seed/chimax must survive the round trip, else a
        // re-run of the displayed spec realizes a different trace
        let src = "burst:r2@x4:iters10-40,step:r3@x6:iters4-,\
                   pulse:r1@x6:from4:period12:on6,markov:r*@x3:p0.2-0.4,\
                   seed:7,chimax:5";
        let s = ScenarioSpec::parse(src).unwrap();
        let re = ScenarioSpec::parse(&s.describe()).unwrap();
        assert_eq!(s, re, "describe() must round-trip the whole spec");
        // default seed/chimax stay implicit
        let plain = ScenarioSpec::parse("burst:r1@x2:iters0-4").unwrap();
        assert!(!plain.describe().contains("seed:"));
        assert_eq!(ScenarioSpec::parse(&plain.describe()).unwrap(), plain);
    }

    #[test]
    fn preempt_parses_describes_and_never_touches_the_trace() {
        let s = ScenarioSpec::parse("burst:r1@x4:iters2-5,preempt:iter7").unwrap();
        assert_eq!(s.preempt, Some(7));
        // bare number form and JSON form agree
        assert_eq!(ScenarioSpec::parse("preempt:7").unwrap().preempt, Some(7));
        let j = Json::parse(r#"{"preempt": 7, "events": []}"#).unwrap();
        assert_eq!(ScenarioSpec::from_json(&j).unwrap().preempt, Some(7));
        // round-trips through describe(), even with no χ events
        let re = ScenarioSpec::parse(&s.describe()).unwrap();
        assert_eq!(s, re);
        let only = ScenarioSpec::parse("preempt:3").unwrap();
        assert_eq!(ScenarioSpec::parse(&only.describe()).unwrap(), only);
        // preempting before any work is a spec error
        assert!(ScenarioSpec::parse("preempt:0").is_err());
        assert!(ScenarioSpec::from_json(&Json::parse(r#"{"preempt":0,"events":[]}"#).unwrap()).is_err());
        // the realized trace is identical with and without the preempt
        let a = ScenarioSpec::parse("burst:r1@x4:iters2-5").unwrap();
        let ta = ContentionTrace::generate(&a, 2, 10);
        let tb = ContentionTrace::generate(&s, 2, 10);
        for g in 0..10 {
            assert_eq!(ta.chis(g), tb.chis(g), "g={g}");
        }
    }

    #[test]
    fn rank_validation_rejects_out_of_range_targets() {
        let s = ScenarioSpec::parse("burst:r5@x4:iters0-20").unwrap();
        assert!(s.validate_ranks(4).is_err(), "r5 on a 4-rank group");
        assert!(s.validate_ranks(6).is_ok());
        // r* is valid for any group size; calm trivially passes
        assert!(ScenarioSpec::parse("markov:r*@x2:p0.1-0.2").unwrap().validate_ranks(1).is_ok());
        assert!(ScenarioSpec::parse("").unwrap().validate_ranks(1).is_ok());
        assert!(preset("tenant-churn").unwrap().validate_ranks(2).is_err(), "preset uses r3");
    }

    #[test]
    fn churn_events_parse_describe_and_json_roundtrip() {
        let s = ScenarioSpec::parse("fail:r3@iter6,join:r3@iter30,step:r2@x3:iters6-").unwrap();
        assert_eq!(s.churn.len(), 2);
        assert_eq!(s.churn[0], ChurnEvent { kind: ChurnKind::Fail, rank: 3, at: 6 });
        assert_eq!(s.churn[1], ChurnEvent { kind: ChurnKind::Join, rank: 3, at: 30 });
        // describe round-trips — checkpoint fingerprints depend on this
        assert_eq!(ScenarioSpec::parse(&s.describe()).unwrap(), s);
        // a churn-only spec is not "calm"
        let only = ScenarioSpec::parse("leave:r1@iter4").unwrap();
        assert_ne!(only.describe(), "calm");
        assert_eq!(ScenarioSpec::parse(&only.describe()).unwrap(), only);
        // JSON object form agrees with the DSL
        let j = Json::parse(
            r#"{"events": [{"kind":"step","rank":2,"chi":3,"from":6}],
                "churn": [{"kind":"fail","rank":3,"at":6},
                          {"kind":"join","rank":3,"at":30}]}"#,
        )
        .unwrap();
        assert_eq!(ScenarioSpec::from_json(&j).unwrap(), s);
        // churn is orchestration-only: the realized χ rows are identical
        let bare = ScenarioSpec::parse("step:r2@x3:iters6-").unwrap();
        let ta = ContentionTrace::generate(&bare, 4, 12);
        let tb = ContentionTrace::generate(&s, 4, 12);
        for g in 0..12 {
            assert_eq!(ta.chis(g), tb.chis(g), "g={g}");
        }
    }

    #[test]
    fn churn_suspends_static_rank_validation() {
        // with churn the group size is dynamic: r3 exists while E=4 even
        // if the model is currently sharded over 2 workers
        let s = ScenarioSpec::parse("step:r3@x6:iters4-,fail:r3@iter6").unwrap();
        assert!(s.validate_ranks(2).is_ok());
        let stat = ScenarioSpec::parse("step:r3@x6:iters4-").unwrap();
        assert!(stat.validate_ranks(2).is_err(), "static spec keeps the range check");
    }

    #[test]
    fn churn_rejects_malformed_clauses() {
        for bad in ["join:r*@iter4", "fail:r1@iter0", "join:r1@x4", "leave:r1", "join:rq@iter3"]
        {
            assert!(ScenarioSpec::parse(bad).is_err(), "{bad} must be rejected");
        }
        // churn sorts stably by firing iteration
        let s = ScenarioSpec::parse("join:r1@iter9,fail:r0@iter3").unwrap();
        let sorted = s.churn_sorted();
        assert_eq!(sorted[0].at, 3);
        assert_eq!(sorted[1].at, 9);
    }

    #[test]
    fn mem_events_parse_describe_and_json_roundtrip() {
        let s =
            ScenarioSpec::parse("memsqueeze:r1@iter4:x0.5,oom:r3@iter8,step:r2@x3:iters6-")
                .unwrap();
        assert_eq!(s.mem.len(), 2);
        assert_eq!(
            s.mem[0],
            MemEvent { kind: MemKind::Squeeze { frac: 0.5 }, rank: 1, at: 4 }
        );
        assert_eq!(s.mem[1], MemEvent { kind: MemKind::Oom, rank: 3, at: 8 });
        // describe round-trips — checkpoint fingerprints depend on this
        assert_eq!(ScenarioSpec::parse(&s.describe()).unwrap(), s);
        // a mem-only spec is not "calm"
        let only = ScenarioSpec::parse("memsqueeze:r0@iter2:x0.25").unwrap();
        assert_ne!(only.describe(), "calm");
        assert_eq!(ScenarioSpec::parse(&only.describe()).unwrap(), only);
        // JSON object form agrees with the DSL
        let j = Json::parse(
            r#"{"events": [{"kind":"step","rank":2,"chi":3,"from":6}],
                "mem": [{"kind":"memsqueeze","rank":1,"at":4,"frac":0.5},
                        {"kind":"oom","rank":3,"at":8}]}"#,
        )
        .unwrap();
        assert_eq!(ScenarioSpec::from_json(&j).unwrap(), s);
        // memory events are orchestration-only: χ rows are unperturbed
        let bare = ScenarioSpec::parse("step:r2@x3:iters6-").unwrap();
        let ta = ContentionTrace::generate(&bare, 4, 12);
        let tb = ContentionTrace::generate(&s, 4, 12);
        for g in 0..12 {
            assert_eq!(ta.chis(g), tb.chis(g), "g={g}");
        }
        // mem sorts stably by firing iteration
        let sorted = s.mem_sorted();
        assert_eq!(sorted[0].at, 4);
        assert_eq!(sorted[1].at, 8);
    }

    #[test]
    fn mem_event_rank_validation_follows_oom_not_squeeze() {
        // an oom evicts through the churn path, so the group size is
        // dynamic and the static range check is suspended …
        let s = ScenarioSpec::parse("oom:r3@iter6").unwrap();
        assert!(s.validate_ranks(2).is_ok());
        // … but a squeeze never changes E, so its rank must exist
        let s = ScenarioSpec::parse("memsqueeze:r3@iter6:x0.5").unwrap();
        assert!(s.validate_ranks(2).is_err(), "squeeze keeps the range check");
        assert!(s.validate_ranks(4).is_ok());
    }

    #[test]
    fn mem_rejects_malformed_clauses() {
        for bad in [
            "memsqueeze:r*@iter4:x0.5",
            "memsqueeze:r1@iter0:x0.5",
            "memsqueeze:r1@iter4",
            "memsqueeze:r1@iter4:x1.5",
            "memsqueeze:r1@iter4:0.5",
            "oom:r1@iter4:x0.5",
            "oom:r1",
            "oom:rq@iter3",
        ] {
            assert!(ScenarioSpec::parse(bad).is_err(), "{bad} must be rejected");
        }
        // JSON: oom forbids frac, memsqueeze requires it, typos rejected
        let j = Json::parse(r#"{"mem": [{"kind":"oom","rank":1,"at":4,"frac":0.5}]}"#)
            .unwrap();
        assert!(ScenarioSpec::from_json(&j).is_err(), "oom must reject 'frac'");
        let j = Json::parse(r#"{"mem": [{"kind":"memsqueeze","rank":1,"at":4}]}"#).unwrap();
        assert!(ScenarioSpec::from_json(&j).is_err(), "memsqueeze needs 'frac'");
        let j =
            Json::parse(r#"{"mem": [{"kind":"memsqueeze","rank":1,"at":4,"fra":0.5}]}"#)
                .unwrap();
        assert!(ScenarioSpec::from_json(&j).is_err(), "typoed 'fra' must not be dropped");
    }

    #[test]
    fn json_rejects_unknown_and_misplaced_fields() {
        // a 'to' on a step would silently change the scenario's meaning
        let j = Json::parse(
            r#"{"events": [{"kind":"step","rank":1,"chi":4,"from":5,"to":20}]}"#,
        )
        .unwrap();
        assert!(ScenarioSpec::from_json(&j).is_err(), "step must reject 'to'");
        let j = Json::parse(
            r#"{"events": [{"kind":"markov","rank":1,"chi":4,"p_on":0.2,"p_of":0.4}]}"#,
        )
        .unwrap();
        assert!(ScenarioSpec::from_json(&j).is_err(), "typoed p_of must not be dropped");
        let j = Json::parse(r#"{"chimax": 5, "events": []}"#).unwrap();
        assert!(ScenarioSpec::from_json(&j).is_err(), "top-level typo (chi_max) rejected");
    }
}
