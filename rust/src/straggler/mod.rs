//! Straggler detection & injection (paper §III-A, §V-A).
//!
//! * **Injector** — per-rank skewness χ: block-GEMM compute charges are
//!   multiplied by χ (the paper injects sleeps into the matmuls of
//!   selected GPUs; a SimClock charge is the deterministic equivalent,
//!   and `emulate_wall` really sleeps for demos).
//! * **Monitor** — per-rank iteration runtime T_i and matmul time M_i.
//!   T_avg is refreshed *passively*: a rank only triggers the (costed)
//!   scalar all-gather when its own runtime moved >10% since the value it
//!   last synchronized on (the paper's on-demand refresh).

use crate::collectives::Comm;
use crate::cluster::Clocks;

/// Per-rank χ multipliers for one **iteration**.
///
/// The injector holds a *snapshot*: [`Injector::set_iter_chi`] is called
/// once per iteration on the coordinator, and every charge — SimClock
/// advance *and* wall-emulation sleep — within that iteration reads the
/// same vector.  Before the snapshot API, χ was re-read per charge, so a
/// trace that advanced mid-epoch could leave sim-clock charges and
/// emulated sleeps disagreeing within one iteration (the wall-drift
/// fix); now the trace can only take effect at iteration boundaries.
#[derive(Debug, Clone)]
pub struct Injector {
    pub chi: Vec<f64>,
    /// really sleep (paper-literal emulation) instead of only charging
    pub emulate_wall: bool,
}

impl Injector {
    pub fn homogeneous(e: usize) -> Injector {
        Injector { chi: vec![1.0; e], emulate_wall: false }
    }

    pub fn new(chi: Vec<f64>) -> Injector {
        Injector { chi, emulate_wall: false }
    }

    /// Snapshot the per-rank χ for the coming iteration (clamped to
    /// ≥ 1.0).  Copies into the existing buffer — allocation-free in the
    /// steady state when the rank count is unchanged.
    pub fn set_iter_chi(&mut self, chi: &[f64]) {
        if self.chi.len() == chi.len() {
            self.chi.copy_from_slice(chi);
        } else {
            self.chi = chi.to_vec();
        }
        for c in &mut self.chi {
            *c = c.max(1.0);
        }
    }

    /// Charge a block-GEMM compute measurement for `rank`: the SimClock
    /// gets `χ·t`; in wall-emulation mode the extra `(χ-1)·t` is slept.
    /// Both read the same snapshotted χ, so the two clocks always agree.
    pub fn charge(&self, clocks: &mut Clocks, rank: usize, measured_s: f64) {
        let chi = self.chi[rank];
        clocks.advance(rank, measured_s * chi);
        if self.emulate_wall && chi > 1.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                measured_s * (chi - 1.0),
            ));
        }
    }

    /// Charge non-GEMM (embed/head) compute — not skewed by χ, matching
    /// the paper's "simulated matrix multiplication in linear projections
    /// and transformations is χ times slower".
    pub fn charge_unskewed(&self, clocks: &mut Clocks, rank: usize, measured_s: f64) {
        clocks.advance(rank, measured_s);
    }

    pub fn stragglers(&self) -> Vec<usize> {
        self.chi
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 1.0)
            .map(|(r, _)| r)
            .collect()
    }
}

/// Runtime statistics the balancers consume.
#[derive(Debug, Clone)]
pub struct Monitor {
    /// last iteration's per-rank total runtime T_i (seconds, sim)
    pub t_iter: Vec<f64>,
    /// last iteration's per-rank matmul runtime M_i (block GEMMs only)
    pub m_iter: Vec<f64>,
    /// the T_avg each rank last synchronized on
    pub(crate) t_avg_cached: Vec<f64>,
    /// the own-T value at the time of the last sync
    pub(crate) t_self_at_sync: Vec<f64>,
    /// number of passive refreshes triggered (metrics)
    pub refreshes: u64,
}

impl Monitor {
    pub fn new(e: usize) -> Monitor {
        Monitor {
            t_iter: vec![0.0; e],
            m_iter: vec![0.0; e],
            t_avg_cached: vec![0.0; e],
            t_self_at_sync: vec![0.0; e],
            refreshes: 0,
        }
    }

    /// Record an iteration's measurements.
    pub fn record(&mut self, t: Vec<f64>, m: Vec<f64>) {
        self.t_iter = t;
        self.m_iter = m;
    }

    /// Passive T_avg (paper: refresh only on >10% own-runtime change).
    /// Charges the scalar all-gather to the clocks when any rank triggers.
    pub fn t_avg(&mut self, comm: &mut Comm, clocks: &mut Clocks) -> Vec<f64> {
        let e = self.t_iter.len();
        self.t_avg_group(comm, clocks, e)
    }

    /// [`Monitor::t_avg`] with the average taken over the rank prefix
    /// `0..g` only — the block-compute group under fine-grained degrees
    /// (DESIGN.md §18).  Ranks outside the prefix run no block GEMMs, so
    /// folding their near-idle runtimes into T_avg would manufacture
    /// phantom demand on every member.  `g == e` is the legacy average.
    pub fn t_avg_group(&mut self, comm: &mut Comm, clocks: &mut Clocks, g: usize) -> Vec<f64> {
        let e = self.t_iter.len();
        let g = g.clamp(1, e);
        let mut trigger = false;
        for r in 0..e {
            let base = self.t_self_at_sync[r];
            let now = self.t_iter[r];
            if base == 0.0 || (now - base).abs() > 0.10 * base.max(1e-12) {
                trigger = true;
            }
        }
        if trigger {
            let gathered = comm.all_gather_scalars(clocks, &self.t_iter);
            let avg = gathered[..g].iter().sum::<f64>() / g as f64;
            for r in 0..e {
                self.t_avg_cached[r] = avg;
                self.t_self_at_sync[r] = self.t_iter[r];
            }
            self.refreshes += 1;
        }
        self.t_avg_cached.clone()
    }

    /// Strict criterion T_min for the hybrid solution (paper §IV-B) —
    /// needs the full runtime list, so it always costs an all-gather.
    pub fn t_list_and_min(&self, comm: &mut Comm, clocks: &mut Clocks) -> (Vec<f64>, f64) {
        let e = self.t_iter.len();
        self.t_list_and_min_group(comm, clocks, e)
    }

    /// [`Monitor::t_list_and_min`] with the minimum taken over the rank
    /// prefix `0..g` only (block-compute group, DESIGN.md §18).  The
    /// gathered list still covers every rank — the collective's cost and
    /// the per-rank entries are unchanged; only the scalar criterion
    /// ignores out-of-group ranks.
    pub fn t_list_and_min_group(
        &self,
        comm: &mut Comm,
        clocks: &mut Clocks,
        g: usize,
    ) -> (Vec<f64>, f64) {
        let list = comm.all_gather_scalars(clocks, &self.t_iter);
        let g = g.clamp(1, list.len().max(1));
        let min = list[..g].iter().cloned().fold(f64::INFINITY, f64::min);
        (list, min)
    }
}

/// Eq. (1): γ_i = (T_i − T_avg) / M_i, clamped to [0, γ_max].
/// `γ_max < 1` because a task cannot prune more than everything.
pub fn gamma_eq1(t_i: f64, t_avg: f64, m_i: f64, gamma_max: f64) -> f64 {
    if m_i <= 0.0 || t_i <= t_avg {
        return 0.0;
    }
    ((t_i - t_avg) / m_i).min(gamma_max).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::cost::CostModel;

    #[test]
    fn injector_charges_skew() {
        let inj = Injector::new(vec![1.0, 3.0]);
        let mut clocks = Clocks::new(2);
        inj.charge(&mut clocks, 0, 0.1);
        inj.charge(&mut clocks, 1, 0.1);
        assert!((clocks.now(0) - 0.1).abs() < 1e-12);
        assert!((clocks.now(1) - 0.3).abs() < 1e-12);
        assert_eq!(inj.stragglers(), vec![1]);
    }

    #[test]
    fn iter_chi_snapshot_is_stable_between_sets() {
        // The wall-drift fix: charges between two set_iter_chi calls all
        // use the earlier snapshot; the source trace advancing has no
        // effect until the next iteration boundary.
        let mut inj = Injector::homogeneous(2);
        let mut clocks = Clocks::new(2);
        let trace_row_a = vec![2.0, 1.0];
        inj.set_iter_chi(&trace_row_a);
        inj.charge(&mut clocks, 0, 0.1);
        // trace moves on mid-iteration — the injector must not care
        let trace_row_b = vec![8.0, 1.0];
        let _ = &trace_row_b;
        inj.charge(&mut clocks, 0, 0.1);
        assert!((clocks.now(0) - 0.4).abs() < 1e-12, "both charges at χ=2");
        // next iteration picks the new row up
        inj.set_iter_chi(&trace_row_b);
        inj.charge(&mut clocks, 0, 0.1);
        assert!((clocks.now(0) - 1.2).abs() < 1e-12);
    }

    #[test]
    fn iter_chi_clamps_below_one() {
        let mut inj = Injector::homogeneous(3);
        inj.set_iter_chi(&[0.5, 1.0, 3.0]);
        assert_eq!(inj.chi, vec![1.0, 1.0, 3.0]);
        // rank-count change falls back to reallocation
        inj.set_iter_chi(&[2.0]);
        assert_eq!(inj.chi, vec![2.0]);
    }

    #[test]
    fn unskewed_charge_ignores_chi() {
        let inj = Injector::new(vec![8.0]);
        let mut clocks = Clocks::new(1);
        inj.charge_unskewed(&mut clocks, 0, 0.1);
        assert!((clocks.now(0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn gamma_eq1_basics() {
        // straggler 2x slower than avg with matmul = all the time:
        // needs to cut the gap T_i - T_avg.
        assert!((gamma_eq1(2.0, 1.0, 2.0, 0.9) - 0.5).abs() < 1e-12);
        assert_eq!(gamma_eq1(1.0, 1.0, 1.0, 0.9), 0.0); // not a straggler
        assert_eq!(gamma_eq1(0.5, 1.0, 1.0, 0.9), 0.0); // fast task
        assert_eq!(gamma_eq1(100.0, 1.0, 1.0, 0.9), 0.9); // clamped
    }

    #[test]
    fn passive_refresh_triggers_on_change() {
        let mut mon = Monitor::new(2);
        let mut comm = Comm::new(CostModel::default());
        let mut clocks = Clocks::new(2);

        mon.record(vec![1.0, 1.0], vec![0.5, 0.5]);
        let avg = mon.t_avg(&mut comm, &mut clocks);
        assert_eq!(avg, vec![1.0, 1.0]);
        assert_eq!(mon.refreshes, 1);

        // small change (<10%) → no refresh, cached value returned
        mon.record(vec![1.05, 1.0], vec![0.5, 0.5]);
        let avg = mon.t_avg(&mut comm, &mut clocks);
        assert_eq!(avg, vec![1.0, 1.0]);
        assert_eq!(mon.refreshes, 1);

        // big change → refresh
        mon.record(vec![2.0, 1.0], vec![0.5, 0.5]);
        let avg = mon.t_avg(&mut comm, &mut clocks);
        assert!((avg[0] - 1.5).abs() < 1e-12);
        assert_eq!(mon.refreshes, 2);
    }

    #[test]
    fn t_min_is_strict() {
        let mon = {
            let mut m = Monitor::new(3);
            m.record(vec![3.0, 1.0, 2.0], vec![1.0; 3]);
            m
        };
        let mut comm = Comm::new(CostModel::default());
        let mut clocks = Clocks::new(3);
        let (list, min) = mon.t_list_and_min(&mut comm, &mut clocks);
        assert_eq!(min, 1.0);
        assert_eq!(list, vec![3.0, 1.0, 2.0]);
    }
}
