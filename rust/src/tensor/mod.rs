//! Host tensor: the coordinator-side data container.
//!
//! The host side does collective sums, residual adds, lineage
//! gathers/scatters, and optimizer updates — the ops here.  Heavy GEMMs
//! run inside an execution backend: blocked kernels from [`linalg`] on the
//! default native backend, or PJRT executables behind `--features pjrt`.
//! [`Tensor::matmul`] routes through the same blocked kernel so host-side
//! checks and backends agree numerically.

pub mod linalg;
pub mod workspace;

pub use workspace::Workspace;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(dims: &[usize]) -> Tensor {
        Tensor { dims: dims.to_vec(), data: vec![0.0; dims.iter().product()] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "dims/data mismatch");
        Tensor { dims: dims.to_vec(), data }
    }

    pub fn full(dims: &[usize], v: f32) -> Tensor {
        Tensor { dims: dims.to_vec(), data: vec![v; dims.iter().product()] }
    }

    pub fn normal(dims: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        Tensor { dims: dims.to_vec(), data: rng.normal_vec(dims.iter().product(), std) }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }

    /// Rows × cols view of the last two dims (leading dims folded into rows).
    pub fn as_2d(&self) -> (usize, usize) {
        let cols = *self.dims.last().expect("tensor has no dims");
        (self.len() / cols, cols)
    }

    // ---- elementwise ------------------------------------------------------

    pub fn add_assign(&mut self, other: &Tensor) {
        debug_assert_eq!(self.dims, other.dims);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub_scaled(&mut self, other: &Tensor, scale: f32) {
        debug_assert_eq!(self.dims, other.dims);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= scale * b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Sum of |x| — grad checksums & priority statistics.
    pub fn abs_sum(&self) -> f64 {
        self.data.iter().map(|x| x.abs() as f64).sum()
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|x| *x as f64).sum()
    }

    // ---- column/row structure (lineage & migration) ------------------------

    /// Mean |Δ| per column of a 2D tensor vs `old` — the paper's
    /// `w_var_list` statistic δ_i = Σ_j |w_ji - w_ji^old| / R.
    pub fn col_abs_delta(&self, old: &Tensor) -> Vec<f32> {
        debug_assert_eq!(self.dims, old.dims);
        let (r, c) = self.as_2d();
        let mut out = vec![0.0f32; c];
        for i in 0..r {
            let row = &self.data[i * c..(i + 1) * c];
            let orow = &old.data[i * c..(i + 1) * c];
            for j in 0..c {
                out[j] += (row[j] - orow[j]).abs();
            }
        }
        let rn = r as f32;
        for v in &mut out {
            *v /= rn;
        }
        out
    }

    /// Mean |Δ| per ROW of a 2D tensor vs `old` — the w_var statistic over
    /// a contraction dimension stored as weight rows.
    pub fn row_abs_delta(&self, old: &Tensor) -> Vec<f32> {
        debug_assert_eq!(self.dims, old.dims);
        let (r, c) = self.as_2d();
        let mut out = vec![0.0f32; r];
        for i in 0..r {
            let row = &self.data[i * c..(i + 1) * c];
            let orow = &old.data[i * c..(i + 1) * c];
            out[i] = row.iter().zip(orow).map(|(a, b)| (a - b).abs()).sum::<f32>()
                / c as f32;
        }
        out
    }

    /// Set columns `pruned` to the per-row mean over columns NOT pruned
    /// (Average imputation for column-pruned matrices).
    pub fn impute_cols_mean(&mut self, pruned: &[u32]) {
        let (r, c) = self.as_2d();
        if pruned.len() >= c {
            return;
        }
        let mut in_pruned = vec![false; c];
        for &j in pruned {
            in_pruned[j as usize] = true;
        }
        let kept = (c - pruned.len()) as f32;
        for i in 0..r {
            let row = &mut self.data[i * c..(i + 1) * c];
            let mean: f32 = row
                .iter()
                .enumerate()
                .filter(|(j, _)| !in_pruned[*j])
                .map(|(_, v)| *v)
                .sum::<f32>()
                / kept;
            for &j in pruned {
                row[j as usize] = mean;
            }
        }
    }

    /// Copy rows `idx` from `src` (same full shape) — Same imputation.
    pub fn copy_rows_from(&mut self, idx: &[u32], src: &Tensor) {
        debug_assert_eq!(self.dims, src.dims);
        let (_, c) = self.as_2d();
        for &i in idx {
            let i = i as usize;
            self.data[i * c..(i + 1) * c].copy_from_slice(&src.data[i * c..(i + 1) * c]);
        }
    }

    /// Copy columns `idx` from `src` (same full shape) — Same imputation.
    pub fn copy_cols_from(&mut self, idx: &[u32], src: &Tensor) {
        debug_assert_eq!(self.dims, src.dims);
        let (r, c) = self.as_2d();
        for i in 0..r {
            for &j in idx {
                self.data[i * c + j as usize] = src.data[i * c + j as usize];
            }
        }
    }

    /// Scatter-assign `src` (shape `[rows, idx.len()]`) into columns `idx`.
    pub fn scatter_cols_assign(&mut self, idx: &[u32], src: &Tensor) {
        let (r, c) = self.as_2d();
        let (sr, sc) = src.as_2d();
        debug_assert_eq!(sr, r);
        debug_assert_eq!(sc, idx.len());
        for i in 0..r {
            for (k, &j) in idx.iter().enumerate() {
                self.data[i * c + j as usize] = src.data[i * sc + k];
            }
        }
    }

    /// Gather columns `idx` of a 2D tensor → `[rows, idx.len()]`.
    pub fn gather_cols(&self, idx: &[u32]) -> Tensor {
        let (r, c) = self.as_2d();
        let k = idx.len();
        let mut data = Vec::with_capacity(r * k);
        for i in 0..r {
            let row = &self.data[i * c..(i + 1) * c];
            for &j in idx {
                data.push(row[j as usize]);
            }
        }
        Tensor::from_vec(&[r, k], data)
    }

    /// Gather rows `idx` of a 2D tensor → `[idx.len(), cols]`.
    pub fn gather_rows(&self, idx: &[u32]) -> Tensor {
        let (_, c) = self.as_2d();
        let mut data = Vec::with_capacity(idx.len() * c);
        for &i in idx {
            data.extend_from_slice(&self.data[i as usize * c..(i as usize + 1) * c]);
        }
        Tensor::from_vec(&[idx.len(), c], data)
    }

    /// Scatter-assign `src` rows into rows `idx` of self (2D).
    pub fn scatter_rows_assign(&mut self, idx: &[u32], src: &Tensor) {
        let (_, c) = self.as_2d();
        let (sr, sc) = src.as_2d();
        debug_assert_eq!(sc, c);
        debug_assert_eq!(sr, idx.len());
        for (k, &i) in idx.iter().enumerate() {
            self.data[i as usize * c..(i as usize + 1) * c]
                .copy_from_slice(&src.data[k * c..(k + 1) * c]);
        }
    }

    /// Scatter-add `src` rows into rows `idx` of self (2D).
    pub fn scatter_rows_add(&mut self, idx: &[u32], src: &Tensor) {
        let (_, c) = self.as_2d();
        for (k, &i) in idx.iter().enumerate() {
            let dst = &mut self.data[i as usize * c..(i as usize + 1) * c];
            for (d, s) in dst.iter_mut().zip(&src.data[k * c..(k + 1) * c]) {
                *d += s;
            }
        }
    }

    /// Set rows `idx` to the per-column mean over rows NOT in `idx`
    /// (the paper's Average imputation policy).
    pub fn impute_rows_mean(&mut self, pruned: &[u32]) {
        let (r, c) = self.as_2d();
        if pruned.len() >= r {
            return;
        }
        let mut in_pruned = vec![false; r];
        for &i in pruned {
            in_pruned[i as usize] = true;
        }
        let mut mean = vec![0.0f32; c];
        let kept = r - pruned.len();
        for i in 0..r {
            if !in_pruned[i] {
                for j in 0..c {
                    mean[j] += self.data[i * c + j];
                }
            }
        }
        for m in &mut mean {
            *m /= kept as f32;
        }
        for &i in pruned {
            self.data[i as usize * c..(i as usize + 1) * c].copy_from_slice(&mean);
        }
    }

    /// Zero-pad a `[r, k]` tensor to `[r, kb]` columns (migration buckets).
    pub fn pad_cols(&self, kb: usize) -> Tensor {
        let (r, k) = self.as_2d();
        assert!(kb >= k);
        let mut out = Tensor::zeros(&[r, kb]);
        for i in 0..r {
            out.data[i * kb..i * kb + k].copy_from_slice(&self.data[i * k..(i + 1) * k]);
        }
        out
    }

    /// Zero-pad a `[k, c]` tensor to `[kb, c]` rows (migration buckets).
    pub fn pad_rows(&self, kb: usize) -> Tensor {
        let (k, c) = self.as_2d();
        assert!(kb >= k);
        let mut out = Tensor::zeros(&[kb, c]);
        out.data[..k * c].copy_from_slice(&self.data);
        out
    }

    /// Truncate a `[kb, c]` tensor to its first `k` rows.
    pub fn take_rows(&self, k: usize) -> Tensor {
        let (kb, c) = self.as_2d();
        assert!(k <= kb);
        Tensor::from_vec(&[k, c], self.data[..k * c].to_vec())
    }

    /// Truncate a `[r, kb]` tensor to its first `k` columns.
    pub fn take_cols(&self, k: usize) -> Tensor {
        let (r, kb) = self.as_2d();
        assert!(k <= kb);
        let mut data = Vec::with_capacity(r * k);
        for i in 0..r {
            data.extend_from_slice(&self.data[i * kb..i * kb + k]);
        }
        Tensor::from_vec(&[r, k], data)
    }

    // ---- dense products ----------------------------------------------------

    /// 2-D matrix product over the folded `as_2d` views, via the blocked
    /// kernel in [`linalg`] (also the native backend's GEMM).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        let (m, k) = self.as_2d();
        let (k2, n) = other.as_2d();
        if k != k2 {
            bail!("matmul shape mismatch: {k} vs {k2}");
        }
        Ok(Tensor::from_vec(&[m, n], linalg::matmul(&self.data, &other.data, m, k, n)))
    }

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.dims == other.dims
            && self.data.iter().zip(&other.data).all(|(a, b)| (a - b).abs() <= atol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_scatter_roundtrip() {
        let t = Tensor::from_vec(&[2, 4], vec![0., 1., 2., 3., 4., 5., 6., 7.]);
        let g = t.gather_cols(&[1, 3]);
        assert_eq!(g.data, vec![1., 3., 5., 7.]);
        let r = t.gather_rows(&[1]);
        assert_eq!(r.data, vec![4., 5., 6., 7.]);
        let mut z = Tensor::zeros(&[2, 4]);
        z.scatter_rows_assign(&[1], &r);
        assert_eq!(z.data[4..], t.data[4..]);
        assert_eq!(z.data[..4], [0.0; 4]);
    }

    #[test]
    fn scatter_add_accumulates() {
        let mut t = Tensor::full(&[3, 2], 1.0);
        let src = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        t.scatter_rows_add(&[0, 2], &src);
        assert_eq!(t.data, vec![2., 3., 1., 1., 4., 5.]);
    }

    #[test]
    fn col_delta_matches_manual() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![0., 2., 5., 3.]);
        let d = b.col_abs_delta(&a);
        assert_eq!(d, vec![(1.0 + 2.0) / 2.0, (0.0 + 1.0) / 2.0]);
    }

    #[test]
    fn impute_mean_fills_pruned_rows() {
        let mut t = Tensor::from_vec(&[3, 2], vec![1., 2., 100., 100., 3., 4.]);
        t.impute_rows_mean(&[1]);
        assert_eq!(&t.data[2..4], &[2.0, 3.0]);
    }

    #[test]
    fn pad_and_take_roundtrip() {
        let t = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let p = t.pad_cols(4);
        assert_eq!(p.dims, vec![2, 4]);
        assert_eq!(p.take_cols(2), t);
        let pr = t.pad_rows(3);
        assert_eq!(pr.dims, vec![3, 2]);
        assert_eq!(pr.take_rows(2), t);
        assert_eq!(&pr.data[4..], &[0.0, 0.0]);
    }

    #[test]
    fn matmul_oracle() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![1., 1., 1., 1.]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![3., 3., 7., 7.]);
        assert!(a.matmul(&Tensor::zeros(&[3, 2])).is_err());
    }

    #[test]
    fn sgd_style_update() {
        let mut p = Tensor::full(&[4], 1.0);
        let g = Tensor::full(&[4], 0.5);
        p.sub_scaled(&g, 0.1);
        assert!(p.allclose(&Tensor::full(&[4], 0.95), 1e-7));
    }
}
