//! Packed, register-blocked GEMM micro-kernels for the native backend.
//!
//! The hot path of every executable role is one of three GEMM shapes —
//! `A·B`, `Aᵀ·B` (weight gradients), `A·Bᵀ` (input gradients) — over
//! row-major f32 buffers, plus the gather-fused pruned variants of
//! Eq. (1).  All of them funnel into one micro-kernel design:
//!
//! * The B operand is **packed** once per `(k-block, n-panel)` into
//!   contiguous `NR`-wide column strips (`pack_b` / `pack_bt`), so the
//!   inner loop streams one cache line per step regardless of the
//!   original layout — including the transposed (`A·Bᵀ`) and row-gathered
//!   (pruned) layouts, which fold their gather into this packing step
//!   instead of materializing a gathered copy of the operand.
//! * The inner loop computes an `MR×NR` **register tile**: `MR×NR`
//!   f32 accumulators in fixed-size arrays that LLVM keeps in vector
//!   registers and auto-vectorizes (every accumulator is an independent
//!   chain, so no float-reassociation is needed).  The old per-element
//!   `av == 0.0` skip is gone from the dense path — branchless tiles beat
//!   the branch even on sparse-ish inputs, and pruned shapes now use the
//!   gather-fused kernels instead of zero-masking.
//!
//! # Intra-op parallelism (and why it stays bitwise deterministic)
//!
//! Each kernel can split its work across **row panels** on scoped OS
//! threads ([`set_gemm_threads`] / `--threads`).  Every output element is
//! owned by exactly one panel, and its accumulation order — ascending
//! over the contraction dimension, identical for the packed tile and the
//! serial loop — never depends on the thread count.  For **tall-skinny**
//! shapes (`rows < threads`, wide output) the split switches to **column
//! panels**: each worker copies its column stripe of C into a private
//! contiguous buffer, runs the exact serial kernel on it, and the
//! coordinator copies the stripes back — seeding the accumulators with
//! the existing C values keeps the per-element arithmetic identical to
//! the serial kernel, so results are still bitwise thread-count-invariant.
//! f32 addition is deterministic for a fixed operand order, which is the
//! property `tests/parallel_determinism.rs` and
//! [`tests::all_kernels_bitwise_identical_across_thread_counts`] pin.
//!
//! The rank-execution pool ([`crate::train::parallel::RankPool`]) runs its
//! workers under [`with_gemm_threads`]`(1, ..)` so rank-level and GEMM-level
//! parallelism never oversubscribe the same cores; the trainer wraps its
//! replicated single-call roles (embed/head) in
//! [`with_gemm_threads`]`(threads, ..)` so those still fan out.
//! [`set_gemm_threads`] sets the *process-wide default* for standalone
//! kernel use outside a trainer.
//!
//! Scratch discipline: the pack buffers are fixed-size stack arrays
//! (`BLOCK_K × BLOCK_N` f32 ≈ 32 KiB), so the serial and row-panel paths
//! perform **zero heap allocations** — the workspace arena
//! ([`crate::tensor::workspace::Workspace`]) only has to cover the
//! buffers *between* kernels.  The one exception is the tall-skinny
//! column split, whose workers allocate their private C stripes; it can
//! only trigger on multi-threaded coordinator-side calls (`rows <
//! threads`), never in the rank workers' serial hot path.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Contraction-dimension tile (rows of a packed B panel).
const BLOCK_K: usize = 64;
/// Output-column tile (columns of a packed B panel; multiple of NR).
const BLOCK_N: usize = 128;
/// Micro-tile rows (register-blocked A rows per inner sweep).
const MR: usize = 4;
/// Micro-tile columns (one strip of packed B; 16 f32 = 2×AVX2 / 1×AVX-512).
const NR: usize = 16;
/// Below this many multiply-adds a GEMM stays serial: thread spawn costs
/// more than the arithmetic saved.
const PAR_MIN_FLOPS: usize = 1 << 17;

/// Process-wide default intra-op thread count (serial unless raised via
/// [`set_gemm_threads`]; the trainer scopes its width per call instead).
static GEMM_THREADS: AtomicUsize = AtomicUsize::new(1);

/// `available_parallelism` resolved once — the old code re-queried the OS
/// on every `with_gemm_threads(0, ..)` entry in the hot loop.
static CORES: OnceLock<usize> = OnceLock::new();

thread_local! {
    /// Per-thread override (0 = defer to the global). Rank-pool workers
    /// set 1 here so nested parallelism cannot oversubscribe.
    static GEMM_THREADS_TLS: Cell<usize> = const { Cell::new(0) };
}

/// Detected core count (cached after the first call).
pub fn available_cores() -> usize {
    *CORES.get_or_init(|| {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    })
}

/// `0` = all available cores (shared convention with `--threads`).
fn resolve(n: usize) -> usize {
    if n == 0 {
        available_cores()
    } else {
        n
    }
}

/// Set the process-wide GEMM thread count. `0` = all available cores.
/// Thread count never changes results (see module docs), only speed.
pub fn set_gemm_threads(n: usize) {
    GEMM_THREADS.store(resolve(n), Ordering::Relaxed);
}

/// Effective GEMM thread count on the calling thread.
pub fn gemm_threads() -> usize {
    let tls = GEMM_THREADS_TLS.with(|c| c.get());
    if tls != 0 {
        tls
    } else {
        GEMM_THREADS.load(Ordering::Relaxed)
    }
}

/// Run `f` with the calling thread's GEMM parallelism overridden to `n`
/// (restored on exit, panic-safe).  `0` = all available cores, matching
/// [`set_gemm_threads`]; the 0-as-defer sentinel stays internal to the
/// TLS cell.
pub fn with_gemm_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            GEMM_THREADS_TLS.with(|c| c.set(self.0));
        }
    }
    let prev = GEMM_THREADS_TLS.with(|c| c.get());
    let _restore = Restore(prev);
    GEMM_THREADS_TLS.with(|c| c.set(resolve(n)));
    f()
}

/// How a kernel invocation splits across worker threads.
enum Split {
    Serial,
    /// `t` contiguous output-row panels (each worker runs the serial
    /// kernel on its own row slice).
    Rows(usize),
    /// `t` output-column panels — the tall-skinny case where there are
    /// fewer rows than threads but plenty of columns.
    Cols(usize),
}

fn choose_split(flops: usize, rows: usize, cols: usize) -> Split {
    if flops < PAR_MIN_FLOPS {
        return Split::Serial;
    }
    let t = gemm_threads();
    if t <= 1 {
        return Split::Serial;
    }
    if rows >= t {
        return Split::Rows(t);
    }
    // Tall-skinny: row panels can't feed t workers.  Split columns when
    // each worker still gets at least one NR strip; otherwise fall back
    // to one panel per row.
    let tc = t.min(cols / NR);
    if tc >= 2 && tc > rows {
        Split::Cols(tc)
    } else if rows >= 2 {
        Split::Rows(rows)
    } else {
        Split::Serial
    }
}

/// Split `total` into `t` contiguous nearly-equal panels: `(start, len)`.
fn row_panels(total: usize, t: usize) -> Vec<(usize, usize)> {
    let mut panels = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = (total - start).div_ceil(t - i);
        panels.push((start, len));
        start += len;
    }
    debug_assert_eq!(start, total);
    panels
}

/// Run `body` over column panels of the row-major `c` (`rows × n`): each
/// worker copies its stripe of C into a private contiguous buffer (so the
/// accumulators are seeded with the existing values — `c +=` semantics),
/// runs the serial kernel on it, and the coordinator copies the stripes
/// back in panel order.  Bitwise-identical to the serial kernel.
fn col_split<F>(c: &mut [f32], rows: usize, n: usize, t: usize, body: F)
where
    F: Fn(&mut [f32], usize, usize) + Sync,
{
    let panels = row_panels(n, t);
    let c_src: &[f32] = c;
    let results: Vec<Vec<f32>> = std::thread::scope(|s| {
        let handles: Vec<_> = panels
            .iter()
            .map(|&(j0, jw)| {
                let body = &body;
                s.spawn(move || {
                    let mut stripe = vec![0.0f32; rows * jw];
                    for i in 0..rows {
                        stripe[i * jw..(i + 1) * jw]
                            .copy_from_slice(&c_src[i * n + j0..i * n + j0 + jw]);
                    }
                    body(&mut stripe, j0, jw);
                    stripe
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
            .collect()
    });
    for (&(j0, jw), stripe) in panels.iter().zip(&results) {
        for i in 0..rows {
            c[i * n + j0..i * n + j0 + jw].copy_from_slice(&stripe[i * jw..(i + 1) * jw]);
        }
    }
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// Pack `b[row(k0+l), j0..j0+nw]` into NR-wide column strips:
/// `pack[(s·kb + l)·NR + jj] = b[row(k0+l)·ldb + j0 + s·NR + jj]`, with
/// strip tails zero-padded.  `rowsel` folds the pruned row-gather of
/// Eq. (1) into the packing (`row(l) = idx[l]`), replacing the old
/// `gather_rows` full-copy.
fn pack_b(
    pack: &mut [f32],
    b: &[f32],
    ldb: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    nw: usize,
    rowsel: Option<&[i32]>,
) {
    let kb = k1 - k0;
    let strips = nw.div_ceil(NR);
    for s in 0..strips {
        let c0 = j0 + s * NR;
        let w = NR.min(j0 + nw - c0);
        for l in 0..kb {
            let src_row = match rowsel {
                None => k0 + l,
                Some(idx) => idx[k0 + l] as usize,
            };
            let src = src_row * ldb + c0;
            let dst = (s * kb + l) * NR;
            pack[dst..dst + w].copy_from_slice(&b[src..src + w]);
            pack[dst + w..dst + NR].fill(0.0);
        }
    }
}

/// Pack the *transpose*: `pack[(s·kb + l)·NR + jj] = b[row(j)·ldb + k0 + l]`
/// where `j = j0 + s·NR + jj` — the `A·Bᵀ` layout, one packed strip per NR
/// B rows.  `rowsel` maps strip columns through `idx` (the pruned
/// `dy · w[idx,:]ᵀ` input-gradient kernel).
fn pack_bt(
    pack: &mut [f32],
    b: &[f32],
    ldb: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    nw: usize,
    rowsel: Option<&[i32]>,
) {
    let kb = k1 - k0;
    let strips = nw.div_ceil(NR);
    for s in 0..strips {
        let c0 = j0 + s * NR;
        let w = NR.min(j0 + nw - c0);
        let base = s * kb * NR;
        for jj in 0..w {
            let row = match rowsel {
                None => c0 + jj,
                Some(idx) => idx[c0 + jj] as usize,
            };
            let src = &b[row * ldb + k0..row * ldb + k1];
            for (l, &v) in src.iter().enumerate() {
                pack[base + l * NR + jj] = v;
            }
        }
        for jj in w..NR {
            for l in 0..kb {
                pack[base + l * NR + jj] = 0.0;
            }
        }
    }
}

/// Gather + mask an `rr × kb` tile of A into a contiguous stack tile
/// (stride `BLOCK_K`): `tile[r·BLOCK_K + l] = a[(i0+r)·lda + idx[k0+l]] ·
/// mask[k0+l]` — the pruned column-gather of Eq. (1) fused to tile
/// granularity (the old `gather_cols_masked` materialized the whole
/// `[rows × kp]` operand).
fn pack_a_gather(
    tile: &mut [f32; MR * BLOCK_K],
    a: &[f32],
    lda: usize,
    i0: usize,
    rr: usize,
    idx: &[i32],
    mask: &[f32],
    k0: usize,
    kb: usize,
) {
    for r in 0..rr {
        let row = &a[(i0 + r) * lda..(i0 + r + 1) * lda];
        let dst = &mut tile[r * BLOCK_K..r * BLOCK_K + kb];
        for (l, d) in dst.iter_mut().enumerate() {
            *d = row[idx[k0 + l] as usize] * mask[k0 + l];
        }
    }
}

// ---------------------------------------------------------------------------
// Micro-kernel
// ---------------------------------------------------------------------------

/// `R×NR` register tile over one packed strip: for ascending `l`,
/// `acc[r][j] += a[(ai+r)·lda + ak + l] · strip[l·NR + j]`.  The tile is
/// loaded from / stored to C around the `l` loop, so the per-element
/// accumulation order is exactly the serial triple loop's — partial sums
/// round-trip through f32 memory losslessly, making block order
/// invisible to the result.
#[inline(always)]
fn micro_ab<const R: usize>(
    c: &mut [f32],
    ldc: usize,
    ci: usize,
    cj: usize,
    w: usize,
    a: &[f32],
    lda: usize,
    ai: usize,
    ak: usize,
    strip: &[f32],
) {
    let mut acc = [[0.0f32; NR]; R];
    for r in 0..R {
        let base = (ci + r) * ldc + cj;
        acc[r][..w].copy_from_slice(&c[base..base + w]);
    }
    for (l, bl) in strip.chunks_exact(NR).enumerate() {
        let bl: &[f32; NR] = bl.try_into().expect("NR-wide strip chunk");
        for r in 0..R {
            let av = a[(ai + r) * lda + ak + l];
            let accr = &mut acc[r];
            for j in 0..NR {
                accr[j] += av * bl[j];
            }
        }
    }
    for r in 0..R {
        let base = (ci + r) * ldc + cj;
        c[base..base + w].copy_from_slice(&acc[r][..w]);
    }
}

/// Sweep all strips of one packed panel for one row block.
#[inline(always)]
fn micro_strips<const R: usize>(
    c: &mut [f32],
    ldc: usize,
    i: usize,
    n0: usize,
    nw: usize,
    a: &[f32],
    lda: usize,
    ai: usize,
    ak: usize,
    pack: &[f32],
    kb: usize,
) {
    let strips = nw.div_ceil(NR);
    for s in 0..strips {
        let cj = n0 + s * NR;
        let w = NR.min(nw - s * NR);
        micro_ab::<R>(c, ldc, i, cj, w, a, lda, ai, ak, &pack[s * kb * NR..(s + 1) * kb * NR]);
    }
}

// ---------------------------------------------------------------------------
// Kernel bodies (serial; the split wrappers call these per panel)
// ---------------------------------------------------------------------------

/// `c[0..m, 0..jw] += A' · B'[:, j0..j0+jw]` where `A'`/`B'` are the
/// (optionally gathered+masked) Eq. (1) views of `a`/`b` and `c` rows
/// have stride `ldc`.  `kp` is the contraction length (`idx.len()` when
/// `sel` is set, the dense `k` otherwise).
fn gemm_ab_body(
    c: &mut [f32],
    ldc: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    m: usize,
    kp: usize,
    j0: usize,
    jw: usize,
    sel: Option<(&[i32], &[f32])>,
) {
    if m == 0 || kp == 0 || jw == 0 {
        return;
    }
    let mut pack = [0.0f32; BLOCK_K * BLOCK_N];
    let mut atile = [0.0f32; MR * BLOCK_K];
    for k0 in (0..kp).step_by(BLOCK_K) {
        let k1 = (k0 + BLOCK_K).min(kp);
        let kb = k1 - k0;
        for n0 in (0..jw).step_by(BLOCK_N) {
            let nw = BLOCK_N.min(jw - n0);
            pack_b(&mut pack, b, ldb, k0, k1, j0 + n0, nw, sel.map(|(idx, _)| idx));
            let mut i = 0;
            while i < m {
                let rr = MR.min(m - i);
                let (asrc, alda, ai, ak): (&[f32], usize, usize, usize) = match sel {
                    None => (a, lda, i, k0),
                    Some((idx, mask)) => {
                        pack_a_gather(&mut atile, a, lda, i, rr, idx, mask, k0, kb);
                        (&atile[..], BLOCK_K, 0, 0)
                    }
                };
                match rr {
                    4 => micro_strips::<4>(c, ldc, i, n0, nw, asrc, alda, ai, ak, &pack, kb),
                    3 => micro_strips::<3>(c, ldc, i, n0, nw, asrc, alda, ai, ak, &pack, kb),
                    2 => micro_strips::<2>(c, ldc, i, n0, nw, asrc, alda, ai, ak, &pack, kb),
                    _ => micro_strips::<1>(c, ldc, i, n0, nw, asrc, alda, ai, ak, &pack, kb),
                }
                i += rr;
            }
        }
    }
}

/// `c[0..m, 0..jw] += a · b[rows j0..j0+jw]ᵀ` (contraction over `k`, the
/// B row length).  `rowsel` maps output columns through `idx` — the
/// pruned `dy · w[idx,:]ᵀ` kernel.  After `pack_bt` transposes the
/// panel, the inner sweep is the same `micro_ab` tile as `A·B`.
fn gemm_abt_body(
    c: &mut [f32],
    ldc: usize,
    a: &[f32],
    b: &[f32],
    ldb: usize,
    m: usize,
    k: usize,
    j0: usize,
    jw: usize,
    rowsel: Option<&[i32]>,
) {
    if m == 0 || k == 0 || jw == 0 {
        return;
    }
    let mut pack = [0.0f32; BLOCK_K * BLOCK_N];
    for k0 in (0..k).step_by(BLOCK_K) {
        let k1 = (k0 + BLOCK_K).min(k);
        let kb = k1 - k0;
        for n0 in (0..jw).step_by(BLOCK_N) {
            let nw = BLOCK_N.min(jw - n0);
            pack_bt(&mut pack, b, ldb, k0, k1, j0 + n0, nw, rowsel);
            let mut i = 0;
            while i < m {
                let rr = MR.min(m - i);
                match rr {
                    4 => micro_strips::<4>(c, ldc, i, n0, nw, a, k, i, k0, &pack, kb),
                    3 => micro_strips::<3>(c, ldc, i, n0, nw, a, k, i, k0, &pack, kb),
                    2 => micro_strips::<2>(c, ldc, i, n0, nw, a, k, i, k0, &pack, kb),
                    _ => micro_strips::<1>(c, ldc, i, n0, nw, a, k, i, k0, &pack, kb),
                }
                i += rr;
            }
        }
    }
}

/// `c[l0.., j0..] += (A')ᵀ · b[:, j0..j0+jw]`: output rows are (possibly
/// gathered+masked) A columns, accumulated over ascending `i` — the
/// weight-gradient shape.  `c` covers output rows `l0..l0+lw` (row 0 of
/// the chunk = logical row `l0`) with stride `ldc`.
fn gemm_atb_body(
    c: &mut [f32],
    ldc: usize,
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    m: usize,
    l0: usize,
    lw: usize,
    j0: usize,
    jw: usize,
    sel: Option<(&[i32], &[f32])>,
) {
    if m == 0 || lw == 0 || jw == 0 {
        return;
    }
    for n0 in (0..jw).step_by(BLOCK_N) {
        let nw = BLOCK_N.min(jw - n0);
        for i0 in (0..m).step_by(BLOCK_K) {
            let i1 = (i0 + BLOCK_K).min(m);
            let mut r0 = 0;
            while r0 < lw {
                let rr = MR.min(lw - r0);
                // resolve the A source column + mask scale per tile row
                let mut cols = [0usize; MR];
                let mut scales = [1.0f32; MR];
                for r in 0..rr {
                    match sel {
                        Some((idx, mask)) => {
                            cols[r] = idx[l0 + r0 + r] as usize;
                            scales[r] = mask[l0 + r0 + r];
                        }
                        None => cols[r] = l0 + r0 + r,
                    }
                }
                let mut s0 = 0;
                while s0 < nw {
                    let w = NR.min(nw - s0);
                    let cj = n0 + s0;
                    let bj = j0 + cj;
                    let mut acc = [[0.0f32; NR]; MR];
                    for r in 0..rr {
                        let base = (r0 + r) * ldc + cj;
                        acc[r][..w].copy_from_slice(&c[base..base + w]);
                    }
                    if w == NR {
                        for i in i0..i1 {
                            let brow: &[f32; NR] = (&b[i * ldb + bj..i * ldb + bj + NR])
                                .try_into()
                                .expect("NR-wide B row segment");
                            for r in 0..rr {
                                let av = a[i * lda + cols[r]] * scales[r];
                                let accr = &mut acc[r];
                                for j in 0..NR {
                                    accr[j] += av * brow[j];
                                }
                            }
                        }
                    } else {
                        for i in i0..i1 {
                            let brow = &b[i * ldb + bj..i * ldb + bj + w];
                            for r in 0..rr {
                                let av = a[i * lda + cols[r]] * scales[r];
                                let accr = &mut acc[r];
                                for (j, &bv) in brow.iter().enumerate() {
                                    accr[j] += av * bv;
                                }
                            }
                        }
                    }
                    for r in 0..rr {
                        let base = (r0 + r) * ldc + cj;
                        c[base..base + w].copy_from_slice(&acc[r][..w]);
                    }
                    s0 += NR;
                }
                r0 += rr;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Public kernels
// ---------------------------------------------------------------------------

/// `c += a · b` for row-major `a [m,k]`, `b [k,n]`, `c [m,n]`.
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    match choose_split(m * k * n, m, n) {
        Split::Serial => gemm_ab_body(c, n, a, k, b, n, m, k, 0, n, None),
        Split::Rows(t) => {
            std::thread::scope(|s| {
                let mut c_rest = c;
                let mut a_rest = a;
                for (_, rows) in row_panels(m, t) {
                    let (c_chunk, c_tail) = c_rest.split_at_mut(rows * n);
                    let (a_chunk, a_tail) = a_rest.split_at(rows * k);
                    c_rest = c_tail;
                    a_rest = a_tail;
                    s.spawn(move || {
                        gemm_ab_body(c_chunk, n, a_chunk, k, b, n, rows, k, 0, n, None)
                    });
                }
            });
        }
        Split::Cols(t) => {
            col_split(c, m, n, t, |stripe, j0, jw| {
                gemm_ab_body(stripe, jw, a, k, b, n, m, k, j0, jw, None)
            });
        }
    }
}

/// `a · b` for row-major `a [m,k]`, `b [k,n]` → `[m,n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_acc(&mut c, a, b, m, k, n);
    c
}

/// Fused Eq. (1) contraction: `c += (a[:,idx]·mask) · b[idx,:]` for
/// `a [m,kfull]`, `b [kfull,n]`, `c [m,n]`.  The column gather of A and
/// row gather of B happen inside the packing step — no gathered operand
/// copies are materialized.
pub fn matmul_gathered_acc(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    kfull: usize,
    n: usize,
    idx: &[i32],
    mask: &[f32],
) {
    debug_assert_eq!(a.len(), m * kfull);
    debug_assert_eq!(b.len(), kfull * n);
    debug_assert_eq!(c.len(), m * n);
    debug_assert_eq!(idx.len(), mask.len());
    let kp = idx.len();
    match choose_split(m * kp * n, m, n) {
        Split::Serial => gemm_ab_body(c, n, a, kfull, b, n, m, kp, 0, n, Some((idx, mask))),
        Split::Rows(t) => {
            std::thread::scope(|s| {
                let mut c_rest = c;
                let mut a_rest = a;
                for (_, rows) in row_panels(m, t) {
                    let (c_chunk, c_tail) = c_rest.split_at_mut(rows * n);
                    let (a_chunk, a_tail) = a_rest.split_at(rows * kfull);
                    c_rest = c_tail;
                    a_rest = a_tail;
                    let sel = Some((idx, mask));
                    s.spawn(move || {
                        gemm_ab_body(c_chunk, n, a_chunk, kfull, b, n, rows, kp, 0, n, sel)
                    });
                }
            });
        }
        Split::Cols(t) => {
            col_split(c, m, n, t, |stripe, j0, jw| {
                gemm_ab_body(stripe, jw, a, kfull, b, n, m, kp, j0, jw, Some((idx, mask)))
            });
        }
    }
}

fn at_b_impl(
    c: &mut [f32],
    a: &[f32],
    lda: usize,
    b: &[f32],
    m: usize,
    lw: usize,
    n: usize,
    sel: Option<(&[i32], &[f32])>,
) {
    debug_assert_eq!(c.len(), lw * n);
    debug_assert_eq!(b.len(), m * n);
    match choose_split(m * lw * n, lw, n) {
        Split::Serial => gemm_atb_body(c, n, a, lda, b, n, m, 0, lw, 0, n, sel),
        Split::Rows(t) => {
            std::thread::scope(|s| {
                let mut c_rest = c;
                for (l0, rows) in row_panels(lw, t) {
                    let (c_chunk, tail) = c_rest.split_at_mut(rows * n);
                    c_rest = tail;
                    s.spawn(move || {
                        gemm_atb_body(c_chunk, n, a, lda, b, n, m, l0, rows, 0, n, sel)
                    });
                }
            });
        }
        Split::Cols(t) => {
            col_split(c, lw, n, t, |stripe, j0, jw| {
                gemm_atb_body(stripe, jw, a, lda, b, n, m, 0, lw, j0, jw, sel)
            });
        }
    }
}

/// `c += aᵀ · b` for row-major `a [m,ka]`, `b [m,n]`, `c [ka,n]` (the
/// weight-gradient shape).  Parallel panels split the *output* rows
/// (= A columns); each element accumulates over `i ∈ 0..m` in the same
/// ascending order as the serial kernel, so results are bit-identical
/// at any thread count.
pub fn matmul_at_b_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, ka: usize, n: usize) {
    debug_assert_eq!(a.len(), m * ka);
    at_b_impl(c, a, ka, b, m, ka, n, None);
}

/// `aᵀ · b` → freshly allocated `[ka,n]`.
pub fn matmul_at_b(a: &[f32], b: &[f32], m: usize, ka: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; ka * n];
    matmul_at_b_acc(&mut c, a, b, m, ka, n);
    c
}

/// Fused pruned weight-gradient kernel:
/// `c += (a[:,idx]·mask)ᵀ · b` for `a [m,kfull]`, `b [m,n]`,
/// `c [idx.len(), n]` — the compact `dwc` of `pruned_matmul_bwd`, with
/// the gather+mask applied at the A read instead of via a gathered copy.
pub fn matmul_at_b_cols_gathered_acc(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    kfull: usize,
    n: usize,
    idx: &[i32],
    mask: &[f32],
) {
    debug_assert_eq!(a.len(), m * kfull);
    debug_assert_eq!(idx.len(), mask.len());
    at_b_impl(c, a, kfull, b, m, idx.len(), n, Some((idx, mask)));
}

fn a_bt_impl(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    ldb: usize,
    m: usize,
    k: usize,
    nb: usize,
    rowsel: Option<&[i32]>,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * nb);
    match choose_split(m * k * nb, m, nb) {
        Split::Serial => gemm_abt_body(c, nb, a, b, ldb, m, k, 0, nb, rowsel),
        Split::Rows(t) => {
            std::thread::scope(|s| {
                let mut c_rest = c;
                let mut a_rest = a;
                for (_, rows) in row_panels(m, t) {
                    let (c_chunk, c_tail) = c_rest.split_at_mut(rows * nb);
                    let (a_chunk, a_tail) = a_rest.split_at(rows * k);
                    c_rest = c_tail;
                    a_rest = a_tail;
                    s.spawn(move || {
                        gemm_abt_body(c_chunk, nb, a_chunk, b, ldb, rows, k, 0, nb, rowsel)
                    });
                }
            });
        }
        Split::Cols(t) => {
            col_split(c, m, nb, t, |stripe, j0, jw| {
                gemm_abt_body(stripe, jw, a, b, ldb, m, k, j0, jw, rowsel)
            });
        }
    }
}

/// `c += a · bᵀ` for row-major `a [m,k]`, `b [nb,k]`, `c [m,nb]` (the
/// input-gradient shape: row-dot-products, contraction ascending over `k`).
pub fn matmul_a_bt_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, nb: usize) {
    debug_assert_eq!(b.len(), nb * k);
    a_bt_impl(c, a, b, k, m, k, nb, None);
}

/// `a · bᵀ` → freshly allocated `[m,nb]`.
pub fn matmul_a_bt(a: &[f32], b: &[f32], m: usize, k: usize, nb: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * nb];
    matmul_a_bt_acc(&mut c, a, b, m, k, nb);
    c
}

/// Fused pruned input-gradient kernel:
/// `c += a · b[idx,:]ᵀ` for `a [m,k]`, `b [nbfull,k]`,
/// `c [m, idx.len()]` — the compact `dxc` of `pruned_matmul_bwd`; the row
/// gather of B folds into `pack_bt` (no `gather_rows` copy).
pub fn matmul_a_bt_rows_gathered_acc(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    idx: &[i32],
) {
    a_bt_impl(c, a, b, k, m, k, idx.len(), Some(idx));
}

/// Dense dot product (accumulated in f32, matching XLA's CPU default).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Textbook triple loop — the oracle the packed kernels are pinned to.
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for l in 0..k {
                    s += a[i * k + l] * b[l * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn close(a: &[f32], b: &[f32], tol: f32) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol)
    }

    #[test]
    fn blocked_matmul_matches_naive_across_odd_shapes() {
        let mut rng = Rng::new(7);
        // shapes straddling block, MR, and NR boundaries
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 2),
            (17, 64, 9),
            (8, 65, 257),
            (130, 70, 300),
            (5, 128, 31),
            (4, 16, 16),
        ] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let want = naive(&a, &b, m, k, n);
            assert!(close(&matmul(&a, &b, m, k, n), &want, 1e-3), "({m},{k},{n})");
        }
    }

    #[test]
    fn transposed_variants_match_naive() {
        let mut rng = Rng::new(9);
        let (m, k, n) = (13, 33, 21);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(m * n, 1.0);
        // aᵀ·b vs naive on explicitly transposed a
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for l in 0..k {
                at[l * m + i] = a[i * k + l];
            }
        }
        let want = naive(&at, &b, k, m, n);
        assert!(close(&matmul_at_b(&a, &b, m, k, n), &want, 1e-3));
        // a·bᵀ vs naive on explicitly transposed b
        let c = rng.normal_vec(n * k, 1.0);
        let mut ct = vec![0.0f32; k * n];
        for j in 0..n {
            for l in 0..k {
                ct[l * n + j] = c[j * k + l];
            }
        }
        let want = naive(&a, &ct, m, k, n);
        assert!(close(&matmul_a_bt(&a, &c, m, k, n), &want, 1e-3));
    }

    #[test]
    fn acc_accumulates_on_top_of_existing() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![10.0, 10.0, 10.0, 10.0];
        matmul_acc(&mut c, &a, &b, 2, 2, 2);
        assert_eq!(c, vec![11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn gathered_kernels_match_gather_then_dense_bitwise() {
        let mut rng = Rng::new(23);
        let (m, kfull, n) = (9, 40, 37);
        let a = rng.normal_vec(m * kfull, 1.0);
        let b = rng.normal_vec(kfull * n, 1.0);
        let idx: Vec<i32> = vec![1, 4, 4, 7, 12, 31, 39, 0];
        let mask: Vec<f32> = vec![1.0, 0.5, 0.0, 1.0, 1.0, 2.0, 1.0, 1.0];
        let kp = idx.len();
        // explicit gathered operands
        let mut ag = vec![0.0f32; m * kp];
        for i in 0..m {
            for (j, (&ix, &mv)) in idx.iter().zip(&mask).enumerate() {
                ag[i * kp + j] = a[i * kfull + ix as usize] * mv;
            }
        }
        let mut bg = vec![0.0f32; kp * n];
        for (j, &ix) in idx.iter().enumerate() {
            bg[j * n..(j + 1) * n].copy_from_slice(&b[ix as usize * n..(ix as usize + 1) * n]);
        }
        // fused A·B
        let mut got = vec![0.0f32; m * n];
        matmul_gathered_acc(&mut got, &a, &b, m, kfull, n, &idx, &mask);
        assert_eq!(got, matmul(&ag, &bg, m, kp, n), "gathered A·B");
        // fused (A')ᵀ·B vs dense on the gathered operand
        let b2 = rng.normal_vec(m * n, 1.0);
        let mut got = vec![0.0f32; kp * n];
        matmul_at_b_cols_gathered_acc(&mut got, &a, &b2, m, kfull, n, &idx, &mask);
        assert_eq!(got, matmul_at_b(&ag, &b2, m, kp, n), "gathered aᵀ·b");
        // fused A·(B[idx,:])ᵀ vs dense on the gathered operand
        let a2 = rng.normal_vec(m * n, 1.0);
        let mut got = vec![0.0f32; m * kp];
        matmul_a_bt_rows_gathered_acc(&mut got, &a2, &b, m, n, &idx);
        assert_eq!(got, matmul_a_bt(&a2, &bg, m, n, kp), "gathered a·bᵀ");
    }

    #[test]
    fn degenerate_shapes_return_empty_or_zero_without_panicking() {
        let empty: Vec<f32> = vec![];
        let ones8 = vec![1.0f32; 8];
        let ones6 = vec![1.0f32; 6];
        let ones9 = vec![1.0f32; 9];
        // every kernel, every zero dimension
        assert!(matmul(&empty, &empty, 0, 5, 3).is_empty());
        assert_eq!(matmul(&empty, &empty, 4, 0, 3), vec![0.0; 12]);
        assert!(matmul(&ones8, &empty, 4, 2, 0).is_empty());
        assert_eq!(matmul_at_b(&empty, &empty, 0, 4, 3), vec![0.0; 12]);
        assert_eq!(matmul_at_b(&ones6, &ones9, 3, 2, 3), vec![3.0; 6]);
        assert!(matmul_a_bt(&empty, &empty, 0, 3, 4).is_empty());
        assert_eq!(matmul_a_bt(&ones6, &empty, 2, 3, 0), Vec::<f32>::new());
        // empty keep set: Eq. (1) with nothing kept is a zero contraction
        let idx: Vec<i32> = vec![];
        let mask: Vec<f32> = vec![];
        let x = vec![1.0f32; 4 * 6];
        let w = vec![1.0f32; 6 * 5];
        let dy = vec![1.0f32; 4 * 5];
        let mut c = vec![0.0f32; 4 * 5];
        matmul_gathered_acc(&mut c, &x, &w, 4, 6, 5, &idx, &mask);
        assert!(c.iter().all(|&v| v == 0.0));
        let mut c: Vec<f32> = vec![];
        matmul_at_b_cols_gathered_acc(&mut c, &x, &dy, 4, 6, 5, &idx, &mask);
        assert!(c.is_empty());
        let mut c: Vec<f32> = vec![];
        matmul_a_bt_rows_gathered_acc(&mut c, &dy, &w, 4, 5, &idx);
        assert!(c.is_empty());
    }

    #[test]
    fn row_panels_tile_exactly() {
        for rows in [1usize, 2, 7, 64, 129] {
            for t in 1..=8usize.min(rows) {
                let panels = row_panels(rows, t);
                assert_eq!(panels.len(), t);
                let mut next = 0;
                for (start, len) in panels {
                    assert_eq!(start, next);
                    assert!(len > 0);
                    next = start + len;
                }
                assert_eq!(next, rows);
            }
        }
    }

    #[test]
    fn all_kernels_bitwise_identical_across_thread_counts() {
        // Big enough to clear PAR_MIN_FLOPS so the parallel path engages.
        let mut rng = Rng::new(31);
        let (m, k, n) = (67, 129, 93);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let bt = rng.normal_vec(n * k, 1.0);
        let b2 = rng.normal_vec(m * n, 1.0);
        let idx: Vec<i32> = (0..k as i32).step_by(2).collect();
        let mask: Vec<f32> = idx.iter().map(|&i| 1.0 + (i % 3) as f32 * 0.25).collect();
        let run = || {
            let mut g = vec![0.0f32; m * n];
            matmul_gathered_acc(&mut g, &a, &b, m, k, n, &idx, &mask);
            let mut gat = vec![0.0f32; idx.len() * n];
            matmul_at_b_cols_gathered_acc(&mut gat, &a, &b2, m, k, n, &idx, &mask);
            let mut gbt = vec![0.0f32; m * idx.len()];
            matmul_a_bt_rows_gathered_acc(&mut gbt, &b2, &b, m, n, &idx);
            (
                matmul(&a, &b, m, k, n),
                matmul_at_b(&a, &b2, m, k, n),
                matmul_a_bt(&a, &bt, m, k, n),
                g,
                gat,
                gbt,
            )
        };
        let serial = with_gemm_threads(1, run);
        for t in [2usize, 3, 4, 7] {
            let par = with_gemm_threads(t, run);
            assert_eq!(serial.0, par.0, "matmul differs at t={t}");
            assert_eq!(serial.1, par.1, "matmul_at_b differs at t={t}");
            assert_eq!(serial.2, par.2, "matmul_a_bt differs at t={t}");
            assert_eq!(serial.3, par.3, "matmul_gathered differs at t={t}");
            assert_eq!(serial.4, par.4, "at_b_cols_gathered differs at t={t}");
            assert_eq!(serial.5, par.5, "a_bt_rows_gathered differs at t={t}");
        }
    }

    #[test]
    fn tall_skinny_column_split_is_bitwise_serial() {
        // rows < threads with a wide output: the column-panel path must
        // engage and still reproduce the serial result exactly.
        let mut rng = Rng::new(53);
        let (m, k, n) = (3, 128, 400); // 3·128·400 = 153 600 > PAR_MIN_FLOPS
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let serial = with_gemm_threads(1, || matmul(&a, &b, m, k, n));
        for t in [4usize, 8, 16] {
            let par = with_gemm_threads(t, || matmul(&a, &b, m, k, n));
            assert_eq!(serial, par, "column-split matmul differs at t={t}");
        }
        // accumulate semantics survive the stripe copy-in
        let mut c0 = rng.normal_vec(m * n, 1.0);
        let mut c1 = c0.clone();
        with_gemm_threads(1, || matmul_acc(&mut c0, &a, &b, m, k, n));
        with_gemm_threads(8, || matmul_acc(&mut c1, &a, &b, m, k, n));
        assert_eq!(c0, c1, "matmul_acc column split must seed accumulators from c");
        // aᵀ·b with few output rows (ka small), wide n
        let (m2, ka, n2) = (200, 3, 400);
        let a2 = rng.normal_vec(m2 * ka, 1.0);
        let b2 = rng.normal_vec(m2 * n2, 1.0);
        let s = with_gemm_threads(1, || matmul_at_b(&a2, &b2, m2, ka, n2));
        let p = with_gemm_threads(8, || matmul_at_b(&a2, &b2, m2, ka, n2));
        assert_eq!(s, p, "column-split matmul_at_b differs");
        // a·bᵀ with few rows, many b rows (flops above the parallel gate)
        let (m3, k3, nb3) = (2, 256, 320);
        let a3 = rng.normal_vec(m3 * k3, 1.0);
        let b3 = rng.normal_vec(nb3 * k3, 1.0);
        let s = with_gemm_threads(1, || matmul_a_bt(&a3, &b3, m3, k3, nb3));
        let p = with_gemm_threads(8, || matmul_a_bt(&a3, &b3, m3, k3, nb3));
        assert_eq!(s, p, "column-split matmul_a_bt differs");
    }

    #[test]
    fn gemm_thread_override_scopes_and_restores() {
        let global = gemm_threads();
        let inner = with_gemm_threads(3, gemm_threads);
        assert_eq!(inner, 3);
        assert_eq!(gemm_threads(), global);
    }

    #[test]
    fn available_cores_is_cached_and_positive() {
        let a = available_cores();
        let b = available_cores();
        assert!(a >= 1);
        assert_eq!(a, b);
        assert_eq!(resolve(0), a);
        assert_eq!(resolve(5), 5);
    }
}
