//! Blocked dense GEMM kernels for the native execution backend.
//!
//! The hot path of every executable role is one of three GEMM shapes —
//! `A·B`, `Aᵀ·B` (weight gradients), `A·Bᵀ` (input gradients) — over
//! row-major f32 buffers.  `matmul_acc` tiles the contraction and output
//! columns so one B panel (`BLOCK_K × BLOCK_N` ≈ 64 KiB) stays resident in
//! L1/L2 while a C row segment is swept — the cache-friendly layout that
//! makes the fig5–fig11 bench timings scale with the arithmetic actually
//! performed instead of with memory stalls.  All kernels are
//! single-threaded on purpose: the simulated worker group executes ranks
//! sequentially and charges measured wall time to per-rank `SimClock`s, so
//! per-call determinism matters more than parallel throughput.

/// Contraction-dimension tile (rows of a B panel).
const BLOCK_K: usize = 64;
/// Output-column tile (columns of a B panel).
const BLOCK_N: usize = 256;

/// `c += a · b` for row-major `a [m,k]`, `b [k,n]`, `c [m,n]`.
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for k0 in (0..k).step_by(BLOCK_K) {
        let k1 = (k0 + BLOCK_K).min(k);
        for n0 in (0..n).step_by(BLOCK_N) {
            let n1 = (n0 + BLOCK_N).min(n);
            for i in 0..m {
                let a_row = &a[i * k..(i + 1) * k];
                let c_row = &mut c[i * n + n0..i * n + n1];
                for (l, &av) in a_row.iter().enumerate().take(k1).skip(k0) {
                    if av == 0.0 {
                        continue;
                    }
                    let b_row = &b[l * n + n0..l * n + n1];
                    for (cv, bv) in c_row.iter_mut().zip(b_row) {
                        *cv += av * bv;
                    }
                }
            }
        }
    }
}

/// `a · b` for row-major `a [m,k]`, `b [k,n]` → `[m,n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_acc(&mut c, a, b, m, k, n);
    c
}

/// `aᵀ · b` for row-major `a [m,ka]`, `b [m,n]` → `[ka,n]` (the
/// weight-gradient shape: both operands are walked row-contiguously).
pub fn matmul_at_b(a: &[f32], b: &[f32], m: usize, ka: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * ka);
    debug_assert_eq!(b.len(), m * n);
    let mut c = vec![0.0f32; ka * n];
    for i in 0..m {
        let a_row = &a[i * ka..(i + 1) * ka];
        let b_row = &b[i * n..(i + 1) * n];
        for (l, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let c_row = &mut c[l * n..(l + 1) * n];
            for (cv, bv) in c_row.iter_mut().zip(b_row) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// `a · bᵀ` for row-major `a [m,k]`, `b [nb,k]` → `[m,nb]` (the
/// input-gradient shape: contiguous row dot products).
pub fn matmul_a_bt(a: &[f32], b: &[f32], m: usize, k: usize, nb: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), nb * k);
    let mut c = vec![0.0f32; m * nb];
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * nb..(i + 1) * nb];
        for (j, cv) in c_row.iter_mut().enumerate() {
            *cv = dot(a_row, &b[j * k..(j + 1) * k]);
        }
    }
    c
}

/// Dense dot product (accumulated in f32, matching XLA's CPU default).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Textbook triple loop — the oracle the blocked kernels are pinned to.
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for l in 0..k {
                    s += a[i * k + l] * b[l * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn close(a: &[f32], b: &[f32], tol: f32) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol)
    }

    #[test]
    fn blocked_matmul_matches_naive_across_odd_shapes() {
        let mut rng = Rng::new(7);
        // shapes straddling the block boundaries, including non-multiples
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 64, 9), (8, 65, 257), (130, 70, 300)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let want = naive(&a, &b, m, k, n);
            assert!(close(&matmul(&a, &b, m, k, n), &want, 1e-3), "({m},{k},{n})");
        }
    }

    #[test]
    fn transposed_variants_match_naive() {
        let mut rng = Rng::new(9);
        let (m, k, n) = (13, 33, 21);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(m * n, 1.0);
        // aᵀ·b vs naive on explicitly transposed a
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for l in 0..k {
                at[l * m + i] = a[i * k + l];
            }
        }
        let want = naive(&at, &b, k, m, n);
        assert!(close(&matmul_at_b(&a, &b, m, k, n), &want, 1e-3));
        // a·bᵀ vs naive on explicitly transposed b
        let c = rng.normal_vec(n * k, 1.0);
        let mut ct = vec![0.0f32; k * n];
        for j in 0..n {
            for l in 0..k {
                ct[l * n + j] = c[j * k + l];
            }
        }
        let want = naive(&a, &ct, m, k, n);
        assert!(close(&matmul_a_bt(&a, &c, m, k, n), &want, 1e-3));
    }

    #[test]
    fn acc_accumulates_on_top_of_existing() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![10.0, 10.0, 10.0, 10.0];
        matmul_acc(&mut c, &a, &b, 2, 2, 2);
        assert_eq!(c, vec![11.0, 12.0, 13.0, 14.0]);
    }
}
