//! Blocked dense GEMM kernels for the native execution backend.
//!
//! The hot path of every executable role is one of three GEMM shapes —
//! `A·B`, `Aᵀ·B` (weight gradients), `A·Bᵀ` (input gradients) — over
//! row-major f32 buffers.  `matmul_acc` tiles the contraction and output
//! columns so one B panel (`BLOCK_K × BLOCK_N` ≈ 64 KiB) stays resident in
//! L1/L2 while a C row segment is swept — the cache-friendly layout that
//! makes the fig5–fig11 bench timings scale with the arithmetic actually
//! performed instead of with memory stalls.
//!
//! # Intra-op parallelism (and why it stays bitwise deterministic)
//!
//! Each kernel can split its work across **row panels** on scoped OS
//! threads ([`set_gemm_threads`] / `--threads`).  Every output element is
//! owned by exactly one panel and its accumulation order is identical to
//! the serial kernel's (`A·B` / `A·Bᵀ` split output rows; `Aᵀ·B` splits
//! output rows = A columns, accumulating over the shared `m` dimension in
//! the same ascending order the serial loop uses).  f32 addition is
//! deterministic for a fixed operand order, so a 1-thread and an N-thread
//! run produce **bit-identical** results — the property the trainer's
//! serial/parallel parity suite (`tests/parallel_determinism.rs`) pins.
//!
//! The rank-execution pool ([`crate::train::parallel::RankPool`]) runs its
//! workers under [`with_gemm_threads`]`(1, ..)` so rank-level and GEMM-level
//! parallelism never oversubscribe the same cores; the trainer wraps its
//! replicated single-call roles (embed/head) in
//! [`with_gemm_threads`]`(threads, ..)` so those still fan out.
//! [`set_gemm_threads`] sets the *process-wide default* for standalone
//! kernel use outside a trainer.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Contraction-dimension tile (rows of a B panel).
const BLOCK_K: usize = 64;
/// Output-column tile (columns of a B panel).
const BLOCK_N: usize = 256;
/// Below this many multiply-adds a GEMM stays serial: thread spawn costs
/// more than the arithmetic saved.
const PAR_MIN_FLOPS: usize = 1 << 17;

/// Process-wide default intra-op thread count (serial unless raised via
/// [`set_gemm_threads`]; the trainer scopes its width per call instead).
static GEMM_THREADS: AtomicUsize = AtomicUsize::new(1);

thread_local! {
    /// Per-thread override (0 = defer to the global). Rank-pool workers
    /// set 1 here so nested parallelism cannot oversubscribe.
    static GEMM_THREADS_TLS: Cell<usize> = const { Cell::new(0) };
}

/// `0` = all available cores (shared convention with `--threads`).
fn resolve(n: usize) -> usize {
    if n == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        n
    }
}

/// Set the process-wide GEMM thread count. `0` = all available cores.
/// Thread count never changes results (see module docs), only speed.
pub fn set_gemm_threads(n: usize) {
    GEMM_THREADS.store(resolve(n), Ordering::Relaxed);
}

/// Effective GEMM thread count on the calling thread.
pub fn gemm_threads() -> usize {
    let tls = GEMM_THREADS_TLS.with(|c| c.get());
    if tls != 0 {
        tls
    } else {
        GEMM_THREADS.load(Ordering::Relaxed)
    }
}

/// Run `f` with the calling thread's GEMM parallelism overridden to `n`
/// (restored on exit, panic-safe).  `0` = all available cores, matching
/// [`set_gemm_threads`]; the 0-as-defer sentinel stays internal to the
/// TLS cell.
pub fn with_gemm_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            GEMM_THREADS_TLS.with(|c| c.set(self.0));
        }
    }
    let prev = GEMM_THREADS_TLS.with(|c| c.get());
    let _restore = Restore(prev);
    GEMM_THREADS_TLS.with(|c| c.set(resolve(n)));
    f()
}

/// Threads worth using for `flops` multiply-adds over `rows` splittable
/// row panels.
fn panel_threads(flops: usize, rows: usize) -> usize {
    if flops < PAR_MIN_FLOPS {
        return 1;
    }
    gemm_threads().min(rows)
}

/// Split `rows` into `t` contiguous nearly-equal panels: `(start, len)`.
fn row_panels(rows: usize, t: usize) -> Vec<(usize, usize)> {
    let mut panels = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = (rows - start).div_ceil(t - i);
        panels.push((start, len));
        start += len;
    }
    debug_assert_eq!(start, rows);
    panels
}

/// `c += a · b` for row-major `a [m,k]`, `b [k,n]`, `c [m,n]`.
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let t = panel_threads(m * k * n, m);
    if t <= 1 {
        matmul_acc_rows(c, a, b, m, k, n);
        return;
    }
    // Row-panel split: each worker owns a disjoint C/A row slice, so every
    // row is computed by exactly the serial kernel — bitwise identical.
    std::thread::scope(|s| {
        let mut c_rest = c;
        let mut a_rest = a;
        for (_, rows) in row_panels(m, t) {
            let (c_chunk, c_tail) = c_rest.split_at_mut(rows * n);
            let (a_chunk, a_tail) = a_rest.split_at(rows * k);
            c_rest = c_tail;
            a_rest = a_tail;
            s.spawn(move || matmul_acc_rows(c_chunk, a_chunk, b, rows, k, n));
        }
    });
}

/// The serial blocked kernel body (one row panel).
fn matmul_acc_rows(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    for k0 in (0..k).step_by(BLOCK_K) {
        let k1 = (k0 + BLOCK_K).min(k);
        for n0 in (0..n).step_by(BLOCK_N) {
            let n1 = (n0 + BLOCK_N).min(n);
            for i in 0..m {
                let a_row = &a[i * k..(i + 1) * k];
                let c_row = &mut c[i * n + n0..i * n + n1];
                for (l, &av) in a_row.iter().enumerate().take(k1).skip(k0) {
                    if av == 0.0 {
                        continue;
                    }
                    let b_row = &b[l * n + n0..l * n + n1];
                    for (cv, bv) in c_row.iter_mut().zip(b_row) {
                        *cv += av * bv;
                    }
                }
            }
        }
    }
}

/// `a · b` for row-major `a [m,k]`, `b [k,n]` → `[m,n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_acc(&mut c, a, b, m, k, n);
    c
}

/// `aᵀ · b` for row-major `a [m,ka]`, `b [m,n]` → `[ka,n]` (the
/// weight-gradient shape).  Parallel panels split the *output* rows
/// (= A columns); each element accumulates over `i ∈ 0..m` in the same
/// ascending order as the serial kernel, so results are bit-identical
/// at any thread count.
pub fn matmul_at_b(a: &[f32], b: &[f32], m: usize, ka: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * ka);
    debug_assert_eq!(b.len(), m * n);
    let mut c = vec![0.0f32; ka * n];
    let t = panel_threads(m * ka * n, ka);
    if t <= 1 {
        matmul_at_b_panel(&mut c, a, b, m, 0, ka, ka, n);
        return c;
    }
    std::thread::scope(|s| {
        let mut c_rest = c.as_mut_slice();
        for (l0, rows) in row_panels(ka, t) {
            let (c_chunk, tail) = c_rest.split_at_mut(rows * n);
            c_rest = tail;
            s.spawn(move || matmul_at_b_panel(c_chunk, a, b, m, l0, l0 + rows, ka, n));
        }
    });
    c
}

/// One `aᵀ·b` output-row panel: `c_chunk` covers rows `[l0, l1)`.
fn matmul_at_b_panel(
    c_chunk: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    l0: usize,
    l1: usize,
    ka: usize,
    n: usize,
) {
    debug_assert_eq!(c_chunk.len(), (l1 - l0) * n);
    for i in 0..m {
        let a_row = &a[i * ka..(i + 1) * ka];
        let b_row = &b[i * n..(i + 1) * n];
        for l in l0..l1 {
            let av = a_row[l];
            if av == 0.0 {
                continue;
            }
            let c_row = &mut c_chunk[(l - l0) * n..(l - l0 + 1) * n];
            for (cv, bv) in c_row.iter_mut().zip(b_row) {
                *cv += av * bv;
            }
        }
    }
}

/// `a · bᵀ` for row-major `a [m,k]`, `b [nb,k]` → `[m,nb]` (the
/// input-gradient shape: contiguous row dot products).
pub fn matmul_a_bt(a: &[f32], b: &[f32], m: usize, k: usize, nb: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), nb * k);
    let mut c = vec![0.0f32; m * nb];
    let t = panel_threads(m * k * nb, m);
    if t <= 1 {
        matmul_a_bt_rows(&mut c, a, b, m, k, nb);
        return c;
    }
    std::thread::scope(|s| {
        let mut c_rest = c.as_mut_slice();
        let mut a_rest = a;
        for (_, rows) in row_panels(m, t) {
            let (c_chunk, c_tail) = c_rest.split_at_mut(rows * nb);
            let (a_chunk, a_tail) = a_rest.split_at(rows * k);
            c_rest = c_tail;
            a_rest = a_tail;
            s.spawn(move || matmul_a_bt_rows(c_chunk, a_chunk, b, rows, k, nb));
        }
    });
    c
}

/// Serial `a·bᵀ` body (one row panel).
fn matmul_a_bt_rows(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, nb: usize) {
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * nb..(i + 1) * nb];
        for (j, cv) in c_row.iter_mut().enumerate() {
            *cv = dot(a_row, &b[j * k..(j + 1) * k]);
        }
    }
}

/// Dense dot product (accumulated in f32, matching XLA's CPU default).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Textbook triple loop — the oracle the blocked kernels are pinned to.
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for l in 0..k {
                    s += a[i * k + l] * b[l * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn close(a: &[f32], b: &[f32], tol: f32) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol)
    }

    #[test]
    fn blocked_matmul_matches_naive_across_odd_shapes() {
        let mut rng = Rng::new(7);
        // shapes straddling the block boundaries, including non-multiples
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 64, 9), (8, 65, 257), (130, 70, 300)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let want = naive(&a, &b, m, k, n);
            assert!(close(&matmul(&a, &b, m, k, n), &want, 1e-3), "({m},{k},{n})");
        }
    }

    #[test]
    fn transposed_variants_match_naive() {
        let mut rng = Rng::new(9);
        let (m, k, n) = (13, 33, 21);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(m * n, 1.0);
        // aᵀ·b vs naive on explicitly transposed a
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for l in 0..k {
                at[l * m + i] = a[i * k + l];
            }
        }
        let want = naive(&at, &b, k, m, n);
        assert!(close(&matmul_at_b(&a, &b, m, k, n), &want, 1e-3));
        // a·bᵀ vs naive on explicitly transposed b
        let c = rng.normal_vec(n * k, 1.0);
        let mut ct = vec![0.0f32; k * n];
        for j in 0..n {
            for l in 0..k {
                ct[l * n + j] = c[j * k + l];
            }
        }
        let want = naive(&a, &ct, m, k, n);
        assert!(close(&matmul_a_bt(&a, &c, m, k, n), &want, 1e-3));
    }

    #[test]
    fn acc_accumulates_on_top_of_existing() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![10.0, 10.0, 10.0, 10.0];
        matmul_acc(&mut c, &a, &b, 2, 2, 2);
        assert_eq!(c, vec![11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn row_panels_tile_exactly() {
        for rows in [1usize, 2, 7, 64, 129] {
            for t in 1..=8usize.min(rows) {
                let panels = row_panels(rows, t);
                assert_eq!(panels.len(), t);
                let mut next = 0;
                for (start, len) in panels {
                    assert_eq!(start, next);
                    assert!(len > 0);
                    next = start + len;
                }
                assert_eq!(next, rows);
            }
        }
    }

    #[test]
    fn all_kernels_bitwise_identical_across_thread_counts() {
        // Big enough to clear PAR_MIN_FLOPS so the parallel path engages.
        let mut rng = Rng::new(31);
        let (m, k, n) = (67, 129, 93);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let bt = rng.normal_vec(n * k, 1.0);
        let b2 = rng.normal_vec(m * n, 1.0);
        let serial = with_gemm_threads(1, || {
            (
                matmul(&a, &b, m, k, n),
                matmul_at_b(&a, &b2, m, k, n),
                matmul_a_bt(&a, &bt, m, k, n),
            )
        });
        for t in [2usize, 3, 4, 7] {
            let par = with_gemm_threads(t, || {
                (
                    matmul(&a, &b, m, k, n),
                    matmul_at_b(&a, &b2, m, k, n),
                    matmul_a_bt(&a, &bt, m, k, n),
                )
            });
            assert_eq!(serial.0, par.0, "matmul differs at t={t}");
            assert_eq!(serial.1, par.1, "matmul_at_b differs at t={t}");
            assert_eq!(serial.2, par.2, "matmul_a_bt differs at t={t}");
        }
    }

    #[test]
    fn gemm_thread_override_scopes_and_restores() {
        let global = gemm_threads();
        let inner = with_gemm_threads(3, gemm_threads);
        assert_eq!(inner, 3);
        assert_eq!(gemm_threads(), global);
    }
}
