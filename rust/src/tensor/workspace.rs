//! Reusable scratch-buffer arena for the native compute path.
//!
//! Every native-backend call needs a handful of intermediate buffers
//! (layernorm x̂/rstd, packed qkv, attention probabilities, compact
//! pruned-GEMM gradients, …).  Allocating them fresh per call puts a
//! `malloc`/`free` pair — and a page-fault-cold buffer — on the critical
//! path of every layer of every simulated rank, every iteration.  A
//! [`Workspace`] turns that into pointer churn: buffers are `take`n for
//! the duration of one use and `give`n back, so a warmed-up workspace
//! services a steady-state training step without touching the allocator.
//!
//! Ownership model (deliberately simple, no lifetimes):
//!
//! * [`Workspace::take`] pops the best-fitting free buffer (smallest
//!   capacity that holds `len`; the largest available otherwise), resizes
//!   it to `len`, and **zero-fills** it — callers get the same
//!   `vec![0.0; len]` semantics the old code had, so kernel results never
//!   depend on what the buffer held before (a determinism requirement:
//!   `--threads 1` and `--threads N` runs interleave workspace reuse
//!   differently).
//! * [`Workspace::give`] returns a buffer to the free list.  *Any*
//!   `Vec<f32>` is accepted, not just ones that came from `take` — the
//!   trainer feeds merged per-rank partials back to the rank's workspace,
//!   which is how output buffers get recycled across iterations.
//! * Buffers that escape (moved into a returned [`crate::tensor::Tensor`]
//!   and never given back) are simply lost to the arena; the next `take`
//!   of that size allocates again.  The trainer's recycling keeps that
//!   from happening in the steady state.
//!
//! A workspace is deliberately **not** `Sync`: each simulated rank owns
//! one (the trainer holds `Vec<Mutex<Workspace>>`, one slot per rank) and
//! the coordinator thread uses a thread-local via `Runtime::call`.
//! Allocation counters ([`Workspace::alloc_count`]) let tests pin the
//! zero-alloc steady-state property.

/// Arena of growable `f32` scratch buffers.  See module docs.
#[derive(Debug, Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
    takes: u64,
    allocs: u64,
    hwm_bytes: usize,
}

impl Workspace {
    pub const fn new() -> Workspace {
        Workspace { free: Vec::new(), takes: 0, allocs: 0, hwm_bytes: 0 }
    }

    /// Pop the best-fitting free buffer for `len` elements (smallest
    /// sufficient capacity; the largest available otherwise), counting
    /// an allocation when nothing on the free list is big enough.
    fn pop_best(&mut self, len: usize) -> Vec<f32> {
        self.takes += 1;
        let mut best: Option<usize> = None;
        for (i, buf) in self.free.iter().enumerate() {
            let cap = buf.capacity();
            best = match best {
                None => Some(i),
                Some(j) => {
                    let bj = self.free[j].capacity();
                    let better = if cap >= len {
                        bj < len || cap < bj // smallest sufficient wins
                    } else {
                        bj < len && cap > bj // else largest insufficient
                    };
                    Some(if better { i } else { j })
                }
            };
        }
        let v = match best {
            Some(i) => self.free.swap_remove(i),
            None => Vec::new(),
        };
        if v.capacity() < len {
            self.allocs += 1;
        }
        v
    }

    /// Check out a zero-filled buffer of exactly `len` elements.
    /// Reuses the best-fitting free buffer; allocates only when nothing
    /// on the free list is large enough.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.pop_best(len);
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Check out a buffer of exactly `len` elements with **unspecified**
    /// contents — stale data from an earlier use may remain.  Only for
    /// slots that are provably overwritten in full before any read (the
    /// trainer's per-block gradient placeholders); anything whose
    /// contents could reach a result must use [`Workspace::take`], whose
    /// zero-fill is what keeps results independent of reuse history.
    pub fn take_unfilled(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.pop_best(len);
        if v.len() > len {
            v.truncate(len);
        } else {
            v.resize(len, 0.0); // only the grown tail is written
        }
        v
    }

    /// Return a buffer to the free list (its contents are dead but left
    /// in place — [`Workspace::take`] re-zeroes on checkout).  Accepts
    /// any `Vec<f32>`, including ones that never came from this
    /// workspace — that is how the trainer recycles output buffers.
    pub fn give(&mut self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        self.free.push(v);
        self.hwm_bytes = self.hwm_bytes.max(self.retained_bytes());
    }

    /// [`Workspace::give`] for a tensor's backing buffer.
    pub fn give_tensor(&mut self, t: crate::tensor::Tensor) {
        self.give(t.data);
    }

    /// How many `take` calls had to fall through to the allocator.
    pub fn alloc_count(&self) -> u64 {
        self.allocs
    }

    /// Total `take` calls serviced.
    pub fn take_count(&self) -> u64 {
        self.takes
    }

    /// Bytes currently parked on the free list.
    pub fn retained_bytes(&self) -> usize {
        self.free.iter().map(|b| b.capacity() * 4).sum()
    }

    /// Peak of [`Workspace::retained_bytes`] ever observed — arenas only
    /// grow under `take`/`give`, so without [`Workspace::shrink_to`] this
    /// is also the current footprint after any transient large shape.
    pub fn hwm_bytes(&self) -> usize {
        self.hwm_bytes
    }

    /// Drop parked buffers, largest first, until the free list fits in
    /// `budget_bytes`.  The ledger calls this after re-shard/transition
    /// events so a transient large shape (a one-off migration slice, a
    /// pre-transition E-wide buffer) does not permanently inflate a
    /// rank's real footprint.  Checked-out buffers are unaffected; the
    /// high-water mark is kept (it records history, not state).  Returns
    /// the bytes freed.
    pub fn shrink_to(&mut self, budget_bytes: usize) -> usize {
        self.free.sort_by_key(|b| b.capacity());
        let mut freed = 0;
        while self.retained_bytes() > budget_bytes {
            match self.free.pop() {
                Some(b) => freed += b.capacity() * 4,
                None => break,
            }
        }
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_reuses() {
        let mut ws = Workspace::new();
        let mut a = ws.take(16);
        assert_eq!(a, vec![0.0; 16]);
        a.iter_mut().for_each(|v| *v = 7.0);
        ws.give(a);
        let b = ws.take(8);
        assert_eq!(b, vec![0.0; 8], "reused buffer must be re-zeroed");
        ws.give(b);
        assert_eq!(ws.alloc_count(), 1, "second take must reuse the first buffer");
        assert_eq!(ws.take_count(), 2);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let mut ws = Workspace::new();
        let big = ws.take(100);
        let small = ws.take(10);
        ws.give(big);
        ws.give(small);
        let v = ws.take(10);
        assert!(v.capacity() < 100, "should pick the small buffer, got {}", v.capacity());
        ws.give(v);
        // asking for more than anything held grows exactly one buffer
        let before = ws.alloc_count();
        let w = ws.take(1000);
        assert_eq!(ws.alloc_count(), before + 1);
        ws.give(w);
    }

    #[test]
    fn steady_state_take_give_never_allocates() {
        let mut ws = Workspace::new();
        // warm with the shape set
        for &n in &[64usize, 128, 256] {
            let v = ws.take(n);
            ws.give(v);
        }
        let warm = ws.alloc_count();
        for _ in 0..100 {
            let a = ws.take(256);
            let b = ws.take(64);
            ws.give(a);
            ws.give(b);
        }
        assert_eq!(ws.alloc_count(), warm, "steady-state reuse must not allocate");
    }

    #[test]
    fn take_unfilled_reuses_without_touching_contents() {
        let mut ws = Workspace::new();
        let mut a = ws.take(32);
        a.iter_mut().for_each(|v| *v = 9.0);
        ws.give(a);
        // shrinking checkout keeps the stale prefix (contents unspecified)
        let b = ws.take_unfilled(16);
        assert_eq!(b.len(), 16);
        assert_eq!(ws.alloc_count(), 1, "must reuse, not allocate");
        ws.give(b);
        // growing checkout zero-fills only the tail beyond the stale part
        let c = ws.take_unfilled(32);
        assert_eq!(c.len(), 32);
        assert!(c[16..].iter().all(|&v| v == 0.0), "grown tail must be zeroed");
        ws.give(c);
        // a plain take after unfilled use is still fully zeroed
        let d = ws.take(32);
        assert_eq!(d, vec![0.0; 32]);
    }

    #[test]
    fn foreign_buffers_are_absorbed() {
        let mut ws = Workspace::new();
        ws.give(vec![1.0f32; 512]);
        let v = ws.take(512);
        assert_eq!(ws.alloc_count(), 0);
        assert!(v.iter().all(|&x| x == 0.0));
        // zero-capacity buffers are dropped, not parked
        ws.give(Vec::new());
        assert_eq!(ws.retained_bytes(), 0);
    }

    #[test]
    fn hwm_records_the_peak_and_shrink_to_enforces_a_budget() {
        let mut ws = Workspace::new();
        assert_eq!(ws.hwm_bytes(), 0);
        // a transient large shape inflates the arena …
        let big = ws.take(1000);
        let small = ws.take(10);
        ws.give(big);
        ws.give(small);
        let peak = ws.retained_bytes();
        assert!(peak >= 1010 * 4);
        assert_eq!(ws.hwm_bytes(), peak);
        // … and shrink_to drops the largest buffers first
        let freed = ws.shrink_to(64);
        assert!(freed >= 1000 * 4, "freed {freed}");
        assert!(ws.retained_bytes() <= 64);
        assert_eq!(ws.hwm_bytes(), peak, "hwm records history, not state");
        // shrink_to(0) empties the free list entirely
        ws.shrink_to(0);
        assert_eq!(ws.retained_bytes(), 0);
        // the arena still works afterwards
        let v = ws.take(8);
        assert_eq!(v, vec![0.0; 8]);
    }
}
