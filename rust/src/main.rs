//! flextp CLI — the Layer-3 coordinator entrypoint.
//!
//! Subcommands:
//!   train               run a training job (strategy, stragglers, model …)
//!   inspect-artifacts   list a model's executables and shapes
//!   bench-comm          compare migration primitives at given sizes
//!   pretest             print the SEMI cost-function fit for a model
//!
//! All options are `--key value` (see `config::apply_overrides`). Example:
//!
//!   flextp train --model vit-tiny --strategy semi --chi 4 --epochs 3

use anyhow::{bail, Context, Result};

use flextp::cluster::Clocks;
use flextp::collectives::{cost::CostModel, Comm};
use flextp::config::{apply_overrides, parse_kv_args, RunCfg};
use flextp::train::trainer::Trainer;
use flextp::util::table::TextTable;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, kv) = parse_kv_args(&args)?;
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&kv),
        "inspect-artifacts" => cmd_inspect(&kv),
        "bench-comm" => cmd_bench_comm(&kv),
        "pretest" => cmd_pretest(&kv),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try: flextp help)"),
    }
}

fn print_help() {
    println!(
        "flextp — flexible workload control for heterogeneous tensor parallelism\n\
         \n\
         USAGE: flextp <command> [--key value ...]\n\
         \n\
         COMMANDS\n\
           train                train a model under a balancing strategy\n\
           inspect-artifacts    list executables in a model's artifact set\n\
           bench-comm           compare broadcast-reduce vs scatter-gather\n\
           pretest              print the SEMI cost-function fit\n\
         \n\
         COMMON OPTIONS\n\
           --model NAME         model preset (vit-tiny|vit-s|vit-m|vit-100m)\n\
           --backend B          native (default, pure Rust) | pjrt\n\
                                (pjrt needs --features pjrt + make artifacts)\n\
           --artifacts DIR      artifacts root (default: artifacts)\n\
           --strategy S         baseline|zero-rd|zero-pri|zero-pridiff-e|\n\
                                zero-pridiff-r|mig|semi\n\
           --imputation P       zero|average|same\n\
           --mig-policy P       broadcast-reduce|scatter-gather\n\
           --chi X              one round-robin straggler at skewness X\n\
           --chis A,B,..        fixed per-rank skewness list\n\
           --gamma G            force a uniform pruning ratio\n\
           --lambda N           force the MIG group size (Fig. 11)\n\
           --emulate-wall       really sleep (χ-1)·t on stragglers\n\
           --threads N          parallel rank-execution threads\n\
                                (0 = all cores, 1 = serial; for a fixed\n\
                                plan results are bitwise identical at any\n\
                                N; env default: FLEXTP_THREADS)\n\
           --epochs/--iters/--lr/--momentum/--seed ...\n"
    );
}

fn build_cfg(kv: &std::collections::BTreeMap<String, String>) -> Result<RunCfg> {
    let mut cfg = RunCfg::new("vit-tiny");
    apply_overrides(&mut cfg, kv)?;
    Ok(cfg)
}

fn cmd_train(kv: &std::collections::BTreeMap<String, String>) -> Result<()> {
    let cfg = build_cfg(kv)?;
    let strategy = cfg.balancer.strategy.name();
    println!(
        "flextp train: model={} strategy={} epochs={} iters={}",
        cfg.model, strategy, cfg.train.epochs, cfg.train.iters_per_epoch
    );
    let mut t = Trainer::new(cfg)?;
    println!(
        "loaded {} ({} params total, e={} workers, platform={}, threads={})",
        t.model().name,
        t.model().params_total,
        t.model().e,
        t.rt.platform(),
        t.threads(),
    );
    t.warmup_and_pretest()?;
    for epoch in 0..t.cfg.train.epochs {
        t.run_epoch(epoch)?;
        let e = t.report.epochs.last().unwrap();
        println!(
            "epoch {:>3}: RT(sim)={:.3}s wall={:.1}s loss={:.4} eval={:.4} \
             acc={:.1}% comm={} pruned={} migrated={}",
            epoch,
            e.rt_sim_s,
            e.rt_wall_s,
            e.train_loss,
            e.eval_loss,
            100.0 * e.acc,
            flextp::util::fmt_bytes(e.comm_bytes),
            e.pruned_cols,
            e.migrated_cols,
        );
    }
    println!("{}", t.report.summary());
    let out = std::path::PathBuf::from("bench_out")
        .join(format!("train_{}_{}.json", t.model().name, strategy));
    t.report.save_json(&out).context("saving report")?;
    println!("report: {}", out.display());
    Ok(())
}

fn cmd_inspect(kv: &std::collections::BTreeMap<String, String>) -> Result<()> {
    let cfg = build_cfg(kv)?;
    let man = flextp::runtime::Manifest::load_or_synthesize(&cfg.model_dir(), &cfg.model)?;
    println!(
        "model {}: hs={} depth={} heads={} e={} bs={} seq={} params={}",
        man.model.name, man.model.hs, man.model.depth, man.model.heads,
        man.model.e, man.model.bs, man.model.seq, man.model.params_total
    );
    let mut t = TextTable::new("executables", &["name", "role", "inputs", "outputs"]);
    for ex in &man.executables {
        t.row(&[
            ex.name.clone(),
            ex.role.clone(),
            ex.inputs.len().to_string(),
            ex.outputs.len().to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "buckets: {:?}",
        man.buckets.iter().map(|b| (&b.name, b.gamma)).collect::<Vec<_>>()
    );
    println!("mig buckets (ffl cols): {:?}", man.mig_buckets);
    Ok(())
}

fn cmd_bench_comm(kv: &std::collections::BTreeMap<String, String>) -> Result<()> {
    let cfg = build_cfg(kv)?;
    let cost = CostModel::from_net(cfg.net);
    let e = 8;
    let mut t = TextTable::new(
        "migration primitive cost (simulated, seconds)",
        &["bytes", "broadcast(tree)", "scatter(flat)", "reduce(tree)", "gather(flat)"],
    );
    for mb in [1usize, 4, 16, 64] {
        let bytes = mb * 1024 * 1024;
        let peers: Vec<usize> = (1..e).collect();
        let (mut c, mut k) = (Comm::new(cost), Clocks::new(e));
        c.broadcast(&mut k, 0, &peers, bytes);
        let tb = k.now(0);
        let (mut c2, mut k) = (Comm::new(cost), Clocks::new(e));
        c2.scatter(&mut k, 0, &peers, bytes);
        let ts = k.now(0);
        let (mut c3, mut k) = (Comm::new(cost), Clocks::new(e));
        c3.reduce(&mut k, 0, &peers, bytes);
        let tr = k.now(0);
        let (mut c4, mut k) = (Comm::new(cost), Clocks::new(e));
        c4.gather(&mut k, 0, &peers, bytes);
        let tg = k.now(0);
        t.row(&[
            flextp::util::fmt_bytes(bytes as u64),
            format!("{tb:.6}"),
            format!("{ts:.6}"),
            format!("{tr:.6}"),
            format!("{tg:.6}"),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_pretest(kv: &std::collections::BTreeMap<String, String>) -> Result<()> {
    let cfg = build_cfg(kv)?;
    let mut t = Trainer::new(cfg)?;
    t.warmup_and_pretest()?;
    let c = &t.costs;
    println!("SEMI cost functions (model {}):", t.model().name);
    println!("  Ω₁  (alloc)          = {:.3e} s", c.omega1_s);
    println!("  Ω₂  (extract/col)    = {:.3e} s", c.omega2_per_col);
    println!("  Φ₁  (comm base)      = {:.3e} s", c.phi1_base_s);
    println!("  Φ₁  (comm/col)       = {:.3e} s", c.phi1_per_col);
    println!("  Φ₂  (remote/col)     = {:.3e} s", c.phi2_per_col);
    for cols in [8.0, 32.0, 128.0] {
        println!(
            "  Φ₁({cols:>4}) = {:.3e}s   Ω₂({cols:>4}) = {:.3e}s",
            c.phi1(cols),
            c.omega2(cols)
        );
    }
    Ok(())
}
