//! flextp CLI — the Layer-3 coordinator entrypoint.
//!
//! Subcommands:
//!   train               run a training job (strategy, stragglers, model …)
//!   rank                one TP rank process (re-exec'd by `train --transport tcp`)
//!   sweep               run a scenario × strategy matrix (BENCH_scenarios.json)
//!   trace               attribution report from an exported span trace
//!   inspect-artifacts   list a model's executables and shapes
//!   bench-comm          compare migration primitives at given sizes
//!   pretest             print the SEMI cost-function fit for a model
//!
//! All options are `--key value` (see `config::apply_overrides`). Examples:
//!
//!   flextp train --model vit-tiny --strategy semi --chi 4 --epochs 3
//!   flextp train --strategy semi --replan online \
//!       --scenario "burst:r2@x4:iters10-40,markov:r*@x2:p0.2-0.4"
//!   flextp sweep --preset smoke

use anyhow::{bail, Context, Result};

use flextp::cluster::Clocks;
use flextp::collectives::{cost::CostModel, Comm};
use flextp::config::{apply_overrides, parse_kv_args, RunCfg};
use flextp::train::trainer::Trainer;
use flextp::util::table::TextTable;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, kv) = parse_kv_args(&args)?;
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&kv),
        "rank" => cmd_rank(&kv),
        "sweep" => cmd_sweep(&kv),
        "trace" => cmd_trace(&pos, &kv),
        "inspect-artifacts" => cmd_inspect(&kv),
        "bench-comm" => cmd_bench_comm(&kv),
        "pretest" => cmd_pretest(&kv),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try: flextp help)"),
    }
}

fn print_help() {
    println!(
        "flextp — flexible workload control for heterogeneous tensor parallelism\n\
         \n\
         USAGE: flextp <command> [--key value ...]\n\
         \n\
         COMMANDS\n\
           train                train a model under a balancing strategy\n\
           rank                 one TP rank process (spawned internally by\n\
                                'train --transport tcp'; not for direct use)\n\
           sweep                scenario × strategy matrix → BENCH_scenarios.json\n\
           trace                per-rank/per-phase attribution from an\n\
                                exported trace (flextp trace report FILE)\n\
           inspect-artifacts    list executables in a model's artifact set\n\
           bench-comm           compare broadcast-reduce vs scatter-gather\n\
           pretest              print the SEMI cost-function fit\n\
         \n\
         COMMON OPTIONS\n\
           --model NAME         model preset (vit-tiny|vit-s|vit-m|vit-100m)\n\
           --backend B          native (default, pure Rust) | pjrt\n\
                                (pjrt needs --features pjrt + make artifacts)\n\
           --artifacts DIR      artifacts root (default: artifacts)\n\
           --strategy S         baseline|zero-rd|zero-pri|zero-pridiff-e|\n\
                                zero-pridiff-r|mig|semi\n\
           --imputation P       zero|average|same\n\
           --mig-policy P       broadcast-reduce|scatter-gather\n\
           --chi X              one round-robin straggler at skewness X\n\
           --chis A,B,..        fixed per-rank skewness list\n\
           --scenario SPEC      iteration-granular contention trace, e.g.\n\
                                \"burst:r2@x4:iters10-40,markov:r*@x2:p0.2-0.4\"\n\
                                (kinds: burst|tenant|ramp|step|pulse|markov;\n\
                                also seed:N, chimax:X, preset:NAME, and\n\
                                preempt:iterN — sweep kills + resumes there)\n\
                                worker churn: join:rN@iterK, leave:rN@iterK,\n\
                                fail:rN@iterK — the run re-shards in-process\n\
                                to the largest E' ≤ live workers dividing\n\
                                hs and heads, at the same global iteration;\n\
                                memory faults: memsqueeze:rN@iterK:xF shrinks\n\
                                rank N's capacity by fraction F, oom:rN@iterK\n\
                                forces a hard OOM (evicts through the churn\n\
                                path, or a typed error when --churn false)\n\
           --churn B            true (default): act on scenario churn\n\
                                events; false: fixed-E baseline that rides\n\
                                out the scenario at its starting width\n\
           --scenario-file F    scenario from a DSL or JSON file\n\
           --replan M           iter (default) | epoch (static per-epoch) |\n\
                                online (EWMA drift-triggered mid-epoch replans)\n\
           --time-model T       measured (default) | modeled (deterministic\n\
                                FLOP-model SimClock — reproducible sims)\n\
           --timeline           per-iteration χ/T_i/RT dump in the report JSON\n\
         \n\
         TRACING (DESIGN.md §17)\n\
           --trace              record per-rank phase spans (compute with\n\
                                χ, comm wait vs transfer, replans,\n\
                                migration, churn/mem/ckpt events); zero\n\
                                observer effect — losses/SimClocks/\n\
                                CommStats are bitwise identical with it\n\
                                on or off.  Exports trace.jsonl +\n\
                                Perfetto trace.json and prints the\n\
                                attribution table after the run\n\
           --trace-out DIR      trace export directory (default\n\
                                bench_out/trace); an unwritable path is\n\
                                a typed warning, never a mid-epoch panic\n\
           --trace-ring N       per-rank span ring capacity (default\n\
                                65536; oldest spans drop first and the\n\
                                drop count is reported, never silent)\n\
           --ctl-hi/--ctl-lo/--ctl-cooldown/--ctl-alpha-fast/--ctl-alpha-slow\n\
                                online-controller drift thresholds\n\
           --gamma G            force a uniform pruning ratio\n\
           --lambda N           force the MIG group size (Fig. 11)\n\
           --emulate-wall       really sleep (χ-1)·t on stragglers\n\
           --threads N          parallel rank-execution threads\n\
                                (0 = all cores, 1 = serial; for a fixed\n\
                                plan results are bitwise identical at any\n\
                                N; env default: FLEXTP_THREADS)\n\
           --epochs/--iters/--lr/--momentum/--seed ...\n\
         \n\
         MEMORY BUDGETS (DESIGN.md §16)\n\
           --mem-cap BYTES      per-rank capacity (suffixes: K/M/G or\n\
                                KiB/MiB/GiB; default: 2× the rank's full\n\
                                modeled footprint, MiB-aligned)\n\
           --mem-cap-rN BYTES   override one rank's capacity (repeatable)\n\
           --mem-recompute      always run activation checkpointing\n\
                                (recompute-in-backward); otherwise it is\n\
                                a per-rank fallback when an iteration\n\
                                would not fit\n\
         \n\
         TRANSPORT (DESIGN.md §15)\n\
           --transport T        inproc (default: ranks are in-process\n\
                                buffer slots) | tcp (ranks are OS\n\
                                processes over localhost TCP; bitwise\n\
                                identical simulated metrics — only wall\n\
                                time differs)\n\
           --transport-timeout-ms N\n\
                                coordinator read deadline before a\n\
                                stalled rank surfaces as a typed Timeout\n\
                                (default 10000)\n\
           --rank-exe PATH      binary to re-exec as 'flextp rank'\n\
                                (default: FLEXTP_RANK_EXE, then this\n\
                                binary itself)\n\
         \n\
         CHECKPOINT / ELASTIC RESUME (DESIGN.md §13)\n\
           --ckpt-dir DIR       write atomic .flexckpt snapshots here\n\
           --ckpt-every N       snapshot every N iterations (0 = off)\n\
           --resume PATH        continue from a snapshot file or the\n\
                                newest one in a checkpoint directory;\n\
                                same config + worker count resumes\n\
                                BITWISE identically to an uninterrupted\n\
                                run\n\
           --stop-after N       simulate preemption: stop (and snapshot,\n\
                                if --ckpt-dir is set) after iteration N\n\
           --e N                elastic resume target: re-shard the saved\n\
                                state over N workers (N must divide hs\n\
                                and heads; native backend only)\n\
           --e-embed/--e-attn/--e-mlp/--e-head N\n\
                                per-component TP degrees: the component\n\
                                runs over the rank prefix 0..N instead of\n\
                                all E workers (N must divide the\n\
                                component's own granularity; native\n\
                                backend only)\n\
           --degrees auto       pick the per-component degree vector from\n\
                                the initial chi profile and pretest cost\n\
                                fits (explicit --e-* flags win)\n\
         \n\
         SWEEP OPTIONS\n\
           --preset P           smoke (CI, 2×2) | bursty | churn (live\n\
                                elastic vs fixed-E baselines under worker\n\
                                fail/join) | mem (capacity squeeze + hard\n\
                                OOM; typed faults become \"error\" rows) |\n\
                                finegrained (mixed per-component degrees\n\
                                vs uniform-E under a heavy-tail rank)\n\
           --scenarios S        \"label=dsl;label2=dsl\" matrix rows\n\
           --strategies S       \"semi@online,semi@epoch,baseline\" columns;\n\
                                further @-segments compose in any order:\n\
                                elasticity (semi@online@fixed-e2 ignores\n\
                                churn events and forces --e 2, ...@live\n\
                                re-shards — the default), transport\n\
                                (...@tcp runs the cell over rank\n\
                                processes), and degrees (...@dega2m2 pins\n\
                                --e-attn 2 --e-mlp 2, ...@degauto lets\n\
                                the balancer pick)\n\
           --rank-exe PATH      binary for @tcp cells' rank processes\n\
           --trace B            true (default): trace each cell and embed\n\
                                its phase-time breakdown (compute/wait/\n\
                                xfer/replan/mig + straggler attribution)\n\
                                as a 'phases' object per cell\n\
           --out FILE           output path (default BENCH_scenarios.json)\n"
    );
}

/// The rank-process entrypoint (`--transport tcp` re-execs this binary
/// as `flextp rank --rank i --e E --connect HOST:PORT --timeout-ms T`).
/// Never prints to stdout (output belongs to the coordinator); any
/// transport error exits nonzero so the coordinator's liveness probe
/// reports a typed `PeerDied`.
fn cmd_rank(kv: &std::collections::BTreeMap<String, String>) -> Result<()> {
    let get = |k: &str| -> Result<&String> {
        kv.get(k).ok_or_else(|| anyhow::anyhow!("flextp rank: missing --{k}"))
    };
    let rank: usize = get("rank")?.parse().context("rank")?;
    let e: usize = get("e")?.parse().context("e")?;
    let connect = get("connect")?;
    let timeout_ms: u64 = kv
        .get("timeout-ms")
        .map(|v| v.parse().context("timeout-ms"))
        .transpose()?
        .unwrap_or(flextp::collectives::transport::RANK_IDLE_TIMEOUT_MS);
    match flextp::collectives::transport::rank_serve(rank, e, connect, timeout_ms) {
        Ok(()) => Ok(()),
        Err(err) => {
            eprintln!("flextp rank {rank}/{e}: {err}");
            std::process::exit(1);
        }
    }
}

fn build_cfg(kv: &std::collections::BTreeMap<String, String>) -> Result<RunCfg> {
    let mut cfg = RunCfg::new("vit-tiny");
    apply_overrides(&mut cfg, kv)?;
    Ok(cfg)
}

/// Where a traced run exports to: `--trace-out`, else bench_out/trace.
fn trace_out_dir(cfg: &RunCfg) -> std::path::PathBuf {
    cfg.train
        .trace_out
        .clone()
        .unwrap_or_else(|| std::path::PathBuf::from("bench_out").join("trace"))
}

fn cmd_train(kv: &std::collections::BTreeMap<String, String>) -> Result<()> {
    let cfg = build_cfg(kv)?;
    if cfg.train.trace {
        // probe --trace-out up front: an unwritable path is a typed
        // warning (TraceError::Unwritable), never a panic mid-epoch —
        // the run proceeds traced and export re-warns at the end
        if let Err(e) = flextp::trace::validate_out(&trace_out_dir(&cfg)) {
            eprintln!("warning: {e}; training continues, trace export will be skipped");
        }
    }
    let strategy = cfg.balancer.strategy.name();
    println!(
        "flextp train: model={} strategy={} epochs={} iters={}",
        cfg.model, strategy, cfg.train.epochs, cfg.train.iters_per_epoch
    );
    let resume = cfg.train.resume.clone();
    let mut t = match &resume {
        Some(path) => {
            let t = Trainer::resume_from(cfg, path)
                .with_context(|| format!("resuming from {}", path.display()))?;
            println!(
                "resumed from {} at iteration {} ({} epoch(s) already recorded)",
                path.display(),
                t.giter(),
                t.report.epochs.len(),
            );
            t
        }
        None => Trainer::new(cfg)?,
    };
    println!(
        "loaded {} ({} params total, e={} workers, platform={}, threads={})",
        t.model().name,
        t.model().params_total,
        t.model().e,
        t.rt.platform(),
        t.threads(),
    );
    let stop = t.cfg.train.stop_after;
    let report = t.run_to(stop)?;
    if !t.is_complete() {
        // simulated preemption: persist a final snapshot so `--resume`
        // picks up exactly here (skipped when the periodic saver just
        // wrote this very cursor)
        if let Some(dir) = t.cfg.train.ckpt_dir.clone() {
            let path = dir.join(flextp::checkpoint::ckpt_filename(t.giter()));
            let every = t.cfg.train.ckpt_every as u64;
            if every == 0 || t.giter() % every != 0 || !path.exists() {
                t.save_checkpoint(&path)?;
            }
            println!(
                "stopped after iteration {} (preempted); resume with --resume {}",
                t.giter(),
                path.display()
            );
        } else {
            println!(
                "stopped after iteration {} (no --ckpt-dir: state not persisted)",
                t.giter()
            );
        }
    }
    for e in &report.epochs {
        println!(
            "epoch {:>3}: RT(sim)={:.3}s wall={:.1}s loss={:.4} eval={:.4} \
             acc={:.1}% comm={} pruned={} migrated={} replans={} chi_max={:.1}",
            e.epoch,
            e.rt_sim_s,
            e.rt_wall_s,
            e.train_loss,
            e.eval_loss,
            100.0 * e.acc,
            flextp::util::fmt_bytes(e.comm_bytes),
            e.pruned_cols,
            e.migrated_cols,
            e.replans,
            e.chi_max,
        );
    }
    println!("{}", report.summary());
    let out = std::path::PathBuf::from("bench_out")
        .join(format!("train_{}_{}.json", t.model().name, strategy));
    report.save_json(&out).context("saving report")?;
    println!("report: {}", out.display());
    if let Some(tr) = &t.tracer {
        let tr = tr.lock().expect("tracer lock");
        if tr.spans_on() {
            let attr = flextp::trace::report::Attribution::from_spans(tr.merged());
            print!("{}", attr.render());
            if tr.dropped() > 0 {
                println!(
                    "trace: {} span(s) dropped at --trace-ring capacity (raise --trace-ring)",
                    tr.dropped()
                );
            }
            match flextp::trace::export::write_outputs(&tr, &trace_out_dir(&t.cfg)) {
                Ok((jsonl, perfetto)) => {
                    println!("trace: {} (JSONL; flextp trace report {})", jsonl.display(), jsonl.display());
                    println!("trace: {} (Perfetto; open at https://ui.perfetto.dev)", perfetto.display());
                }
                Err(e) => eprintln!("warning: {e}; trace not exported"),
            }
        }
    }
    Ok(())
}

/// `flextp trace report <trace.jsonl>` — parse an exported JSONL trace
/// and print the per-rank/per-phase attribution tables with the
/// straggler verdict per epoch.
fn cmd_trace(pos: &[String], kv: &std::collections::BTreeMap<String, String>) -> Result<()> {
    let sub = pos.get(1).map(String::as_str).unwrap_or("report");
    if sub != "report" {
        bail!("unknown trace subcommand '{sub}' (try: flextp trace report <trace.jsonl>)");
    }
    let path = pos
        .get(2)
        .cloned()
        .or_else(|| kv.get("in").cloned())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "flextp trace report: missing trace file \
                 (e.g. flextp trace report bench_out/trace/trace.jsonl)"
            )
        })?;
    let path = std::path::PathBuf::from(path);
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
    let spans = flextp::trace::export::parse_jsonl(&text, &path)?;
    println!("{}: {} span(s)", path.display(), spans.len());
    print!("{}", flextp::trace::report::Attribution::from_spans(spans.iter()).render());
    Ok(())
}

fn cmd_sweep(kv: &std::collections::BTreeMap<String, String>) -> Result<()> {
    use flextp::bench::sweep;
    // reject typos up front (cmd_train gets this from apply_overrides)
    const KNOWN: [&str; 11] = [
        "preset", "scenarios", "strategies", "model", "epochs", "iters",
        "eval-iters", "seed", "time-model", "rank-exe", "trace",
    ];
    for k in kv.keys() {
        if k != "out" && !KNOWN.contains(&k.as_str()) {
            bail!("unknown sweep option --{k} (known: --out, {})",
                  KNOWN.map(|k| format!("--{k}")).join(", "));
        }
    }
    let preset = kv.get("preset").map(String::as_str).unwrap_or("smoke");
    let mut spec = sweep::SweepSpec::preset(preset)?;
    if let Some(s) = kv.get("scenarios") {
        spec.scenarios = sweep::parse_scenarios(s)?;
        spec.name = "custom".to_string();
    }
    if let Some(s) = kv.get("strategies") {
        spec.cells = s
            .split(',')
            .filter(|x| !x.trim().is_empty())
            .map(|x| sweep::parse_cell(x.trim()))
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(v) = kv.get("model") {
        spec.model = v.clone();
    }
    if let Some(v) = kv.get("epochs") {
        spec.epochs = v.parse().context("epochs")?;
    }
    if let Some(v) = kv.get("iters") {
        spec.iters = v.parse().context("iters")?;
    }
    if let Some(v) = kv.get("eval-iters") {
        spec.eval_iters = v.parse().context("eval-iters")?;
    }
    if let Some(v) = kv.get("seed") {
        spec.seed = v.parse().context("seed")?;
    }
    if let Some(v) = kv.get("time-model") {
        spec.time_model = flextp::config::TimeModel::parse(v)?;
    }
    if let Some(v) = kv.get("rank-exe") {
        spec.rank_exe = Some(std::path::PathBuf::from(v));
    }
    if let Some(v) = kv.get("trace") {
        spec.trace = v.parse().context("trace")?;
    }
    println!(
        "flextp sweep: preset={} model={} {} scenario(s) × {} strategy cell(s), \
         epochs={} iters={} time-model={}",
        spec.name,
        spec.model,
        spec.scenarios.len(),
        spec.cells.len(),
        spec.epochs,
        spec.iters,
        spec.time_model.name(),
    );
    let report = sweep::run_sweep(&spec)?;
    println!("{}", report.render());
    let out = std::path::PathBuf::from(
        kv.get("out").map(String::as_str).unwrap_or("BENCH_scenarios.json"),
    );
    report.save(&out)?;
    println!("\nreport: {}", out.display());
    Ok(())
}

fn cmd_inspect(kv: &std::collections::BTreeMap<String, String>) -> Result<()> {
    let cfg = build_cfg(kv)?;
    let man = flextp::runtime::Manifest::load_or_synthesize(&cfg.model_dir(), &cfg.model)?;
    println!(
        "model {}: hs={} depth={} heads={} e={} bs={} seq={} params={}",
        man.model.name, man.model.hs, man.model.depth, man.model.heads,
        man.model.e, man.model.bs, man.model.seq, man.model.params_total
    );
    let mut t = TextTable::new("executables", &["name", "role", "inputs", "outputs"]);
    for ex in &man.executables {
        t.row(&[
            ex.name.clone(),
            ex.role.clone(),
            ex.inputs.len().to_string(),
            ex.outputs.len().to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "buckets: {:?}",
        man.buckets.iter().map(|b| (&b.name, b.gamma)).collect::<Vec<_>>()
    );
    println!("mig buckets (ffl cols): {:?}", man.mig_buckets);
    Ok(())
}

fn cmd_bench_comm(kv: &std::collections::BTreeMap<String, String>) -> Result<()> {
    let cfg = build_cfg(kv)?;
    let cost = CostModel::from_net(cfg.net);
    let e = 8;
    let mut t = TextTable::new(
        "migration primitive cost (simulated, seconds)",
        &["bytes", "broadcast(tree)", "scatter(flat)", "reduce(tree)", "gather(flat)"],
    );
    for mb in [1usize, 4, 16, 64] {
        let bytes = mb * 1024 * 1024;
        let peers: Vec<usize> = (1..e).collect();
        let (mut c, mut k) = (Comm::new(cost), Clocks::new(e));
        c.broadcast(&mut k, 0, &peers, bytes);
        let tb = k.now(0);
        let (mut c2, mut k) = (Comm::new(cost), Clocks::new(e));
        c2.scatter(&mut k, 0, &peers, bytes);
        let ts = k.now(0);
        let (mut c3, mut k) = (Comm::new(cost), Clocks::new(e));
        c3.reduce(&mut k, 0, &peers, bytes);
        let tr = k.now(0);
        let (mut c4, mut k) = (Comm::new(cost), Clocks::new(e));
        c4.gather(&mut k, 0, &peers, bytes);
        let tg = k.now(0);
        t.row(&[
            flextp::util::fmt_bytes(bytes as u64),
            format!("{tb:.6}"),
            format!("{ts:.6}"),
            format!("{tr:.6}"),
            format!("{tg:.6}"),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_pretest(kv: &std::collections::BTreeMap<String, String>) -> Result<()> {
    let cfg = build_cfg(kv)?;
    let mut t = Trainer::new(cfg)?;
    t.warmup_and_pretest()?;
    let c = &t.costs;
    println!("SEMI cost functions (model {}):", t.model().name);
    println!("  Ω₁  (alloc)          = {:.3e} s", c.omega1_s);
    println!("  Ω₂  (extract/col)    = {:.3e} s", c.omega2_per_col);
    println!("  Φ₁  (comm base)      = {:.3e} s", c.phi1_base_s);
    println!("  Φ₁  (comm/col)       = {:.3e} s", c.phi1_per_col);
    println!("  Φ₂  (remote/col)     = {:.3e} s", c.phi2_per_col);
    for cols in [8.0, 32.0, 128.0] {
        println!(
            "  Φ₁({cols:>4}) = {:.3e}s   Ω₂({cols:>4}) = {:.3e}s",
            c.phi1(cols),
            c.omega2(cols)
        );
    }
    Ok(())
}
