//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (executable names, files, input/output shapes+dtypes,
//! pruning-bucket metadata).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => bail!("unknown dtype '{s}'"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    pub file: String,
    pub role: String,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// Per-component TP degrees (fine-grained tensor parallelism,
/// DESIGN.md §18).  Each degree is the size of that component's rank
/// group; a group is always the **rank prefix** `0..d` of the global
/// worker set, so sub-group collectives reuse the global binomial tree
/// (prefix membership is closed under `children_of`).  Every degree must
/// divide the component's own contraction granularity: attention needs
/// `d | hs` *and* `d | heads` (whole heads per worker), embed/MLP/head
/// only slice hs-granular panels.  The default — every degree equal to
/// the worker count `e` — is classic uniform 1D TP and is
/// behavior-identical to the pre-fine-grained engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Degrees {
    pub embed: usize,
    pub attn: usize,
    pub mlp: usize,
    pub head: usize,
}

impl Degrees {
    /// Classic uniform TP: every component sharded over all `e` workers.
    pub fn uniform(e: usize) -> Degrees {
        Degrees { embed: e, attn: e, mlp: e, head: e }
    }

    /// True when every component runs at the global degree — the fast
    /// path that keeps uniform runs bitwise identical to the historic
    /// engine.
    pub fn is_uniform(&self, e: usize) -> bool {
        *self == Degrees::uniform(e)
    }

    /// `[embed, attn, mlp, head]` — the serialization order used by the
    /// checkpoint meta and the sweep cell tag.
    pub fn as_array(&self) -> [usize; 4] {
        [self.embed, self.attn, self.mlp, self.head]
    }

    pub fn from_array(v: [usize; 4]) -> Degrees {
        Degrees { embed: v[0], attn: v[1], mlp: v[2], head: v[3] }
    }
}

impl std::fmt::Display for Degrees {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "e{}a{}m{}h{}", self.embed, self.attn, self.mlp, self.head)
    }
}

/// Static model/parallelism facts (mirrors python ModelCfg).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub hs: usize,
    pub depth: usize,
    pub heads: usize,
    pub e: usize,
    pub bs: usize,
    pub classes: usize,
    pub seq: usize,
    pub seq0: usize,
    pub pd: usize,
    pub hsl: usize,
    pub hl: usize,
    pub hd: usize,
    pub ffl: usize,
    pub params_total: usize,
    pub params_per_worker: usize,
    /// Per-component TP group sizes.  `hsl`/`hl` derive from
    /// `degrees.attn`, `ffl` from `degrees.mlp`; ranks `>= degrees.c`
    /// hold component `c`'s shard slots but never compute with them.
    pub degrees: Degrees,
}

/// A pruning bucket: γ plus the static keep sizes it compiles to.
#[derive(Debug, Clone)]
pub struct Bucket {
    pub name: String,
    pub gamma: f64,
    pub keep_hs: usize,
    pub keep_ffl: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelInfo,
    /// ascending γ (g00 first)
    pub buckets: Vec<Bucket>,
    /// ascending receiver-slice bucket sizes (over ffl)
    pub mig_buckets: Vec<usize>,
    pub executables: Vec<ExecSpec>,
}

impl Manifest {
    /// Synthesize the manifest for a built-in preset (no artifacts needed
    /// — the native backend's path; see [`crate::runtime::presets`]).
    pub fn for_model(name: &str) -> Result<Manifest> {
        crate::runtime::presets::synthesize(name)
    }

    /// Prefer `model_dir/manifest.json` when compiled artifacts exist,
    /// falling back to preset synthesis — the single fallback policy the
    /// runtime, CLI, and benches share.
    pub fn load_or_synthesize(model_dir: &Path, model: &str) -> Result<Manifest> {
        let mpath = model_dir.join("manifest.json");
        if mpath.exists() {
            Self::load(&mpath).with_context(|| format!("loading manifest for '{model}'"))
        } else {
            Self::for_model(model)
        }
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let m = j.get("model")?;
        let e = m.get("e")?.usize()?;
        // lenient: manifests compiled before fine-grained TP carry no
        // degree vector — they are uniform by construction
        let degrees = match m.opt("degrees") {
            None => Degrees::uniform(e),
            Some(d) => Degrees {
                embed: d.get("embed")?.usize()?,
                attn: d.get("attn")?.usize()?,
                mlp: d.get("mlp")?.usize()?,
                head: d.get("head")?.usize()?,
            },
        };
        let model = ModelInfo {
            name: m.get("name")?.str()?.to_string(),
            hs: m.get("hs")?.usize()?,
            depth: m.get("depth")?.usize()?,
            heads: m.get("heads")?.usize()?,
            e,
            bs: m.get("bs")?.usize()?,
            classes: m.get("classes")?.usize()?,
            seq: m.get("seq")?.usize()?,
            seq0: m.get("seq0")?.usize()?,
            pd: m.get("pd")?.usize()?,
            hsl: m.get("hsl")?.usize()?,
            hl: m.get("hl")?.usize()?,
            hd: m.get("hd")?.usize()?,
            ffl: m.get("ffl")?.usize()?,
            params_total: m.get("params_total")?.usize()?,
            params_per_worker: m.get("params_per_worker")?.usize()?,
            degrees,
        };
        let mut buckets = Vec::new();
        for b in j.get("buckets")?.arr()? {
            buckets.push(Bucket {
                name: b.get("name")?.str()?.to_string(),
                gamma: b.get("gamma")?.num()?,
                keep_hs: b.get("keep_hs")?.usize()?,
                keep_ffl: b.get("keep_ffl")?.usize()?,
            });
        }
        buckets.sort_by(|a, b| a.gamma.partial_cmp(&b.gamma).unwrap());
        let mut mig_buckets: Vec<usize> = j
            .get("mig_buckets")?
            .arr()?
            .iter()
            .map(|v| v.usize())
            .collect::<Result<_>>()?;
        mig_buckets.sort_unstable();
        let mut executables = Vec::new();
        for e in j.get("executables")?.arr()? {
            let args = |key: &str| -> Result<Vec<ArgSpec>> {
                e.get(key)?
                    .arr()?
                    .iter()
                    .map(|a| {
                        Ok(ArgSpec {
                            name: a.get("name")?.str()?.to_string(),
                            dims: a.get("dims")?.dims()?,
                            dtype: Dtype::parse(a.get("dtype")?.str()?)?,
                        })
                    })
                    .collect()
            };
            executables.push(ExecSpec {
                name: e.get("name")?.str()?.to_string(),
                file: e.get("file")?.str()?.to_string(),
                role: e.get("role")?.str()?.to_string(),
                inputs: args("inputs")?,
                outputs: args("outputs")?,
            });
        }
        Ok(Manifest { model, buckets, mig_buckets, executables })
    }

    pub fn exec(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("no executable '{name}'"))
    }

    /// Smallest bucket whose γ satisfies the demand (round UP so the
    /// straggler never prunes less than Eq.(1) requires). γ=0 → g00.
    pub fn bucket_for_gamma(&self, gamma: f64) -> &Bucket {
        self.buckets
            .iter()
            .find(|b| b.gamma >= gamma - 1e-9)
            .unwrap_or_else(|| self.buckets.last().expect("no buckets"))
    }

    /// Smallest migration bucket that fits `cols` receiver-slice columns.
    pub fn mig_bucket_for(&self, cols: usize) -> Option<usize> {
        self.mig_buckets.iter().copied().find(|&kb| kb >= cols)
            .or(self.mig_buckets.last().copied())
    }

    /// Executable name helpers (naming contract with aot.py).
    pub fn attn_name(&self, dir: &str, bucket: &str) -> String {
        format!("attn_{dir}_{bucket}")
    }

    pub fn mlp_name(&self, dir: &str, b1: &str, b2: &str) -> String {
        if b1 == b2 {
            format!("mlp_{dir}_{b1}")
        } else {
            format!("mlp_{dir}_{b1}_{b2}")
        }
    }

    pub fn mig_name(&self, dir: &str, kb: usize) -> String {
        format!("mlp_mig_{dir}_k{kb}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest() -> &'static str {
        r#"{
          "model": {"name":"t","hs":32,"depth":1,"heads":4,"e":4,"bs":2,
                    "classes":10,"seq":17,"seq0":16,"pd":48,"hsl":8,"hl":1,
                    "hd":8,"ffl":32,"params_total":1000,"params_per_worker":300,
                    "img":16,"patch":4,"chans":3,"mlp_ratio":4},
          "buckets": [
            {"name":"g00","gamma":0,"keep_hs":32,"keep_ffl":32},
            {"name":"g50","gamma":0.5,"keep_hs":16,"keep_ffl":16},
            {"name":"g88","gamma":0.875,"keep_hs":8,"keep_ffl":8}
          ],
          "mig_buckets": [8, 16],
          "executables": [
            {"name":"attn_fwd_g00","file":"attn_fwd_g00.hlo.txt","role":"attn_fwd",
             "inputs":[{"name":"x","dims":[2,17,32],"dtype":"f32"}],
             "outputs":[{"name":"y","dims":[2,17,32],"dtype":"f32"}]}
          ]
        }"#
    }

    #[test]
    fn parses_model_and_buckets() {
        let m = Manifest::parse(tiny_manifest()).unwrap();
        assert_eq!(m.model.hs, 32);
        assert_eq!(m.buckets.len(), 3);
        assert_eq!(m.buckets[0].name, "g00"); // sorted ascending γ
        // pre-fine-grained manifests carry no degree vector: uniform
        assert_eq!(m.model.degrees, Degrees::uniform(4));
        assert!(m.model.degrees.is_uniform(m.model.e));
    }

    #[test]
    fn parses_explicit_degree_vector() {
        let text = tiny_manifest().replace(
            r#""e":4,"#,
            r#""e":4,"degrees":{"embed":4,"attn":2,"mlp":2,"head":4},"#,
        );
        let m = Manifest::parse(&text).unwrap();
        let d = m.model.degrees;
        assert_eq!(d, Degrees { embed: 4, attn: 2, mlp: 2, head: 4 });
        assert!(!d.is_uniform(4));
        assert_eq!(d.as_array(), [4, 2, 2, 4]);
        assert_eq!(Degrees::from_array(d.as_array()), d);
        assert_eq!(d.to_string(), "e4a2m2h4");
    }

    #[test]
    fn bucket_rounding_never_under_prunes() {
        let m = Manifest::parse(tiny_manifest()).unwrap();
        assert_eq!(m.bucket_for_gamma(0.0).name, "g00");
        assert_eq!(m.bucket_for_gamma(0.3).name, "g50");
        assert_eq!(m.bucket_for_gamma(0.5).name, "g50");
        assert_eq!(m.bucket_for_gamma(0.51).name, "g88");
        assert_eq!(m.bucket_for_gamma(0.99).name, "g88"); // saturates
    }

    #[test]
    fn mig_bucket_fits() {
        let m = Manifest::parse(tiny_manifest()).unwrap();
        assert_eq!(m.mig_bucket_for(5), Some(8));
        assert_eq!(m.mig_bucket_for(8), Some(8));
        assert_eq!(m.mig_bucket_for(9), Some(16));
        assert_eq!(m.mig_bucket_for(99), Some(16)); // saturates to largest
    }

    #[test]
    fn naming_contract() {
        let m = Manifest::parse(tiny_manifest()).unwrap();
        assert_eq!(m.attn_name("fwd", "g50"), "attn_fwd_g50");
        assert_eq!(m.mlp_name("bwd", "g50", "g50"), "mlp_bwd_g50");
        assert_eq!(m.mlp_name("fwd", "g00", "g50"), "mlp_fwd_g00_g50");
        assert_eq!(m.mig_name("fwd", 16), "mlp_mig_fwd_k16");
    }

    #[test]
    fn exec_lookup() {
        let m = Manifest::parse(tiny_manifest()).unwrap();
        assert!(m.exec("attn_fwd_g00").is_ok());
        assert!(m.exec("nope").is_err());
    }
}
