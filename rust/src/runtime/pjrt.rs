//! PJRT execution backend (`--features pjrt`): loads the AOT artifacts
//! (HLO text) produced by `python/compile/aot.py` and executes them
//! through the `xla` crate.  This is the only module that touches `xla`.
//!
//! Flow (adapted from /opt/xla-example/load_hlo):
//!   `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `client.compile` → `execute` per call.  Executables compile lazily on
//!   first use and are cached for the life of the backend, so each model
//!   variant compiles exactly once.
//!
//! The offline workspace builds this module against the vendored stub in
//! `vendor/xla` (compiles, errors at runtime); point `rust/Cargo.toml`'s
//! `xla` dependency at the real bindings to execute (DESIGN.md §8).
//!
//! Thread safety: the [`Backend`] contract is `Send + Sync` (the parallel
//! rank engine calls `execute` concurrently), so the compiled-executable
//! cache is a `Mutex<BTreeMap>` of `Arc`s; `execute` itself runs without
//! that lock.  The vendored stub's handle types are plain data and
//! satisfy the bounds.  Note the bound is *compile-time*: real PJRT
//! bindings whose client handles are `!Send`/`!Sync` will not build at
//! any `--threads` setting — wrap them (internal `Mutex` around the
//! client + an `unsafe impl Send/Sync` shim whose safety argument is that
//! every handle access is serialized) — see DESIGN.md §10.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::manifest::{ArgSpec, Dtype, ExecSpec, Manifest};
use super::{Arg, Backend, Out};
use crate::tensor::{Tensor, Workspace};

struct CompiledExec {
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT backend: client + lazily-compiled executable cache.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<CompiledExec>>>,
}

impl PjrtBackend {
    /// Load a model's artifact directory (manifest + HLO text files).
    pub fn load(model_dir: &Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(&model_dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", model_dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend {
            client,
            dir: model_dir.to_path_buf(),
            manifest,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    fn compiled(&self, spec: &ExecSpec) -> Result<Arc<CompiledExec>> {
        if let Some(c) = self.cache.lock().expect("pjrt cache poisoned").get(&spec.name) {
            return Ok(c.clone());
        }
        let path = self.dir.join(&spec.file);
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        let c = Arc::new(CompiledExec { exe });
        self.cache
            .lock()
            .expect("pjrt cache poisoned")
            .insert(spec.name.clone(), c.clone());
        Ok(c)
    }
}

impl Backend for PjrtBackend {
    // `_ws` is host-side scratch; PJRT computes on device buffers.
    fn execute(
        &self,
        spec: &ExecSpec,
        args: &[Arg],
        _ws: &mut Workspace,
    ) -> Result<(Vec<Out>, f64)> {
        let c = self.compiled(spec)?;
        // Inputs go through self-owned PjRtBuffers + execute_b: the
        // crate's literal-taking `execute` leaks its internally-created
        // input buffers (~input bytes per call — measured by
        // examples/leak_probe.rs), while buffers we create are freed by
        // PjRtBuffer::drop.  This is also the §Perf device-buffer path.
        // Buffer staging stays OUTSIDE the timed region so the SimClock
        // compute charge matches the seed's RT accounting.
        let mut buffers = Vec::with_capacity(args.len());
        for (arg, aspec) in args.iter().zip(&spec.inputs) {
            buffers.push(to_buffer(&self.client, arg, aspec)?);
        }
        let t0 = std::time::Instant::now();
        let result = c
            .exe
            .execute_b(&buffers)
            .with_context(|| format!("executing {}", spec.name))?[0][0]
            .to_literal_sync()?;
        let elapsed = t0.elapsed().as_secs_f64();
        // aot.py lowers with return_tuple=True → always a tuple.
        let elems = result.to_tuple()?;
        if elems.len() != spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                spec.name,
                elems.len(),
                spec.outputs.len()
            );
        }
        let mut outs = Vec::with_capacity(elems.len());
        for (lit, ospec) in elems.into_iter().zip(&spec.outputs) {
            outs.push(from_literal(lit, ospec)?);
        }
        Ok((outs, elapsed))
    }

    fn prepare(&self, spec: &ExecSpec) -> Result<()> {
        self.compiled(spec).map(|_| ())
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }
}

fn to_buffer(client: &xla::PjRtClient, arg: &Arg, spec: &ArgSpec) -> Result<xla::PjRtBuffer> {
    match (arg, spec.dtype) {
        (Arg::F32(t), Dtype::F32) => {
            if t.dims != spec.dims {
                bail!("input '{}' dims {:?} != manifest {:?}", spec.name, t.dims, spec.dims);
            }
            Ok(client.buffer_from_host_buffer(&t.data, &spec.dims, None)?)
        }
        (Arg::I32(v), Dtype::I32) => {
            let n: usize = spec.dims.iter().product();
            if v.len() != n {
                bail!("input '{}' len {} != manifest {:?}", spec.name, v.len(), spec.dims);
            }
            Ok(client.buffer_from_host_buffer(v, &spec.dims, None)?)
        }
        _ => bail!("input '{}': dtype mismatch", spec.name),
    }
}

fn from_literal(lit: xla::Literal, spec: &ArgSpec) -> Result<Out> {
    match spec.dtype {
        Dtype::F32 => {
            let data = lit.to_vec::<f32>()?;
            let dims = if spec.dims.is_empty() { vec![1] } else { spec.dims.clone() };
            if data.len() != dims.iter().product::<usize>() {
                bail!("output '{}': {} elems, expected {:?}", spec.name, data.len(), spec.dims);
            }
            Ok(Out::F32(Tensor::from_vec(&dims, data)))
        }
        Dtype::I32 => Ok(Out::I32(lit.to_vec::<i32>()?)),
    }
}
