//! Numeric primitives shared by the native executable implementations:
//! layernorm forward/backward, tanh-approximate GELU, row softmax /
//! log-softmax, and the pruned-GEMM dataflows of Eq. (1).
//!
//! Semantics are pinned to the JAX definitions in
//! `python/compile/model.py` and `python/compile/kernels/` — same ε, same
//! GELU constants, same zero-imputed scatter-ADD backward — so a PJRT
//! build and a native build of the same executable agree to f32 rounding.
//!
//! # Fused pruned contraction (PR 3)
//!
//! The old `pruned_matmul`/`pruned_matmul_bwd` materialized gathered
//! copies of their operands per call (`gather_cols_masked` +
//! `gather_rows`) — for the common full-width g00 bucket those are
//! *full-size* copies of the activations and weights, four of them per
//! layer per step.  The `_ws` entry points now route through the
//! gather-fused kernels in [`crate::tensor::linalg`] (the gather happens
//! inside the GEMM packing step), keep their compact gradients in a
//! reusable [`Workspace`], and special-case the identity keep so g00
//! performs plain dense GEMMs with zero copies.  The old signatures
//! remain as thin wrappers over a throwaway workspace.
//!
//! Every `_ws` function `take`s scratch from the workspace and `give`s
//! back what does not escape in its return value; returned buffers are
//! the *caller's* to give back (the vit layer recycles them, the trainer
//! recycles the buffers behind returned tensors).

use crate::tensor::linalg;
use crate::tensor::Workspace;

/// LayerNorm ε (matches `model.layernorm`).
pub const LN_EPS: f32 = 1e-5;

/// √(2/π) for the tanh-approximate GELU (shortest f32 round-trip).
pub const SQRT_2_OVER_PI: f32 = 0.797_884_6;

const GELU_C: f32 = 0.044_715;

/// Per-row layernorm residuals needed by the backward pass.
pub struct LnCache {
    /// normalized activations x̂ = (x − μ)·rstd, `[rows·cols]`
    pub xhat: Vec<f32>,
    /// 1/√(var + ε) per row
    pub rstd: Vec<f32>,
}

impl LnCache {
    /// Return the cache's buffers to a workspace.
    pub fn recycle(self, ws: &mut Workspace) {
        ws.give(self.xhat);
        ws.give(self.rstd);
    }
}

/// Row-wise layernorm: `y = x̂·g + b` over the last dimension.
///
/// Mean and variance come from a **single Welford pass** (one read of x
/// per row instead of the old two-pass mean-then-variance sweep); the
/// second pass writes x̂ and y together.
pub fn layernorm_ws(
    x: &[f32],
    g: &[f32],
    b: &[f32],
    rows: usize,
    cols: usize,
    ws: &mut Workspace,
) -> (Vec<f32>, LnCache) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(g.len(), cols);
    debug_assert_eq!(b.len(), cols);
    let mut y = ws.take(rows * cols);
    let mut xhat = ws.take(rows * cols);
    let mut rstd = ws.take(rows);
    for i in 0..rows {
        let xr = &x[i * cols..(i + 1) * cols];
        // Welford: mean and M2 in one pass
        let mut mean = 0.0f32;
        let mut m2 = 0.0f32;
        for (j, &v) in xr.iter().enumerate() {
            let d = v - mean;
            mean += d / (j + 1) as f32;
            m2 += d * (v - mean);
        }
        let var = m2 / cols as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[i] = rs;
        let xh = &mut xhat[i * cols..(i + 1) * cols];
        let yr = &mut y[i * cols..(i + 1) * cols];
        for j in 0..cols {
            let h = (xr[j] - mean) * rs;
            xh[j] = h;
            yr[j] = h * g[j] + b[j];
        }
    }
    (y, LnCache { xhat, rstd })
}

/// [`layernorm_ws`] over a throwaway workspace (tests / standalone use).
pub fn layernorm(x: &[f32], g: &[f32], b: &[f32], rows: usize, cols: usize) -> (Vec<f32>, LnCache) {
    layernorm_ws(x, g, b, rows, cols, &mut Workspace::new())
}

/// Layernorm backward: given `dy` w.r.t. the LN output, produce
/// `(dx, dg, db)`.  Standard vjp of `y = x̂·g + b` with x̂ recomputed from
/// the cache:  dx = rstd·(dx̂ − mean(dx̂) − x̂·mean(dx̂·x̂)).
pub fn layernorm_bwd_ws(
    dy: &[f32],
    cache: &LnCache,
    g: &[f32],
    rows: usize,
    cols: usize,
    ws: &mut Workspace,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dy.len(), rows * cols);
    let mut dx = ws.take(rows * cols);
    let mut dg = ws.take(cols);
    let mut db = ws.take(cols);
    let mut dxhat = ws.take(cols);
    for i in 0..rows {
        let dyr = &dy[i * cols..(i + 1) * cols];
        let xh = &cache.xhat[i * cols..(i + 1) * cols];
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for j in 0..cols {
            let dh = dyr[j] * g[j];
            dxhat[j] = dh;
            m1 += dh;
            m2 += dh * xh[j];
            dg[j] += dyr[j] * xh[j];
            db[j] += dyr[j];
        }
        m1 /= cols as f32;
        m2 /= cols as f32;
        let rs = cache.rstd[i];
        let dxr = &mut dx[i * cols..(i + 1) * cols];
        for j in 0..cols {
            dxr[j] = rs * (dxhat[j] - m1 - xh[j] * m2);
        }
    }
    ws.give(dxhat);
    (dx, dg, db)
}

/// [`layernorm_bwd_ws`] over a throwaway workspace.
pub fn layernorm_bwd(
    dy: &[f32],
    cache: &LnCache,
    g: &[f32],
    rows: usize,
    cols: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    layernorm_bwd_ws(dy, cache, g, rows, cols, &mut Workspace::new())
}

/// Tanh-approximate GELU (`jax.nn.gelu(·, approximate=True)`).
pub fn gelu(x: f32) -> f32 {
    let u = SQRT_2_OVER_PI * (x + GELU_C * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

/// Derivative of [`gelu`].
pub fn gelu_grad(x: f32) -> f32 {
    let x2 = x * x;
    let u = SQRT_2_OVER_PI * (x + GELU_C * x * x2);
    let t = u.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * x2)
}

/// Max and exp-sum of one row (the shared softmax/log-softmax reduction).
#[inline]
fn row_max_expsum(row: &[f32]) -> (f32, f32) {
    let mut mx = f32::NEG_INFINITY;
    for &v in row {
        mx = mx.max(v);
    }
    let mut sum = 0.0f32;
    for &v in row {
        sum += (v - mx).exp();
    }
    (mx, sum)
}

/// In-place row softmax with max subtraction.  Each exponential is
/// computed exactly once and stored; the row is then scaled by a single
/// hoisted `1/sum` (one divide per row, like `log_softmax_rows`'s one
/// `ln` per row).
pub fn softmax_rows(a: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(a.len(), rows * cols);
    for i in 0..rows {
        let row = &mut a[i * cols..(i + 1) * cols];
        let mut mx = f32::NEG_INFINITY;
        for &v in row.iter() {
            mx = mx.max(v);
        }
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Row log-softmax into a workspace buffer.
pub fn log_softmax_rows_ws(a: &[f32], rows: usize, cols: usize, ws: &mut Workspace) -> Vec<f32> {
    debug_assert_eq!(a.len(), rows * cols);
    let mut out = ws.take(rows * cols);
    for i in 0..rows {
        let row = &a[i * cols..(i + 1) * cols];
        let (mx, sum) = row_max_expsum(row);
        let lse = mx + sum.ln();
        let o = &mut out[i * cols..(i + 1) * cols];
        for j in 0..cols {
            o[j] = row[j] - lse;
        }
    }
    out
}

/// [`log_softmax_rows_ws`] over a throwaway workspace.
pub fn log_softmax_rows(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    log_softmax_rows_ws(a, rows, cols, &mut Workspace::new())
}

// ---------------------------------------------------------------------------
// Pruned-GEMM dataflows (kernel contract of python/compile/kernels/)
// ---------------------------------------------------------------------------

/// Gather + mask the kept contraction columns of `x [rows, kfull]` into a
/// compact `[rows, idx.len()]` buffer: `x[:, idx] * mask`.  (Reference
/// dataflow — the hot path fuses this into the GEMM packing step.)
pub fn gather_cols_masked(
    x: &[f32],
    rows: usize,
    kfull: usize,
    idx: &[i32],
    mask: &[f32],
) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * kfull);
    debug_assert_eq!(idx.len(), mask.len());
    let kp = idx.len();
    let mut out = vec![0.0f32; rows * kp];
    for i in 0..rows {
        let row = &x[i * kfull..(i + 1) * kfull];
        let o = &mut out[i * kp..(i + 1) * kp];
        for (j, (&ix, &mv)) in idx.iter().zip(mask).enumerate() {
            o[j] = row[ix as usize] * mv;
        }
    }
    out
}

/// Gather the kept contraction rows of `w [kfull, n]` → `[idx.len(), n]`.
/// (Reference dataflow — the hot path fuses this into the GEMM packing.)
pub fn gather_rows(w: &[f32], kfull: usize, n: usize, idx: &[i32]) -> Vec<f32> {
    debug_assert_eq!(w.len(), kfull * n);
    let mut out = vec![0.0f32; idx.len() * n];
    for (j, &ix) in idx.iter().enumerate() {
        out[j * n..(j + 1) * n].copy_from_slice(&w[ix as usize * n..(ix as usize + 1) * n]);
    }
    out
}

/// Scatter-ADD compact columns `src [rows, idx.len()]` into
/// `dst [rows, kfull]` at the kept positions (zero-imputed grad_input of
/// paper Fig. 2; ADD so mask-padded duplicate indices stay exact).
pub fn scatter_add_cols(dst: &mut [f32], rows: usize, kfull: usize, idx: &[i32], src: &[f32]) {
    debug_assert_eq!(dst.len(), rows * kfull);
    debug_assert_eq!(src.len(), rows * idx.len());
    let kp = idx.len();
    for i in 0..rows {
        let s = &src[i * kp..(i + 1) * kp];
        let d = &mut dst[i * kfull..(i + 1) * kfull];
        for (j, &ix) in idx.iter().enumerate() {
            d[ix as usize] += s[j];
        }
    }
}

/// Scatter-ADD compact rows `src [idx.len(), n]` into `dst [kfull, n]`
/// (zero-imputed grad_weight of paper Fig. 2, right).
pub fn scatter_add_rows(dst: &mut [f32], kfull: usize, n: usize, idx: &[i32], src: &[f32]) {
    debug_assert_eq!(dst.len(), kfull * n);
    debug_assert_eq!(src.len(), idx.len() * n);
    for (j, &ix) in idx.iter().enumerate() {
        let d = &mut dst[ix as usize * n..(ix as usize + 1) * n];
        for (dv, sv) in d.iter_mut().zip(&src[j * n..(j + 1) * n]) {
            *dv += sv;
        }
    }
}

/// Whether `(idx, mask)` selects the whole contraction unchanged — the
/// common g00 case, which skips the gather entirely.
pub fn is_identity_keep(kfull: usize, idx: &[i32], mask: &[f32]) -> bool {
    idx.len() == kfull
        && idx.iter().enumerate().all(|(j, &ix)| ix as usize == j)
        && mask.iter().all(|&m| m == 1.0)
}

/// The Layer-1 kernel contract:
/// `pruned_matmul(x[rows,kfull], w[kfull,n], idx, mask) =
///  (x[:,idx]·mask) @ w[idx,:]` — gathers fused into the GEMM packing,
/// output buffer from the workspace.
pub fn pruned_matmul_ws(
    x: &[f32],
    w: &[f32],
    rows: usize,
    kfull: usize,
    n: usize,
    idx: &[i32],
    mask: &[f32],
    ws: &mut Workspace,
) -> Vec<f32> {
    let mut y = ws.take(rows * n);
    if is_identity_keep(kfull, idx, mask) {
        linalg::matmul_acc(&mut y, x, w, rows, kfull, n);
    } else {
        linalg::matmul_gathered_acc(&mut y, x, w, rows, kfull, n, idx, mask);
    }
    y
}

/// [`pruned_matmul_ws`] over a throwaway workspace (tests / compat).
pub fn pruned_matmul(
    x: &[f32],
    w: &[f32],
    rows: usize,
    kfull: usize,
    n: usize,
    idx: &[i32],
    mask: &[f32],
) -> Vec<f32> {
    pruned_matmul_ws(x, w, rows, kfull, n, idx, mask, &mut Workspace::new())
}

/// Backward of [`pruned_matmul_ws`] w.r.t. its dense inputs, both
/// zero-imputed into full shapes:
/// `dx[:,idx] += (dy @ w[idx,:]ᵀ)·mask`, `dw[idx,:] += (x[:,idx]·mask)ᵀ @ dy`.
///
/// The compact gradients live in workspace scratch and are scattered
/// directly into the full-shape outputs; the identity-keep (g00) case
/// skips the compact stage entirely and writes the dense GEMM results
/// straight into `dx`/`dw` (bitwise-equal to scattering through an
/// identity index set).
pub fn pruned_matmul_bwd_ws(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    rows: usize,
    kfull: usize,
    n: usize,
    idx: &[i32],
    mask: &[f32],
    ws: &mut Workspace,
) -> (Vec<f32>, Vec<f32>) {
    let kp = idx.len();
    let identity = is_identity_keep(kfull, idx, mask);
    // dx = zero-impute((dy @ w[idx,:]ᵀ) · mask)
    let mut dx = ws.take(rows * kfull);
    if identity {
        linalg::matmul_a_bt_acc(&mut dx, dy, w, rows, n, kfull);
    } else {
        let mut dxc = ws.take(rows * kp);
        linalg::matmul_a_bt_rows_gathered_acc(&mut dxc, dy, w, rows, n, idx);
        for i in 0..rows {
            let row = &mut dxc[i * kp..(i + 1) * kp];
            for (v, &mv) in row.iter_mut().zip(mask) {
                *v *= mv;
            }
        }
        scatter_add_cols(&mut dx, rows, kfull, idx, &dxc);
        ws.give(dxc);
    }
    // dw = zero-impute((x[:,idx]·mask)ᵀ @ dy)
    let mut dw = ws.take(kfull * n);
    if identity {
        linalg::matmul_at_b_acc(&mut dw, x, dy, rows, kfull, n);
    } else {
        let mut dwc = ws.take(kp * n);
        linalg::matmul_at_b_cols_gathered_acc(&mut dwc, x, dy, rows, kfull, n, idx, mask);
        scatter_add_rows(&mut dw, kfull, n, idx, &dwc);
        ws.give(dwc);
    }
    (dx, dw)
}

/// [`pruned_matmul_bwd_ws`] over a throwaway workspace (tests / compat).
pub fn pruned_matmul_bwd(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    rows: usize,
    kfull: usize,
    n: usize,
    idx: &[i32],
    mask: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    pruned_matmul_bwd_ws(x, w, dy, rows, kfull, n, idx, mask, &mut Workspace::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fd_scalar<F: FnMut(f32) -> f32>(mut f: F, x: f32, eps: f32) -> f32 {
        (f(x + eps) - f(x - eps)) / (2.0 * eps)
    }

    #[test]
    fn gelu_matches_known_values_and_grad() {
        // gelu(0)=0, gelu(large)≈x, gelu(-large)≈0
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4);
        for &x in &[-2.0f32, -0.7, -0.1, 0.0, 0.3, 1.5, 3.0] {
            let fd = fd_scalar(gelu, x, 1e-3);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}: {} vs {fd}", gelu_grad(x));
        }
    }

    #[test]
    fn layernorm_rows_are_normalized() {
        let mut rng = Rng::new(3);
        let (rows, cols) = (5, 16);
        let x = rng.normal_vec(rows * cols, 2.0);
        let g = vec![1.0; cols];
        let b = vec![0.0; cols];
        let (y, cache) = layernorm(&x, &g, &b, rows, cols);
        for i in 0..rows {
            let row = &y[i * cols..(i + 1) * cols];
            let mu: f32 = row.iter().sum::<f32>() / cols as f32;
            let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / cols as f32;
            assert!(mu.abs() < 1e-4, "row {i} mean {mu}");
            assert!((var - 1.0).abs() < 1e-2, "row {i} var {var}");
            assert!(cache.rstd[i] > 0.0);
        }
    }

    #[test]
    fn welford_layernorm_matches_two_pass_reference() {
        // The single-pass Welford stats must agree with the textbook
        // two-pass mean/variance to f32 rounding.
        let mut rng = Rng::new(29);
        let (rows, cols) = (7, 33);
        let x = rng.normal_vec(rows * cols, 3.0);
        let g = rng.normal_vec(cols, 0.5);
        let b = rng.normal_vec(cols, 0.5);
        let (y, cache) = layernorm(&x, &g, &b, rows, cols);
        for i in 0..rows {
            let xr = &x[i * cols..(i + 1) * cols];
            let mu: f32 = xr.iter().sum::<f32>() / cols as f32;
            let var: f32 = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / cols as f32;
            let rs = 1.0 / (var + LN_EPS).sqrt();
            assert!(
                (cache.rstd[i] - rs).abs() <= 1e-4 * rs.abs().max(1.0),
                "row {i}: rstd {} vs two-pass {rs}",
                cache.rstd[i]
            );
            for j in 0..cols {
                let want = (xr[j] - mu) * rs * g[j] + b[j];
                assert!(
                    (y[i * cols + j] - want).abs() < 1e-3,
                    "y[{i},{j}] {} vs {want}",
                    y[i * cols + j]
                );
            }
        }
    }

    #[test]
    fn layernorm_bwd_matches_finite_differences() {
        let mut rng = Rng::new(11);
        let (rows, cols) = (3, 8);
        let x = rng.normal_vec(rows * cols, 1.0);
        let g = rng.normal_vec(cols, 0.5);
        let b = rng.normal_vec(cols, 0.5);
        let r = rng.normal_vec(rows * cols, 1.0); // cotangent
        let phi = |xv: &[f32], gv: &[f32], bv: &[f32]| -> f64 {
            let (y, _) = layernorm(xv, gv, bv, rows, cols);
            y.iter().zip(&r).map(|(a, c)| (*a as f64) * (*c as f64)).sum()
        };
        let (dx, dg, db) = layernorm_bwd(&r, &layernorm(&x, &g, &b, rows, cols).1, &g, rows, cols);
        let eps = 1e-2f32;
        for probe in 0..6 {
            let i = rng.below(rows * cols);
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (phi(&xp, &g, &b) - phi(&xm, &g, &b)) / (2.0 * eps as f64);
            assert!(
                (dx[i] as f64 - fd).abs() < 2e-2 * fd.abs().max(1.0),
                "probe {probe} dx[{i}]: {} vs {fd}",
                dx[i]
            );
        }
        for j in 0..cols {
            let mut gp = g.clone();
            gp[j] += eps;
            let mut gm = g.clone();
            gm[j] -= eps;
            let fd = (phi(&x, &gp, &b) - phi(&x, &gm, &b)) / (2.0 * eps as f64);
            assert!((dg[j] as f64 - fd).abs() < 2e-2 * fd.abs().max(1.0), "dg[{j}]");
            let mut bp = b.clone();
            bp[j] += eps;
            let mut bm = b.clone();
            bm[j] -= eps;
            let fd = (phi(&x, &g, &bp) - phi(&x, &g, &bm)) / (2.0 * eps as f64);
            assert!((db[j] as f64 - fd).abs() < 2e-2 * fd.abs().max(1.0), "db[{j}]");
        }
    }

    #[test]
    fn softmax_rows_sum_to_one_and_log_softmax_agrees() {
        let mut rng = Rng::new(5);
        let (rows, cols) = (4, 9);
        let a = rng.normal_vec(rows * cols, 3.0);
        let mut sm = a.clone();
        softmax_rows(&mut sm, rows, cols);
        let lsm = log_softmax_rows(&a, rows, cols);
        for i in 0..rows {
            let s: f32 = sm[i * cols..(i + 1) * cols].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        for (p, lp) in sm.iter().zip(&lsm) {
            assert!((p.ln() - lp).abs() < 1e-4);
        }
    }

    #[test]
    fn pruned_matmul_equals_dense_on_identity_keep() {
        let mut rng = Rng::new(8);
        let (m, k, n) = (6, 16, 10);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(k * n, 1.0);
        let idx: Vec<i32> = (0..k as i32).collect();
        let mask = vec![1.0f32; k];
        let a = pruned_matmul(&x, &w, m, k, n, &idx, &mask);
        let b = linalg::matmul(&x, &w, m, k, n);
        assert_eq!(a, b);
    }

    #[test]
    fn pruned_matmul_drops_masked_and_unkept_columns() {
        let mut rng = Rng::new(13);
        let (m, k, n) = (4, 12, 7);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(k * n, 1.0);
        let idx = [0i32, 3, 5, 5]; // duplicate padded index …
        let mask = [1.0f32, 1.0, 1.0, 0.0]; // … zeroed by the mask
        let got = pruned_matmul(&x, &w, m, k, n, &idx, &mask);
        // oracle: zero out everything but columns {0,3,5} then dense matmul
        let mut xz = vec![0.0f32; m * k];
        for i in 0..m {
            for &j in &[0usize, 3, 5] {
                xz[i * k + j] = x[i * k + j];
            }
        }
        let want = linalg::matmul(&xz, &w, m, k, n);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn fused_pruned_paths_match_gather_reference_bitwise() {
        // The fused kernels must reproduce the explicit
        // gather → dense-GEMM → scatter dataflow exactly.
        let mut rng = Rng::new(19);
        let (m, k, n) = (5, 14, 9);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(k * n, 1.0);
        let dy = rng.normal_vec(m * n, 1.0);
        let idx = [2i32, 5, 5, 9, 13];
        let mask = [1.0f32, 0.5, 0.0, 1.0, 2.0];
        let kp = idx.len();
        // forward reference
        let xg = gather_cols_masked(&x, m, k, &idx, &mask);
        let wg = gather_rows(&w, k, n, &idx);
        let want_y = linalg::matmul(&xg, &wg, m, kp, n);
        assert_eq!(pruned_matmul(&x, &w, m, k, n, &idx, &mask), want_y);
        // backward reference
        let mut dxc = linalg::matmul_a_bt(&dy, &wg, m, n, kp);
        for i in 0..m {
            for (v, &mv) in dxc[i * kp..(i + 1) * kp].iter_mut().zip(&mask) {
                *v *= mv;
            }
        }
        let mut want_dx = vec![0.0f32; m * k];
        scatter_add_cols(&mut want_dx, m, k, &idx, &dxc);
        let dwc = linalg::matmul_at_b(&xg, &dy, m, kp, n);
        let mut want_dw = vec![0.0f32; k * n];
        scatter_add_rows(&mut want_dw, k, n, &idx, &dwc);
        let (dx, dw) = pruned_matmul_bwd(&x, &w, &dy, m, k, n, &idx, &mask);
        assert_eq!(dx, want_dx);
        assert_eq!(dw, want_dw);
        // identity keep: the dense fast path must equal scattering
        // through an identity index set
        let idx_id: Vec<i32> = (0..k as i32).collect();
        let ones = vec![1.0f32; k];
        let (dx_id, dw_id) = pruned_matmul_bwd(&x, &w, &dy, m, k, n, &idx_id, &ones);
        let wg_id = gather_rows(&w, k, n, &idx_id);
        let mut want_dx = vec![0.0f32; m * k];
        let dxc_id = linalg::matmul_a_bt(&dy, &wg_id, m, n, k);
        scatter_add_cols(&mut want_dx, m, k, &idx_id, &dxc_id);
        assert_eq!(dx_id, want_dx);
        let xg_id = gather_cols_masked(&x, m, k, &idx_id, &ones);
        let dwc_id = linalg::matmul_at_b(&xg_id, &dy, m, k, n);
        let mut want_dw = vec![0.0f32; k * n];
        scatter_add_rows(&mut want_dw, k, n, &idx_id, &dwc_id);
        assert_eq!(dw_id, want_dw);
    }

    #[test]
    fn pruned_matmul_bwd_zero_imputes_and_matches_fd() {
        let mut rng = Rng::new(17);
        let (m, k, n) = (3, 10, 5);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(k * n, 1.0);
        let idx = [1i32, 4, 7, 8];
        let mask = [1.0f32; 4];
        let r = rng.normal_vec(m * n, 1.0);
        let (dx, dw) = pruned_matmul_bwd(&x, &w, &r, m, k, n, &idx, &mask);
        // pruned rows/cols are exactly zero
        for i in 0..m {
            for j in [0usize, 2, 3, 5, 6, 9] {
                assert_eq!(dx[i * k + j], 0.0);
            }
        }
        for j in [0usize, 2, 3, 5, 6, 9] {
            assert!(dw[j * n..(j + 1) * n].iter().all(|&v| v == 0.0));
        }
        // FD on a kept weight entry
        let phi = |wv: &[f32]| -> f64 {
            pruned_matmul(&x, wv, m, k, n, &idx, &mask)
                .iter()
                .zip(&r)
                .map(|(a, c)| (*a as f64) * (*c as f64))
                .sum()
        };
        let eps = 1e-2f32;
        let target = 4 * n + 2; // w[4, 2], kept
        let mut wp = w.clone();
        wp[target] += eps;
        let mut wm = w.clone();
        wm[target] -= eps;
        let fd = (phi(&wp) - phi(&wm)) / (2.0 * eps as f64);
        assert!((dw[target] as f64 - fd).abs() < 2e-2 * fd.abs().max(1.0));
    }

    #[test]
    fn empty_keep_set_yields_zero_outputs_without_panicking() {
        let (m, k, n) = (3, 6, 4);
        let x = vec![1.0f32; m * k];
        let w = vec![1.0f32; k * n];
        let dy = vec![1.0f32; m * n];
        let idx: [i32; 0] = [];
        let mask: [f32; 0] = [];
        let y = pruned_matmul(&x, &w, m, k, n, &idx, &mask);
        assert_eq!(y, vec![0.0; m * n]);
        let (dx, dw) = pruned_matmul_bwd(&x, &w, &dy, m, k, n, &idx, &mask);
        assert_eq!(dx, vec![0.0; m * k]);
        assert_eq!(dw, vec![0.0; k * n]);
        // degenerate gathers/scatters
        assert!(gather_cols_masked(&x, m, k, &idx, &mask).is_empty());
        assert!(gather_rows(&w, k, n, &idx).is_empty());
        let mut dst = vec![0.0f32; m * k];
        scatter_add_cols(&mut dst, m, k, &idx, &[]);
        assert!(dst.iter().all(|&v| v == 0.0));
        let mut dst = vec![0.0f32; k * n];
        scatter_add_rows(&mut dst, k, n, &idx, &[]);
        assert!(dst.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn workspace_steady_state_allocates_nothing() {
        let mut rng = Rng::new(37);
        let (m, k, n) = (16, 24, 12);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(k * n, 1.0);
        let dy = rng.normal_vec(m * n, 1.0);
        let idx = [0i32, 3, 8, 11, 20];
        let mask = [1.0f32; 5];
        let gains = vec![1.0f32; k];
        let biases = vec![0.0f32; k];
        let mut ws = Workspace::new();
        let run = |ws: &mut Workspace| {
            let y = pruned_matmul_ws(&x, &w, m, k, n, &idx, &mask, ws);
            let (dx, dw) = pruned_matmul_bwd_ws(&x, &w, &dy, m, k, n, &idx, &mask, ws);
            let (ln, cache) = layernorm_ws(&x, &gains, &biases, m, k, ws);
            let (da, dg, db) = layernorm_bwd_ws(&ln, &cache, &gains, m, k, ws);
            // caller recycles everything, as the vit layer does
            for v in [y, dx, dw, ln, da, dg, db] {
                ws.give(v);
            }
            cache.recycle(ws);
        };
        run(&mut ws);
        let warm = ws.alloc_count();
        for _ in 0..10 {
            run(&mut ws);
        }
        assert_eq!(ws.alloc_count(), warm, "steady-state ops must not allocate");
    }
}
