//! Native implementations of every executable role in the manifest.
//!
//! Each function reproduces, on [`crate::tensor::Tensor`] buffers, the
//! exact math of the corresponding JAX shard program in
//! `python/compile/model.py` — same layernorm ε, tanh-approximate GELU,
//! softmax attention, pruned-GEMM contraction semantics (Eq. 1), and the
//! same zero-imputed backward scatters as the Pallas kernel's custom vjp.
//! Backward roles rematerialize their forward internally (the remat
//! structure of `build_attn_bwd`/`build_mlp_bwd`), so call signatures stay
//! identical to the AOT artifacts and the trainer cannot tell the
//! backends apart.
//!
//! # Scratch discipline (PR 3)
//!
//! Every intermediate buffer — layernorm x̂/rstd, packed qkv, attention
//! probabilities, per-head panels, co-pruned FC weights, compact
//! gradients — is `take`n from the caller's [`Workspace`] and `give`n
//! back before returning, so a warmed-up workspace services steady-state
//! calls with **zero heap allocations** inside the backend.  Only the
//! declared outputs escape (moved into `Out` tensors); the trainer feeds
//! those buffers back to the per-rank workspaces after merging, closing
//! the loop.  The common full-width g00 bucket additionally skips the
//! co-pruned FC1/FC2 weight copies entirely ([`WeightView::Full`]).

use anyhow::{bail, Result};

use super::ops;
use crate::runtime::manifest::{ExecSpec, ModelInfo};
use crate::runtime::{Arg, Out};
use crate::tensor::{linalg, Tensor, Workspace};

/// Dispatch one validated call to its role implementation.
pub fn execute(
    m: &ModelInfo,
    spec: &ExecSpec,
    args: &[Arg],
    ws: &mut Workspace,
) -> Result<Vec<Out>> {
    match spec.role.as_str() {
        "embed_fwd" => embed_fwd(m, spec, args, ws),
        "embed_bwd" => embed_bwd(m, spec, args, ws),
        "attn_fwd" => attn_fwd(m, spec, args, ws),
        "attn_bwd" => attn_bwd(m, spec, args, ws),
        "mlp_fwd" => mlp_fwd(m, spec, args, ws),
        "mlp_bwd" => mlp_bwd(m, spec, args, ws),
        "head_fwdbwd" => head_fwdbwd(m, spec, args, ws),
        "head_infer" => head_infer(m, spec, args, ws),
        "mlp_mig_fwd" => mlp_mig_fwd(m, spec, args, ws),
        "mlp_mig_bwd" => mlp_mig_bwd(m, spec, args, ws),
        other => bail!(
            "native backend: unknown role '{other}' for executable '{}'",
            spec.name
        ),
    }
}

// ---------------------------------------------------------------------------
// argument / output plumbing
// ---------------------------------------------------------------------------

fn f32_arg<'a>(args: &'a [Arg<'a>], i: usize) -> Result<&'a Tensor> {
    match args.get(i) {
        Some(Arg::F32(t)) => Ok(t),
        _ => bail!("native backend: expected f32 argument {i}"),
    }
}

fn i32_arg<'a>(args: &'a [Arg<'a>], i: usize) -> Result<&'a [i32]> {
    match args.get(i) {
        Some(Arg::I32(v)) => Ok(v),
        _ => bail!("native backend: expected i32 argument {i}"),
    }
}

/// Reject out-of-range keep indices up front: `check_args` can only see
/// flattened lengths, and a bad index would otherwise abort with a
/// slice-bounds panic instead of the contract's `Err`.
fn check_idx(idx: &[i32], bound: usize, what: &str) -> Result<()> {
    for &ix in idx {
        if ix < 0 || ix as usize >= bound {
            bail!("keep index {ix} out of range for {what} (size {bound})");
        }
    }
    Ok(())
}

/// Wrap a buffer in the spec's declared output shape (scalars become `[1]`,
/// the same normalization the PJRT literal path applies).
fn out_f32(spec: &ExecSpec, i: usize, data: Vec<f32>) -> Out {
    let dims = &spec.outputs[i].dims;
    let dims = if dims.is_empty() { vec![1] } else { dims.clone() };
    Out::F32(Tensor::from_vec(&dims, data))
}

/// A weight operand that is either the caller's full buffer (identity
/// keep — no copy) or a compact co-pruned copy in workspace scratch.
enum WeightView<'a> {
    Full(&'a [f32]),
    Packed(Vec<f32>),
}

impl WeightView<'_> {
    fn as_slice(&self) -> &[f32] {
        match self {
            WeightView::Full(s) => s,
            WeightView::Packed(v) => v,
        }
    }

    fn recycle(self, ws: &mut Workspace) {
        if let WeightView::Packed(v) = self {
            ws.give(v);
        }
    }
}

// ---------------------------------------------------------------------------
// embed
// ---------------------------------------------------------------------------

fn embed_fwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg], ws: &mut Workspace) -> Result<Vec<Out>> {
    let patches = f32_arg(args, 0)?;
    let w_patch = f32_arg(args, 1)?;
    let pos = f32_arg(args, 2)?;
    let cls = f32_arg(args, 3)?;
    let (b, s0, pd, s, hs) = (m.bs, m.seq0, m.pd, m.seq, m.hs);
    let mut tok = ws.take(b * s0 * hs);
    linalg::matmul_acc(&mut tok, &patches.data, &w_patch.data, b * s0, pd, hs);
    let mut x = ws.take(b * s * hs);
    for bi in 0..b {
        let base = bi * s * hs;
        for j in 0..hs {
            x[base + j] = cls.data[j] + pos.data[j];
        }
        for t in 0..s0 {
            let dst = base + (1 + t) * hs;
            let src = (bi * s0 + t) * hs;
            let prow = &pos.data[(1 + t) * hs..(2 + t) * hs];
            for j in 0..hs {
                x[dst + j] = tok[src + j] + prow[j];
            }
        }
    }
    ws.give(tok);
    Ok(vec![out_f32(spec, 0, x)])
}

fn embed_bwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg], ws: &mut Workspace) -> Result<Vec<Out>> {
    let patches = f32_arg(args, 0)?;
    let dy = f32_arg(args, 4)?;
    let (b, s0, pd, s, hs) = (m.bs, m.seq0, m.pd, m.seq, m.hs);
    let mut dcls = ws.take(hs);
    let mut dpos = ws.take(s * hs);
    let mut dtok = ws.take(b * s0 * hs);
    for bi in 0..b {
        let base = bi * s * hs;
        for t in 0..s {
            let dyr = &dy.data[base + t * hs..base + (t + 1) * hs];
            let dp = &mut dpos[t * hs..(t + 1) * hs];
            for j in 0..hs {
                dp[j] += dyr[j];
            }
            if t == 0 {
                for j in 0..hs {
                    dcls[j] += dyr[j];
                }
            } else {
                dtok[(bi * s0 + t - 1) * hs..(bi * s0 + t) * hs].copy_from_slice(dyr);
            }
        }
    }
    let mut dw_patch = ws.take(pd * hs);
    linalg::matmul_at_b_acc(&mut dw_patch, &patches.data, &dtok, b * s0, pd, hs);
    ws.give(dtok);
    Ok(vec![
        out_f32(spec, 0, dw_patch),
        out_f32(spec, 1, dpos),
        out_f32(spec, 2, dcls),
    ])
}

// ---------------------------------------------------------------------------
// attention branch
// ---------------------------------------------------------------------------

struct AttnCore {
    xln: Vec<f32>,
    cache: ops::LnCache,
    qkv: Vec<f32>,
    /// softmaxed attention per (batch, head): `[b·hl, s·s]`
    att: Vec<f32>,
    /// merged head outputs `[b·s, hsl]`
    o: Vec<f32>,
}

impl AttnCore {
    fn recycle(self, ws: &mut Workspace) {
        ws.give(self.xln);
        self.cache.recycle(ws);
        ws.give(self.qkv);
        ws.give(self.att);
        ws.give(self.o);
    }
}

/// Copy one (batch, head)'s q/k/v `[s, hd]` panels out of the packed
/// `[b·s, 3·hsl]` qkv buffer (token layout `[3, hl, hd]`).
fn gather_qkv(
    qkv: &[f32],
    bi: usize,
    h: usize,
    s: usize,
    hd: usize,
    hsl: usize,
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
) {
    for t in 0..s {
        let base = (bi * s + t) * 3 * hsl;
        let oq = base + h * hd;
        let ok = base + hsl + h * hd;
        let ov = base + 2 * hsl + h * hd;
        q[t * hd..(t + 1) * hd].copy_from_slice(&qkv[oq..oq + hd]);
        k[t * hd..(t + 1) * hd].copy_from_slice(&qkv[ok..ok + hd]);
        v[t * hd..(t + 1) * hd].copy_from_slice(&qkv[ov..ov + hd]);
    }
}

fn attn_forward(
    m: &ModelInfo,
    x: &[f32],
    ln_g: &[f32],
    ln_b: &[f32],
    wqkv: &[f32],
    idx: &[i32],
    mask: &[f32],
    ws: &mut Workspace,
) -> AttnCore {
    let (b, s, hs, hl, hd, hsl) = (m.bs, m.seq, m.hs, m.hl, m.hd, m.hsl);
    let rows = b * s;
    let (xln, cache) = ops::layernorm_ws(x, ln_g, ln_b, rows, hs, ws);
    let qkv = ops::pruned_matmul_ws(&xln, wqkv, rows, hs, 3 * hsl, idx, mask, ws);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut att = ws.take(b * hl * s * s);
    let mut o = ws.take(rows * hsl);
    let mut q = ws.take(s * hd);
    let mut k = ws.take(s * hd);
    let mut v = ws.take(s * hd);
    let mut a = ws.take(s * s);
    let mut oh = ws.take(s * hd);
    for bi in 0..b {
        for h in 0..hl {
            gather_qkv(&qkv, bi, h, s, hd, hsl, &mut q, &mut k, &mut v);
            a.fill(0.0);
            linalg::matmul_a_bt_acc(&mut a, &q, &k, s, hd, s);
            for av in a.iter_mut() {
                *av *= scale;
            }
            ops::softmax_rows(&mut a, s, s);
            oh.fill(0.0);
            linalg::matmul_acc(&mut oh, &a, &v, s, s, hd);
            let ab = (bi * hl + h) * s * s;
            att[ab..ab + s * s].copy_from_slice(&a);
            for t in 0..s {
                let dst = (bi * s + t) * hsl + h * hd;
                o[dst..dst + hd].copy_from_slice(&oh[t * hd..(t + 1) * hd]);
            }
        }
    }
    ws.give(q);
    ws.give(k);
    ws.give(v);
    ws.give(a);
    ws.give(oh);
    AttnCore { xln, cache, qkv, att, o }
}

fn attn_fwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg], ws: &mut Workspace) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let wqkv = f32_arg(args, 3)?;
    let wo = f32_arg(args, 4)?;
    let idx = i32_arg(args, 5)?;
    let mask = f32_arg(args, 6)?;
    check_idx(idx, m.hs, "attn qkv contraction")?;
    let rows = m.bs * m.seq;
    let core = attn_forward(m, &x.data, &ln_g.data, &ln_b.data, &wqkv.data, idx, &mask.data, ws);
    let mut y = ws.take(rows * m.hs);
    linalg::matmul_acc(&mut y, &core.o, &wo.data, rows, m.hsl, m.hs);
    core.recycle(ws);
    Ok(vec![out_f32(spec, 0, y)])
}

fn attn_bwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg], ws: &mut Workspace) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let wqkv = f32_arg(args, 3)?;
    let wo = f32_arg(args, 4)?;
    let idx = i32_arg(args, 5)?;
    let mask = f32_arg(args, 6)?;
    let dy = f32_arg(args, 7)?;
    check_idx(idx, m.hs, "attn qkv contraction")?;
    let (b, s, hs, hl, hd, hsl) = (m.bs, m.seq, m.hs, m.hl, m.hd, m.hsl);
    let rows = b * s;
    let scale = 1.0 / (hd as f32).sqrt();

    // rematerialized forward
    let core = attn_forward(m, &x.data, &ln_g.data, &ln_b.data, &wqkv.data, idx, &mask.data, ws);

    // y = o @ wo
    let mut do_ = ws.take(rows * hsl);
    linalg::matmul_a_bt_acc(&mut do_, &dy.data, &wo.data, rows, hs, hsl);
    let mut dwo = ws.take(hsl * hs);
    linalg::matmul_at_b_acc(&mut dwo, &core.o, &dy.data, rows, hsl, hs);

    // per-head attention backward into dqkv
    let mut dqkv = ws.take(rows * 3 * hsl);
    let mut q = ws.take(s * hd);
    let mut k = ws.take(s * hd);
    let mut v = ws.take(s * hd);
    let mut doh = ws.take(s * hd);
    let mut dpre = ws.take(s * s);
    let mut dv = ws.take(s * hd);
    let mut datt = ws.take(s * s);
    let mut dq = ws.take(s * hd);
    let mut dk = ws.take(s * hd);
    for bi in 0..b {
        for h in 0..hl {
            gather_qkv(&core.qkv, bi, h, s, hd, hsl, &mut q, &mut k, &mut v);
            for t in 0..s {
                let src = (bi * s + t) * hsl + h * hd;
                doh[t * hd..(t + 1) * hd].copy_from_slice(&do_[src..src + hd]);
            }
            let ab = (bi * hl + h) * s * s;
            let a = &core.att[ab..ab + s * s];
            // o = att @ v
            dv.fill(0.0);
            linalg::matmul_at_b_acc(&mut dv, a, &doh, s, s, hd);
            datt.fill(0.0);
            linalg::matmul_a_bt_acc(&mut datt, &doh, &v, s, hd, s);
            // softmax backward: dpre = att ⊙ (datt − ⟨datt, att⟩_row)
            for t in 0..s {
                let ar = &a[t * s..(t + 1) * s];
                let dr = &datt[t * s..(t + 1) * s];
                let inner = linalg::dot(ar, dr);
                let dp = &mut dpre[t * s..(t + 1) * s];
                for j in 0..s {
                    dp[j] = ar[j] * (dr[j] - inner);
                }
            }
            for dv_ in dpre.iter_mut() {
                *dv_ *= scale;
            }
            dq.fill(0.0);
            linalg::matmul_acc(&mut dq, &dpre, &k, s, s, hd);
            dk.fill(0.0);
            linalg::matmul_at_b_acc(&mut dk, &dpre, &q, s, s, hd);
            for t in 0..s {
                let base = (bi * s + t) * 3 * hsl;
                dqkv[base + h * hd..base + h * hd + hd]
                    .copy_from_slice(&dq[t * hd..(t + 1) * hd]);
                dqkv[base + hsl + h * hd..base + hsl + h * hd + hd]
                    .copy_from_slice(&dk[t * hd..(t + 1) * hd]);
                dqkv[base + 2 * hsl + h * hd..base + 2 * hsl + h * hd + hd]
                    .copy_from_slice(&dv[t * hd..(t + 1) * hd]);
            }
        }
    }
    ws.give(q);
    ws.give(k);
    ws.give(v);
    ws.give(doh);
    ws.give(dpre);
    ws.give(dv);
    ws.give(datt);
    ws.give(dq);
    ws.give(dk);
    ws.give(do_);

    // pruned-GEMM backward (zero-imputed), then layernorm backward
    let (dxln, dwqkv) = ops::pruned_matmul_bwd_ws(
        &core.xln, &wqkv.data, &dqkv, rows, hs, 3 * hsl, idx, &mask.data, ws,
    );
    ws.give(dqkv);
    let (dx, dg, db) = ops::layernorm_bwd_ws(&dxln, &core.cache, &ln_g.data, rows, hs, ws);
    ws.give(dxln);
    core.recycle(ws);
    Ok(vec![
        out_f32(spec, 0, dx),
        out_f32(spec, 1, dg),
        out_f32(spec, 2, db),
        out_f32(spec, 3, dwqkv),
        out_f32(spec, 4, dwo),
    ])
}

// ---------------------------------------------------------------------------
// FFN branch
// ---------------------------------------------------------------------------

struct MlpCore<'a> {
    xln: Vec<f32>,
    cache: ops::LnCache,
    /// co-pruned FC1 weight `w1[:, idx2]·mask2`, `[hs, k2]` (the full
    /// `w1` itself on the identity keep)
    w1g: WeightView<'a>,
    /// pre-GELU activations `[rows, k2]`
    h: Vec<f32>,
    /// post-GELU activations `[rows, k2]`
    hg: Vec<f32>,
    /// pruned FC2 weight `w2[idx2,:]·mask2`, `[k2, hs]` (or full `w2`)
    w2g: WeightView<'a>,
}

impl MlpCore<'_> {
    fn recycle(self, ws: &mut Workspace) {
        ws.give(self.xln);
        self.cache.recycle(ws);
        self.w1g.recycle(ws);
        ws.give(self.h);
        ws.give(self.hg);
        self.w2g.recycle(ws);
    }
}

#[allow(clippy::too_many_arguments)]
fn mlp_forward<'a>(
    m: &ModelInfo,
    x: &[f32],
    ln_g: &[f32],
    ln_b: &[f32],
    w1: &'a [f32],
    w2: &'a [f32],
    idx1: &[i32],
    mask1: &[f32],
    idx2: &[i32],
    mask2: &[f32],
    ws: &mut Workspace,
) -> MlpCore<'a> {
    let (b, s, hs, ffl) = (m.bs, m.seq, m.hs, m.ffl);
    let rows = b * s;
    let k2 = idx2.len();
    let identity2 = ops::is_identity_keep(ffl, idx2, mask2);
    let (xln, cache) = ops::layernorm_ws(x, ln_g, ln_b, rows, hs, ws);
    // N-side co-prune of FC1: w1g = w1[:, idx2] * mask2 (skipped — no
    // copy at all — for the identity keep)
    let w1g = if identity2 {
        WeightView::Full(w1)
    } else {
        let mut buf = ws.take(hs * k2);
        for r in 0..hs {
            let row = &w1[r * ffl..(r + 1) * ffl];
            let o = &mut buf[r * k2..(r + 1) * k2];
            for (j, (&ix, &mv)) in idx2.iter().zip(mask2).enumerate() {
                o[j] = row[ix as usize] * mv;
            }
        }
        WeightView::Packed(buf)
    };
    let h = ops::pruned_matmul_ws(&xln, w1g.as_slice(), rows, hs, k2, idx1, mask1, ws);
    let mut hg = ws.take(rows * k2);
    hg.copy_from_slice(&h);
    for v in hg.iter_mut() {
        *v = ops::gelu(*v);
    }
    // K-side prune of FC2: w2g = w2[idx2, :] * mask2
    let w2g = if identity2 {
        WeightView::Full(w2)
    } else {
        let mut buf = ws.take(k2 * hs);
        for (j, (&ix, &mv)) in idx2.iter().zip(mask2).enumerate() {
            let src = &w2[ix as usize * hs..(ix as usize + 1) * hs];
            let dst = &mut buf[j * hs..(j + 1) * hs];
            for (d, sv) in dst.iter_mut().zip(src) {
                *d = sv * mv;
            }
        }
        WeightView::Packed(buf)
    };
    MlpCore { xln, cache, w1g, h, hg, w2g }
}

fn mlp_fwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg], ws: &mut Workspace) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let w1 = f32_arg(args, 3)?;
    let w2 = f32_arg(args, 4)?;
    let idx1 = i32_arg(args, 5)?;
    let mask1 = f32_arg(args, 6)?;
    let idx2 = i32_arg(args, 7)?;
    let mask2 = f32_arg(args, 8)?;
    check_idx(idx1, m.hs, "mlp fc1 contraction")?;
    check_idx(idx2, m.ffl, "mlp ffl dimension")?;
    let rows = m.bs * m.seq;
    let core = mlp_forward(
        m, &x.data, &ln_g.data, &ln_b.data, &w1.data, &w2.data, idx1, &mask1.data, idx2,
        &mask2.data, ws,
    );
    let mut y = ws.take(rows * m.hs);
    linalg::matmul_acc(&mut y, &core.hg, core.w2g.as_slice(), rows, idx2.len(), m.hs);
    core.recycle(ws);
    Ok(vec![out_f32(spec, 0, y)])
}

fn mlp_bwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg], ws: &mut Workspace) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let w1 = f32_arg(args, 3)?;
    let w2 = f32_arg(args, 4)?;
    let idx1 = i32_arg(args, 5)?;
    let mask1 = f32_arg(args, 6)?;
    let idx2 = i32_arg(args, 7)?;
    let mask2 = f32_arg(args, 8)?;
    let dy = f32_arg(args, 9)?;
    check_idx(idx1, m.hs, "mlp fc1 contraction")?;
    check_idx(idx2, m.ffl, "mlp ffl dimension")?;
    let (hs, ffl) = (m.hs, m.ffl);
    let rows = m.bs * m.seq;
    let k2 = idx2.len();
    let identity2 = ops::is_identity_keep(ffl, idx2, &mask2.data);

    let core = mlp_forward(
        m, &x.data, &ln_g.data, &ln_b.data, &w1.data, &w2.data, idx1, &mask1.data, idx2,
        &mask2.data, ws,
    );

    // y = hg @ w2g
    let mut dhg = ws.take(rows * k2);
    linalg::matmul_a_bt_acc(&mut dhg, &dy.data, core.w2g.as_slice(), rows, hs, k2);
    // dw2[idx2[j], :] += dw2g[j, :] * mask2[j]  (zero-imputed full shape);
    // on the identity keep the compact stage collapses into the output.
    let mut dw2 = ws.take(ffl * hs);
    if identity2 {
        linalg::matmul_at_b_acc(&mut dw2, &core.hg, &dy.data, rows, k2, hs);
    } else {
        let mut dw2g = ws.take(k2 * hs);
        linalg::matmul_at_b_acc(&mut dw2g, &core.hg, &dy.data, rows, k2, hs);
        for (j, (&ix, &mv)) in idx2.iter().zip(&mask2.data).enumerate() {
            let dst = &mut dw2[ix as usize * hs..(ix as usize + 1) * hs];
            for (d, sv) in dst.iter_mut().zip(&dw2g[j * hs..(j + 1) * hs]) {
                *d += sv * mv;
            }
        }
        ws.give(dw2g);
    }
    // through the GELU
    let mut dh = dhg;
    for (dv, &hv) in dh.iter_mut().zip(&core.h) {
        *dv *= ops::gelu_grad(hv);
    }
    // pruned FC1 backward w.r.t. (xln, w1g)
    let (dxln, dw1g) = ops::pruned_matmul_bwd_ws(
        &core.xln, core.w1g.as_slice(), &dh, rows, hs, k2, idx1, &mask1.data, ws,
    );
    ws.give(dh);
    // dw1[:, idx2[j]] += dw1g[:, j] * mask2[j]; identity keep → dw1g IS dw1
    let dw1 = if identity2 {
        dw1g
    } else {
        let mut dw1 = ws.take(hs * ffl);
        for r in 0..hs {
            let src = &dw1g[r * k2..(r + 1) * k2];
            let dst = &mut dw1[r * ffl..(r + 1) * ffl];
            for (j, (&ix, &mv)) in idx2.iter().zip(&mask2.data).enumerate() {
                dst[ix as usize] += src[j] * mv;
            }
        }
        ws.give(dw1g);
        dw1
    };
    let (dx, dg, db) = ops::layernorm_bwd_ws(&dxln, &core.cache, &ln_g.data, rows, hs, ws);
    ws.give(dxln);
    core.recycle(ws);
    Ok(vec![
        out_f32(spec, 0, dx),
        out_f32(spec, 1, dg),
        out_f32(spec, 2, db),
        out_f32(spec, 3, dw1),
        out_f32(spec, 4, dw2),
    ])
}

// ---------------------------------------------------------------------------
// head
// ---------------------------------------------------------------------------

struct HeadCore {
    cache: ops::LnCache,
    pooled: Vec<f32>,
    /// softmax probabilities `[b, classes]`
    probs: Vec<f32>,
    loss: f32,
    ncorrect: i32,
}

impl HeadCore {
    fn recycle(self, ws: &mut Workspace) {
        self.cache.recycle(ws);
        ws.give(self.pooled);
        ws.give(self.probs);
    }
}

fn head_forward(
    m: &ModelInfo,
    x: &[f32],
    lnf_g: &[f32],
    lnf_b: &[f32],
    w_head: &[f32],
    b_head: &[f32],
    labels: &[i32],
    ws: &mut Workspace,
) -> Result<HeadCore> {
    let (b, s, hs, cl) = (m.bs, m.seq, m.hs, m.classes);
    let rows = b * s;
    let (xln, cache) = ops::layernorm_ws(x, lnf_g, lnf_b, rows, hs, ws);
    let mut pooled = ws.take(b * hs);
    for bi in 0..b {
        pooled[bi * hs..(bi + 1) * hs].copy_from_slice(&xln[bi * s * hs..bi * s * hs + hs]);
    }
    ws.give(xln);
    let mut logits = ws.take(b * cl);
    linalg::matmul_acc(&mut logits, &pooled, w_head, b, hs, cl);
    for bi in 0..b {
        let row = &mut logits[bi * cl..(bi + 1) * cl];
        for (lv, bv) in row.iter_mut().zip(b_head) {
            *lv += bv;
        }
    }
    let logp = ops::log_softmax_rows_ws(&logits, b, cl, ws);
    let mut loss = 0.0f64;
    let mut ncorrect = 0i32;
    for bi in 0..b {
        let li = labels[bi];
        if li < 0 || li as usize >= cl {
            // the caller owns no reference to these buffers — park them
            ws.give(logits);
            ws.give(logp);
            ws.give(pooled);
            cache.recycle(ws);
            bail!("label {li} out of range [0, {cl})");
        }
        loss -= logp[bi * cl + li as usize] as f64;
        // first-occurrence argmax (jnp.argmax semantics)
        let row = &logits[bi * cl..(bi + 1) * cl];
        let mut best = 0usize;
        for (c, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = c;
            }
        }
        if best == li as usize {
            ncorrect += 1;
        }
    }
    ws.give(logits);
    let mut probs = logp;
    for p in probs.iter_mut() {
        *p = p.exp();
    }
    Ok(HeadCore {
        cache,
        pooled,
        probs,
        loss: (loss / b as f64) as f32,
        ncorrect,
    })
}

fn head_fwdbwd(
    m: &ModelInfo,
    spec: &ExecSpec,
    args: &[Arg],
    ws: &mut Workspace,
) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let lnf_g = f32_arg(args, 1)?;
    let lnf_b = f32_arg(args, 2)?;
    let w_head = f32_arg(args, 3)?;
    let b_head = f32_arg(args, 4)?;
    let labels = i32_arg(args, 5)?;
    let (b, s, hs, cl) = (m.bs, m.seq, m.hs, m.classes);
    let rows = b * s;
    let core = head_forward(
        m, &x.data, &lnf_g.data, &lnf_b.data, &w_head.data, &b_head.data, labels, ws,
    )?;

    // d(loss)/d(logits) of mean softmax-CE
    let inv_b = 1.0 / b as f32;
    let mut dlogits = ws.take(b * cl);
    dlogits.copy_from_slice(&core.probs);
    for bi in 0..b {
        dlogits[bi * cl + labels[bi] as usize] -= 1.0;
    }
    for v in dlogits.iter_mut() {
        *v *= inv_b;
    }
    let mut dw_head = ws.take(hs * cl);
    linalg::matmul_at_b_acc(&mut dw_head, &core.pooled, &dlogits, b, hs, cl);
    let mut db_head = ws.take(cl);
    for bi in 0..b {
        for (d, &v) in db_head.iter_mut().zip(&dlogits[bi * cl..(bi + 1) * cl]) {
            *d += v;
        }
    }
    let mut dpooled = ws.take(b * hs);
    linalg::matmul_a_bt_acc(&mut dpooled, &dlogits, &w_head.data, b, cl, hs);
    ws.give(dlogits);
    // only the cls-token rows receive gradient
    let mut dxln = ws.take(rows * hs);
    for bi in 0..b {
        dxln[bi * s * hs..bi * s * hs + hs].copy_from_slice(&dpooled[bi * hs..(bi + 1) * hs]);
    }
    ws.give(dpooled);
    let (dx, dg, db) = ops::layernorm_bwd_ws(&dxln, &core.cache, &lnf_g.data, rows, hs, ws);
    ws.give(dxln);
    let loss = core.loss;
    let ncorrect = core.ncorrect;
    core.recycle(ws);
    Ok(vec![
        out_f32(spec, 0, vec![loss]),
        Out::I32(vec![ncorrect]),
        out_f32(spec, 2, dx),
        out_f32(spec, 3, dg),
        out_f32(spec, 4, db),
        out_f32(spec, 5, dw_head),
        out_f32(spec, 6, db_head),
    ])
}

fn head_infer(
    m: &ModelInfo,
    spec: &ExecSpec,
    args: &[Arg],
    ws: &mut Workspace,
) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let lnf_g = f32_arg(args, 1)?;
    let lnf_b = f32_arg(args, 2)?;
    let w_head = f32_arg(args, 3)?;
    let b_head = f32_arg(args, 4)?;
    let labels = i32_arg(args, 5)?;
    let core = head_forward(
        m, &x.data, &lnf_g.data, &lnf_b.data, &w_head.data, &b_head.data, labels, ws,
    )?;
    let loss = core.loss;
    let ncorrect = core.ncorrect;
    core.recycle(ws);
    Ok(vec![out_f32(spec, 0, vec![loss]), Out::I32(vec![ncorrect])])
}

// ---------------------------------------------------------------------------
// migration receiver slices
// ---------------------------------------------------------------------------

fn mig_forward(
    m: &ModelInfo,
    x: &[f32],
    ln_g: &[f32],
    ln_b: &[f32],
    w1c: &[f32],
    kb: usize,
    ws: &mut Workspace,
) -> (Vec<f32>, Vec<f32>, ops::LnCache) {
    let rows = m.bs * m.seq;
    let (xln, cache) = ops::layernorm_ws(x, ln_g, ln_b, rows, m.hs, ws);
    let mut h = ws.take(rows * kb);
    linalg::matmul_acc(&mut h, &xln, w1c, rows, m.hs, kb);
    (xln, h, cache)
}

fn mlp_mig_fwd(
    m: &ModelInfo,
    spec: &ExecSpec,
    args: &[Arg],
    ws: &mut Workspace,
) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let w1c = f32_arg(args, 3)?;
    let w2c = f32_arg(args, 4)?;
    let kb = w1c.dims[1];
    let rows = m.bs * m.seq;
    let (xln, h, cache) = mig_forward(m, &x.data, &ln_g.data, &ln_b.data, &w1c.data, kb, ws);
    ws.give(xln);
    cache.recycle(ws);
    let mut hg = h;
    for v in hg.iter_mut() {
        *v = ops::gelu(*v);
    }
    let mut y = ws.take(rows * m.hs);
    linalg::matmul_acc(&mut y, &hg, &w2c.data, rows, kb, m.hs);
    ws.give(hg);
    Ok(vec![out_f32(spec, 0, y)])
}

fn mlp_mig_bwd(
    m: &ModelInfo,
    spec: &ExecSpec,
    args: &[Arg],
    ws: &mut Workspace,
) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let w1c = f32_arg(args, 3)?;
    let w2c = f32_arg(args, 4)?;
    let dy = f32_arg(args, 5)?;
    let kb = w1c.dims[1];
    let rows = m.bs * m.seq;
    let (xln, h, cache) = mig_forward(m, &x.data, &ln_g.data, &ln_b.data, &w1c.data, kb, ws);
    let mut hg = ws.take(rows * kb);
    hg.copy_from_slice(&h);
    for v in hg.iter_mut() {
        *v = ops::gelu(*v);
    }
    let mut dhg = ws.take(rows * kb);
    linalg::matmul_a_bt_acc(&mut dhg, &dy.data, &w2c.data, rows, m.hs, kb);
    let mut dw2c = ws.take(kb * m.hs);
    linalg::matmul_at_b_acc(&mut dw2c, &hg, &dy.data, rows, kb, m.hs);
    ws.give(hg);
    let mut dh = dhg;
    for (dv, &hv) in dh.iter_mut().zip(&h) {
        *dv *= ops::gelu_grad(hv);
    }
    ws.give(h);
    let mut dw1c = ws.take(m.hs * kb);
    linalg::matmul_at_b_acc(&mut dw1c, &xln, &dh, rows, m.hs, kb);
    let mut dxln = ws.take(rows * m.hs);
    linalg::matmul_a_bt_acc(&mut dxln, &dh, &w1c.data, rows, kb, m.hs);
    ws.give(dh);
    ws.give(xln);
    let (dx, dg, db) = ops::layernorm_bwd_ws(&dxln, &cache, &ln_g.data, rows, m.hs, ws);
    ws.give(dxln);
    cache.recycle(ws);
    Ok(vec![
        out_f32(spec, 0, dx),
        out_f32(spec, 1, dg),
        out_f32(spec, 2, db),
        out_f32(spec, 3, dw1c),
        out_f32(spec, 4, dw2c),
    ])
}
