//! Native implementations of every executable role in the manifest.
//!
//! Each function reproduces, on [`crate::tensor::Tensor`] buffers, the
//! exact math of the corresponding JAX shard program in
//! `python/compile/model.py` — same layernorm ε, tanh-approximate GELU,
//! softmax attention, pruned-GEMM contraction semantics (Eq. 1), and the
//! same zero-imputed backward scatters as the Pallas kernel's custom vjp.
//! Backward roles rematerialize their forward internally (the remat
//! structure of `build_attn_bwd`/`build_mlp_bwd`), so call signatures stay
//! identical to the AOT artifacts and the trainer cannot tell the
//! backends apart.

use anyhow::{bail, Result};

use super::ops;
use crate::runtime::manifest::{ExecSpec, ModelInfo};
use crate::runtime::{Arg, Out};
use crate::tensor::{linalg, Tensor};

/// Dispatch one validated call to its role implementation.
pub fn execute(m: &ModelInfo, spec: &ExecSpec, args: &[Arg]) -> Result<Vec<Out>> {
    match spec.role.as_str() {
        "embed_fwd" => embed_fwd(m, spec, args),
        "embed_bwd" => embed_bwd(m, spec, args),
        "attn_fwd" => attn_fwd(m, spec, args),
        "attn_bwd" => attn_bwd(m, spec, args),
        "mlp_fwd" => mlp_fwd(m, spec, args),
        "mlp_bwd" => mlp_bwd(m, spec, args),
        "head_fwdbwd" => head_fwdbwd(m, spec, args),
        "head_infer" => head_infer(m, spec, args),
        "mlp_mig_fwd" => mlp_mig_fwd(m, spec, args),
        "mlp_mig_bwd" => mlp_mig_bwd(m, spec, args),
        other => bail!(
            "native backend: unknown role '{other}' for executable '{}'",
            spec.name
        ),
    }
}

// ---------------------------------------------------------------------------
// argument / output plumbing
// ---------------------------------------------------------------------------

fn f32_arg<'a>(args: &'a [Arg<'a>], i: usize) -> Result<&'a Tensor> {
    match args.get(i) {
        Some(Arg::F32(t)) => Ok(t),
        _ => bail!("native backend: expected f32 argument {i}"),
    }
}

fn i32_arg<'a>(args: &'a [Arg<'a>], i: usize) -> Result<&'a [i32]> {
    match args.get(i) {
        Some(Arg::I32(v)) => Ok(v),
        _ => bail!("native backend: expected i32 argument {i}"),
    }
}

/// Reject out-of-range keep indices up front: `check_args` can only see
/// flattened lengths, and a bad index would otherwise abort with a
/// slice-bounds panic instead of the contract's `Err`.
fn check_idx(idx: &[i32], bound: usize, what: &str) -> Result<()> {
    for &ix in idx {
        if ix < 0 || ix as usize >= bound {
            bail!("keep index {ix} out of range for {what} (size {bound})");
        }
    }
    Ok(())
}

/// Wrap a buffer in the spec's declared output shape (scalars become `[1]`,
/// the same normalization the PJRT literal path applies).
fn out_f32(spec: &ExecSpec, i: usize, data: Vec<f32>) -> Out {
    let dims = &spec.outputs[i].dims;
    let dims = if dims.is_empty() { vec![1] } else { dims.clone() };
    Out::F32(Tensor::from_vec(&dims, data))
}

// ---------------------------------------------------------------------------
// embed
// ---------------------------------------------------------------------------

fn embed_fwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg]) -> Result<Vec<Out>> {
    let patches = f32_arg(args, 0)?;
    let w_patch = f32_arg(args, 1)?;
    let pos = f32_arg(args, 2)?;
    let cls = f32_arg(args, 3)?;
    let (b, s0, pd, s, hs) = (m.bs, m.seq0, m.pd, m.seq, m.hs);
    let tok = linalg::matmul(&patches.data, &w_patch.data, b * s0, pd, hs);
    let mut x = vec![0.0f32; b * s * hs];
    for bi in 0..b {
        let base = bi * s * hs;
        for j in 0..hs {
            x[base + j] = cls.data[j] + pos.data[j];
        }
        for t in 0..s0 {
            let dst = base + (1 + t) * hs;
            let src = (bi * s0 + t) * hs;
            let prow = &pos.data[(1 + t) * hs..(2 + t) * hs];
            for j in 0..hs {
                x[dst + j] = tok[src + j] + prow[j];
            }
        }
    }
    Ok(vec![out_f32(spec, 0, x)])
}

fn embed_bwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg]) -> Result<Vec<Out>> {
    let patches = f32_arg(args, 0)?;
    let dy = f32_arg(args, 4)?;
    let (b, s0, pd, s, hs) = (m.bs, m.seq0, m.pd, m.seq, m.hs);
    let mut dcls = vec![0.0f32; hs];
    let mut dpos = vec![0.0f32; s * hs];
    let mut dtok = vec![0.0f32; b * s0 * hs];
    for bi in 0..b {
        let base = bi * s * hs;
        for t in 0..s {
            let dyr = &dy.data[base + t * hs..base + (t + 1) * hs];
            let dp = &mut dpos[t * hs..(t + 1) * hs];
            for j in 0..hs {
                dp[j] += dyr[j];
            }
            if t == 0 {
                for j in 0..hs {
                    dcls[j] += dyr[j];
                }
            } else {
                dtok[(bi * s0 + t - 1) * hs..(bi * s0 + t) * hs].copy_from_slice(dyr);
            }
        }
    }
    let dw_patch = linalg::matmul_at_b(&patches.data, &dtok, b * s0, pd, hs);
    Ok(vec![
        out_f32(spec, 0, dw_patch),
        out_f32(spec, 1, dpos),
        out_f32(spec, 2, dcls),
    ])
}

// ---------------------------------------------------------------------------
// attention branch
// ---------------------------------------------------------------------------

struct AttnCore {
    xln: Vec<f32>,
    cache: ops::LnCache,
    qkv: Vec<f32>,
    /// softmaxed attention per (batch, head): `[b·hl, s·s]`
    att: Vec<f32>,
    /// merged head outputs `[b·s, hsl]`
    o: Vec<f32>,
}

/// Copy one (batch, head)'s q/k/v `[s, hd]` panels out of the packed
/// `[b·s, 3·hsl]` qkv buffer (token layout `[3, hl, hd]`).
fn gather_qkv(
    qkv: &[f32],
    bi: usize,
    h: usize,
    s: usize,
    hd: usize,
    hsl: usize,
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
) {
    for t in 0..s {
        let base = (bi * s + t) * 3 * hsl;
        let oq = base + h * hd;
        let ok = base + hsl + h * hd;
        let ov = base + 2 * hsl + h * hd;
        q[t * hd..(t + 1) * hd].copy_from_slice(&qkv[oq..oq + hd]);
        k[t * hd..(t + 1) * hd].copy_from_slice(&qkv[ok..ok + hd]);
        v[t * hd..(t + 1) * hd].copy_from_slice(&qkv[ov..ov + hd]);
    }
}

fn attn_forward(
    m: &ModelInfo,
    x: &[f32],
    ln_g: &[f32],
    ln_b: &[f32],
    wqkv: &[f32],
    idx: &[i32],
    mask: &[f32],
) -> AttnCore {
    let (b, s, hs, hl, hd, hsl) = (m.bs, m.seq, m.hs, m.hl, m.hd, m.hsl);
    let rows = b * s;
    let (xln, cache) = ops::layernorm(x, ln_g, ln_b, rows, hs);
    let qkv = ops::pruned_matmul(&xln, wqkv, rows, hs, 3 * hsl, idx, mask);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut att = vec![0.0f32; b * hl * s * s];
    let mut o = vec![0.0f32; rows * hsl];
    let mut q = vec![0.0f32; s * hd];
    let mut k = vec![0.0f32; s * hd];
    let mut v = vec![0.0f32; s * hd];
    for bi in 0..b {
        for h in 0..hl {
            gather_qkv(&qkv, bi, h, s, hd, hsl, &mut q, &mut k, &mut v);
            let mut a = linalg::matmul_a_bt(&q, &k, s, hd, s);
            for av in &mut a {
                *av *= scale;
            }
            ops::softmax_rows(&mut a, s, s);
            let oh = linalg::matmul(&a, &v, s, s, hd);
            let ab = (bi * hl + h) * s * s;
            att[ab..ab + s * s].copy_from_slice(&a);
            for t in 0..s {
                let dst = (bi * s + t) * hsl + h * hd;
                o[dst..dst + hd].copy_from_slice(&oh[t * hd..(t + 1) * hd]);
            }
        }
    }
    AttnCore { xln, cache, qkv, att, o }
}

fn attn_fwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg]) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let wqkv = f32_arg(args, 3)?;
    let wo = f32_arg(args, 4)?;
    let idx = i32_arg(args, 5)?;
    let mask = f32_arg(args, 6)?;
    check_idx(idx, m.hs, "attn qkv contraction")?;
    let rows = m.bs * m.seq;
    let core = attn_forward(m, &x.data, &ln_g.data, &ln_b.data, &wqkv.data, idx, &mask.data);
    let y = linalg::matmul(&core.o, &wo.data, rows, m.hsl, m.hs);
    Ok(vec![out_f32(spec, 0, y)])
}

fn attn_bwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg]) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let wqkv = f32_arg(args, 3)?;
    let wo = f32_arg(args, 4)?;
    let idx = i32_arg(args, 5)?;
    let mask = f32_arg(args, 6)?;
    let dy = f32_arg(args, 7)?;
    check_idx(idx, m.hs, "attn qkv contraction")?;
    let (b, s, hs, hl, hd, hsl) = (m.bs, m.seq, m.hs, m.hl, m.hd, m.hsl);
    let rows = b * s;
    let scale = 1.0 / (hd as f32).sqrt();

    // rematerialized forward
    let core = attn_forward(m, &x.data, &ln_g.data, &ln_b.data, &wqkv.data, idx, &mask.data);

    // y = o @ wo
    let do_ = linalg::matmul_a_bt(&dy.data, &wo.data, rows, hs, hsl);
    let dwo = linalg::matmul_at_b(&core.o, &dy.data, rows, hsl, hs);

    // per-head attention backward into dqkv
    let mut dqkv = vec![0.0f32; rows * 3 * hsl];
    let mut q = vec![0.0f32; s * hd];
    let mut k = vec![0.0f32; s * hd];
    let mut v = vec![0.0f32; s * hd];
    let mut doh = vec![0.0f32; s * hd];
    let mut dpre = vec![0.0f32; s * s];
    for bi in 0..b {
        for h in 0..hl {
            gather_qkv(&core.qkv, bi, h, s, hd, hsl, &mut q, &mut k, &mut v);
            for t in 0..s {
                let src = (bi * s + t) * hsl + h * hd;
                doh[t * hd..(t + 1) * hd].copy_from_slice(&do_[src..src + hd]);
            }
            let ab = (bi * hl + h) * s * s;
            let a = &core.att[ab..ab + s * s];
            // o = att @ v
            let dv = linalg::matmul_at_b(a, &doh, s, s, hd);
            let datt = linalg::matmul_a_bt(&doh, &v, s, hd, s);
            // softmax backward: dpre = att ⊙ (datt − ⟨datt, att⟩_row)
            for t in 0..s {
                let ar = &a[t * s..(t + 1) * s];
                let dr = &datt[t * s..(t + 1) * s];
                let inner = linalg::dot(ar, dr);
                let dp = &mut dpre[t * s..(t + 1) * s];
                for j in 0..s {
                    dp[j] = ar[j] * (dr[j] - inner);
                }
            }
            for dv_ in &mut dpre {
                *dv_ *= scale;
            }
            let dq = linalg::matmul(&dpre, &k, s, s, hd);
            let dk = linalg::matmul_at_b(&dpre, &q, s, s, hd);
            for t in 0..s {
                let base = (bi * s + t) * 3 * hsl;
                dqkv[base + h * hd..base + h * hd + hd]
                    .copy_from_slice(&dq[t * hd..(t + 1) * hd]);
                dqkv[base + hsl + h * hd..base + hsl + h * hd + hd]
                    .copy_from_slice(&dk[t * hd..(t + 1) * hd]);
                dqkv[base + 2 * hsl + h * hd..base + 2 * hsl + h * hd + hd]
                    .copy_from_slice(&dv[t * hd..(t + 1) * hd]);
            }
        }
    }

    // pruned-GEMM backward (zero-imputed), then layernorm backward
    let (dxln, dwqkv) =
        ops::pruned_matmul_bwd(&core.xln, &wqkv.data, &dqkv, rows, hs, 3 * hsl, idx, &mask.data);
    let (dx, dg, db) = ops::layernorm_bwd(&dxln, &core.cache, &ln_g.data, rows, hs);
    Ok(vec![
        out_f32(spec, 0, dx),
        out_f32(spec, 1, dg),
        out_f32(spec, 2, db),
        out_f32(spec, 3, dwqkv),
        out_f32(spec, 4, dwo),
    ])
}

// ---------------------------------------------------------------------------
// FFN branch
// ---------------------------------------------------------------------------

struct MlpCore {
    xln: Vec<f32>,
    cache: ops::LnCache,
    /// co-pruned FC1 weight `w1[:, idx2]·mask2`, `[hs, k2]`
    w1g: Vec<f32>,
    /// pre-GELU activations `[rows, k2]`
    h: Vec<f32>,
    /// post-GELU activations `[rows, k2]`
    hg: Vec<f32>,
    /// pruned FC2 weight `w2[idx2,:]·mask2`, `[k2, hs]`
    w2g: Vec<f32>,
}

#[allow(clippy::too_many_arguments)]
fn mlp_forward(
    m: &ModelInfo,
    x: &[f32],
    ln_g: &[f32],
    ln_b: &[f32],
    w1: &[f32],
    w2: &[f32],
    idx1: &[i32],
    mask1: &[f32],
    idx2: &[i32],
    mask2: &[f32],
) -> MlpCore {
    let (b, s, hs, ffl) = (m.bs, m.seq, m.hs, m.ffl);
    let rows = b * s;
    let k2 = idx2.len();
    let (xln, cache) = ops::layernorm(x, ln_g, ln_b, rows, hs);
    // N-side co-prune of FC1: w1g = w1[:, idx2] * mask2
    let mut w1g = vec![0.0f32; hs * k2];
    for r in 0..hs {
        let row = &w1[r * ffl..(r + 1) * ffl];
        let o = &mut w1g[r * k2..(r + 1) * k2];
        for (j, (&ix, &mv)) in idx2.iter().zip(mask2).enumerate() {
            o[j] = row[ix as usize] * mv;
        }
    }
    let h = ops::pruned_matmul(&xln, &w1g, rows, hs, k2, idx1, mask1);
    let mut hg = h.clone();
    for v in &mut hg {
        *v = ops::gelu(*v);
    }
    // K-side prune of FC2: w2g = w2[idx2, :] * mask2
    let mut w2g = vec![0.0f32; k2 * hs];
    for (j, (&ix, &mv)) in idx2.iter().zip(mask2).enumerate() {
        let src = &w2[ix as usize * hs..(ix as usize + 1) * hs];
        let dst = &mut w2g[j * hs..(j + 1) * hs];
        for (d, sv) in dst.iter_mut().zip(src) {
            *d = sv * mv;
        }
    }
    MlpCore { xln, cache, w1g, h, hg, w2g }
}

fn mlp_fwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg]) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let w1 = f32_arg(args, 3)?;
    let w2 = f32_arg(args, 4)?;
    let idx1 = i32_arg(args, 5)?;
    let mask1 = f32_arg(args, 6)?;
    let idx2 = i32_arg(args, 7)?;
    let mask2 = f32_arg(args, 8)?;
    check_idx(idx1, m.hs, "mlp fc1 contraction")?;
    check_idx(idx2, m.ffl, "mlp ffl dimension")?;
    let rows = m.bs * m.seq;
    let core = mlp_forward(
        m, &x.data, &ln_g.data, &ln_b.data, &w1.data, &w2.data, idx1, &mask1.data, idx2,
        &mask2.data,
    );
    let y = linalg::matmul(&core.hg, &core.w2g, rows, idx2.len(), m.hs);
    Ok(vec![out_f32(spec, 0, y)])
}

fn mlp_bwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg]) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let w1 = f32_arg(args, 3)?;
    let w2 = f32_arg(args, 4)?;
    let idx1 = i32_arg(args, 5)?;
    let mask1 = f32_arg(args, 6)?;
    let idx2 = i32_arg(args, 7)?;
    let mask2 = f32_arg(args, 8)?;
    let dy = f32_arg(args, 9)?;
    check_idx(idx1, m.hs, "mlp fc1 contraction")?;
    check_idx(idx2, m.ffl, "mlp ffl dimension")?;
    let (hs, ffl) = (m.hs, m.ffl);
    let rows = m.bs * m.seq;
    let k2 = idx2.len();

    let core = mlp_forward(
        m, &x.data, &ln_g.data, &ln_b.data, &w1.data, &w2.data, idx1, &mask1.data, idx2,
        &mask2.data,
    );

    // y = hg @ w2g
    let dhg = linalg::matmul_a_bt(&dy.data, &core.w2g, rows, hs, k2);
    let dw2g = linalg::matmul_at_b(&core.hg, &dy.data, rows, k2, hs);
    // dw2[idx2[j], :] += dw2g[j, :] * mask2[j]  (zero-imputed full shape)
    let mut dw2 = vec![0.0f32; ffl * hs];
    for (j, (&ix, &mv)) in idx2.iter().zip(&mask2.data).enumerate() {
        let dst = &mut dw2[ix as usize * hs..(ix as usize + 1) * hs];
        for (d, sv) in dst.iter_mut().zip(&dw2g[j * hs..(j + 1) * hs]) {
            *d += sv * mv;
        }
    }
    // through the GELU
    let mut dh = dhg;
    for (dv, &hv) in dh.iter_mut().zip(&core.h) {
        *dv *= ops::gelu_grad(hv);
    }
    // pruned FC1 backward w.r.t. (xln, w1g)
    let (dxln, dw1g) =
        ops::pruned_matmul_bwd(&core.xln, &core.w1g, &dh, rows, hs, k2, idx1, &mask1.data);
    // dw1[:, idx2[j]] += dw1g[:, j] * mask2[j]
    let mut dw1 = vec![0.0f32; hs * ffl];
    for r in 0..hs {
        let src = &dw1g[r * k2..(r + 1) * k2];
        let dst = &mut dw1[r * ffl..(r + 1) * ffl];
        for (j, (&ix, &mv)) in idx2.iter().zip(&mask2.data).enumerate() {
            dst[ix as usize] += src[j] * mv;
        }
    }
    let (dx, dg, db) = ops::layernorm_bwd(&dxln, &core.cache, &ln_g.data, rows, hs);
    Ok(vec![
        out_f32(spec, 0, dx),
        out_f32(spec, 1, dg),
        out_f32(spec, 2, db),
        out_f32(spec, 3, dw1),
        out_f32(spec, 4, dw2),
    ])
}

// ---------------------------------------------------------------------------
// head
// ---------------------------------------------------------------------------

struct HeadCore {
    cache: ops::LnCache,
    pooled: Vec<f32>,
    /// softmax probabilities `[b, classes]`
    probs: Vec<f32>,
    loss: f32,
    ncorrect: i32,
}

fn head_forward(
    m: &ModelInfo,
    x: &[f32],
    lnf_g: &[f32],
    lnf_b: &[f32],
    w_head: &[f32],
    b_head: &[f32],
    labels: &[i32],
) -> Result<HeadCore> {
    let (b, s, hs, cl) = (m.bs, m.seq, m.hs, m.classes);
    let rows = b * s;
    let (xln, cache) = ops::layernorm(x, lnf_g, lnf_b, rows, hs);
    let mut pooled = vec![0.0f32; b * hs];
    for bi in 0..b {
        pooled[bi * hs..(bi + 1) * hs].copy_from_slice(&xln[bi * s * hs..bi * s * hs + hs]);
    }
    let mut logits = linalg::matmul(&pooled, w_head, b, hs, cl);
    for bi in 0..b {
        let row = &mut logits[bi * cl..(bi + 1) * cl];
        for (lv, bv) in row.iter_mut().zip(b_head) {
            *lv += bv;
        }
    }
    let logp = ops::log_softmax_rows(&logits, b, cl);
    let mut loss = 0.0f64;
    let mut ncorrect = 0i32;
    for bi in 0..b {
        let li = labels[bi];
        if li < 0 || li as usize >= cl {
            bail!("label {li} out of range [0, {cl})");
        }
        loss -= logp[bi * cl + li as usize] as f64;
        // first-occurrence argmax (jnp.argmax semantics)
        let row = &logits[bi * cl..(bi + 1) * cl];
        let mut best = 0usize;
        for (c, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = c;
            }
        }
        if best == li as usize {
            ncorrect += 1;
        }
    }
    let mut probs = logp;
    for p in &mut probs {
        *p = p.exp();
    }
    Ok(HeadCore {
        cache,
        pooled,
        probs,
        loss: (loss / b as f64) as f32,
        ncorrect,
    })
}

fn head_fwdbwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg]) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let lnf_g = f32_arg(args, 1)?;
    let lnf_b = f32_arg(args, 2)?;
    let w_head = f32_arg(args, 3)?;
    let b_head = f32_arg(args, 4)?;
    let labels = i32_arg(args, 5)?;
    let (b, s, hs, cl) = (m.bs, m.seq, m.hs, m.classes);
    let rows = b * s;
    let core = head_forward(
        m, &x.data, &lnf_g.data, &lnf_b.data, &w_head.data, &b_head.data, labels,
    )?;

    // d(loss)/d(logits) of mean softmax-CE
    let inv_b = 1.0 / b as f32;
    let mut dlogits = core.probs.clone();
    for bi in 0..b {
        dlogits[bi * cl + labels[bi] as usize] -= 1.0;
    }
    for v in &mut dlogits {
        *v *= inv_b;
    }
    let dw_head = linalg::matmul_at_b(&core.pooled, &dlogits, b, hs, cl);
    let mut db_head = vec![0.0f32; cl];
    for bi in 0..b {
        for (d, &v) in db_head.iter_mut().zip(&dlogits[bi * cl..(bi + 1) * cl]) {
            *d += v;
        }
    }
    let dpooled = linalg::matmul_a_bt(&dlogits, &w_head.data, b, cl, hs);
    // only the cls-token rows receive gradient
    let mut dxln = vec![0.0f32; rows * hs];
    for bi in 0..b {
        dxln[bi * s * hs..bi * s * hs + hs].copy_from_slice(&dpooled[bi * hs..(bi + 1) * hs]);
    }
    let (dx, dg, db) = ops::layernorm_bwd(&dxln, &core.cache, &lnf_g.data, rows, hs);
    Ok(vec![
        out_f32(spec, 0, vec![core.loss]),
        Out::I32(vec![core.ncorrect]),
        out_f32(spec, 2, dx),
        out_f32(spec, 3, dg),
        out_f32(spec, 4, db),
        out_f32(spec, 5, dw_head),
        out_f32(spec, 6, db_head),
    ])
}

fn head_infer(m: &ModelInfo, spec: &ExecSpec, args: &[Arg]) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let lnf_g = f32_arg(args, 1)?;
    let lnf_b = f32_arg(args, 2)?;
    let w_head = f32_arg(args, 3)?;
    let b_head = f32_arg(args, 4)?;
    let labels = i32_arg(args, 5)?;
    let core = head_forward(
        m, &x.data, &lnf_g.data, &lnf_b.data, &w_head.data, &b_head.data, labels,
    )?;
    Ok(vec![out_f32(spec, 0, vec![core.loss]), Out::I32(vec![core.ncorrect])])
}

// ---------------------------------------------------------------------------
// migration receiver slices
// ---------------------------------------------------------------------------

fn mig_forward(
    m: &ModelInfo,
    x: &[f32],
    ln_g: &[f32],
    ln_b: &[f32],
    w1c: &[f32],
    kb: usize,
) -> (Vec<f32>, Vec<f32>, ops::LnCache) {
    let rows = m.bs * m.seq;
    let (xln, cache) = ops::layernorm(x, ln_g, ln_b, rows, m.hs);
    let h = linalg::matmul(&xln, w1c, rows, m.hs, kb);
    (xln, h, cache)
}

fn mlp_mig_fwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg]) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let w1c = f32_arg(args, 3)?;
    let w2c = f32_arg(args, 4)?;
    let kb = w1c.dims[1];
    let rows = m.bs * m.seq;
    let (_xln, h, _cache) = mig_forward(m, &x.data, &ln_g.data, &ln_b.data, &w1c.data, kb);
    let mut hg = h;
    for v in &mut hg {
        *v = ops::gelu(*v);
    }
    let y = linalg::matmul(&hg, &w2c.data, rows, kb, m.hs);
    Ok(vec![out_f32(spec, 0, y)])
}

fn mlp_mig_bwd(m: &ModelInfo, spec: &ExecSpec, args: &[Arg]) -> Result<Vec<Out>> {
    let x = f32_arg(args, 0)?;
    let ln_g = f32_arg(args, 1)?;
    let ln_b = f32_arg(args, 2)?;
    let w1c = f32_arg(args, 3)?;
    let w2c = f32_arg(args, 4)?;
    let dy = f32_arg(args, 5)?;
    let kb = w1c.dims[1];
    let rows = m.bs * m.seq;
    let (xln, h, cache) = mig_forward(m, &x.data, &ln_g.data, &ln_b.data, &w1c.data, kb);
    let mut hg = h.clone();
    for v in &mut hg {
        *v = ops::gelu(*v);
    }
    let dhg = linalg::matmul_a_bt(&dy.data, &w2c.data, rows, m.hs, kb);
    let dw2c = linalg::matmul_at_b(&hg, &dy.data, rows, kb, m.hs);
    let mut dh = dhg;
    for (dv, &hv) in dh.iter_mut().zip(&h) {
        *dv *= ops::gelu_grad(hv);
    }
    let dw1c = linalg::matmul_at_b(&xln, &dh, rows, m.hs, kb);
    let dxln = linalg::matmul_a_bt(&dh, &w1c.data, rows, kb, m.hs);
    let (dx, dg, db) = ops::layernorm_bwd(&dxln, &cache, &ln_g.data, rows, m.hs);
    Ok(vec![
        out_f32(spec, 0, dx),
        out_f32(spec, 1, dg),
        out_f32(spec, 2, db),
        out_f32(spec, 3, dw1c),
        out_f32(spec, 4, dw2c),
    ])
}
