//! The pure-Rust native execution backend.
//!
//! Implements every executable role of the artifact manifest directly on
//! [`crate::tensor::Tensor`] buffers — no Python, no XLA, no artifacts on
//! disk.  The manifest is synthesized from the model presets
//! ([`crate::runtime::presets`]) when `artifacts/<model>/` is absent, so
//! `flextp train --model vit-tiny --strategy semi` runs from a clean
//! checkout with nothing but `cargo`.
//!
//! Numerics are pinned to the JAX programs the PJRT backend executes (see
//! [`vit`] and [`ops`]); GEMMs go through the blocked kernels in
//! [`crate::tensor::linalg`], so measured per-call wall time scales with
//! the arithmetic a pruning bucket actually performs — which is what makes
//! ZERO-resizing/migration bench timings meaningful on this backend.
//! `execute` measures its own kernel-body wall time (the compute charge);
//! the ×χ straggler skew is applied by the trainer when charging it to
//! the rank's `SimClock`.

pub mod ops;
pub mod vit;

use std::time::Instant;

use anyhow::Result;

use super::manifest::{ExecSpec, Manifest, ModelInfo};
use super::{Arg, Backend, Out};
use crate::tensor::Workspace;

/// Stateless native executor for one model's manifest.
///
/// The only field is the read-only [`ModelInfo`] shared by every call, so
/// the backend is trivially `Send + Sync` (the [`Backend`] contract): all
/// per-call state — activations, co-pruned weights, LN caches — lives in
/// the *caller-owned* [`Workspace`] threaded through [`vit::execute`]
/// (one workspace per simulated rank in the trainer), plus fixed-size
/// stack tiles inside the GEMM kernels.  Concurrent calls from the
/// parallel rank engine therefore cannot alias; determinism at any
/// thread count follows from the panel-parallel GEMM guarantee in
/// [`crate::tensor::linalg`] (workspace reuse never changes results —
/// buffers are checked out zero-filled).
pub struct NativeBackend {
    model: ModelInfo,
}

impl NativeBackend {
    pub fn new(manifest: &Manifest) -> NativeBackend {
        NativeBackend { model: manifest.model.clone() }
    }
}

impl Backend for NativeBackend {
    fn execute(
        &self,
        spec: &ExecSpec,
        args: &[Arg],
        ws: &mut Workspace,
    ) -> Result<(Vec<Out>, f64)> {
        let t0 = Instant::now();
        let outs = vit::execute(&self.model, spec, args, ws)?;
        Ok((outs, t0.elapsed().as_secs_f64()))
    }

    fn prepare(&self, _spec: &ExecSpec) -> Result<()> {
        Ok(()) // nothing to compile
    }

    fn platform(&self) -> String {
        "native-cpu".to_string()
    }
}
