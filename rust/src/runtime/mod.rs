//! Execution runtime: the pluggable backend layer.
//!
//! [`Runtime`] is the single entry point the trainer, benches, and tests
//! use to execute manifest executables.  It owns the [`Manifest`] (loaded
//! from `artifacts/<model>/manifest.json` when present, synthesized from
//! the built-in presets otherwise), validates every call against the
//! declared shapes, accumulates each call's backend-measured compute
//! seconds into a timing profile, and dispatches to a [`Backend`]:
//!
//! * [`native::NativeBackend`] (default) — pure-Rust implementations of
//!   every role; runs from a clean checkout with nothing but `cargo`.
//! * `pjrt::PjrtBackend` (`--features pjrt`) — loads the AOT HLO-text
//!   artifacts produced by `python/compile/aot.py` and executes them
//!   through the `xla` crate's PJRT bindings.
//!
//! The measured seconds returned by [`Runtime::call`] are what the engine
//! charges (×χ for stragglers) to the rank's `SimClock` — see the
//! [`Backend`] contract below.

pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod presets;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::config::BackendKind;
use crate::tensor::{Tensor, Workspace};
pub use manifest::{ArgSpec, Bucket, Dtype, ExecSpec, Manifest, ModelInfo};

thread_local! {
    /// Per-thread workspace behind [`Runtime::call`]: callers that do not
    /// manage an explicit [`Workspace`] (tests, benches, the trainer's
    /// replicated embed/head calls on the coordinator thread) still reuse
    /// scratch across calls made from the same thread.
    static CALL_WS: RefCell<Workspace> = const { RefCell::new(Workspace::new()) };
}

/// Park a buffer in the calling thread's [`Runtime::call`] workspace so a
/// later call can reuse it — the coordinator-side analogue of the
/// trainer's per-rank recycling.
pub fn recycle_local(t: Tensor) {
    CALL_WS.with(|w| w.borrow_mut().give(t.data));
}

/// An input argument to an executable call.
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a [i32]),
}

/// An output value from an executable call.
#[derive(Debug, Clone)]
pub enum Out {
    F32(Tensor),
    I32(Vec<i32>),
}

impl Out {
    pub fn tensor(self) -> Result<Tensor> {
        match self {
            Out::F32(t) => Ok(t),
            _ => bail!("output is not f32"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            Out::F32(t) if t.len() == 1 => Ok(t.data[0]),
            _ => bail!("output is not a f32 scalar"),
        }
    }

    pub fn scalar_i32(&self) -> Result<i32> {
        match self {
            Out::I32(v) if v.len() == 1 => Ok(v[0]),
            _ => bail!("output is not an i32 scalar"),
        }
    }
}

/// The execution-backend contract.
///
/// Invariants every implementation must uphold:
///
/// * **Validated calls** — `execute` receives `args` already checked
///   against `spec.inputs` (count, dtype, exact dims), and must return
///   exactly `spec.outputs.len()` outputs in manifest order, with scalar
///   f32/i32 outputs normalized to length-1 values.
/// * **Pruning semantics** — roles taking `(idx, mask)` implement the
///   Eq. (1) contraction contract `(x[:,idx]·mask) @ w[idx,:]`, with the
///   zero-imputed scatter-ADD backward of the Pallas kernel's vjp.
/// * **Timing** — `execute` returns the measured seconds of the *device
///   compute* it performed; the trainer charges exactly that (multiplied
///   by the rank's skewness χ for stragglers) to the rank's `SimClock`.
///   Backends time their own compute boundary — PJRT times execution +
///   output download but not host→device input staging, matching the
///   seed's RT accounting; the native backend times the whole kernel
///   body.  All work must happen synchronously inside `execute`, or RT
///   measurements lose meaning.
/// * **Determinism** — same inputs, same outputs (bitwise), so golden
///   tests and cross-backend checks are reproducible.  This must hold at
///   any thread count: an executable's result may not depend on what else
///   runs concurrently.
/// * **Thread safety** — `Backend` is `Send + Sync`: the parallel rank
///   engine issues `execute` calls for different ranks concurrently from
///   scoped worker threads.  Implementations keep per-call state on the
///   stack (the native backend is stateless beyond the shared read-only
///   `ModelInfo`) and guard any shared caches with locks (the PJRT
///   backend's compiled-executable cache).
pub trait Backend: Send + Sync {
    /// Execute one manifest executable on validated arguments; returns
    /// the outputs plus the measured compute seconds.  `ws` is the
    /// caller's scratch arena: backends that compute on the host (the
    /// native backend) draw every intermediate buffer from it so
    /// steady-state calls are allocation-free; device-side backends
    /// (PJRT) may ignore it.  Workspace contents never influence results
    /// — buffers come out zero-filled.
    fn execute(&self, spec: &ExecSpec, args: &[Arg], ws: &mut Workspace) -> Result<(Vec<Out>, f64)>;

    /// Pre-compile / warm an executable before timed regions (PJRT
    /// compiles the HLO here; the native backend has nothing to do).
    fn prepare(&self, spec: &ExecSpec) -> Result<()>;

    /// Human-readable platform label for logs.
    fn platform(&self) -> String;
}

/// The runtime facade: manifest + backend + per-executable timing profile.
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
    /// cumulative (calls, seconds) per executable — §Perf profiling.
    /// Mutex (not RefCell) so concurrent rank workers can record timings;
    /// held only for the map update, never across a backend call.
    timings: Mutex<BTreeMap<String, (u64, f64)>>,
}

impl Runtime {
    fn with_backend(manifest: Manifest, backend: Box<dyn Backend>) -> Runtime {
        Runtime { manifest, backend, timings: Mutex::new(BTreeMap::new()) }
    }

    /// Open a model on the requested backend.  With [`BackendKind::Native`]
    /// the manifest comes from `model_dir/manifest.json` when present and
    /// is synthesized from the `model` preset otherwise; PJRT always
    /// requires the compiled artifact directory.
    pub fn open(model_dir: &Path, model: &str, kind: BackendKind) -> Result<Runtime> {
        match kind {
            BackendKind::Native => {
                let manifest = Manifest::load_or_synthesize(model_dir, model)?;
                let backend = Box::new(native::NativeBackend::new(&manifest));
                Ok(Self::with_backend(manifest, backend))
            }
            BackendKind::Pjrt => Self::open_pjrt(model_dir),
        }
    }

    /// Native runtime from a synthesized preset manifest (no disk I/O).
    pub fn native_for(model: &str) -> Result<Runtime> {
        let manifest = Manifest::for_model(model)?;
        let backend = Box::new(native::NativeBackend::new(&manifest));
        Ok(Self::with_backend(manifest, backend))
    }

    /// Native runtime over an explicit manifest (tests, custom configs).
    pub fn native_with_manifest(manifest: Manifest) -> Runtime {
        let backend = Box::new(native::NativeBackend::new(&manifest));
        Self::with_backend(manifest, backend)
    }

    #[cfg(feature = "pjrt")]
    fn open_pjrt(model_dir: &Path) -> Result<Runtime> {
        let backend = pjrt::PjrtBackend::load(model_dir)?;
        let manifest = backend.manifest.clone();
        Ok(Self::with_backend(manifest, Box::new(backend)))
    }

    #[cfg(not(feature = "pjrt"))]
    fn open_pjrt(_model_dir: &Path) -> Result<Runtime> {
        bail!(
            "backend 'pjrt' is not compiled in — rebuild with \
             `cargo build --features pjrt` (and a real `xla` crate, see \
             DESIGN.md §8) or use --backend native"
        )
    }

    /// Pre-compile a set of executables (warmup before timed regions).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.backend.prepare(self.manifest.exec(n)?)?;
        }
        Ok(())
    }

    /// Execute `name` with `args` using the calling thread's shared
    /// workspace; returns outputs and the backend's measured compute
    /// seconds (used as the SimClock compute charge).
    pub fn call(&self, name: &str, args: &[Arg]) -> Result<(Vec<Out>, f64)> {
        CALL_WS.with(|w| self.call_ws(name, args, &mut w.borrow_mut()))
    }

    /// [`Runtime::call`] with an explicit [`Workspace`] — the trainer
    /// routes each simulated rank's calls through that rank's own arena
    /// so steady-state steps reuse every intermediate buffer.
    pub fn call_ws(&self, name: &str, args: &[Arg], ws: &mut Workspace) -> Result<(Vec<Out>, f64)> {
        let spec = self.manifest.exec(name)?;
        check_args(spec, args)?;
        let (outs, elapsed) = self
            .backend
            .execute(spec, args, ws)
            .with_context(|| format!("executing {name}"))?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "{name}: got {} outputs, manifest says {}",
                outs.len(),
                spec.outputs.len()
            );
        }
        let mut t = self.timings.lock().expect("timings lock poisoned");
        let e = t.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += elapsed;
        Ok((outs, elapsed))
    }

    /// (calls, total seconds) per executable, sorted by total time.
    pub fn timing_profile(&self) -> Vec<(String, u64, f64)> {
        let mut v: Vec<(String, u64, f64)> = self
            .timings
            .lock()
            .expect("timings lock poisoned")
            .iter()
            .map(|(k, (n, s))| (k.clone(), *n, *s))
            .collect();
        v.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        v
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }
}

/// Validate argument count, dtypes, and exact dims against the manifest.
fn check_args(spec: &ExecSpec, args: &[Arg]) -> Result<()> {
    if args.len() != spec.inputs.len() {
        bail!(
            "{}: got {} args, manifest says {}",
            spec.name,
            args.len(),
            spec.inputs.len()
        );
    }
    for (arg, s) in args.iter().zip(&spec.inputs) {
        match (arg, s.dtype) {
            (Arg::F32(t), Dtype::F32) => {
                if t.dims != s.dims {
                    bail!(
                        "{}: input '{}' dims {:?} != manifest {:?}",
                        spec.name,
                        s.name,
                        t.dims,
                        s.dims
                    );
                }
            }
            (Arg::I32(v), Dtype::I32) => {
                let n: usize = s.dims.iter().product();
                if v.len() != n {
                    bail!(
                        "{}: input '{}' len {} != manifest {:?}",
                        spec.name,
                        s.name,
                        v.len(),
                        s.dims
                    );
                }
            }
            _ => bail!("{}: input '{}': dtype mismatch", spec.name, s.name),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_synthesizes_and_validates() {
        let rt = Runtime::native_for("vit-tiny").unwrap();
        assert_eq!(rt.platform(), "native-cpu");
        let m = rt.manifest.model.clone();
        // wrong dims rejected
        let bad = Tensor::zeros(&[1, 2, 3]);
        let z = Tensor::zeros(&[1]);
        assert!(rt
            .call("embed_fwd", &[Arg::F32(&bad), Arg::F32(&z), Arg::F32(&z), Arg::F32(&z)])
            .is_err());
        // wrong arity rejected
        assert!(rt.call("embed_fwd", &[Arg::F32(&bad)]).is_err());
        // unknown name rejected
        let x = Tensor::zeros(&[m.bs, m.seq, m.hs]);
        assert!(rt.call("nope", &[Arg::F32(&x)]).is_err());
    }

    #[test]
    fn warmup_is_ok_for_known_and_err_for_unknown() {
        let rt = Runtime::native_for("vit-tiny").unwrap();
        assert!(rt.warmup(&["embed_fwd", "attn_fwd_g00"]).is_ok());
        assert!(rt.warmup(&["bogus"]).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_unavailable_without_feature() {
        let e = Runtime::open(
            Path::new("artifacts/vit-tiny"),
            "vit-tiny",
            BackendKind::Pjrt,
        )
        .unwrap_err();
        assert!(e.to_string().contains("pjrt"));
    }
}
