//! PJRT runtime: loads the AOT artifacts (HLO text) and executes them on
//! the request path. This is the only module that touches the `xla` crate.
//!
//! Flow (adapted from /opt/xla-example/load_hlo):
//!   `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `client.compile` → `execute` per call.  Executables compile lazily on
//!   first use and are cached for the life of the runtime, so each model
//!   variant compiles exactly once.  Every call is timed; the engine
//!   charges that measurement (×χ for stragglers) to the rank's SimClock.

pub mod manifest;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
pub use manifest::{ArgSpec, Dtype, ExecSpec, Manifest};

/// An input argument to an executable call.
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a [i32]),
}

/// An output value from an executable call.
#[derive(Debug, Clone)]
pub enum Out {
    F32(Tensor),
    I32(Vec<i32>),
}

impl Out {
    pub fn tensor(self) -> Result<Tensor> {
        match self {
            Out::F32(t) => Ok(t),
            _ => bail!("output is not f32"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            Out::F32(t) if t.len() == 1 => Ok(t.data[0]),
            _ => bail!("output is not a f32 scalar"),
        }
    }

    pub fn scalar_i32(&self) -> Result<i32> {
        match self {
            Out::I32(v) if v.len() == 1 => Ok(v[0]),
            _ => bail!("output is not an i32 scalar"),
        }
    }
}

struct CompiledExec {
    exe: xla::PjRtLoadedExecutable,
    spec: ExecSpec,
}

/// The PJRT service: client + lazily-compiled executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<CompiledExec>>>,
    /// cumulative (calls, seconds) per executable — §Perf profiling
    timings: RefCell<BTreeMap<String, (u64, f64)>>,
}

impl Runtime {
    /// Load a model's artifact directory (manifest + HLO text files).
    pub fn load(model_dir: &std::path::Path) -> Result<Runtime> {
        let manifest = Manifest::load(&model_dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", model_dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir: model_dir.to_path_buf(),
            manifest,
            cache: RefCell::new(BTreeMap::new()),
            timings: RefCell::new(BTreeMap::new()),
        })
    }

    fn compiled(&self, name: &str) -> Result<Rc<CompiledExec>> {
        if let Some(c) = self.cache.borrow().get(name) {
            return Ok(c.clone());
        }
        let spec = self
            .manifest
            .exec(name)
            .with_context(|| format!("executable '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let c = Rc::new(CompiledExec { exe, spec });
        self.cache.borrow_mut().insert(name.to_string(), c.clone());
        Ok(c)
    }

    /// Pre-compile a set of executables (warmup before timed regions).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.compiled(n)?;
        }
        Ok(())
    }

    /// Execute `name` with `args`; returns outputs and the measured
    /// execution seconds (used as the SimClock compute charge).
    pub fn call(&self, name: &str, args: &[Arg]) -> Result<(Vec<Out>, f64)> {
        let c = self.compiled(name)?;
        if args.len() != c.spec.inputs.len() {
            bail!("{name}: got {} args, manifest says {}", args.len(), c.spec.inputs.len());
        }
        // Inputs go through self-owned PjRtBuffers + execute_b: the
        // crate's literal-taking `execute` leaks its internally-created
        // input buffers (~input bytes per call — measured by
        // examples/leak_probe.rs), while buffers we create are freed by
        // PjRtBuffer::drop.  This is also the §Perf device-buffer path.
        let mut buffers = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(&c.spec.inputs) {
            buffers.push(to_buffer(&self.client, arg, spec)?);
        }
        let t0 = Instant::now();
        let result = c.exe.execute_b(&buffers)
            .with_context(|| format!("executing {name}"))?[0][0]
            .to_literal_sync()?;
        let elapsed = t0.elapsed().as_secs_f64();
        // aot.py lowers with return_tuple=True → always a tuple.
        let elems = result.to_tuple()?;
        if elems.len() != c.spec.outputs.len() {
            bail!("{name}: got {} outputs, manifest says {}",
                  elems.len(), c.spec.outputs.len());
        }
        let mut outs = Vec::with_capacity(elems.len());
        for (lit, spec) in elems.into_iter().zip(&c.spec.outputs) {
            outs.push(from_literal(lit, spec)?);
        }
        let mut t = self.timings.borrow_mut();
        let e = t.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += elapsed;
        Ok((outs, elapsed))
    }

    /// (calls, total seconds) per executable, sorted by total time.
    pub fn timing_profile(&self) -> Vec<(String, u64, f64)> {
        let mut v: Vec<(String, u64, f64)> = self
            .timings
            .borrow()
            .iter()
            .map(|(k, (n, s))| (k.clone(), *n, *s))
            .collect();
        v.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        v
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

fn to_buffer(client: &xla::PjRtClient, arg: &Arg, spec: &ArgSpec) -> Result<xla::PjRtBuffer> {
    match (arg, spec.dtype) {
        (Arg::F32(t), Dtype::F32) => {
            if t.dims != spec.dims {
                bail!("input '{}' dims {:?} != manifest {:?}", spec.name, t.dims, spec.dims);
            }
            Ok(client.buffer_from_host_buffer(&t.data, &spec.dims, None)?)
        }
        (Arg::I32(v), Dtype::I32) => {
            let n: usize = spec.dims.iter().product();
            if v.len() != n {
                bail!("input '{}' len {} != manifest {:?}", spec.name, v.len(), spec.dims);
            }
            Ok(client.buffer_from_host_buffer(v, &spec.dims, None)?)
        }
        _ => bail!("input '{}': dtype mismatch", spec.name),
    }
}

fn from_literal(lit: xla::Literal, spec: &ArgSpec) -> Result<Out> {
    match spec.dtype {
        Dtype::F32 => {
            let data = lit.to_vec::<f32>()?;
            let dims = if spec.dims.is_empty() { vec![1] } else { spec.dims.clone() };
            if data.len() != dims.iter().product::<usize>() {
                bail!("output '{}': {} elems, expected {:?}", spec.name, data.len(), spec.dims);
            }
            Ok(Out::F32(Tensor::from_vec(&dims, data)))
        }
        Dtype::I32 => Ok(Out::I32(lit.to_vec::<i32>()?)),
    }
}
