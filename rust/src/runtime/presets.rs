//! Built-in model presets and manifest synthesis.
//!
//! The native backend needs no AOT artifacts: when `artifacts/<model>/`
//! does not exist, the manifest (model facts, pruning buckets, executable
//! inventory) is synthesized here from the same presets and derivation
//! rules as `python/compile/model.py` + `aot.py`.  The synthesized
//! manifest is byte-for-byte equivalent in structure to a compiled one —
//! names, roles, shapes, and bucket sizes all follow the aot.py contract —
//! so the trainer, balancers, and tests run identically on either source.

use anyhow::{bail, ensure, Result};

use super::manifest::{ArgSpec, Bucket, Degrees, Dtype, ExecSpec, Manifest, ModelInfo};

/// Static pruning buckets: fraction of the contraction that SURVIVES
/// (γ = 1 − keep_frac), mirroring `model.KEEP_FRACS`.
pub const KEEP_FRACS: [f64; 5] = [1.0, 0.75, 0.5, 0.25, 0.125];

/// Migration-slice buckets over ffl, mirroring `model.MIG_FRACS`.
pub const MIG_FRACS: [f64; 3] = [0.5, 0.25, 0.125];

const IMG: usize = 32;
const PATCH: usize = 4;
const CHANS: usize = 3;
const CLASSES: usize = 10;
pub const MLP_RATIO: usize = 4;

/// One artifact-set preset (mirrors python `ModelCfg` presets).
#[derive(Debug, Clone, Copy)]
pub struct Preset {
    pub name: &'static str,
    pub hs: usize,
    pub depth: usize,
    pub heads: usize,
    pub e: usize,
    pub bs: usize,
}

/// The preset table from `python/compile/model.py` (vit-s / vit-m are the
/// ViT-1B / ViT-3B scale stand-ins — DESIGN.md §2).
pub const PRESETS: [Preset; 4] = [
    Preset { name: "vit-tiny", hs: 128, depth: 2, heads: 4, e: 4, bs: 8 },
    Preset { name: "vit-s", hs: 256, depth: 4, heads: 8, e: 8, bs: 16 },
    Preset { name: "vit-m", hs: 384, depth: 6, heads: 8, e: 8, bs: 16 },
    Preset { name: "vit-100m", hs: 768, depth: 12, heads: 12, e: 4, bs: 8 },
];

/// Look up a preset by name.
pub fn preset(name: &str) -> Result<Preset> {
    match PRESETS.iter().copied().find(|p| p.name == name) {
        Some(p) => Ok(p),
        None => bail!(
            "unknown model '{name}' and no artifacts on disk \
             (presets: vit-tiny|vit-s|vit-m|vit-100m)"
        ),
    }
}

/// Bucket keep-size: multiple of 8 (lane width), at least 8.
pub fn keep_count(k: usize, frac: f64) -> usize {
    (((k as f64 * frac / 8.0).round() as usize) * 8).max(8)
}

/// Bucket suffix by pruning percentage, e.g. keep-frac 0.75 → "g25".
pub fn bucket_name(frac: f64) -> String {
    format!("g{:02}", ((1.0 - frac) * 100.0).round() as i64)
}

fn model_info(p: &Preset, degrees: Degrees) -> ModelInfo {
    let seq0 = (IMG / PATCH) * (IMG / PATCH);
    let seq = seq0 + 1;
    let pd = CHANS * PATCH * PATCH;
    // shard widths derive from each component's own group size, not the
    // global worker count (DESIGN.md §18); uniform degrees reproduce the
    // historic hs/e and 4·hs/e widths exactly
    let hsl = p.hs / degrees.attn;
    let hl = p.heads / degrees.attn;
    let hd = p.hs / p.heads;
    let ffl = MLP_RATIO * p.hs / degrees.mlp;
    // per-worker: shard of every block + one replica of embed/head
    // (under mixed degrees this is a group-member rank's count — the
    // densest rank, since groups are rank prefixes)
    let blk_w = 4 * p.hs + p.hs * 3 * hsl + hsl * p.hs + p.hs * ffl + ffl * p.hs;
    let emb = pd * p.hs + seq * p.hs + p.hs;
    let head = 2 * p.hs + p.hs * CLASSES + CLASSES;
    let params_per_worker = p.depth * blk_w + emb + head;
    // global: full (unsharded) blocks + one replica set
    let blk = 4 * p.hs
        + p.hs * 3 * p.hs
        + p.hs * p.hs
        + p.hs * MLP_RATIO * p.hs
        + MLP_RATIO * p.hs * p.hs;
    let params_total = p.depth * blk + emb + head;
    ModelInfo {
        name: p.name.to_string(),
        hs: p.hs,
        depth: p.depth,
        heads: p.heads,
        e: p.e,
        bs: p.bs,
        classes: CLASSES,
        seq,
        seq0,
        pd,
        hsl,
        hl,
        hd,
        ffl,
        params_total,
        params_per_worker,
        degrees,
    }
}

fn f32_spec(name: &str, dims: &[usize]) -> ArgSpec {
    ArgSpec { name: name.to_string(), dims: dims.to_vec(), dtype: Dtype::F32 }
}

fn i32_spec(name: &str, dims: &[usize]) -> ArgSpec {
    ArgSpec { name: name.to_string(), dims: dims.to_vec(), dtype: Dtype::I32 }
}

fn exec(name: String, role: &str, inputs: Vec<ArgSpec>, outputs: Vec<ArgSpec>) -> ExecSpec {
    ExecSpec { file: format!("{name}.hlo.txt"), name, role: role.to_string(), inputs, outputs }
}

/// Build the full executable inventory for a model, mirroring
/// `aot.py::executable_inventory` name for name and shape for shape.
fn executables(m: &ModelInfo) -> Vec<ExecSpec> {
    let (b, s, s0) = (m.bs, m.seq, m.seq0);
    let (hs, pd, hsl, ffl, cl) = (m.hs, m.pd, m.hsl, m.ffl, m.classes);
    let x3: &[usize] = &[b, s, hs];
    let mut inv = Vec::new();

    inv.push(exec(
        "embed_fwd".to_string(),
        "embed_fwd",
        vec![
            f32_spec("patches", &[b, s0, pd]),
            f32_spec("w_patch", &[pd, hs]),
            f32_spec("pos", &[s, hs]),
            f32_spec("cls", &[hs]),
        ],
        vec![f32_spec("x0", x3)],
    ));
    inv.push(exec(
        "embed_bwd".to_string(),
        "embed_bwd",
        vec![
            f32_spec("patches", &[b, s0, pd]),
            f32_spec("w_patch", &[pd, hs]),
            f32_spec("pos", &[s, hs]),
            f32_spec("cls", &[hs]),
            f32_spec("dy", x3),
        ],
        vec![
            f32_spec("dw_patch", &[pd, hs]),
            f32_spec("dpos", &[s, hs]),
            f32_spec("dcls", &[hs]),
        ],
    ));
    let head_inputs = || {
        vec![
            f32_spec("x", x3),
            f32_spec("lnf_g", &[hs]),
            f32_spec("lnf_b", &[hs]),
            f32_spec("w_head", &[hs, cl]),
            f32_spec("b_head", &[cl]),
            i32_spec("labels", &[b]),
        ]
    };
    inv.push(exec(
        "head_fwdbwd".to_string(),
        "head_fwdbwd",
        head_inputs(),
        vec![
            f32_spec("loss", &[]),
            i32_spec("ncorrect", &[]),
            f32_spec("dx", x3),
            f32_spec("dlnf_g", &[hs]),
            f32_spec("dlnf_b", &[hs]),
            f32_spec("dw_head", &[hs, cl]),
            f32_spec("db_head", &[cl]),
        ],
    ));
    inv.push(exec(
        "head_infer".to_string(),
        "head_infer",
        head_inputs(),
        vec![f32_spec("loss", &[]), i32_spec("ncorrect", &[])],
    ));

    for &frac in &KEEP_FRACS {
        let kq = keep_count(hs, frac);
        let bname = bucket_name(frac);
        let attn_inputs = || {
            vec![
                f32_spec("x", x3),
                f32_spec("ln1_g", &[hs]),
                f32_spec("ln1_b", &[hs]),
                f32_spec("wqkv", &[hs, 3 * hsl]),
                f32_spec("wo", &[hsl, hs]),
                i32_spec("idx", &[kq]),
                f32_spec("mask", &[kq]),
            ]
        };
        inv.push(exec(
            format!("attn_fwd_{bname}"),
            "attn_fwd",
            attn_inputs(),
            vec![f32_spec("y_partial", x3)],
        ));
        let mut bwd_in = attn_inputs();
        bwd_in.push(f32_spec("dy", x3));
        inv.push(exec(
            format!("attn_bwd_{bname}"),
            "attn_bwd",
            bwd_in,
            vec![
                f32_spec("dx", x3),
                f32_spec("dln1_g", &[hs]),
                f32_spec("dln1_b", &[hs]),
                f32_spec("dwqkv", &[hs, 3 * hsl]),
                f32_spec("dwo", &[hsl, hs]),
            ],
        ));
    }

    // The FULL bucket cross-product: differentiated per-layer ratios
    // (Alg. 1) pick FC1's and FC2's buckets independently, so any (b1, b2)
    // pair can be requested.  aot.py compiles only the diagonal + (g00, b)
    // column combos (compile time is per-variant there); the native
    // backend pays nothing per variant, so it covers the whole grid —
    // see DESIGN.md §3.
    let mut combos: Vec<(f64, f64)> = Vec::new();
    for &f1 in &KEEP_FRACS {
        for &f2 in &KEEP_FRACS {
            combos.push((f1, f2));
        }
    }
    for (f1, f2) in combos {
        let (k1, k2) = (keep_count(hs, f1), keep_count(ffl, f2));
        let (b1, b2) = (bucket_name(f1), bucket_name(f2));
        let suffix = if f1 == f2 { b1 } else { format!("{b1}_{b2}") };
        let mlp_inputs = || {
            vec![
                f32_spec("x", x3),
                f32_spec("ln2_g", &[hs]),
                f32_spec("ln2_b", &[hs]),
                f32_spec("w1", &[hs, ffl]),
                f32_spec("w2", &[ffl, hs]),
                i32_spec("idx1", &[k1]),
                f32_spec("mask1", &[k1]),
                i32_spec("idx2", &[k2]),
                f32_spec("mask2", &[k2]),
            ]
        };
        inv.push(exec(
            format!("mlp_fwd_{suffix}"),
            "mlp_fwd",
            mlp_inputs(),
            vec![f32_spec("y_partial", x3)],
        ));
        let mut bwd_in = mlp_inputs();
        bwd_in.push(f32_spec("dy", x3));
        inv.push(exec(
            format!("mlp_bwd_{suffix}"),
            "mlp_bwd",
            bwd_in,
            vec![
                f32_spec("dx", x3),
                f32_spec("dln2_g", &[hs]),
                f32_spec("dln2_b", &[hs]),
                f32_spec("dw1", &[hs, ffl]),
                f32_spec("dw2", &[ffl, hs]),
            ],
        ));
    }

    for kb in mig_buckets(ffl) {
        inv.push(exec(
            format!("mlp_mig_fwd_k{kb}"),
            "mlp_mig_fwd",
            vec![
                f32_spec("x", x3),
                f32_spec("ln2_g", &[hs]),
                f32_spec("ln2_b", &[hs]),
                f32_spec("w1c", &[hs, kb]),
                f32_spec("w2c", &[kb, hs]),
            ],
            vec![f32_spec("y_partial", x3)],
        ));
        inv.push(exec(
            format!("mlp_mig_bwd_k{kb}"),
            "mlp_mig_bwd",
            vec![
                f32_spec("x", x3),
                f32_spec("ln2_g", &[hs]),
                f32_spec("ln2_b", &[hs]),
                f32_spec("w1c", &[hs, kb]),
                f32_spec("w2c", &[kb, hs]),
                f32_spec("dy", x3),
            ],
            vec![
                f32_spec("dx_partial", x3),
                f32_spec("dln2_g", &[hs]),
                f32_spec("dln2_b", &[hs]),
                f32_spec("dw1c", &[hs, kb]),
                f32_spec("dw2c", &[kb, hs]),
            ],
        ));
    }
    inv
}

fn mig_buckets(ffl: usize) -> Vec<usize> {
    let mut kbs: Vec<usize> = MIG_FRACS.iter().map(|&f| keep_count(ffl, f)).collect();
    kbs.sort_unstable();
    kbs.dedup();
    kbs
}

/// Synthesize a full manifest for a preset model (the aot.py output,
/// minus the HLO files the native backend does not need).
pub fn synthesize(name: &str) -> Result<Manifest> {
    let p = preset(name)?;
    synthesize_preset(p, Degrees::uniform(p.e))
}

/// Largest valid attention degree ≤ `want`: attention panels slice in
/// whole heads, so the degree must divide `hs` *and* `heads`.  `want ≥ 1`
/// guarantees a result (1 divides everything).
pub fn attn_degree_floor(hs: usize, heads: usize, want: usize) -> usize {
    (1..=want.max(1))
        .rev()
        .find(|&d| hs % d == 0 && heads % d == 0)
        .expect("d=1 always divides")
}

/// Clamp a requested per-component degree vector onto worker count `e`:
/// each degree drops to the largest value ≤ min(requested, e) that still
/// divides its component's contraction at the component's own
/// granularity (embed/head: hs; MLP: 4·hs; attention: hs *and* heads).
/// This is the degree-aware form of the churn path's nearest-divisor
/// degradation — a uniform request reproduces it exactly.
pub fn clamp_degrees(hs: usize, heads: usize, req: Degrees, e: usize) -> Degrees {
    let floor = |granule: usize, want: usize| -> usize {
        (1..=want.min(e).max(1))
            .rev()
            .find(|&d| granule % d == 0)
            .expect("d=1 always divides")
    };
    Degrees {
        embed: floor(hs, req.embed),
        attn: attn_degree_floor(hs, heads, req.attn.min(e)),
        mlp: floor(MLP_RATIO * hs, req.mlp),
        head: floor(hs, req.head),
    }
}

/// Synthesize a preset's manifest at a **different worker count** — the
/// elastic-resume target geometry (`--e`, DESIGN.md §13).  The model
/// itself (hs, depth, heads, batch) is unchanged; only the 1D-TP shard
/// widths (`hsl`, `ffl`, `hl`) re-derive.  `e` must divide `hs` (the
/// hs-granular components slice lane-aligned panels); attention — the
/// only component that also slices whole heads — clamps to the largest
/// degree ≤ `e` dividing both `hs` and `heads`, instead of rejecting
/// targets where `e ∤ heads` outright (historically the check demanded
/// `e | heads` for every component, including the ones that never touch
/// head panels).
pub fn synthesize_with_e(name: &str, e: usize) -> Result<Manifest> {
    let p = preset(name)?;
    let mut d = Degrees::uniform(e);
    if e >= 1 {
        d.attn = attn_degree_floor(p.hs, p.heads, e);
    }
    synthesize_with_degrees(name, e, d)
}

/// Synthesize a preset's manifest with an explicit per-component degree
/// vector over `e` workers (fine-grained TP, DESIGN.md §18).  Each
/// degree must already be valid — use [`clamp_degrees`] first when the
/// vector comes from user input or a churn transition.
pub fn synthesize_with_degrees(name: &str, e: usize, degrees: Degrees) -> Result<Manifest> {
    let mut p = preset(name)?;
    ensure!(e >= 1, "worker count must be ≥ 1");
    ensure!(
        p.hs % e == 0,
        "'{name}' cannot be sharded over {e} workers: e must divide hs={}",
        p.hs,
    );
    for (what, d) in [
        ("embed", degrees.embed),
        ("attn", degrees.attn),
        ("mlp", degrees.mlp),
        ("head", degrees.head),
    ] {
        ensure!(
            d >= 1 && d <= e,
            "'{name}': {what} degree {d} must be in 1..={e} (the worker count)"
        );
    }
    ensure!(
        p.hs % degrees.embed == 0,
        "'{name}': embed degree {} must divide hs={}",
        degrees.embed,
        p.hs,
    );
    ensure!(
        p.hs % degrees.head == 0,
        "'{name}': head degree {} must divide hs={}",
        degrees.head,
        p.hs,
    );
    ensure!(
        (MLP_RATIO * p.hs) % degrees.mlp == 0,
        "'{name}': mlp degree {} must divide 4·hs={}",
        degrees.mlp,
        MLP_RATIO * p.hs,
    );
    ensure!(
        p.hs % degrees.attn == 0 && p.heads % degrees.attn == 0,
        "'{name}': attn degree {} must divide hs={} and heads={} \
         (valid: divisors of {})",
        degrees.attn,
        p.hs,
        p.heads,
        crate::util::gcd(p.hs, p.heads),
    );
    p.e = e;
    synthesize_preset(p, degrees)
}

fn synthesize_preset(p: Preset, degrees: Degrees) -> Result<Manifest> {
    let m = model_info(&p, degrees);
    let buckets = KEEP_FRACS
        .iter()
        .map(|&f| Bucket {
            name: bucket_name(f),
            gamma: 1.0 - f,
            keep_hs: keep_count(m.hs, f),
            keep_ffl: keep_count(m.ffl, f),
        })
        .collect();
    Ok(Manifest {
        executables: executables(&m),
        mig_buckets: mig_buckets(m.ffl),
        buckets,
        model: m,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keep_counts_match_python_rule() {
        assert_eq!(keep_count(128, 1.0), 128);
        assert_eq!(keep_count(128, 0.75), 96);
        assert_eq!(keep_count(128, 0.5), 64);
        assert_eq!(keep_count(128, 0.125), 16);
        assert_eq!(keep_count(8, 0.125), 8); // floor at lane width
        assert_eq!(bucket_name(1.0), "g00");
        assert_eq!(bucket_name(0.125), "g88");
        assert_eq!(bucket_name(0.75), "g25");
    }

    #[test]
    fn vit_tiny_derivations() {
        let p = preset("vit-tiny").unwrap();
        let m = model_info(&p, Degrees::uniform(p.e));
        assert_eq!(m.seq0, 64);
        assert_eq!(m.seq, 65);
        assert_eq!(m.pd, 48);
        assert_eq!(m.hsl, 32);
        assert_eq!(m.hl, 1);
        assert_eq!(m.hd, 32);
        assert_eq!(m.ffl, 128);
        assert!(m.params_total > m.params_per_worker);
    }

    #[test]
    fn synthesized_manifest_has_full_inventory() {
        let man = synthesize("vit-tiny").unwrap();
        // 4 fixed + 5*2 attn + 25*2 mlp (full bucket grid) + 3*2 mig
        assert_eq!(man.executables.len(), 4 + 10 + 50 + 6);
        assert!(man.exec("embed_fwd").is_ok());
        assert!(man.exec("attn_fwd_g00").is_ok());
        assert!(man.exec("attn_bwd_g88").is_ok());
        assert!(man.exec("mlp_fwd_g50").is_ok());
        assert!(man.exec("mlp_bwd_g00_g50").is_ok());
        assert!(man.exec("mlp_mig_fwd_k64").is_ok());
        assert_eq!(man.mig_buckets, vec![16, 32, 64]);
        assert_eq!(man.buckets.len(), 5);
        assert_eq!(man.buckets[0].name, "g00");
        assert_eq!(man.bucket_for_gamma(0.3).name, "g50");
    }

    #[test]
    fn synthesized_specs_follow_naming_contract() {
        let man = synthesize("vit-tiny").unwrap();
        // trainer resolves names via these helpers — every combination the
        // planners can produce (independent FC1/FC2 buckets included)
        // must exist in the inventory
        for b in &man.buckets {
            assert!(man.exec(&man.attn_name("fwd", &b.name)).is_ok());
            assert!(man.exec(&man.attn_name("bwd", &b.name)).is_ok());
            for b2 in &man.buckets {
                assert!(man.exec(&man.mlp_name("fwd", &b.name, &b2.name)).is_ok());
                assert!(man.exec(&man.mlp_name("bwd", &b.name, &b2.name)).is_ok());
            }
        }
        for &kb in &man.mig_buckets {
            assert!(man.exec(&man.mig_name("fwd", kb)).is_ok());
            assert!(man.exec(&man.mig_name("bwd", kb)).is_ok());
        }
    }

    #[test]
    fn unknown_preset_rejected() {
        assert!(preset("vit-9000").is_err());
        assert!(synthesize("vit-9000").is_err());
    }

    #[test]
    fn synthesize_with_e_rederives_shard_widths() {
        // vit-tiny default e=4; elastic at e=2 doubles every shard width
        let man = synthesize_with_e("vit-tiny", 2).unwrap();
        let m = &man.model;
        assert_eq!(m.e, 2);
        assert_eq!(m.hsl, 64);
        assert_eq!(m.hl, 2);
        assert_eq!(m.ffl, 256);
        assert_eq!(m.hd, 32, "head dim is e-independent");
        // the whole inventory re-derives against the new widths
        assert!(man.exec("mlp_fwd_g00").is_ok());
        assert_eq!(man.buckets[0].keep_ffl, 256);
        // default-e synthesis is unchanged
        let d = synthesize_with_e("vit-tiny", 4).unwrap();
        assert_eq!(d.model.hsl, synthesize("vit-tiny").unwrap().model.hsl);
    }

    #[test]
    fn synthesize_with_e_rejects_indivisible_targets() {
        // vit-tiny: hs=128 → e=3 violates hs; e=0 is nonsense
        assert!(synthesize_with_e("vit-tiny", 3).is_err());
        assert!(synthesize_with_e("vit-tiny", 0).is_err());
        // vit-s: hs=256, heads=8 → 1, 2, 4, 8 all valid and uniform
        for e in [1usize, 2, 4, 8] {
            let man = synthesize_with_e("vit-s", e).unwrap();
            assert!(man.model.degrees.is_uniform(e), "e={e}");
        }
    }

    #[test]
    fn e_dividing_hs_but_not_heads_clamps_attn_only() {
        // the historic check rejected any e ∤ heads even though only
        // attention slices head panels; now the hs-granular components
        // run at e and attention clamps to the largest whole-head degree
        let man = synthesize_with_e("vit-tiny", 8).unwrap(); // hs=128, heads=4
        let m = &man.model;
        assert_eq!(m.e, 8);
        assert_eq!(m.degrees, Degrees { embed: 8, attn: 4, mlp: 8, head: 8 });
        assert_eq!(m.hsl, 32, "attn widths follow the clamped degree");
        assert_eq!(m.hl, 1);
        assert_eq!(m.ffl, 64, "mlp width follows the full worker count");
        // vit-100m: hs=768, heads=12 → e=8 divides hs, heads%8=4;
        // attention lands on 6 (the largest divisor of both ≤ 8)
        let man = synthesize_with_e("vit-100m", 8).unwrap();
        assert_eq!(man.model.degrees.attn, 6);
        assert_eq!(man.model.hsl, 128);
        assert_eq!(man.model.hl, 2);
    }

    #[test]
    fn synthesize_with_degrees_validates_per_component() {
        // a mixed vector: attn/mlp at 2, embed/head at the full count
        let d = Degrees { embed: 4, attn: 2, mlp: 2, head: 4 };
        let man = synthesize_with_degrees("vit-tiny", 4, d).unwrap();
        let m = &man.model;
        assert_eq!(m.degrees, d);
        assert_eq!(m.hsl, 64, "hsl = hs/degrees.attn");
        assert_eq!(m.hl, 2);
        assert_eq!(m.ffl, 256, "ffl = 4·hs/degrees.mlp");
        // the executable inventory re-derives against the mixed widths
        assert!(man.exec("attn_fwd_g00").is_ok());
        assert_eq!(man.buckets[0].keep_ffl, 256);
        // degrees above the worker count are rejected
        let too_big = Degrees { attn: 8, ..Degrees::uniform(4) };
        assert!(synthesize_with_degrees("vit-tiny", 4, too_big).is_err());
        // attention degree must keep whole heads (heads=4: no degree 8
        // even over 8 workers... but 8 divides hs so mlp may use it)
        let bad_attn = Degrees { attn: 8, ..Degrees::uniform(8) };
        assert!(synthesize_with_degrees("vit-tiny", 8, bad_attn).is_err());
        let ok_mlp = Degrees { attn: 4, ..Degrees::uniform(8) };
        assert!(synthesize_with_degrees("vit-tiny", 8, ok_mlp).is_ok());
        // degree 0 is rejected
        let zero = Degrees { mlp: 0, ..Degrees::uniform(4) };
        assert!(synthesize_with_degrees("vit-tiny", 4, zero).is_err());
        // uniform degrees reproduce synthesize() exactly
        let u = synthesize_with_degrees("vit-tiny", 4, Degrees::uniform(4)).unwrap();
        let s = synthesize("vit-tiny").unwrap();
        assert_eq!(u.model.hsl, s.model.hsl);
        assert_eq!(u.model.ffl, s.model.ffl);
        assert_eq!(u.model.params_per_worker, s.model.params_per_worker);
        assert_eq!(u.executables.len(), s.executables.len());
    }

    #[test]
    fn clamp_degrees_degrades_per_component() {
        // uniform request over a shrinking worker pool reproduces the
        // churn path's nearest-divisor behavior per component
        let req = Degrees::uniform(4);
        assert_eq!(clamp_degrees(128, 4, req, 4), Degrees::uniform(4));
        // 3 workers: hs=128 % 3 ≠ 0 → hs-granular components drop to 2;
        // 4·hs=512 % 3 ≠ 0 too
        assert_eq!(clamp_degrees(128, 4, req, 3), Degrees::uniform(2));
        // mixed request survives a clamp that doesn't constrain it
        let mixed = Degrees { embed: 4, attn: 2, mlp: 2, head: 4 };
        assert_eq!(clamp_degrees(128, 4, mixed, 4), mixed);
        // ... and degrades component-wise when the pool shrinks
        let clamped = clamp_degrees(128, 4, mixed, 2);
        assert_eq!(clamped, Degrees { embed: 2, attn: 2, mlp: 2, head: 2 });
        // attention respects heads where the others don't: over 8
        // workers a uniform request lands attn on 4, everything else 8
        let wide = clamp_degrees(128, 4, Degrees::uniform(8), 8);
        assert_eq!(wide, Degrees { embed: 8, attn: 4, mlp: 8, head: 8 });
        assert_eq!(attn_degree_floor(768, 12, 8), 6);
    }
}
