//! Built-in model presets and manifest synthesis.
//!
//! The native backend needs no AOT artifacts: when `artifacts/<model>/`
//! does not exist, the manifest (model facts, pruning buckets, executable
//! inventory) is synthesized here from the same presets and derivation
//! rules as `python/compile/model.py` + `aot.py`.  The synthesized
//! manifest is byte-for-byte equivalent in structure to a compiled one —
//! names, roles, shapes, and bucket sizes all follow the aot.py contract —
//! so the trainer, balancers, and tests run identically on either source.

use anyhow::{bail, ensure, Result};

use super::manifest::{ArgSpec, Bucket, Dtype, ExecSpec, Manifest, ModelInfo};

/// Static pruning buckets: fraction of the contraction that SURVIVES
/// (γ = 1 − keep_frac), mirroring `model.KEEP_FRACS`.
pub const KEEP_FRACS: [f64; 5] = [1.0, 0.75, 0.5, 0.25, 0.125];

/// Migration-slice buckets over ffl, mirroring `model.MIG_FRACS`.
pub const MIG_FRACS: [f64; 3] = [0.5, 0.25, 0.125];

const IMG: usize = 32;
const PATCH: usize = 4;
const CHANS: usize = 3;
const CLASSES: usize = 10;
const MLP_RATIO: usize = 4;

/// One artifact-set preset (mirrors python `ModelCfg` presets).
#[derive(Debug, Clone, Copy)]
pub struct Preset {
    pub name: &'static str,
    pub hs: usize,
    pub depth: usize,
    pub heads: usize,
    pub e: usize,
    pub bs: usize,
}

/// The preset table from `python/compile/model.py` (vit-s / vit-m are the
/// ViT-1B / ViT-3B scale stand-ins — DESIGN.md §2).
pub const PRESETS: [Preset; 4] = [
    Preset { name: "vit-tiny", hs: 128, depth: 2, heads: 4, e: 4, bs: 8 },
    Preset { name: "vit-s", hs: 256, depth: 4, heads: 8, e: 8, bs: 16 },
    Preset { name: "vit-m", hs: 384, depth: 6, heads: 8, e: 8, bs: 16 },
    Preset { name: "vit-100m", hs: 768, depth: 12, heads: 12, e: 4, bs: 8 },
];

/// Look up a preset by name.
pub fn preset(name: &str) -> Result<Preset> {
    match PRESETS.iter().copied().find(|p| p.name == name) {
        Some(p) => Ok(p),
        None => bail!(
            "unknown model '{name}' and no artifacts on disk \
             (presets: vit-tiny|vit-s|vit-m|vit-100m)"
        ),
    }
}

/// Bucket keep-size: multiple of 8 (lane width), at least 8.
pub fn keep_count(k: usize, frac: f64) -> usize {
    (((k as f64 * frac / 8.0).round() as usize) * 8).max(8)
}

/// Bucket suffix by pruning percentage, e.g. keep-frac 0.75 → "g25".
pub fn bucket_name(frac: f64) -> String {
    format!("g{:02}", ((1.0 - frac) * 100.0).round() as i64)
}

fn model_info(p: &Preset) -> ModelInfo {
    let seq0 = (IMG / PATCH) * (IMG / PATCH);
    let seq = seq0 + 1;
    let pd = CHANS * PATCH * PATCH;
    let hsl = p.hs / p.e;
    let hl = p.heads / p.e;
    let hd = p.hs / p.heads;
    let ffl = MLP_RATIO * p.hs / p.e;
    // per-worker: shard of every block + one replica of embed/head
    let blk_w = 4 * p.hs + p.hs * 3 * hsl + hsl * p.hs + p.hs * ffl + ffl * p.hs;
    let emb = pd * p.hs + seq * p.hs + p.hs;
    let head = 2 * p.hs + p.hs * CLASSES + CLASSES;
    let params_per_worker = p.depth * blk_w + emb + head;
    // global: full (unsharded) blocks + one replica set
    let blk = 4 * p.hs
        + p.hs * 3 * p.hs
        + p.hs * p.hs
        + p.hs * MLP_RATIO * p.hs
        + MLP_RATIO * p.hs * p.hs;
    let params_total = p.depth * blk + emb + head;
    ModelInfo {
        name: p.name.to_string(),
        hs: p.hs,
        depth: p.depth,
        heads: p.heads,
        e: p.e,
        bs: p.bs,
        classes: CLASSES,
        seq,
        seq0,
        pd,
        hsl,
        hl,
        hd,
        ffl,
        params_total,
        params_per_worker,
    }
}

fn f32_spec(name: &str, dims: &[usize]) -> ArgSpec {
    ArgSpec { name: name.to_string(), dims: dims.to_vec(), dtype: Dtype::F32 }
}

fn i32_spec(name: &str, dims: &[usize]) -> ArgSpec {
    ArgSpec { name: name.to_string(), dims: dims.to_vec(), dtype: Dtype::I32 }
}

fn exec(name: String, role: &str, inputs: Vec<ArgSpec>, outputs: Vec<ArgSpec>) -> ExecSpec {
    ExecSpec { file: format!("{name}.hlo.txt"), name, role: role.to_string(), inputs, outputs }
}

/// Build the full executable inventory for a model, mirroring
/// `aot.py::executable_inventory` name for name and shape for shape.
fn executables(m: &ModelInfo) -> Vec<ExecSpec> {
    let (b, s, s0) = (m.bs, m.seq, m.seq0);
    let (hs, pd, hsl, ffl, cl) = (m.hs, m.pd, m.hsl, m.ffl, m.classes);
    let x3: &[usize] = &[b, s, hs];
    let mut inv = Vec::new();

    inv.push(exec(
        "embed_fwd".to_string(),
        "embed_fwd",
        vec![
            f32_spec("patches", &[b, s0, pd]),
            f32_spec("w_patch", &[pd, hs]),
            f32_spec("pos", &[s, hs]),
            f32_spec("cls", &[hs]),
        ],
        vec![f32_spec("x0", x3)],
    ));
    inv.push(exec(
        "embed_bwd".to_string(),
        "embed_bwd",
        vec![
            f32_spec("patches", &[b, s0, pd]),
            f32_spec("w_patch", &[pd, hs]),
            f32_spec("pos", &[s, hs]),
            f32_spec("cls", &[hs]),
            f32_spec("dy", x3),
        ],
        vec![
            f32_spec("dw_patch", &[pd, hs]),
            f32_spec("dpos", &[s, hs]),
            f32_spec("dcls", &[hs]),
        ],
    ));
    let head_inputs = || {
        vec![
            f32_spec("x", x3),
            f32_spec("lnf_g", &[hs]),
            f32_spec("lnf_b", &[hs]),
            f32_spec("w_head", &[hs, cl]),
            f32_spec("b_head", &[cl]),
            i32_spec("labels", &[b]),
        ]
    };
    inv.push(exec(
        "head_fwdbwd".to_string(),
        "head_fwdbwd",
        head_inputs(),
        vec![
            f32_spec("loss", &[]),
            i32_spec("ncorrect", &[]),
            f32_spec("dx", x3),
            f32_spec("dlnf_g", &[hs]),
            f32_spec("dlnf_b", &[hs]),
            f32_spec("dw_head", &[hs, cl]),
            f32_spec("db_head", &[cl]),
        ],
    ));
    inv.push(exec(
        "head_infer".to_string(),
        "head_infer",
        head_inputs(),
        vec![f32_spec("loss", &[]), i32_spec("ncorrect", &[])],
    ));

    for &frac in &KEEP_FRACS {
        let kq = keep_count(hs, frac);
        let bname = bucket_name(frac);
        let attn_inputs = || {
            vec![
                f32_spec("x", x3),
                f32_spec("ln1_g", &[hs]),
                f32_spec("ln1_b", &[hs]),
                f32_spec("wqkv", &[hs, 3 * hsl]),
                f32_spec("wo", &[hsl, hs]),
                i32_spec("idx", &[kq]),
                f32_spec("mask", &[kq]),
            ]
        };
        inv.push(exec(
            format!("attn_fwd_{bname}"),
            "attn_fwd",
            attn_inputs(),
            vec![f32_spec("y_partial", x3)],
        ));
        let mut bwd_in = attn_inputs();
        bwd_in.push(f32_spec("dy", x3));
        inv.push(exec(
            format!("attn_bwd_{bname}"),
            "attn_bwd",
            bwd_in,
            vec![
                f32_spec("dx", x3),
                f32_spec("dln1_g", &[hs]),
                f32_spec("dln1_b", &[hs]),
                f32_spec("dwqkv", &[hs, 3 * hsl]),
                f32_spec("dwo", &[hsl, hs]),
            ],
        ));
    }

    // The FULL bucket cross-product: differentiated per-layer ratios
    // (Alg. 1) pick FC1's and FC2's buckets independently, so any (b1, b2)
    // pair can be requested.  aot.py compiles only the diagonal + (g00, b)
    // column combos (compile time is per-variant there); the native
    // backend pays nothing per variant, so it covers the whole grid —
    // see DESIGN.md §3.
    let mut combos: Vec<(f64, f64)> = Vec::new();
    for &f1 in &KEEP_FRACS {
        for &f2 in &KEEP_FRACS {
            combos.push((f1, f2));
        }
    }
    for (f1, f2) in combos {
        let (k1, k2) = (keep_count(hs, f1), keep_count(ffl, f2));
        let (b1, b2) = (bucket_name(f1), bucket_name(f2));
        let suffix = if f1 == f2 { b1 } else { format!("{b1}_{b2}") };
        let mlp_inputs = || {
            vec![
                f32_spec("x", x3),
                f32_spec("ln2_g", &[hs]),
                f32_spec("ln2_b", &[hs]),
                f32_spec("w1", &[hs, ffl]),
                f32_spec("w2", &[ffl, hs]),
                i32_spec("idx1", &[k1]),
                f32_spec("mask1", &[k1]),
                i32_spec("idx2", &[k2]),
                f32_spec("mask2", &[k2]),
            ]
        };
        inv.push(exec(
            format!("mlp_fwd_{suffix}"),
            "mlp_fwd",
            mlp_inputs(),
            vec![f32_spec("y_partial", x3)],
        ));
        let mut bwd_in = mlp_inputs();
        bwd_in.push(f32_spec("dy", x3));
        inv.push(exec(
            format!("mlp_bwd_{suffix}"),
            "mlp_bwd",
            bwd_in,
            vec![
                f32_spec("dx", x3),
                f32_spec("dln2_g", &[hs]),
                f32_spec("dln2_b", &[hs]),
                f32_spec("dw1", &[hs, ffl]),
                f32_spec("dw2", &[ffl, hs]),
            ],
        ));
    }

    for kb in mig_buckets(ffl) {
        inv.push(exec(
            format!("mlp_mig_fwd_k{kb}"),
            "mlp_mig_fwd",
            vec![
                f32_spec("x", x3),
                f32_spec("ln2_g", &[hs]),
                f32_spec("ln2_b", &[hs]),
                f32_spec("w1c", &[hs, kb]),
                f32_spec("w2c", &[kb, hs]),
            ],
            vec![f32_spec("y_partial", x3)],
        ));
        inv.push(exec(
            format!("mlp_mig_bwd_k{kb}"),
            "mlp_mig_bwd",
            vec![
                f32_spec("x", x3),
                f32_spec("ln2_g", &[hs]),
                f32_spec("ln2_b", &[hs]),
                f32_spec("w1c", &[hs, kb]),
                f32_spec("w2c", &[kb, hs]),
                f32_spec("dy", x3),
            ],
            vec![
                f32_spec("dx_partial", x3),
                f32_spec("dln2_g", &[hs]),
                f32_spec("dln2_b", &[hs]),
                f32_spec("dw1c", &[hs, kb]),
                f32_spec("dw2c", &[kb, hs]),
            ],
        ));
    }
    inv
}

fn mig_buckets(ffl: usize) -> Vec<usize> {
    let mut kbs: Vec<usize> = MIG_FRACS.iter().map(|&f| keep_count(ffl, f)).collect();
    kbs.sort_unstable();
    kbs.dedup();
    kbs
}

/// Synthesize a full manifest for a preset model (the aot.py output,
/// minus the HLO files the native backend does not need).
pub fn synthesize(name: &str) -> Result<Manifest> {
    let p = preset(name)?;
    synthesize_preset(p)
}

/// Synthesize a preset's manifest at a **different worker count** — the
/// elastic-resume target geometry (`--e`, DESIGN.md §13).  The model
/// itself (hs, depth, heads, batch) is unchanged; only the 1D-TP shard
/// widths (`hsl = hs/e`, `ffl = 4·hs/e`, `hl = heads/e`) re-derive.
/// Valid targets must divide both `hs` and `heads` so every worker gets
/// whole attention heads and lane-aligned FFN slices.
pub fn synthesize_with_e(name: &str, e: usize) -> Result<Manifest> {
    let mut p = preset(name)?;
    ensure!(e >= 1, "worker count must be ≥ 1");
    ensure!(
        p.hs % e == 0 && p.heads % e == 0,
        "'{name}' cannot be sharded over {e} workers: e must divide \
         hs={} and heads={} (valid: divisors of {})",
        p.hs,
        p.heads,
        crate::util::gcd(p.hs, p.heads),
    );
    p.e = e;
    synthesize_preset(p)
}

fn synthesize_preset(p: Preset) -> Result<Manifest> {
    let m = model_info(&p);
    let buckets = KEEP_FRACS
        .iter()
        .map(|&f| Bucket {
            name: bucket_name(f),
            gamma: 1.0 - f,
            keep_hs: keep_count(m.hs, f),
            keep_ffl: keep_count(m.ffl, f),
        })
        .collect();
    Ok(Manifest {
        executables: executables(&m),
        mig_buckets: mig_buckets(m.ffl),
        buckets,
        model: m,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keep_counts_match_python_rule() {
        assert_eq!(keep_count(128, 1.0), 128);
        assert_eq!(keep_count(128, 0.75), 96);
        assert_eq!(keep_count(128, 0.5), 64);
        assert_eq!(keep_count(128, 0.125), 16);
        assert_eq!(keep_count(8, 0.125), 8); // floor at lane width
        assert_eq!(bucket_name(1.0), "g00");
        assert_eq!(bucket_name(0.125), "g88");
        assert_eq!(bucket_name(0.75), "g25");
    }

    #[test]
    fn vit_tiny_derivations() {
        let m = model_info(&preset("vit-tiny").unwrap());
        assert_eq!(m.seq0, 64);
        assert_eq!(m.seq, 65);
        assert_eq!(m.pd, 48);
        assert_eq!(m.hsl, 32);
        assert_eq!(m.hl, 1);
        assert_eq!(m.hd, 32);
        assert_eq!(m.ffl, 128);
        assert!(m.params_total > m.params_per_worker);
    }

    #[test]
    fn synthesized_manifest_has_full_inventory() {
        let man = synthesize("vit-tiny").unwrap();
        // 4 fixed + 5*2 attn + 25*2 mlp (full bucket grid) + 3*2 mig
        assert_eq!(man.executables.len(), 4 + 10 + 50 + 6);
        assert!(man.exec("embed_fwd").is_ok());
        assert!(man.exec("attn_fwd_g00").is_ok());
        assert!(man.exec("attn_bwd_g88").is_ok());
        assert!(man.exec("mlp_fwd_g50").is_ok());
        assert!(man.exec("mlp_bwd_g00_g50").is_ok());
        assert!(man.exec("mlp_mig_fwd_k64").is_ok());
        assert_eq!(man.mig_buckets, vec![16, 32, 64]);
        assert_eq!(man.buckets.len(), 5);
        assert_eq!(man.buckets[0].name, "g00");
        assert_eq!(man.bucket_for_gamma(0.3).name, "g50");
    }

    #[test]
    fn synthesized_specs_follow_naming_contract() {
        let man = synthesize("vit-tiny").unwrap();
        // trainer resolves names via these helpers — every combination the
        // planners can produce (independent FC1/FC2 buckets included)
        // must exist in the inventory
        for b in &man.buckets {
            assert!(man.exec(&man.attn_name("fwd", &b.name)).is_ok());
            assert!(man.exec(&man.attn_name("bwd", &b.name)).is_ok());
            for b2 in &man.buckets {
                assert!(man.exec(&man.mlp_name("fwd", &b.name, &b2.name)).is_ok());
                assert!(man.exec(&man.mlp_name("bwd", &b.name, &b2.name)).is_ok());
            }
        }
        for &kb in &man.mig_buckets {
            assert!(man.exec(&man.mig_name("fwd", kb)).is_ok());
            assert!(man.exec(&man.mig_name("bwd", kb)).is_ok());
        }
    }

    #[test]
    fn unknown_preset_rejected() {
        assert!(preset("vit-9000").is_err());
        assert!(synthesize("vit-9000").is_err());
    }

    #[test]
    fn synthesize_with_e_rederives_shard_widths() {
        // vit-tiny default e=4; elastic at e=2 doubles every shard width
        let man = synthesize_with_e("vit-tiny", 2).unwrap();
        let m = &man.model;
        assert_eq!(m.e, 2);
        assert_eq!(m.hsl, 64);
        assert_eq!(m.hl, 2);
        assert_eq!(m.ffl, 256);
        assert_eq!(m.hd, 32, "head dim is e-independent");
        // the whole inventory re-derives against the new widths
        assert!(man.exec("mlp_fwd_g00").is_ok());
        assert_eq!(man.buckets[0].keep_ffl, 256);
        // default-e synthesis is unchanged
        let d = synthesize_with_e("vit-tiny", 4).unwrap();
        assert_eq!(d.model.hsl, synthesize("vit-tiny").unwrap().model.hsl);
    }

    #[test]
    fn synthesize_with_e_rejects_indivisible_targets() {
        // vit-tiny: hs=128, heads=4 → e=8 violates heads, e=3 violates hs
        assert!(synthesize_with_e("vit-tiny", 8).is_err());
        assert!(synthesize_with_e("vit-tiny", 3).is_err());
        assert!(synthesize_with_e("vit-tiny", 0).is_err());
        // vit-s: hs=256, heads=8 → 1, 2, 4, 8 all valid
        for e in [1usize, 2, 4, 8] {
            assert!(synthesize_with_e("vit-s", e).is_ok(), "e={e}");
        }
    }
}
