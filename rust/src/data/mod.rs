//! Synthetic labeled image dataset (CIFAR-10 substitute — DESIGN.md §2).
//!
//! Each class has a fixed random patch-space template; a sample is
//! `0.5·template[label] + 0.5·noise`.  The task is learnable by a small
//! ViT within a few epochs, which is what the paper's *relative* ACC
//! comparisons need (it explicitly does not target absolute accuracy).

use crate::runtime::manifest::ModelInfo;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Batch {
    /// `[bs, seq0, pd]` patch tensors (pre-patchified)
    pub patches: Tensor,
    /// `[bs]` class labels
    pub labels: Vec<i32>,
}

/// Deterministic synthetic dataset, generated batch-by-batch from a seed.
pub struct SynthData {
    templates: Vec<Vec<f32>>, // [classes][seq0*pd]
    bs: usize,
    seq0: usize,
    pd: usize,
    classes: usize,
    seed: u64,
}

impl SynthData {
    pub fn new(m: &ModelInfo, seed: u64) -> SynthData {
        let mut rng = Rng::new(seed ^ 0x7E3);
        let templates = (0..m.classes)
            .map(|_| rng.normal_vec(m.seq0 * m.pd, 1.0))
            .collect();
        SynthData {
            templates,
            bs: m.bs,
            seq0: m.seq0,
            pd: m.pd,
            classes: m.classes,
            seed,
        }
    }

    /// The i-th batch of a split ("train" or "eval" streams never collide).
    pub fn batch(&self, split: u64, i: u64) -> Batch {
        let mut rng = Rng::new(self.seed ^ (split << 32) ^ i.wrapping_mul(0x9E37));
        let n = self.seq0 * self.pd;
        let mut data = Vec::with_capacity(self.bs * n);
        let mut labels = Vec::with_capacity(self.bs);
        for _ in 0..self.bs {
            let label = rng.below(self.classes);
            labels.push(label as i32);
            let t = &self.templates[label];
            for j in 0..n {
                data.push(0.5 * t[j] + 0.5 * rng.normal());
            }
        }
        Batch {
            patches: Tensor::from_vec(&[self.bs, self.seq0, self.pd], data),
            labels,
        }
    }

    pub fn train_batch(&self, i: u64) -> Batch {
        self.batch(1, i)
    }

    pub fn eval_batch(&self, i: u64) -> Batch {
        self.batch(2, i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ModelInfo;

    fn info() -> ModelInfo {
        ModelInfo {
            name: "t".into(), hs: 32, depth: 1, heads: 4, e: 4, bs: 8,
            classes: 10, seq: 17, seq0: 16, pd: 48, hsl: 8, hl: 1, hd: 8,
            ffl: 32, params_total: 0, params_per_worker: 0,
            degrees: crate::runtime::manifest::Degrees::uniform(4),
        }
    }

    #[test]
    fn batches_deterministic() {
        let d = SynthData::new(&info(), 42);
        let a = d.train_batch(3);
        let b = d.train_batch(3);
        assert_eq!(a.patches.data, b.patches.data);
        assert_eq!(a.labels, b.labels);
        let c = d.train_batch(4);
        assert_ne!(a.patches.data, c.patches.data);
    }

    #[test]
    fn train_eval_streams_distinct() {
        let d = SynthData::new(&info(), 42);
        assert_ne!(d.train_batch(0).patches.data, d.eval_batch(0).patches.data);
    }

    #[test]
    fn labels_in_range_and_varied() {
        let d = SynthData::new(&info(), 1);
        let mut seen = std::collections::HashSet::new();
        for i in 0..16 {
            for &l in &d.batch(1, i).labels {
                assert!((0..10).contains(&l));
                seen.insert(l);
            }
        }
        assert!(seen.len() > 3, "labels collapsed: {seen:?}");
    }

    #[test]
    fn signal_present() {
        // same-class samples correlate more than cross-class ones
        let d = SynthData::new(&info(), 7);
        let mut by_class: std::collections::HashMap<i32, Vec<Vec<f32>>> = Default::default();
        for i in 0..32 {
            let b = d.batch(1, i);
            let n = 16 * 48;
            for (s, &l) in b.labels.iter().enumerate() {
                by_class.entry(l).or_default().push(b.patches.data[s * n..(s + 1) * n].to_vec());
            }
        }
        let corr = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>() / a.len() as f32
        };
        let (l0, l1) = {
            let mut keys: Vec<i32> = by_class.keys().copied().collect();
            keys.sort();
            (keys[0], keys[1])
        };
        let same = corr(&by_class[&l0][0], &by_class[&l0][1]);
        let diff = corr(&by_class[&l0][0], &by_class[&l1][0]);
        assert!(same > diff, "no class signal: same={same} diff={diff}");
    }
}
