//! The `.flexckpt` snapshot container (DESIGN.md §13).
//!
//! A checkpoint is one self-describing file:
//!
//! ```text
//! bytes 0..8    magic  b"FLEXTPCK"
//! bytes 8..12   u32 LE format version (readers reject newer versions)
//! bytes 12..20  u64 LE FNV-1a checksum of every byte after this field
//! bytes 20..24  u32 LE header length H
//! bytes 24..24+H JSON header: {"meta": {...}, "entries": [...]}
//! then          raw little-endian array payload ("the blob")
//! ```
//!
//! The JSON `meta` object carries every scalar of trainer state (clock
//! vectors, cursors, EWMA statistics, cached plans) — f64 values survive
//! the trip bitwise because Rust's shortest-roundtrip float formatting is
//! exact.  Bulk arrays (model shards, optimizer moments, tracker
//! statistics) live in the blob as typed [`Payload`] entries, each
//! declared in the header's `entries` table (name, dtype, byte offset,
//! element count).
//!
//! # Integrity contract
//!
//! Loading never panics and never partially succeeds: every failure mode
//! maps to a typed [`CkptError`] — wrong magic, newer version, truncation
//! at any byte, checksum mismatch (any bit flip after the checksum
//! field), or malformed header/entry tables.  Writing is atomic: the file
//! is assembled in memory, written to a `.tmp` sibling, fsynced, and
//! renamed into place, so a crash mid-save leaves either the old
//! checkpoint or a `.tmp` orphan that [`latest_in_dir`] ignores — never a
//! torn `.flexckpt`.

use std::collections::BTreeMap;
use std::fmt;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::util::json::{obj, Json};

/// File magic: identifies a flextp checkpoint regardless of extension.
pub const MAGIC: [u8; 8] = *b"FLEXTPCK";

/// Current container format version.  Readers accept `<= VERSION` and
/// reject newer files with [`CkptError::UnsupportedVersion`]; adding
/// fields to `meta` or new entry names is backward-compatible and does
/// NOT bump this (absent state restores to defaults where documented).
pub const VERSION: u32 = 1;

/// Canonical checkpoint file extension.
pub const EXT: &str = "flexckpt";

/// Typed checkpoint failure — the load path's full error surface.
/// Implements `std::error::Error`, so `?` converts into `anyhow::Error`
/// at call sites while tests can still match exact variants.
#[derive(Debug)]
pub enum CkptError {
    /// Underlying filesystem failure (open/read/write/rename).
    Io(std::io::Error),
    /// The first 8 bytes are not `FLEXTPCK` — not a checkpoint at all.
    BadMagic,
    /// Written by a newer flextp than this reader understands.
    UnsupportedVersion { found: u32, supported: u32 },
    /// File ends before a declared structure does (torn/partial file).
    Truncated { need: usize, have: usize },
    /// The stored FNV-1a digest does not match the bytes (bit rot,
    /// manual edits, or a corrupted transfer).
    ChecksumMismatch { stored: u64, computed: u64 },
    /// Structurally invalid header or entry table (bad JSON, unknown
    /// dtype, out-of-range offsets, missing/mistyped entries).
    Malformed(String),
    /// The snapshot is valid but does not fit the run it is being
    /// restored into (model/config fingerprint mismatch).
    Incompatible(String),
}

impl fmt::Display for CkptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint I/O error: {e}"),
            CkptError::BadMagic => write!(f, "not a flextp checkpoint (bad magic)"),
            CkptError::UnsupportedVersion { found, supported } => write!(
                f,
                "checkpoint format v{found} is newer than supported v{supported}"
            ),
            CkptError::Truncated { need, have } => write!(
                f,
                "checkpoint truncated: need {need} bytes, have {have}"
            ),
            CkptError::ChecksumMismatch { stored, computed } => write!(
                f,
                "checkpoint checksum mismatch (stored {stored:#018x}, \
                 computed {computed:#018x}) — file is corrupt"
            ),
            CkptError::Malformed(m) => write!(f, "malformed checkpoint: {m}"),
            CkptError::Incompatible(m) => write!(f, "incompatible checkpoint: {m}"),
        }
    }
}

impl std::error::Error for CkptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CkptError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> CkptError {
        CkptError::Io(e)
    }
}

/// One typed blob array.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    F64(Vec<f64>),
    U32(Vec<u32>),
    U8(Vec<u8>),
}

impl Payload {
    fn dtype(&self) -> &'static str {
        match self {
            Payload::F32(_) => "f32",
            Payload::F64(_) => "f64",
            Payload::U32(_) => "u32",
            Payload::U8(_) => "u8",
        }
    }

    fn count(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::F64(v) => v.len(),
            Payload::U32(v) => v.len(),
            Payload::U8(v) => v.len(),
        }
    }

    fn elem_bytes(dtype: &str) -> Option<usize> {
        match dtype {
            "f32" | "u32" => Some(4),
            "f64" => Some(8),
            "u8" => Some(1),
            _ => None,
        }
    }

    fn write_to(&self, out: &mut Vec<u8>) {
        match self {
            Payload::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Payload::F64(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Payload::U32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Payload::U8(v) => out.extend_from_slice(v),
        }
    }

    fn read(dtype: &str, bytes: &[u8]) -> Result<Payload, CkptError> {
        Ok(match dtype {
            "f32" => Payload::F32(
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
            ),
            "u32" => Payload::U32(
                bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
            ),
            "f64" => Payload::F64(
                bytes
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
                    .collect(),
            ),
            "u8" => Payload::U8(bytes.to_vec()),
            d => return Err(CkptError::Malformed(format!("unknown entry dtype '{d}'"))),
        })
    }
}

/// FNV-1a 64-bit digest — deterministic, dependency-free corruption
/// detection (not cryptographic; the threat model is bit rot and torn
/// writes, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// An in-memory checkpoint: JSON `meta` + named typed arrays.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub meta: Json,
    entries: BTreeMap<String, Payload>,
}

impl Snapshot {
    pub fn new(meta: Json) -> Snapshot {
        Snapshot { meta, entries: BTreeMap::new() }
    }

    // ---- entry accessors --------------------------------------------------

    pub fn put(&mut self, name: &str, p: Payload) {
        self.entries.insert(name.to_string(), p);
    }

    pub fn put_f32(&mut self, name: &str, v: Vec<f32>) {
        self.put(name, Payload::F32(v));
    }

    pub fn put_u8(&mut self, name: &str, v: Vec<u8>) {
        self.put(name, Payload::U8(v));
    }

    pub fn has(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn entry_names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn f32(&self, name: &str) -> Result<&[f32], CkptError> {
        match self.entries.get(name) {
            Some(Payload::F32(v)) => Ok(v),
            Some(p) => Err(CkptError::Malformed(format!(
                "entry '{name}' is {}, expected f32",
                p.dtype()
            ))),
            None => Err(CkptError::Malformed(format!("missing entry '{name}'"))),
        }
    }

    pub fn u8(&self, name: &str) -> Result<&[u8], CkptError> {
        match self.entries.get(name) {
            Some(Payload::U8(v)) => Ok(v),
            Some(p) => Err(CkptError::Malformed(format!(
                "entry '{name}' is {}, expected u8",
                p.dtype()
            ))),
            None => Err(CkptError::Malformed(format!("missing entry '{name}'"))),
        }
    }

    pub fn opt_f32(&self, name: &str) -> Option<&[f32]> {
        match self.entries.get(name) {
            Some(Payload::F32(v)) => Some(v),
            _ => None,
        }
    }

    // ---- wire format ------------------------------------------------------

    /// Serialize to the on-disk byte layout (header + checksum + blob).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut specs = Vec::new();
        let mut blob: Vec<u8> = Vec::new();
        for (name, p) in &self.entries {
            specs.push(obj([
                ("name", name.as_str().into()),
                ("dtype", p.dtype().into()),
                ("offset", blob.len().into()),
                ("count", p.count().into()),
            ]));
            p.write_to(&mut blob);
        }
        let header = obj([
            ("meta", self.meta.clone()),
            ("entries", Json::Arr(specs)),
        ])
        .to_string();

        // checksum covers header_len + header + blob (everything after
        // the checksum field itself)
        let mut body = Vec::with_capacity(4 + header.len() + blob.len());
        body.extend_from_slice(&(header.len() as u32).to_le_bytes());
        body.extend_from_slice(header.as_bytes());
        body.extend_from_slice(&blob);
        let sum = fnv1a64(&body);

        let mut out = Vec::with_capacity(20 + body.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&sum.to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Parse the on-disk byte layout.  Every malformation maps to a typed
    /// [`CkptError`]; no input can panic this function or yield a
    /// partially-populated snapshot.
    pub fn from_bytes(bytes: &[u8]) -> Result<Snapshot, CkptError> {
        let need = |n: usize| -> Result<(), CkptError> {
            if bytes.len() < n {
                Err(CkptError::Truncated { need: n, have: bytes.len() })
            } else {
                Ok(())
            }
        };
        need(8)?;
        if bytes[0..8] != MAGIC {
            return Err(CkptError::BadMagic);
        }
        need(12)?;
        let version = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
        if version > VERSION || version == 0 {
            return Err(CkptError::UnsupportedVersion { found: version, supported: VERSION });
        }
        need(20)?;
        let stored = u64::from_le_bytes([
            bytes[12], bytes[13], bytes[14], bytes[15], bytes[16], bytes[17], bytes[18], bytes[19],
        ]);
        let computed = fnv1a64(&bytes[20..]);
        if stored != computed {
            return Err(CkptError::ChecksumMismatch { stored, computed });
        }
        need(24)?;
        let hlen = u32::from_le_bytes([bytes[20], bytes[21], bytes[22], bytes[23]]) as usize;
        let hend = 24usize
            .checked_add(hlen)
            .ok_or_else(|| CkptError::Malformed("header length overflows".to_string()))?;
        need(hend)?;
        let htext = std::str::from_utf8(&bytes[24..hend])
            .map_err(|e| CkptError::Malformed(format!("header not UTF-8: {e}")))?;
        let header = Json::parse(htext)
            .map_err(|e| CkptError::Malformed(format!("header JSON: {e}")))?;
        let meta = header
            .get("meta")
            .map_err(|e| CkptError::Malformed(format!("{e}")))?
            .clone();
        let blob = &bytes[hend..];
        let mut entries = BTreeMap::new();
        let specs = header
            .get("entries")
            .and_then(|e| e.arr().map(<[Json]>::to_vec))
            .map_err(|e| CkptError::Malformed(format!("entry table: {e}")))?;
        for s in &specs {
            let bad = |what: &str| CkptError::Malformed(format!("entry table: {what}"));
            let name = s
                .get("name")
                .and_then(|v| v.str().map(str::to_string))
                .map_err(|_| bad("missing name"))?;
            let dtype = s
                .get("dtype")
                .and_then(|v| v.str().map(str::to_string))
                .map_err(|_| bad("missing dtype"))?;
            let offset = s.get("offset").and_then(|v| v.usize()).map_err(|_| bad("bad offset"))?;
            let count = s.get("count").and_then(|v| v.usize()).map_err(|_| bad("bad count"))?;
            let esz = Payload::elem_bytes(&dtype)
                .ok_or_else(|| CkptError::Malformed(format!("unknown dtype '{dtype}'")))?;
            let nbytes = count
                .checked_mul(esz)
                .ok_or_else(|| bad("entry size overflows"))?;
            let end = offset.checked_add(nbytes).ok_or_else(|| bad("entry range overflows"))?;
            let slice = blob.get(offset..end).ok_or(CkptError::Truncated {
                need: hend.saturating_add(end),
                have: bytes.len(),
            })?;
            entries.insert(name, Payload::read(&dtype, slice)?);
        }
        Ok(Snapshot { meta, entries })
    }

    /// Atomic save: serialize, write to `<path>.tmp`, fsync, rename.
    /// A crash at any point leaves either the previous file or an
    /// ignorable `.tmp` orphan — never a torn checkpoint.
    pub fn save_atomic(&self, path: &Path) -> Result<(), CkptError> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut tmp = path.as_os_str().to_os_string();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        let bytes = self.to_bytes();
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Snapshot, CkptError> {
        let bytes = std::fs::read(path)?;
        Self::from_bytes(&bytes)
    }
}

/// The newest complete checkpoint in a directory: highest-numbered
/// `ckpt-<giter>.flexckpt`.  `.tmp` orphans from interrupted saves and
/// unrelated files are ignored.  `None` when the directory is missing or
/// holds no checkpoints.
pub fn latest_in_dir(dir: &Path) -> Option<PathBuf> {
    let mut best: Option<(u64, PathBuf)> = None;
    for entry in std::fs::read_dir(dir).ok()?.flatten() {
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let Some(rest) = name.strip_prefix("ckpt-") else { continue };
        let Some(num) = rest.strip_suffix(&format!(".{EXT}")) else { continue };
        let Ok(g) = num.parse::<u64>() else { continue };
        if best.as_ref().is_none_or(|(b, _)| g > *b) {
            best = Some((g, path));
        }
    }
    best.map(|(_, p)| p)
}

/// Canonical checkpoint filename for a global-iteration cursor.
pub fn ckpt_filename(giter: u64) -> String {
    format!("ckpt-{giter:08}.{EXT}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Snapshot {
        let mut s = Snapshot::new(obj([
            ("hello", "world".into()),
            ("x", 4.25f64.into()),
        ]));
        s.put_f32("a", vec![1.0, -2.5, 3.25]);
        s.put("b", Payload::F64(vec![1e-300, 2.0]));
        s.put("c", Payload::U32(vec![7, 8, 9]));
        s.put_u8("d", vec![0, 1, 1, 0]);
        s
    }

    #[test]
    fn roundtrip_preserves_meta_and_entries() {
        let s = sample();
        let r = Snapshot::from_bytes(&s.to_bytes()).unwrap();
        assert_eq!(r.meta, s.meta);
        assert_eq!(r.f32("a").unwrap(), &[1.0, -2.5, 3.25]);
        assert_eq!(r.u8("d").unwrap(), &[0, 1, 1, 0]);
        assert!(r.has("b") && r.has("c"));
        assert!(r.f32("missing").is_err());
        assert!(r.u8("a").is_err(), "dtype mismatch must be typed");
    }

    #[test]
    fn bad_magic_and_version_are_typed() {
        let mut b = sample().to_bytes();
        b[0] = b'X';
        assert!(matches!(Snapshot::from_bytes(&b), Err(CkptError::BadMagic)));
        let mut b = sample().to_bytes();
        b[8] = 99; // version 99
        assert!(matches!(
            Snapshot::from_bytes(&b),
            Err(CkptError::UnsupportedVersion { found: 99, .. })
        ));
    }

    #[test]
    fn every_truncation_point_is_rejected() {
        let b = sample().to_bytes();
        for len in 0..b.len() {
            let e = Snapshot::from_bytes(&b[..len]).unwrap_err();
            assert!(
                matches!(
                    e,
                    CkptError::Truncated { .. }
                        | CkptError::BadMagic
                        | CkptError::ChecksumMismatch { .. }
                        | CkptError::Malformed(_)
                ),
                "len={len}: unexpected {e:?}"
            );
        }
    }

    #[test]
    fn every_bit_flip_after_checksum_is_caught() {
        let b = sample().to_bytes();
        // flip one bit in a spread of positions across header and blob
        for pos in (20..b.len()).step_by(7) {
            let mut c = b.clone();
            c[pos] ^= 0x10;
            assert!(
                matches!(Snapshot::from_bytes(&c), Err(CkptError::ChecksumMismatch { .. })),
                "flip at {pos} not caught"
            );
        }
    }

    #[test]
    fn atomic_save_load_and_latest() {
        let dir = std::env::temp_dir().join("flextp_ckpt_fmt_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let s = sample();
        for g in [5u64, 10, 2] {
            s.save_atomic(&dir.join(ckpt_filename(g))).unwrap();
        }
        // a torn .tmp orphan and an unrelated file must be ignored
        std::fs::write(dir.join("ckpt-00000099.flexckpt.tmp"), b"torn").unwrap();
        std::fs::write(dir.join("notes.txt"), b"hi").unwrap();
        let latest = latest_in_dir(&dir).unwrap();
        assert!(latest.ends_with(ckpt_filename(10)));
        let r = Snapshot::load(&latest).unwrap();
        assert_eq!(r.f32("a").unwrap(), s.f32("a").unwrap());
        assert!(latest_in_dir(&dir.join("missing")).is_none());
    }

    #[test]
    fn fnv_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }
}
