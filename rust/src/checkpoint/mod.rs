//! Checkpoint / elastic-resume subsystem (DESIGN.md §13).
//!
//! [`save_trainer`] snapshots the **complete** training state into a
//! versioned, self-describing [`Snapshot`] (`format` module); the
//! captured pieces are exactly what bitwise same-`E` continuation needs:
//!
//! * model shards + replicated params, optimizer momentum buffers;
//! * cursors: the global iteration (which is simultaneously the data
//!   stream position and the contention-trace position — both are pure
//!   functions of it) and the balancer's RNG stream state;
//! * the straggler monitor (T_i/M_i, passive T_avg cache), the online
//!   controller's fast/slow EWMAs + hysteresis/cooldown, the standing
//!   pretest cost fits (EWMA-blended by mid-run refits);
//! * the cached balancing plan (`--replan epoch|online` keep a plan
//!   alive across iterations — a mid-epoch resume must reuse it, not
//!   recompute and re-charge Ω₁);
//! * SimClock vectors, `CommStats` byte/op counters, the epoch-in-
//!   progress accumulators, and the run report so far;
//! * balancer priority statistics (trackers, weight snapshots, pruned
//!   marks) and, under `--imputation same`, the previous-iteration
//!   gradients.
//!
//! [`restore_trainer`] validates a config **fingerprint** (everything
//! that feeds the math: seed, schedule shape, strategy, costs, scenario
//! — but not `--threads`, which is bitwise-invariant by the PR-2
//! contract, and not `--epochs`, so a run may be extended) and then
//! either restores in place (same worker count → bitwise) or routes
//! through [`elastic`] re-sharding (different `--e` → parameters and
//! moments move exactly; rank-shaped transient state re-initializes and
//! the Eq. 2/3 allocation re-runs before the first resumed iteration).

pub mod elastic;
pub mod format;

pub use format::{ckpt_filename, latest_in_dir, CkptError, Payload, Snapshot, EXT};

use crate::balancer::WorkerAction;
use crate::config::{RunCfg, StragglerPlan};
use crate::metrics::{EpochMetrics, IterSample, RunReport};
use crate::migration::{Chunk, MigPlan, ReceiverWork};
use crate::model::{BlockShard, ModelState, RepParams};
use crate::resizing::LayerPlan;
use crate::runtime::manifest::ModelInfo;
use crate::tensor::Tensor;
use crate::train::trainer::Trainer;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

/// Snapshot kind tag (`meta.kind`) — guards against feeding some other
/// valid container (e.g. a future sweep snapshot) into the trainer.
const KIND: &str = "flextp-trainer";

// ---------------------------------------------------------------------------
// JSON helpers (u64s travel as decimal strings — Json numbers are f64)
// ---------------------------------------------------------------------------

fn ju64(v: u64) -> Json {
    Json::Str(v.to_string())
}

fn jf64s(v: &[f64]) -> Json {
    v.iter().copied().collect()
}

fn ju32s(v: &[u32]) -> Json {
    v.iter().map(|&x| x as usize).collect()
}

fn bad(msg: impl std::fmt::Display) -> CkptError {
    CkptError::Malformed(msg.to_string())
}

fn jget<'a>(j: &'a Json, key: &str) -> Result<&'a Json, CkptError> {
    j.get(key).map_err(bad)
}

fn pstr<'a>(j: &'a Json, key: &str) -> Result<&'a str, CkptError> {
    jget(j, key)?.str().map_err(bad)
}

fn pf64(j: &Json, key: &str) -> Result<f64, CkptError> {
    jget(j, key)?.num().map_err(bad)
}

fn pusize(j: &Json, key: &str) -> Result<usize, CkptError> {
    jget(j, key)?.usize().map_err(bad)
}

fn pbool(j: &Json, key: &str) -> Result<bool, CkptError> {
    match jget(j, key)? {
        Json::Bool(b) => Ok(*b),
        other => Err(bad(format!("'{key}' is not a bool: {other:?}"))),
    }
}

/// Accept a u64 stored either as a decimal string (the writer's form)
/// or a non-negative integral number — the single place the rule lives.
fn u64_from(v: &Json, what: &str) -> Result<u64, CkptError> {
    match v {
        Json::Str(s) => s.parse().map_err(|e| bad(format!("{what}: {e}"))),
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as u64),
        other => Err(bad(format!("{what} is not a u64: {other:?}"))),
    }
}

fn pu64(j: &Json, key: &str) -> Result<u64, CkptError> {
    u64_from(jget(j, key)?, key)
}

fn pf64s(j: &Json, key: &str) -> Result<Vec<f64>, CkptError> {
    jget(j, key)?
        .arr()
        .map_err(bad)?
        .iter()
        .map(|v| v.num().map_err(bad))
        .collect()
}

fn pu32s(j: &Json, key: &str) -> Result<Vec<u32>, CkptError> {
    jget(j, key)?
        .arr()
        .map_err(bad)?
        .iter()
        .map(|v| v.usize().map_err(bad).map(|x| x as u32))
        .collect()
}

/// The snapshot's per-component degree vector (`meta.model.deg`,
/// DESIGN.md §18).  Lenient: snapshots from before fine-grained degrees
/// carry no `deg` key and read back as the uniform vector at `ck_e` —
/// exactly the geometry those runs were sharded with.
fn degrees_from_meta(
    mm: &Json,
    ck_e: usize,
) -> Result<crate::runtime::manifest::Degrees, CkptError> {
    let Some(v) = mm.opt("deg") else {
        return Ok(crate::runtime::manifest::Degrees::uniform(ck_e));
    };
    let arr = v.arr().map_err(bad)?;
    if arr.len() != 4 {
        return Err(bad(format!("model.deg has {} entries, expected 4", arr.len())));
    }
    let mut d = [0usize; 4];
    for (slot, item) in d.iter_mut().zip(arr) {
        *slot = item.usize().map_err(bad)?;
    }
    Ok(crate::runtime::manifest::Degrees::from_array(d))
}

// ---------------------------------------------------------------------------
// Config fingerprint
// ---------------------------------------------------------------------------

/// One line describing the straggler plan, stable across save/load (the
/// scenario form round-trips through `ScenarioSpec::describe`).  This is
/// the persisted **trace cursor contract**: plan descriptor + global
/// iteration fully determine the χ row (traces are prefix-stable), so
/// serializing the cursor alone is exact.
pub fn plan_desc(p: &StragglerPlan) -> String {
    match p {
        StragglerPlan::None => "none".to_string(),
        StragglerPlan::Fixed(v) => format!("chis:{v:?}"),
        StragglerPlan::RoundRobin { chi, period_epochs } => format!("rr:{chi}@{period_epochs}"),
        StragglerPlan::Scenario(s) => format!("scenario:{}", s.describe()),
    }
}

/// Everything that feeds the training math, in one comparable string.
/// Excluded on purpose: `--threads` (bitwise-invariant), `--epochs`
/// (runs may be extended), wall-only knobs (`--emulate-wall`,
/// `--timeline`), the observability knobs (`--trace`, `--trace-out`,
/// `--trace-ring` — zero observer effect, tests/trace_determinism.rs,
/// so a traced run may resume an untraced checkpoint and vice versa),
/// the transport knobs (`--transport`, `--transport-timeout-ms`,
/// `--rank-exe` — cross-transport parity is bitwise,
/// tests/transport_parity.rs, so a tcp run may resume an inproc
/// checkpoint and vice versa), and checkpoint plumbing itself.
pub fn cfg_fingerprint(cfg: &RunCfg) -> String {
    let b = &cfg.balancer;
    let t = &cfg.train;
    let c = &cfg.control;
    format!(
        "model={};seed={};ipe={};eval={};batches={};lr={};mom={};\
         strategy={};imp={:?};migpol={:?};theta={};alpha={};gamma={:?};\
         lambda={:?};merge={};replan={};time={};net={},{};\
         ctl={},{},{},{},{};churn={};mem={:?},{:?},{};plan={}",
        cfg.model,
        t.seed,
        t.iters_per_epoch,
        t.eval_iters,
        t.train_batches,
        t.lr,
        t.momentum,
        b.strategy.name(),
        b.imputation,
        b.mig_policy,
        b.theta_iter,
        b.alpha,
        b.gamma_override,
        b.forced_lambda,
        b.reduce_merging,
        b.replan.name(),
        t.time_model.name(),
        cfg.net.alpha_s,
        cfg.net.bytes_per_s,
        c.alpha_fast,
        c.alpha_slow,
        c.hi,
        c.lo,
        c.cooldown,
        t.churn,
        // memory budgets gate the balancer's migration filter and the
        // recompute fallback, so they are part of the training math
        t.mem_cap,
        t.mem_caps,
        t.mem_recompute,
        plan_desc(&cfg.stragglers),
    )
}

// ---------------------------------------------------------------------------
// Shape tables
// ---------------------------------------------------------------------------

fn shard_dims(m: &ModelInfo, name: &str) -> Vec<usize> {
    match name {
        "ln1_g" | "ln1_b" | "ln2_g" | "ln2_b" => vec![m.hs],
        "wqkv" => vec![m.hs, 3 * m.hsl],
        "wo" => vec![m.hsl, m.hs],
        "w1" => vec![m.hs, m.ffl],
        "w2" => vec![m.ffl, m.hs],
        other => unreachable!("unknown shard tensor '{other}'"),
    }
}

fn rep_dims(m: &ModelInfo, name: &str) -> Vec<usize> {
    match name {
        "w_patch" => vec![m.pd, m.hs],
        "pos" => vec![m.seq, m.hs],
        "cls" => vec![m.hs],
        "lnf_g" | "lnf_b" => vec![m.hs],
        "w_head" => vec![m.hs, m.classes],
        "b_head" => vec![m.classes],
        other => unreachable!("unknown rep tensor '{other}'"),
    }
}

fn zero_state(m: &ModelInfo) -> ModelState {
    ModelState {
        shards: (0..m.e)
            .map(|_| (0..m.depth).map(|_| crate::model::zero_block_grads(m)).collect())
            .collect(),
        rep: RepParams {
            w_patch: Tensor::zeros(&rep_dims(m, "w_patch")),
            pos: Tensor::zeros(&rep_dims(m, "pos")),
            cls: Tensor::zeros(&rep_dims(m, "cls")),
            lnf_g: Tensor::zeros(&rep_dims(m, "lnf_g")),
            lnf_b: Tensor::zeros(&rep_dims(m, "lnf_b")),
            w_head: Tensor::zeros(&rep_dims(m, "w_head")),
            b_head: Tensor::zeros(&rep_dims(m, "b_head")),
        },
    }
}

/// Read entry `name` into `dst.data` (length-checked, bitwise copy).
fn copy_into(snap: &Snapshot, name: &str, dst: &mut Tensor) -> Result<(), CkptError> {
    let src = snap.f32(name)?;
    if src.len() != dst.len() {
        return Err(bad(format!(
            "entry '{name}' has {} elements, expected {}",
            src.len(),
            dst.len()
        )));
    }
    dst.data.copy_from_slice(src);
    Ok(())
}

// ---------------------------------------------------------------------------
// WorkerAction <-> JSON
// ---------------------------------------------------------------------------

fn action_to_json(a: &WorkerAction) -> Json {
    let layers: Vec<Json> = a
        .layers
        .iter()
        .map(|p| {
            obj([
                ("ab", p.attn_bucket.as_str().into()),
                ("b1", p.mlp_b1.as_str().into()),
                ("b2", p.mlp_b2.as_str().into()),
                ("ak", ju32s(&p.attn_keep)),
                ("k1", ju32s(&p.mlp_keep1)),
                ("k2", ju32s(&p.mlp_keep2)),
            ])
        })
        .collect();
    let mig = match &a.mig {
        None => Json::Null,
        Some(m) => obj([
            ("straggler", m.straggler.into()),
            ("migrated", ju32s(&m.migrated)),
            ("kept", ju32s(&m.kept)),
            ("kept_bucket", m.kept_bucket.as_str().into()),
            (
                "receivers",
                Json::Arr(
                    m.receivers
                        .iter()
                        .map(|r| {
                            obj([
                                ("rank", r.rank.into()),
                                (
                                    "chunks",
                                    Json::Arr(
                                        r.chunks
                                            .iter()
                                            .map(|c| {
                                                obj([
                                                    ("start", c.start.into()),
                                                    ("len", c.len.into()),
                                                    ("kb", c.kb.into()),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    };
    obj([("layers", Json::Arr(layers)), ("mig", mig)])
}

fn idx_in_bounds(v: &[u32], bound: usize, what: &str) -> Result<(), CkptError> {
    for &i in v {
        if i as usize >= bound {
            return Err(bad(format!("{what}: index {i} out of range (size {bound})")));
        }
    }
    Ok(())
}

fn action_from_json(j: &Json, m: &ModelInfo) -> Result<WorkerAction, CkptError> {
    let mut layers = Vec::new();
    for l in jget(j, "layers")?.arr().map_err(bad)? {
        let p = LayerPlan {
            attn_bucket: pstr(l, "ab")?.to_string(),
            mlp_b1: pstr(l, "b1")?.to_string(),
            mlp_b2: pstr(l, "b2")?.to_string(),
            attn_keep: pu32s(l, "ak")?,
            mlp_keep1: pu32s(l, "k1")?,
            mlp_keep2: pu32s(l, "k2")?,
        };
        idx_in_bounds(&p.attn_keep, m.hs, "cached plan attn_keep")?;
        idx_in_bounds(&p.mlp_keep1, m.hs, "cached plan mlp_keep1")?;
        idx_in_bounds(&p.mlp_keep2, m.ffl, "cached plan mlp_keep2")?;
        layers.push(p);
    }
    if layers.len() != m.depth {
        return Err(bad(format!(
            "cached plan has {} layer plans, model depth is {}",
            layers.len(),
            m.depth
        )));
    }
    let mig = match jget(j, "mig")? {
        Json::Null => None,
        mj => {
            let migrated = pu32s(mj, "migrated")?;
            let kept = pu32s(mj, "kept")?;
            idx_in_bounds(&migrated, m.ffl, "cached plan migrated")?;
            idx_in_bounds(&kept, m.ffl, "cached plan kept")?;
            let straggler = pusize(mj, "straggler")?;
            if straggler >= m.e {
                return Err(bad(format!("cached plan straggler {straggler} ≥ e={}", m.e)));
            }
            let mut receivers = Vec::new();
            for r in jget(mj, "receivers")?.arr().map_err(bad)? {
                let rank = pusize(r, "rank")?;
                if rank >= m.e || rank == straggler {
                    return Err(bad(format!("cached plan receiver rank {rank} invalid")));
                }
                let mut chunks = Vec::new();
                for c in jget(r, "chunks")?.arr().map_err(bad)? {
                    let chunk = Chunk {
                        start: pusize(c, "start")?,
                        len: pusize(c, "len")?,
                        kb: pusize(c, "kb")?,
                    };
                    let end = chunk.start.checked_add(chunk.len);
                    if chunk.len == 0 || chunk.len > chunk.kb || end.is_none_or(|e| e > migrated.len())
                    {
                        return Err(bad("cached plan chunk out of range"));
                    }
                    chunks.push(chunk);
                }
                receivers.push(ReceiverWork { rank, chunks });
            }
            Some(MigPlan {
                straggler,
                migrated,
                kept,
                kept_bucket: pstr(mj, "kept_bucket")?.to_string(),
                receivers,
            })
        }
    };
    Ok(WorkerAction { layers, mig })
}

// ---------------------------------------------------------------------------
// Report <-> JSON
// ---------------------------------------------------------------------------

fn epoch_to_json(e: &EpochMetrics) -> Json {
    obj([
        ("epoch", e.epoch.into()),
        ("rt_sim_s", e.rt_sim_s.into()),
        ("rt_wall_s", e.rt_wall_s.into()),
        ("train_loss", e.train_loss.into()),
        ("eval_loss", e.eval_loss.into()),
        ("acc", e.acc.into()),
        ("comm_bytes", ju64(e.comm_bytes)),
        ("pruned_cols", ju64(e.pruned_cols)),
        ("migrated_cols", ju64(e.migrated_cols)),
        ("rank_compute_s", jf64s(&e.rank_compute_s)),
        ("replans", ju64(e.replans)),
        ("chi_mean", e.chi_mean.into()),
        ("chi_max", e.chi_max.into()),
    ])
}

fn epoch_from_json(j: &Json) -> Result<EpochMetrics, CkptError> {
    Ok(EpochMetrics {
        epoch: pusize(j, "epoch")?,
        rt_sim_s: pf64(j, "rt_sim_s")?,
        rt_wall_s: pf64(j, "rt_wall_s")?,
        train_loss: pf64(j, "train_loss")?,
        eval_loss: pf64(j, "eval_loss")?,
        acc: pf64(j, "acc")?,
        comm_bytes: pu64(j, "comm_bytes")?,
        pruned_cols: pu64(j, "pruned_cols")?,
        migrated_cols: pu64(j, "migrated_cols")?,
        rank_compute_s: pf64s(j, "rank_compute_s")?,
        replans: pu64(j, "replans")?,
        chi_mean: pf64(j, "chi_mean")?,
        chi_max: pf64(j, "chi_max")?,
    })
}

fn sample_to_json(s: &IterSample) -> Json {
    obj([
        ("giter", ju64(s.giter)),
        ("epoch", s.epoch.into()),
        ("iter", s.iter.into()),
        ("chi", jf64s(&s.chi)),
        ("t_iter", jf64s(&s.t_iter)),
        ("rt_iter_s", s.rt_iter_s.into()),
        ("replanned", s.replanned.into()),
    ])
}

fn sample_from_json(j: &Json) -> Result<IterSample, CkptError> {
    Ok(IterSample {
        giter: pu64(j, "giter")?,
        epoch: pusize(j, "epoch")?,
        iter: pusize(j, "iter")?,
        chi: pf64s(j, "chi")?,
        t_iter: pf64s(j, "t_iter")?,
        rt_iter_s: pf64(j, "rt_iter_s")?,
        replanned: pbool(j, "replanned")?,
    })
}

// ---------------------------------------------------------------------------
// Save
// ---------------------------------------------------------------------------

/// Assemble a complete trainer snapshot (see module docs for contents).
pub fn save_trainer(t: &Trainer) -> Snapshot {
    let m = t.rt.manifest.model.clone();
    let (s0, s1, spare) = t.balancer.rng.state();
    let rng_spare = match spare {
        None => Json::Null,
        Some(v) => Json::Num(v as f64),
    };
    let cached = match &t.cached_actions {
        None => Json::Null,
        Some(acts) => Json::Arr(acts.iter().map(action_to_json).collect()),
    };
    let cs = &t.comm.stats;
    let meta = obj([
        ("kind", KIND.into()),
        (
            "model",
            obj([
                ("name", m.name.as_str().into()),
                ("e", m.e.into()),
                ("hs", m.hs.into()),
                ("depth", m.depth.into()),
                ("heads", m.heads.into()),
                ("bs", m.bs.into()),
                ("ffl", m.ffl.into()),
                // per-component TP degree vector (DESIGN.md §18), in
                // [`Degrees::as_array`] order [embed, attn, mlp, head];
                // pre-fine-grained snapshots carry none and read back as
                // the uniform vector
                (
                    "deg",
                    Json::Arr(m.degrees.as_array().iter().map(|&d| d.into()).collect()),
                ),
            ]),
        ),
        ("cfg_fp", cfg_fingerprint(&t.cfg).into()),
        (
            "cursor",
            obj([
                ("global_iter", ju64(t.global_iter)),
                // live worker count at the cut — churn events strictly
                // before `global_iter` have already been folded in, so a
                // resume must start from this count, not from the model's
                // sharding degree (they differ when the last transition
                // landed on a nearest-divisor E' < avail)
                ("avail", t.avail.into()),
            ]),
        ),
        (
            "clocks",
            obj([("t", jf64s(&t.clocks.t)), ("ic", jf64s(&t.clocks.iter_compute))]),
        ),
        (
            "comm",
            obj([
                ("allreduce_ops", ju64(cs.allreduce_ops)),
                ("allreduce_bytes", ju64(cs.allreduce_bytes)),
                ("broadcast_ops", ju64(cs.broadcast_ops)),
                ("broadcast_bytes", ju64(cs.broadcast_bytes)),
                ("reduce_ops", ju64(cs.reduce_ops)),
                ("reduce_bytes", ju64(cs.reduce_bytes)),
                ("scatter_ops", ju64(cs.scatter_ops)),
                ("scatter_bytes", ju64(cs.scatter_bytes)),
                ("gather_ops", ju64(cs.gather_ops)),
                ("gather_bytes", ju64(cs.gather_bytes)),
                ("allgather_ops", ju64(cs.allgather_ops)),
                ("allgather_bytes", ju64(cs.allgather_bytes)),
            ]),
        ),
        (
            "monitor",
            obj([
                ("t_iter", jf64s(&t.monitor.t_iter)),
                ("m_iter", jf64s(&t.monitor.m_iter)),
                ("t_avg", jf64s(&t.monitor.t_avg_cached)),
                ("t_sync", jf64s(&t.monitor.t_self_at_sync)),
                ("refreshes", ju64(t.monitor.refreshes)),
            ]),
        ),
        (
            "ctl",
            obj([
                ("fast", jf64s(&t.controller.fast)),
                ("slow", jf64s(&t.controller.slow)),
                ("armed", t.controller.armed.into()),
                ("cooldown", t.controller.cooldown_left.into()),
                ("triggers", ju64(t.controller.triggers)),
            ]),
        ),
        (
            "costs",
            obj([
                ("omega1_s", t.costs.omega1_s.into()),
                ("omega2_per_col", t.costs.omega2_per_col.into()),
                ("phi1_base_s", t.costs.phi1_base_s.into()),
                ("phi1_per_col", t.costs.phi1_per_col.into()),
                ("phi2_per_col", t.costs.phi2_per_col.into()),
            ]),
        ),
        (
            "epoch",
            obj([
                ("pruned_cols", ju64(t.epoch_pruned_cols)),
                ("migrated_cols", ju64(t.epoch_migrated_cols)),
                ("compute", jf64s(&t.epoch_compute)),
                ("replans", ju64(t.epoch_replans)),
                ("chi_sum", t.epoch_chi_sum.into()),
                ("chi_max", t.epoch_chi_max.into()),
                ("chi_iters", ju64(t.epoch_chi_iters)),
                ("loss_sum", t.epoch_loss_sum.into()),
                ("start_bytes", ju64(t.epoch_start_bytes)),
                ("wall_s", t.epoch_wall_s.into()),
                // ju64 is a decimal string, so the u64::MAX
                // fresh-epoch sentinel in headroom_min round-trips exactly
                ("mem_hwm", ju64(t.epoch_mem_hwm)),
                ("headroom_min", ju64(t.epoch_headroom_min)),
                ("recompute_iters", ju64(t.epoch_recompute_iters)),
            ]),
        ),
        (
            "balancer",
            obj([
                ("rng", Json::Arr(vec![ju64(s0), ju64(s1), rng_spare])),
                ("have_snapshots", (!t.balancer.snapshots.is_empty()).into()),
            ]),
        ),
        ("cached_actions", cached),
        (
            "flags",
            obj([("prev_grads", t.prev_grads.is_some().into())]),
        ),
        (
            "report",
            obj([
                ("label", t.report.label.as_str().into()),
                (
                    "epochs",
                    Json::Arr(t.report.epochs.iter().map(epoch_to_json).collect()),
                ),
                (
                    "timeline",
                    Json::Arr(t.report.timeline.iter().map(sample_to_json).collect()),
                ),
            ]),
        ),
    ]);

    let mut snap = Snapshot::new(meta);
    // model shards + replicated params
    for w in 0..m.e {
        for k in 0..m.depth {
            for n in BlockShard::names() {
                snap.put_f32(
                    &format!("model.{w}.{k}.{n}"),
                    t.state.shards[w][k].get(n).data.clone(),
                );
            }
        }
    }
    for n in RepParams::names() {
        snap.put_f32(&format!("model.rep.{n}"), t.state.rep.get(n).data.clone());
    }
    // optimizer momentum buffers
    for (key, buf) in &t.opt.bufs {
        snap.put_f32(&format!("opt.{key}"), buf.data.clone());
    }
    // Same-imputation previous-iteration gradients
    if let Some(pg) = &t.prev_grads {
        for (w, per_w) in pg.iter().enumerate() {
            for (k, g) in per_w.iter().enumerate() {
                for n in BlockShard::names() {
                    snap.put_f32(&format!("prev.{w}.{k}.{n}"), g.get(n).data.clone());
                }
            }
        }
    }
    // balancer statistics
    for (w, per_w) in t.balancer.trackers.iter().enumerate() {
        for (k, bt) in per_w.iter().enumerate() {
            for (c, tr) in [("qkv", &bt.qkv), ("fc1", &bt.fc1), ("fc2", &bt.fc2)] {
                if let Some(v) = &tr.w_var {
                    snap.put_f32(&format!("bal.var.{w}.{k}.{c}"), v.clone());
                }
            }
        }
    }
    for (w, per_w) in t.balancer.snapshots.iter().enumerate() {
        for (k, (wqkv, w1, w2)) in per_w.iter().enumerate() {
            snap.put_f32(&format!("bal.snap.{w}.{k}.wqkv"), wqkv.data.clone());
            snap.put_f32(&format!("bal.snap.{w}.{k}.w1"), w1.data.clone());
            snap.put_f32(&format!("bal.snap.{w}.{k}.w2"), w2.data.clone());
        }
    }
    for (w, per_w) in t.balancer.pruned_epoch.iter().enumerate() {
        for (k, kinds) in per_w.iter().enumerate() {
            for (i, marks) in kinds.iter().enumerate() {
                snap.put_u8(
                    &format!("bal.pruned.{w}.{k}.{i}"),
                    marks.iter().map(|&b| b as u8).collect(),
                );
            }
        }
    }
    // loss curve (f32-exact in the blob)
    snap.put_f32("report.loss_curve", t.report.loss_curve.clone());
    snap
}

// ---------------------------------------------------------------------------
// Restore
// ---------------------------------------------------------------------------

/// Restore a trainer from a snapshot: bitwise in-place when the worker
/// count matches, elastic re-shard otherwise.  On any error the trainer
/// should be discarded (state may be partially written).
pub fn restore_trainer(t: &mut Trainer, snap: &Snapshot) -> Result<(), CkptError> {
    let meta = &snap.meta;
    if pstr(meta, "kind")? != KIND {
        return Err(bad(format!("not a {KIND} snapshot")));
    }
    let cur = t.rt.manifest.model.clone();
    let mm = jget(meta, "model")?;
    let name = pstr(mm, "name")?;
    if name != cur.name {
        return Err(CkptError::Incompatible(format!(
            "checkpoint is for model '{name}', trainer runs '{}'",
            cur.name
        )));
    }
    let geometry =
        [("hs", cur.hs), ("depth", cur.depth), ("heads", cur.heads), ("bs", cur.bs)];
    for (key, have) in geometry {
        let want = pusize(mm, key)?;
        if want != have {
            return Err(CkptError::Incompatible(format!(
                "model geometry mismatch: checkpoint {key}={want}, trainer {key}={have}"
            )));
        }
    }
    let fp = pstr(meta, "cfg_fp")?;
    let want_fp = cfg_fingerprint(&t.cfg);
    if fp != want_fp {
        return Err(CkptError::Incompatible(format!(
            "run configuration differs from the checkpointed one\n  \
             checkpoint: {fp}\n  current:    {want_fp}"
        )));
    }
    let giter = pu64(jget(meta, "cursor")?, "global_iter")?;
    let total = (t.cfg.train.epochs * t.cfg.train.iters_per_epoch) as u64;
    if giter > total {
        return Err(CkptError::Incompatible(format!(
            "checkpoint cursor {giter} is past the configured schedule ({total} iterations) \
             — raise --epochs to extend the run"
        )));
    }

    // ---- run report + comm stats + epoch scalars (all geometries) -------
    let rj = jget(meta, "report")?;
    t.report = RunReport::new(pstr(rj, "label")?);
    for e in jget(rj, "epochs")?.arr().map_err(bad)? {
        t.report.epochs.push(epoch_from_json(e)?);
    }
    for s in jget(rj, "timeline")?.arr().map_err(bad)? {
        t.report.timeline.push(sample_from_json(s)?);
    }
    t.report.loss_curve = snap.f32("report.loss_curve")?.to_vec();

    let cj = jget(meta, "comm")?;
    let cs = &mut t.comm.stats;
    cs.allreduce_ops = pu64(cj, "allreduce_ops")?;
    cs.allreduce_bytes = pu64(cj, "allreduce_bytes")?;
    cs.broadcast_ops = pu64(cj, "broadcast_ops")?;
    cs.broadcast_bytes = pu64(cj, "broadcast_bytes")?;
    cs.reduce_ops = pu64(cj, "reduce_ops")?;
    cs.reduce_bytes = pu64(cj, "reduce_bytes")?;
    cs.scatter_ops = pu64(cj, "scatter_ops")?;
    cs.scatter_bytes = pu64(cj, "scatter_bytes")?;
    cs.gather_ops = pu64(cj, "gather_ops")?;
    cs.gather_bytes = pu64(cj, "gather_bytes")?;
    cs.allgather_ops = pu64(cj, "allgather_ops")?;
    cs.allgather_bytes = pu64(cj, "allgather_bytes")?;

    let ej = jget(meta, "epoch")?;
    t.epoch_pruned_cols = pu64(ej, "pruned_cols")?;
    t.epoch_migrated_cols = pu64(ej, "migrated_cols")?;
    t.epoch_replans = pu64(ej, "replans")?;
    t.epoch_chi_sum = pf64(ej, "chi_sum")?;
    t.epoch_chi_max = pf64(ej, "chi_max")?;
    t.epoch_chi_iters = pu64(ej, "chi_iters")?;
    t.epoch_loss_sum = pf64(ej, "loss_sum")?;
    t.epoch_start_bytes = pu64(ej, "start_bytes")?;
    t.epoch_wall_s = pf64(ej, "wall_s")?;
    // memory accumulators: lenient reads (pre-memory snapshots carry
    // none; the fresh-epoch sentinel for headroom_min is u64::MAX)
    t.epoch_mem_hwm = match ej.opt("mem_hwm") {
        Some(v) => u64_from(v, "mem_hwm")?,
        None => 0,
    };
    t.epoch_headroom_min = match ej.opt("headroom_min") {
        Some(v) => u64_from(v, "headroom_min")?,
        None => u64::MAX,
    };
    t.epoch_recompute_iters = match ej.opt("recompute_iters") {
        Some(v) => u64_from(v, "recompute_iters")?,
        None => 0,
    };

    let ck_e = pusize(mm, "e")?;
    let ck_deg = degrees_from_meta(mm, ck_e)?;
    // bitwise in-place restore requires the whole degree vector to
    // match, not just the worker count — a mixed-degree snapshot landing
    // on a uniform trainer (or vice versa) re-shards elastically
    if ck_e == cur.e && ck_deg == cur.degrees {
        restore_same_e(t, snap, &cur)?;
    } else {
        restore_elastic(t, snap, ck_e, ck_deg)?;
    }

    t.global_iter = giter;
    // ---- worker-churn cursor ---------------------------------------------
    // Live worker count at the cut (snapshots from before churn support
    // carry none: their count *is* the sharding degree), plus the
    // fired-event cursor.  An event scheduled `@iterK` fires before
    // iteration K runs, so exactly the events strictly before `giter`
    // have been folded into `avail` by the run that wrote the snapshot;
    // the event *at* `giter` (if any) is still pending and will fire as
    // the resumed run enters its first iteration.
    t.avail = match jget(meta, "cursor")?.opt("avail") {
        Some(v) => v.usize().map_err(bad)?,
        None => ck_e,
    };
    t.churn_fired = t.churn.iter().filter(|ev| (ev.at as u64) < giter).count();
    // ---- memory-event cursor + ledger -------------------------------------
    // Same firing contract as churn; the ledger is then rebuilt as a pure
    // function of (cfg, restored E, fired squeeze events), which is what
    // makes a live OOM eviction and this resume path bitwise equal.
    t.mem_fired = t.mem_events.iter().filter(|ev| (ev.at as u64) < giter).count();
    t.rebuild_ledger();
    t.resumed = true;
    Ok(())
}

/// Bitwise in-place restore (worker count unchanged).
fn restore_same_e(t: &mut Trainer, snap: &Snapshot, m: &ModelInfo) -> Result<(), CkptError> {
    let meta = &snap.meta;
    // model + optimizer
    for w in 0..m.e {
        for k in 0..m.depth {
            for n in BlockShard::names() {
                copy_into(snap, &format!("model.{w}.{k}.{n}"), t.state.shards[w][k].get_mut(n))?;
            }
        }
    }
    for n in RepParams::names() {
        copy_into(snap, &format!("model.rep.{n}"), t.state.rep.get_mut(n))?;
    }
    t.opt.bufs.clear();
    let opt_keys: Vec<String> = snap
        .entry_names()
        .filter_map(|n| n.strip_prefix("opt.").map(str::to_string))
        .collect();
    for key in opt_keys {
        let dims = param_dims(m, &key)
            .ok_or_else(|| bad(format!("optimizer buffer for unknown param '{key}'")))?;
        let mut buf = Tensor::zeros(&dims);
        copy_into(snap, &format!("opt.{key}"), &mut buf)?;
        t.opt.bufs.insert(key, buf);
    }
    // Same-imputation gradient history
    let flagged = pbool(jget(meta, "flags")?, "prev_grads")?;
    match (&mut t.prev_grads, flagged) {
        (Some(pg), true) => {
            for (w, per_w) in pg.iter_mut().enumerate() {
                for (k, g) in per_w.iter_mut().enumerate() {
                    for n in BlockShard::names() {
                        copy_into(snap, &format!("prev.{w}.{k}.{n}"), g.get_mut(n))?;
                    }
                }
            }
        }
        (None, false) => {}
        _ => {
            return Err(bad(
                "prev_grads flag disagrees with the imputation policy (corrupt snapshot)",
            ))
        }
    }
    // clocks + per-rank epoch compute
    let kj = jget(meta, "clocks")?;
    let ct = pf64s(kj, "t")?;
    let ic = pf64s(kj, "ic")?;
    if ct.len() != m.e || ic.len() != m.e {
        return Err(bad("clock vectors have the wrong rank count"));
    }
    t.clocks.t = ct;
    t.clocks.iter_compute = ic;
    let compute = pf64s(jget(meta, "epoch")?, "compute")?;
    if !compute.is_empty() && compute.len() != m.e {
        return Err(bad("epoch compute vector has the wrong rank count"));
    }
    t.epoch_compute = compute;
    // monitor
    let mj = jget(meta, "monitor")?;
    let (ti, mi) = (pf64s(mj, "t_iter")?, pf64s(mj, "m_iter")?);
    let (ta, ts) = (pf64s(mj, "t_avg")?, pf64s(mj, "t_sync")?);
    if [&ti, &mi, &ta, &ts].iter().any(|v| v.len() != m.e) {
        return Err(bad("monitor vectors have the wrong rank count"));
    }
    t.monitor.t_iter = ti;
    t.monitor.m_iter = mi;
    t.monitor.t_avg_cached = ta;
    t.monitor.t_self_at_sync = ts;
    t.monitor.refreshes = pu64(mj, "refreshes")?;
    // controller
    let oj = jget(meta, "ctl")?;
    t.controller.fast = pf64s(oj, "fast")?;
    t.controller.slow = pf64s(oj, "slow")?;
    t.controller.armed = pbool(oj, "armed")?;
    t.controller.cooldown_left = pusize(oj, "cooldown")?;
    t.controller.triggers = pu64(oj, "triggers")?;
    // cost fits
    let fj = jget(meta, "costs")?;
    t.costs.omega1_s = pf64(fj, "omega1_s")?;
    t.costs.omega2_per_col = pf64(fj, "omega2_per_col")?;
    t.costs.phi1_base_s = pf64(fj, "phi1_base_s")?;
    t.costs.phi1_per_col = pf64(fj, "phi1_per_col")?;
    t.costs.phi2_per_col = pf64(fj, "phi2_per_col")?;
    // cached balancing plan
    t.cached_actions = match jget(meta, "cached_actions")? {
        Json::Null => None,
        Json::Arr(acts) => {
            if acts.len() != m.e {
                return Err(bad("cached plan has the wrong rank count"));
            }
            Some(acts.iter().map(|a| action_from_json(a, m)).collect::<Result<_, _>>()?)
        }
        other => return Err(bad(format!("cached_actions is not null/array: {other:?}"))),
    };
    // balancer
    let bj = jget(meta, "balancer")?;
    let rj = jget(bj, "rng")?.arr().map_err(bad)?;
    if rj.len() != 3 {
        return Err(bad("balancer rng state must be [s0, s1, spare]"));
    }
    let spare = match &rj[2] {
        Json::Null => None,
        Json::Num(n) => Some(*n as f32),
        other => return Err(bad(format!("rng spare is not null/number: {other:?}"))),
    };
    t.balancer.rng = Rng::from_state(
        u64_from(&rj[0], "rng s0")?,
        u64_from(&rj[1], "rng s1")?,
        spare,
    );
    for (w, per_w) in t.balancer.trackers.iter_mut().enumerate() {
        for (k, bt) in per_w.iter_mut().enumerate() {
            for (c, tr) in [("qkv", &mut bt.qkv), ("fc1", &mut bt.fc1), ("fc2", &mut bt.fc2)] {
                let name = format!("bal.var.{w}.{k}.{c}");
                if let Some(v) = snap.opt_f32(&name) {
                    if v.len() != tr.n() {
                        return Err(bad(format!("tracker '{name}' has the wrong width")));
                    }
                    tr.w_var = Some(v.to_vec());
                } else {
                    tr.w_var = None;
                }
            }
        }
    }
    if pbool(bj, "have_snapshots")? {
        let mut snaps = Vec::with_capacity(m.e);
        for w in 0..m.e {
            let mut per_w = Vec::with_capacity(m.depth);
            for k in 0..m.depth {
                let mut wqkv = Tensor::zeros(&shard_dims(m, "wqkv"));
                let mut w1 = Tensor::zeros(&shard_dims(m, "w1"));
                let mut w2 = Tensor::zeros(&shard_dims(m, "w2"));
                copy_into(snap, &format!("bal.snap.{w}.{k}.wqkv"), &mut wqkv)?;
                copy_into(snap, &format!("bal.snap.{w}.{k}.w1"), &mut w1)?;
                copy_into(snap, &format!("bal.snap.{w}.{k}.w2"), &mut w2)?;
                per_w.push((wqkv, w1, w2));
            }
            snaps.push(per_w);
        }
        t.balancer.snapshots = snaps;
    } else {
        t.balancer.snapshots = Vec::new();
    }
    for (w, per_w) in t.balancer.pruned_epoch.iter_mut().enumerate() {
        for (k, kinds) in per_w.iter_mut().enumerate() {
            for (i, marks) in kinds.iter_mut().enumerate() {
                let v = snap.u8(&format!("bal.pruned.{w}.{k}.{i}"))?;
                if v.len() != marks.len() {
                    return Err(bad(format!("pruned marks {w}.{k}.{i} have the wrong width")));
                }
                for (dst, &src) in marks.iter_mut().zip(v) {
                    *dst = src != 0;
                }
            }
        }
    }
    Ok(())
}

/// Elastic restore: re-shard model + momentum onto the current worker
/// count; rank-shaped transient state (clocks, monitor, controller,
/// balancer statistics, cached plan, gradient history) re-initializes,
/// and the pretest cost fits recompute for the new shard widths, so the
/// Eq. 2/3 allocation re-runs before the first resumed iteration.
/// Continuation is loss-equivalent, not bitwise (DESIGN.md §13).
fn restore_elastic(
    t: &mut Trainer,
    snap: &Snapshot,
    ck_e: usize,
    ck_deg: crate::runtime::manifest::Degrees,
) -> Result<(), CkptError> {
    let new_m = t.rt.manifest.model.clone();
    let old_man =
        crate::runtime::presets::synthesize_with_degrees(&new_m.name, ck_e, ck_deg)
            .map_err(|e| CkptError::Incompatible(format!("elastic resume: {e}")))?;
    let old_m = old_man.model;
    // model parameters: fill the old geometry, undo TP, re-shard
    let mut old_state = zero_state(&old_m);
    for w in 0..old_m.e {
        for k in 0..old_m.depth {
            for n in BlockShard::names() {
                copy_into(snap, &format!("model.{w}.{k}.{n}"), old_state.shards[w][k].get_mut(n))?;
            }
        }
    }
    for n in RepParams::names() {
        copy_into(snap, &format!("model.rep.{n}"), old_state.rep.get_mut(n))?;
    }
    let full = elastic::gather_full(&old_m, &old_state);
    t.state = elastic::shard_full(&new_m, &full);
    // optimizer momentum re-shards with exactly the same slicing
    let has_shard_moments = snap
        .entry_names()
        .any(|n| n.strip_prefix("opt.").is_some_and(|k| !k.starts_with("rep.")));
    t.opt.bufs.clear();
    if has_shard_moments {
        let mut old_mom = zero_state(&old_m);
        for w in 0..old_m.e {
            for k in 0..old_m.depth {
                for n in BlockShard::names() {
                    let key = format!("opt.{w}.{k}.{n}");
                    if snap.has(&key) {
                        copy_into(snap, &key, old_mom.shards[w][k].get_mut(n))?;
                    }
                }
            }
        }
        let mom = elastic::shard_full(&new_m, &elastic::gather_full(&old_m, &old_mom));
        for w in 0..new_m.e {
            for k in 0..new_m.depth {
                for n in BlockShard::names() {
                    // ranks outside a tensor's component group never step
                    // it — their moment keys stay absent, exactly like
                    // the live path (`elastic::reshard_moments`)
                    if w >= crate::model::shard_degree(&new_m, n) {
                        continue;
                    }
                    t.opt
                        .bufs
                        .insert(format!("{w}.{k}.{n}"), mom.shards[w][k].get(n).clone());
                }
            }
        }
    }
    for n in RepParams::names() {
        let key = format!("opt.rep.{n}");
        if snap.has(&key) {
            let mut buf = Tensor::zeros(&rep_dims(&new_m, n));
            copy_into(snap, &key, &mut buf)?;
            t.opt.bufs.insert(format!("rep.{n}"), buf);
        }
    }
    // rank-shaped transient state stays freshly initialized (Trainer::new
    // already sized everything for the new e); recompute the cost fits
    // against the new shard widths
    t.epoch_compute = vec![0.0; new_m.e];
    t.cached_actions = None;
    t.costs = t.fresh_cost_fit();
    // sim clocks: a re-shard is a barrier, so every new rank starts at the
    // checkpointed frontier.  The live transition path
    // (`Trainer::transition_to`) does exactly the same, which is what
    // keeps modeled rt identical between an in-process E→E' switch and
    // this kill/resume oracle (tests/elastic_live.rs).
    let ct = pf64s(jget(&snap.meta, "clocks")?, "t")?;
    let frontier = ct.iter().cloned().fold(0.0f64, f64::max);
    t.clocks = crate::cluster::Clocks::new(new_m.e);
    t.clocks.t.fill(frontier);
    Ok(())
}

fn param_dims(m: &ModelInfo, key: &str) -> Option<Vec<usize>> {
    if let Some(n) = key.strip_prefix("rep.") {
        if RepParams::names().iter().any(|&x| x == n) {
            return Some(rep_dims(m, n));
        }
        return None;
    }
    let mut it = key.splitn(3, '.');
    let w: usize = it.next()?.parse().ok()?;
    let k: usize = it.next()?.parse().ok()?;
    let n = it.next()?;
    if w >= m.e || k >= m.depth || !BlockShard::names().iter().any(|&x| x == n) {
        return None;
    }
    Some(shard_dims(m, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Strategy;

    #[test]
    fn fingerprint_pins_math_but_not_threads_or_epochs() {
        let mut a = RunCfg::new("vit-tiny");
        let b = a.clone();
        a.train.threads = 7;
        a.train.epochs = 99;
        a.train.emulate_wall = true;
        a.train.timeline = true;
        a.train.ckpt_every = 3;
        // the transport is a pure data plane — a tcp run may resume an
        // inproc checkpoint (tests/transport_parity.rs)
        a.train.transport = crate::config::TransportKind::Tcp;
        a.train.transport_timeout_ms = 123;
        a.train.rank_exe = Some(std::path::PathBuf::from("/tmp/flextp"));
        // tracing has zero observer effect (tests/trace_determinism.rs):
        // a traced run may resume an untraced checkpoint and vice versa
        a.train.trace = true;
        a.train.trace_out = Some(std::path::PathBuf::from("/tmp/flextp_trace"));
        a.train.trace_ring = 128;
        assert_eq!(cfg_fingerprint(&a), cfg_fingerprint(&b), "non-math knobs must not pin");
        let mut c = b.clone();
        c.train.seed = 43;
        assert_ne!(cfg_fingerprint(&b), cfg_fingerprint(&c));
        let mut d = b.clone();
        d.balancer.strategy = Strategy::Semi;
        assert_ne!(cfg_fingerprint(&b), cfg_fingerprint(&d));
        let mut e = b.clone();
        e.stragglers = StragglerPlan::Fixed(vec![2.0, 1.0]);
        assert_ne!(cfg_fingerprint(&b), cfg_fingerprint(&e));
        // memory budgets gate the plan filter and recompute fallback —
        // they are math knobs and must pin
        let mut f = b.clone();
        f.train.mem_cap = Some(64 << 20);
        assert_ne!(cfg_fingerprint(&b), cfg_fingerprint(&f));
        let mut g = b.clone();
        g.train.mem_caps = vec![(1, 32 << 20)];
        assert_ne!(cfg_fingerprint(&b), cfg_fingerprint(&g));
        let mut h = b.clone();
        h.train.mem_recompute = true;
        assert_ne!(cfg_fingerprint(&b), cfg_fingerprint(&h));
    }

    #[test]
    fn plan_desc_distinguishes_and_roundtrips_scenarios() {
        use crate::contention::ScenarioSpec;
        assert_eq!(plan_desc(&StragglerPlan::None), "none");
        let s = ScenarioSpec::parse("burst:r1@x4:iters2-5,seed:9").unwrap();
        let d = plan_desc(&StragglerPlan::Scenario(s.clone()));
        // the descriptor re-parses to the same spec — the trace-cursor
        // persistence contract
        let re = ScenarioSpec::parse(d.strip_prefix("scenario:").unwrap()).unwrap();
        assert_eq!(re, s);
    }

    #[test]
    fn action_json_roundtrip_and_validation() {
        let man = crate::runtime::presets::synthesize("vit-tiny").unwrap();
        let m = man.model.clone();
        let mig = crate::migration::plan(&man, 0, 0.5, 1.0, None).unwrap();
        let mut a = WorkerAction::full(&man);
        a.layers[0].mlp_keep2 = mig.kept.clone();
        a.mig = Some(mig);
        let j = action_to_json(&a);
        let r = action_from_json(&j, &m).unwrap();
        assert_eq!(r.layers[0].mlp_keep2, a.layers[0].mlp_keep2);
        let (ra, aa) = (r.mig.unwrap(), a.mig.unwrap());
        assert_eq!(ra.migrated, aa.migrated);
        assert_eq!(ra.receivers.len(), aa.receivers.len());
        // out-of-range indices are rejected, not deferred to a panic
        let mut b = WorkerAction::full(&man);
        b.layers[0].attn_keep = vec![m.hs as u32 + 7];
        assert!(action_from_json(&action_to_json(&b), &m).is_err());
    }

    #[test]
    fn u64_values_survive_the_json_trip() {
        let big = u64::MAX - 12345;
        let j = obj([("x", ju64(big))]);
        assert_eq!(pu64(&j, "x").unwrap(), big);
        let j = Json::parse(&j.to_string()).unwrap();
        assert_eq!(pu64(&j, "x").unwrap(), big, "string form survives emission");
    }
}
