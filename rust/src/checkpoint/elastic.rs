//! Elastic re-sharding: move saved 1D-TP state between worker counts.
//!
//! The shard layout (DESIGN.md §2, `model` module docs) is the classic
//! column-then-row split, so the full model is recoverable by pure
//! concatenation and re-shardable by pure slicing — both bitwise-exact
//! copies, no arithmetic:
//!
//! * `wqkv [hs, 3·hsl]` — worker w's packed q|k|v column panels; head
//!   `h ∈ [w·hl, (w+1)·hl)` of the full `[hs, hs]` q (resp. k, v) matrix
//!   lands at local q-columns `(h − w·hl)·hd ..`.  Because heads are
//!   assigned to workers in contiguous blocks, worker w's q panel is
//!   exactly full-q columns `[w·hsl, (w+1)·hsl)` — the same contiguous
//!   range math as the cluster's migration slicing, with `E` equal parts
//!   instead of `E−1` renumbered ones.
//! * `wo [hsl, hs]` — row split of the full `[hs, hs]` output projection.
//! * `w1 [hs, ffl]` / `w2 [ffl, hs]` — column / row split of the full
//!   `[hs, 4·hs]` / `[4·hs, hs]` FFN matrices.
//! * LayerNorm vectors and the embed/head replica are replicated; the
//!   trainer's all-reduced-gradient invariant keeps every worker's copy
//!   bit-identical, so worker 0's copy stands for all.
//!
//! With fine-grained per-component degrees (DESIGN.md §18) each
//! component concatenates over its **own** group — attention panels over
//! ranks `0..degrees.attn`, FFN panels over ranks `0..degrees.mlp` — and
//! re-sharding distributes back onto each target group.  Ranks outside a
//! component's group hold zero-filled shard slots: they carry no model
//! content, and both directions skip them.  Re-sharding onto `E'`
//! requires `E' | hs`, with attention clamped to whole-head degrees
//! (checked by [`crate::runtime::presets::synthesize_with_e`] /
//! [`crate::runtime::presets::synthesize_with_degrees`]).  Optimizer
//! momentum buffers are per-element and re-shard with exactly the same
//! slicing.

use std::collections::BTreeMap;

use crate::model::{shard_degree, BlockShard, ModelState, RepParams};
use crate::runtime::manifest::{Degrees, ModelInfo};
use crate::tensor::Tensor;

/// One transformer block's unsharded weights.
#[derive(Debug, Clone, PartialEq)]
pub struct FullBlock {
    pub ln1_g: Tensor,
    pub ln1_b: Tensor,
    /// `[hs, 3·hs]`, q|k|v column sections
    pub wqkv: Tensor,
    /// `[hs, hs]`
    pub wo: Tensor,
    pub ln2_g: Tensor,
    pub ln2_b: Tensor,
    /// `[hs, 4·hs]`
    pub w1: Tensor,
    /// `[4·hs, hs]`
    pub w2: Tensor,
}

/// The whole model with tensor parallelism undone.
#[derive(Debug, Clone, PartialEq)]
pub struct FullModel {
    pub blocks: Vec<FullBlock>,
    pub rep: RepParams,
}

/// Copy `src[:, 0..w]` into `dst[:, at..at+w]` (row-major, same row count).
fn put_cols(dst: &mut Tensor, at: usize, src: &Tensor) {
    let (rows, dc) = dst.as_2d();
    let (srows, sc) = src.as_2d();
    assert_eq!(rows, srows, "column-panel row mismatch");
    assert!(at + sc <= dc, "column panel out of range");
    for r in 0..rows {
        dst.data[r * dc + at..r * dc + at + sc]
            .copy_from_slice(&src.data[r * sc..(r + 1) * sc]);
    }
}

/// Extract `src[:, at..at+w]` as a fresh `[rows, w]` tensor.
fn get_cols(src: &Tensor, at: usize, w: usize) -> Tensor {
    let (rows, sc) = src.as_2d();
    assert!(at + w <= sc, "column slice out of range");
    let mut data = Vec::with_capacity(rows * w);
    for r in 0..rows {
        data.extend_from_slice(&src.data[r * sc + at..r * sc + at + w]);
    }
    Tensor::from_vec(&[rows, w], data)
}

/// Copy `src` (shape `[h, cols]`) into `dst[at..at+h, :]`.
fn put_rows(dst: &mut Tensor, at: usize, src: &Tensor) {
    let (dr, dc) = dst.as_2d();
    let (sr, sc) = src.as_2d();
    assert_eq!(dc, sc, "row-panel column mismatch");
    assert!(at + sr <= dr, "row panel out of range");
    dst.data[at * dc..(at + sr) * dc].copy_from_slice(&src.data);
}

/// Extract `src[at..at+h, :]` as a fresh `[h, cols]` tensor.
fn get_rows(src: &Tensor, at: usize, h: usize) -> Tensor {
    let (sr, sc) = src.as_2d();
    assert!(at + h <= sr, "row slice out of range");
    Tensor::from_vec(&[h, sc], src.data[at * sc..(at + h) * sc].to_vec())
}

/// Undo the 1D-TP split: concatenate each component group's shards into
/// the full per-block matrices.  Pure copies — bitwise-exact.  Attention
/// panels come from ranks `0..degrees.attn`, FFN panels from ranks
/// `0..degrees.mlp`; ranks outside a group hold zero slots with no model
/// content and are skipped.
pub fn gather_full(m: &ModelInfo, state: &ModelState) -> FullModel {
    let (hs, hsl, ffl) = (m.hs, m.hsl, m.ffl);
    let mut blocks = Vec::with_capacity(m.depth);
    for k in 0..m.depth {
        let b0 = &state.shards[0][k];
        let mut wqkv = Tensor::zeros(&[hs, 3 * hs]);
        let mut wo = Tensor::zeros(&[hs, hs]);
        let mut w1 = Tensor::zeros(&[hs, m.degrees.mlp * ffl]);
        let mut w2 = Tensor::zeros(&[m.degrees.mlp * ffl, hs]);
        for w in 0..m.degrees.attn {
            let b = &state.shards[w][k];
            // local q|k|v sections map to the full q|k|v sections at the
            // worker's contiguous head-column range
            for sec in 0..3 {
                let local = get_cols(&b.wqkv, sec * hsl, hsl);
                put_cols(&mut wqkv, sec * hs + w * hsl, &local);
            }
            put_rows(&mut wo, w * hsl, &b.wo);
        }
        for w in 0..m.degrees.mlp {
            let b = &state.shards[w][k];
            put_cols(&mut w1, w * ffl, &b.w1);
            put_rows(&mut w2, w * ffl, &b.w2);
        }
        blocks.push(FullBlock {
            ln1_g: b0.ln1_g.clone(),
            ln1_b: b0.ln1_b.clone(),
            wqkv,
            wo,
            ln2_g: b0.ln2_g.clone(),
            ln2_b: b0.ln2_b.clone(),
            w1,
            w2,
        });
    }
    FullModel { blocks, rep: state.rep.clone() }
}

/// Re-apply the 1D-TP split for a (possibly different) worker count
/// and/or degree vector.  `m2` must describe the same model geometry
/// (`hs`, `depth`) with its own degree-derived shard widths.  Pure
/// copies — bitwise-exact, and an exact inverse of [`gather_full`] for
/// any valid geometry.  Ranks outside a component's target group get
/// zero-filled slots at the member shapes (the canonical encoding of
/// "holds no model content").
pub fn shard_full(m2: &ModelInfo, full: &FullModel) -> ModelState {
    let (hs, hsl, ffl) = (m2.hs, m2.hsl, m2.ffl);
    let mut shards = Vec::with_capacity(m2.e);
    for w in 0..m2.e {
        let mut blocks = Vec::with_capacity(m2.depth);
        for fb in &full.blocks {
            let (wqkv, wo) = if w < m2.degrees.attn {
                let mut wqkv = Tensor::zeros(&[hs, 3 * hsl]);
                for sec in 0..3 {
                    let panel = get_cols(&fb.wqkv, sec * hs + w * hsl, hsl);
                    put_cols(&mut wqkv, sec * hsl, &panel);
                }
                (wqkv, get_rows(&fb.wo, w * hsl, hsl))
            } else {
                (Tensor::zeros(&[hs, 3 * hsl]), Tensor::zeros(&[hsl, hs]))
            };
            let (w1, w2) = if w < m2.degrees.mlp {
                (get_cols(&fb.w1, w * ffl, ffl), get_rows(&fb.w2, w * ffl, ffl))
            } else {
                (Tensor::zeros(&[hs, ffl]), Tensor::zeros(&[ffl, hs]))
            };
            blocks.push(BlockShard {
                ln1_g: fb.ln1_g.clone(),
                ln1_b: fb.ln1_b.clone(),
                wqkv,
                wo,
                ln2_g: fb.ln2_g.clone(),
                ln2_b: fb.ln2_b.clone(),
                w1,
                w2,
            });
        }
        shards.push(blocks);
    }
    ModelState { shards, rep: full.rep.clone() }
}

/// Re-shard a live [`ModelState`] from geometry `m1` to `m2` in one
/// step — the in-memory transition path (DESIGN.md §14): live elastic
/// re-parallelization moves state between worker counts without a
/// `.flexckpt` round-trip, with the same bitwise-exactness guarantee as
/// the checkpoint path (both are [`gather_full`] ∘ [`shard_full`]).
pub fn reshard_state(m1: &ModelInfo, m2: &ModelInfo, s: &ModelState) -> ModelState {
    shard_full(m2, &gather_full(m1, s))
}

/// Re-shard SGD momentum buffers (keys `"{w}.{k}.{name}"` for shard
/// tensors, `"rep.{name}"` for the replicated embed/head) from `m1`'s
/// layout to `m2`'s.  Momentum is per-element, so it re-slices exactly
/// like the weights; `rep.*` buffers are e-independent and carry over
/// unchanged.  When no shard buffers exist (momentum 0, or a run too
/// young to have created them) none are invented — matching the
/// checkpoint elastic-restore path bit for bit.
pub fn reshard_moments(
    m1: &ModelInfo,
    m2: &ModelInfo,
    bufs: &BTreeMap<String, Tensor>,
) -> BTreeMap<String, Tensor> {
    let mut out = BTreeMap::new();
    if bufs.keys().any(|k| !k.starts_with("rep.")) {
        let mut old = super::zero_state(m1);
        for w in 0..m1.e {
            for k in 0..m1.depth {
                for n in BlockShard::names() {
                    if w >= shard_degree(m1, n) {
                        continue; // non-member slot: no momentum content
                    }
                    if let Some(b) = bufs.get(&format!("{w}.{k}.{n}")) {
                        old.shards[w][k].get_mut(n).data.copy_from_slice(&b.data);
                    }
                }
            }
        }
        let new = reshard_state(m1, m2, &old);
        for w in 0..m2.e {
            for k in 0..m2.depth {
                for n in BlockShard::names() {
                    if w >= shard_degree(m2, n) {
                        continue; // non-members never step, so no buffer
                    }
                    out.insert(format!("{w}.{k}.{n}"), new.shards[w][k].get(n).clone());
                }
            }
        }
    }
    for (k, b) in bufs {
        if k.starts_with("rep.") {
            out.insert(k.clone(), b.clone());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// hs=32, heads=8 (hd=4) so e ∈ {1, 2, 4, 8} are all valid.
    fn info(e: usize) -> ModelInfo {
        assert_eq!(32 % e, 0);
        assert_eq!(8 % e, 0);
        ModelInfo {
            name: "t".into(),
            hs: 32,
            depth: 2,
            heads: 8,
            e,
            bs: 2,
            classes: 10,
            seq: 17,
            seq0: 16,
            pd: 48,
            hsl: 32 / e,
            hl: 8 / e,
            hd: 4,
            ffl: 4 * 32 / e,
            params_total: 0,
            params_per_worker: 0,
            degrees: Degrees::uniform(e),
        }
    }

    /// Mixed per-component degrees over `e` workers: attn/mlp shard
    /// widths follow their own group sizes.
    fn info_mixed(e: usize, d: Degrees) -> ModelInfo {
        let mut m = info(e);
        assert!(d.attn <= e && d.mlp <= e && 32 % d.attn == 0 && 8 % d.attn == 0);
        assert_eq!((4 * 32) % d.mlp, 0);
        m.hsl = 32 / d.attn;
        m.hl = 8 / d.attn;
        m.ffl = 4 * 32 / d.mlp;
        m.degrees = d;
        m
    }

    #[test]
    fn gather_shard_roundtrips_same_e() {
        let m = info(4);
        let s = ModelState::init(&m, 3);
        let full = gather_full(&m, &s);
        let back = shard_full(&m, &full);
        for w in 0..4 {
            for k in 0..2 {
                for n in BlockShard::names() {
                    assert_eq!(
                        s.shards[w][k].get(n).data,
                        back.shards[w][k].get(n).data,
                        "w={w} k={k} {n}"
                    );
                }
            }
        }
    }

    #[test]
    fn reshard_preserves_full_model_exactly() {
        // 4 → 2 → 8 → 4: the full model must be bitwise stable across
        // arbitrary re-partitions (the elastic-resume exactness claim).
        let m4 = info(4);
        let s4 = ModelState::init(&m4, 7);
        let full = gather_full(&m4, &s4);
        let s2 = shard_full(&info(2), &full);
        let full2 = gather_full(&info(2), &s2);
        assert_eq!(full, full2, "4→2 changed the full model");
        let s8 = shard_full(&info(8), &full2);
        let full8 = gather_full(&info(8), &s8);
        assert_eq!(full, full8, "2→8 changed the full model");
        let s4b = shard_full(&m4, &full8);
        assert_eq!(
            gather_full(&m4, &s4b),
            full,
            "8→4 changed the full model"
        );
    }

    #[test]
    fn mixed_roundtrip_members_bitwise_nonmembers_zeroed() {
        // attn group = ranks 0..2, mlp group = all 4.  Re-sharding onto
        // the same mixed geometry must return member panels bitwise and
        // canonicalize non-member attn slots (which carry no model
        // content) to zero.
        let d = Degrees { embed: 4, attn: 2, mlp: 4, head: 4 };
        let m = info_mixed(4, d);
        let s = ModelState::init(&m, 11);
        let back = shard_full(&m, &gather_full(&m, &s));
        for w in 0..4 {
            for k in 0..2 {
                for n in BlockShard::names() {
                    if w < shard_degree(&m, n) {
                        assert_eq!(
                            s.shards[w][k].get(n).data,
                            back.shards[w][k].get(n).data,
                            "member w={w} k={k} {n}"
                        );
                    } else {
                        assert!(
                            back.shards[w][k].get(n).data.iter().all(|&v| v == 0.0),
                            "non-member w={w} k={k} {n} not zeroed"
                        );
                        assert_eq!(
                            back.shards[w][k].get(n).dims,
                            s.shards[w][k].get(n).dims,
                            "non-member slot shape w={w} k={k} {n}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn mixed_divisor_chain_preserves_full_model() {
        // uniform(4) → mixed attn=1/mlp=2 over 4 → uniform(8) (every
        // degree = E) → mixed attn=2/mlp=8 over 8 → uniform(4): the full
        // model must be bitwise stable across every hop, including the
        // degenerate degrees e_c = 1 and e_c = E.
        let m4 = info(4);
        let full = gather_full(&m4, &ModelState::init(&m4, 9));
        let ma = info_mixed(4, Degrees { embed: 1, attn: 1, mlp: 2, head: 1 });
        let fa = gather_full(&ma, &shard_full(&ma, &full));
        assert_eq!(full, fa, "4 → mixed(a1,m2) changed the full model");
        let m8 = info(8);
        let fb = gather_full(&m8, &shard_full(&m8, &fa));
        assert_eq!(full, fb, "mixed → uniform(8) changed the full model");
        let mc = info_mixed(8, Degrees { embed: 8, attn: 2, mlp: 8, head: 8 });
        let fc = gather_full(&mc, &shard_full(&mc, &fb));
        assert_eq!(full, fc, "uniform(8) → mixed(a2,m8) changed the full model");
        let fd = gather_full(&m4, &shard_full(&m4, &fc));
        assert_eq!(full, fd, "mixed → uniform(4) changed the full model");
    }

    #[test]
    fn reshard_moments_mixed_keeps_member_keys_only() {
        let m1 = info(4);
        let d = Degrees { embed: 4, attn: 2, mlp: 4, head: 4 };
        let m2 = info_mixed(4, d);
        let src = ModelState::init(&m1, 5);
        let mut bufs = BTreeMap::new();
        for w in 0..4 {
            for k in 0..2 {
                for n in BlockShard::names() {
                    bufs.insert(format!("{w}.{k}.{n}"), src.shards[w][k].get(n).clone());
                }
            }
        }
        bufs.insert("rep.w_head".into(), src.rep.w_head.clone());
        let out = reshard_moments(&m1, &m2, &bufs);
        // attn buffers only for ranks 0..2; mlp buffers for all 4
        assert!(out.contains_key("1.0.wqkv"));
        assert!(!out.contains_key("2.0.wqkv"), "non-member attn buffer leaked");
        assert!(!out.contains_key("3.1.wo"), "non-member attn buffer leaked");
        assert!(out.contains_key("3.1.w1"));
        // member buffers re-slice exactly like the weights
        let want = reshard_state(&m1, &m2, &src);
        assert_eq!(out["1.0.wqkv"].data, want.shards[1][0].wqkv.data);
        assert_eq!(out["3.1.w2"].data, want.shards[3][1].w2.data);
        // replicated buffers pass through untouched
        assert_eq!(out["rep.w_head"].data, src.rep.w_head.data);
    }

    #[test]
    fn qkv_head_panels_land_in_head_order() {
        // Fill worker shards with values encoding (section, global col)
        // and verify the gathered q|k|v sections are column-ordered.
        let m = info(2);
        let mut s = ModelState::init(&m, 1);
        for w in 0..2 {
            for (sec, base) in [(0usize, 0.0f32), (1, 1000.0), (2, 2000.0)] {
                for r in 0..m.hs {
                    for c in 0..m.hsl {
                        let global = (w * m.hsl + c) as f32;
                        s.shards[w][0].wqkv.data[r * 3 * m.hsl + sec * m.hsl + c] =
                            base + global + r as f32 * 0.001;
                    }
                }
            }
        }
        let full = gather_full(&m, &s);
        for (sec, base) in [(0usize, 0.0f32), (1, 1000.0), (2, 2000.0)] {
            for c in 0..m.hs {
                let v = full.blocks[0].wqkv.data[sec * m.hs + c];
                assert_eq!(v, base + c as f32, "sec={sec} col={c}");
            }
        }
    }
}
